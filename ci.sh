#!/usr/bin/env bash
# Tier-1 verify in one command: build, tests, formatting, lints.
#
# Usage: ./ci.sh [--no-clippy] [--no-fmt] [--bench-commit]
#   SD_ACC_PROP_CASES=16 ./ci.sh     # trim property-test cases for speed
#   ./ci.sh --bench-commit           # also refresh BENCH_obs.json,
#                                    # BENCH_chaos.json and BENCH_policy.json
#                                    # (repo root) after validating schemas
#                                    # and budgets
#
# The crate builds fully offline: external deps are vendored under
# rust/vendor (anyhow subset + backend-less xla stub), so no network or
# crates.io cache is required. In artifact-less containers the
# integration suites and the runtime-backed bench sections EXECUTE on
# the deterministic pure-Rust sim backend (SD_ACC_BACKEND=sim) instead
# of skipping; when artifacts/manifest.json exists the xla path is used
# unchanged.

set -euo pipefail
cd "$(dirname "$0")/rust"

# Resolve the artifacts dir the same way the code does (SD_ACC_ARTIFACTS
# override honoured), and never clobber an explicit backend choice.
if [ -z "${SD_ACC_BACKEND:-}" ] && [ ! -f "${SD_ACC_ARTIFACTS:-artifacts}/manifest.json" ]; then
    export SD_ACC_BACKEND=sim
    echo "no artifacts manifest — integration suites and smoke benches run on the sim backend"
fi

run_clippy=1
run_fmt=1
bench_commit=0
for arg in "$@"; do
    case "$arg" in
        --no-clippy) run_clippy=0 ;;
        --no-fmt) run_fmt=0 ;;
        --bench-commit) bench_commit=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# Includes every integration suite (pipeline, server, api, runtime,
# quant, cache, backend). With SD_ACC_BACKEND=sim exported above, the
# runtime-backed bodies execute on the deterministic sim backend in
# artifact-less containers — nothing skips.
cargo test -q

echo "== quant bench (smoke) =="
# Cheap precision-sweep pass: asserts the precision-scaled cost model's
# acceptance bands (W8A8 >= 3x energy vs fp32, monotone quality/traffic)
# so regressions fail CI, not just the full bench run.
cargo bench --bench bench_quant -- --smoke

echo "== serving bench (smoke) =="
# Serving hot-path pass: warm request-cache hit >= 3x the cold
# regenerate-and-repopulate floor, batch occupancy only uses compiled
# sizes, and the job API's event-channel path (one streamed Step event
# per denoising step + a cancellation poll) adds < 5% p50 overhead over
# the blocking step loop. The live-serving section executes on the
# resolved backend (sim here without artifacts) instead of skipping.
# Full mode writes BENCH_serving.json at repo root, including
# submit->event->done and cancel-ack latency.
cargo bench --bench bench_serving -- --smoke

echo "== obs bench (smoke) =="
# Observability pass: deterministic sim-backed workload through a traced
# server. Asserts the BENCH_obs.json schema (required keys, non-zero
# step/byte counters, cache_hit_ratio in [0,1]), exactly one terminal
# span per job in the trace ring, and — when the counting allocator is
# active — that allocs/step stays within the committed
# allocs_per_step_limit. Writes nothing.
cargo bench --bench bench_obs -- --smoke

echo "== trace analytics (smoke) =="
# End-to-end CLI pass over a real recorded trace: serve writes a JSONL
# span trace on the sim backend, then `trace --analyze --strict` must
# produce a non-empty phase decomposition (and find no orphan jobs),
# and the Chrome/Perfetto exporter must emit JSON that parses back
# through util::json (the CLI prints "(validated)" only after the
# round-trip succeeds).
trace_tmp="$(mktemp -d "${TMPDIR:-/tmp}/sdacc_ci_trace.XXXXXX")"
trap 'rm -rf "$trace_tmp"' EXIT
./target/release/sd-acc serve --requests 4 --steps 3 --workers 1 \
    --trace-out "$trace_tmp/trace.jsonl" > /dev/null
analyze_out="$(./target/release/sd-acc trace "$trace_tmp/trace.jsonl" \
    --analyze --strict --export-chrome "$trace_tmp/trace.chrome.json")"
echo "$analyze_out" | grep -q "where does a millisecond go" \
    || { echo "trace --analyze produced no decomposition table" >&2; exit 1; }
echo "$analyze_out" | grep -q "(validated)" \
    || { echo "chrome export did not self-validate" >&2; exit 1; }
rm -rf "$trace_tmp"

echo "== policy bench (smoke) =="
# Approximation-policy pass: on the sim backend, the cold-started
# StabilityPolicy (no calibration.json anywhere) must skip at least as
# many MACs as the calibrated 25-step PAS plan while staying inside its
# latent-PSNR quality band against the shared full-trajectory
# reference. Writes nothing; full mode refreshes BENCH_policy.json.
cargo bench --bench bench_policy -- --smoke

echo "== chaos bench (smoke) =="
# Resilience pass: a seeded transient-fault wave (closed loop) must
# recover >=95% of retried jobs with exactly one terminal each, and the
# bursty load-engine phase must engage brownout against one worker.
# Writes nothing; full mode refreshes BENCH_chaos.json at repo root.
cargo bench --bench bench_chaos -- --smoke

echo "== chaos serve lane =="
# End-to-end CLI pass: deterministic fault injection (--chaos, sim-only)
# plus the bursty deterministic load engine (--load) with shedding and
# brownout armed. The serve report's always-printed resilience line is
# the gate: the fault schedule must produce retries, and the burst
# pattern must drive at least one brownout transition.
chaos_out="$(./target/release/sd-acc serve --backend sim \
    --chaos "seed=7,err=0.10,slow=0.03,slow_ms=1" \
    --load "bursty:rate=800,burst=12@6,n=36,seed=3,steps=3,cooldown=8" \
    --workers 2 --shed-low 6 --brownout 5:2)"
echo "$chaos_out" | grep -q "chaos: deterministic fault injection armed" \
    || { echo "chaos serve lane: --chaos did not arm fault injection" >&2; exit 1; }
echo "$chaos_out" | grep -qE "resilience: [1-9][0-9]* retries" \
    || { echo "chaos serve lane: fault schedule produced no retries" >&2; exit 1; }
echo "$chaos_out" | grep -qE "[1-9][0-9]* brownout transitions" \
    || { echo "chaos serve lane: burst load never engaged brownout" >&2; exit 1; }

echo "== wire serve lane =="
# Two serve processes over loopback HTTP/SSE sharing one on-disk cache:
# process A handles a mixed done+cancel workload via `sd-acc request`
# (exactly one `terminal:` line per job, streamed `event:` frames);
# process B, started afterwards on the same --cache-dir, must answer the
# identical request with a cross-process `cache-hit` frame and the same
# latent checksum. Both drain via `request --shutdown`.
wire_tmp="$(mktemp -d "${TMPDIR:-/tmp}/sdacc_ci_wire.XXXXXX")"
wire_a=""; wire_b=""
trap 'kill $wire_a $wire_b 2>/dev/null || true; rm -rf "$wire_tmp"' EXIT
sd="./target/release/sd-acc"
wire_addr() { sed -n 's/^listening on //p' "$1" 2>/dev/null | head -n1 || true; }
wait_addr() { # wait_addr <log> -> prints the bound address or nothing
    for _ in $(seq 1 100); do
        local a; a="$(wire_addr "$1")"
        if [ -n "$a" ]; then echo "$a"; return 0; fi
        sleep 0.1
    done
}

"$sd" serve --backend sim --workers 1 --listen 127.0.0.1:0 \
    --cache-dir "$wire_tmp/cache" > "$wire_tmp/a.log" 2>&1 &
wire_a=$!
addr_a="$(wait_addr "$wire_tmp/a.log")"
[ -n "$addr_a" ] || { echo "wire lane: serve A never reported its address" >&2; cat "$wire_tmp/a.log" >&2; exit 1; }

done_out="$("$sd" request --addr "$addr_a" \
    --prompt "wire lane red circle x4 y4" --seed 11 --steps 3)"
echo "$done_out" | grep -q '^event: ' \
    || { echo "wire lane: no SSE event frames streamed" >&2; echo "$done_out" >&2; exit 1; }
echo "$done_out" | grep -q '^terminal: done$' \
    || { echo "wire lane: done job did not end in terminal: done" >&2; echo "$done_out" >&2; exit 1; }
[ "$(echo "$done_out" | grep -c '^terminal: ')" = 1 ] \
    || { echo "wire lane: expected exactly one terminal line for the done job" >&2; exit 1; }
fnv_cold="$(echo "$done_out" | sed -n 's/^done: .*latent_fnv //p')"
[ -n "$fnv_cold" ] || { echo "wire lane: done report carried no latent_fnv" >&2; exit 1; }

# Cancel mid-stream: DELETE after two streamed events on a long job.
cancel_out="$("$sd" request --addr "$addr_a" --prompt "wire lane cancel me" \
    --seed 12 --steps 2000 --cancel-after-events 2)"
echo "$cancel_out" | grep -q '^terminal: cancelled$' \
    || { echo "wire lane: cancel job did not end in terminal: cancelled" >&2; echo "$cancel_out" >&2; exit 1; }
[ "$(echo "$cancel_out" | grep -c '^terminal: ')" = 1 ] \
    || { echo "wire lane: expected exactly one terminal line for the cancel job" >&2; exit 1; }

"$sd" serve --backend sim --workers 1 --listen 127.0.0.1:0 \
    --cache-dir "$wire_tmp/cache" > "$wire_tmp/b.log" 2>&1 &
wire_b=$!
addr_b="$(wait_addr "$wire_tmp/b.log")"
[ -n "$addr_b" ] || { echo "wire lane: serve B never reported its address" >&2; cat "$wire_tmp/b.log" >&2; exit 1; }

warm_out="$("$sd" request --addr "$addr_b" \
    --prompt "wire lane red circle x4 y4" --seed 11 --steps 3)"
echo "$warm_out" | grep -q '^event: cache-hit$' \
    || { echo "wire lane: process B missed the cross-process cache hit" >&2; echo "$warm_out" >&2; exit 1; }
fnv_warm="$(echo "$warm_out" | sed -n 's/^done: .*latent_fnv //p')"
[ "$fnv_cold" = "$fnv_warm" ] \
    || { echo "wire lane: cross-process hit checksum mismatch ('$fnv_cold' vs '$fnv_warm')" >&2; exit 1; }

"$sd" request --addr "$addr_a" --shutdown > /dev/null
"$sd" request --addr "$addr_b" --shutdown > /dev/null
wait "$wire_a" "$wire_b"
wire_a=""; wire_b=""
grep -q '^wire drained: ' "$wire_tmp/a.log" \
    || { echo "wire lane: serve A printed no drain report" >&2; cat "$wire_tmp/a.log" >&2; exit 1; }
rm -rf "$wire_tmp"
trap - EXIT
echo "wire lane: done + cancel + cross-process cache hit verified"

echo "== policy serve lane =="
# End-to-end CLI pass for the approximation-policy subsystem: a serve
# run under `--policy stability` plus a load mix spanning two policies
# must complete work under BOTH policy ids (the per-policy report
# lines), and `sd-acc policy list` must print the full registry.
policy_out="$(./target/release/sd-acc serve --backend sim --workers 2 \
    --policy stability \
    --load "closed:n=12,seed=5,steps=3,mix=pas*1+stability:90*1")"
echo "$policy_out" | grep -qE '^policy pas: [1-9][0-9]* ok$' \
    || { echo "policy lane: no completed work under the pas policy" >&2; echo "$policy_out" >&2; exit 1; }
echo "$policy_out" | grep -qE '^policy stability:90: [1-9][0-9]* ok$' \
    || { echo "policy lane: no completed work under the stability policy" >&2; echo "$policy_out" >&2; exit 1; }
list_out="$(./target/release/sd-acc policy list)"
for p in pas block-cache stability text-precision; do
    echo "$list_out" | grep -q "$p" \
        || { echo "policy lane: 'sd-acc policy list' missing '$p'" >&2; exit 1; }
done
echo "policy lane: per-policy goodput + registry listing verified"

if [ "$bench_commit" = 1 ]; then
    echo "== obs bench (commit trajectory point) =="
    # Full measurement; validates schema + the allocs/step budget against
    # the committed limit, then rewrites BENCH_obs.json at the repo root.
    # The limit itself is carried over from the committed file — raising
    # it is a reviewed edit, never an automatic ratchet.
    cargo bench --bench bench_obs -- --commit

    echo "== chaos bench (commit trajectory point) =="
    # Same gates as the smoke lane, then rewrite BENCH_chaos.json.
    cargo bench --bench bench_chaos -- --commit

    echo "== policy bench (commit trajectory point) =="
    # Same gates as the smoke lane, then rewrite BENCH_policy.json.
    cargo bench --bench bench_policy -- --commit
fi

if [ "$run_fmt" = 1 ]; then
    echo "== cargo fmt --check =="
    # Formatting drift fails CI only when rustfmt is installed.
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "rustfmt not installed — skipping"
    fi
fi

if [ "$run_clippy" = 1 ]; then
    echo "== cargo clippy -D warnings =="
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings
    else
        echo "clippy not installed — skipping"
    fi
fi

echo "== ci.sh: all checks passed =="
