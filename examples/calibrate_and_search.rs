//! The Fig. 7 optimisation framework, end to end:
//!
//! 1. measure per-block shift scores over real denoising trajectories
//!    (Eq. 1) with the calib artifact,
//! 2. find the phase transition D* (Eq. 2) and the outlier blocks,
//! 3. enumerate PAS configurations under user constraints ranked by
//!    Eq. 3 MAC reduction,
//! 4. validate the top candidates by generating and scoring the latent
//!    PSNR proxy against full sampling.
//!
//! Writes artifacts/calibration.json (consumed by bench_fig4) and
//! memoizes both phases in the persistent cache: a warm start (second
//! run with the same artifacts + settings) skips the trajectories and
//! the search entirely and replays the stored results.
//!
//! Run: `cargo run --release --example calibrate_and_search`
//! (sim backend without artifacts; `make artifacts` for the xla path)
//! Env: SD_ACC_CALIB_STEPS (default 25), SD_ACC_CALIB_PROMPTS (default 2),
//!      SD_ACC_CACHE (cache dir, default ./cache).

use std::time::Instant;

use sd_acc::cache::{default_cache_dir, StoreConfig};
use sd_acc::coordinator::Coordinator;
use sd_acc::models::inventory::sd_tiny;
use sd_acc::pas::calibrate::Calibrator;
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::search::{SearchConstraints, Searcher};
use sd_acc::runtime::{default_artifacts_dir, RuntimeService};
use sd_acc::util::table::{f, ratio, Table};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    let steps: usize = std::env::var("SD_ACC_CALIB_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(25);
    let n_prompts: usize = std::env::var("SD_ACC_CALIB_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);

    // Backend auto-resolution: xla over artifacts, deterministic sim
    // backend otherwise.
    let svc = RuntimeService::start(&dir)?;
    println!("backend: {}", svc.backend());
    let coord = Coordinator::new(svc.handle());
    let cache = coord.open_cache(StoreConfig::new(default_cache_dir()))?;

    // Step 1+2: calibration (5%-style prompt subset, Sec. III-C).
    let prompts: Vec<String> = [
        "red circle x4 y4 blue square x11 y11",
        "green stripe x8 y8",
        "yellow circle x12 y3 magenta square x5 y10",
    ]
    .iter()
    .take(n_prompts)
    .map(|s| s.to_string())
    .collect();
    println!("calibrating on {} prompts x {steps} steps (complete U-Net trajectories)...", prompts.len());
    let t0 = Instant::now();
    let (report, calib_hit) = Calibrator::new(&coord).run_cached(&cache, &prompts, steps, 7.5)?;
    println!(
        "calibration {} in {:.2}s",
        if calib_hit { "cache hit (trajectories skipped)" } else { "computed" },
        t0.elapsed().as_secs_f64()
    );
    // Only the xla backend persists calibration.json: the file lives in
    // the artifacts dir untagged, and sim-measured shift scores must not
    // be mistaken for measurements of the real model.
    if svc.backend() == sd_acc::runtime::BackendKind::Xla {
        std::fs::write(dir.join("calibration.json"), report.to_json().to_string())?;
    }
    println!("D* = {} / {steps}   outlier blocks = {:?}", report.d_star, report.outliers);
    println!("(full curves: cargo bench --bench bench_fig4_shift_scores)");

    // Step 3: enumerate + rank under constraints.
    let cons = SearchConstraints {
        total_steps: steps,
        min_mac_reduction: 1.6,
        min_psnr_db: Some(13.0),
        max_validate: 3,
    };
    println!(
        "\nsearching: steps={}, min MAC reduction {:.1}x, min PSNR {:?} dB",
        cons.total_steps, cons.min_mac_reduction, cons.min_psnr_db
    );
    let searcher = Searcher { coord: &coord, cost: CostModel::new(&sd_tiny()) };
    let t0 = Instant::now();
    let (cands, search_hit) =
        searcher.search_cached(&cache, &report, &cons, &prompts[..1.min(prompts.len())])?;
    println!(
        "search {} in {:.2}s",
        if search_hit { "cache hit (validation generations skipped)" } else { "computed" },
        t0.elapsed().as_secs_f64()
    );

    let mut t = Table::new(&["rank", "config", "MAC red.", "latent PSNR (dB)", "validated"]);
    for (i, c) in cands.iter().take(8).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            format!(
                "T_sk={} T_cm={} T_sp={} L_sk={} L_rf={}",
                c.cfg.t_sketch, c.cfg.t_complete, c.cfg.t_sparse, c.cfg.l_sketch, c.cfg.l_refine
            ),
            ratio(c.mac_reduction),
            c.psnr_db.map(|p| f(p, 1)).unwrap_or_else(|| "-".into()),
            if c.validated { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    if let Some(best) = cands.first() {
        println!(
            "\nselected solution: {} with {:.2}x MAC reduction (Fig. 7 output)",
            best.cfg.label(),
            best.mac_reduction
        );
    } else {
        println!("\nno feasible solution — relax the constraints");
    }
    Ok(())
}
