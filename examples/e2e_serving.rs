//! End-to-end serving driver (DESIGN.md's E2E validation run).
//!
//! Starts the full stack — PJRT runtime thread, coordinator, dynamic
//! batcher, worker fleet — and pushes a synthetic prompt workload through
//! it with a mix of original and phase-aware sampling requests. Reports
//! latency percentiles, throughput, mean batch size, and the PAS quality
//! proxy, and appends a JSON record consumed by EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_serving`
//! (sim backend without artifacts; `make artifacts` for the xla path)
//! Env: SD_ACC_E2E_REQS (default 12), SD_ACC_E2E_STEPS (default 20).

use std::sync::Arc;
use std::time::{Duration, Instant};

use sd_acc::cache::StoreConfig;
use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::pas::plan::{PasConfig, SamplingPlan};
use sd_acc::quality;
use sd_acc::runtime::{default_artifacts_dir, Runtime, RuntimeService};
use sd_acc::server::{Server, ServerConfig};
use sd_acc::util::json::Json;
use sd_acc::util::rng::Pcg32;
use sd_acc::util::stats;

const COLORS: [&str; 6] = ["red", "green", "blue", "yellow", "cyan", "magenta"];
const SHAPES: [&str; 3] = ["circle", "square", "stripe"];

fn synth_prompt(rng: &mut Pcg32) -> String {
    let n = rng.gen_range(1, 2) as usize + 1;
    (0..n)
        .map(|_| {
            format!(
                "{} {} x{} y{}",
                rng.choose(&COLORS),
                rng.choose(&SHAPES),
                rng.gen_range(2, 13),
                rng.gen_range(2, 13)
            )
        })
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    let n_reqs: usize = std::env::var("SD_ACC_E2E_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(12);
    let steps: usize = std::env::var("SD_ACC_E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);

    // Backend auto-resolution: xla over artifacts, deterministic sim
    // backend otherwise — the driver runs either way.
    let svc = RuntimeService::start(&dir)?;
    println!("backend: {}", svc.backend());
    // Warm the executable cache so serving latency excludes compiles.
    let warm = [
        Runtime::unet_full(1),
        Runtime::unet_full(2),
        Runtime::unet_partial(2, 1),
        Runtime::unet_partial(2, 2),
        Runtime::text_encoder(1),
        Runtime::text_encoder(2),
    ];
    print!("compiling {} artifacts... ", warm.len());
    let t0 = Instant::now();
    svc.handle().preload(&warm)?;
    println!("done in {:.1}s", t0.elapsed().as_secs_f64());

    let coord = Arc::new(Coordinator::new(svc.handle()));
    // Optional persistent cache: set SD_ACC_E2E_CACHE to a directory and
    // a second run of this driver is served from the request cache.
    let cache = match std::env::var("SD_ACC_E2E_CACHE") {
        Ok(dir) => Some(Arc::new(coord.open_cache(StoreConfig::new(dir))?)),
        Err(_) => None,
    };
    // One worker: PJRT submissions are serialised on the runtime thread
    // anyway (runtime/service.rs), so a single worker gives clean
    // per-plan latency numbers while batching still packs same-plan
    // requests together.
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(40),
            cache,
            // This driver submits the whole workload up front; admit it
            // all even when SD_ACC_E2E_REQS exceeds the default bound.
            max_queue: n_reqs.max(1024),
            ..Default::default()
        },
    );
    let client = server.client();

    let mut rng = Pcg32::seeded(2026);
    let pas = PasConfig { t_sketch: steps / 2, t_complete: 3, t_sparse: 4, l_sketch: 2, l_refine: 2 };

    println!("submitting {n_reqs} requests ({steps} steps each, 50% PAS)...");
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..n_reqs {
        let mut r = GenRequest::new(&synth_prompt(&mut rng), 4000 + i as u64);
        r.steps = steps;
        r.sampler = "pndm".into();
        if i % 2 == 1 {
            r.plan = SamplingPlan::Pas(pas);
        }
        // submit returns a JobHandle (id + streaming events + cancel
        // token); this driver only needs the blocking wait.
        let handle = client.submit(r.clone())?;
        handles.push((r, handle));
    }

    let mut lat_full = Vec::new();
    let mut lat_pas = Vec::new();
    let mut results = Vec::new();
    for (req, handle) in handles {
        let res = handle.wait()?;
        match req.plan {
            SamplingPlan::Full | SamplingPlan::Auto => lat_full.push(res.stats.total_ms),
            SamplingPlan::Pas(_) => lat_pas.push(res.stats.total_ms),
        }
        results.push((req, res));
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics.summary();

    println!("\n== serving report ==");
    println!("completed {} requests in {:.1}s  ({:.2} img/min)", m.completed, wall, m.completed as f64 / wall * 60.0);
    println!("queue+exec latency: p50 {:.0} ms, p95 {:.0} ms, mean {:.0} ms", m.p50_ms, m.p95_ms, m.mean_ms);
    println!("mean executed batch size: {:.2}", m.mean_batch_size);
    if m.cache_hits + m.cache_misses > 0 {
        println!("request cache: {} hits, {} misses, {} evictions", m.cache_hits, m.cache_misses, m.cache_evictions);
    }
    println!("mean generation ms: full {:.0}, PAS {:.0} ({:.2}x step-time reduction)",
        stats::mean(&lat_full), stats::mean(&lat_pas), stats::mean(&lat_full) / stats::mean(&lat_pas).max(1.0));

    // PAS quality proxy vs a matched full run for one sampled request.
    let (req_pas, res_pas) = results.iter().find(|(r, _)| matches!(r.plan, SamplingPlan::Pas(_))).unwrap();
    let mut matched = req_pas.clone();
    matched.plan = SamplingPlan::Full;
    let reference = coord.generate_one(&matched)?;
    let psnr = quality::latent_psnr(&res_pas.latent, &reference.latent);
    println!("PAS latent PSNR vs matched full run: {:.1} dB (MAC reduction {:.2}x)",
        psnr, res_pas.stats.mac_reduction);

    let record = Json::obj(vec![
        ("requests", Json::num(n_reqs as f64)),
        ("steps", Json::num(steps as f64)),
        ("wall_s", Json::num(wall)),
        ("throughput_img_per_min", Json::num(m.completed as f64 / wall * 60.0)),
        ("p50_ms", Json::num(m.p50_ms)),
        ("p95_ms", Json::num(m.p95_ms)),
        ("mean_batch", Json::num(m.mean_batch_size)),
        ("full_ms", Json::num(stats::mean(&lat_full))),
        ("pas_ms", Json::num(stats::mean(&lat_pas))),
        ("pas_psnr_db", Json::num(psnr)),
        ("pas_mac_reduction", Json::num(res_pas.stats.mac_reduction)),
    ]);
    std::fs::write("e2e_serving_report.json", record.to_string())?;
    println!("\nwrote e2e_serving_report.json");
    server.shutdown();
    Ok(())
}
