//! Design-space exploration of the SD-Acc accelerator: sweep the systolic
//! array size, frequency and global buffer over the SD v1.4 workload and
//! report latency / energy / roofline position per point.
//!
//! Runs without artifacts (pure simulator).
//! Run: `cargo run --release --example hwsim_explore`

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::simulate_unet_step;
use sd_acc::models::inventory::{sd_v14, unet_ops};
use sd_acc::util::table::{f, Table};

fn main() {
    let ops = unet_ops(&sd_v14());

    println!("== systolic array size sweep (200 MHz, 2 MB GB) ==");
    let mut t = Table::new(&["SA", "peak GMAC/s", "step (s)", "util", "img energy (kJ)", "intensity (FLOP/B)"]);
    for dim in [16usize, 32, 64, 128] {
        let mut cfg = AccelConfig::default();
        cfg.sa_rows = dim;
        cfg.sa_cols = dim;
        cfg.vpu_lanes = dim;
        cfg.dram_bw = AccelConfig::default().dram_bw * (dim * dim) as f64 / 1024.0;
        let r = simulate_unet_step(&cfg, Policy::optimized(), &ops);
        t.row(vec![
            format!("{dim}x{dim}"),
            f(cfg.peak_macs() / 1e9, 1),
            f(r.seconds(&cfg), 2),
            f(r.utilization(&cfg), 3),
            f(r.energy_j(&cfg) * 50.0 / 1e3, 2),
            f(r.operational_intensity(), 0),
        ]);
    }
    t.print();

    println!("\n== frequency sweep (32x32) ==");
    let mut t = Table::new(&["freq", "step (s)", "img latency (s)", "img energy (kJ)"]);
    for mhz in [100.0f64, 200.0, 400.0, 1000.0] {
        let mut cfg = AccelConfig::default();
        cfg.freq_hz = mhz * 1e6;
        let r = simulate_unet_step(&cfg, Policy::optimized(), &ops);
        t.row(vec![
            format!("{mhz:.0} MHz"),
            f(r.seconds(&cfg), 2),
            f(r.seconds(&cfg) * 50.0, 1),
            f(r.energy_j(&cfg) * 50.0 / 1e3, 2),
        ]);
    }
    t.print();

    println!("\n== global buffer sweep (32x32 @ 200 MHz) ==");
    let mut t = Table::new(&["GB", "traffic/step (GB)", "stall share", "step (s)"]);
    for kb in [256usize, 512, 1024, 2048, 4096] {
        let mut cfg = AccelConfig::default();
        cfg.gb_bytes = kb << 10;
        let r = simulate_unet_step(&cfg, Policy::optimized(), &ops);
        t.row(vec![
            format!("{kb} KB"),
            f(r.traffic_bytes / 1e9, 2),
            f(r.mem_stall_cycles / r.total_cycles(), 4),
            f(r.seconds(&cfg), 3),
        ]);
    }
    t.print();
    println!("\n(2 MB matches the paper's sweet spot; beyond it the workload is fully compute-bound)");
}
