//! Mixed-precision walkthrough: calibrate -> search -> report, mirroring
//! `hwsim_explore.rs`. Runs without artifacts (synthetic calibration +
//! pure simulator).
//!
//! Run: `cargo run --release --example quant_explore`

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::simulate_unet_step_quant;
use sd_acc::models::inventory::{sd_v14, unet_ops};
use sd_acc::quant::{
    assign, predicted_psnr_db, search, synthetic_profile, QuantConstraints, QuantScheme,
};
use sd_acc::util::table::{f, ratio, Table};

fn main() {
    let arch = sd_v14();
    let ops = unet_ops(&arch);
    let cfg = AccelConfig::default();
    let policy = Policy::optimized();

    // 1. Calibrate: deterministic activation ranges per paper block.
    println!("== 1. calibrate (synthetic ranges, {} ops -> per-block entries) ==", ops.len());
    let profile = synthetic_profile(&arch, 50);
    let mut t = Table::new(&["tensor", "absmax", "p99", "drf"]);
    for name in ["down2", "down2.tf", "mid", "mid.tf", "up1"] {
        let r = profile.range_for(name).expect(name);
        t.row(vec![
            name.to_string(),
            f(r.absmax as f64, 2),
            f(r.p99 as f64, 2),
            f(profile.drf(name), 2),
        ]);
    }
    t.print();
    println!("(attention `.tf` tensors carry heavy tails -> high dynamic-range factor)\n");

    // 2. Search: quality-gated Pareto front over bit-width schemes.
    for target in [30.0, 15.0] {
        println!("== 2. search (quality target {target} dB) ==");
        let cons = QuantConstraints { min_psnr_db: target, pin_fragile: true };
        let front = search(&ops, &cfg, policy, &cons, Some(&profile));
        let mut t = Table::new(&["scheme", "PSNR proxy (dB)", "energy/step (J)", "vs fp32", "pinned"]);
        for c in &front {
            t.row(vec![
                c.scheme.label(),
                f(c.psnr_db, 1),
                f(c.energy_j, 2),
                ratio(c.energy_reduction),
                c.pinned.to_string(),
            ]);
        }
        t.print();
        println!();
    }

    // 3. Report: the chosen precision in full hwsim detail.
    let scheme = QuantScheme::w8a8();
    println!("== 3. report ({} at the optimized policy) ==", scheme.label());
    let base = simulate_unet_step_quant(&cfg, policy, &ops, &assign(&ops, QuantScheme::fp32(), false));
    let plan = assign(&ops, scheme, true);
    let r = simulate_unet_step_quant(&cfg, policy, &ops, &plan);
    let mut t = Table::new(&["metric", "fp32", "W8A8", "reduction"]);
    t.row(vec!["step time (s)".into(), f(base.seconds(&cfg), 3), f(r.seconds(&cfg), 3), ratio(base.seconds(&cfg) / r.seconds(&cfg))]);
    t.row(vec!["traffic (GB)".into(), f(base.traffic_bytes / 1e9, 2), f(r.traffic_bytes / 1e9, 2), ratio(base.traffic_bytes / r.traffic_bytes)]);
    t.row(vec!["energy (J)".into(), f(base.energy_j(&cfg), 2), f(r.energy_j(&cfg), 2), ratio(base.energy_j(&cfg) / r.energy_j(&cfg))]);
    t.print();
    println!(
        "PSNR proxy at W8A8 (fragile layers pinned to fp16): {} dB",
        f(predicted_psnr_db(&ops, &plan, Some(&profile)), 1)
    );
    println!("\n(next: `sd-acc quant calibrate --artifacts <dir>` measures real ranges,");
    println!(" and `sd-acc generate --quant w8a8` runs the emulated datapath end to end)");
}
