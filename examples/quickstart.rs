//! Quickstart: generate an image with the original sampler and with
//! phase-aware sampling, compare cost + quality, save PPM images.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
//!
//! **No artifacts? No problem.** The runtime auto-resolves its
//! execution backend: with `artifacts/manifest.json` present it runs
//! the PJRT/xla path, without it (or with `SD_ACC_BACKEND=sim`, or
//! `sd-acc ... --backend sim` on the CLI) it runs the deterministic
//! pure-Rust `SimBackend` — same API, same shapes, bit-reproducible
//! outputs, zero setup.

use std::path::Path;

use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::pas::plan::{PasConfig, SamplingPlan};
use sd_acc::quality;
use sd_acc::runtime::{default_artifacts_dir, BackendKind, RuntimeService};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    let svc = RuntimeService::start(&dir)?;
    if svc.backend() == BackendKind::Sim {
        println!("backend: sim (no artifacts at {} — deterministic simulator)", dir.display());
    }
    // Compile ahead of time so the reported step times are steady-state.
    println!("compiling artifacts (one-time)...");
    svc.handle().preload(&[
        sd_acc::runtime::Runtime::text_encoder(1),
        sd_acc::runtime::Runtime::unet_full(1),
        sd_acc::runtime::Runtime::unet_partial(2, 1),
        sd_acc::runtime::Runtime::vae_decoder(1),
    ])?;
    let coord = Coordinator::new(svc.handle());
    let m = coord.runtime().manifest().model.clone();

    let prompt = "red circle x4 y4 blue square x11 y11";
    let steps = 30;
    println!("prompt: {prompt:?}, {steps} steps, PNDM, guidance {}", m.guidance);

    // Original sampling.
    let mut req = GenRequest::new(prompt, 42);
    req.steps = steps;
    let full = coord.generate_one(&req)?;
    println!(
        "original : {:7.0} ms total, {:5.1} ms/step, MAC reduction {:.2}x",
        full.stats.total_ms,
        full.stats.total_ms / steps as f64,
        full.stats.mac_reduction
    );

    // Phase-aware sampling.
    let pas = PasConfig { t_sketch: steps / 2, t_complete: 3, t_sparse: 4, l_sketch: 2, l_refine: 2 };
    req.plan = SamplingPlan::Pas(pas);
    let fast = coord.generate_one(&req)?;
    let psnr = quality::latent_psnr(&fast.latent, &full.latent);
    println!(
        "PAS      : {:7.0} ms total, {:5.1} ms/step avg, MAC reduction {:.2}x, latent PSNR {:.1} dB vs original",
        fast.stats.total_ms,
        fast.stats.total_ms / steps as f64,
        fast.stats.mac_reduction,
        psnr
    );
    println!(
        "wall-clock speedup: {:.2}x",
        full.stats.total_ms / fast.stats.total_ms
    );

    // Decode + save both.
    let imgs = coord.decode(&[full.latent, fast.latent])?;
    quality::write_ppm(&imgs[0], m.img_h, m.img_w, Path::new("quickstart_original.ppm"))?;
    quality::write_ppm(&imgs[1], m.img_h, m.img_w, Path::new("quickstart_pas.ppm"))?;
    println!("wrote quickstart_original.ppm / quickstart_pas.ppm ({}x{})", m.img_w, m.img_h);
    Ok(())
}
