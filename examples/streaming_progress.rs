//! Streaming job API walkthrough: submit, watch per-step events arrive
//! live, then cancel a second job mid-flight.
//!
//! Demonstrates the session-oriented serving API:
//!   - `Client::submit` -> `JobHandle { id, events, cancel }`
//!   - the event vocabulary (Queued / Scheduled / Step / Done / ...)
//!   - `SubmitOptions` priorities
//!   - cooperative cancellation observed once per denoising step, so a
//!     fired token stops a run *before its final step*, not just while
//!     it waits in the queue.
//!
//! Run: `cargo run --release --example streaming_progress`
//! (runs on the deterministic sim backend when no artifacts exist;
//! `make artifacts` first to drive the PJRT/xla path instead)

use std::sync::Arc;
use std::time::Duration;

use sd_acc::coordinator::{Coordinator, GenRequest, SamplerKind};
use sd_acc::pas::plan::StepAction;
use sd_acc::runtime::{default_artifacts_dir, RuntimeService};
use sd_acc::server::{JobEvent, Priority, Server, ServerConfig, SubmitOptions};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    let svc = RuntimeService::start(&dir)?;
    println!("backend: {}", svc.backend());
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig { workers: 1, max_wait: Duration::from_millis(20), ..Default::default() },
    );
    let client = server.client();

    // ---- 1. Watch a generation stream its lifecycle, step by step.
    let req = GenRequest::builder("red circle x4 y4 blue square x11 y11", 7)
        .steps(12)
        .sampler(SamplerKind::Ddim)
        .build()?;
    let handle = client.submit_with(req, SubmitOptions::with_priority(Priority::High))?;
    println!("submitted {} (high priority); streaming events:", handle.id);
    loop {
        let ev = handle
            .events
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped the event stream"))?;
        match &ev {
            JobEvent::Queued => println!("  queued"),
            JobEvent::CacheHit => println!("  cache hit — no generation needed"),
            JobEvent::Scheduled { batch_size } => {
                println!("  scheduled in a batch of {batch_size}")
            }
            JobEvent::Step { i, action, ms } => {
                let what = match action {
                    StepAction::Full => "full U-Net".to_string(),
                    StepAction::Partial(l) => format!("partial (cut {l})"),
                };
                println!("  step {:>2}: {what:<16} {ms:6.1} ms", i + 1);
            }
            JobEvent::Done(res) => {
                println!(
                    "  done: {:.0} ms total, MAC reduction {:.2}x",
                    res.stats.total_ms, res.stats.mac_reduction
                );
            }
            JobEvent::Failed(e) => println!("  failed: {e}"),
            JobEvent::Cancelled => println!("  cancelled"),
        }
        if ev.is_terminal() {
            break;
        }
    }

    // ---- 2. Cancel a job after its third step: the denoising loop
    // polls the token every step, so the run aborts mid-flight.
    let req = GenRequest::builder("green stripe x8 y8", 8).steps(12).build()?;
    let handle = client.submit(req)?;
    println!("\nsubmitted {}; cancelling after 3 observed steps...", handle.id);
    let mut steps_seen = 0usize;
    loop {
        let Ok(ev) = handle.events.recv() else { break };
        match &ev {
            JobEvent::Step { i, .. } => {
                steps_seen += 1;
                println!("  step {} ran", i + 1);
                if steps_seen == 3 {
                    handle.cancel.cancel();
                    println!("  -> cancel requested");
                }
            }
            JobEvent::Cancelled => println!("  cancelled after {steps_seen} of 12 steps"),
            other => println!("  {}", other.label()),
        }
        if ev.is_terminal() {
            break;
        }
    }

    server.shutdown();
    Ok(())
}
