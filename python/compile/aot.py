"""AOT pipeline: lower the L2 model (Pallas backend) to HLO-text artifacts.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  unet_full_b{B}.hlo.txt       (lat, t, ctx, g)        -> (eps, cache_l1..l3)
  unet_partial_l{l}_b{B}.hlo.txt (lat, t, ctx, g, cache) -> eps
  unet_calib_b{B}.hlo.txt      (lat, t, ctx, g)        -> (eps, up_in_1..12)
  text_encoder_b{B}.hlo.txt    (tokens)                -> ctx
  vae_decoder_b{B}.hlo.txt     (lat)                   -> img
  weights_{unet,text,vae}.bin  raw little-endian f32 in lowering order
  manifest.json                shapes, param tables, vocab, schedule
  train_log.json               training loss curves (from compile.train)

Weights are *parameters* of every artifact (never baked constants), so the
rust runtime owns them: it loads each .bin once, builds PJRT literals, and
prepends them to every execute call.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model as M, train
from .backends import PALLAS
from .config import BATCH_SIZES, CFG, DEFAULT_GUIDANCE

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_of(s):
    return {"shape": list(s.shape), "dtype": "i32" if s.dtype == jnp.int32 else "f32"}


def write_weights(params, path: str):
    """Raw little-endian f32 blob in jax lowering (tree) order + table."""
    flat = train.flatten_params(params)
    table = []
    offset = 0
    with open(path, "wb") as f:
        for name, leaf in flat:
            raw = leaf.astype("<f4").tobytes()
            table.append({
                "name": name,
                "shape": list(leaf.shape),
                "offset": offset,
                "len": int(leaf.size),
            })
            f.write(raw)
            offset += len(raw)
    return table


def lower_artifact(out_dir, name, fn, params, input_specs, manifest_entry):
    """Lower fn(params, *inputs) and write <name>.hlo.txt."""
    # keep_unused=True: partial-U-Net artifacts use only a subset of the
    # parameter pytree, but every artifact must accept the SAME weight list
    # so the rust runtime can prepend one cached literal set uniformly.
    lowered = jax.jit(fn, keep_unused=True).lower(
        params, *[spec(s, d) for s, d in input_specs]
    )
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest_entry["artifacts"].append({
        "name": name,
        "file": f"{name}.hlo.txt",
        "n_params": len(jax.tree_util.tree_leaves(params)),
        "inputs": [
            {"shape": list(s), "dtype": "i32" if d == I32 else "f32"}
            for s, d in input_specs
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    })
    print(f"[aot] {name}: {len(text)} chars")


def ensure_trained(out_dir: str):
    """Train (or reuse) parameters; returns (unet, text, vae) pytrees."""
    key = jax.random.PRNGKey(CFG.seed)
    ku, kt, kv = jax.random.split(key, 3)
    unet_t = M.init_unet_params(ku)
    text_t = M.init_text_params(kt)
    vae_t = M.init_vae_params(kv)
    paths = {n: os.path.join(out_dir, f"params_{n}.npz") for n in ("unet", "text", "vae")}
    if not all(os.path.exists(p) for p in paths.values()):
        if os.environ.get("SD_ACC_SKIP_TRAIN") == "1":
            print("[aot] SD_ACC_SKIP_TRAIN=1 — using untrained parameters")
            train.save_params(unet_t, paths["unet"])
            train.save_params(text_t, paths["text"])
            train.save_params(vae_t, paths["vae"])
            with open(os.path.join(out_dir, "train_log.json"), "w") as f:
                json.dump({"unet": [], "vae": [], "unet_steps": 0, "vae_steps": 0}, f)
        else:
            train.main(out_dir)
    return (
        train.load_params(unet_t, paths["unet"]),
        train.load_params(text_t, paths["text"]),
        train.load_params(vae_t, paths["vae"]),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    unet_p, text_p, vae_p = ensure_trained(out_dir)

    manifest = {
        "model": {
            "latent_h": CFG.latent_h,
            "latent_w": CFG.latent_w,
            "latent_c": CFG.latent_c,
            "channels": list(CFG.channels),
            "ctx_len": CFG.ctx_len,
            "ctx_dim": CFG.ctx_dim,
            "img_h": CFG.img_h,
            "img_w": CFG.img_w,
            "max_cut": CFG.max_cut,
            "train_steps": CFG.train_steps,
            "beta_start": CFG.beta_start,
            "beta_end": CFG.beta_end,
            "guidance": DEFAULT_GUIDANCE,
            "seed": CFG.seed,
        },
        "batch_sizes": list(BATCH_SIZES),
        "vocab": data.VOCAB,
        "alpha_bar": [float(x) for x in train.diffusion_schedule()],
        "weights": {},
        "artifacts": [],
    }

    manifest["weights"]["unet"] = {
        "file": "weights_unet.bin",
        "table": write_weights(unet_p, os.path.join(out_dir, "weights_unet.bin")),
    }
    manifest["weights"]["text"] = {
        "file": "weights_text.bin",
        "table": write_weights(text_p, os.path.join(out_dir, "weights_text.bin")),
    }
    manifest["weights"]["vae"] = {
        "file": "weights_vae.bin",
        "table": write_weights(vae_p, os.path.join(out_dir, "weights_vae.bin")),
    }

    l_lat = CFG.latent_l
    for b in BATCH_SIZES:
        lat = ((b, l_lat, CFG.latent_c), F32)
        t = ((b,), F32)
        ctx = ((b, CFG.ctx_len, CFG.ctx_dim), F32)
        g = ((), F32)
        cache = ((2 * b, l_lat, CFG.channels[0]), F32)

        lower_artifact(
            out_dir, f"unet_full_b{b}",
            lambda p, la, tt, cc, gg: M.unet_full(PALLAS, p, la, tt, cc, gg),
            unet_p, [lat, t, ctx, g], manifest,
        )
        for l in range(1, CFG.max_cut + 1):
            lower_artifact(
                out_dir, f"unet_partial_l{l}_b{b}",
                (lambda l_: lambda p, la, tt, cc, gg, ca:
                    M.unet_partial(PALLAS, p, l_, la, tt, cc, gg, ca))(l),
                unet_p, [lat, t, ctx, g, cache], manifest,
            )
        lower_artifact(
            out_dir, f"unet_calib_b{b}",
            lambda p, la, tt, cc, gg: M.unet_calib(PALLAS, p, la, tt, cc, gg),
            unet_p, [lat, t, ctx, g], manifest,
        )
        lower_artifact(
            out_dir, f"text_encoder_b{b}",
            lambda p, tk: (M.text_encoder(PALLAS, p, tk),),
            text_p, [((b, CFG.ctx_len), I32)], manifest,
        )
        lower_artifact(
            out_dir, f"vae_decoder_b{b}",
            lambda p, la: (M.vae_decoder(PALLAS, p, la),),
            vae_p, [lat], manifest,
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"[aot] manifest + {len(manifest['artifacts'])} artifacts -> {out_dir}")


if __name__ == "__main__":
    main()
