"""Op backends for the L2 model.

The same model code runs on two interchangeable op sets:

- ``PALLAS``: the L1 Pallas kernels (interpret mode). Used by aot.py so the
  kernels lower into the exported HLO.
- ``REF``: the pure-jnp oracles from kernels/ref.py. Used by train.py
  (fast jnp training) and by tests as the independent reference.

python/tests/test_model.py asserts the two backends agree on the full
U-Net forward pass, which transitively validates every kernel in context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import mha as _pallas_mha
from .kernels.elementwise import gelu as _pallas_gelu, silu as _pallas_silu
from .kernels.norms import groupnorm as _pallas_gn, layernorm as _pallas_ln
from .kernels.uni_conv import uni_conv as _pallas_conv


class PallasOps:
    """L1 Pallas kernels (lowered into the AOT artifacts)."""

    name = "pallas"

    @staticmethod
    def conv(x, w, b, h, w_dim, stride=1):
        return _pallas_conv(x, w, b, h=h, w_dim=w_dim, stride=stride)

    @staticmethod
    def mha(q, k, v):
        return _pallas_mha(q, k, v)

    @staticmethod
    def layernorm(x, g, b):
        return _pallas_ln(x, g, b)

    @staticmethod
    def groupnorm(x, g, b, groups):
        return _pallas_gn(x, g, b, groups=groups)

    @staticmethod
    def gelu(x):
        return _pallas_gelu(x)

    @staticmethod
    def silu(x):
        return _pallas_silu(x)


class RefOps:
    """Pure-jnp oracle ops (training + independent reference)."""

    name = "ref"

    @staticmethod
    def conv(x, w, b, h, w_dim, stride=1):
        return ref.conv2d_same(x, w, b, h, w_dim, stride)

    @staticmethod
    def mha(q, k, v):
        return jax.vmap(ref.attention)(q, k, v)

    @staticmethod
    def layernorm(x, g, b):
        return ref.layernorm(x, g, b)

    @staticmethod
    def groupnorm(x, g, b, groups):
        return ref.groupnorm(x, g, b, groups)

    @staticmethod
    def gelu(x):
        return ref.gelu_sigmoid(x)

    @staticmethod
    def silu(x):
        return ref.silu(x)


PALLAS = PallasOps()
REF = RefOps()
