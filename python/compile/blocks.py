"""U-Net building blocks (L2), written against an op backend (backends.py).

Everything operates in the paper's address-centric storage format: a
sample's activation is ``(L, C)`` with ``L = h * w`` (Sec. IV-B). Spatial
sizes travel alongside as python ints, so downsample/upsample blocks are
pure metadata changes plus a strided uni_conv / nearest repeat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import CFG


# ----------------------------------------------------------------- helpers


def _init_linear(key, cin, cout, scale=1.0):
    w = jax.random.normal(key, (cin, cout), jnp.float32) * (scale / cin**0.5)
    return w


def _init_conv(key, k, cin, cout, scale=1.0):
    fan = k * k * cin
    return jax.random.normal(key, (k * k, cin, cout), jnp.float32) * (scale / fan**0.5)


def _keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------- time embedding


def sinusoidal_embedding(t, dim: int):
    """Sinusoidal timestep embedding. t: scalar f32 -> (dim,)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)])


def init_temb(key):
    k1, k2 = _keys(key, 2)
    return {
        "w1": _init_linear(k1, CFG.time_dim, CFG.temb_dim),
        "b1": jnp.zeros((CFG.temb_dim,)),
        "w2": _init_linear(k2, CFG.temb_dim, CFG.temb_dim),
        "b2": jnp.zeros((CFG.temb_dim,)),
    }


def apply_temb(ops, p, t):
    """t: scalar raw timestep -> (temb_dim,)."""
    e = sinusoidal_embedding(t, CFG.time_dim)
    e = (e @ p["w1"] + p["b1"])[None, :]
    e = ops.silu(e)
    e = e @ p["w2"] + p["b2"]
    return e[0]


# ------------------------------------------------------------ resnet block


def init_resnet(key, cin, cout):
    ks = _keys(key, 4)
    p = {
        "gn1_g": jnp.ones((cin,)),
        "gn1_b": jnp.zeros((cin,)),
        "conv1_w": _init_conv(ks[0], 3, cin, cout),
        "conv1_b": jnp.zeros((cout,)),
        "temb_w": _init_linear(ks[1], CFG.temb_dim, cout),
        "temb_b": jnp.zeros((cout,)),
        "gn2_g": jnp.ones((cout,)),
        "gn2_b": jnp.zeros((cout,)),
        # Near-zero-init second conv: residual blocks start close to
        # identity, the standard DDPM/SD initialisation.
        "conv2_w": _init_conv(ks[2], 3, cout, cout, scale=1e-2),
        "conv2_b": jnp.zeros((cout,)),
    }
    if cin != cout:
        p["skip_w"] = _init_conv(ks[3], 1, cin, cout)
        p["skip_b"] = jnp.zeros((cout,))
    return p


def apply_resnet(ops, p, x, temb, h, w):
    """x: (L, cin) -> (L, cout). 3x3 convs via uni_conv, GN via Eq. 4."""
    y = ops.groupnorm(x, p["gn1_g"], p["gn1_b"], CFG.groups)
    y = ops.silu(y)
    y = ops.conv(y, p["conv1_w"], p["conv1_b"], h, w)
    y = y + (ops.silu((temb @ p["temb_w"] + p["temb_b"])[None, :]))
    y = ops.groupnorm(y, p["gn2_g"], p["gn2_b"], CFG.groups)
    y = ops.silu(y)
    y = ops.conv(y, p["conv2_w"], p["conv2_b"], h, w)
    if "skip_w" in p:
        x = ops.conv(x, p["skip_w"], p["skip_b"], h, w)
    return x + y


# ------------------------------------------------------- transformer block


def init_transformer(key, c):
    ks = _keys(key, 12)
    return {
        "gn_g": jnp.ones((c,)),
        "gn_b": jnp.zeros((c,)),
        "proj_in_w": _init_conv(ks[0], 1, c, c),
        "proj_in_b": jnp.zeros((c,)),
        "ln1_g": jnp.ones((c,)),
        "ln1_b": jnp.zeros((c,)),
        "q_w": _init_linear(ks[1], c, c),
        "k_w": _init_linear(ks[2], c, c),
        "v_w": _init_linear(ks[3], c, c),
        "o_w": _init_linear(ks[4], c, c, scale=1e-2),
        "o_b": jnp.zeros((c,)),
        "ln2_g": jnp.ones((c,)),
        "ln2_b": jnp.zeros((c,)),
        "cq_w": _init_linear(ks[5], c, c),
        "ck_w": _init_linear(ks[6], CFG.ctx_dim, c),
        "cv_w": _init_linear(ks[7], CFG.ctx_dim, c),
        "co_w": _init_linear(ks[8], c, c, scale=1e-2),
        "co_b": jnp.zeros((c,)),
        "ln3_g": jnp.ones((c,)),
        "ln3_b": jnp.zeros((c,)),
        "ff1_w": _init_linear(ks[9], c, 4 * c),
        "ff1_b": jnp.zeros((4 * c,)),
        "ff2_w": _init_linear(ks[10], 4 * c, c, scale=1e-2),
        "ff2_b": jnp.zeros((c,)),
        "proj_out_w": _init_conv(ks[11], 1, c, c, scale=1e-2),
        "proj_out_b": jnp.zeros((c,)),
    }


def _split_heads(x, heads):
    l, c = x.shape
    return x.reshape(l, heads, c // heads).transpose(1, 0, 2)


def _merge_heads(x):
    heads, l, d = x.shape
    return x.transpose(1, 0, 2).reshape(l, heads * d)


def apply_transformer(ops, p, x, ctx, h, w):
    """x: (L, C), ctx: (ctx_len, ctx_dim) -> (L, C).

    GN + 1x1 conv in, self-attention, text cross-attention, GELU FFN,
    1x1 conv out, residual — the SD Transformer block (Fig. 3).
    """
    heads = CFG.heads
    res = x
    y = ops.groupnorm(x, p["gn_g"], p["gn_b"], CFG.groups)
    y = ops.conv(y, p["proj_in_w"], p["proj_in_b"], h, w)

    # Self-attention (softmax via the online Eq. 5-6 kernel).
    z = ops.layernorm(y, p["ln1_g"], p["ln1_b"])
    q, k, v = z @ p["q_w"], z @ p["k_w"], z @ p["v_w"]
    a = _merge_heads(ops.mha(*(_split_heads(m, heads) for m in (q, k, v))))
    y = y + a @ p["o_w"] + p["o_b"]

    # Cross-attention over the text context.
    z = ops.layernorm(y, p["ln2_g"], p["ln2_b"])
    q = z @ p["cq_w"]
    k, v = ctx @ p["ck_w"], ctx @ p["cv_w"]
    a = _merge_heads(ops.mha(*(_split_heads(m, heads) for m in (q, k, v))))
    y = y + a @ p["co_w"] + p["co_b"]

    # Feed-forward with the paper's sigmoid-GELU.
    z = ops.layernorm(y, p["ln3_g"], p["ln3_b"])
    z = ops.gelu(z @ p["ff1_w"] + p["ff1_b"]) @ p["ff2_w"] + p["ff2_b"]
    y = y + z

    y = ops.conv(y, p["proj_out_w"], p["proj_out_b"], h, w)
    return y + res


# --------------------------------------------------------- down / upsample


def init_downsample(key, c):
    return {"w": _init_conv(key, 3, c, c), "b": jnp.zeros((c,))}


def apply_downsample(ops, p, x, h, w):
    """3x3 stride-2 conv (the paper's downsampling op)."""
    return ops.conv(x, p["w"], p["b"], h, w, stride=2)


def upsample_nearest(x, h, w):
    """Nearest-neighbour 2x upsample (the paper's upsampling op).

    (h*w, C) -> (2h*2w, C); pure data movement, no parameters.
    """
    c = x.shape[-1]
    img = x.reshape(h, w, c)
    img = jnp.repeat(jnp.repeat(img, 2, axis=0), 2, axis=1)
    return img.reshape(4 * h * w, c)
