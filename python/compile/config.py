"""sd-tiny model configuration.

A structurally faithful miniature of the StableDiff v1.4 U-Net (DESIGN.md
substitution table): same 12-down / middle / 12-up block topology with
downsamples at blocks 4/7/10 and upsamples at up-blocks 10/7/4 (Fig. 3 of
the paper), ResNet blocks with time embedding, Transformer blocks with
text cross-attention, scaled to a 16x16x4 latent so the whole system runs
under Pallas interpret mode on CPU.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # Latent space (VAE downsamples the 64x64 RGB image by 4x).
    latent_h: int = 16
    latent_w: int = 16
    latent_c: int = 4
    # Channel schedule: levels at 16x16, 8x8, 4x4, 2x2.
    channels: tuple = (32, 64, 128, 128)
    groups: int = 8
    heads: int = 4
    # Text conditioning.
    ctx_len: int = 16
    ctx_dim: int = 64
    vocab: int = 4096
    text_layers: int = 2
    # Time embedding.
    time_dim: int = 64
    temb_dim: int = 128
    # Diffusion (training) schedule — SD's scaled-linear betas.
    train_steps: int = 1000
    beta_start: float = 0.00085
    beta_end: float = 0.012
    # Image output of the VAE decoder.
    img_h: int = 64
    img_w: int = 64
    # Phase-aware-sampling cut points exported from the full U-Net: the
    # main-branch inputs of up-blocks 1..MAX_CUT (all at 16x16, C=ch[0]).
    max_cut: int = 3
    seed: int = 42

    @property
    def latent_l(self) -> int:
        return self.latent_h * self.latent_w


CFG = ModelConfig()

# Batch sizes for which artifacts are compiled (PJRT executables are
# shape-specialised; the rust batcher groups requests to these sizes).
BATCH_SIZES = (1, 2)

# Classifier-free guidance default, matching the paper's setup (Sec. VI-A).
DEFAULT_GUIDANCE = 7.5
