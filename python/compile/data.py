"""Procedural text-to-image dataset for sd-tiny.

Stands in for MS-COCO/PartiPrompts (DESIGN.md substitution table): scenes
of 1-3 coloured shapes on a gradient background, with captions drawn from
a small closed vocabulary. The fixed analytic encoder maps 64x64 RGB to
the 16x16x4 latent space (3 pooled colour channels + 1 high-frequency luma
channel), so the VAE decoder has a learnable inverse.

The vocabulary (word -> token id) is exported in the AOT manifest so the
rust tokenizer reproduces it exactly.
"""

from __future__ import annotations

import numpy as np

from .config import CFG

COLORS = {
    "red": (0.9, 0.15, 0.1),
    "green": (0.1, 0.8, 0.2),
    "blue": (0.15, 0.25, 0.9),
    "yellow": (0.95, 0.85, 0.1),
    "magenta": (0.85, 0.1, 0.8),
    "cyan": (0.1, 0.8, 0.85),
    "white": (0.95, 0.95, 0.95),
    "orange": (0.95, 0.55, 0.1),
}
SHAPES = ("circle", "square", "stripe")


def build_vocab() -> dict:
    """word -> token id; id 0 is <pad>."""
    words = ["<pad>"]
    words += list(COLORS)
    words += list(SHAPES)
    words += [f"x{i}" for i in range(16)]
    words += [f"y{i}" for i in range(16)]
    words += ["a", "and", "on", "dark", "light"]
    return {w: i for i, w in enumerate(words)}


VOCAB = build_vocab()


def tokenize(caption: str) -> np.ndarray:
    """Whitespace tokenizer over the closed vocabulary; pads/clips to ctx_len."""
    ids = [VOCAB.get(w, 0) for w in caption.lower().split()]
    ids = ids[: CFG.ctx_len]
    return np.asarray(ids + [0] * (CFG.ctx_len - len(ids)), np.int32)


def random_scene(rng: np.random.Generator):
    """Sample a scene spec and its caption."""
    n_obj = int(rng.integers(1, 4))
    objs = []
    words = []
    for _ in range(n_obj):
        color = list(COLORS)[rng.integers(len(COLORS))]
        shape = SHAPES[rng.integers(len(SHAPES))]
        cx, cy = int(rng.integers(2, 14)), int(rng.integers(2, 14))
        size = float(rng.uniform(1.5, 4.0))
        objs.append((shape, color, cx, cy, size))
        words += [color, shape, f"x{cx}", f"y{cy}"]
    return objs, " ".join(words)


def render_scene(objs, rng: np.random.Generator) -> np.ndarray:
    """Render to (img_h, img_w, 3) float32 in [0, 1]."""
    h, w = CFG.img_h, CFG.img_w
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    base = rng.uniform(0.05, 0.25, size=3).astype(np.float32)
    img = base[None, None, :] * (0.6 + 0.4 * (yy / h))[:, :, None]
    scale = h / CFG.latent_h  # latent-grid coordinates -> pixels
    for shape, color, cx, cy, size in objs:
        px, py, pr = (cx + 0.5) * scale, (cy + 0.5) * scale, size * scale
        rgb = np.asarray(COLORS[color], np.float32)
        if shape == "circle":
            d = np.sqrt((xx - px) ** 2 + (yy - py) ** 2)
            mask = np.clip(pr - d, 0.0, 1.0)
        elif shape == "square":
            d = np.maximum(np.abs(xx - px), np.abs(yy - py))
            mask = np.clip(pr - d, 0.0, 1.0)
        else:  # stripe: horizontal band through (px, py)
            mask = np.clip(pr / 2 - np.abs(yy - py), 0.0, 1.0)
        img = img * (1 - mask[:, :, None]) + rgb[None, None, :] * mask[:, :, None]
    return img.astype(np.float32)


def encode_latent(img: np.ndarray) -> np.ndarray:
    """Fixed analytic encoder: (img_h, img_w, 3) -> (L, latent_c) in ~[-1,1]."""
    f = CFG.img_h // CFG.latent_h
    h, w = CFG.latent_h, CFG.latent_w
    pooled = img.reshape(h, f, w, f, 3).mean(axis=(1, 3))  # (h, w, 3)
    luma = img.mean(axis=-1)
    luma_pool = luma.reshape(h, f, w, f).mean(axis=(1, 3))
    # High-frequency channel: pooled |residual| of luma inside each cell.
    up = np.repeat(np.repeat(luma_pool, f, 0), f, 1)
    hf = np.abs(luma - up).reshape(h, f, w, f).mean(axis=(1, 3))
    lat = np.concatenate([pooled * 2 - 1, (hf * 8 - 1)[..., None]], axis=-1)
    return lat.reshape(h * w, CFG.latent_c).astype(np.float32)


def make_dataset(n: int, seed: int = 0):
    """Returns (tokens (n,ctx_len) i32, latents (n,L,4) f32, images (n,HW,3))."""
    rng = np.random.default_rng(seed)
    toks, lats, imgs = [], [], []
    for _ in range(n):
        objs, caption = random_scene(rng)
        img = render_scene(objs, rng)
        toks.append(tokenize(caption))
        lats.append(encode_latent(img))
        imgs.append(img.reshape(-1, 3))
    return (np.stack(toks), np.stack(lats), np.stack(imgs))
