"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .attention import attention, mha  # noqa: F401
from .elementwise import gelu, silu  # noqa: F401
from .norms import groupnorm, layernorm  # noqa: F401
from .uni_conv import uni_conv  # noqa: F401
