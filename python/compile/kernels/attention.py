"""Attention with the paper's tile-decoupled online softmax (Eq. 5-6).

Sec. IV-C: softmax needs a global maximum, which would stall the systolic
array until the whole logit row exists. The paper instead keeps a running
``(max, exp-sum)`` pair that is updated per tile (Eq. 5-6, after online
softmax [40]) so the NCA stage rides the matmul's output stream. This is
the same recurrence as flash-attention; here it is expressed as a Pallas
kernel whose q-tile grid streams K/V tiles through VMEM, carrying the
``(m, es, acc)`` statistics in scratch — the TPU analogue of the paper's
VPU register stack (DESIGN.md §Hardware-Adaptation).

interpret=True only — see uni_conv.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
DEFAULT_K_TILE = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bk, lk, lk_pad, scale):
    """One q-tile grid step: stream K/V tiles, carry (m, es, acc)."""
    q = q_ref[...] * scale  # (bq, d)
    bq, d = q.shape
    n_kt = lk_pad // bk

    def body(i, carry):
        acc, m_prev, es_prev = carry
        k_tile = jax.lax.dynamic_slice(k_ref[...], (i * bk, 0), (bk, d))
        v_tile = jax.lax.dynamic_slice(v_ref[...], (i * bk, 0), (bk, d))
        logits = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32)
        # Edge flag: mask out K rows beyond the true sequence length.
        col = i * bk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(col < lk, logits, NEG_INF)
        # Eq. (5): tile statistics under the latest maximum.
        new_max = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - new_max)
        es_n = jnp.sum(p, axis=-1, keepdims=True)
        # Eq. (6): rescale the running exp-sum and accumulator.
        alpha = jnp.exp(m_prev - new_max)
        es = es_prev * alpha + es_n
        acc = acc * alpha + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return acc, new_max, es

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    es0 = jnp.zeros((bq, 1), jnp.float32)
    acc, _, es = jax.lax.fori_loop(0, n_kt, body, (acc0, m0, es0))
    # Norm stage: division by the final exp-sum on the read-out stream.
    o_ref[...] = acc / es


def _pad_rows(x, mult):
    l = x.shape[0]
    lp = -(-l // mult) * mult
    if lp != l:
        x = jnp.pad(x, ((0, lp - l), (0, 0)))
    return x, lp


@functools.partial(jax.jit, static_argnames=("q_tile", "k_tile"))
def attention(q, k, v, *, q_tile: int = DEFAULT_Q_TILE, k_tile: int = DEFAULT_K_TILE):
    """Single-head attention, online-softmax Pallas kernel.

    q: ``(Lq, d)``, k/v: ``(Lk, d)`` -> ``(Lq, d)``. Scale = 1/sqrt(d).
    """
    lq, d = q.shape
    lk = k.shape[0]
    scale = 1.0 / float(d) ** 0.5
    bq = min(q_tile, max(lq, 1))
    bk = min(k_tile, max(lk, 1))
    qp, lq_pad = _pad_rows(q, bq)
    kp, lk_pad = _pad_rows(k, bk)
    vp, _ = _pad_rows(v, bk)

    kernel = functools.partial(_attn_kernel, bk=bk, lk=lk, lk_pad=lk_pad, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(lq_pad // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((lk_pad, d), lambda i: (0, 0)),
            pl.BlockSpec((lk_pad, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lq_pad, d), jnp.float32),
        interpret=True,
    )(qp, kp, vp)
    return out[:lq]


def mha(q, k, v):
    """Multi-head attention over ``(heads, L, d)`` tensors via vmap."""
    return jax.vmap(attention)(q, k, v)


def vmem_bytes(lq: int, lk: int, d: int, q_tile: int = DEFAULT_Q_TILE) -> int:
    """Per-step VMEM estimate (f32) for DESIGN.md §Perf."""
    bq = min(q_tile, lq)
    return (bq * d + 2 * lk * d + bq * d + bq * 2) * 4
