"""Elementwise nonlinearities on the reconfigurable VPU datapath.

Sec. IV-D: GELU is implemented as the official sigmoid approximation [15]
(``x * sigmoid(1.702 x)``), which the paper validates as accuracy-neutral
for StableDiff; SiLU shares the same EXP/adder/divider arrays. These are
trivially streaming (no NCA stage needed) and tile over rows.

interpret=True only — see uni_conv.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...]
    # sigmoid(t) built from the EXP + adder + divider arrays (Fig. 12c).
    o_ref[...] = x / (1.0 + jnp.exp(-1.702 * x))


def _silu_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = x / (1.0 + jnp.exp(-x))


def _rowwise(kernel, x, row_tile):
    l, c = x.shape
    bt = min(row_tile, l)
    lp = -(-l // bt) * bt
    xp = jnp.pad(x, ((0, lp - l), (0, 0))) if lp != l else x
    out = pl.pallas_call(
        kernel,
        grid=(lp // bt,),
        in_specs=[pl.BlockSpec((bt, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, c), jnp.float32),
        interpret=True,
    )(xp)
    return out[:l]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def gelu(x, *, row_tile: int = DEFAULT_ROW_TILE):
    """Sigmoid-approximated GELU over ``(L, C)``."""
    return _rowwise(_gelu_kernel, x, row_tile)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def silu(x, *, row_tile: int = DEFAULT_ROW_TILE):
    """SiLU over ``(L, C)`` (ResNet blocks + time embedding)."""
    return _rowwise(_silu_kernel, x, row_tile)
