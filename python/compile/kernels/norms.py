"""2-stage layernorm / groupnorm Pallas kernels (Eq. 4).

Sec. IV-C inefficiency-(i): naive layernorm makes three passes (mean,
variance, normalise). The paper's NCA stage accumulates ``sum`` and
``square-sum`` while the preceding matmul streams out, then derives
``mu = sum/N`` and ``sigma^2 = sqsum/N - mu^2`` (Eq. 4) — one pass over
the data plus a cheap per-row epilogue. These kernels use exactly that
formulation: statistics come from single-pass sum/sq-sum accumulation,
never from a second data pass.

interpret=True only — see uni_conv.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 128


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    n = x.shape[-1]
    # NCA stage: single pass producing sum and square-sum (Eq. 4).
    s = jnp.sum(x, axis=-1, keepdims=True)
    sq = jnp.sum(x * x, axis=-1, keepdims=True)
    mu = s / n
    var = sq / n - mu * mu
    # Norm stage: applied on the operand read-out stream.
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x - mu) * inv * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def layernorm(x, gamma, beta, *, eps: float = 1e-5, row_tile: int = DEFAULT_ROW_TILE):
    """Layernorm over the last dim of ``(L, C)`` via Eq. 4 statistics."""
    l, c = x.shape
    bt = min(row_tile, l)
    lp = -(-l // bt) * bt
    xp = jnp.pad(x, ((0, lp - l), (0, 0))) if lp != l else x
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(lp // bt,),
        in_specs=[
            pl.BlockSpec((bt, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, c), jnp.float32),
        interpret=True,
    )(x if lp == l else xp, gamma, beta)
    return out[:l]


def _groupnorm_kernel(x_ref, g_ref, b_ref, o_ref, *, groups, eps):
    x = x_ref[...]
    l, c = x.shape
    cg = c // groups
    xg = x.reshape(l, groups, cg)
    n = l * cg
    # NCA: sum / square-sum per group, single pass.
    s = jnp.sum(xg, axis=(0, 2))
    sq = jnp.sum(xg * xg, axis=(0, 2))
    mu = s / n
    var = sq / n - mu * mu
    inv = jax.lax.rsqrt(var + eps)
    # Norm stage.
    xn = ((xg - mu[None, :, None]) * inv[None, :, None]).reshape(l, c)
    o_ref[...] = xn * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("groups",))
def groupnorm(x, gamma, beta, *, groups: int, eps: float = 1e-5):
    """Groupnorm over ``(L, C)`` address-centric activations.

    The reduction spans the whole spatial dim, so the kernel holds the
    full ``(L, C)`` block in VMEM — sized for the tiny model (L <= 256,
    C <= 128: 128 KiB). The real accelerator streams this through the
    VPU's NCA stage instead (modelled in rust/src/hwsim/streaming.rs).
    """
    l, c = x.shape
    assert c % groups == 0
    return pl.pallas_call(
        functools.partial(_groupnorm_kernel, groups=groups, eps=eps),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((l, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((l, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, c), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
