"""Pure-jnp reference oracles for the Pallas kernels.

Every L1 kernel in this package is checked against these functions by
``python/tests``. They use the *paper's* storage format: activations are
``(L, C)`` with ``L = H * W`` (address-centric flattened spatial dim,
Sec. IV-B), weights for conv are ``(F, C_in, C_out)`` with ``F = R * S``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_same(x, w, b, h: int, w_dim: int, stride: int = 1):
    """Reference convolution in address-centric storage.

    Args:
      x: ``(L, C_in)`` activations, ``L = h * w_dim`` (row-major spatial).
      w: ``(F, C_in, C_out)`` weights; ``F = k*k`` with k in {1, 3}; the
         f index is ``r * k + s`` (kernel row-major).
      b: ``(C_out,)`` bias.
      h, w_dim: spatial height/width of ``x``.
      stride: 1 or 2 (stride 2 implements the SD downsample conv).

    Returns:
      ``(L_out, C_out)`` with ``L_out = ceil(h/stride) * ceil(w_dim/stride)``.
    """
    f, c_in, c_out = w.shape
    k = int(round(f**0.5))
    assert k * k == f, f"non-square kernel F={f}"
    img = x.reshape(h, w_dim, c_in).transpose(2, 0, 1)[None]  # NCHW
    ker = w.reshape(k, k, c_in, c_out).transpose(3, 2, 0, 1)  # OIHW
    pad = (k - 1) // 2
    out = jax.lax.conv_general_dilated(
        img,
        ker,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    p, q = out.shape[1], out.shape[2]
    return out.transpose(1, 2, 0).reshape(p * q, c_out) + b[None, :]


def softmax(x, axis=-1):
    """Numerically-stable softmax (global max, the multi-pass baseline)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q, k, v, scale=None):
    """Single-head attention. q: (Lq, d), k/v: (Lk, d) -> (Lq, d)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = (q @ k.T) * scale
    return softmax(logits, axis=-1) @ v


def layernorm(x, gamma, beta, eps: float = 1e-5):
    """Layernorm over the last dim. x: (L, C)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma[None, :] + beta[None, :]


def groupnorm(x, gamma, beta, groups: int, eps: float = 1e-5):
    """Groupnorm in address-centric storage. x: (L, C).

    Normalises over (L, C/groups) per group — the spatial dim and the
    channels of the group, matching torch.nn.GroupNorm on (1, C, H, W).
    """
    l, c = x.shape
    assert c % groups == 0
    xg = x.reshape(l, groups, c // groups)
    mu = jnp.mean(xg, axis=(0, 2), keepdims=True)
    var = jnp.mean((xg - mu) ** 2, axis=(0, 2), keepdims=True)
    xn = ((xg - mu) / jnp.sqrt(var + eps)).reshape(l, c)
    return xn * gamma[None, :] + beta[None, :]


def gelu_sigmoid(x):
    """The paper's hardware GELU: sigmoid approximation [15]."""
    return x * jax.nn.sigmoid(1.702 * x)


def gelu_exact(x):
    """Exact (erf) GELU, used only to report the approximation error."""
    return jax.nn.gelu(x, approximate=False)


def silu(x):
    """SiLU / swish, used by SD ResNet blocks and time embedding."""
    return x * jax.nn.sigmoid(x)


def online_softmax_update(es, prev_max, xs_tile):
    """One step of the paper's Eq. (5)-(6) running softmax statistics.

    Given the running exponential sum ``es`` w.r.t. ``prev_max`` and a new
    tile ``xs_tile``, returns ``(es', new_max)`` such that after consuming
    all tiles, ``es' == sum(exp(x - max(x)))`` over everything seen.
    """
    tile_max = jnp.max(xs_tile)
    new_max = jnp.maximum(prev_max, tile_max)
    es_n = jnp.sum(jnp.exp(xs_tile - new_max))
    es = es * jnp.exp(prev_max - new_max) + es_n
    return es, new_max
