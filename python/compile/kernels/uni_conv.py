"""Uni-conv: the paper's address-centric convolution as a Pallas kernel.

Sec. IV-A/IV-B: a K×K convolution is decomposed into F = K² separate 1×1
kernels. Each 1×1 kernel is a plain ``(L, C_in) x (C_in, C_out)`` matmul
(MXU-friendly), and its partial sums are routed to the output by a simple
address map ``l -> l + δ(f)`` with edge flags. The outermost loop of the
transformed four-layer loop nest (Fig. 10 right, Line 1) runs over the F
kernel positions; here it is the slowest grid dimension, so each output
block is accumulated in place across F sequential grid steps — the Pallas
analogue of the paper's VPU partial-sum accumulation riding the systolic
array's output stream.

TPU adaptation (DESIGN.md §Hardware-Adaptation): activations are stored in
the paper's ``(L, C)`` format; the grid is ``(C_out tiles, F)`` so each
VMEM-resident output tile is revisited F times while a fresh ``(1, C_in,
C_out_tile)`` weight slice streams in — weight-stationary within a step,
exactly the paper's SA mapping. Zero-padding of the *partial sums* at the
spatial border implements the paper's edge-validity flags (an out-of-range
contribution is identically zero).

The kernel MUST be lowered with ``interpret=True`` on this image: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default C_out tile. 128 matches the MXU lane width; the tiny model's
# channel counts are below this, so most layers run as a single tile.
DEFAULT_COUT_TILE = 128


def _uni_conv_kernel(x_ref, w_ref, b_ref, o_ref, *, h, w_dim, stride, k, pad, p, q):
    """One (cout-tile, kernel-position) grid step."""
    f = pl.program_id(1)
    # Line 2-8 of the paper's loop nest: the 1x1-kernel matmul.
    partial = jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)
    ct = partial.shape[-1]
    # Line 1 + Line 9: partial-sum routing by the address map. Zero-pad the
    # partial-sum image so out-of-range source addresses contribute zero
    # (the paper's edge flag).
    img = partial.reshape(h, w_dim, ct)
    padded = jnp.pad(img, ((pad, pad), (pad, pad), (0, 0)))
    r = f // k
    s = f % k
    size_h = (p - 1) * stride + 1
    size_w = (q - 1) * stride + 1
    window = jax.lax.dynamic_slice(padded, (r, s, 0), (size_h, size_w, ct))
    contrib = window[::stride, ::stride].reshape(p * q, ct)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = contrib + b_ref[...][None, :]

    @pl.when(f != 0)
    def _accum():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("h", "w_dim", "stride", "cout_tile"))
def uni_conv(x, w, b, *, h: int, w_dim: int, stride: int = 1,
             cout_tile: int = DEFAULT_COUT_TILE):
    """Address-centric convolution.

    Args:
      x: ``(L, C_in)`` activations, ``L = h * w_dim``.
      w: ``(F, C_in, C_out)`` weights, ``F = k*k``, f index ``r*k + s``.
      b: ``(C_out,)`` bias.
      h, w_dim: spatial size of ``x``.
      stride: 1 or 2 ('same' zero padding for k=3, none for k=1).
      cout_tile: C_out tile width (VMEM sizing knob).

    Returns:
      ``(L_out, C_out)`` activations with ``L_out = ceil(h/s)*ceil(w/s)``.
    """
    l, c_in = x.shape
    f, wc_in, c_out = w.shape
    assert l == h * w_dim, f"L={l} != h*w={h * w_dim}"
    assert wc_in == c_in, f"C_in mismatch {wc_in} vs {c_in}"
    k = int(round(f**0.5))
    assert k * k == f and k in (1, 3), f"unsupported kernel F={f}"
    assert stride in (1, 2), f"unsupported stride {stride}"
    pad = (k - 1) // 2
    p = -(-h // stride)
    q = -(-w_dim // stride)

    ct = min(cout_tile, c_out)
    # Pad C_out to a tile multiple; sliced off below.
    c_out_pad = -(-c_out // ct) * ct
    if c_out_pad != c_out:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, c_out_pad - c_out)))
        b = jnp.pad(b, (0, c_out_pad - c_out))
    n_tiles = c_out_pad // ct

    kernel = functools.partial(
        _uni_conv_kernel, h=h, w_dim=w_dim, stride=stride, k=k, pad=pad, p=p, q=q
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles, f),
        in_specs=[
            pl.BlockSpec((l, c_in), lambda j, f_: (0, 0)),
            pl.BlockSpec((1, c_in, ct), lambda j, f_: (f_, 0, j)),
            pl.BlockSpec((ct,), lambda j, f_: (j,)),
        ],
        out_specs=pl.BlockSpec((p * q, ct), lambda j, f_: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p * q, c_out_pad), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:, :c_out]


def vmem_bytes(l: int, c_in: int, c_out: int, cout_tile: int = DEFAULT_COUT_TILE,
               stride: int = 1) -> int:
    """Estimated per-step VMEM footprint (f32) for DESIGN.md §Perf."""
    ct = min(cout_tile, c_out)
    lo = l // (stride * stride)
    x_b = l * c_in * 4
    w_b = c_in * ct * 4
    o_b = lo * ct * 4
    partial_b = l * ct * 4
    return x_b + w_b + o_b + partial_b
