"""sd-tiny: the L2 model — U-Net, text encoder, VAE decoder.

Structurally faithful to the paper's Fig. 3: 12 downsampling blocks
(block 1 = single 3x3 conv; blocks 4/7/10 = stride-2 downsample; ResNet +
Transformer elsewhere, plain ResNet at the deepest level), a middle block,
and 12 upsampling blocks (up-blocks 10/7/4 carry the nearest-interpolation
upsample) joined by skip-connection concatenation.

Phase-aware sampling hooks:
- ``unet_full``   also returns the main-branch inputs of up-blocks
  1..CFG.max_cut (the reusable "entry point" features, Fig. 5 bottom).
- ``unet_partial(l)`` runs only down-blocks 1..l and up-blocks l..1,
  consuming a cached entry-point feature.
- ``unet_calib``  additionally returns all 12 up-block main-branch inputs
  (the ``A_t^i`` of Eq. 1) for shift-score calibration.

Classifier-free guidance is folded inside each entry point: the batch is
doubled internally (cond ‖ uncond with a learned null context), and
``eps = eps_u + g * (eps_c - eps_u)``. Cached features are returned for
the doubled batch so partial steps reproduce both branches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import blocks as B
from .config import CFG

# Down-block schedule, 1-based index -> (kind, cin, cout, h_in).
# kind: CI = conv_in, RT = ResNet+Transformer, R = ResNet, D = downsample.
C0, C1, C2, C3 = CFG.channels
H0 = CFG.latent_h
DOWN_SCHEDULE = [
    (1, "CI", CFG.latent_c, C0, H0),
    (2, "RT", C0, C0, H0),
    (3, "RT", C0, C0, H0),
    (4, "D", C0, C0, H0),
    (5, "RT", C0, C1, H0 // 2),
    (6, "RT", C1, C1, H0 // 2),
    (7, "D", C1, C1, H0 // 2),
    (8, "RT", C1, C2, H0 // 4),
    (9, "RT", C2, C2, H0 // 4),
    (10, "D", C2, C2, H0 // 4),
    (11, "R", C2, C3, H0 // 8),
    (12, "R", C3, C3, H0 // 8),
]

# Up-block schedule, 1-based index -> (kind, c_main, c_skip, cout, h, upsample_after).
UP_SCHEDULE = [
    (1, "R", C0, C0, C0, H0, False),
    (2, "RT", C0, C0, C0, H0, False),
    (3, "RT", C0, C0, C0, H0, False),
    (4, "R", C1, C0, C0, H0 // 2, True),
    (5, "RT", C1, C1, C1, H0 // 2, False),
    (6, "RT", C1, C1, C1, H0 // 2, False),
    (7, "R", C2, C1, C1, H0 // 4, True),
    (8, "RT", C2, C2, C2, H0 // 4, False),
    (9, "RT", C2, C2, C2, H0 // 4, False),
    (10, "R", C3, C2, C2, H0 // 8, True),
    (11, "R", C3, C3, C3, H0 // 8, False),
    (12, "R", C3, C3, C3, H0 // 8, False),
]


# ------------------------------------------------------------------- init


def init_unet_params(key):
    """Initialise the full U-Net parameter pytree (deterministic)."""
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    params = {"temb": B.init_temb(next(ki)), "down": [], "up": []}
    for _i, kind, cin, cout, _h in DOWN_SCHEDULE:
        if kind == "CI":
            params["down"].append({
                "w": B._init_conv(next(ki), 3, cin, cout),
                "b": jnp.zeros((cout,)),
            })
        elif kind == "D":
            params["down"].append(B.init_downsample(next(ki), cout))
        elif kind == "R":
            params["down"].append({"res": B.init_resnet(next(ki), cin, cout)})
        else:  # RT
            params["down"].append({
                "res": B.init_resnet(next(ki), cin, cout),
                "attn": B.init_transformer(next(ki), cout),
            })
    params["mid"] = {
        "res1": B.init_resnet(next(ki), C3, C3),
        "attn": B.init_transformer(next(ki), C3),
        "res2": B.init_resnet(next(ki), C3, C3),
    }
    for _i, kind, cm, cs, cout, _h, _up in UP_SCHEDULE:
        blk = {"res": B.init_resnet(next(ki), cm + cs, cout)}
        if kind == "RT":
            blk["attn"] = B.init_transformer(next(ki), cout)
        params["up"].append(blk)
    params["out"] = {
        "gn_g": jnp.ones((C0,)),
        "gn_b": jnp.zeros((C0,)),
        "w": B._init_conv(next(ki), 3, C0, CFG.latent_c, scale=1e-2),
        "b": jnp.zeros((CFG.latent_c,)),
    }
    # Learned null context for classifier-free guidance.
    params["null_ctx"] = (
        jax.random.normal(next(ki), (CFG.ctx_len, CFG.ctx_dim), jnp.float32) * 0.02
    )
    return params


# -------------------------------------------------------- single-sample fwd


def _apply_down_block(ops, p, kind, x, temb, ctx, h, w):
    if kind == "CI":
        return ops.conv(x, p["w"], p["b"], h, w), h, w
    if kind == "D":
        return B.apply_downsample(ops, p, x, h, w), h // 2, w // 2
    y = B.apply_resnet(ops, p["res"], x, temb, h, w)
    if kind == "RT":
        y = B.apply_transformer(ops, p["attn"], y, ctx, h, w)
    return y, h, w


def _apply_up_block(ops, p, sched, x, skip, temb, ctx):
    _i, kind, _cm, _cs, _cout, h, up_after = sched
    y = jnp.concatenate([x, skip], axis=-1)
    y = B.apply_resnet(ops, p["res"], y, temb, h, h)
    if kind == "RT":
        y = B.apply_transformer(ops, p["attn"], y, ctx, h, h)
    if up_after:
        y = B.upsample_nearest(y, h, h)
    return y


def unet_single(ops, params, lat, t, ctx, n_up_inputs: int = 0):
    """One conditional forward pass of the full U-Net.

    lat: (L, latent_c), t: scalar, ctx: (ctx_len, ctx_dim).
    Returns (eps, up_inputs) with up_inputs[i-1] = main-branch input of
    up-block i (the A_t^i of Eq. 1), for i = 1..n_up_inputs.
    """
    temb = B.apply_temb(ops, params["temb"], t)
    h = w = CFG.latent_h
    x = lat
    skips = []
    for (idx, kind, _ci, _co, _h), p in zip(DOWN_SCHEDULE, params["down"]):
        x, h, w = _apply_down_block(ops, p, kind, x, temb, ctx, h, w)
        skips.append(x)

    x = B.apply_resnet(ops, params["mid"]["res1"], x, temb, h, w)
    x = B.apply_transformer(ops, params["mid"]["attn"], x, ctx, h, w)
    x = B.apply_resnet(ops, params["mid"]["res2"], x, temb, h, w)

    up_inputs = [None] * 12
    for i in range(12, 0, -1):
        up_inputs[i - 1] = x
        x = _apply_up_block(ops, params["up"][i - 1], UP_SCHEDULE[i - 1],
                            x, skips[i - 1], temb, ctx)

    y = ops.groupnorm(x, params["out"]["gn_g"], params["out"]["gn_b"], CFG.groups)
    y = ops.silu(y)
    eps = ops.conv(y, params["out"]["w"], params["out"]["b"], CFG.latent_h, CFG.latent_w)
    return eps, up_inputs[:n_up_inputs]


def unet_partial_single(ops, params, l: int, lat, t, ctx, cached):
    """Partial U-Net: down-blocks 1..l, cached entry point, up-blocks l..1.

    Only valid for l <= CFG.max_cut (all retained blocks are at the top
    16x16 resolution — the paper's retained top blocks, Fig. 5).
    cached: (L, C0) — the main-branch input of up-block l from the most
    recent complete timestep.
    """
    assert 1 <= l <= CFG.max_cut
    temb = B.apply_temb(ops, params["temb"], t)
    h = w = CFG.latent_h
    x = lat
    skips = []
    for (idx, kind, _ci, _co, _h), p in zip(DOWN_SCHEDULE[:l], params["down"][:l]):
        x, h, w = _apply_down_block(ops, p, kind, x, temb, ctx, h, w)
        skips.append(x)

    x = cached
    for i in range(l, 0, -1):
        x = _apply_up_block(ops, params["up"][i - 1], UP_SCHEDULE[i - 1],
                            x, skips[i - 1], temb, ctx)

    y = ops.groupnorm(x, params["out"]["gn_g"], params["out"]["gn_b"], CFG.groups)
    y = ops.silu(y)
    return ops.conv(y, params["out"]["w"], params["out"]["b"], CFG.latent_h, CFG.latent_w)


# ------------------------------------------------- batched + CFG entry points


def _double_batch(params, lat, ctx):
    b = lat.shape[0]
    null = jnp.broadcast_to(params["null_ctx"][None], (b, CFG.ctx_len, CFG.ctx_dim))
    lat2 = jnp.concatenate([lat, lat], axis=0)
    ctx2 = jnp.concatenate([ctx, null], axis=0)
    return lat2, ctx2


def _guide(eps2, b, g):
    eps_c, eps_u = eps2[:b], eps2[b:]
    return eps_u + g * (eps_c - eps_u)


def unet_full(ops, params, lat, t, ctx, g):
    """Full U-Net step with CFG.

    lat: (B, L, latent_c), t: (B,), ctx: (B, ctx_len, ctx_dim), g: scalar.
    Returns (eps: (B, L, latent_c), caches: tuple of CFG.max_cut tensors
    shaped (2B, L, C0) — cond‖uncond entry points for cuts l = 1..max_cut).
    """
    b = lat.shape[0]
    lat2, ctx2 = _double_batch(params, lat, ctx)
    t2 = jnp.concatenate([t, t], axis=0)
    eps2, ups = jax.vmap(
        lambda la, tt, cc: unet_single(ops, params, la, tt, cc, CFG.max_cut)
    )(lat2, t2, ctx2)
    return _guide(eps2, b, g), tuple(ups)


def unet_partial(ops, params, l: int, lat, t, ctx, g, cached):
    """Partial U-Net step with CFG. cached: (2B, L, C0)."""
    b = lat.shape[0]
    lat2, ctx2 = _double_batch(params, lat, ctx)
    t2 = jnp.concatenate([t, t], axis=0)
    eps2 = jax.vmap(
        lambda la, tt, cc, ca: unet_partial_single(ops, params, l, la, tt, cc, ca)
    )(lat2, t2, ctx2, cached)
    return _guide(eps2, b, g)


def unet_calib(ops, params, lat, t, ctx, g):
    """Calibration step: eps + all 12 up-block inputs (cond branch only)."""
    b = lat.shape[0]
    lat2, ctx2 = _double_batch(params, lat, ctx)
    t2 = jnp.concatenate([t, t], axis=0)
    eps2, ups = jax.vmap(
        lambda la, tt, cc: unet_single(ops, params, la, tt, cc, 12)
    )(lat2, t2, ctx2)
    return _guide(eps2, b, g), tuple(u[:b] for u in ups)


# ------------------------------------------------------------ text encoder


def init_text_params(key):
    keys = iter(jax.random.split(key, 32))
    d = CFG.ctx_dim
    p = {
        "embed": jax.random.normal(next(keys), (CFG.vocab, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(next(keys), (CFG.ctx_len, d), jnp.float32) * 0.02,
        "layers": [],
        "lnf_g": jnp.ones((d,)),
        "lnf_b": jnp.zeros((d,)),
    }
    for _ in range(CFG.text_layers):
        p["layers"].append({
            "ln1_g": jnp.ones((d,)),
            "ln1_b": jnp.zeros((d,)),
            "q_w": B._init_linear(next(keys), d, d),
            "k_w": B._init_linear(next(keys), d, d),
            "v_w": B._init_linear(next(keys), d, d),
            "o_w": B._init_linear(next(keys), d, d),
            "o_b": jnp.zeros((d,)),
            "ln2_g": jnp.ones((d,)),
            "ln2_b": jnp.zeros((d,)),
            "ff1_w": B._init_linear(next(keys), d, 4 * d),
            "ff1_b": jnp.zeros((4 * d,)),
            "ff2_w": B._init_linear(next(keys), 4 * d, d),
            "ff2_b": jnp.zeros((d,)),
        })
    return p


def text_encoder_single(ops, p, tokens):
    """tokens: (ctx_len,) i32 -> (ctx_len, ctx_dim)."""
    x = p["embed"][tokens] + p["pos"]
    heads = 4
    for lp in p["layers"]:
        z = ops.layernorm(x, lp["ln1_g"], lp["ln1_b"])
        q, k, v = z @ lp["q_w"], z @ lp["k_w"], z @ lp["v_w"]
        a = B._merge_heads(ops.mha(*(B._split_heads(m, heads) for m in (q, k, v))))
        x = x + a @ lp["o_w"] + lp["o_b"]
        z = ops.layernorm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + ops.gelu(z @ lp["ff1_w"] + lp["ff1_b"]) @ lp["ff2_w"] + lp["ff2_b"]
    return ops.layernorm(x, p["lnf_g"], p["lnf_b"])


def text_encoder(ops, p, tokens):
    """tokens: (B, ctx_len) i32 -> (B, ctx_len, ctx_dim)."""
    return jax.vmap(lambda tk: text_encoder_single(ops, p, tk))(tokens)


# ------------------------------------------------------------- VAE decoder


def init_vae_params(key):
    keys = iter(jax.random.split(key, 8))
    return {
        "conv_in_w": B._init_conv(next(keys), 3, CFG.latent_c, 48),
        "conv_in_b": jnp.zeros((48,)),
        "gn1_g": jnp.ones((48,)),
        "gn1_b": jnp.zeros((48,)),
        "conv1_w": B._init_conv(next(keys), 3, 48, 24),
        "conv1_b": jnp.zeros((24,)),
        "gn2_g": jnp.ones((24,)),
        "gn2_b": jnp.zeros((24,)),
        "conv2_w": B._init_conv(next(keys), 3, 24, 16),
        "conv2_b": jnp.zeros((16,)),
        "gn3_g": jnp.ones((16,)),
        "gn3_b": jnp.zeros((16,)),
        "conv_out_w": B._init_conv(next(keys), 3, 16, 3),
        "conv_out_b": jnp.zeros((3,)),
    }


def vae_decoder_single(ops, p, lat):
    """lat: (L, latent_c) @16x16 -> (img_h*img_w, 3) @64x64 RGB."""
    h = w = CFG.latent_h
    x = ops.conv(lat, p["conv_in_w"], p["conv_in_b"], h, w)
    x = ops.silu(ops.groupnorm(x, p["gn1_g"], p["gn1_b"], CFG.groups))
    x = B.upsample_nearest(x, h, w)
    h, w = 2 * h, 2 * w
    x = ops.conv(x, p["conv1_w"], p["conv1_b"], h, w)
    x = ops.silu(ops.groupnorm(x, p["gn2_g"], p["gn2_b"], CFG.groups))
    x = B.upsample_nearest(x, h, w)
    h, w = 2 * h, 2 * w
    x = ops.conv(x, p["conv2_w"], p["conv2_b"], h, w)
    x = ops.silu(ops.groupnorm(x, p["gn3_g"], p["gn3_b"], CFG.groups))
    return ops.conv(x, p["conv_out_w"], p["conv_out_b"], h, w)


def vae_decoder(ops, p, lat):
    """lat: (B, L, latent_c) -> (B, img_h*img_w, 3)."""
    return jax.vmap(lambda la: vae_decoder_single(ops, p, la))(lat)
