"""Build-time training of sd-tiny (REF backend: pure jnp, fast on CPU).

Gives the U-Net real denoiser dynamics so phase-aware sampling calibration
(Fig. 4 / Eq. 2) measures a trained model rather than noise, and trains
the VAE decoder so generated latents decode to recognisable images. The
training loss curve is logged to artifacts/train_log.json and summarised
in EXPERIMENTS.md (end-to-end validation requirement).

Run via ``python -m compile.train`` or implicitly from ``compile.aot``.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model as M
from .backends import REF
from .config import CFG


def diffusion_schedule():
    """SD's scaled-linear beta schedule -> cumulative alpha-bar (T,)."""
    betas = (
        np.linspace(CFG.beta_start**0.5, CFG.beta_end**0.5, CFG.train_steps) ** 2
    )
    return np.cumprod(1.0 - betas).astype(np.float32)


# ------------------------------------------------------------------- adam


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------- training


def train_unet(params, text_params, steps: int, batch: int = 8, lr: float = 2e-4,
               seed: int = 0, log_every: int = 20):
    """eps-prediction MSE training with 10% context dropout (CFG-style)."""
    toks, lats, _ = data.make_dataset(256, seed=seed)
    ctx_all = np.asarray(M.text_encoder(REF, text_params, jnp.asarray(toks)))
    alpha_bar = jnp.asarray(diffusion_schedule())
    n = lats.shape[0]

    def loss_fn(p, lat0, ctx, t, noise, drop):
        ab = alpha_bar[t][:, None, None]
        x_t = jnp.sqrt(ab) * lat0 + jnp.sqrt(1 - ab) * noise
        null = jnp.broadcast_to(p["null_ctx"][None], ctx.shape)
        ctx_eff = jnp.where(drop[:, None, None], null, ctx)
        eps = jax.vmap(lambda la, tt, cc: M.unet_single(REF, p, la, tt, cc, 0)[0])(
            x_t, t.astype(jnp.float32), ctx_eff
        )
        return jnp.mean((eps - noise) ** 2)

    @jax.jit
    def step_fn(p, opt, lat0, ctx, t, noise, drop):
        loss, grads = jax.value_and_grad(loss_fn)(p, lat0, ctx, t, noise, drop)
        p, opt = adam_update(p, grads, opt, lr)
        return p, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed + 1)
    log = []
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, n, size=batch)
        t = jnp.asarray(rng.integers(0, CFG.train_steps, size=batch))
        noise = jnp.asarray(rng.standard_normal((batch, CFG.latent_l, CFG.latent_c),
                                                dtype=np.float32))
        drop = jnp.asarray(rng.random(batch) < 0.1)
        params, opt, loss = step_fn(params, opt, jnp.asarray(lats[idx]),
                                    jnp.asarray(ctx_all[idx]), t, noise, drop)
        if it % log_every == 0 or it == steps - 1:
            log.append({"step": it, "loss": float(loss),
                        "elapsed_s": round(time.time() - t0, 2)})
            print(f"[train-unet] step {it:4d} loss {float(loss):.4f}")
    return params, log


def train_vae(params, steps: int, batch: int = 8, lr: float = 1e-3, seed: int = 3,
              log_every: int = 20):
    """Train the VAE decoder to invert the analytic encoder (MSE)."""
    _, lats, imgs = data.make_dataset(192, seed=seed)
    n = lats.shape[0]

    def loss_fn(p, lat, img):
        out = M.vae_decoder(REF, p, lat)
        return jnp.mean((out - img) ** 2)

    @jax.jit
    def step_fn(p, opt, lat, img):
        loss, grads = jax.value_and_grad(loss_fn)(p, lat, img)
        p, opt = adam_update(p, grads, opt, lr)
        return p, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed + 1)
    log = []
    for it in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt, loss = step_fn(params, opt, jnp.asarray(lats[idx]),
                                    jnp.asarray(imgs[idx]))
        if it % log_every == 0 or it == steps - 1:
            log.append({"step": it, "loss": float(loss)})
            print(f"[train-vae]  step {it:4d} loss {float(loss):.4f}")
    return params, log


# ------------------------------------------------------------ (de)serialise


def flatten_params(params):
    """Deterministic (path, leaf) list matching jax's lowering order."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, np.asarray(leaf, np.float32)))
    return out


def save_params(params, path: str):
    np.savez(path, **{name: leaf for name, leaf in flatten_params(params)})


def load_params(template, path: str):
    """Load leaves saved by save_params back into the template's structure."""
    stored = np.load(path)
    flat = flatten_params(template)
    leaves = [jnp.asarray(stored[name]) for name, _ in flat]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def main(out_dir: str = "../artifacts", unet_steps: int | None = None,
         vae_steps: int | None = None):
    os.makedirs(out_dir, exist_ok=True)
    unet_steps = unet_steps if unet_steps is not None else int(
        os.environ.get("SD_ACC_TRAIN_STEPS", "300"))
    vae_steps = vae_steps if vae_steps is not None else int(
        os.environ.get("SD_ACC_VAE_STEPS", "200"))

    key = jax.random.PRNGKey(CFG.seed)
    ku, kt, kv = jax.random.split(key, 3)
    unet = M.init_unet_params(ku)
    text = M.init_text_params(kt)
    vae = M.init_vae_params(kv)

    unet, unet_log = train_unet(unet, text, steps=unet_steps)
    vae, vae_log = train_vae(vae, steps=vae_steps)

    save_params(unet, os.path.join(out_dir, "params_unet.npz"))
    save_params(text, os.path.join(out_dir, "params_text.npz"))
    save_params(vae, os.path.join(out_dir, "params_vae.npz"))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump({"unet": unet_log, "vae": vae_log,
                   "unet_steps": unet_steps, "vae_steps": vae_steps}, f, indent=1)
    print(f"[train] params + log written to {out_dir}")


if __name__ == "__main__":
    main()
