"""AOT pipeline invariants: weight serialisation round-trips, manifest
consistency, scheduler table, dataset/tokeniser determinism."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data, train
from compile.config import CFG

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_flatten_params_deterministic_order():
    key = jax.random.PRNGKey(0)
    p = {"b": {"x": jnp.ones((2,)), "a": jnp.zeros((3,))},
         "a": [jnp.ones((1,)), jnp.full((2, 2), 2.0)]}
    f1 = train.flatten_params(p)
    f2 = train.flatten_params(p)
    assert [n for n, _ in f1] == [n for n, _ in f2]
    # Lowering order == tree_leaves order.
    leaves = jax.tree_util.tree_leaves(p)
    for (_, a), b in zip(f1, leaves):
        assert np.array_equal(a, np.asarray(b))
    del key


def test_save_load_roundtrip(tmp_path):
    key = jax.random.PRNGKey(3)
    template = {"w": jax.random.normal(key, (4, 5)), "b": jnp.zeros((5,))}
    path = str(tmp_path / "p.npz")
    train.save_params(template, path)
    loaded = train.load_params(
        {"w": jnp.zeros((4, 5)), "b": jnp.ones((5,))}, path
    )
    assert np.allclose(np.asarray(loaded["w"]), np.asarray(template["w"]))
    assert np.allclose(np.asarray(loaded["b"]), 0.0)


def test_diffusion_schedule_monotone():
    ab = train.diffusion_schedule()
    assert ab.shape == (CFG.train_steps,)
    assert np.all(np.diff(ab) < 0)
    assert ab[0] > 0.99 and ab[-1] < 0.02


def test_vocab_stable_and_padded_tokenizer():
    v = data.build_vocab()
    assert v["<pad>"] == 0
    assert v == data.VOCAB
    toks = data.tokenize("red circle x3 y4")
    assert toks.shape == (CFG.ctx_len,)
    assert toks[0] == v["red"]
    assert toks[-1] == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_dataset_deterministic_and_bounded(seed):
    t1, l1, i1 = data.make_dataset(4, seed=seed)
    t2, l2, i2 = data.make_dataset(4, seed=seed)
    assert np.array_equal(t1, t2)
    assert np.array_equal(l1, l2)
    assert i1.min() >= 0.0 and i1.max() <= 1.0
    assert np.abs(l1).max() <= 3.0
    del i2, l2


def test_encoder_latent_shape_and_channels():
    rng = np.random.default_rng(0)
    objs, _ = data.random_scene(rng)
    img = data.render_scene(objs, rng)
    lat = data.encode_latent(img)
    assert lat.shape == (CFG.latent_l, CFG.latent_c)
    # Colour channels track the pooled image.
    pooled = img.reshape(CFG.latent_h, 4, CFG.latent_w, 4, 3).mean(axis=(1, 3))
    assert np.allclose(lat[:, :3].reshape(CFG.latent_h, CFG.latent_w, 3),
                       pooled * 2 - 1, atol=1e-5)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistent_with_weight_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["latent_h"] == CFG.latent_h
    assert set(man["weights"]) == {"unet", "text", "vae"}
    for name, ws in man["weights"].items():
        blob = os.path.getsize(os.path.join(ART, ws["file"]))
        total = sum(e["len"] for e in ws["table"]) * 4
        assert blob == total, f"{name}: file {blob} != table {total}"
        # Offsets are contiguous.
        off = 0
        for e in ws["table"]:
            assert e["offset"] == off
            off += e["len"] * 4
    # Every artifact file exists and n_params matches its weight set.
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["file"])), a["file"]
        wset = "unet" if a["name"].startswith("unet") else (
            "text" if a["name"].startswith("text") else "vae")
        assert a["n_params"] == len(man["weights"][wset]["table"])


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_hlo_artifacts_are_parseable_text():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        with open(path) as fh:
            head = fh.read(4096)
        assert "HloModule" in head, f"{a['file']} lacks HloModule header"
