"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/strides/tiles; assert_allclose against ref.py.
This is the core correctness signal for everything the AOT artifacts
compute (DESIGN.md S1).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.attention import attention, mha
from compile.kernels.uni_conv import uni_conv
from compile.kernels import elementwise, norms, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- uni_conv


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(2, 9),
    w=st.integers(2, 9),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
)
def test_uni_conv_matches_ref(seed, h, w, cin, cout, k, stride):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (h * w, cin))
    wt = _arr(rng, (k * k, cin, cout))
    b = _arr(rng, (cout,))
    got = uni_conv(x, wt, b, h=h, w_dim=w, stride=stride)
    want = ref.conv2d_same(x, wt, b, h, w, stride)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), cout_tile=st.sampled_from([2, 3, 8, 128]))
def test_uni_conv_cout_tiling_invariant(seed, cout_tile):
    """C_out tiling is a pure scheduling knob: results must not change."""
    rng = np.random.default_rng(seed)
    h, w, cin, cout = 5, 4, 3, 7
    x = _arr(rng, (h * w, cin))
    wt = _arr(rng, (9, cin, cout))
    b = _arr(rng, (cout,))
    base = uni_conv(x, wt, b, h=h, w_dim=w, cout_tile=128)
    tiled = uni_conv(x, wt, b, h=h, w_dim=w, cout_tile=cout_tile)
    assert_allclose(np.asarray(tiled), np.asarray(base), rtol=1e-5, atol=1e-5)


def test_uni_conv_identity_kernel():
    """A 3x3 kernel with only the centre tap set is the identity map."""
    rng = np.random.default_rng(0)
    h, w, c = 6, 6, 4
    x = _arr(rng, (h * w, c))
    wt = np.zeros((9, c, c), np.float32)
    wt[4] = np.eye(c)
    got = uni_conv(x, jnp.asarray(wt), jnp.zeros((c,)), h=h, w_dim=w)
    assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)


def test_uni_conv_edge_flags_zero_padding():
    """Ones-input, ones-kernel: corner outputs see only 4 taps, centre 9."""
    h = w = 5
    x = jnp.ones((h * w, 1))
    wt = jnp.ones((9, 1, 1))
    out = np.asarray(uni_conv(x, wt, jnp.zeros((1,)), h=h, w_dim=w)).reshape(h, w)
    assert out[0, 0] == pytest.approx(4.0)
    assert out[0, 2] == pytest.approx(6.0)
    assert out[2, 2] == pytest.approx(9.0)


def test_uni_conv_stride2_shape():
    rng = np.random.default_rng(1)
    for h, w in [(8, 8), (6, 4), (5, 5), (7, 3)]:
        x = _arr(rng, (h * w, 2))
        wt = _arr(rng, (9, 2, 3))
        got = uni_conv(x, wt, jnp.zeros((3,)), h=h, w_dim=w, stride=2)
        assert got.shape == (-(-h // 2) * -(-w // 2), 3)


# --------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    lq=st.integers(1, 70),
    lk=st.integers(1, 70),
    d=st.sampled_from([4, 8, 16]),
    tile=st.sampled_from([8, 16, 128]),
)
def test_attention_matches_ref(seed, lq, lk, d, tile):
    rng = np.random.default_rng(seed)
    q, k, v = (_arr(rng, (n, d)) for n in (lq, lk, lk))
    got = attention(q, k, v, q_tile=tile, k_tile=tile)
    want = ref.attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_attention_tile_size_invariant():
    rng = np.random.default_rng(3)
    q, k, v = (_arr(rng, (40, 8)) for _ in range(3))
    a = attention(q, k, v, q_tile=8, k_tile=8)
    b = attention(q, k, v, q_tile=128, k_tile=128)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_attention_large_logits_stable():
    """Online softmax (Eq. 5-6) must survive logits far above exp range."""
    rng = np.random.default_rng(4)
    q = _arr(rng, (8, 4)) * 100.0
    k = _arr(rng, (32, 4)) * 100.0
    v = _arr(rng, (32, 4))
    got = np.asarray(attention(q, k, v, k_tile=8))
    assert np.all(np.isfinite(got))
    assert_allclose(got, np.asarray(ref.attention(q, k, v)), rtol=1e-3, atol=1e-4)


def test_mha_heads_independent():
    rng = np.random.default_rng(5)
    q, k, v = (_arr(rng, (3, 20, 8)) for _ in range(3))
    got = np.asarray(mha(q, k, v))
    for hd in range(3):
        want = np.asarray(ref.attention(q[hd], k[hd], v[hd]))
        assert_allclose(got[hd], want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), n_tiles=st.integers(1, 8), tile=st.integers(1, 16))
def test_online_softmax_update_rule(seed, n_tiles, tile):
    """Eq. (5)-(6): streaming exp-sum equals the global-max exp-sum."""
    rng = np.random.default_rng(seed)
    xs = _arr(rng, (n_tiles * tile,)) * 10.0
    es, m = jnp.float32(0.0), jnp.float32(-1e30)
    for i in range(n_tiles):
        es, m = ref.online_softmax_update(es, m, xs[i * tile:(i + 1) * tile])
    want_m = jnp.max(xs)
    want_es = jnp.sum(jnp.exp(xs - want_m))
    assert m == pytest.approx(float(want_m))
    assert float(es) == pytest.approx(float(want_es), rel=1e-5)


# ------------------------------------------------------------------- norms


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    l=st.integers(1, 60),
    c=st.sampled_from([4, 8, 32]),
    row_tile=st.sampled_from([4, 16, 128]),
)
def test_layernorm_matches_ref(seed, l, c, row_tile):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (l, c))
    g, b = _arr(rng, (c,)), _arr(rng, (c,))
    got = norms.layernorm(x, g, b, row_tile=row_tile)
    want = ref.layernorm(x, g, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    l=st.integers(1, 60),
    groups=st.sampled_from([1, 2, 4]),
    cg=st.integers(1, 8),
)
def test_groupnorm_matches_ref(seed, l, groups, cg):
    rng = np.random.default_rng(seed)
    c = groups * cg
    x = _arr(rng, (l, c))
    g, b = _arr(rng, (c,)), _arr(rng, (c,))
    got = norms.groupnorm(x, g, b, groups=groups)
    want = ref.groupnorm(x, g, b, groups)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)


def test_layernorm_output_statistics():
    """With unit gamma / zero beta each row is ~N(0,1)-normalised."""
    rng = np.random.default_rng(6)
    x = _arr(rng, (10, 64)) * 5.0 + 3.0
    out = np.asarray(norms.layernorm(x, jnp.ones((64,)), jnp.zeros((64,))))
    assert_allclose(out.mean(axis=1), np.zeros(10), atol=1e-5)
    assert_allclose(out.std(axis=1), np.ones(10), atol=1e-2)


# ------------------------------------------------------------- elementwise


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), l=st.integers(1, 80), c=st.integers(1, 16))
def test_gelu_silu_match_ref(seed, l, c):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (l, c)) * 4.0
    assert_allclose(np.asarray(elementwise.gelu(x)),
                    np.asarray(ref.gelu_sigmoid(x)), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(elementwise.silu(x)),
                    np.asarray(ref.silu(x)), rtol=1e-5, atol=1e-6)


def test_gelu_sigmoid_close_to_exact():
    """Paper Sec. IV-D: sigmoid GELU is accuracy-neutral — bound its error."""
    x = jnp.linspace(-6.0, 6.0, 1001).reshape(-1, 1)
    approx = np.asarray(elementwise.gelu(x))
    exact = np.asarray(ref.gelu_exact(x))
    assert np.abs(approx - exact).max() < 0.021
