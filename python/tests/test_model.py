"""L2 model correctness: Pallas backend vs pure-jnp REF backend on the
full U-Net, partial-U-Net consistency, CFG semantics, text encoder and
VAE shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.backends import PALLAS, REF
from compile.config import CFG


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(CFG.seed)
    ku, kt, kv = jax.random.split(key, 3)
    return {
        "unet": M.init_unet_params(ku),
        "text": M.init_text_params(kt),
        "vae": M.init_vae_params(kv),
    }


@pytest.fixture(scope="module")
def inputs():
    k = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(k)
    return {
        "lat": jax.random.normal(k1, (1, CFG.latent_l, CFG.latent_c)),
        "t": jnp.array([321.0]),
        "ctx": jax.random.normal(k2, (1, CFG.ctx_len, CFG.ctx_dim)),
    }


def test_pallas_backend_matches_ref_on_full_unet(params, inputs):
    """The decisive L1-in-context check: the entire U-Net forward under
    the Pallas kernels must match the pure-jnp oracle composition."""
    ep, cp = M.unet_full(PALLAS, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    er, cr = M.unet_full(REF, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    assert_allclose(np.asarray(ep), np.asarray(er), rtol=5e-3, atol=5e-4)
    for a, b in zip(cp, cr):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_partial_equals_full_with_fresh_cache(params, inputs):
    eps, caches = M.unet_full(REF, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    for l in range(1, CFG.max_cut + 1):
        pe = M.unet_partial(REF, params["unet"], l, inputs["lat"], inputs["t"],
                            inputs["ctx"], 7.5, caches[l - 1])
        assert_allclose(np.asarray(pe), np.asarray(eps), rtol=1e-5, atol=1e-6)


def test_cfg_guidance_semantics(params, inputs):
    """g=0 must equal the unconditional prediction; g=1 the conditional."""
    u = params["unet"]
    lat1 = inputs["lat"][0]
    t1 = inputs["t"][0]
    null = u["null_ctx"]
    eps_c, _ = M.unet_single(REF, u, lat1, t1, inputs["ctx"][0], 0)
    eps_u, _ = M.unet_single(REF, u, lat1, t1, null, 0)
    g0 = M.unet_full(REF, u, inputs["lat"], inputs["t"], inputs["ctx"], 0.0)[0][0]
    g1 = M.unet_full(REF, u, inputs["lat"], inputs["t"], inputs["ctx"], 1.0)[0][0]
    assert_allclose(np.asarray(g0), np.asarray(eps_u), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(g1), np.asarray(eps_c), rtol=1e-5, atol=1e-6)


def test_calib_exposes_12_block_inputs(params, inputs):
    _, ups = M.unet_calib(REF, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    assert len(ups) == 12
    # Top three blocks share the (L, C0) shape used by the caches.
    for u in ups[:3]:
        assert u.shape == (1, CFG.latent_l, CFG.channels[0])


def test_text_encoder_shape_and_padding(params):
    toks = jnp.zeros((2, CFG.ctx_len), jnp.int32)
    out = M.text_encoder(REF, params["text"], toks)
    assert out.shape == (2, CFG.ctx_len, CFG.ctx_dim)
    assert np.all(np.isfinite(np.asarray(out)))


def test_vae_decoder_shape(params, inputs):
    out = M.vae_decoder(REF, params["vae"], inputs["lat"])
    assert out.shape == (1, CFG.img_h * CFG.img_w, 3)


def test_unet_deterministic(params, inputs):
    a, _ = M.unet_full(REF, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    b, _ = M.unet_full(REF, params["unet"], inputs["lat"], inputs["t"], inputs["ctx"], 7.5)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_timestep_changes_output(params, inputs):
    a, _ = M.unet_full(REF, params["unet"], inputs["lat"], jnp.array([100.0]), inputs["ctx"], 7.5)
    b, _ = M.unet_full(REF, params["unet"], inputs["lat"], jnp.array([900.0]), inputs["ctx"], 7.5)
    assert float(jnp.abs(a - b).max()) > 1e-6
