//! Cache hot path: cold calibrate-and-search vs warm store lookup, and
//! request-cache hit latency.
//!
//! Needs no AOT artifacts — the cold path times the CPU side of the
//! Fig. 7 pipeline (Eq. 2 analysis + candidate enumeration + store
//! population) against the warm path (content-addressed lookup + decode).
//! The acceptance bar for the cache subsystem is warm >= 10x faster than
//! cold; the bench asserts it.
//!
//! Run: `cargo bench --bench bench_cache_hotpath`

use sd_acc::cache::{Cache, PlanFront, StoreConfig};
use sd_acc::coordinator::{GenRequest, GenResult, GenStats};
use sd_acc::models::inventory::sd_v14;
use sd_acc::pas::calibrate::analyse;
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::StepAction;
use sd_acc::pas::search::{enumerate_candidates, SearchConstraints};
use sd_acc::runtime::Tensor;
use sd_acc::util::bench::Bench;

/// Fig. 4-shaped synthetic shift-score curves (knee at 45%).
fn synthetic_raw(steps: usize) -> Vec<Vec<f64>> {
    let t1 = steps - 1;
    (0..12)
        .map(|b| {
            (0..t1)
                .map(|t| {
                    let x = t as f64 / t1 as f64;
                    if x < 0.45 {
                        0.7 + 0.3 * (-5.0 * (x - 0.1) * (x - 0.1)).exp()
                    } else if b < 2 {
                        0.5 + 0.3 * (9.0 * x).sin().abs()
                    } else {
                        0.05
                    }
                })
                .collect()
        })
        .collect()
}

fn main() {
    let dir = std::env::temp_dir().join(format!("sdacc_bench_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = Cache::open(StoreConfig::new(&dir), 0xbe9c).expect("open cache");

    let steps = 50usize;
    let prompts: Vec<String> =
        vec!["red circle x4 y4".into(), "green stripe x8 y8".into()];
    let raw = synthetic_raw(steps);
    let noise: Vec<f64> = (0..steps).map(|t| 1.0 / (1.0 + t as f64)).collect();
    let cost = CostModel::new(&sd_v14());
    let cons = SearchConstraints { total_steps: steps, ..Default::default() };

    let mut b = Bench::default();

    // Cold: the full CPU-side calibrate-and-search pipeline + store
    // population (what a first run pays, minus the runtime trajectories
    // which only make the ratio larger).
    let cold_ns = b.run("cold: analyse + enumerate + populate store", || {
        let report = analyse(raw.clone(), noise.clone(), steps, prompts.len());
        let cands = enumerate_candidates(&report, &cost, &cons, 3);
        let front = PlanFront {
            total_steps: cons.total_steps,
            min_mac_reduction: cons.min_mac_reduction,
            min_psnr_db: cons.min_psnr_db,
            d_star: report.d_star,
            candidates: cands.into_iter().take(32).collect(),
        };
        cache.put_calibration(steps, &prompts, 7.5, &report).expect("put calib");
        cache
            .put_plan_front(&cons, &prompts, report.d_star, &report.outliers, &front)
            .expect("put front");
    });

    // Warm: what every later process start pays instead.
    let report = cache.get_calibration(steps, &prompts, 7.5).expect("populated");
    let warm_ns = b.run("warm: calibration + plan front lookup", || {
        let rep = cache.get_calibration(steps, &prompts, 7.5).expect("calib hit");
        let front = cache
            .get_plan_front(&cons, &prompts, rep.d_star, &rep.outliers)
            .expect("front hit");
        std::hint::black_box(front.candidates.len());
    });

    b.run("warm: Auto plan resolution (best_plan)", || {
        std::hint::black_box(cache.best_plan(steps));
    });

    // Request cache: sd-tiny-sized latent (16x16x4).
    let mut req = GenRequest::new("blue square x3 y9 red circle x12 y2", 4242);
    req.steps = steps;
    let result = GenResult {
        latent: Tensor::new(vec![256, 4], (0..1024).map(|i| (i as f32 * 0.37).sin()).collect())
            .expect("latent"),
        stats: GenStats {
            actions: vec![StepAction::Full; steps],
            step_ms: vec![10.0; steps],
            mac_reduction: 1.0,
            total_ms: 500.0,
        },
    };
    cache.put_result(&req, &result).expect("put result");
    b.run("request cache hit (1024-elem latent)", || {
        let hit = cache.get_result(&req).expect("request hit");
        std::hint::black_box(hit.latent.data().len());
    });
    let absent = GenRequest::new("never generated", 1);
    b.run("request cache miss (key absent)", || {
        std::hint::black_box(cache.get_result(&absent).is_none());
    });

    b.emit_json();

    let ratio = cold_ns / warm_ns.max(1.0);
    println!(
        "\ncold/warm ratio: {ratio:.1}x (D*={} outliers={:?})",
        report.d_star, report.outliers
    );
    assert!(
        ratio >= 10.0,
        "acceptance: warm lookup must be >= 10x faster than cold (got {ratio:.1}x)"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
