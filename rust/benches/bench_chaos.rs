//! Chaos bench: the deterministic fault-injection workload behind the
//! committed `BENCH_chaos.json` trajectory (repo root).
//!
//! Two phases over the sim backend:
//!
//! 1. **Transient wave** (closed loop): a seeded fault schedule fails
//!    ~half of first attempts with transient execute errors plus
//!    latency spikes. Reports goodput, how many jobs retried, and the
//!    retry recovery ratio — gated at >= 95%, with exactly one terminal
//!    event per job.
//! 2. **Pressure** (bursty open loop via `server::loadgen`): a
//!    fault-free server with shedding and brownout armed, driven by the
//!    deterministic bursty arrival process. Reports sheds, brownout
//!    transitions, degraded admissions and load-engine accounting —
//!    gated on brownout engaging and the report's terminal accounting.
//!
//! Modes (ci.sh):
//!   `--smoke`  validate only: schema keys present, gates hold. No file
//!              writes.
//!   `--commit` everything `--smoke` checks, then rewrite
//!              `BENCH_chaos.json`.
//!   default    measure and print, write nothing.
//!
//! Run: `cargo bench --bench bench_chaos [-- --smoke | -- --commit]`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::runtime::{BackendKind, FaultSpec, RuntimeService};
use sd_acc::server::loadgen::{run_load, LoadReport, LoadSpec};
use sd_acc::server::{JobEvent, ResiliencePolicy, Server, ServerConfig};
use sd_acc::util::json::Json;

/// Keys every BENCH_chaos.json point must carry (schema validation).
const REQUIRED_KEYS: [&str; 12] = [
    "bench",
    "wave_jobs",
    "wave_goodput_per_sec",
    "wave_retried_jobs",
    "wave_retries",
    "wave_recovery_ratio",
    "wave_errors",
    "load_submitted",
    "load_goodput_per_sec",
    "sheds",
    "brownout_transitions",
    "degraded",
];

struct WaveMeasured {
    jobs: u64,
    goodput_per_sec: f64,
    retried: u64,
    retries: u64,
    recovery_ratio: f64,
    errors: u64,
}

/// Phase 1: closed-loop transient wave. Same schedule family as
/// `tests/integration_chaos.rs` — err=0.15 over 4 faultable calls per
/// attempt fails ~48% of first attempts; a 12-retry budget makes
/// permanent failure a ~1e-4 tail.
fn run_wave() -> anyhow::Result<WaveMeasured> {
    let art_dir =
        std::env::temp_dir().join(format!("sdacc_bench_chaos_art_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&art_dir);
    let spec = FaultSpec::parse("seed=11,err=0.15,slow=0.05,slow_ms=1")?;
    let svc = RuntimeService::start_with_faults(BackendKind::Sim, &art_dir, Some(spec))?;
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(0),
            resilience: ResiliencePolicy {
                retry_budget: 12,
                backoff_base: Duration::from_micros(200),
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let client = server.client();
    let n = 30u64;
    let t0 = Instant::now();
    let mut retried = 0u64;
    let mut recovered = 0u64;
    let mut ok = 0u64;
    for i in 0..n {
        let mut r = GenRequest::new(&format!("wave {i}"), 8_800 + i);
        r.steps = 3;
        let h = client.submit(r).map_err(|e| anyhow::anyhow!("submit {i}: {e:?}"))?;
        let (events, outcome) = h.wait_with_events();
        anyhow::ensure!(
            events.iter().filter(|e| e.is_terminal()).count() == 1,
            "job {i}: want exactly one terminal event"
        );
        let scheds =
            events.iter().filter(|e| matches!(e, JobEvent::Scheduled { .. })).count();
        if scheds > 1 {
            retried += 1;
            if outcome.is_ok() {
                recovered += 1;
            }
        }
        if outcome.is_ok() {
            ok += 1;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let s = server.metrics.summary();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&art_dir);
    anyhow::ensure!(s.completed + s.errors == n, "terminal accounting under chaos");
    anyhow::ensure!(s.retries_recovered == recovered, "recovery counter agrees with event logs");
    Ok(WaveMeasured {
        jobs: n,
        goodput_per_sec: ok as f64 / wall_s.max(1e-9),
        retried,
        retries: s.retries,
        recovery_ratio: if retried == 0 { 1.0 } else { recovered as f64 / retried as f64 },
        errors: s.errors,
    })
}

struct PressureMeasured {
    report: LoadReport,
    sheds: u64,
    brownout_transitions: u64,
    degraded: u64,
}

/// Phase 2: the deterministic load engine drives a bursty arrival
/// process at a fault-free server with the pressure ladder armed. One
/// worker against 10-request bursts guarantees the smoothed depth
/// crosses the brownout threshold.
fn run_pressure() -> anyhow::Result<PressureMeasured> {
    let art_dir =
        std::env::temp_dir().join(format!("sdacc_bench_chaos_press_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&art_dir);
    let svc = RuntimeService::start_with_faults(BackendKind::Sim, &art_dir, None)?;
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(0),
            resilience: ResiliencePolicy {
                shed_low_depth: Some(4),
                brownout_enter: Some(5),
                brownout_exit: 2,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let client = server.client();
    let spec = LoadSpec::parse("bursty:rate=2000,burst=10@5,n=30,seed=3,steps=12,cooldown=8")
        .map_err(|e| anyhow::anyhow!(e))?;
    let report = run_load(&client, &spec);
    let s = server.metrics.summary();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&art_dir);
    let terminals =
        report.ok + report.failed + report.rejected + report.cancelled + report.deadline_miss;
    anyhow::ensure!(
        terminals == report.submitted,
        "load accounting: {terminals} terminals vs {} submitted",
        report.submitted
    );
    Ok(PressureMeasured {
        report,
        sheds: s.sheds,
        brownout_transitions: s.brownout_transitions,
        degraded: s.degraded,
    })
}

/// Schema-validate a BENCH_chaos.json document.
fn validate(doc: &Json) -> Result<(), String> {
    for k in REQUIRED_KEYS {
        if doc.get(k).is_none() {
            return Err(format!("BENCH_chaos.json missing required key '{k}'"));
        }
    }
    let ratio = doc.get_f64("wave_recovery_ratio").unwrap_or(-1.0);
    if !(0.0..=1.0).contains(&ratio) {
        return Err(format!("wave_recovery_ratio {ratio} outside [0, 1]"));
    }
    for k in ["wave_goodput_per_sec", "load_goodput_per_sec", "wave_retried_jobs"] {
        let v = doc.get_f64(k).ok_or_else(|| format!("key '{k}' is not a number"))?;
        if v <= 0.0 {
            return Err(format!("key '{k}' must be > 0 (got {v})"));
        }
    }
    Ok(())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let commit = std::env::args().any(|a| a == "--commit");

    let w = run_wave().expect("chaos wave workload");
    println!(
        "chaos bench wave: {} jobs | {:.0} ok/s | {} retried ({} re-dispatches) | recovery {:.3} | {} permanent failures",
        w.jobs, w.goodput_per_sec, w.retried, w.retries, w.recovery_ratio, w.errors
    );
    assert!(w.retried >= 3, "the wave should transiently fail a material share of jobs");
    assert!(
        w.recovery_ratio >= 0.95,
        "retry recovery regression: {:.3} < 0.95",
        w.recovery_ratio
    );

    let p = run_pressure().expect("pressure workload");
    println!(
        "chaos bench pressure: {} submitted, {} ok, {} rejected | {} sheds, {} brownout transitions, {} degraded | {:.0} ok/s",
        p.report.submitted,
        p.report.ok,
        p.report.rejected,
        p.sheds,
        p.brownout_transitions,
        p.degraded,
        p.report.goodput()
    );
    assert!(
        p.brownout_transitions >= 1,
        "10-request bursts against one worker must engage brownout"
    );
    assert!(p.report.ok >= 1, "pressure phase served nothing");

    let doc = Json::obj(vec![
        ("bench", Json::str("chaos_resilience")),
        ("wave_jobs", Json::num(w.jobs as f64)),
        ("wave_goodput_per_sec", Json::num(w.goodput_per_sec)),
        ("wave_retried_jobs", Json::num(w.retried as f64)),
        ("wave_retries", Json::num(w.retries as f64)),
        ("wave_recovery_ratio", Json::num(w.recovery_ratio)),
        ("wave_errors", Json::num(w.errors as f64)),
        ("load_submitted", Json::num(p.report.submitted as f64)),
        ("load_ok", Json::num(p.report.ok as f64)),
        ("load_rejected", Json::num(p.report.rejected as f64)),
        ("load_goodput_per_sec", Json::num(p.report.goodput())),
        ("sheds", Json::num(p.sheds as f64)),
        ("brownout_transitions", Json::num(p.brownout_transitions as f64)),
        ("degraded", Json::num(p.degraded as f64)),
    ]);
    validate(&doc).expect("fresh measurement must satisfy the BENCH_chaos schema");
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_chaos.json");
    if let Some(prev) = std::fs::read_to_string(&out).ok().and_then(|s| Json::parse(&s).ok()) {
        validate(&prev).expect("committed BENCH_chaos.json must satisfy the schema");
    }

    if commit {
        std::fs::write(&out, doc.to_string()).expect("write BENCH_chaos.json");
        println!("wrote {}", out.display());
    } else if smoke {
        println!("bench_chaos --smoke: schema, recovery and pressure gates hold");
    }
}
