//! Fig. 15 — latency reduction by 2-stage streaming computing on isolated
//! SD v1.4 transformer layers (self-attention and FFN at sequence lengths
//! 4096 / 1024 / 256). Paper: softmax savings 39/24/14 %, FFN 25/14/8 %.

use sd_acc::hwsim::arch::{AccelConfig, NonlinearMode};
use sd_acc::hwsim::dataflow::matmul_cycles;
use sd_acc::hwsim::streaming::nonlinear_visible_cycles;
use sd_acc::models::inventory::OpKind;
use sd_acc::util::table::{f, Table};

fn main() {
    let cfg = AccelConfig::default();
    let layers = [(4096usize, 320usize, "-1"), (1024, 640, "-2"), (256, 1280, "-3")];

    println!("== Fig. 15 (left): self-attention ==");
    let mut t = Table::new(&["layer", "seq", "matmul (Mcyc)", "softmax base (Mcyc)", "reduction", "paper"]);
    let paper_attn = [0.39, 0.24, 0.14];
    for (i, (seq, c, tag)) in layers.iter().enumerate() {
        let mm = matmul_cycles(&cfg, *seq, *seq, *c).cycles
            + matmul_cycles(&cfg, *seq, *c, *seq).cycles;
        let sm = OpKind::Softmax { rows: *seq, cols: *seq };
        let base = nonlinear_visible_cycles(&cfg, NonlinearMode::StoreThenCompute, &sm);
        let stream = nonlinear_visible_cycles(&cfg, NonlinearMode::Streaming2Stage, &sm);
        let red = 1.0 - (mm + stream) / (mm + base);
        t.row(vec![
            format!("attn{tag}"),
            seq.to_string(),
            f(mm / 1e6, 2),
            f(base / 1e6, 2),
            format!("{:.1}%", red * 100.0),
            format!("{:.0}%", paper_attn[i] * 100.0),
        ]);
        assert!((red - paper_attn[i]).abs() < 0.05, "attn{tag} off paper band");
    }
    t.print();

    println!("\n== Fig. 15 (right): FFN ==");
    let paper_ffn = [0.25, 0.14, 0.08];
    let mut t = Table::new(&["layer", "seq", "matmul (Mcyc)", "nonlinear base (Mcyc)", "reduction", "paper"]);
    for (i, (seq, c, tag)) in layers.iter().enumerate() {
        let inner = 4 * c;
        let mm = matmul_cycles(&cfg, *seq, 2 * inner, *c).cycles
            + matmul_cycles(&cfg, *seq, *c, inner).cycles;
        let base = nonlinear_visible_cycles(
            &cfg,
            NonlinearMode::StoreThenCompute,
            &OpKind::Layernorm { rows: *seq, cols: *c },
        ) + nonlinear_visible_cycles(
            &cfg,
            NonlinearMode::StoreThenCompute,
            &OpKind::Gelu { n: seq * inner },
        );
        let stream = 2.0
            * nonlinear_visible_cycles(
                &cfg,
                NonlinearMode::Streaming2Stage,
                &OpKind::Gelu { n: seq * inner },
            );
        let red = 1.0 - (mm + stream) / (mm + base);
        t.row(vec![
            format!("ffn{tag}"),
            seq.to_string(),
            f(mm / 1e6, 2),
            f(base / 1e6, 2),
            format!("{:.1}%", red * 100.0),
            format!("{:.0}%", paper_ffn[i] * 100.0),
        ]);
        assert!((red - paper_ffn[i]).abs() < 0.06, "ffn{tag} off paper band");
    }
    t.print();
    println!("\nall reductions within the paper's bands");
}
