//! Fig. 16 — adaptive fusion per-layer traffic gains (left) and the
//! global-buffer size exploration (right; 2 MB is the paper's sweet spot).

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::simulate;
use sd_acc::hwsim::fusion::{plan_fusion, FusionKind};
use sd_acc::hwsim::memory::{op_traffic, FusionTag};
use sd_acc::models::inventory::{conv3x3_layers, sd_v14, unet_ops};
use sd_acc::util::table::{f, Table};

fn main() {
    let cfg = AccelConfig::default();
    let ops = unet_ops(&sd_v14());
    let convs = conv3x3_layers(&ops);
    let plan = plan_fusion(&cfg, &convs);

    println!("== Fig. 16 (left): per-conv-layer fusion decision and traffic ==");
    let mut t = Table::new(&["layer", "name", "kind", "traffic no-fuse (MB)", "traffic fused (MB)", "saving"]);
    let mut p_nofuse = Policy::optimized();
    p_nofuse.fusion = false;
    let p_fuse = Policy::optimized();
    for (i, op) in convs.iter().enumerate() {
        let base = op_traffic(&cfg, p_nofuse, &op.kind, FusionTag { weight_refetch: 1.0, ..Default::default() });
        let fused = op_traffic(&cfg, p_fuse, &op.kind, plan.tags[i]);
        let save = 1.0 - fused.total() / base.total().max(1.0);
        t.row(vec![
            i.to_string(),
            op.name.clone(),
            format!("{:?}", plan.kinds[i]),
            f(base.total() / 1e6, 2),
            f(fused.total() / 1e6, 2),
            format!("{:.0}%", save * 100.0),
        ]);
    }
    t.print();

    let cross: Vec<usize> = plan
        .kinds
        .iter()
        .enumerate()
        .filter(|(_, &k)| k == FusionKind::CrossLayer)
        .map(|(i, _)| i)
        .collect();
    println!("\ncross-layer fused layers: {cross:?} (paper: 0~5 and 44~51)");

    println!("\n== Fig. 16 (right): global-buffer size sweep ==");
    let mut t = Table::new(&["GB size", "off-chip traffic (GB)", "normalised (256KB=1)"]);
    let mut norm = None;
    for kb in [256usize, 512, 1024, 2048, 4096, 8192] {
        let mut c = cfg.clone();
        c.gb_bytes = kb << 10;
        let traffic = simulate(&c, Policy::optimized(), &ops).traffic_bytes;
        let n = *norm.get_or_insert(traffic);
        t.row(vec![
            format!("{} KB", kb),
            f(traffic / 1e9, 3),
            f(traffic / n, 3),
        ]);
    }
    t.print();
    println!("\npaper: 2 MB is the sweet spot (diminishing returns beyond)");
}
