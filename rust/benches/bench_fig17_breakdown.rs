//! Fig. 17 — technique breakdown on SD v1.4:
//! (a) roofline position, (b-left) hardware ablation AC -> AD -> SC,
//! (b-right) phase-aware-sampling speedup on the optimised hardware,
//! (c) energy breakdown. Also prints the Table I configuration.

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::{simulate, simulate_unet_step};
use sd_acc::models::inventory::{partial_unet_ops, sd_v14, unet_ops};
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::{PasConfig, StepAction};
use sd_acc::util::table::{f, ratio, Table};

fn main() {
    let cfg = AccelConfig::default();
    let arch = sd_v14();
    let ops = unet_ops(&arch);

    println!("== Table I configuration ==");
    println!(
        "SA {}x{} @ {:.0} MHz, VPU {}-parallel, GB {} KB, DDR {:.1} GB/s, {:.2} W on-chip, peak {:.1} GMAC/s",
        cfg.sa_rows,
        cfg.sa_cols,
        cfg.freq_hz / 1e6,
        cfg.vpu_lanes,
        cfg.gb_bytes >> 10,
        cfg.dram_bw / 1e9,
        cfg.onchip_power_w(),
        cfg.peak_macs() / 1e9,
    );

    // ---------------------------------------------------------- (a) roofline
    let opt = simulate(&cfg, Policy::optimized(), &ops);
    let knee = cfg.peak_flops() / cfg.dram_bw;
    println!("\n== Fig. 17a: roofline ==");
    println!(
        "operational intensity {:.0} FLOP/B vs knee {:.1} FLOP/B -> {}",
        opt.operational_intensity(),
        knee,
        if opt.operational_intensity() > knee { "COMPUTE-BOUND (as in the paper)" } else { "memory-bound" }
    );
    println!(
        "achieved {:.1} GMAC/s of {:.1} peak ({:.1}% of theoretical; paper ~95%)",
        opt.macs / opt.seconds(&cfg) / 1e9,
        cfg.peak_macs() / 1e9,
        100.0 * opt.utilization(&cfg)
    );

    // --------------------------------------------- (b-left) hardware ablation
    println!("\n== Fig. 17b (left): hardware ablation (one U-Net pass) ==");
    let mut t = Table::new(&["config", "SA", "im2col", "nonlinear", "mem stall", "total (Mcyc)", "speedup", "paper"]);
    let base_total = simulate(&cfg, Policy::baseline(), &ops).total_cycles();
    for (name, p, paper) in [
        ("baseline (im2col)", Policy::baseline(), "1.00x"),
        ("+AC", Policy::with_ac(), "1.24x"),
        ("+AC+AD", Policy::with_ac_ad(), "1.37x"),
        ("+AC+AD+SC", Policy::optimized(), "1.65x"),
    ] {
        let r = simulate(&cfg, p, &ops);
        t.row(vec![
            name.into(),
            f(r.sa_cycles / 1e6, 1),
            f(r.conversion_cycles / 1e6, 1),
            f(r.nonlinear_cycles / 1e6, 1),
            f(r.mem_stall_cycles / 1e6, 1),
            f(r.total_cycles() / 1e6, 1),
            ratio(base_total / r.total_cycles()),
            paper.into(),
        ]);
    }
    t.print();

    // ------------------------------------- (b-right) PAS speedup on the HW
    println!("\n== Fig. 17b (right): PAS speedup on the optimised hardware ==");
    let cm = CostModel::new(&arch);
    let full_step = simulate_unet_step(&cfg, Policy::optimized(), &ops);
    let partial_secs: Vec<f64> = (1..=3)
        .map(|l| {
            simulate_unet_step(&cfg, Policy::optimized(), &partial_unet_ops(&arch, l))
                .seconds(&cfg)
        })
        .collect();
    let mut t = Table::new(&["config", "theoretical (Eq.3)", "HW speedup", "HW/theory", "paper"]);
    let paper_speedups = ["2.31x", "2.58x", "2.69x", "3.10x"];
    for (i, sparse) in [2usize, 3, 4, 5].iter().enumerate() {
        let pas = PasConfig::pas25(*sparse);
        let plan = pas.plan(50);
        let theory = cm.mac_reduction(&plan);
        let t_full = full_step.seconds(&cfg) * 50.0;
        let t_pas: f64 = plan
            .iter()
            .map(|a| match a {
                StepAction::Full => full_step.seconds(&cfg),
                StepAction::Partial(l) => partial_secs[*l - 1],
            })
            .sum();
        let hw = t_full / t_pas;
        t.row(vec![
            pas.label(),
            ratio(theory),
            ratio(hw),
            format!("{:.0}%", 100.0 * hw / theory),
            paper_speedups[i].into(),
        ]);
        assert!(hw / theory > 0.80, "HW must realise most of the theoretical gain");
    }
    t.print();

    // --------------------------------------------------- (c) energy breakdown
    println!("\n== Fig. 17c: energy (one image, 50 steps) ==");
    let mut t = Table::new(&["config", "time (s)", "on-chip (J)", "DRAM (J)", "total (J)", "saving"]);
    let base_e = {
        let r = simulate_unet_step(&cfg, Policy::baseline(), &ops);
        r.energy_j(&cfg) * 50.0
    };
    for (name, p, plan) in [
        ("baseline", Policy::baseline(), None),
        ("hw-optimized", Policy::optimized(), None),
        ("hw-opt + PAS-25/4", Policy::optimized(), Some(PasConfig::pas25(4))),
    ] {
        let (secs, energy) = match plan {
            None => {
                let r = simulate_unet_step(&cfg, p, &ops);
                (r.seconds(&cfg) * 50.0, r.energy_j(&cfg) * 50.0)
            }
            Some(pas) => {
                let full = simulate_unet_step(&cfg, p, &ops);
                let mut secs = 0.0;
                let mut e = 0.0;
                for a in pas.plan(50) {
                    let r = match a {
                        StepAction::Full => full.clone(),
                        StepAction::Partial(l) => {
                            simulate_unet_step(&cfg, p, &partial_unet_ops(&arch, l))
                        }
                    };
                    secs += r.seconds(&cfg);
                    e += r.energy_j(&cfg);
                }
                (secs, e)
            }
        };
        let onchip = cfg.onchip_power_w() * secs;
        t.row(vec![
            name.into(),
            f(secs, 1),
            f(onchip, 0),
            f(energy - onchip, 1),
            f(energy, 0),
            ratio(base_e / energy),
        ]);
    }
    t.print();
    println!("\npaper: hardware opts ~1.73x energy, +PAS ~2.63x more; on-chip dominates");
}
