//! Fig. 18 — speedup of SD-Acc (PAS-25/4) over the SOTA StableDiff
//! accelerators Cambricon-D [25] and SDP [5], iso-peak-throughput, across
//! the three models. Paper: 1.8~3.2x over Cambricon-D, 1.6~2.3x over SDP;
//! the C-D gap widens with XL's transformer share, the SDP gap narrows.

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::baselines::{transformer_share, CambriconD, Sdp};
use sd_acc::hwsim::engine::simulate;
use sd_acc::models::inventory::*;
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::PasConfig;
use sd_acc::util::table::{f, ratio, Table};

fn main() {
    // All scaled to Cambricon-D's peak (it has the highest).
    let peak_flops = 16.0e12;
    let cfg = AccelConfig::default().scaled_to_peak(peak_flops);

    let mut t = Table::new(&[
        "model", "tf share", "C-D step (ms)", "SDP step (ms)", "SD-Acc step (ms)",
        "vs C-D", "vs SDP",
    ]);
    let mut vs_cd = Vec::new();
    let mut vs_sdp = Vec::new();
    for arch in [sd_v14(), sd_v21_base(), sd_xl()] {
        let ops = unet_ops(&arch);
        let cm = CostModel::new(&arch);
        let red = cm.mac_reduction(&PasConfig::pas25(4).plan(50));
        let util = simulate(&cfg, Policy::optimized(), &ops).utilization(&cfg);

        let cd = CambriconD::new(peak_flops).step_latency_s(&ops);
        let depth = *arch.tf_depth.iter().max().unwrap();
        let sdp = Sdp::for_arch(peak_flops, depth).step_latency_s(&ops);
        let ours = sd_acc::hwsim::baselines::sd_acc_step_latency_s(&cfg, &ops, red, util.max(0.8));

        vs_cd.push(cd / ours);
        vs_sdp.push(sdp / ours);
        t.row(vec![
            arch.name.into(),
            f(transformer_share(&ops), 2),
            f(cd * 1e3, 2),
            f(sdp * 1e3, 2),
            f(ours * 1e3, 2),
            ratio(cd / ours),
            ratio(sdp / ours),
        ]);
    }
    t.print();

    println!("\npaper bands: 1.8~3.2x over Cambricon-D, 1.6~2.3x over SDP");
    // Trend checks (the paper's Sec. VI-E observations).
    assert!(vs_cd[2] > vs_cd[0], "C-D gap must widen on XL");
    assert!(vs_sdp[2] < vs_sdp[0], "SDP gap must narrow on XL");
    for s in &vs_cd {
        assert!((1.6..4.0).contains(s), "vs C-D {s}");
    }
    for s in &vs_sdp {
        assert!((1.4..2.6).contains(s), "vs SDP {s}");
    }
    println!("trends and bands OK");
}
