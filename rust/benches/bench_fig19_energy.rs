//! Fig. 19 — energy saving of the (unscaled, VCU118-config) accelerator
//! with PAS over the original model on AMD 6800H / Intel 5220R / V100.
//! Paper: 14.7~37.3x, 18.3~44.9x, 2.7~6.0x across the three models.

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::baselines::{amd_6800h, intel_5220r, v100};
use sd_acc::hwsim::engine::simulate_unet_step;
use sd_acc::models::inventory::*;
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::{PasConfig, StepAction};
use sd_acc::util::table::{ratio, Table};

fn accel_image_energy(cfg: &AccelConfig, arch: &UNetArch, pas: PasConfig) -> f64 {
    let full = simulate_unet_step(cfg, Policy::optimized(), &unet_ops(arch));
    let mut e = 0.0;
    for a in pas.plan(50) {
        e += match a {
            StepAction::Full => full.energy_j(cfg),
            StepAction::Partial(l) => {
                simulate_unet_step(cfg, Policy::optimized(), &partial_unet_ops(arch, l))
                    .energy_j(cfg)
            }
        };
    }
    e
}

fn main() {
    let cfg = AccelConfig::default();
    let plats = [amd_6800h(), intel_5220r(), v100()];

    let mut t = Table::new(&["model", "PAS", "ours (kJ)", "vs AMD", "vs Intel", "vs V100"]);
    let mut v100_savings = Vec::new();
    for arch in [sd_v14(), sd_v21_base(), sd_xl()] {
        let ops = unet_ops(&arch);
        let cm = CostModel::new(&arch);
        for sparse in [2usize, 5] {
            let pas = PasConfig::pas25(sparse);
            let _red = cm.mac_reduction(&pas.plan(50));
            let ours = accel_image_energy(&cfg, &arch, pas);
            let mut row = vec![arch.name.to_string(), pas.label(), format!("{:.2}", ours / 1e3)];
            for p in &plats {
                // Original model on the platform: 50 CFG-doubled steps.
                let base = p.energy_j(&ops) * 100.0;
                let save = base / ours;
                row.push(ratio(save));
                if p.name == "V100" {
                    v100_savings.push(save);
                }
            }
            t.row(row);
        }
    }
    t.print();

    println!("\npaper bands: 14.7~37.3x (AMD), 18.3~44.9x (Intel), 2.7~6.0x (V100)");
    // v1.4 / v2.1 must land inside the paper's 2.7~6.0x; XL may exceed it
    // because our Table-II MAC reduction on XL (up to 5.7x) is larger.
    for s in &v100_savings[..4] {
        assert!((2.4..6.5).contains(s), "V100 energy saving {s}");
    }
    assert!(v100_savings[4..].iter().all(|s| *s > 3.0));
    println!("V100 savings in band: {v100_savings:?}");
}
