//! Fig. 20 — scaled speedup (accelerator at 1 GHz / 4096 MACs, consistent
//! with prior work) of SD-Acc + PAS over the original model on CPU/GPU.
//! Paper: 102.5~258.9x (AMD 6800H), 38.4~93.3x (Intel 5220R),
//! 2.2~4.7x (V100).

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::baselines::{amd_6800h, intel_5220r, v100};
use sd_acc::hwsim::engine::simulate_unet_step;
use sd_acc::models::inventory::*;
use sd_acc::pas::plan::{PasConfig, StepAction};
use sd_acc::util::table::{f, ratio, Table};

fn accel_image_seconds(cfg: &AccelConfig, arch: &UNetArch, pas: PasConfig) -> f64 {
    let full = simulate_unet_step(cfg, Policy::optimized(), &unet_ops(arch));
    pas.plan(50)
        .iter()
        .map(|a| match a {
            StepAction::Full => full.seconds(cfg),
            StepAction::Partial(l) => {
                simulate_unet_step(cfg, Policy::optimized(), &partial_unet_ops(arch, *l))
                    .seconds(cfg)
            }
        })
        .sum()
}

fn main() {
    let cfg = AccelConfig::default().scaled_1ghz_4096();
    println!(
        "scaled accelerator: {}x{} @ {:.1} GHz = {:.2} TMAC/s peak",
        cfg.sa_rows,
        cfg.sa_cols,
        cfg.freq_hz / 1e9,
        cfg.peak_macs() / 1e12
    );
    let plats = [amd_6800h(), intel_5220r(), v100()];

    let mut t = Table::new(&["model", "PAS", "ours (s/img)", "vs AMD", "vs Intel", "vs V100"]);
    let mut v100_speedups = Vec::new();
    for arch in [sd_v14(), sd_v21_base(), sd_xl()] {
        let ops = unet_ops(&arch);
        for sparse in [2usize, 5] {
            let pas = PasConfig::pas25(sparse);
            let ours = accel_image_seconds(&cfg, &arch, pas);
            let mut row = vec![arch.name.to_string(), pas.label(), f(ours, 2)];
            for p in &plats {
                let base = p.latency_s(&ops) * 100.0; // 50 steps x CFG
                let s = base / ours;
                row.push(ratio(s));
                if p.name == "V100" {
                    v100_speedups.push(s);
                }
            }
            t.row(row);
        }
    }
    t.print();

    println!("\npaper bands: 102.5~258.9x (AMD), 38.4~93.3x (Intel), 2.2~4.7x (V100)");
    // v1.4 / v2.1 within the paper's 2.2~4.7x; XL exceeds it in step with
    // its larger Table-II MAC reduction (see EXPERIMENTS.md).
    for s in &v100_speedups[..4] {
        assert!((2.0..5.2).contains(s), "V100 speedup {s}");
    }
    println!("V100 speedups in band: {v100_speedups:?}");
}
