//! Fig. 2 — component profiling of StableDiff v1.4: parameters, MACs and
//! CPU/GPU latency estimates for text encoder / U-Net / VAE (50 steps,
//! classifier-free guidance).

use sd_acc::hwsim::baselines::{amd_6800h, intel_5220r, v100};
use sd_acc::models::inventory::*;
use sd_acc::util::table::{f, Table};

fn main() {
    let arch = sd_v14();
    let unet = unet_ops(&arch);
    let text = text_encoder_ops(&arch);
    let vae = vae_decoder_ops(&arch);
    let steps = 50u64;

    println!("== Fig. 2 (left): parameters and MACs of SD v1.4 ==");
    let mut t = Table::new(&["component", "params (M)", "MACs/exec (G)", "execs", "total MACs (T)"]);
    for (name, ops, execs) in [
        ("text-encoder", &text, 1u64),
        ("u-net", &unet, 2 * steps), // CFG doubles each of the 50 steps
        ("vae-decoder", &vae, 1),
    ] {
        let p = total_params(ops) as f64 / 1e6;
        let m = total_macs(ops) as f64 / 1e9;
        t.row(vec![
            name.into(),
            f(p, 1),
            f(m, 1),
            execs.to_string(),
            f(m * execs as f64 / 1e3, 2),
        ]);
    }
    t.print();

    println!("\n== Fig. 2 (right): single-precision latency estimates ==");
    let mut t = Table::new(&["platform", "text (s)", "u-net x100 (s)", "vae (s)", "total (s)"]);
    for plat in [amd_6800h(), intel_5220r(), v100()] {
        let lt = plat.latency_s(&text);
        let lu = plat.latency_s(&unet) * (2 * steps) as f64;
        let lv = plat.latency_s(&vae);
        t.row(vec![
            plat.name.into(),
            f(lt, 3),
            f(lu, 1),
            f(lv, 2),
            f(lt + lu + lv, 1),
        ]);
    }
    t.print();

    println!("\nshape checks: u-net dominates (~100x VAE latency), text encoder negligible");
    let v = v100();
    let ratio = v.latency_s(&unet) * (2 * steps) as f64 / v.latency_s(&vae);
    println!("  u-net/vae latency ratio on V100: {ratio:.0}x");
    assert!(ratio > 20.0, "U-Net must dominate");
}
