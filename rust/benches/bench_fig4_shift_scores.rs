//! Fig. 4 — normalized per-up-block shift scores across the denoising
//! process, the predicted-noise curve, outlier blocks and D*.
//!
//! Uses artifacts/calibration.json if present (written by
//! examples/calibrate_and_search.rs); otherwise runs a small calibration
//! through the unet_calib artifact directly (requires `make artifacts`).

use sd_acc::coordinator::Coordinator;
use sd_acc::pas::calibrate::{CalibrationReport, Calibrator};
use sd_acc::runtime::{default_artifacts_dir, RuntimeService};
use sd_acc::util::json::Json;

fn spark(xs: &[f64]) -> String {
    const RAMP: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    xs.iter()
        .map(|&v| RAMP[((v.clamp(0.0, 1.0) * 7.0).round()) as usize])
        .collect()
}

fn main() {
    let dir = default_artifacts_dir();
    let cached = dir.join("calibration.json");
    let report: CalibrationReport = if cached.exists() {
        let text = std::fs::read_to_string(&cached).expect("read calibration.json");
        CalibrationReport::from_json(&Json::parse(&text).expect("parse")).expect("decode")
    } else {
        let steps: usize = std::env::var("SD_ACC_BENCH_STEPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(25);
        // Auto backend: xla over artifacts, deterministic sim otherwise
        // — the measurement runs either way.
        let svc = RuntimeService::start(&dir).expect("runtime");
        println!(
            "(no calibration.json cache — measuring {steps}-step trajectories on the {} backend)",
            svc.backend()
        );
        let coord = Coordinator::new(svc.handle());
        let prompts = vec![
            "red circle x4 y4 blue square x11 y11".to_string(),
            "green stripe x8 y8".to_string(),
        ];
        let rep = Calibrator::new(&coord).run(&prompts, steps, 7.5).expect("calibration");
        // Cache the file for repeat runs only on the xla path: the
        // artifacts-dir calibration.json carries no backend tag, so sim
        // measurements must not be mistaken for the real model's.
        if svc.backend() == sd_acc::runtime::BackendKind::Xla {
            std::fs::write(&cached, rep.to_json().to_string()).ok();
        }
        rep
    };

    println!(
        "== Fig. 4: normalized shift scores ({} steps, {} prompts) ==",
        report.steps, report.prompts
    );
    for (i, s) in report.scores.iter().enumerate() {
        let marker = if report.outliers.contains(&(i + 1)) { " <- outlier" } else { "" };
        println!("block {:2} |{}|{}", i + 1, spark(s), marker);
    }
    println!("noise    |{}|", spark(&report.noise));
    println!("\nD* (Eq. 2 phase transition) = step {} of {}", report.d_star, report.steps);
    println!("outlier blocks (stay active in refinement): {:?}", report.outliers);
    println!("\nshape: early phase varies everywhere; deep blocks stabilise after D*; top blocks stay active");
}
