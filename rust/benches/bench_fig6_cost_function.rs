//! Fig. 6 — per-block MAC breakdown of the U-Net and the cost function
//! f(l) (cumulative MAC ratio of the first l down+up blocks).

use sd_acc::models::inventory::*;
use sd_acc::pas::cost::CostModel;
use sd_acc::util::table::{f, Table};

fn main() {
    for arch in [sd_v14(), sd_v21_base(), sd_xl()] {
        let cm = CostModel::new(&arch);
        println!("== Fig. 6 — {} (total {:.1} GMAC/step) ==", arch.name, cm.total as f64 / 1e9);
        let mut t = Table::new(&["block l", "down MACs (G)", "up MACs (G)", "f(l)"]);
        for l in 1..=cm.n_blocks {
            t.row(vec![
                l.to_string(),
                f(cm.down[l] as f64 / 1e9, 2),
                f(cm.up[l] as f64 / 1e9, 2),
                f(cm.f(l), 4),
            ]);
        }
        t.row(vec![
            format!("{} (full+mid)", cm.n_blocks + 1),
            "-".into(),
            f(cm.mid as f64 / 1e9, 2),
            f(cm.f(cm.n_blocks + 1), 4),
        ]);
        t.print();
        println!();
        // Shape check: f is increasing and top blocks are cheap.
        assert!(cm.f(2) < 0.4, "retaining 2 blocks must be cheap");
    }
}
