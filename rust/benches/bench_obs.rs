//! Observability bench: the deterministic sim-backed workload behind
//! the committed `BENCH_obs.json` trajectory (repo root).
//!
//! Drives a real server (sim backend, request cache, trace sink) with a
//! mixed cold/warm request stream and reports, from the obs layer
//! itself rather than ad-hoc timers:
//!
//! - **steps/s** — per-PAS-action step counters over the measured wall;
//! - **allocs/step** — steady-state global-allocator delta per denoising
//!   step (counting allocator, `count-alloc` feature; reported as 0 and
//!   not gated when counting is unavailable);
//! - **bytes moved** — per-backend execute operand+result bytes;
//! - **cache hit ratio** — request-namespace hit/miss counters;
//! - **p50/p95 job latency** — per-job `queued -> terminal` deltas from
//!   the trace ring.
//!
//! Modes (ci.sh):
//!   `--smoke`  validate only: schema keys present, counters non-zero,
//!              one terminal span per job. No file writes.
//!   `--commit` the `ci.sh --bench-commit` lane: everything `--smoke`
//!              checks, plus the allocs/step regression gate against the
//!              committed `allocs_per_step_limit`, then rewrite
//!              `BENCH_obs.json` (the limit itself is carried over, not
//!              re-derived — ratcheting it is a reviewed edit).
//!   default    measure and print, write nothing.
//!
//! Run: `cargo bench --bench bench_obs [-- --smoke | -- --commit]`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sd_acc::cache::StoreConfig;
use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::obs::{self, alloc, TraceSink};
use sd_acc::runtime::{BackendKind, RuntimeService};
use sd_acc::server::{Server, ServerConfig};
use sd_acc::util::json::Json;
use sd_acc::util::stats;

/// Keys every BENCH_obs.json point must carry (schema validation).
const REQUIRED_KEYS: [&str; 16] = [
    "bench",
    "trace_schema_version",
    "steps_per_sec",
    "allocs_per_step",
    "allocs_per_step_limit",
    "bytes_moved",
    "cache_hit_ratio",
    "p50_ms",
    "p95_ms",
    "counting_alloc_active",
    "windowed_p50_ms",
    "windowed_p95_ms",
    "phase_queue_ms",
    "phase_step_full_ms",
    "phase_step_partial_ms",
    "phase_decode_ms",
];

struct Measured {
    steps_per_sec: f64,
    allocs_per_step: f64,
    bytes_moved: u64,
    executes: u64,
    cache_hit_ratio: f64,
    request_hits: u64,
    request_misses: u64,
    steps: u64,
    p50_ms: f64,
    p95_ms: f64,
    jobs: usize,
    /// Sliding-window percentiles from the server's SLO tracker,
    /// captured while the window still covers the whole run.
    windowed_p50_ms: f64,
    windowed_p95_ms: f64,
    /// Per-phase totals from the trace analyzer ("where does a
    /// millisecond go"), summed over complete jobs.
    phase_queue_ms: f64,
    phase_step_full_ms: f64,
    phase_step_partial_ms: f64,
    phase_decode_ms: f64,
}

fn run_workload(smoke: bool) -> anyhow::Result<Measured> {
    let art_dir =
        std::env::temp_dir().join(format!("sdacc_bench_obs_art_{}", std::process::id()));
    let cache_dir =
        std::env::temp_dir().join(format!("sdacc_bench_obs_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&art_dir);
    let _ = std::fs::remove_dir_all(&cache_dir);

    // Sim backend always: the trajectory point must be deterministic and
    // runnable in artifact-less containers.
    let svc = RuntimeService::start_with(BackendKind::Sim, &art_dir)?;
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let cache = Arc::new(coord.open_cache(StoreConfig::new(&cache_dir))?);
    let trace = TraceSink::in_memory(obs::trace::DEFAULT_RING_CAP);
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(10),
            cache: Some(Arc::clone(&cache)),
            trace: Some(Arc::clone(&trace)),
            ..Default::default()
        },
    );
    let client = server.client();
    let n = if smoke { 6 } else { 16 };
    let steps = if smoke { 4 } else { 10 };

    let before = obs::counters().snapshot();
    let t0 = Instant::now();
    let drive = || -> anyhow::Result<()> {
        // Cold pass (misses + generation), then a warm pass over the
        // same requests (request-cache hits) for a non-trivial ratio.
        for pass in 0..2 {
            for i in 0..n {
                let mut r = GenRequest::new(
                    &format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9),
                    i as u64,
                );
                r.steps = steps;
                r.sampler = "ddim".into();
                client
                    .generate(r)
                    .map_err(|e| anyhow::anyhow!("pass {pass} req {i}: {e}"))?;
            }
        }
        Ok(())
    };
    let driven = drive();
    let wall_s = t0.elapsed().as_secs_f64();
    let served = obs::counters().snapshot().delta_since(&before);
    // Windowed SLO view must be read before shutdown, while the
    // sliding window still covers the run.
    let summary = server.metrics.summary();
    server.shutdown();
    driven?;

    // Steady-state allocation cost per denoising step: warm everything
    // first (plan resolution, runtime buffers), then measure direct
    // coordinator generates so server-thread churn stays out of the
    // numerator. Counting is armed only around the measured region.
    let alloc_iters = if smoke { 2 } else { 4 };
    let mut warm = GenRequest::new("alloc probe prompt", 77_001);
    warm.steps = steps;
    warm.sampler = "ddim".into();
    coord.generate_one(&warm)?;
    let was_enabled = alloc::enabled();
    alloc::enable();
    let alloc_before = alloc::snapshot();
    for k in 0..alloc_iters {
        let mut r = GenRequest::new("alloc probe prompt", 78_000 + k as u64);
        r.steps = steps;
        r.sampler = "ddim".into();
        coord.generate_one(&r)?;
    }
    let alloc_delta = alloc::snapshot().delta_since(&alloc_before);
    if !was_enabled {
        alloc::disable();
    }
    let allocs_per_step = if alloc::counting_active() {
        alloc_delta.allocs as f64 / (alloc_iters * steps) as f64
    } else {
        0.0
    };

    // Job latency from the trace ring: queued -> terminal, per job.
    let spans = trace.snapshot();
    let mut lat_ms: Vec<f64> = Vec::new();
    let mut jobs_seen: Vec<u64> = Vec::new();
    for ev in &spans {
        if !ev.phase.is_entry() || jobs_seen.contains(&ev.job) {
            continue;
        }
        jobs_seen.push(ev.job);
        let terminal = spans
            .iter()
            .find(|t| t.job == ev.job && t.phase.is_terminal())
            .ok_or_else(|| anyhow::anyhow!("job {} has no terminal span", ev.job))?;
        let extra = spans
            .iter()
            .filter(|t| t.job == ev.job && t.phase.is_terminal())
            .count();
        anyhow::ensure!(extra == 1, "job {} has {extra} terminal spans, want exactly 1", ev.job);
        lat_ms.push((terminal.ts_us.saturating_sub(ev.ts_us)) as f64 / 1e3);
    }
    anyhow::ensure!(!lat_ms.is_empty(), "trace ring recorded no complete jobs");
    let counts = trace.lifecycle_counts();
    anyhow::ensure!(
        counts.terminals() == counts.enqueued,
        "drained server must have terminals == enqueued (got {} vs {})",
        counts.terminals(),
        counts.enqueued
    );

    // Phase decomposition over the same span stream the latency numbers
    // came from.
    let analysis = sd_acc::obs::analyze::analyze(&spans);

    let req = served.ns("request").expect("request namespace counters");
    let sim = served.backend("sim").expect("sim backend counters");
    let total_steps = served.steps_full + served.steps_partial;
    let _ = std::fs::remove_dir_all(&art_dir);
    let _ = std::fs::remove_dir_all(&cache_dir);
    Ok(Measured {
        steps_per_sec: total_steps as f64 / wall_s.max(1e-9),
        allocs_per_step,
        bytes_moved: sim.bytes_moved(),
        executes: sim.executes,
        cache_hit_ratio: req.hit_ratio(),
        request_hits: req.hits,
        request_misses: req.misses,
        steps: total_steps,
        p50_ms: stats::percentile(&lat_ms, 50.0),
        p95_ms: stats::percentile(&lat_ms, 95.0),
        jobs: lat_ms.len(),
        windowed_p50_ms: summary.windowed_p50_ms,
        windowed_p95_ms: summary.windowed_p95_ms,
        phase_queue_ms: analysis.phase_total_ms("queue"),
        phase_step_full_ms: analysis.phase_total_ms("step-full"),
        phase_step_partial_ms: analysis.phase_total_ms("step-partial"),
        phase_decode_ms: analysis.phase_total_ms("decode"),
    })
}

/// Schema-validate a BENCH_obs.json document: required keys present,
/// load-bearing counters non-zero.
fn validate(doc: &Json) -> Result<(), String> {
    for k in REQUIRED_KEYS {
        if doc.get(k).is_none() {
            return Err(format!("BENCH_obs.json missing required key '{k}'"));
        }
    }
    // phase_step_full_ms is the only phase gated non-zero: the ddim
    // workload has no partial steps, and queue/decode can round small.
    let nonzero = ["steps_per_sec", "bytes_moved", "p95_ms", "windowed_p95_ms", "phase_step_full_ms"];
    for k in nonzero {
        let v = doc.get_f64(k).ok_or_else(|| format!("key '{k}' is not a number"))?;
        if v <= 0.0 {
            return Err(format!("key '{k}' must be > 0 (got {v})"));
        }
    }
    let ratio = doc.get_f64("cache_hit_ratio").unwrap_or(-1.0);
    if !(0.0..=1.0).contains(&ratio) {
        return Err(format!("cache_hit_ratio {ratio} outside [0, 1]"));
    }
    if doc.get_f64("trace_schema_version") != Some(obs::TRACE_SCHEMA_VERSION as f64) {
        return Err(format!(
            "trace_schema_version mismatch (want {})",
            obs::TRACE_SCHEMA_VERSION
        ));
    }
    Ok(())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let commit = std::env::args().any(|a| a == "--commit");

    let m = run_workload(smoke).expect("obs workload");
    println!(
        "obs bench: {} jobs | {:.0} steps/s ({} steps) | {} executes, {} bytes moved",
        m.jobs, m.steps_per_sec, m.steps, m.executes, m.bytes_moved
    );
    println!(
        "  request cache: {} hits / {} misses (ratio {:.2}) | job latency p50 {:.1} ms p95 {:.1} ms",
        m.request_hits, m.request_misses, m.cache_hit_ratio, m.p50_ms, m.p95_ms
    );
    println!(
        "  allocs/step: {:.0} (counting {})",
        m.allocs_per_step,
        if alloc::counting_active() { "active" } else { "unavailable" }
    );
    println!(
        "  windowed p50 {:.1} ms p95 {:.1} ms | phase ms: queue {:.1}, step-full {:.1}, step-partial {:.1}, decode {:.1}",
        m.windowed_p50_ms,
        m.windowed_p95_ms,
        m.phase_queue_ms,
        m.phase_step_full_ms,
        m.phase_step_partial_ms,
        m.phase_decode_ms
    );

    // Warm pass over identical requests: every one must hit.
    assert!(m.cache_hit_ratio > 0.0, "warm pass produced no request-cache hits");
    assert!(m.bytes_moved > 0, "backend byte counters never moved");
    assert!(m.steps > 0, "step counters never moved");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_obs.json");
    let committed = std::fs::read_to_string(&out)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    // The regression gate rides the *committed* limit so a bad change
    // fails `--bench-commit` instead of silently ratcheting the budget.
    let limit = committed
        .as_ref()
        .and_then(|d| d.get_f64("allocs_per_step_limit"))
        .unwrap_or(8192.0);
    if alloc::counting_active() && m.allocs_per_step > 0.0 {
        assert!(
            m.allocs_per_step <= limit,
            "allocs/step regression: measured {:.0} > committed limit {:.0}",
            m.allocs_per_step,
            limit
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("obs_trajectory")),
        ("trace_schema_version", Json::num(obs::TRACE_SCHEMA_VERSION as f64)),
        ("steps_per_sec", Json::num(m.steps_per_sec)),
        ("allocs_per_step", Json::num(m.allocs_per_step)),
        ("allocs_per_step_limit", Json::num(limit)),
        ("bytes_moved", Json::num(m.bytes_moved as f64)),
        ("executes", Json::num(m.executes as f64)),
        ("steps", Json::num(m.steps as f64)),
        ("cache_hit_ratio", Json::num(m.cache_hit_ratio)),
        ("request_hits", Json::num(m.request_hits as f64)),
        ("request_misses", Json::num(m.request_misses as f64)),
        ("p50_ms", Json::num(m.p50_ms)),
        ("p95_ms", Json::num(m.p95_ms)),
        ("jobs", Json::num(m.jobs as f64)),
        ("counting_alloc_active", Json::Bool(alloc::counting_active())),
        ("windowed_p50_ms", Json::num(m.windowed_p50_ms)),
        ("windowed_p95_ms", Json::num(m.windowed_p95_ms)),
        ("phase_queue_ms", Json::num(m.phase_queue_ms)),
        ("phase_step_full_ms", Json::num(m.phase_step_full_ms)),
        ("phase_step_partial_ms", Json::num(m.phase_step_partial_ms)),
        ("phase_decode_ms", Json::num(m.phase_decode_ms)),
    ]);
    validate(&doc).expect("fresh measurement must satisfy the BENCH_obs schema");
    if let Some(prev) = &committed {
        validate(prev).expect("committed BENCH_obs.json must satisfy the schema");
    }

    if commit {
        std::fs::write(&out, doc.to_string()).expect("write BENCH_obs.json");
        println!("wrote {}", out.display());
    } else if smoke {
        println!("bench_obs --smoke: schema + counter + trace invariants hold");
    }
}
