//! §Perf — hot-path micro-benchmarks for the whole stack (used by the
//! EXPERIMENTS.md §Perf before/after log).
//!
//! Always runs the L3 simulator/substrate benches; runtime benches
//! (PJRT execute, coordinator step) run when artifacts are present.

use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::simulate;
use sd_acc::models::inventory::{sd_v14, unet_ops};
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::PasConfig;
use sd_acc::runtime::{default_artifacts_dir, Input, Runtime, RuntimeService, Tensor};
use sd_acc::scheduler::{NoiseSchedule, Pndm, Sampler};
use sd_acc::util::bench::Bench;
use sd_acc::util::json::Json;
use sd_acc::util::rng::Pcg32;

fn main() {
    let mut b = Bench::default();

    // --- L3 simulator throughput -----------------------------------------
    let cfg = AccelConfig::default();
    let ops = unet_ops(&sd_v14());
    println!("hwsim inventory: {} ops", ops.len());
    b.run("hwsim/simulate_sd14_optimized", || {
        std::hint::black_box(simulate(&cfg, Policy::optimized(), &ops));
    });
    b.run("hwsim/simulate_sd14_baseline", || {
        std::hint::black_box(simulate(&cfg, Policy::baseline(), &ops));
    });

    // --- PAS search space --------------------------------------------------
    let cm = CostModel::new(&sd_v14());
    b.run("pas/cost_model_build", || {
        std::hint::black_box(CostModel::new(&sd_v14()));
    });
    b.run("pas/plan_eval_50steps", || {
        let plan = PasConfig::pas25(4).plan(50);
        std::hint::black_box(cm.mac_reduction(&plan));
    });

    // --- scheduler ----------------------------------------------------------
    let sched = NoiseSchedule::scaled_linear(1000, 0.00085, 0.012);
    let mut rng = Pcg32::seeded(3);
    let latent: Vec<f32> = rng.gaussian_vec(256 * 4);
    let eps: Vec<f32> = rng.gaussian_vec(256 * 4);
    b.run("scheduler/pndm_step_1k_elems", || {
        let mut p = Pndm::new(sched.clone(), 50);
        for i in 0..4 {
            std::hint::black_box(p.step(i, &latent, &eps));
        }
    });
    b.run("scheduler/pndm_step_mut_1k_elems", || {
        // In-place hot-path form: one buffer for the whole trajectory.
        let mut p = Pndm::new(sched.clone(), 50);
        let mut buf = latent.clone();
        for i in 0..4 {
            p.step_mut(i, &mut buf, &eps);
        }
        std::hint::black_box(&buf);
    });

    // --- json codec ----------------------------------------------------------
    let blob = Json::Arr((0..2000).map(|i| Json::Num(i as f64 * 0.5)).collect()).to_string();
    b.run("util/json_parse_2k_floats", || {
        std::hint::black_box(Json::parse(&blob).unwrap());
    });

    // --- runtime hot path (xla over artifacts, sim backend otherwise) ---------
    let dir = default_artifacts_dir();
    {
        let svc = RuntimeService::start(&dir).expect("runtime");
        println!("runtime hot path backend: {}", svc.backend());
        let h = svc.handle();
        let m = h.manifest().model.clone();
        // warm compile outside timing
        h.preload(&[Runtime::unet_full(1), Runtime::unet_partial(2, 1)]).expect("preload");

        let mut rng = Pcg32::seeded(5);
        let lat = Tensor::new(vec![1, m.latent_l(), m.latent_c], rng.gaussian_vec(m.latent_elems())).unwrap();
        let t = Tensor::new(vec![1], vec![400.0]).unwrap();
        let ctx = Tensor::new(vec![1, m.ctx_len, m.ctx_dim], rng.gaussian_vec(m.ctx_len * m.ctx_dim)).unwrap();
        let g = Tensor::scalar(7.5);
        let inputs = vec![
            Input::F32(lat.clone()),
            Input::F32(t.clone()),
            Input::F32(ctx.clone()),
            Input::F32(g.clone()),
        ];
        let mut bench_rt = Bench::new(1, 5);
        bench_rt.run("runtime/unet_full_b1_execute", || {
            std::hint::black_box(h.execute(&Runtime::unet_full(1), &inputs).unwrap());
        });
        let full = h.execute(&Runtime::unet_full(1), &inputs).unwrap();
        let partial_inputs = vec![
            Input::F32(lat),
            Input::F32(t),
            Input::F32(ctx),
            Input::F32(g),
            Input::F32(full[2].clone()),
        ];
        bench_rt.run("runtime/unet_partial_l2_b1_execute", || {
            std::hint::black_box(h.execute(&Runtime::unet_partial(2, 1), &partial_inputs).unwrap());
        });

        let coord = Coordinator::new(h);
        let mut req = GenRequest::new("red circle x3 y3", 11);
        req.steps = 4;
        req.sampler = "ddim".into();
        bench_rt.run("coordinator/generate_4step_b1", || {
            std::hint::black_box(coord.generate_one(&req).unwrap());
        });
        bench_rt.emit_json();
    }

    b.emit_json();
}
