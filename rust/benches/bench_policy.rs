//! Approximation-policy bench: the deterministic sim-backed comparison
//! behind the committed `BENCH_policy.json` trajectory (repo root).
//!
//! Three runs of the same 25-step prompt/seed on the sim backend:
//!
//! - **full** — all-full reference trajectory (quality anchor);
//! - **pas** — the calibrated PAS plan (`t_sparse=4`), the paper's
//!   default approximation and this bench's quality floor;
//! - **stability** — `StabilityPolicy` running *cold* (no
//!   calibration.json exists in the temp artifacts dir), the online
//!   alternative the policy subsystem adds.
//!
//! Reported per run: MAC reduction vs all-full (from `GenStats`) and
//! latent PSNR against the full reference (`quality::latent_psnr`).
//!
//! Modes (ci.sh):
//!   `--smoke`  validate only: StabilityPolicy must skip at least as
//!              many MACs as the PAS plan while landing inside the PAS
//!              quality band (PSNR within 6 dB of the PAS run). No
//!              file writes. This is the ISSUE acceptance criterion:
//!              uncalibrated stability meets the PAS floor.
//!   `--commit` everything `--smoke` checks, then rewrite
//!              `BENCH_policy.json`.
//!   default    measure and print, write nothing.
//!
//! Run: `cargo bench --bench bench_policy [-- --smoke | -- --commit]`

use std::path::Path;

use sd_acc::coordinator::{Coordinator, GenRequest, SamplerKind};
use sd_acc::pas::plan::{PasConfig, SamplingPlan};
use sd_acc::policy::PolicySpec;
use sd_acc::quality;
use sd_acc::runtime::{BackendKind, RuntimeService};
use sd_acc::util::json::Json;

/// Keys every BENCH_policy.json point must carry (schema validation).
const REQUIRED_KEYS: [&str; 8] = [
    "bench",
    "steps",
    "mac_reduction_pas",
    "mac_reduction_stability",
    "psnr_pas_db",
    "psnr_stability_db",
    "full_steps_stability",
    "psnr_band_db",
];

/// Stability may trade at most this much latent PSNR against the
/// calibrated PAS plan and still count as meeting the quality floor.
const PSNR_BAND_DB: f64 = 6.0;

const STEPS: usize = 25;

struct Measured {
    mac_pas: f64,
    mac_stab: f64,
    psnr_pas: f64,
    psnr_stab: f64,
    full_steps_stab: u64,
}

fn run_workload() -> anyhow::Result<Measured> {
    let art_dir =
        std::env::temp_dir().join(format!("sdacc_bench_policy_art_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&art_dir);
    // Sim backend over an empty dir: deterministic, artifact-less, and
    // provably calibration-free — the cold-start claim under test.
    let svc = RuntimeService::start_with(BackendKind::Sim, &art_dir)?;
    let coord = Coordinator::new(svc.handle());
    anyhow::ensure!(
        !art_dir.join("calibration.json").exists(),
        "bench precondition: no calibration artifact"
    );

    let base = |plan: SamplingPlan, policy: PolicySpec| {
        let mut r = GenRequest::new("red circle x4 y4 blue square x11 y11", 4242);
        r.steps = STEPS;
        r.sampler = SamplerKind::Ddim;
        r.plan = plan;
        r.policy = policy;
        r
    };
    let full = coord.generate_one(&base(SamplingPlan::Full, PolicySpec::Pas))?;
    let pas_cfg = PasConfig {
        t_sketch: STEPS / 2,
        t_complete: 3,
        t_sparse: 4,
        l_sketch: 2,
        l_refine: 2,
    };
    let pas = coord.generate_one(&base(SamplingPlan::Pas(pas_cfg), PolicySpec::Pas))?;
    let stab = coord.generate_one(&base(
        SamplingPlan::Full,
        PolicySpec::Stability { threshold_milli: 250 },
    ))?;

    let _ = std::fs::remove_dir_all(&art_dir);
    Ok(Measured {
        mac_pas: pas.stats.mac_reduction,
        mac_stab: stab.stats.mac_reduction,
        psnr_pas: quality::latent_psnr(&pas.latent, &full.latent),
        psnr_stab: quality::latent_psnr(&stab.latent, &full.latent),
        full_steps_stab: stab.stats.full_steps(),
    })
}

/// Schema-validate a BENCH_policy.json document.
fn validate(doc: &Json) -> Result<(), String> {
    for k in REQUIRED_KEYS {
        if doc.get(k).is_none() {
            return Err(format!("BENCH_policy.json missing required key '{k}'"));
        }
    }
    for k in ["mac_reduction_pas", "mac_reduction_stability"] {
        let v = doc.get_f64(k).ok_or_else(|| format!("key '{k}' is not a number"))?;
        if v <= 1.0 {
            return Err(format!("key '{k}' must be > 1 — the plan skipped no work (got {v})"));
        }
    }
    for k in ["psnr_pas_db", "psnr_stability_db"] {
        let v = doc.get_f64(k).ok_or_else(|| format!("key '{k}' is not a number"))?;
        if !v.is_finite() || v <= 0.0 {
            return Err(format!("key '{k}' must be a positive finite dB value (got {v})"));
        }
    }
    Ok(())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let commit = std::env::args().any(|a| a == "--commit");

    let m = run_workload().expect("policy workload");
    println!(
        "policy bench ({STEPS} steps, sim): pas mac x{:.2} psnr {:.1} dB | \
         stability mac x{:.2} psnr {:.1} dB ({} full steps, uncalibrated)",
        m.mac_pas, m.psnr_pas, m.mac_stab, m.psnr_stab, m.full_steps_stab
    );

    // The acceptance criterion: cold-started StabilityPolicy must be at
    // least as cheap as the calibrated PAS plan AND land in its quality
    // band against the shared full-trajectory reference.
    assert!(
        m.mac_stab >= m.mac_pas,
        "stability must skip at least as many MACs as pas (x{:.3} < x{:.3})",
        m.mac_stab,
        m.mac_pas
    );
    assert!(
        m.psnr_stab >= m.psnr_pas - PSNR_BAND_DB,
        "stability quality {:.1} dB fell below the PAS floor {:.1} dB - {PSNR_BAND_DB} dB band",
        m.psnr_stab,
        m.psnr_pas
    );
    assert!(
        (m.full_steps_stab as usize) < STEPS,
        "stability never skipped a step"
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("policy_tradeoff")),
        ("steps", Json::num(STEPS as f64)),
        ("mac_reduction_pas", Json::num(m.mac_pas)),
        ("mac_reduction_stability", Json::num(m.mac_stab)),
        ("psnr_pas_db", Json::num(m.psnr_pas)),
        ("psnr_stability_db", Json::num(m.psnr_stab)),
        ("full_steps_stability", Json::num(m.full_steps_stab as f64)),
        ("psnr_band_db", Json::num(PSNR_BAND_DB)),
    ]);
    validate(&doc).expect("fresh measurement must satisfy the BENCH_policy schema");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_policy.json");
    if let Some(prev) = std::fs::read_to_string(&out).ok().and_then(|s| Json::parse(&s).ok()) {
        validate(&prev).expect("committed BENCH_policy.json must satisfy the schema");
    }

    if commit {
        std::fs::write(&out, doc.to_string()).expect("write BENCH_policy.json");
        println!("wrote {}", out.display());
    } else if smoke {
        println!("bench_policy --smoke: stability meets the PAS cost + quality floor uncalibrated");
    }
}
