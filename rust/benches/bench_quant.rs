//! Mixed-precision sweep: fp32 -> fp16 -> W8A8 -> W4A8 over the real
//! SD v1.4 inventory and the runnable sd-tiny model, reporting effective
//! MAC, DRAM-traffic and energy reduction plus the latent-PSNR quality
//! proxy, and writing a machine-readable `BENCH_quant.json` at the repo
//! root to anchor the perf trajectory.
//!
//! `--smoke` (used by ci.sh) skips the wall-clock timing loops and the
//! repo-root artifact write but still computes every table and enforces
//! the acceptance bands, so a regression in the precision-scaled cost
//! model fails CI rather than only the full bench run (and CI leaves no
//! untracked files behind).

use std::path::Path;

use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::simulate_unet_step_quant;
use sd_acc::models::inventory::{sd_tiny, sd_v14, unet_ops, UNetArch};
use sd_acc::quant::{assign, predicted_psnr_db, QuantScheme};
use sd_acc::util::bench::Bench;
use sd_acc::util::json::Json;
use sd_acc::util::table::{f, ratio, Table};

struct Row {
    scheme: QuantScheme,
    macs_eff: f64,
    traffic_bytes: f64,
    energy_j: f64,
    energy_reduction: f64,
    psnr_db: f64,
}

fn sweep(arch: &UNetArch, cfg: &AccelConfig, policy: Policy) -> Vec<Row> {
    let ops = unet_ops(arch);
    let native_bits = (cfg.dtype_bytes * 8) as f64;
    let schemes = [
        QuantScheme::fp32(),
        QuantScheme::fp16(),
        QuantScheme::w8a8(),
        QuantScheme::w4a8(),
    ];
    let base_energy = {
        let plan = assign(&ops, QuantScheme::fp32(), false);
        simulate_unet_step_quant(cfg, policy, &ops, &plan).energy_j(cfg)
    };
    schemes
        .iter()
        .map(|&scheme| {
            let plan = assign(&ops, scheme, true);
            let r = simulate_unet_step_quant(cfg, policy, &ops, &plan);
            // Effective MACs from the PINNED plan (fragile layers run at
            // fp16), so the column agrees with the simulated traffic and
            // energy rather than the uniform scheme's width.
            let macs_eff: f64 = ops
                .iter()
                .zip(&plan)
                .map(|(op, p)| op.kind.macs() as f64 * 2.0 * p.mac_bits() as f64 / native_bits)
                .sum();
            Row {
                scheme,
                macs_eff,
                traffic_bytes: r.traffic_bytes,
                energy_j: r.energy_j(cfg),
                energy_reduction: base_energy / r.energy_j(cfg),
                psnr_db: predicted_psnr_db(&ops, &plan, None),
            }
        })
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = AccelConfig::default();
    let policy = Policy::optimized();
    let models = [sd_v14(), sd_tiny()];

    let mut json_models = Vec::new();
    for arch in &models {
        println!("== precision sweep: {} (optimized policy, CFG x2 step) ==", arch.name);
        let rows = sweep(arch, &cfg, policy);
        let mut t = Table::new(&[
            "scheme", "eff MACs (G)", "traffic (GB)", "energy (J)", "vs fp32", "PSNR proxy (dB)",
        ]);
        for r in &rows {
            t.row(vec![
                r.scheme.label(),
                f(r.macs_eff / 1e9, 1),
                f(r.traffic_bytes / 1e9, 3),
                f(r.energy_j, 2),
                ratio(r.energy_reduction),
                f(r.psnr_db, 1),
            ]);
        }
        t.print();
        println!();

        // Acceptance bands — the precision-scaled cost model must keep
        // modelling the headline wins, on every model.
        let get = |s: QuantScheme| rows.iter().find(|r| r.scheme == s).unwrap();
        let w8 = get(QuantScheme::w8a8());
        let w48 = get(QuantScheme::w4a8());
        let f16 = get(QuantScheme::fp16());
        assert!(
            w8.energy_reduction >= 3.0,
            "{}: W8A8 energy reduction {:.2}x < 3x",
            arch.name,
            w8.energy_reduction
        );
        assert!(
            f16.psnr_db > w8.psnr_db && w8.psnr_db > w48.psnr_db,
            "{}: PSNR proxy must degrade with aggressiveness",
            arch.name
        );
        assert!(
            f16.traffic_bytes > w8.traffic_bytes && w8.traffic_bytes > w48.traffic_bytes,
            "{}: traffic must shrink with operand bytes",
            arch.name
        );
        assert!(
            w48.energy_reduction > w8.energy_reduction,
            "{}: W4A8 must beat W8A8 on energy",
            arch.name
        );

        json_models.push(Json::obj(vec![
            ("model", Json::str(arch.name)),
            (
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("scheme", Json::str(&r.scheme.label())),
                                ("eff_macs", Json::num(r.macs_eff)),
                                ("traffic_bytes", Json::num(r.traffic_bytes)),
                                ("energy_j", Json::num(r.energy_j)),
                                ("energy_reduction", Json::num(r.energy_reduction)),
                                ("psnr_proxy_db", Json::num(r.psnr_db)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }

    if smoke {
        // Smoke mode is a CI assertion pass only — no repo-root artifact
        // write, no timing loops.
        println!("bench_quant --smoke: all acceptance bands hold");
        return;
    }

    // Machine-readable trailer at the repo root (the perf trajectory).
    let doc = Json::obj(vec![
        ("bench", Json::str("quant_precision_sweep")),
        ("policy", Json::str("optimized")),
        ("models", Json::Arr(json_models)),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_quant.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => println!("could not write {}: {e}", out.display()),
    }

    // Full mode: time the precision-aware hot path.
    let ops = unet_ops(&sd_v14());
    let plan = assign(&ops, QuantScheme::w8a8(), true);
    let mut b = Bench::default();
    b.run("simulate_unet_step_quant(sd-v1.4, W8A8)", || {
        std::hint::black_box(simulate_unet_step_quant(&cfg, policy, &ops, &plan));
    });
    b.run("assign(sd-v1.4, W8A8, pinned)", || {
        std::hint::black_box(assign(&ops, QuantScheme::w8a8(), true));
    });
    b.emit_json();
}
