//! End-to-end serving bench: request throughput/latency, batch
//! occupancy, and warm-vs-cold request-cache latency — the serving perf
//! trajectory's baseline (`BENCH_serving.json` at the repo root).
//!
//! Three sections:
//!
//! 1. **Request cache warm vs cold** (no artifacts needed): the cold
//!    path pays a regeneration proxy — a 50-step PNDM scheduler
//!    trajectory over an sd-tiny-sized latent, a strict *lower bound*
//!    on real generation, which also runs 100 U-Net executions — plus
//!    binary encode + store populate; the warm path is a content-
//!    addressed hit (store read + binary decode). The diffusion-cache
//!    acceptance bar: a warm hit must be >= 3x faster than even this
//!    floor on recompute-and-repopulate. Asserted, also in `--smoke`.
//! 2. **Batch occupancy** (no artifacts needed): a synthetic arrival
//!    pattern through the real `Batcher` + `Metrics`, reporting the
//!    executed-batch-size histogram, mean occupancy and queue depth.
//! 3. **Live serving** (only when AOT artifacts are present): full
//!    server over the PJRT runtime — req/s, p50/p95/p99, occupancy,
//!    measured warm-vs-cold hit latency through the client path.
//!
//! `--smoke` (used by ci.sh) trims iteration counts, still enforces the
//! warm >= 3x cold band, and skips the repo-root artifact write.
//!
//! Run: `cargo bench --bench bench_serving [-- --smoke]`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sd_acc::cache::{Cache, StoreConfig};
use sd_acc::coordinator::{BatchKey, GenRequest, GenResult, GenStats};
use sd_acc::pas::plan::StepAction;
use sd_acc::runtime::Tensor;
use sd_acc::scheduler::{make_sampler, NoiseSchedule};
use sd_acc::server::batcher::{BatchItem, Batcher};
use sd_acc::server::metrics::Metrics;
use sd_acc::util::bench::Bench;
use sd_acc::util::json::Json;
use sd_acc::util::rng::Pcg32;
use sd_acc::util::stats;

const LATENT_ELEMS: usize = 1024; // sd-tiny: 16x16x4
const STEPS: usize = 50;

fn sample_result(rng: &mut Pcg32) -> GenResult {
    GenResult {
        latent: Tensor::new(vec![LATENT_ELEMS / 4, 4], rng.gaussian_vec(LATENT_ELEMS)).unwrap(),
        stats: GenStats {
            actions: vec![StepAction::Full; STEPS],
            step_ms: vec![10.0; STEPS],
            mac_reduction: 1.0,
            total_ms: 500.0,
        },
    }
}

/// The cheapest conceivable "regeneration": just the scheduler math of a
/// full trajectory, no U-Net, no text encoder. Real cold generation is
/// orders of magnitude above this floor.
fn regeneration_floor(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    let mut latent = rng.gaussian_vec(LATENT_ELEMS);
    let eps: Vec<f32> = rng.gaussian_vec(LATENT_ELEMS);
    let mut sampler = make_sampler("pndm", NoiseSchedule::scaled_linear(1000, 0.00085, 0.012), STEPS);
    for i in 0..STEPS {
        sampler.step_mut(i, &mut latent, &eps);
    }
    latent
}

struct Item(GenRequest);

impl BatchItem for Item {
    type Key = BatchKey;

    fn key(&self) -> BatchKey {
        self.0.batch_key()
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke { Bench::new(2, 8) } else { Bench::default() };

    // ------------------------------------------- 1. warm vs cold cache
    let dir = std::env::temp_dir().join(format!("sdacc_bench_serving_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = Cache::open(StoreConfig::new(&dir), 0x5e41).expect("open cache");
    let mut rng = Pcg32::seeded(2026);

    let mut req = GenRequest::new("red circle x4 y4 blue square x11 y11", 4242);
    req.steps = STEPS;
    let result = sample_result(&mut rng);

    let mut cold_seed = 0u64;
    let cold_ns = b.run("cold: regeneration floor + populate request cache", || {
        cold_seed += 1;
        let latent = regeneration_floor(cold_seed);
        std::hint::black_box(latent.len());
        cache.put_result(&req, &result).expect("put result");
    });
    let warm_ns = b.run("warm: request cache hit (binary decode)", || {
        let hit = cache.get_result(&req).expect("request hit");
        std::hint::black_box(hit.latent.data().len());
    });
    let miss_ns = b.run("request cache miss (key absent)", || {
        std::hint::black_box(cache.get_result(&GenRequest::new("never generated", 1)).is_none());
    });
    let warm_ratio = cold_ns / warm_ns.max(1.0);
    println!("\nwarm-hit speedup over cold regenerate+populate: {warm_ratio:.1}x");
    assert!(
        warm_ratio >= 3.0,
        "acceptance: warm hit must be >= 3x faster than cold (got {warm_ratio:.1}x)"
    );

    // ---------------------------------------------- 2. batch occupancy
    let metrics = Metrics::default();
    let sizes = vec![1usize, 2, 4];
    let mut batcher: Batcher<Item> = Batcher::new(sizes.clone(), Duration::from_millis(0));
    let n_requests = if smoke { 64 } else { 512 };
    let mut flushed = 0usize;
    for i in 0..n_requests {
        let mut r = GenRequest::new("occupancy probe", i as u64);
        // Three distinct batch keys, weighted toward one hot key.
        r.steps = match i % 5 {
            0 => 20,
            1 => 30,
            _ => STEPS,
        };
        batcher.push(Item(r));
        if i % 8 == 7 {
            // Aged flush pass (max_wait = 0 so everything is ready).
            for batch in batcher.flush_ready(Instant::now()) {
                metrics.on_batch(batch.len());
                flushed += batch.len();
            }
            metrics.set_queue_depth(batcher.pending());
        }
    }
    for batch in batcher.flush_all() {
        metrics.on_batch(batch.len());
        flushed += batch.len();
    }
    metrics.set_queue_depth(batcher.pending());
    let occ = metrics.summary();
    println!(
        "batch occupancy: mean {:.2} over {} requests, histogram {:?}, final queue depth {}",
        occ.mean_batch_size, flushed, occ.batch_hist, occ.queue_depth
    );
    assert_eq!(flushed, n_requests, "every request must flush");
    assert_eq!(occ.queue_depth, 0, "drained batcher reports empty");
    assert!(
        occ.batch_hist.iter().all(|&(size, _)| sizes.contains(&size)),
        "only compiled batch sizes may execute: {:?}",
        occ.batch_hist
    );
    assert!(
        occ.batch_hist.iter().any(|&(size, _)| size == 4),
        "the hot key must fill max-size batches: {:?}",
        occ.batch_hist
    );

    // ------------------------------------------------- 3. live serving
    let e2e = run_e2e(smoke);

    b.emit_json();
    if smoke {
        println!("bench_serving --smoke: all acceptance bands hold");
        let _ = std::fs::remove_dir_all(&dir);
        return;
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("serving_hotpath")),
        ("latent_elems", Json::num(LATENT_ELEMS as f64)),
        ("steps", Json::num(STEPS as f64)),
        ("cold_ns", Json::num(cold_ns)),
        ("warm_hit_ns", Json::num(warm_ns)),
        ("miss_ns", Json::num(miss_ns)),
        ("warm_ratio", Json::num(warm_ratio)),
        ("mean_batch_size", Json::num(occ.mean_batch_size)),
        (
            "batch_hist",
            Json::Arr(
                occ.batch_hist
                    .iter()
                    .map(|&(size, count)| {
                        Json::obj(vec![
                            ("size", Json::num(size as f64)),
                            ("count", Json::num(count as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("e2e", e2e.unwrap_or(Json::Null)),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serving.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => println!("could not write {}: {e}", out.display()),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Full-stack serving measurement; `None` when no AOT artifacts exist
/// or the run failed (failures are *reported*, never silently folded
/// into the no-artifacts case).
fn run_e2e(smoke: bool) -> Option<Json> {
    use sd_acc::runtime::default_artifacts_dir;

    let art_dir = default_artifacts_dir();
    if !art_dir.join("manifest.json").exists() {
        println!("no artifacts at {} — skipping live serving section", art_dir.display());
        return None;
    }
    match run_e2e_inner(smoke, &art_dir) {
        Ok(j) => Some(j),
        Err(e) => {
            println!("live serving section FAILED (artifacts present): {e:#}");
            None
        }
    }
}

fn run_e2e_inner(smoke: bool, art_dir: &Path) -> anyhow::Result<Json> {
    use sd_acc::coordinator::Coordinator;
    use sd_acc::runtime::RuntimeService;
    use sd_acc::server::{Server, ServerConfig};

    let svc = RuntimeService::start(art_dir)?;
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let cache_dir =
        std::env::temp_dir().join(format!("sdacc_bench_serving_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cache = Arc::new(Cache::open(StoreConfig::new(&cache_dir), coord.manifest_hash())?);
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(30),
            cache: Some(Arc::clone(&cache)),
        },
    );
    let client = server.client();
    let n = if smoke { 4 } else { 16 };
    let steps = if smoke { 4 } else { 12 };

    // Drive both passes in a closure so the server is always shut down
    // cleanly afterwards, success or failure.
    let drive = || -> anyhow::Result<(Vec<f64>, Vec<f64>, f64)> {
        // Cold pass: generate everything, measuring per-request wall time.
        let t0 = Instant::now();
        let mut lat_ms = Vec::with_capacity(n);
        for i in 0..n {
            let mut r =
                GenRequest::new(&format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9), i as u64);
            r.steps = steps;
            r.sampler = "ddim".into();
            let t = Instant::now();
            client.generate(r)?;
            lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let wall_s = t0.elapsed().as_secs_f64();

        // Warm pass: identical requests — served from the request cache.
        let mut warm_ms = Vec::with_capacity(n);
        for i in 0..n {
            let mut r =
                GenRequest::new(&format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9), i as u64);
            r.steps = steps;
            r.sampler = "ddim".into();
            let t = Instant::now();
            client.generate(r)?;
            warm_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        Ok((lat_ms, warm_ms, wall_s))
    };
    let driven = drive();
    let m = server.metrics.summary();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&cache_dir);
    let (lat_ms, warm_ms, wall_s) = driven?;

    let (p50, p95, p99) = (
        stats::percentile(&lat_ms, 50.0),
        stats::percentile(&lat_ms, 95.0),
        stats::percentile(&lat_ms, 99.0),
    );
    println!(
        "live serving: {n} reqs in {wall_s:.2}s ({:.2} req/s) | cold p50 {p50:.0} ms p99 {p99:.0} ms | \
         warm hit p50 {:.2} ms | occupancy {:.2} | hits {} misses {}",
        n as f64 / wall_s,
        stats::percentile(&warm_ms, 50.0),
        m.mean_batch_size,
        m.cache_hits,
        m.cache_misses,
    );
    Ok(Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("steps", Json::num(steps as f64)),
        ("wall_s", Json::num(wall_s)),
        ("req_per_s", Json::num(n as f64 / wall_s)),
        ("p50_ms", Json::num(p50)),
        ("p95_ms", Json::num(p95)),
        ("p99_ms", Json::num(p99)),
        ("warm_hit_p50_ms", Json::num(stats::percentile(&warm_ms, 50.0))),
        ("mean_batch_size", Json::num(m.mean_batch_size)),
        ("cache_hits", Json::num(m.cache_hits as f64)),
        ("cache_misses", Json::num(m.cache_misses as f64)),
    ]))
}
