//! End-to-end serving bench: request throughput/latency, batch
//! occupancy, and warm-vs-cold request-cache latency — the serving perf
//! trajectory's baseline (`BENCH_serving.json` at the repo root).
//!
//! Four sections:
//!
//! 1. **Request cache warm vs cold** (no artifacts needed): the cold
//!    path pays a regeneration proxy — a 50-step PNDM scheduler
//!    trajectory over an sd-tiny-sized latent, a strict *lower bound*
//!    on real generation, which also runs 100 U-Net executions — plus
//!    binary encode + store populate; the warm path is a content-
//!    addressed hit (store read + binary decode). The diffusion-cache
//!    acceptance bar: a warm hit must be >= 3x faster than even this
//!    floor on recompute-and-repopulate. Asserted, also in `--smoke`.
//! 2. **Batch occupancy** (no artifacts needed): a synthetic arrival
//!    pattern through the real `Batcher` + `Metrics`, reporting the
//!    executed-batch-size histogram, mean occupancy and queue depth.
//! 3. **Event-channel & cancellation overhead** (no artifacts needed):
//!    the job API streams one `Step` event per denoising step through a
//!    `StepObserver`; this section runs the scheduler-floor loop with
//!    (a) the no-op observer, (b) a cancel-poll-only observer, and
//!    (c) a channel observer feeding a live drainer thread, and
//!    asserts the event-channel path adds **< 5% p50 overhead** over
//!    the blocking path. Asserted, also in `--smoke` — this is the
//!    acceptance band for the streaming job API.
//! 4. **Live serving**: full server over the resolved execution
//!    backend (xla when AOT artifacts are present, the deterministic
//!    sim backend otherwise — this section always executes) — req/s,
//!    p50/p95/p99, occupancy, measured warm-vs-cold hit latency
//!    through the client path, plus submit->event->done latency and
//!    time-to-cancel-ack through the `JobHandle` API, and the same
//!    submit->stream->done round-trip over the loopback HTTP/SSE wire
//!    tier (`net::WireServer` / `net::WireClient`) so the wire tax over
//!    the in-process job API is a tracked number.
//!
//! `--smoke` (used by ci.sh) trims iteration counts, still enforces the
//! warm >= 3x cold and event-overhead bands, and skips the repo-root
//! artifact write.
//!
//! Run: `cargo bench --bench bench_serving [-- --smoke]`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sd_acc::cache::{Cache, StoreConfig};
use sd_acc::coordinator::{BatchKey, GenRequest, GenResult, GenStats, NoopObserver, StepObserver};
use sd_acc::pas::plan::StepAction;
use sd_acc::runtime::Tensor;
use sd_acc::scheduler::{make_sampler, NoiseSchedule};
use sd_acc::server::batcher::{BatchItem, Batcher};
use sd_acc::server::metrics::Metrics;
use sd_acc::server::{CancelToken, JobEvent};
use sd_acc::util::bench::Bench;
use sd_acc::util::json::Json;
use sd_acc::util::rng::Pcg32;
use sd_acc::util::stats;

const LATENT_ELEMS: usize = 1024; // sd-tiny: 16x16x4
const STEPS: usize = 50;

fn sample_result(rng: &mut Pcg32) -> GenResult {
    GenResult {
        latent: Tensor::new(vec![LATENT_ELEMS / 4, 4], rng.gaussian_vec(LATENT_ELEMS)).unwrap(),
        stats: GenStats {
            actions: vec![StepAction::Full; STEPS],
            step_ms: vec![10.0; STEPS],
            mac_reduction: 1.0,
            total_ms: 500.0,
        },
    }
}

/// The cheapest conceivable "regeneration": just the scheduler math of a
/// full trajectory, no U-Net, no text encoder. Real cold generation is
/// orders of magnitude above this floor.
fn regeneration_floor(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    let mut latent = rng.gaussian_vec(LATENT_ELEMS);
    let eps: Vec<f32> = rng.gaussian_vec(LATENT_ELEMS);
    let mut sampler = make_sampler("pndm", NoiseSchedule::scaled_linear(1000, 0.00085, 0.012), STEPS);
    for i in 0..STEPS {
        sampler.step_mut(i, &mut latent, &eps);
    }
    latent
}

struct Item(GenRequest);

impl BatchItem for Item {
    type Key = BatchKey;

    fn key(&self) -> BatchKey {
        self.0.batch_key()
    }
}

/// SD-class latent for the observer-overhead loop (64x64 images decode
/// from 4096-element latents; sd-tiny's 1024 would make the per-step
/// work so small that channel costs dominate by construction).
const OBS_ELEMS: usize = 4096;

/// The scheduler-floor loop with the coordinator's observer contract:
/// one `should_cancel` poll before each step, one `on_step` after —
/// exactly the per-step hooks `generate_batch_observed` adds.
fn observed_floor(seed: u64, obs: &dyn StepObserver) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    let mut latent = rng.gaussian_vec(OBS_ELEMS);
    let eps: Vec<f32> = rng.gaussian_vec(OBS_ELEMS);
    let mut sampler =
        make_sampler("pndm", NoiseSchedule::scaled_linear(1000, 0.00085, 0.012), STEPS);
    for i in 0..STEPS {
        if obs.should_cancel() {
            break;
        }
        let t0 = Instant::now();
        sampler.step_mut(i, &mut latent, &eps);
        obs.on_step(i, StepAction::Full, t0.elapsed().as_secs_f64() * 1e3);
    }
    latent
}

/// Observer that only pays the cancellation poll (token never fires).
struct CancelPollObserver {
    cancel: CancelToken,
}

impl StepObserver for CancelPollObserver {
    fn should_cancel(&self) -> bool {
        self.cancel.is_cancelled()
    }
}

/// Observer streaming one `JobEvent::Step` per step into a channel —
/// the job API's event path.
struct ChannelObserver {
    tx: std::sync::mpsc::Sender<JobEvent>,
    cancel: CancelToken,
}

impl StepObserver for ChannelObserver {
    fn on_step(&self, i: usize, action: StepAction, ms: f64) {
        let _ = self.tx.send(JobEvent::Step { i, action, ms });
    }

    fn should_cancel(&self) -> bool {
        self.cancel.is_cancelled()
    }
}

/// One timed run of `observed_floor`, in ns. When a receiver is given,
/// the timed region also drains it (same thread — deterministic, no
/// cross-thread scheduler noise in the measurement); the second return
/// is the number of events drained.
fn timed_floor(
    seed: u64,
    obs: &dyn StepObserver,
    drain: Option<&std::sync::mpsc::Receiver<JobEvent>>,
) -> (f64, usize) {
    let mut drained = 0usize;
    let t0 = Instant::now();
    let latent = observed_floor(seed, obs);
    std::hint::black_box(latent.len());
    if let Some(rx) = drain {
        while let Ok(ev) = rx.try_recv() {
            std::hint::black_box(ev.label());
            drained += 1;
        }
    }
    (t0.elapsed().as_nanos() as f64, drained)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke { Bench::new(2, 8) } else { Bench::default() };

    // ------------------------------------------- 1. warm vs cold cache
    let dir = std::env::temp_dir().join(format!("sdacc_bench_serving_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = Cache::open(StoreConfig::new(&dir), 0x5e41).expect("open cache");
    let mut rng = Pcg32::seeded(2026);

    let mut req = GenRequest::new("red circle x4 y4 blue square x11 y11", 4242);
    req.steps = STEPS;
    let result = sample_result(&mut rng);

    let mut cold_seed = 0u64;
    let cold_ns = b.run("cold: regeneration floor + populate request cache", || {
        cold_seed += 1;
        let latent = regeneration_floor(cold_seed);
        std::hint::black_box(latent.len());
        cache.put_result(&req, &result).expect("put result");
    });
    let warm_ns = b.run("warm: request cache hit (binary decode)", || {
        let hit = cache.get_result(&req).expect("request hit");
        std::hint::black_box(hit.latent.data().len());
    });
    let miss_ns = b.run("request cache miss (key absent)", || {
        std::hint::black_box(cache.get_result(&GenRequest::new("never generated", 1)).is_none());
    });
    let warm_ratio = cold_ns / warm_ns.max(1.0);
    println!("\nwarm-hit speedup over cold regenerate+populate: {warm_ratio:.1}x");
    assert!(
        warm_ratio >= 3.0,
        "acceptance: warm hit must be >= 3x faster than cold (got {warm_ratio:.1}x)"
    );

    // ---------------------------------------------- 2. batch occupancy
    let metrics = Metrics::default();
    let sizes = vec![1usize, 2, 4];
    let mut batcher: Batcher<Item> = Batcher::new(sizes.clone(), Duration::from_millis(0));
    let n_requests = if smoke { 64 } else { 512 };
    let mut flushed = 0usize;
    for i in 0..n_requests {
        let mut r = GenRequest::new("occupancy probe", i as u64);
        // Three distinct batch keys, weighted toward one hot key.
        r.steps = match i % 5 {
            0 => 20,
            1 => 30,
            _ => STEPS,
        };
        batcher.push(Item(r));
        if i % 8 == 7 {
            // Aged flush pass (max_wait = 0 so everything is ready).
            for batch in batcher.flush_ready(Instant::now()) {
                metrics.on_batch(batch.len());
                flushed += batch.len();
            }
            metrics.set_queue_depth(batcher.pending());
        }
    }
    for batch in batcher.flush_all() {
        metrics.on_batch(batch.len());
        flushed += batch.len();
    }
    metrics.set_queue_depth(batcher.pending());
    let occ = metrics.summary();
    println!(
        "batch occupancy: mean {:.2} over {} requests, histogram {:?}, final queue depth {}",
        occ.mean_batch_size, flushed, occ.batch_hist, occ.queue_depth
    );
    assert_eq!(flushed, n_requests, "every request must flush");
    assert_eq!(occ.queue_depth, 0, "drained batcher reports empty");
    assert!(
        occ.batch_hist.iter().all(|&(size, _)| sizes.contains(&size)),
        "only compiled batch sizes may execute: {:?}",
        occ.batch_hist
    );
    assert!(
        occ.batch_hist.iter().any(|&(size, _)| size == 4),
        "the hot key must fill max-size batches: {:?}",
        occ.batch_hist
    );

    // -------------------- 3. event-channel & cancellation overhead
    // The event path sends one JobEvent::Step per step and drains them
    // inside the timed region (same thread: deterministic, no consumer
    // wakeup races). The three variants are measured *interleaved* —
    // blocking/cancel/event per iteration — so a load burst or
    // frequency transition hits all three alike instead of biasing
    // whichever was measured last; p50 then absorbs the outliers.
    let iters = if smoke { 64 } else { 256 };
    let cancel_obs = CancelPollObserver { cancel: CancelToken::new() };
    let (ev_tx, ev_rx) = std::sync::mpsc::channel::<JobEvent>();
    let chan_obs = ChannelObserver { tx: ev_tx, cancel: CancelToken::new() };
    for k in 0..8u64 {
        // Warm-up: first-touch allocation noise stays out of the medians.
        let _ = timed_floor(k, &NoopObserver, None);
        let _ = timed_floor(k, &chan_obs, Some(&ev_rx));
    }
    let mut blocking_ns = Vec::with_capacity(iters);
    let mut cancel_ns = Vec::with_capacity(iters);
    let mut event_ns = Vec::with_capacity(iters);
    let mut delivered = 0usize;
    for k in 0..iters {
        blocking_ns.push(timed_floor(k as u64, &NoopObserver, None).0);
        cancel_ns.push(timed_floor(k as u64, &cancel_obs, None).0);
        let (ns, n) = timed_floor(k as u64, &chan_obs, Some(&ev_rx));
        event_ns.push(ns);
        delivered += n;
    }
    let blocking_p50 = stats::percentile(&blocking_ns, 50.0);
    let cancel_p50 = stats::percentile(&cancel_ns, 50.0);
    let event_p50 = stats::percentile(&event_ns, 50.0);
    assert_eq!(delivered, iters * STEPS, "every step event must be delivered");
    let event_overhead = event_p50 / blocking_p50.max(1.0) - 1.0;
    let cancel_overhead = cancel_p50 / blocking_p50.max(1.0) - 1.0;
    println!(
        "step-loop p50: blocking {:.0} ns | +cancel poll {:.0} ns ({:+.2}%) | \
         +event channel {:.0} ns ({:+.2}%)",
        blocking_p50,
        cancel_p50,
        cancel_overhead * 100.0,
        event_p50,
        event_overhead * 100.0,
    );
    assert!(
        event_overhead < 0.05,
        "acceptance: the event-channel path must add < 5% p50 overhead over the \
         blocking path (got {:.2}%)",
        event_overhead * 100.0
    );

    // ------------------------------------------------- 4. live serving
    let e2e = run_e2e(smoke);

    b.emit_json();
    if smoke {
        println!("bench_serving --smoke: all acceptance bands hold");
        let _ = std::fs::remove_dir_all(&dir);
        return;
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("serving_hotpath")),
        ("latent_elems", Json::num(LATENT_ELEMS as f64)),
        ("steps", Json::num(STEPS as f64)),
        ("cold_ns", Json::num(cold_ns)),
        ("warm_hit_ns", Json::num(warm_ns)),
        ("miss_ns", Json::num(miss_ns)),
        ("warm_ratio", Json::num(warm_ratio)),
        ("step_blocking_p50_ns", Json::num(blocking_p50)),
        ("step_cancel_poll_p50_ns", Json::num(cancel_p50)),
        ("step_event_channel_p50_ns", Json::num(event_p50)),
        ("event_channel_overhead", Json::num(event_overhead)),
        ("mean_batch_size", Json::num(occ.mean_batch_size)),
        (
            "batch_hist",
            Json::Arr(
                occ.batch_hist
                    .iter()
                    .map(|&(size, count)| {
                        Json::obj(vec![
                            ("size", Json::num(size as f64)),
                            ("count", Json::num(count as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("e2e", e2e.unwrap_or(Json::Null)),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serving.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => println!("could not write {}: {e}", out.display()),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Full-stack serving measurement over whichever execution backend
/// resolves — xla over real artifacts when present, the deterministic
/// sim backend otherwise — so this section *executes* (never skips) in
/// artifact-less containers; `None` only when the run itself failed
/// (failures are *reported*, never silently folded away).
fn run_e2e(smoke: bool) -> Option<Json> {
    use sd_acc::runtime::default_artifacts_dir;

    let art_dir = default_artifacts_dir();
    match run_e2e_inner(smoke, &art_dir) {
        Ok(j) => Some(j),
        Err(e) => {
            println!("live serving section FAILED: {e:#}");
            None
        }
    }
}

fn run_e2e_inner(smoke: bool, art_dir: &Path) -> anyhow::Result<Json> {
    use sd_acc::coordinator::Coordinator;
    use sd_acc::runtime::RuntimeService;
    use sd_acc::server::{Server, ServerConfig};

    let svc = RuntimeService::start(art_dir)?;
    println!("live serving backend: {}", svc.backend());
    let coord = Arc::new(Coordinator::new(svc.handle()));
    let cache_dir =
        std::env::temp_dir().join(format!("sdacc_bench_serving_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cache = Arc::new(coord.open_cache(StoreConfig::new(&cache_dir))?);
    let server = Server::start(
        Arc::clone(&coord),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(30),
            cache: Some(Arc::clone(&cache)),
            ..Default::default()
        },
    );
    let client = server.client();
    let n = if smoke { 4 } else { 16 };
    let steps = if smoke { 4 } else { 12 };

    struct WireProbe {
        round_trip_ms: f64,
        frames: usize,
    }

    // Drive the passes in a closure so the server is always shut down
    // cleanly afterwards, success or failure.
    #[allow(clippy::type_complexity)]
    let drive = || -> anyhow::Result<(Vec<f64>, Vec<f64>, f64, f64, usize, f64, WireProbe)> {
        // Cold pass: generate everything, measuring per-request wall time.
        let t0 = Instant::now();
        let mut lat_ms = Vec::with_capacity(n);
        for i in 0..n {
            let mut r =
                GenRequest::new(&format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9), i as u64);
            r.steps = steps;
            r.sampler = "ddim".into();
            let t = Instant::now();
            client.generate(r)?;
            lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let wall_s = t0.elapsed().as_secs_f64();

        // Warm pass: identical requests — served from the request cache.
        let mut warm_ms = Vec::with_capacity(n);
        for i in 0..n {
            let mut r =
                GenRequest::new(&format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9), i as u64);
            r.steps = steps;
            r.sampler = "ddim".into();
            let t = Instant::now();
            client.generate(r)?;
            warm_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }

        // Job-API path: submit -> streamed events -> done on a fresh
        // (cache-missing) request, counting the Step events.
        let mut r = GenRequest::new("yellow circle x1 y13", 9_000_001);
        r.steps = steps;
        r.sampler = "ddim".into();
        let t = Instant::now();
        let h = client.submit(r)?;
        let (events, outcome) = h.wait_with_events();
        outcome.map_err(|e| anyhow::anyhow!("job-API run failed: {e}"))?;
        let submit_done_ms = t.elapsed().as_secs_f64() * 1e3;
        let step_events =
            events.iter().filter(|e| matches!(e, JobEvent::Step { .. })).count();

        // Cancellation overhead: cancel immediately after submit and
        // time until the Cancelled ack arrives.
        let mut r = GenRequest::new("yellow circle x2 y12", 9_000_002);
        r.steps = steps;
        r.sampler = "ddim".into();
        let t = Instant::now();
        let h = client.submit(r)?;
        h.cancel.cancel();
        let _ = h.wait(); // Cancelled (or Done if it raced the flush)
        let cancel_ack_ms = t.elapsed().as_secs_f64() * 1e3;

        // Wire tier: the same submit -> stream -> done round-trip over
        // loopback HTTP/SSE, so the wire tax over the in-process job
        // API above is a tracked number, not folklore.
        let wire = sd_acc::net::WireServer::start(
            client.clone(),
            Arc::clone(&server.metrics),
            "127.0.0.1:0",
            2,
        )?;
        let body = Json::obj(vec![
            ("prompt", Json::str("yellow circle x3 y11")),
            ("seed", Json::num(9_000_003.0)),
            ("steps", Json::num(steps as f64)),
            ("sampler", Json::str("ddim")),
        ]);
        let wc = sd_acc::net::WireClient::new(wire.addr().to_string());
        let t = Instant::now();
        let (_id, frames) = wc.run(&body)?;
        let round_trip_ms = t.elapsed().as_secs_f64() * 1e3;
        let last = frames.last().map(|e| e.label.as_str()).unwrap_or("");
        anyhow::ensure!(last == "done", "wire run must end in done (got {last:?})");
        let probe = WireProbe { round_trip_ms, frames: frames.len() };
        wire.shutdown();

        Ok((lat_ms, warm_ms, wall_s, submit_done_ms, step_events, cancel_ack_ms, probe))
    };
    let driven = drive();
    let m = server.metrics.summary();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&cache_dir);
    let (lat_ms, warm_ms, wall_s, submit_done_ms, step_events, cancel_ack_ms, wire) = driven?;

    let (p50, p95, p99) = (
        stats::percentile(&lat_ms, 50.0),
        stats::percentile(&lat_ms, 95.0),
        stats::percentile(&lat_ms, 99.0),
    );
    println!(
        "live serving: {n} reqs in {wall_s:.2}s ({:.2} req/s) | cold p50 {p50:.0} ms p99 {p99:.0} ms | \
         warm hit p50 {:.2} ms | occupancy {:.2} | hits {} misses {}",
        n as f64 / wall_s,
        stats::percentile(&warm_ms, 50.0),
        m.mean_batch_size,
        m.cache_hits,
        m.cache_misses,
    );
    println!(
        "job API: submit->event->done {submit_done_ms:.0} ms ({step_events} step events) | \
         cancel ack {cancel_ack_ms:.1} ms | {} cancellations",
        m.cancellations,
    );
    println!(
        "wire tier: submit->stream->done {:.0} ms over loopback HTTP/SSE ({} frames)",
        wire.round_trip_ms, wire.frames,
    );
    Ok(Json::obj(vec![
        ("backend", Json::str(svc.backend().as_str())),
        ("requests", Json::num(n as f64)),
        ("steps", Json::num(steps as f64)),
        ("wall_s", Json::num(wall_s)),
        ("req_per_s", Json::num(n as f64 / wall_s)),
        ("p50_ms", Json::num(p50)),
        ("p95_ms", Json::num(p95)),
        ("p99_ms", Json::num(p99)),
        ("warm_hit_p50_ms", Json::num(stats::percentile(&warm_ms, 50.0))),
        ("submit_done_ms", Json::num(submit_done_ms)),
        ("step_events", Json::num(step_events as f64)),
        ("cancel_ack_ms", Json::num(cancel_ack_ms)),
        ("wire_round_trip_ms", Json::num(wire.round_trip_ms)),
        ("wire_frames", Json::num(wire.frames as f64)),
        ("mean_batch_size", Json::num(m.mean_batch_size)),
        ("cache_hits", Json::num(m.cache_hits as f64)),
        ("cache_misses", Json::num(m.cache_misses as f64)),
    ]))
}
