//! Table II — phase-aware sampling under different configurations:
//! MAC reduction from the REAL model inventories (v1.4 / v2.1-base / XL)
//! plus measured quality proxies on the runnable sd-tiny model when AOT
//! artifacts are available (latent PSNR + Fréchet proxy vs the original
//! 50-step sampling; DESIGN.md substitution for CLIP/FID/IS).

use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::models::inventory::{sd_tiny, sd_v14, sd_v21_base, sd_xl};
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::{PasConfig, SamplingPlan};
use sd_acc::quality;
use sd_acc::runtime::{default_artifacts_dir, RuntimeService};
use sd_acc::util::stats;
use sd_acc::util::table::{f, ratio, Table};

fn main() {
    // --- MAC-reduction columns (real architectures) ----------------------
    println!("== Table II: MAC reduction (real inventories, 50 steps) ==");
    let mut t = Table::new(&["config", "sd-v1.4", "paper", "sd-v2.1", "paper", "sd-xl", "paper"]);
    let paper = [
        ("PAS-25/2", "", "", ""),
        ("PAS-25/3", "2.72x", "2.84x", "3.96x"),
        ("PAS-25/4", "2.84x", "2.98x", "4.28x"),
        ("PAS-25/5", "3.31x", "3.50x", "5.68x"),
    ];
    let cms = [CostModel::new(&sd_v14()), CostModel::new(&sd_v21_base()), CostModel::new(&sd_xl())];
    // v1.4 uses T_complete=4, others 3 (Sec. VI-B).
    for (i, sparse) in [2usize, 3, 4, 5].iter().enumerate() {
        let mut row = vec![format!("PAS-25/{sparse}")];
        for (j, cm) in cms.iter().enumerate() {
            let t_complete = if j == 0 { 4 } else { 3 };
            let cfg = PasConfig { t_sketch: 25, t_complete, t_sparse: *sparse, l_sketch: 2, l_refine: 2 };
            let red = cm.mac_reduction(&cfg.plan(50));
            row.push(ratio(red));
            row.push(paper[i].1.to_string().clone());
        }
        // Fix paper columns per model.
        let row = vec![
            row[0].clone(),
            row[1].clone(),
            paper[i].1.into(),
            row[3].clone(),
            paper[i].2.into(),
            row[5].clone(),
            paper[i].3.into(),
        ];
        t.row(row);
    }
    t.print();

    // Sanity: our v1.4 PAS-25/4 must be near the paper's 2.84x.
    let red = cms[0].mac_reduction(
        &PasConfig { t_sketch: 25, t_complete: 4, t_sparse: 4, l_sketch: 2, l_refine: 2 }.plan(50),
    );
    assert!((2.3..3.4).contains(&red), "PAS-25/4 v1.4 reduction {red}");

    // --- quality proxies on the runnable model (xla over artifacts,
    // --- deterministic sim backend otherwise) -----------------------------
    let dir = default_artifacts_dir();
    let steps: usize = std::env::var("SD_ACC_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    let n_prompts: usize = std::env::var("SD_ACC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let svc = RuntimeService::start(&dir).expect("runtime");
    println!(
        "\n== Table II: measured quality proxies on sd-tiny ({steps} steps, {n_prompts} prompts, backend {}) ==",
        svc.backend()
    );
    let coord = Coordinator::new(svc.handle());
    let cm_tiny = CostModel::new(&sd_tiny());
    let prompts = ["red circle x4 y4 blue square x11 y11", "green stripe x8 y8"];

    // Reference latents (original sampling).
    let refs: Vec<_> = prompts
        .iter()
        .take(n_prompts)
        .enumerate()
        .map(|(i, p)| {
            let mut r = GenRequest::new(p, 500 + i as u64);
            r.steps = steps;
            coord.generate_one(&r).expect("ref gen")
        })
        .collect();

    let mut t = Table::new(&["config", "MAC red. (tiny)", "latent PSNR (dB)", "Frechet proxy", "wall ms/img"]);
    t.row(vec!["Original".into(), "1.00x".into(), "inf".into(), "0.000".into(),
               f(stats::mean(&refs.iter().map(|r| r.stats.total_ms).collect::<Vec<_>>()), 0)]);
    let m = coord.runtime().manifest().model.clone();
    let ref_imgs: Vec<Vec<f64>> = coord
        .decode(&refs.iter().map(|r| r.latent.clone()).collect::<Vec<_>>())
        .unwrap()
        .iter()
        .map(|img| quality::image_features(img, m.img_h, m.img_w))
        .collect();
    for sparse in [2usize, 3, 4, 5] {
        let pas = PasConfig { t_sketch: steps / 2, t_complete: 3, t_sparse: sparse, l_sketch: 2, l_refine: 2 };
        let mut psnrs = Vec::new();
        let mut lats = Vec::new();
        let mut ms = Vec::new();
        for (i, p) in prompts.iter().take(n_prompts).enumerate() {
            let mut r = GenRequest::new(p, 500 + i as u64);
            r.steps = steps;
            r.plan = SamplingPlan::Pas(pas);
            let out = coord.generate_one(&r).expect("pas gen");
            psnrs.push(quality::latent_psnr(&out.latent, &refs[i].latent));
            ms.push(out.stats.total_ms);
            lats.push(out.latent);
        }
        let imgs: Vec<Vec<f64>> = coord
            .decode(&lats)
            .unwrap()
            .iter()
            .map(|img| quality::image_features(img, m.img_h, m.img_w))
            .collect();
        let fre = quality::frechet_proxy(&imgs, &ref_imgs);
        let red = cm_tiny.mac_reduction(&pas.plan(steps));
        t.row(vec![
            format!("PAS-{}/{sparse}", steps / 2),
            ratio(red),
            f(stats::mean(&psnrs), 1),
            f(fre, 3),
            f(stats::mean(&ms), 0),
        ]);
    }
    t.print();
    println!("\nshape: quality proxy degrades monotonically-ish as T_sparse grows, like Table II's CLIP column");
}
