//! Table III — comparison with state-of-the-art U-Net optimisations on
//! SD v1.4: BK-SDM (Base/Small/Tiny), DeepCache, and PAS-25/4.
//!
//! MAC reductions come from the real inventory (BK-SDM by pruning the
//! published block sets; DeepCache/PAS by plan accounting); GPU speedup
//! uses the V100 analytic model. CLIP/FID columns are quoted from the
//! papers (we cannot run the pretrained eval networks — DESIGN.md);
//! DeepCache-vs-PAS quality is additionally *measured* on sd-tiny via
//! the latent-PSNR proxy when artifacts are present.

use sd_acc::coordinator::{Coordinator, GenRequest};
use sd_acc::models::inventory::{sd_tiny, sd_v14};
use sd_acc::pas::baselines::{deepcache_plan, BkSdmVariant};
use sd_acc::pas::cost::CostModel;
use sd_acc::pas::plan::{PasConfig, SamplingPlan, StepAction};
use sd_acc::quality;
use sd_acc::runtime::{default_artifacts_dir, RuntimeService};
use sd_acc::util::stats;
use sd_acc::util::table::{f, ratio, Table};

/// GPU speedup model: compute-bound latency scales with per-plan MACs,
/// with an efficiency penalty for irregular (pruned/cached) execution.
fn gpu_speedup(mac_reduction: f64, irregularity_penalty: f64) -> f64 {
    1.0 / ((1.0 / mac_reduction) + irregularity_penalty)
}

fn main() {
    let arch = sd_v14();
    let cm = CostModel::new(&arch);

    println!("== Table III: SD v1.4, 50 steps ==");
    let mut t = Table::new(&["method", "CLIP^ / psnr*", "FID^", "MAC red.", "paper", "GPU speedup"]);
    t.row(vec!["Original".into(), "0.3004^".into(), "25.38^".into(), "1.00x".into(), "1.00x".into(), "1.00x".into()]);
    for v in [BkSdmVariant::Base, BkSdmVariant::Small, BkSdmVariant::Tiny] {
        let (clip, fid) = v.published_clip_fid();
        let red = v.mac_reduction(&arch);
        t.row(vec![
            v.label().into(),
            format!("{clip:.4}^"),
            format!("{fid:.2}^"),
            ratio(red),
            match v {
                BkSdmVariant::Base => "1.51x".into(),
                BkSdmVariant::Small => "1.56x".into(),
                BkSdmVariant::Tiny => "1.65x".into(),
            },
            ratio(gpu_speedup(red, 0.02)),
        ]);
    }
    let dc_plan = deepcache_plan(50, 3, 2);
    let dc_red = cm.mac_reduction(&dc_plan);
    t.row(vec![
        "DeepCache".into(),
        "0.2980^".into(),
        "24.54^".into(),
        ratio(dc_red),
        "2.11x".into(),
        ratio(gpu_speedup(dc_red, 0.12)),
    ]);
    let pas = PasConfig { t_sketch: 25, t_complete: 4, t_sparse: 4, l_sketch: 2, l_refine: 2 };
    let pas_red = cm.mac_reduction(&pas.plan(50));
    t.row(vec![
        "PAS-25/4 (ours)".into(),
        "0.2978^".into(),
        "24.01^".into(),
        ratio(pas_red),
        "2.84x".into(),
        ratio(gpu_speedup(pas_red, 0.12)),
    ]);
    t.print();
    println!("^ quoted from the respective papers (eval nets unavailable here)");

    assert!(pas_red > dc_red, "PAS must beat DeepCache on MAC reduction");
    assert!(dc_red > BkSdmVariant::Tiny.mac_reduction(&arch));

    // --- measured DeepCache-vs-PAS quality proxy (xla over artifacts,
    // --- deterministic sim backend otherwise) -----------------------------
    let dir = default_artifacts_dir();
    let steps: usize = std::env::var("SD_ACC_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    let svc = RuntimeService::start(&dir).expect("runtime");
    println!(
        "\n== measured on sd-tiny ({steps} steps, backend {}): PAS vs DeepCache at matched MAC budget ==",
        svc.backend()
    );
    let coord = Coordinator::new(svc.handle());
    let cm_tiny = CostModel::new(&sd_tiny());
    let prompts = ["red circle x4 y4", "blue square x10 y6"];

    let refs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut r = GenRequest::new(p, 700 + i as u64);
            r.steps = steps;
            coord.generate_one(&r).expect("ref")
        })
        .collect();

    let pas_tiny = PasConfig { t_sketch: steps / 2, t_complete: 3, t_sparse: 3, l_sketch: 2, l_refine: 2 };
    let dc_interval = 2usize; // denser refresh than PAS => comparable budget
    let eval = |plans: Vec<Vec<StepAction>>, label: &str| -> (f64, f64) {
        let mut psnrs = Vec::new();
        let mut red = 0.0;
        for (i, p) in prompts.iter().enumerate() {
            let mut r = GenRequest::new(p, 700 + i as u64);
            r.steps = steps;
            r.plan = match label {
                "pas" => SamplingPlan::Pas(pas_tiny),
                _ => SamplingPlan::Pas(PasConfig {
                    // DeepCache as a degenerate PAS: uniform from step 0.
                    t_sketch: steps,
                    t_complete: 1,
                    t_sparse: dc_interval,
                    l_sketch: 2,
                    l_refine: 2,
                }),
            };
            let out = coord.generate_one(&r).expect("gen");
            red = out.stats.mac_reduction;
            psnrs.push(quality::latent_psnr(&out.latent, &refs[i].latent));
        }
        let _ = plans;
        (stats::mean(&psnrs), red)
    };

    let (pas_psnr, pas_r) = eval(vec![], "pas");
    let (dc_psnr, dc_r) = eval(vec![], "dc");
    let _ = cm_tiny;
    let mut t = Table::new(&["method", "MAC red. (tiny)", "latent PSNR (dB)"]);
    t.row(vec!["DeepCache-style".into(), ratio(dc_r), f(dc_psnr, 1)]);
    t.row(vec!["PAS (ours)".into(), ratio(pas_r), f(pas_psnr, 1)]);
    t.print();
    println!("\nshape: PAS achieves more MAC reduction at comparable-or-better proxy quality");
}
