//! Length-delimited little-endian binary payload codec for large latent
//! values (`GenResult`).
//!
//! JSON float text costs ~3x the bytes of raw f32 (a shortest-roundtrip
//! Gaussian sample is 10-12 characters against 4 bytes) and pays a
//! parse per element on every warm request hit. This codec stores the
//! latent buffer as raw little-endian f32 with length prefixes, so a
//! cache hit is a bounds-checked `memcpy`, the stored bytes are
//! ≤ 40% of the JSON encoding (asserted in tests), and non-finite
//! values (NaN/±inf) plus signed zero round-trip bit-exactly — JSON has
//! no representation for them at all.
//!
//! Framing (everything little-endian):
//!
//! ```text
//! magic  b"SDAB"                      4 bytes
//! format version                      1 byte  (FORMAT_VERSION)
//! ndims  u32, then dims as u64 each
//! latent u64 count, then raw f32 LE   4 bytes/elem
//! actions u64 count, then u32 each    (0 = Full, l = Partial(l))
//! step_ms u64 count, then f64 LE each
//! mac_reduction f64, total_ms f64
//! ```
//!
//! Every read is bounds-checked and the decoder requires the buffer to
//! be fully consumed, so truncated or trailing-garbage payloads are
//! decode errors, never panics — the store's corruption-recovery scan
//! uses [`is_well_formed`] to tell a damaged payload from a healthy one
//! without constructing the value.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{GenResult, GenStats};
use crate::pas::plan::StepAction;
use crate::runtime::Tensor;

/// File magic: "SD-Acc binary" payload.
pub const MAGIC: [u8; 4] = *b"SDAB";

/// Bump together with `CACHE_VERSION` when the framing changes shape.
pub const FORMAT_VERSION: u8 = 1;

/// Caps that make [`is_well_formed`] and the decoder reject absurd
/// length prefixes before allocating (a corrupt length must not ask for
/// gigabytes).
const MAX_DIMS: usize = 16;

// ------------------------------------------------------------------ writer

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(capacity: usize) -> Writer {
        let mut buf = Vec::with_capacity(capacity + MAGIC.len() + 1);
        buf.extend_from_slice(&MAGIC);
        buf.push(FORMAT_VERSION);
        Writer { buf }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32_slice(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

// ------------------------------------------------------------------ reader

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Result<Reader<'a>> {
        if bytes.len() < MAGIC.len() + 1 {
            bail!("binary payload: {} bytes is shorter than the header", bytes.len());
        }
        if bytes[..4] != MAGIC {
            bail!("binary payload: bad magic");
        }
        if bytes[4] != FORMAT_VERSION {
            bail!("binary payload: format version {} (expected {FORMAT_VERSION})", bytes[4]);
        }
        Ok(Reader { bytes, pos: 5 })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| anyhow!("binary payload: truncated at byte {}", self.pos))?;
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed count, sanity-bounded by the remaining bytes.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        let remaining = self.bytes.len() - self.pos;
        if n.checked_mul(elem_bytes).map_or(true, |total| total > remaining) {
            bail!("binary payload: length prefix {n} exceeds remaining {remaining} bytes");
        }
        Ok(n)
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            bail!(
                "binary payload: {} trailing bytes after value",
                self.bytes.len() - self.pos
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- GenResult

/// Encode a generation result (latent + stats) into the binary framing.
pub fn encode_gen_result(res: &GenResult) -> Vec<u8> {
    let mut w = Writer::new(
        res.latent.len() * 4 + res.stats.step_ms.len() * 8 + res.stats.actions.len() * 4 + 64,
    );
    w.u32(res.latent.dims.len() as u32);
    for &d in &res.latent.dims {
        w.u64(d as u64);
    }
    w.f32_slice(res.latent.data());
    w.u64(res.stats.actions.len() as u64);
    for a in &res.stats.actions {
        w.u32(match a {
            StepAction::Full => 0,
            StepAction::Partial(l) => *l as u32,
        });
    }
    w.u64(res.stats.step_ms.len() as u64);
    for &ms in &res.stats.step_ms {
        w.f64(ms);
    }
    w.f64(res.stats.mac_reduction);
    w.f64(res.stats.total_ms);
    w.buf
}

/// Decode the binary framing back into a `GenResult`. Bit-exact for
/// every f32/f64 payload value, non-finite included.
pub fn decode_gen_result(bytes: &[u8]) -> Result<GenResult> {
    let mut r = Reader::new(bytes)?;
    let ndims = r.u32()? as usize;
    if ndims > MAX_DIMS {
        bail!("binary payload: {ndims} dims (cap {MAX_DIMS})");
    }
    // Validate the dims *here*, with overflow-checked arithmetic, before
    // any of them reach `Tensor::new`'s unchecked product — a corrupt
    // payload must decode to an error, never a panic (the store's
    // self-heal path depends on that).
    let mut dims = Vec::with_capacity(ndims);
    let mut elems: u64 = 1;
    for _ in 0..ndims {
        let d = r.u64()?;
        elems = elems
            .checked_mul(d)
            .ok_or_else(|| anyhow!("binary payload: dims product overflows"))?;
        dims.push(d as usize);
    }
    let data = r.f32_vec()?;
    if data.len() as u64 != elems {
        bail!(
            "binary payload: latent length {} disagrees with dims {dims:?}",
            data.len()
        );
    }
    let latent = Tensor::new(dims, data)?;
    let n_actions = r.count(4)?;
    let mut actions = Vec::with_capacity(n_actions);
    for _ in 0..n_actions {
        let l = r.u32()? as usize;
        actions.push(if l == 0 { StepAction::Full } else { StepAction::Partial(l) });
    }
    let n_ms = r.count(8)?;
    let mut step_ms = Vec::with_capacity(n_ms);
    for _ in 0..n_ms {
        step_ms.push(r.f64()?);
    }
    let mac_reduction = r.f64()?;
    let total_ms = r.f64()?;
    r.finish()?;
    Ok(GenResult { latent, stats: GenStats { actions, step_ms, mac_reduction, total_ms } })
}

/// Structural health check without building the value: does this byte
/// buffer walk as a complete, self-consistent binary payload? Used by
/// the store's payload-scan recovery to separate damaged files from
/// healthy ones (the JSON namespaces use a parse check instead).
pub fn is_well_formed(bytes: &[u8]) -> bool {
    fn walk(r: &mut Reader) -> Result<()> {
        let ndims = r.u32()? as usize;
        if ndims > MAX_DIMS {
            bail!("too many dims");
        }
        let mut elems: u64 = 1;
        for _ in 0..ndims {
            // checked, not saturating: must agree with decode_gen_result
            // on what counts as healthy.
            elems = elems
                .checked_mul(r.u64()?)
                .ok_or_else(|| anyhow!("dims product overflows"))?;
        }
        let n = r.count(4)?;
        if n as u64 != elems {
            bail!("latent length disagrees with dims");
        }
        r.take(n * 4)?;
        let n_actions = r.count(4)?;
        r.take(n_actions * 4)?;
        let n_ms = r.count(8)?;
        r.take(n_ms * 8)?;
        r.f64()?;
        r.f64()?;
        r.finish()
    }
    let Ok(mut r) = Reader::new(bytes) else { return false };
    walk(&mut r).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(latent: Vec<f32>) -> GenResult {
        let n = latent.len();
        GenResult {
            latent: Tensor::new(vec![n / 2, 2], latent).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(2), StepAction::Partial(1)],
                step_ms: vec![12.5, 3.25, 3.0],
                mac_reduction: 2.5,
                total_ms: 18.75,
            },
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let res = sample(vec![0.5, -1.25, 3.0e-7, 0.1, -0.0, 7.5e-3, 2.0, 9.9]);
        let bytes = encode_gen_result(&res);
        let back = decode_gen_result(&bytes).unwrap();
        assert_eq!(back.latent.dims, res.latent.dims);
        let bits = |t: &Tensor| t.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.latent), bits(&res.latent));
        assert_eq!(back.stats.actions, res.stats.actions);
        assert_eq!(back.stats.step_ms, res.stats.step_ms);
        assert_eq!(back.stats.mac_reduction, res.stats.mac_reduction);
        assert_eq!(back.stats.total_ms, res.stats.total_ms);
    }

    #[test]
    fn non_finite_and_signed_zero_survive() {
        // JSON cannot carry any of these; the binary codec must keep the
        // exact bit patterns (including the NaN payload bits).
        let specials = vec![
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            0.0,
            f32::from_bits(0x7fc0_dead), // NaN with payload
            f32::MIN_POSITIVE / 2.0,     // subnormal
            f32::MAX,
        ];
        let res = sample(specials.clone());
        let back = decode_gen_result(&encode_gen_result(&res)).unwrap();
        for (a, b) in specials.iter().zip(back.latent.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
        }
    }

    #[test]
    fn truncation_is_an_error_never_a_panic() {
        let bytes = encode_gen_result(&sample(vec![1.0, 2.0, 3.0, 4.0]));
        for cut in 0..bytes.len() {
            assert!(decode_gen_result(&bytes[..cut]).is_err(), "cut at {cut} decoded");
            // Well-formedness agrees with the decoder.
            assert!(!is_well_formed(&bytes[..cut]), "cut at {cut} claimed well-formed");
        }
        assert!(decode_gen_result(&bytes).is_ok());
        assert!(is_well_formed(&bytes));
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = encode_gen_result(&sample(vec![1.0, 2.0]));
        bytes.extend_from_slice(b"junk");
        assert!(decode_gen_result(&bytes).is_err());
        assert!(!is_well_formed(&bytes));
    }

    #[test]
    fn wrong_magic_or_version_rejected() {
        let mut bytes = encode_gen_result(&sample(vec![1.0, 2.0]));
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(decode_gen_result(&bad_magic).is_err());
        bytes[4] = FORMAT_VERSION + 1;
        assert!(decode_gen_result(&bytes).is_err());
        assert!(!is_well_formed(b""));
        assert!(!is_well_formed(b"{\"json\":true}"));
    }

    #[test]
    fn absurd_length_prefix_rejected_before_allocation() {
        // Header + ndims=1 + dim=u64::MAX + latent count u64::MAX.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(FORMAT_VERSION);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_gen_result(&bytes).is_err());
        assert!(!is_well_formed(&bytes));
    }

    /// Dims whose product overflows, or that disagree with the latent
    /// length, must be decode *errors* — never a panic inside
    /// `Tensor::new`'s unchecked product (debug) or a wrapped bogus
    /// tensor (release).
    #[test]
    fn corrupt_dims_are_errors_not_panics() {
        let tail = |bytes: &mut Vec<u8>| {
            // empty latent + empty actions + empty step_ms + scalars
            bytes.extend_from_slice(&0u64.to_le_bytes());
            bytes.extend_from_slice(&0u64.to_le_bytes());
            bytes.extend_from_slice(&0u64.to_le_bytes());
            bytes.extend_from_slice(&1.0f64.to_le_bytes());
            bytes.extend_from_slice(&1.0f64.to_le_bytes());
        };
        // dims [2^40, 2^40, 0]: checked product overflows before the 0.
        let mut overflow = Vec::new();
        overflow.extend_from_slice(&MAGIC);
        overflow.push(FORMAT_VERSION);
        overflow.extend_from_slice(&3u32.to_le_bytes());
        overflow.extend_from_slice(&(1u64 << 40).to_le_bytes());
        overflow.extend_from_slice(&(1u64 << 40).to_le_bytes());
        overflow.extend_from_slice(&0u64.to_le_bytes());
        tail(&mut overflow);
        assert!(decode_gen_result(&overflow).is_err(), "overflowing dims must error");
        assert!(!is_well_formed(&overflow), "health check must agree with the decoder");

        // dims [4] but zero latent elements: consistent framing, wrong shape.
        let mut mismatch = Vec::new();
        mismatch.extend_from_slice(&MAGIC);
        mismatch.push(FORMAT_VERSION);
        mismatch.extend_from_slice(&1u32.to_le_bytes());
        mismatch.extend_from_slice(&4u64.to_le_bytes());
        tail(&mut mismatch);
        assert!(decode_gen_result(&mismatch).is_err(), "dims/length mismatch must error");
        assert!(!is_well_formed(&mismatch));
    }
}
