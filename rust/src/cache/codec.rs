//! Codec layer: typed values <-> on-disk payload bytes.
//!
//! One impl per cached namespace. Small structured payloads
//! (calibration reports, plan fronts, quant profiles) keep the compact
//! JSON text encoding — they are a few KB of config/score data and JSON
//! keeps them greppable on disk. Request-level `GenResult` payloads are
//! dominated by the latent buffer and go through the length-delimited
//! binary codec ([`super::binary`]): raw little-endian f32 is ≤ 40% of
//! the JSON float text (asserted below) and a warm hit decodes with a
//! bounds-checked copy instead of per-element float parsing. The binary
//! form is also bit-exact for NaN/±inf/-0.0, which JSON cannot carry at
//! all. `decode_bytes(encode_bytes(x)) == x` is property-tested in
//! `proptests.rs` for every namespace.

use anyhow::{anyhow, Result};

use crate::coordinator::GenResult;
#[cfg(test)]
use crate::coordinator::GenStats;
use crate::pas::calibrate::CalibrationReport;
use crate::pas::plan::PasConfig;
#[cfg(test)]
use crate::pas::plan::StepAction;
use crate::pas::search::Candidate;
use crate::quant::calibrate::QuantProfile;
use crate::util::json::Json;

use super::binary;
use super::namespaces::{NS_CALIB, NS_PLAN, NS_QUANT, NS_REQUEST};

/// A value that can live in the store under a fixed namespace.
pub trait Codec: Sized {
    /// Namespace (subdirectory + key salt) this type is stored under.
    const NAMESPACE: &'static str;

    fn encode_payload(&self) -> Vec<u8>;
    fn decode_payload(bytes: &[u8]) -> Result<Self>;
}

/// Parse a JSON-namespace payload (UTF-8 text bytes).
fn parse_json(bytes: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(bytes).map_err(|e| anyhow!("cache payload: {e}"))?;
    Json::parse(text).map_err(|e| anyhow!("cache payload: {e}"))
}

// ------------------------------------------------------------ calibration

impl Codec for CalibrationReport {
    const NAMESPACE: &'static str = NS_CALIB;

    fn encode_payload(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    fn decode_payload(bytes: &[u8]) -> Result<CalibrationReport> {
        CalibrationReport::from_json(&parse_json(bytes)?)
    }
}

// ----------------------------------------------------------- quant profile

impl Codec for QuantProfile {
    const NAMESPACE: &'static str = NS_QUANT;

    fn encode_payload(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    fn decode_payload(bytes: &[u8]) -> Result<QuantProfile> {
        QuantProfile::from_json(&parse_json(bytes)?)
    }
}

// -------------------------------------------------------------- plan front

/// A searched Pareto front for one (model, steps, quality target) cell:
/// the ranked candidates plus the search inputs that produced them.
#[derive(Debug, Clone)]
pub struct PlanFront {
    pub total_steps: usize,
    pub min_mac_reduction: f64,
    pub min_psnr_db: Option<f64>,
    /// D* of the calibration report the search ran against.
    pub d_star: usize,
    pub candidates: Vec<Candidate>,
}

impl PlanFront {
    /// Best configuration of the front (rank 0), if any.
    pub fn best(&self) -> Option<&Candidate> {
        self.candidates.first()
    }
}

fn pas_config_json(cfg: &PasConfig) -> Json {
    Json::obj(vec![
        ("t_sketch", Json::num(cfg.t_sketch as f64)),
        ("t_complete", Json::num(cfg.t_complete as f64)),
        ("t_sparse", Json::num(cfg.t_sparse as f64)),
        ("l_sketch", Json::num(cfg.l_sketch as f64)),
        ("l_refine", Json::num(cfg.l_refine as f64)),
    ])
}

fn pas_config_from_json(j: &Json) -> Result<PasConfig> {
    let field = |k: &str| j.get_usize(k).ok_or_else(|| anyhow!("plan config: missing '{k}'"));
    Ok(PasConfig {
        t_sketch: field("t_sketch")?,
        t_complete: field("t_complete")?,
        t_sparse: field("t_sparse")?,
        l_sketch: field("l_sketch")?,
        l_refine: field("l_refine")?,
    })
}

impl Codec for PlanFront {
    const NAMESPACE: &'static str = NS_PLAN;

    fn encode_payload(&self) -> Vec<u8> {
        Json::obj(vec![
            ("total_steps", Json::num(self.total_steps as f64)),
            ("min_mac_reduction", Json::num(self.min_mac_reduction)),
            (
                "min_psnr_db",
                self.min_psnr_db.map(Json::num).unwrap_or(Json::Null),
            ),
            ("d_star", Json::num(self.d_star as f64)),
            (
                "candidates",
                Json::Arr(
                    self.candidates
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("cfg", pas_config_json(&c.cfg)),
                                ("mac_reduction", Json::num(c.mac_reduction)),
                                ("psnr_db", c.psnr_db.map(Json::num).unwrap_or(Json::Null)),
                                ("validated", Json::Bool(c.validated)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string()
        .into_bytes()
    }

    fn decode_payload(bytes: &[u8]) -> Result<PlanFront> {
        let j = parse_json(bytes)?;
        let candidates = j
            .get("candidates")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan front: missing candidates"))?
            .iter()
            .map(|c| {
                Ok(Candidate {
                    cfg: pas_config_from_json(c.req("cfg").map_err(|e| anyhow!("{e}"))?)?,
                    mac_reduction: c
                        .get_f64("mac_reduction")
                        .ok_or_else(|| anyhow!("candidate: missing mac_reduction"))?,
                    psnr_db: c.get_f64("psnr_db"),
                    validated: c.get("validated").and_then(Json::as_bool).unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PlanFront {
            total_steps: j
                .get_usize("total_steps")
                .ok_or_else(|| anyhow!("plan front: missing total_steps"))?,
            min_mac_reduction: j
                .get_f64("min_mac_reduction")
                .ok_or_else(|| anyhow!("plan front: missing min_mac_reduction"))?,
            min_psnr_db: j.get_f64("min_psnr_db"),
            d_star: j.get_usize("d_star").unwrap_or(0),
            candidates,
        })
    }
}

// --------------------------------------------------------- request results

impl Codec for GenResult {
    const NAMESPACE: &'static str = NS_REQUEST;

    fn encode_payload(&self) -> Vec<u8> {
        binary::encode_gen_result(self)
    }

    fn decode_payload(bytes: &[u8]) -> Result<GenResult> {
        binary::decode_gen_result(bytes)
    }
}

/// The retired v2 JSON encoding of a `GenResult`, kept under test so the
/// equivalence property (binary decode == JSON decode for finite
/// latents) and the ≤ 40% size bound stay checkable against the real
/// old format rather than an approximation.
#[cfg(test)]
pub(crate) fn gen_result_to_json_v2(res: &GenResult) -> String {
    let actions = Json::Arr(
        res.stats
            .actions
            .iter()
            .map(|a| match a {
                StepAction::Full => Json::num(0.0),
                StepAction::Partial(l) => Json::num(*l as f64),
            })
            .collect(),
    );
    Json::obj(vec![
        (
            "dims",
            Json::Arr(res.latent.dims.iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        (
            "latent",
            Json::Arr(res.latent.data().iter().map(|&x| Json::num(x as f64)).collect()),
        ),
        ("actions", actions),
        ("step_ms", Json::arr_f64(&res.stats.step_ms)),
        ("mac_reduction", Json::num(res.stats.mac_reduction)),
        ("total_ms", Json::num(res.stats.total_ms)),
    ])
    .to_string()
}

#[cfg(test)]
pub(crate) fn gen_result_from_json_v2(text: &str) -> Result<GenResult> {
    let j = Json::parse(text).map_err(|e| anyhow!("gen result json: {e}"))?;
    let dims: Vec<usize> = j
        .get("dims")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("gen result: missing dims"))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    let data: Vec<f32> = j
        .get("latent")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("gen result: missing latent"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("gen result: non-numeric latent element"))
        })
        .collect::<Result<Vec<_>>>()?;
    let latent = crate::runtime::Tensor::new(dims, data)?;
    let actions = j
        .get("actions")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("gen result: missing actions"))?
        .iter()
        .map(|v| {
            let l = v.as_usize().ok_or_else(|| anyhow!("gen result: bad action"))?;
            Ok(if l == 0 { StepAction::Full } else { StepAction::Partial(l) })
        })
        .collect::<Result<Vec<_>>>()?;
    let step_ms = j
        .get("step_ms")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default();
    Ok(GenResult {
        latent,
        stats: GenStats {
            actions,
            step_ms,
            mac_reduction: j.get_f64("mac_reduction").unwrap_or(1.0),
            total_ms: j.get_f64("total_ms").unwrap_or(0.0),
        },
    })
}

/// Encode straight to the on-disk payload bytes.
pub fn encode_bytes<T: Codec>(value: &T) -> Vec<u8> {
    value.encode_payload()
}

/// Decode the on-disk payload bytes.
pub fn decode_bytes<T: Codec>(bytes: &[u8]) -> Result<T> {
    T::decode_payload(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::calibrate::analyse;
    use crate::runtime::Tensor;
    use crate::util::rng::Pcg32;

    #[test]
    fn quant_profile_text_roundtrip() {
        let prof = crate::quant::calibrate::synthetic_profile(
            &crate::models::inventory::sd_tiny(),
            20,
        );
        let back: QuantProfile = decode_bytes(&encode_bytes(&prof)).unwrap();
        assert_eq!(back, prof);
        assert!(decode_bytes::<QuantProfile>(b"{\"model\":\"x\"}").is_err(), "missing ranges");
    }

    #[test]
    fn calibration_text_roundtrip() {
        let raw: Vec<Vec<f64>> = (0..12)
            .map(|b| (0..19).map(|t| ((b * 19 + t) as f64).sin().abs()).collect())
            .collect();
        let rep = analyse(raw, vec![0.25; 20], 20, 3);
        let back: CalibrationReport = decode_bytes(&encode_bytes(&rep)).unwrap();
        assert_eq!(back.d_star, rep.d_star);
        assert_eq!(back.outliers, rep.outliers);
        assert_eq!(back.scores, rep.scores);
        assert_eq!(back.noise, rep.noise);
    }

    #[test]
    fn plan_front_roundtrip_exact() {
        let front = PlanFront {
            total_steps: 50,
            min_mac_reduction: 1.6,
            min_psnr_db: Some(13.0),
            d_star: 21,
            candidates: vec![
                Candidate {
                    cfg: PasConfig { t_sketch: 25, t_complete: 4, t_sparse: 4, l_sketch: 2, l_refine: 2 },
                    mac_reduction: 2.84,
                    psnr_db: Some(14.25),
                    validated: true,
                },
                Candidate {
                    cfg: PasConfig { t_sketch: 30, t_complete: 2, t_sparse: 3, l_sketch: 3, l_refine: 1 },
                    mac_reduction: 2.1,
                    psnr_db: None,
                    validated: false,
                },
            ],
        };
        let back: PlanFront = decode_bytes(&encode_bytes(&front)).unwrap();
        assert_eq!(back.total_steps, front.total_steps);
        assert_eq!(back.min_psnr_db, front.min_psnr_db);
        assert_eq!(back.candidates.len(), 2);
        assert_eq!(back.candidates[0].cfg, front.candidates[0].cfg);
        assert_eq!(back.candidates[0].psnr_db, Some(14.25));
        assert!(back.candidates[0].validated);
        assert_eq!(back.candidates[1].psnr_db, None);
        assert_eq!(back.best().unwrap().cfg.t_sketch, 25);
    }

    #[test]
    fn gen_result_roundtrip_exact() {
        let res = GenResult {
            latent: Tensor::new(vec![4, 2], vec![0.5, -1.25, 3.0, 0.1, -0.0, 7.5e-3, 2.0, 9.9])
                .unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(2), StepAction::Partial(1)],
                step_ms: vec![12.5, 3.25, 3.0],
                mac_reduction: 2.5,
                total_ms: 18.75,
            },
        };
        let back: GenResult = decode_bytes(&encode_bytes(&res)).unwrap();
        assert_eq!(back.latent.dims, res.latent.dims);
        assert_eq!(back.latent.data(), res.latent.data());
        assert_eq!(back.stats.actions, res.stats.actions);
        assert_eq!(back.stats.step_ms, res.stats.step_ms);
        assert_eq!(back.stats.mac_reduction, res.stats.mac_reduction);
    }

    #[test]
    fn truncated_payload_is_an_error_not_a_panic() {
        let res = GenResult {
            latent: Tensor::new(vec![2], vec![1.0, 2.0]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full],
                step_ms: vec![1.0],
                mac_reduction: 1.0,
                total_ms: 1.0,
            },
        };
        let bytes = encode_bytes(&res);
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_bytes::<GenResult>(&bytes[..cut]).is_err());
        }
    }

    /// The acceptance bound for the binary payload switch: a realistic
    /// latent stores in ≤ 40% of the v2 JSON encoding's bytes.
    #[test]
    fn binary_latent_is_at_most_40_percent_of_json() {
        let mut rng = Pcg32::seeded(424242);
        let steps = 50;
        let res = GenResult {
            latent: Tensor::new(vec![256, 4], rng.gaussian_vec(1024)).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full; steps],
                step_ms: (0..steps).map(|i| 10.0 + i as f64 * 0.125).collect(),
                mac_reduction: 1.0,
                total_ms: 512.5,
            },
        };
        let bin = encode_bytes(&res).len() as f64;
        let json = gen_result_to_json_v2(&res).len() as f64;
        assert!(
            bin <= 0.4 * json,
            "binary {bin} bytes vs JSON {json} bytes = {:.1}% (bound 40%)",
            100.0 * bin / json
        );
    }

    /// For finite latents the binary codec is semantically identical to
    /// the retired JSON encoding (same decoded value, bit for bit — the
    /// JSON path's f32 -> f64 -> shortest-roundtrip text -> f32 chain is
    /// exact for finite f32).
    #[test]
    fn binary_equals_json_semantics_for_finite_latents() {
        let mut rng = Pcg32::seeded(99);
        let res = GenResult {
            latent: Tensor::new(vec![32, 4], rng.gaussian_vec(128)).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(3)],
                step_ms: vec![8.0, 2.0],
                mac_reduction: 1.75,
                total_ms: 10.0,
            },
        };
        let via_bin = decode_bytes::<GenResult>(&encode_bytes(&res)).unwrap();
        let via_json = gen_result_from_json_v2(&gen_result_to_json_v2(&res)).unwrap();
        assert_eq!(via_bin.latent.dims, via_json.latent.dims);
        let bits = |t: &Tensor| t.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&via_bin.latent), bits(&via_json.latent));
        assert_eq!(via_bin.stats.actions, via_json.stats.actions);
        assert_eq!(via_bin.stats.step_ms, via_json.stats.step_ms);
    }
}
