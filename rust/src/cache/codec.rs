//! Codec layer: typed values <-> `util::json::Json` payloads.
//!
//! One impl per cached namespace: calibration reports, searched plan
//! fronts, and request-level generation results. Encoding uses only
//! finite numbers (JSON has no inf/nan; the store never receives
//! non-finite latents because the coordinator rejects them upstream),
//! and `Json`'s shortest-roundtrip float formatting makes
//! `decode(encode(x)) == x` exact — property-tested in `proptests.rs`.

use anyhow::{anyhow, Result};

use crate::coordinator::{GenResult, GenStats};
use crate::pas::calibrate::CalibrationReport;
use crate::pas::plan::{PasConfig, StepAction};
use crate::pas::search::Candidate;
use crate::quant::calibrate::QuantProfile;
use crate::runtime::Tensor;
use crate::util::json::Json;

use super::namespaces::{NS_CALIB, NS_PLAN, NS_QUANT, NS_REQUEST};

/// A value that can live in the store under a fixed namespace.
pub trait Codec: Sized {
    /// Namespace (subdirectory + key salt) this type is stored under.
    const NAMESPACE: &'static str;

    fn encode(&self) -> Json;
    fn decode(j: &Json) -> Result<Self>;
}

// ------------------------------------------------------------ calibration

impl Codec for CalibrationReport {
    const NAMESPACE: &'static str = NS_CALIB;

    fn encode(&self) -> Json {
        self.to_json()
    }

    fn decode(j: &Json) -> Result<CalibrationReport> {
        CalibrationReport::from_json(j)
    }
}

// ----------------------------------------------------------- quant profile

impl Codec for QuantProfile {
    const NAMESPACE: &'static str = NS_QUANT;

    fn encode(&self) -> Json {
        self.to_json()
    }

    fn decode(j: &Json) -> Result<QuantProfile> {
        QuantProfile::from_json(j)
    }
}

// -------------------------------------------------------------- plan front

/// A searched Pareto front for one (model, steps, quality target) cell:
/// the ranked candidates plus the search inputs that produced them.
#[derive(Debug, Clone)]
pub struct PlanFront {
    pub total_steps: usize,
    pub min_mac_reduction: f64,
    pub min_psnr_db: Option<f64>,
    /// D* of the calibration report the search ran against.
    pub d_star: usize,
    pub candidates: Vec<Candidate>,
}

impl PlanFront {
    /// Best configuration of the front (rank 0), if any.
    pub fn best(&self) -> Option<&Candidate> {
        self.candidates.first()
    }
}

fn pas_config_json(cfg: &PasConfig) -> Json {
    Json::obj(vec![
        ("t_sketch", Json::num(cfg.t_sketch as f64)),
        ("t_complete", Json::num(cfg.t_complete as f64)),
        ("t_sparse", Json::num(cfg.t_sparse as f64)),
        ("l_sketch", Json::num(cfg.l_sketch as f64)),
        ("l_refine", Json::num(cfg.l_refine as f64)),
    ])
}

fn pas_config_from_json(j: &Json) -> Result<PasConfig> {
    let field = |k: &str| j.get_usize(k).ok_or_else(|| anyhow!("plan config: missing '{k}'"));
    Ok(PasConfig {
        t_sketch: field("t_sketch")?,
        t_complete: field("t_complete")?,
        t_sparse: field("t_sparse")?,
        l_sketch: field("l_sketch")?,
        l_refine: field("l_refine")?,
    })
}

impl Codec for PlanFront {
    const NAMESPACE: &'static str = NS_PLAN;

    fn encode(&self) -> Json {
        Json::obj(vec![
            ("total_steps", Json::num(self.total_steps as f64)),
            ("min_mac_reduction", Json::num(self.min_mac_reduction)),
            (
                "min_psnr_db",
                self.min_psnr_db.map(Json::num).unwrap_or(Json::Null),
            ),
            ("d_star", Json::num(self.d_star as f64)),
            (
                "candidates",
                Json::Arr(
                    self.candidates
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("cfg", pas_config_json(&c.cfg)),
                                ("mac_reduction", Json::num(c.mac_reduction)),
                                ("psnr_db", c.psnr_db.map(Json::num).unwrap_or(Json::Null)),
                                ("validated", Json::Bool(c.validated)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn decode(j: &Json) -> Result<PlanFront> {
        let candidates = j
            .get("candidates")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan front: missing candidates"))?
            .iter()
            .map(|c| {
                Ok(Candidate {
                    cfg: pas_config_from_json(c.req("cfg").map_err(|e| anyhow!("{e}"))?)?,
                    mac_reduction: c
                        .get_f64("mac_reduction")
                        .ok_or_else(|| anyhow!("candidate: missing mac_reduction"))?,
                    psnr_db: c.get_f64("psnr_db"),
                    validated: c.get("validated").and_then(Json::as_bool).unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PlanFront {
            total_steps: j
                .get_usize("total_steps")
                .ok_or_else(|| anyhow!("plan front: missing total_steps"))?,
            min_mac_reduction: j
                .get_f64("min_mac_reduction")
                .ok_or_else(|| anyhow!("plan front: missing min_mac_reduction"))?,
            min_psnr_db: j.get_f64("min_psnr_db"),
            d_star: j.get_usize("d_star").unwrap_or(0),
            candidates,
        })
    }
}

// --------------------------------------------------------- request results

fn actions_json(actions: &[StepAction]) -> Json {
    // Full -> 0, Partial(l) -> l (valid plans have l >= 1).
    Json::Arr(
        actions
            .iter()
            .map(|a| match a {
                StepAction::Full => Json::num(0.0),
                StepAction::Partial(l) => Json::num(*l as f64),
            })
            .collect(),
    )
}

fn actions_from_json(j: &Json) -> Result<Vec<StepAction>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("gen result: actions not an array"))?
        .iter()
        .map(|v| {
            let l = v.as_usize().ok_or_else(|| anyhow!("gen result: bad action"))?;
            Ok(if l == 0 { StepAction::Full } else { StepAction::Partial(l) })
        })
        .collect()
}

impl Codec for GenResult {
    const NAMESPACE: &'static str = NS_REQUEST;

    fn encode(&self) -> Json {
        Json::obj(vec![
            (
                "dims",
                Json::Arr(self.latent.dims.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            (
                "latent",
                Json::Arr(self.latent.data.iter().map(|&x| Json::num(x as f64)).collect()),
            ),
            ("actions", actions_json(&self.stats.actions)),
            ("step_ms", Json::arr_f64(&self.stats.step_ms)),
            ("mac_reduction", Json::num(self.stats.mac_reduction)),
            ("total_ms", Json::num(self.stats.total_ms)),
        ])
    }

    fn decode(j: &Json) -> Result<GenResult> {
        let dims: Vec<usize> = j
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("gen result: missing dims"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let data: Vec<f32> = j
            .get("latent")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("gen result: missing latent"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow!("gen result: non-numeric latent element"))
            })
            .collect::<Result<Vec<_>>>()?;
        let latent = Tensor::new(dims, data)?;
        let step_ms = j
            .get("step_ms")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default();
        Ok(GenResult {
            latent,
            stats: GenStats {
                actions: actions_from_json(
                    j.get("actions").ok_or_else(|| anyhow!("gen result: missing actions"))?,
                )?,
                step_ms,
                mac_reduction: j.get_f64("mac_reduction").unwrap_or(1.0),
                total_ms: j.get_f64("total_ms").unwrap_or(0.0),
            },
        })
    }
}

/// Encode straight to the compact on-disk text form.
pub fn encode_text<T: Codec>(value: &T) -> String {
    value.encode().to_string()
}

/// Parse + decode the on-disk text form.
pub fn decode_text<T: Codec>(text: &str) -> Result<T> {
    let j = Json::parse(text).map_err(|e| anyhow!("cache payload: {e}"))?;
    T::decode(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::calibrate::analyse;

    #[test]
    fn quant_profile_text_roundtrip() {
        let prof = crate::quant::calibrate::synthetic_profile(
            &crate::models::inventory::sd_tiny(),
            20,
        );
        let back: QuantProfile = decode_text(&encode_text(&prof)).unwrap();
        assert_eq!(back, prof);
        assert!(decode_text::<QuantProfile>("{\"model\":\"x\"}").is_err(), "missing ranges");
    }

    #[test]
    fn calibration_text_roundtrip() {
        let raw: Vec<Vec<f64>> = (0..12)
            .map(|b| (0..19).map(|t| ((b * 19 + t) as f64).sin().abs()).collect())
            .collect();
        let rep = analyse(raw, vec![0.25; 20], 20, 3);
        let back: CalibrationReport = decode_text(&encode_text(&rep)).unwrap();
        assert_eq!(back.d_star, rep.d_star);
        assert_eq!(back.outliers, rep.outliers);
        assert_eq!(back.scores, rep.scores);
        assert_eq!(back.noise, rep.noise);
    }

    #[test]
    fn plan_front_roundtrip_exact() {
        let front = PlanFront {
            total_steps: 50,
            min_mac_reduction: 1.6,
            min_psnr_db: Some(13.0),
            d_star: 21,
            candidates: vec![
                Candidate {
                    cfg: PasConfig { t_sketch: 25, t_complete: 4, t_sparse: 4, l_sketch: 2, l_refine: 2 },
                    mac_reduction: 2.84,
                    psnr_db: Some(14.25),
                    validated: true,
                },
                Candidate {
                    cfg: PasConfig { t_sketch: 30, t_complete: 2, t_sparse: 3, l_sketch: 3, l_refine: 1 },
                    mac_reduction: 2.1,
                    psnr_db: None,
                    validated: false,
                },
            ],
        };
        let back: PlanFront = decode_text(&encode_text(&front)).unwrap();
        assert_eq!(back.total_steps, front.total_steps);
        assert_eq!(back.min_psnr_db, front.min_psnr_db);
        assert_eq!(back.candidates.len(), 2);
        assert_eq!(back.candidates[0].cfg, front.candidates[0].cfg);
        assert_eq!(back.candidates[0].psnr_db, Some(14.25));
        assert!(back.candidates[0].validated);
        assert_eq!(back.candidates[1].psnr_db, None);
        assert_eq!(back.best().unwrap().cfg.t_sketch, 25);
    }

    #[test]
    fn gen_result_roundtrip_exact() {
        let res = GenResult {
            latent: Tensor::new(vec![4, 2], vec![0.5, -1.25, 3.0, 0.1, -0.0, 7.5e-3, 2.0, 9.9])
                .unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(2), StepAction::Partial(1)],
                step_ms: vec![12.5, 3.25, 3.0],
                mac_reduction: 2.5,
                total_ms: 18.75,
            },
        };
        let back: GenResult = decode_text(&encode_text(&res)).unwrap();
        assert_eq!(back.latent.dims, res.latent.dims);
        assert_eq!(back.latent.data, res.latent.data);
        assert_eq!(back.stats.actions, res.stats.actions);
        assert_eq!(back.stats.step_ms, res.stats.step_ms);
        assert_eq!(back.stats.mac_reduction, res.stats.mac_reduction);
    }

    #[test]
    fn truncated_payload_is_an_error_not_a_panic() {
        let res = GenResult {
            latent: Tensor::new(vec![2], vec![1.0, 2.0]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full],
                step_ms: vec![1.0],
                mac_reduction: 1.0,
                total_ms: 1.0,
            },
        };
        let text = encode_text(&res);
        for cut in [0, 1, text.len() / 2, text.len() - 1] {
            assert!(decode_text::<GenResult>(&text[..cut]).is_err());
        }
    }
}
