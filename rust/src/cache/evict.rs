//! Eviction policy: LRU ordering under a byte cap and an entry cap.
//!
//! Pure planning logic, separated from the store so the invariants are
//! property-testable without touching the filesystem: after applying the
//! returned evictions, the retained set never exceeds either cap, and no
//! retained entry is older (by last-use clock) than any evicted one.

use super::key::CacheKey;

/// Index-entry view the planner works over.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EvictEntry {
    pub key: CacheKey,
    pub bytes: u64,
    /// Logical last-use clock (monotonically increasing, larger = newer).
    pub last_used: u64,
}

/// Plan which entries to evict so the retained set satisfies
/// `total_bytes <= max_bytes` and `count <= max_entries`.
///
/// Returns indices into `entries`, least-recently-used first. A single
/// entry larger than `max_bytes` is itself evicted — the byte cap is a
/// hard invariant, never "cap plus one oversized entry".
pub fn plan_evictions(entries: &[EvictEntry], max_bytes: u64, max_entries: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..entries.len()).collect();
    // Oldest first; key as tiebreaker keeps the plan deterministic.
    order.sort_by_key(|&i| (entries[i].last_used, entries[i].key));

    let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
    let mut count = entries.len();
    let mut evict = Vec::new();
    for &i in &order {
        if total <= max_bytes && count <= max_entries {
            break;
        }
        total -= entries[i].bytes;
        count -= 1;
        evict.push(i);
    }
    evict
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(key: u64, bytes: u64, last_used: u64) -> EvictEntry {
        EvictEntry { key: CacheKey(key), bytes, last_used }
    }

    #[test]
    fn under_caps_evicts_nothing() {
        let entries = vec![e(1, 10, 1), e(2, 20, 2)];
        assert!(plan_evictions(&entries, 100, 10).is_empty());
        assert!(plan_evictions(&entries, 30, 2).is_empty(), "exactly at cap is fine");
    }

    #[test]
    fn evicts_lru_first_until_under_byte_cap() {
        // Oldest is key 3 (last_used 1), then 1, then 2.
        let entries = vec![e(1, 40, 5), e(2, 40, 9), e(3, 40, 1)];
        let ev = plan_evictions(&entries, 80, 10);
        assert_eq!(ev, vec![2], "only the oldest needs to go");
        let ev = plan_evictions(&entries, 50, 10);
        assert_eq!(ev, vec![2, 0], "two oldest go, newest stays");
    }

    #[test]
    fn entry_cap_enforced() {
        let entries = vec![e(1, 1, 3), e(2, 1, 1), e(3, 1, 2)];
        let ev = plan_evictions(&entries, 1000, 1);
        assert_eq!(ev, vec![1, 2], "oldest two evicted, newest kept");
    }

    #[test]
    fn oversized_single_entry_is_evicted() {
        let entries = vec![e(1, 500, 1)];
        assert_eq!(plan_evictions(&entries, 100, 10), vec![0]);
    }

    #[test]
    fn zero_cap_clears_everything() {
        let entries = vec![e(1, 10, 1), e(2, 10, 2)];
        assert_eq!(plan_evictions(&entries, 0, 10).len(), 2);
    }
}
