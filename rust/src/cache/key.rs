//! Content-addressed cache keys: a structured FNV-1a 64-bit hasher.
//!
//! Keys are built by feeding *typed, length-delimited* fields into the
//! hasher — never by formatting values into strings — so two different
//! field sequences cannot collide by concatenation (e.g. `("ab", "c")`
//! vs `("a", "bc")`) and float fields hash their exact bit patterns.
//! Every key is salted with the namespace name and the cache format
//! version, so a codec change invalidates old entries instead of
//! misreading them.

use std::fmt;

/// Bump when any namespace's on-disk encoding changes shape.
/// v2: request keys hash the quant scheme; `quant` namespace added.
/// v3: `request` payloads switched from JSON f32 text to the binary
///     latent codec (`cache::binary`); payload files renamed `.bin`.
///     A store written by an older version is flushed clean on open —
///     never scanned in, since its payloads would be misread.
/// v4: request keys hash the approximation-policy id (`crate::policy`
///     seam) — results produced under different policies must never
///     satisfy each other's lookups, and legacy digests retire via this
///     bump rather than silently changing meaning.
pub const CACHE_VERSION: u32 = 4;

/// FNV-1a offset basis (the initial state for [`fnv1a_update`]).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1_0000_0001_b3;

/// Incremental FNV-1a: fold `bytes` into an existing state — THE one
/// implementation of the algorithm in the crate (`fnv1a`, the keyed
/// hasher, and the sim backend's input digests all route through it).
pub fn fnv1a_update(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(FNV_PRIME);
    }
    state
}

/// Raw FNV-1a over a byte slice (also used for the manifest digest).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET, bytes)
}

/// A 64-bit content-addressed key. The hex form names the payload file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey(pub u64);

impl CacheKey {
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }

    pub fn from_hex(s: &str) -> Option<CacheKey> {
        if s.len() != 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(CacheKey)
    }
}

impl fmt::Display for CacheKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Structured field hasher. Field order matters; each field is tagged by
/// its type and (for variable-length data) its length.
#[derive(Debug, Clone)]
pub struct KeyHasher {
    state: u64,
}

impl KeyHasher {
    /// Start a key for `namespace` under the current cache version.
    pub fn new(namespace: &str) -> KeyHasher {
        let mut h = KeyHasher { state: FNV_OFFSET };
        h.raw(&CACHE_VERSION.to_le_bytes());
        h.str(namespace);
        h
    }

    fn raw(&mut self, bytes: &[u8]) {
        self.state = fnv1a_update(self.state, bytes);
    }

    /// Length-prefixed string field.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.raw(&(s.len() as u64).to_le_bytes());
        self.raw(s.as_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.raw(&v.to_le_bytes());
        self
    }

    pub fn usize(&mut self, v: usize) -> &mut Self {
        self.u64(v as u64)
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.raw(&[v as u8]);
        self
    }

    /// Exact bit pattern — no lossy decimal formatting.
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.raw(&v.to_bits().to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.raw(&v.to_bits().to_le_bytes());
        self
    }

    /// Optional field: presence tag then the value.
    pub fn opt_f64(&mut self, v: Option<f64>) -> &mut Self {
        match v {
            Some(x) => self.bool(true).f64(x),
            None => self.bool(false),
        }
    }

    /// Length-prefixed list of strings.
    pub fn str_list(&mut self, xs: &[String]) -> &mut Self {
        self.raw(&(xs.len() as u64).to_le_bytes());
        for s in xs {
            self.str(s);
        }
        self
    }

    /// Length-prefixed list of usize.
    pub fn usize_list(&mut self, xs: &[usize]) -> &mut Self {
        self.raw(&(xs.len() as u64).to_le_bytes());
        for &x in xs {
            self.usize(x);
        }
        self
    }

    pub fn finish(&self) -> CacheKey {
        CacheKey(self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let k = CacheKey(0x0123_4567_89ab_cdef);
        assert_eq!(k.hex(), "0123456789abcdef");
        assert_eq!(CacheKey::from_hex(&k.hex()), Some(k));
        assert_eq!(CacheKey::from_hex("xyz"), None);
        assert_eq!(CacheKey::from_hex("123"), None, "short hex rejected");
    }

    #[test]
    fn field_order_and_type_matter() {
        let a = KeyHasher::new("ns").str("ab").str("c").finish();
        let b = KeyHasher::new("ns").str("a").str("bc").finish();
        assert_ne!(a, b, "length prefixing prevents concat collisions");

        let c = KeyHasher::new("ns").u64(1).u64(2).finish();
        let d = KeyHasher::new("ns").u64(2).u64(1).finish();
        assert_ne!(c, d);
    }

    #[test]
    fn namespace_salts_the_key() {
        let a = KeyHasher::new("calib").u64(7).finish();
        let b = KeyHasher::new("plan").u64(7).finish();
        assert_ne!(a, b);
    }

    #[test]
    fn floats_hash_bit_patterns() {
        let a = KeyHasher::new("ns").f32(7.5).finish();
        let b = KeyHasher::new("ns").f32(7.500001).finish();
        assert_ne!(a, b);
        // -0.0 and 0.0 differ in bits — distinct keys by design.
        assert_ne!(
            KeyHasher::new("ns").f64(0.0).finish(),
            KeyHasher::new("ns").f64(-0.0).finish()
        );
    }

    #[test]
    fn option_presence_is_tagged() {
        let some0 = KeyHasher::new("ns").opt_f64(Some(0.0)).finish();
        let none = KeyHasher::new("ns").opt_f64(None).finish();
        assert_ne!(some0, none);
    }

    #[test]
    fn deterministic_across_hashers() {
        let mk = || {
            KeyHasher::new("req")
                .u64(0xdead_beef)
                .str("red circle x4 y4")
                .usize(50)
                .f32(7.5)
                .finish()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn fnv_reference_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
    }
}
