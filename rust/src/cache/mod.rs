//! Persistent content-addressed plan & artifact cache (S11).
//!
//! SD-Acc's phase-aware sampling only pays off in serving if the
//! expensive one-time work — calibration trajectories (Fig. 4 / Eq. 1-2),
//! Pareto plan search (Fig. 7), and per-prompt generation — is computed
//! once and reused across requests *and process restarts*. This module
//! is that reuse layer:
//!
//! - [`key`]: structured FNV-1a keys over (manifest digest, model meta,
//!   request/config fields) — never lossy string formatting.
//! - [`codec`]: typed value <-> payload bytes for the four namespaces.
//!   Small structured payloads (calibration reports, plan fronts, quant
//!   profiles) stay JSON; request-level generation results use the
//!   length-delimited binary latent codec.
//! - [`binary`]: the versioned binary framing for large latents — raw
//!   little-endian f32 with length prefixes, ≤ 40% of the JSON float
//!   text and bit-exact for NaN/±inf/-0.0.
//! - [`store`]: the on-disk store — atomic write-then-rename index,
//!   crash/corruption recovery by payload scan, version-skew flush (an
//!   older store's payload encodings are never misread), hit/miss/
//!   eviction counters, optional per-namespace TTLs.
//! - [`evict`]: LRU + byte-cap eviction planning (pure, property-tested).
//! - [`namespaces`]: typed keys and the [`Cache`] facade; owns the
//!   invalidation rule (manifest hash change ⇒ namespace flush).
//!
//! Consumers: `pas::calibrate`/`pas::search` memoize through it (warm
//! starts of `examples/calibrate_and_search.rs` become lookups), the
//! server consults the request namespace before enqueueing and feeds
//! hit/miss/eviction counts into `server::metrics`, the coordinator
//! resolves `SamplingPlan::Auto` from the plan namespace, and the
//! `sd-acc cache` CLI subcommand exposes `stats`/`gc`/`clear`.

pub mod binary;
pub mod codec;
pub mod evict;
pub mod key;
pub mod namespaces;
mod proptests;
pub mod store;

pub use codec::{Codec, PlanFront};
pub use key::{CacheKey, KeyHasher, CACHE_VERSION};
pub use namespaces::{Cache, NS_CALIB, NS_PLAN, NS_QUANT, NS_REQUEST};
pub use store::{Store, StoreConfig, StoreStats};

/// Default cache directory: `$SD_ACC_CACHE` or `./cache`.
pub fn default_cache_dir() -> std::path::PathBuf {
    std::env::var("SD_ACC_CACHE")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("cache"))
}
