//! Typed namespaces over the raw store, plus the [`Cache`] facade the
//! rest of the system talks to.
//!
//! Key derivations (all content-addressed, all salted with the AOT
//! manifest digest so artifact rebuilds can never serve stale data):
//!
//! - `calib`:   (manifest, steps, calibration prompts, guidance)
//! - `plan`:    (manifest, total steps, quality target, report digest)
//!              plus a "best plan" summary entry per (manifest, steps)
//!              that `SamplingPlan::Auto` resolution reads
//! - `quant`:   (manifest, steps, calibration prompts, guidance) —
//!              activation-range profiles for mixed-precision search
//! - `request`: (manifest, prompt, seed, steps, sampler, guidance, plan,
//!              quant scheme, approximation-policy id)
//!
//! Invalidation rule: a manifest-hash change on open flushes every
//! namespace (the store records the hash it was populated under).
//! Per-namespace TTLs are configured on the [`StoreConfig`] (default
//! off); the `request` namespace is the intended user — generated
//! latents age out while calibration/search artifacts persist.

use anyhow::Result;

use crate::coordinator::{GenRequest, GenResult};
use crate::obs;
use crate::runtime::BackendKind;
use crate::pas::calibrate::CalibrationReport;
use crate::pas::plan::{PasConfig, SamplingPlan};
use crate::pas::search::SearchConstraints;
use crate::policy::PolicySpec;
use crate::quant::calibrate::QuantProfile;
use crate::quant::format::QuantScheme;

use super::codec::{decode_bytes, encode_bytes, Codec, PlanFront};
use super::key::{CacheKey, KeyHasher};
use super::store::{Store, StoreConfig, StoreStats};

pub const NS_CALIB: &str = "calib";
pub const NS_PLAN: &str = "plan";
pub const NS_QUANT: &str = "quant";
pub const NS_REQUEST: &str = "request";

/// Store-meta key recording which manifest populated the cache.
pub const META_MANIFEST_HASH: &str = "manifest_hash";

// ------------------------------------------------------------------- keys

fn hash_plan(h: &mut KeyHasher, plan: &SamplingPlan) {
    match plan {
        SamplingPlan::Full => {
            h.u64(0);
        }
        SamplingPlan::Pas(cfg) => {
            h.u64(1)
                .usize(cfg.t_sketch)
                .usize(cfg.t_complete)
                .usize(cfg.t_sparse)
                .usize(cfg.l_sketch)
                .usize(cfg.l_refine);
        }
        SamplingPlan::Auto => {
            // Auto is resolved to a concrete plan before cache lookup;
            // hashing the discriminant keeps the function total.
            h.u64(2);
        }
    }
}

/// Calibration-report key.
pub fn calib_key(
    manifest_hash: u64,
    steps: usize,
    prompts: &[String],
    guidance: f32,
) -> CacheKey {
    KeyHasher::new(NS_CALIB)
        .u64(manifest_hash)
        .usize(steps)
        .str_list(prompts)
        .f32(guidance)
        .finish()
}

/// Searched-front key: one cell per (model, steps, quality target,
/// validation prompts, calibration outcome). The prompts matter because
/// the stored `psnr_db`/`validated` fields were measured against them.
pub fn plan_key(
    manifest_hash: u64,
    cons: &SearchConstraints,
    validation_prompts: &[String],
    d_star: usize,
    outliers: &[usize],
) -> CacheKey {
    KeyHasher::new(NS_PLAN)
        .u64(manifest_hash)
        .usize(cons.total_steps)
        .f64(cons.min_mac_reduction)
        .opt_f64(cons.min_psnr_db)
        .usize(cons.max_validate)
        .str_list(validation_prompts)
        .usize(d_star)
        .usize_list(outliers)
        .finish()
}

/// Summary entry consulted by `SamplingPlan::Auto` resolution.
pub fn best_plan_key(manifest_hash: u64, total_steps: usize) -> CacheKey {
    KeyHasher::new(NS_PLAN)
        .u64(manifest_hash)
        .str("best")
        .usize(total_steps)
        .finish()
}

fn hash_quant(h: &mut KeyHasher, quant: &Option<QuantScheme>) {
    match quant {
        None => {
            h.bool(false);
        }
        Some(s) => {
            // Bit widths are unique per format (4/8/16/32).
            h.bool(true).u64(s.weight.bits() as u64).u64(s.act.bits() as u64);
        }
    }
}

fn hash_policy(h: &mut KeyHasher, policy: &PolicySpec) {
    // The label is the policy's stable identity, parameterization
    // included — exactly the `policy_id()` string the built policy
    // reports. Hashing it as one typed string field keeps the standing
    // invariant: every policy id enters every request key.
    h.str(&policy.label());
}

/// Quant-profile key: same cell shape as calibration reports.
pub fn quant_key(
    manifest_hash: u64,
    steps: usize,
    prompts: &[String],
    guidance: f32,
) -> CacheKey {
    KeyHasher::new(NS_QUANT)
        .u64(manifest_hash)
        .usize(steps)
        .str_list(prompts)
        .f32(guidance)
        .finish()
}

/// Request-level result key: everything that determines the latent.
///
/// The sampler hashes as `SamplerKind::as_str` bytes — exactly what
/// the retired `sampler: String` field fed this hasher — so the
/// `String` -> enum migration changed no digest and `CACHE_VERSION`
/// stayed put (the stability property test below locks this in; if a
/// variant's canonical bytes ever change, bump `CACHE_VERSION` so the
/// flush-on-open rule retires old stores).
///
/// The approximation-policy id hashes last (cache format v4): results
/// generated under different policies — including a brownout-degraded
/// policy swap — can never satisfy each other's lookups.
pub fn request_key(manifest_hash: u64, req: &GenRequest) -> CacheKey {
    let mut h = KeyHasher::new(NS_REQUEST);
    h.u64(manifest_hash)
        .str(&req.prompt)
        .u64(req.seed)
        .usize(req.steps)
        .str(req.sampler.as_str())
        .f32(req.guidance);
    hash_plan(&mut h, &req.plan);
    hash_quant(&mut h, &req.quant);
    hash_policy(&mut h, &req.policy);
    h.finish()
}

/// Backend salt applied to the manifest digest before *every* key
/// derivation. **Digest-stability rule:** the xla path (and `Auto`,
/// which the runtime service grounds before any cache exists) returns
/// the digest untouched — every pre-existing entry in every namespace
/// still hits and `CACHE_VERSION` did not move with the backend
/// redesign. The sim backend mixes in a fixed tag, which makes ALL
/// namespaces disjoint from the xla path's entries — not just
/// `request`: calibration shift-scores, searched plans and activation
/// ranges are measurements *of the executor's numerics*, not of the
/// manifest alone, so sim-measured data must never resolve an xla
/// lookup (and vice versa) even when the sim ran over the same real
/// manifest.json.
pub fn backend_salted_hash(manifest_hash: u64, backend: BackendKind) -> u64 {
    match backend {
        BackendKind::Xla | BackendKind::Auto => manifest_hash,
        BackendKind::Sim => {
            let mut bytes = [0u8; 19];
            bytes[..8].copy_from_slice(&manifest_hash.to_le_bytes());
            bytes[8..].copy_from_slice(b"backend:sim");
            crate::cache::key::fnv1a(&bytes)
        }
    }
}

/// Backend-aware request key: the legacy [`request_key`] derivation over
/// the backend-salted digest (xla keys are byte-identical to the
/// pre-seam era; sim keys are disjoint).
pub fn request_key_for(manifest_hash: u64, backend: BackendKind, req: &GenRequest) -> CacheKey {
    request_key(backend_salted_hash(manifest_hash, backend), req)
}

// ------------------------------------------------------------------ facade

/// The typed cache: a [`Store`] bound to one manifest generation and
/// one execution backend. Every key derivation — all four namespaces —
/// goes through the backend-salted digest ([`backend_salted_hash`]), so
/// sim-backend entries can never satisfy xla lookups; the *flush* rule
/// stays anchored on the raw manifest digest, so the two backends can
/// share one store without clobbering each other on open.
pub struct Cache {
    store: Store,
    /// Raw manifest digest: the flush-on-open anchor.
    manifest_hash: u64,
    /// Backend-salted digest: what every key derivation hashes.
    key_hash: u64,
    backend: BackendKind,
}

impl std::fmt::Debug for Cache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let hash = format!("{:016x}", self.manifest_hash);
        f.debug_struct("Cache")
            .field("dir", &self.store.dir())
            .field("manifest_hash", &hash)
            .field("backend", &self.backend.as_str())
            .finish()
    }
}

impl Cache {
    /// Open the cache for a given manifest digest over the **xla**
    /// backend (the legacy construction — keys are byte-identical to
    /// every release since the `SamplerKind` migration). If the store
    /// was populated under a different manifest, every namespace is
    /// flushed before use (the invalidation rule).
    ///
    /// **Do not call this with a live coordinator/runtime in hand** —
    /// a sim-resolved runtime opened through here would store sim
    /// numerics under untagged xla keys, exactly the cross-backend
    /// poisoning the salting prevents. Use
    /// [`Coordinator::open_cache`](crate::coordinator::Coordinator::open_cache)
    /// (which supplies digest + kind from the running backend) or
    /// [`Cache::open_for`]; this constructor exists for xla-tagged
    /// fixtures and offline maintenance (`sd-acc cache`), where no
    /// executor is running.
    pub fn open(cfg: StoreConfig, manifest_hash: u64) -> Result<Cache> {
        Self::open_for(cfg, manifest_hash, BackendKind::Xla)
    }

    /// Open the cache for a given manifest digest and execution backend.
    /// Prefer [`Coordinator::open_cache`](crate::coordinator::Coordinator::open_cache),
    /// which supplies both from the live runtime.
    pub fn open_for(cfg: StoreConfig, manifest_hash: u64, backend: BackendKind) -> Result<Cache> {
        let store = Store::open(cfg)?;
        let hash_hex = format!("{manifest_hash:016x}");
        if store.meta(META_MANIFEST_HASH).as_deref() != Some(hash_hex.as_str()) {
            store.clear(None);
            store.set_meta(META_MANIFEST_HASH, &hash_hex)?;
        }
        Ok(Cache {
            store,
            manifest_hash,
            key_hash: backend_salted_hash(manifest_hash, backend),
            backend,
        })
    }

    pub fn manifest_hash(&self) -> u64 {
        self.manifest_hash
    }

    /// The backend whose results this cache stores/serves.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Decode a stored payload; undecodable entries self-heal (removed).
    ///
    /// Observability chokepoint: every typed lookup bumps the
    /// per-namespace hit/miss counters and, inside a [`TraceScope`],
    /// records a `cache-lookup` span attributed to the scope's job (a
    /// self-healed corrupt entry counts as a miss).
    ///
    /// [`TraceScope`]: crate::obs::TraceScope
    fn get_typed<T: Codec>(&self, key: CacheKey) -> Option<T> {
        let t0 = std::time::Instant::now();
        let out = self.store.get(T::NAMESPACE, key).and_then(|bytes| {
            match decode_bytes(&bytes) {
                Ok(v) => Some(v),
                Err(_) => {
                    self.store.remove(T::NAMESPACE, key);
                    None
                }
            }
        });
        let hit = out.is_some();
        if hit {
            obs::counters().cache_hit(T::NAMESPACE);
        } else {
            obs::counters().cache_miss(T::NAMESPACE);
        }
        obs::with_current(|sink, job| {
            sink.record(
                obs::SpanEvent::new(job, obs::Phase::CacheLookup)
                    .with_namespace(T::NAMESPACE)
                    .with_hit(hit)
                    .with_dur_us(t0.elapsed().as_micros() as u64),
            );
        });
        out
    }

    /// Observability chokepoint mirroring [`Cache::get_typed`]: counts
    /// evictions per namespace and records a `cache-write` span.
    fn put_typed<T: Codec>(&self, key: CacheKey, value: &T) -> Result<usize> {
        let payload = encode_bytes(value);
        let bytes = payload.len() as u64;
        let res = self.store.put(T::NAMESPACE, key, &payload);
        if let Ok(evicted) = &res {
            obs::counters().cache_evictions(T::NAMESPACE, *evicted as u64);
        }
        obs::with_current(|sink, job| {
            sink.record(
                obs::SpanEvent::new(job, obs::Phase::CacheWrite)
                    .with_namespace(T::NAMESPACE)
                    .with_bytes(bytes),
            );
        });
        res
    }

    // ------------------------------------------------------------ calib

    pub fn get_calibration(
        &self,
        steps: usize,
        prompts: &[String],
        guidance: f32,
    ) -> Option<CalibrationReport> {
        self.get_typed(calib_key(self.key_hash, steps, prompts, guidance))
    }

    pub fn put_calibration(
        &self,
        steps: usize,
        prompts: &[String],
        guidance: f32,
        report: &CalibrationReport,
    ) -> Result<usize> {
        self.put_typed(calib_key(self.key_hash, steps, prompts, guidance), report)
    }

    // ------------------------------------------------------------ quant

    pub fn get_quant_profile(
        &self,
        steps: usize,
        prompts: &[String],
        guidance: f32,
    ) -> Option<QuantProfile> {
        self.get_typed(quant_key(self.key_hash, steps, prompts, guidance))
    }

    pub fn put_quant_profile(
        &self,
        steps: usize,
        prompts: &[String],
        guidance: f32,
        profile: &QuantProfile,
    ) -> Result<usize> {
        self.put_typed(quant_key(self.key_hash, steps, prompts, guidance), profile)
    }

    // ------------------------------------------------------------- plan

    pub fn get_plan_front(
        &self,
        cons: &SearchConstraints,
        validation_prompts: &[String],
        d_star: usize,
        outliers: &[usize],
    ) -> Option<PlanFront> {
        self.get_typed(plan_key(self.key_hash, cons, validation_prompts, d_star, outliers))
    }

    /// Store a searched front; also refreshes the per-steps "best plan"
    /// summary that [`Cache::best_plan`] serves. Callers only store
    /// fronts that satisfied their quality target (see
    /// `Searcher::search_cached`).
    pub fn put_plan_front(
        &self,
        cons: &SearchConstraints,
        validation_prompts: &[String],
        d_star: usize,
        outliers: &[usize],
        front: &PlanFront,
    ) -> Result<usize> {
        let mut evicted = self.put_typed(
            plan_key(self.key_hash, cons, validation_prompts, d_star, outliers),
            front,
        )?;
        if !front.candidates.is_empty() {
            let summary = PlanFront {
                candidates: front.candidates.iter().take(1).cloned().collect(),
                ..front.clone()
            };
            let summary_evicted = self.store.put(
                NS_PLAN,
                best_plan_key(self.key_hash, front.total_steps),
                &encode_bytes(&summary),
            )?;
            obs::counters().cache_evictions(NS_PLAN, summary_evicted as u64);
            evicted += summary_evicted;
        }
        Ok(evicted)
    }

    /// Best known PAS configuration for this (manifest, steps) cell —
    /// what `SamplingPlan::Auto` resolves to.
    pub fn best_plan(&self, total_steps: usize) -> Option<PasConfig> {
        let front: PlanFront =
            self.get_typed(best_plan_key(self.key_hash, total_steps))?;
        front.best().map(|c| c.cfg)
    }

    // ---------------------------------------------------------- request

    pub fn get_result(&self, req: &GenRequest) -> Option<GenResult> {
        self.get_typed(request_key(self.key_hash, req))
    }

    /// Request results flush the index eagerly (not after
    /// `PERSIST_EVERY` buffered puts): a sibling `serve --listen`
    /// process sharing this store directory must be able to hit this
    /// entry as soon as the put returns — the cross-process warm-hit
    /// guarantee the wire tier's CI lane asserts.
    pub fn put_result(&self, req: &GenRequest, result: &GenResult) -> Result<usize> {
        let evicted = self.put_typed(request_key(self.key_hash, req), result)?;
        self.store.flush()?;
        Ok(evicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenStats;
    use crate::pas::calibrate::analyse;
    use crate::pas::plan::StepAction;
    use crate::pas::search::Candidate;
    use crate::runtime::Tensor;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sdacc_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_report() -> CalibrationReport {
        let raw: Vec<Vec<f64>> = (0..12)
            .map(|b| (0..19).map(|t| ((b + t) as f64 * 0.37).cos().abs()).collect())
            .collect();
        analyse(raw, vec![0.5; 20], 20, 2)
    }

    fn sample_result() -> GenResult {
        GenResult {
            latent: Tensor::new(vec![2, 2], vec![0.25, -1.5, 3.75, 0.125]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(2)],
                step_ms: vec![5.0, 2.5],
                mac_reduction: 1.8,
                total_ms: 7.5,
            },
        }
    }

    /// The acceptance property for the `String` -> `SamplerKind`
    /// migration: for every reachable request, the new enum-based key
    /// equals the key a string sampler field would produce, byte for
    /// byte. The "legacy" derivation mirrors the current field order
    /// (policy axis included — the v4 policy field is orthogonal to the
    /// sampler slot this property guards) with `.str(<sampler string>)`
    /// in the sampler slot.
    #[test]
    fn request_key_digests_stable_across_sampler_enum_migration() {
        use crate::coordinator::SamplerKind;
        use crate::quant::format::QuantScheme;
        use crate::testing::{check_no_shrink, gen_usize};

        fn legacy_request_key(manifest_hash: u64, sampler: &str, req: &GenRequest) -> CacheKey {
            let mut h = KeyHasher::new(NS_REQUEST);
            h.u64(manifest_hash)
                .str(&req.prompt)
                .u64(req.seed)
                .usize(req.steps)
                .str(sampler)
                .f32(req.guidance);
            hash_plan(&mut h, &req.plan);
            hash_quant(&mut h, &req.quant);
            hash_policy(&mut h, &req.policy);
            h.finish()
        }

        /// The literal strings the retired `String` field carried —
        /// deliberately NOT `as_str()`, so a change to a variant's
        /// canonical bytes *fails* this property instead of being
        /// absorbed into both sides of the comparison.
        fn legacy_name(kind: SamplerKind) -> &'static str {
            match kind {
                SamplerKind::Ddim => "ddim",
                SamplerKind::Pndm => "pndm",
            }
        }

        check_no_shrink(
            "cache-request-key-sampler-migration",
            |rng| {
                let words = ["red", "blue", "circle", "square", "x4", "y11", ""];
                let prompt = (0..gen_usize(rng, 1, 4))
                    .map(|_| words[gen_usize(rng, 0, words.len() - 1)])
                    .collect::<Vec<_>>()
                    .join(" ");
                let mut req = GenRequest::new(&prompt, rng.next_u64());
                req.steps = gen_usize(rng, 1, 100);
                req.sampler =
                    SamplerKind::ALL[gen_usize(rng, 0, SamplerKind::ALL.len() - 1)];
                req.guidance = (rng.next_f32() - 0.5) * 30.0;
                req.plan = match gen_usize(rng, 0, 2) {
                    0 => SamplingPlan::Full,
                    1 => SamplingPlan::Pas(PasConfig {
                        t_sketch: gen_usize(rng, 1, 50),
                        t_complete: gen_usize(rng, 1, 8),
                        t_sparse: gen_usize(rng, 2, 8),
                        l_sketch: gen_usize(rng, 1, 4),
                        l_refine: gen_usize(rng, 1, 4),
                    }),
                    _ => SamplingPlan::Auto,
                };
                req.quant = match gen_usize(rng, 0, 4) {
                    0 => Some(QuantScheme::w8a8()),
                    1 => Some(QuantScheme::w4a8()),
                    2 => Some(QuantScheme::fp16()),
                    _ => None,
                };
                req.policy = match gen_usize(rng, 0, 3) {
                    0 => PolicySpec::BlockCache { budget: gen_usize(rng, 1, 8) },
                    1 => PolicySpec::Stability {
                        threshold_milli: gen_usize(rng, 1, 2000) as u32,
                    },
                    2 => PolicySpec::TextPrecision,
                    _ => PolicySpec::Pas,
                };
                (rng.next_u64(), req)
            },
            |(manifest_hash, req)| {
                let old = legacy_request_key(*manifest_hash, legacy_name(req.sampler), req);
                request_key(*manifest_hash, req) == old
            },
        );
        // And the two legacy sampler strings map to *different* keys —
        // the enum did not collapse the sampler axis.
        let mut a = GenRequest::new("p", 1);
        a.sampler = SamplerKind::Ddim;
        let mut b = GenRequest::new("p", 1);
        b.sampler = SamplerKind::Pndm;
        assert_ne!(request_key(1, &a), request_key(1, &b));
        assert_eq!(legacy_request_key(1, "ddim", &a), request_key(1, &a));
        assert_eq!(legacy_request_key(1, "pndm", &b), request_key(1, &b));
    }

    /// The backend-tagging acceptance rule: xla keys are byte-identical
    /// to the untagged legacy derivation (no `CACHE_VERSION` bump, every
    /// old entry still hits), sim keys are disjoint, and inside one
    /// shared store a sim-produced latent can never satisfy an xla
    /// lookup or vice versa.
    #[test]
    fn sim_and_xla_request_caches_are_disjoint() {
        let req = GenRequest::new("red circle x4 y4", 42);
        // Key level: xla == legacy, sim != xla.
        assert_eq!(
            request_key_for(7, BackendKind::Xla, &req),
            request_key(7, &req),
            "xla path must keep every legacy digest"
        );
        assert_eq!(
            request_key_for(7, BackendKind::Auto, &req),
            request_key(7, &req),
            "Auto hashes as xla (it is grounded before any cache exists)"
        );
        assert_ne!(
            request_key_for(7, BackendKind::Sim, &req),
            request_key(7, &req),
            "sim latents must never land on an xla key"
        );

        // Facade level: one shared store, same manifest hash (no flush),
        // two backend bindings.
        let dir = tmp_dir("backend_tag");
        let sim = Cache::open_for(StoreConfig::new(&dir), 9, BackendKind::Sim).unwrap();
        sim.put_result(&req, &sample_result()).unwrap();
        assert!(sim.get_result(&req).is_some(), "sim sees its own entry");
        drop(sim);
        let xla = Cache::open(StoreConfig::new(&dir), 9).unwrap();
        assert!(
            xla.get_result(&req).is_none(),
            "an xla lookup must not be satisfied by a sim latent"
        );
        xla.put_result(&req, &sample_result()).unwrap();
        drop(xla);
        let sim = Cache::open_for(StoreConfig::new(&dir), 9, BackendKind::Sim).unwrap();
        assert!(sim.get_result(&req).is_some(), "sim entry survived the xla session");
        assert_eq!(sim.stats().entries, 2, "both backends coexist in one store");

        // The measurement namespaces are backend-tagged too: shift
        // scores / plans / activation ranges measure the executor's
        // numerics, so a sim-measured calibration must not resolve an
        // xla lookup even over the same manifest digest.
        let prompts = vec!["red circle x4 y4".to_string()];
        sim.put_calibration(20, &prompts, 7.5, &sample_report()).unwrap();
        drop(sim);
        let xla = Cache::open(StoreConfig::new(&dir), 9).unwrap();
        assert!(
            xla.get_calibration(20, &prompts, 7.5).is_none(),
            "sim-measured calibration must be invisible to the xla binding"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_key_separates_every_field() {
        let base = GenRequest::new("red circle x4 y4", 42);
        let k0 = request_key(1, &base);
        let mut r = base.clone();
        r.seed = 43;
        assert_ne!(request_key(1, &r), k0, "seed");
        let mut r = base.clone();
        r.steps = 49;
        assert_ne!(request_key(1, &r), k0, "steps");
        let mut r = base.clone();
        r.sampler = "ddim".into();
        assert_ne!(request_key(1, &r), k0, "sampler");
        let mut r = base.clone();
        r.guidance = 7.0;
        assert_ne!(request_key(1, &r), k0, "guidance");
        let mut r = base.clone();
        r.plan = SamplingPlan::Pas(PasConfig::pas25(4));
        assert_ne!(request_key(1, &r), k0, "plan");
        let mut r = base.clone();
        r.quant = Some(QuantScheme::w8a8());
        let k_w8 = request_key(1, &r);
        assert_ne!(k_w8, k0, "quant scheme");
        r.quant = Some(QuantScheme::w4a8());
        assert_ne!(request_key(1, &r), k_w8, "different schemes differ");
        let mut r = base.clone();
        r.policy = PolicySpec::Stability { threshold_milli: 250 };
        let k_stab = request_key(1, &r);
        assert_ne!(k_stab, k0, "policy");
        r.policy = PolicySpec::Stability { threshold_milli: 100 };
        assert_ne!(request_key(1, &r), k_stab, "policy parameterizations differ");
        assert_ne!(request_key(2, &base), k0, "manifest hash");
        assert_eq!(request_key(1, &base.clone()), k0, "identical request hits");
    }

    /// Every registry policy (and the brownout-swap target) keys its
    /// own cache cell: same request, different policy -> different
    /// digest, and the default spec reproduces the bare-request key.
    #[test]
    fn request_key_isolates_every_policy() {
        use std::collections::HashSet;
        let base = GenRequest::new("red circle x4 y4", 42);
        let mut keys = HashSet::new();
        for spec in PolicySpec::all() {
            let mut r = base.clone();
            r.policy = spec;
            assert!(keys.insert(request_key(1, &r)), "{} collided", spec.label());
        }
        assert_eq!(keys.len(), PolicySpec::all().len());
        assert!(
            keys.contains(&request_key(1, &base)),
            "default Pas spec must key the same cell as an untouched request"
        );
    }

    #[test]
    fn all_three_namespaces_roundtrip_through_cache() {
        let cache = Cache::open(StoreConfig::new(tmp_dir("ns3")), 0xabc).unwrap();

        let prompts = vec!["red circle x4 y4".to_string()];
        let rep = sample_report();
        assert!(cache.get_calibration(20, &prompts, 7.5).is_none());
        cache.put_calibration(20, &prompts, 7.5, &rep).unwrap();
        let back = cache.get_calibration(20, &prompts, 7.5).unwrap();
        assert_eq!(back.d_star, rep.d_star);
        assert_eq!(back.scores, rep.scores);

        let cons = SearchConstraints::default();
        let front = PlanFront {
            total_steps: cons.total_steps,
            min_mac_reduction: cons.min_mac_reduction,
            min_psnr_db: cons.min_psnr_db,
            d_star: rep.d_star,
            candidates: vec![Candidate {
                cfg: PasConfig::pas25(4),
                mac_reduction: 2.8,
                psnr_db: None,
                validated: false,
            }],
        };
        cache.put_plan_front(&cons, &prompts, rep.d_star, &rep.outliers, &front).unwrap();
        let back = cache.get_plan_front(&cons, &prompts, rep.d_star, &rep.outliers).unwrap();
        assert_eq!(back.candidates[0].cfg, PasConfig::pas25(4));
        assert_eq!(cache.best_plan(cons.total_steps), Some(PasConfig::pas25(4)));
        assert_eq!(cache.best_plan(cons.total_steps + 1), None);
        // Different validation prompts are a different front cell.
        let other = vec!["blue square x2 y2".to_string()];
        assert!(cache.get_plan_front(&cons, &other, rep.d_star, &rep.outliers).is_none());

        let req = GenRequest::new("blue square x2 y2", 7);
        let res = sample_result();
        assert!(cache.get_result(&req).is_none());
        cache.put_result(&req, &res).unwrap();
        let back = cache.get_result(&req).unwrap();
        assert_eq!(back.latent.data(), res.latent.data());
        assert_eq!(back.stats.actions, res.stats.actions);
    }

    #[test]
    fn manifest_hash_change_flushes_all_namespaces() {
        let dir = tmp_dir("flush");
        {
            let cache = Cache::open(StoreConfig::new(&dir), 1).unwrap();
            cache.put_result(&GenRequest::new("x", 1), &sample_result()).unwrap();
            cache
                .put_calibration(20, &["p".to_string()], 7.5, &sample_report())
                .unwrap();
            assert_eq!(cache.stats().entries, 2);
        }
        // Same hash: entries survive the reopen.
        {
            let cache = Cache::open(StoreConfig::new(&dir), 1).unwrap();
            assert_eq!(cache.stats().entries, 2);
        }
        // New hash: everything flushed.
        let cache = Cache::open(StoreConfig::new(&dir), 2).unwrap();
        assert_eq!(cache.stats().entries, 0);
        assert!(cache.get_result(&GenRequest::new("x", 1)).is_none());
    }

    #[test]
    fn quant_namespace_roundtrips_and_flushes_with_manifest() {
        let dir = tmp_dir("quantns");
        let prompts = vec!["red circle x4 y4".to_string()];
        let prof = crate::quant::calibrate::synthetic_profile(
            &crate::models::inventory::sd_tiny(),
            20,
        );
        {
            let cache = Cache::open(StoreConfig::new(&dir), 7).unwrap();
            assert!(cache.get_quant_profile(20, &prompts, 7.5).is_none());
            cache.put_quant_profile(20, &prompts, 7.5, &prof).unwrap();
            let back = cache.get_quant_profile(20, &prompts, 7.5).unwrap();
            assert_eq!(back, prof);
            // Different steps / prompts are different cells.
            assert!(cache.get_quant_profile(21, &prompts, 7.5).is_none());
            assert!(cache
                .get_quant_profile(20, &["other".to_string()], 7.5)
                .is_none());
        }
        // Same manifest: profile survives the reopen.
        {
            let cache = Cache::open(StoreConfig::new(&dir), 7).unwrap();
            assert!(cache.get_quant_profile(20, &prompts, 7.5).is_some());
        }
        // Manifest hash change: the quant namespace flushes with the rest.
        let cache = Cache::open(StoreConfig::new(&dir), 8).unwrap();
        assert!(cache.get_quant_profile(20, &prompts, 7.5).is_none());
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn request_ttl_expires_results_but_not_other_namespaces() {
        // TTL 0 = expire immediately (the test knob); default is off.
        let cfg = StoreConfig::new(tmp_dir("ttl")).with_ttl(NS_REQUEST, 0);
        let cache = Cache::open(cfg, 3).unwrap();
        let req = GenRequest::new("ephemeral", 1);
        cache.put_result(&req, &sample_result()).unwrap();
        cache
            .put_calibration(20, &["p".to_string()], 7.5, &sample_report())
            .unwrap();
        assert!(cache.get_result(&req).is_none(), "request entry expired");
        assert!(
            cache.get_calibration(20, &["p".to_string()], 7.5).is_some(),
            "calib namespace has no TTL"
        );
        // The expired entry is gone from the store, not just hidden.
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn corrupt_payload_self_heals() {
        let cache = Cache::open(StoreConfig::new(tmp_dir("heal")), 5).unwrap();
        let req = GenRequest::new("y", 9);
        cache.put_result(&req, &sample_result()).unwrap();
        // Clobber the payload with bytes that are not a binary GenResult.
        let key = request_key(5, &req);
        cache.store().put(NS_REQUEST, key, b"{\"not\":\"a result\"}").unwrap();
        assert!(cache.get_result(&req).is_none());
        // Entry was dropped, not left poisoned.
        assert!(cache.store().get(NS_REQUEST, key).is_none());
    }
}
