//! Property tests over cache invariants (in-tree framework,
//! rust/src/testing): codec round-trips must be the identity for every
//! namespace (the binary request codec bit-exactly, non-finite values
//! included), binary and JSON encodings must agree semantically for
//! finite latents, eviction must never breach the byte cap and must
//! respect LRU order, and no on-disk corruption may panic the store.

#![cfg(test)]

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::cache::codec::{
    decode_bytes, encode_bytes, gen_result_from_json_v2, gen_result_to_json_v2, PlanFront,
};
use crate::cache::evict::{plan_evictions, EvictEntry};
use crate::cache::key::CacheKey;
use crate::cache::store::{Store, StoreConfig};
use crate::coordinator::{GenResult, GenStats};
use crate::pas::calibrate::CalibrationReport;
use crate::pas::plan::{PasConfig, StepAction};
use crate::pas::search::Candidate;
use crate::runtime::Tensor;
use crate::testing::{check_no_shrink, gen_usize};
use crate::util::rng::Pcg32;

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Fresh scratch dir per property case.
fn case_dir(tag: &str) -> PathBuf {
    let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "sdacc_cacheprop_{tag}_{}_{n}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ------------------------------------------------------- codec round-trips

fn gen_report(rng: &mut Pcg32) -> CalibrationReport {
    let steps = gen_usize(rng, 4, 40);
    let t1 = steps - 1;
    let blocks = gen_usize(rng, 1, 12);
    CalibrationReport {
        scores: (0..blocks)
            .map(|_| (0..t1).map(|_| rng.next_f64()).collect())
            .collect(),
        noise: (0..steps).map(|_| rng.next_f64() * 10.0 - 5.0).collect(),
        d_star: gen_usize(rng, 1, t1),
        outliers: (0..gen_usize(rng, 0, 3)).map(|_| gen_usize(rng, 1, 12)).collect(),
        steps,
        prompts: gen_usize(rng, 1, 8),
    }
}

#[test]
fn calibration_codec_roundtrip_is_identity() {
    check_no_shrink("cache-codec-calib", gen_report, |rep| {
        let back: CalibrationReport = match decode_bytes(&encode_bytes(rep)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        back.scores == rep.scores
            && back.noise == rep.noise
            && back.d_star == rep.d_star
            && back.outliers == rep.outliers
            && back.steps == rep.steps
            && back.prompts == rep.prompts
    });
}

fn gen_front(rng: &mut Pcg32) -> PlanFront {
    let n = gen_usize(rng, 0, 6);
    PlanFront {
        total_steps: gen_usize(rng, 8, 100),
        min_mac_reduction: rng.next_f64() * 3.0,
        min_psnr_db: if rng.bernoulli(0.5) { Some(rng.next_f64() * 30.0) } else { None },
        d_star: gen_usize(rng, 1, 50),
        candidates: (0..n)
            .map(|_| Candidate {
                cfg: PasConfig {
                    t_sketch: gen_usize(rng, 1, 100),
                    t_complete: gen_usize(rng, 1, 8),
                    t_sparse: gen_usize(rng, 2, 8),
                    l_sketch: gen_usize(rng, 1, 4),
                    l_refine: gen_usize(rng, 1, 4),
                },
                mac_reduction: rng.next_f64() * 4.0,
                psnr_db: if rng.bernoulli(0.5) { Some(rng.next_f64() * 40.0) } else { None },
                validated: rng.bernoulli(0.5),
            })
            .collect(),
    }
}

#[test]
fn plan_front_codec_roundtrip_is_identity() {
    check_no_shrink("cache-codec-plan", gen_front, |front| {
        let back: PlanFront = match decode_bytes(&encode_bytes(front)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        back.total_steps == front.total_steps
            && back.min_mac_reduction == front.min_mac_reduction
            && back.min_psnr_db == front.min_psnr_db
            && back.d_star == front.d_star
            && back.candidates.len() == front.candidates.len()
            && back.candidates.iter().zip(&front.candidates).all(|(a, b)| {
                a.cfg == b.cfg
                    && a.mac_reduction == b.mac_reduction
                    && a.psnr_db == b.psnr_db
                    && a.validated == b.validated
            })
    });
}

/// Random finite latent values (the JSON-comparable regime).
fn gen_result(rng: &mut Pcg32) -> GenResult {
    let steps = gen_usize(rng, 1, 12);
    let l = gen_usize(rng, 1, 32);
    let c = gen_usize(rng, 1, 4);
    GenResult {
        latent: Tensor::new(
            vec![l, c],
            (0..l * c).map(|_| (rng.next_f32() - 0.5) * 8.0).collect(),
        )
        .expect("dims match"),
        stats: GenStats {
            actions: (0..steps)
                .map(|_| {
                    if rng.bernoulli(0.4) {
                        StepAction::Full
                    } else {
                        StepAction::Partial(gen_usize(rng, 1, 4))
                    }
                })
                .collect(),
            step_ms: (0..steps).map(|_| rng.next_f64() * 100.0).collect(),
            mac_reduction: 1.0 + rng.next_f64() * 3.0,
            total_ms: rng.next_f64() * 1000.0,
        },
    }
}

/// The same, with non-finite and signed-zero specials sprinkled in —
/// values the retired JSON encoding could not carry at all.
fn gen_result_with_specials(rng: &mut Pcg32) -> GenResult {
    let mut res = gen_result(rng);
    let specials = [
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0f32,
        f32::from_bits(0x7fc1_2345), // NaN with payload bits
        f32::MIN_POSITIVE / 4.0,     // subnormal
    ];
    let n = res.latent.len();
    let buf = res.latent.make_mut();
    for _ in 0..gen_usize(rng, 1, n.min(6)) {
        let at = gen_usize(rng, 0, n - 1);
        buf[at] = specials[gen_usize(rng, 0, specials.len() - 1)];
    }
    res
}

fn latent_bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

#[test]
fn gen_result_codec_roundtrip_is_identity() {
    check_no_shrink("cache-codec-genresult", gen_result, |res| {
        let back: GenResult = match decode_bytes(&encode_bytes(res)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        back.latent.dims == res.latent.dims
            && back.latent.data() == res.latent.data()
            && back.stats.actions == res.stats.actions
            && back.stats.step_ms == res.stats.step_ms
            && back.stats.mac_reduction == res.stats.mac_reduction
            && back.stats.total_ms == res.stats.total_ms
    });
}

/// Binary round-trips are bit-exact even for NaN (payload bits and all),
/// ±inf, -0.0 and subnormals — `==` would be false for NaN, so this
/// property compares bit patterns.
#[test]
fn gen_result_binary_roundtrip_preserves_nonfinite_bits() {
    check_no_shrink("cache-codec-genresult-specials", gen_result_with_specials, |res| {
        let back: GenResult = match decode_bytes(&encode_bytes(res)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        back.latent.dims == res.latent.dims
            && latent_bits(&back.latent) == latent_bits(&res.latent)
            && back.stats.actions == res.stats.actions
    });
}

/// For finite latents the binary codec and the retired v2 JSON encoding
/// decode to the same value, bit for bit — the byte format changed, the
/// semantics did not.
#[test]
fn gen_result_binary_equals_json_semantics() {
    check_no_shrink("cache-codec-genresult-vs-json", gen_result, |res| {
        let via_bin: GenResult = match decode_bytes(&encode_bytes(res)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        let via_json = match gen_result_from_json_v2(&gen_result_to_json_v2(res)) {
            Ok(b) => b,
            Err(_) => return false,
        };
        via_bin.latent.dims == via_json.latent.dims
            && latent_bits(&via_bin.latent) == latent_bits(&via_json.latent)
            && via_bin.stats.actions == via_json.stats.actions
            && via_bin.stats.step_ms == via_json.stats.step_ms
            && via_bin.stats.mac_reduction == via_json.stats.mac_reduction
            && via_bin.stats.total_ms == via_json.stats.total_ms
    });
}

// ----------------------------------------------------- eviction invariants

fn gen_evict_case(rng: &mut Pcg32) -> (Vec<EvictEntry>, u64, usize) {
    let n = gen_usize(rng, 0, 24);
    // Distinct last_used clocks in random order.
    let mut clocks: Vec<u64> = (1..=n as u64).collect();
    rng.shuffle(&mut clocks);
    let entries: Vec<EvictEntry> = (0..n)
        .map(|i| EvictEntry {
            key: CacheKey(rng.next_u64()),
            bytes: gen_usize(rng, 0, 64) as u64,
            last_used: clocks[i],
        })
        .collect();
    let max_bytes = gen_usize(rng, 0, 600) as u64;
    let max_entries = gen_usize(rng, 0, 30);
    (entries, max_bytes, max_entries)
}

#[test]
fn eviction_caps_and_lru_order_hold() {
    check_no_shrink("cache-evict-invariants", gen_evict_case, |(entries, max_bytes, max_entries)| {
        let plan = plan_evictions(entries, *max_bytes, *max_entries);
        // No duplicate or out-of-range indices.
        let mut seen = std::collections::BTreeSet::new();
        for &i in &plan {
            if i >= entries.len() || !seen.insert(i) {
                return false;
            }
        }
        let retained: Vec<&EvictEntry> = entries
            .iter()
            .enumerate()
            .filter(|(i, _)| !seen.contains(i))
            .map(|(_, e)| e)
            .collect();
        // Caps are hard invariants.
        let total: u64 = retained.iter().map(|e| e.bytes).sum();
        if total > *max_bytes || retained.len() > *max_entries {
            return false;
        }
        // LRU order: every evicted entry is older than every retained one
        // (clocks are distinct by construction).
        let newest_evicted = plan.iter().map(|&i| entries[i].last_used).max();
        let oldest_retained = retained.iter().map(|e| e.last_used).min();
        if let (Some(ev), Some(ret)) = (newest_evicted, oldest_retained) {
            if ev >= ret {
                return false;
            }
        }
        // Minimality: dropping the last eviction must re-violate a cap.
        if let Some(&last) = plan.last() {
            let total_with_last = total + entries[last].bytes;
            if total_with_last <= *max_bytes && retained.len() + 1 <= *max_entries {
                return false;
            }
        }
        true
    });
}

#[test]
fn store_byte_cap_never_exceeded_under_random_workload() {
    check_no_shrink(
        "cache-store-byte-cap",
        |rng| {
            let cap = gen_usize(rng, 8, 200) as u64;
            let ops: Vec<(u64, usize)> = (0..gen_usize(rng, 1, 20))
                .map(|_| (rng.gen_range(0, 6), gen_usize(rng, 2, 60)))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let dir = case_dir("cap");
            let store = Store::open(StoreConfig::new(&dir).with_max_bytes(*cap)).unwrap();
            let mut ok = true;
            for &(key, len) in ops {
                // Valid JSON payload of exactly `len` bytes: "xxx...".
                let payload = format!("\"{}\"", "x".repeat(len - 2));
                store.put("request", CacheKey(key), payload.as_bytes()).unwrap();
                if store.stats().bytes > *cap {
                    ok = false;
                    break;
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
            ok
        },
    );
}

// -------------------------------------------------- corruption recovery

#[test]
fn corrupt_or_truncated_index_never_panics_and_recovers_payloads() {
    check_no_shrink(
        "cache-index-corruption",
        |rng| (gen_usize(rng, 0, 400), rng.bernoulli(0.3)),
        |&(cut, scramble)| {
            let dir = case_dir("corrupt");
            let binary_payload = encode_bytes(&GenResult {
                latent: Tensor::new(vec![2], vec![0.5, -0.5]).unwrap(),
                stats: GenStats {
                    actions: vec![StepAction::Full],
                    step_ms: vec![1.0],
                    mac_reduction: 1.0,
                    total_ms: 1.0,
                },
            });
            {
                let store = Store::open(StoreConfig::new(&dir)).unwrap();
                store.put("calib", CacheKey(1), b"{\"d_star\":5}").unwrap();
                store.put("plan", CacheKey(2), b"{\"candidates\":[]}").unwrap();
                store.put("request", CacheKey(3), &binary_payload).unwrap();
            }
            let index = dir.join("index.json");
            let text = std::fs::read(&index).unwrap();
            let cut = cut.min(text.len());
            let mut mangled = text[..cut].to_vec();
            if scramble {
                mangled.extend_from_slice(b"\x00\xffgarbage{{{");
            }
            std::fs::write(&index, &mangled).unwrap();

            // Must open without panicking and recover all three payloads
            // (JSON and binary alike).
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            let ok = store.get("calib", CacheKey(1)).is_some()
                && store.get("plan", CacheKey(2)).is_some()
                && store.get("request", CacheKey(3)).as_deref() == Some(&binary_payload[..]);
            let _ = std::fs::remove_dir_all(&dir);
            ok
        },
    );
}
