//! The on-disk store: payload files + a single index with atomic
//! write-then-rename updates.
//!
//! Layout under the cache directory:
//!
//! ```text
//! <dir>/index.json          # {"version","clock","meta","entries":[..]}
//! <dir>/<namespace>/<key-hex>.bin   # one payload per entry
//! ```
//!
//! Payloads are opaque bytes to the store (the codec layer decides
//! between JSON text and the binary latent framing). The index is the
//! source of truth for LRU state and byte accounting; payloads are
//! content-addressed by [`CacheKey`] hex. Index updates go through a
//! temp file + `rename`, so a crash leaves either the old or the new
//! index — never a torn one.
//!
//! Open-time recovery distinguishes two failure shapes:
//!
//! - **Version skew** (the index parses but carries a different
//!   `CACHE_VERSION`): the store was written by another release whose
//!   payload encodings may differ — v2 kept request latents as JSON
//!   where v3 expects binary — so everything is flushed clean rather
//!   than scanned in and misread.
//! - **Corrupt/missing/truncated index**: same-version payloads are
//!   still trustworthy, so the index is rebuilt by scanning the payload
//!   directories (entries keep their bytes, LRU order resets). Files
//!   that are neither parseable JSON nor well-formed binary payloads
//!   are deleted during the scan.
//!
//! Neither path can make [`Store::open`] panic.
//!
//! ## Multi-process sharing (the lock protocol)
//!
//! N processes (e.g. several `sd-acc serve --listen` instances) may
//! open one cache directory. Three mechanisms make that safe:
//!
//! 1. **Advisory index lock** (`<dir>/index.lock`): an `O_EXCL`
//!    lockfile taken around every index load-merge-write sequence —
//!    open, persist, gc, and the read-through reload. Acquisition
//!    retries with a bounded backoff, breaks locks older than
//!    [`LOCK_STALE`] (a crashed holder must not wedge the fleet), and
//!    after [`LOCK_TIMEOUT`] proceeds unlocked — `write_atomic` still
//!    guarantees an untorn file, the lock only guarantees no *lost*
//!    foreign entries.
//! 2. **Merge-on-commit**: before writing the index, the on-disk copy
//!    is re-read under the lock and union-merged into memory. A
//!    disk-only entry is adopted iff its payload file exists (payload
//!    writes always precede index commits, so an existing payload is
//!    ground truth; a missing one means *we* deleted the entry and the
//!    disk copy predates our removal). Clocks merge by max.
//! 3. **Read-through on miss**: a `get` that misses in memory stats
//!    `index.json` (mtime + length) and, when it changed since our
//!    last sync, reloads and merges under the lock before declaring
//!    the miss — so an entry committed by a sibling process is served
//!    without reopening the store.
//!
//! In front of the disk sits an optional process-wide [`MemTier`] — a
//! bounded write-through LRU of payload bytes shared by every `Store`
//! opened on the same canonical directory in this process. Payloads
//! are content-addressed, so a stale tier entry can only ever hold the
//! same bytes the disk held; the tier is invalidated wholesale on
//! version-skew flush and `clear` (which the manifest-mismatch rule in
//! `namespaces.rs` routes through).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::binary;
use super::evict::{plan_evictions, EvictEntry};
use super::key::{CacheKey, CACHE_VERSION};

/// Default byte cap: plenty for plan fronts + calibration, bounded for
/// request latents.
pub const DEFAULT_MAX_BYTES: u64 = 256 * 1024 * 1024;
pub const DEFAULT_MAX_ENTRIES: usize = 65_536;

/// Puts between index persists. The index write is O(entries), and `put`
/// runs per request on the serving path, so inserts buffer and the index
/// catches up every N puts, on eviction, on structural ops, and on
/// `flush`/`Drop`. A hard crash can orphan at most N-1 recent payloads —
/// they are re-generated on miss and swept by `gc`, which the recovery
/// path already tolerates.
const PERSIST_EVERY: u32 = 16;

/// Default byte cap for the shared in-memory payload tier; 0 disables.
pub const DEFAULT_MEM_TIER_BYTES: u64 = 32 * 1024 * 1024;

/// Backoff between lock-acquisition attempts.
const LOCK_RETRY: Duration = Duration::from_millis(2);
/// Give up acquiring after this long and proceed unlocked (the file
/// write is still atomic; only merge freshness degrades).
const LOCK_TIMEOUT: Duration = Duration::from_secs(2);
/// A lockfile older than this belongs to a crashed holder: break it.
/// Index writes hold the lock for one read-merge-write, far under this.
const LOCK_STALE: Duration = Duration::from_secs(5);

/// Store configuration (the `ServerConfig`/CLI cache knobs map to this).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    pub dir: PathBuf,
    /// Hard cap on total payload bytes (the eviction invariant).
    pub max_bytes: u64,
    /// Hard cap on entry count.
    pub max_entries: usize,
    /// Per-namespace time-to-live in seconds (absent = never expires,
    /// the default). An expired entry behaves like a miss on `get` and
    /// is removed on sight; `gc` sweeps the rest. A TTL of 0 expires
    /// entries immediately (useful in tests). Intended user: the
    /// `request` namespace, whose latents age out while calibration and
    /// plan artifacts persist.
    pub ttl_secs: BTreeMap<String, u64>,
    /// Byte cap for the process-wide shared [`MemTier`] in front of the
    /// disk store (0 disables it). Stores opened on the same canonical
    /// directory share one tier regardless of their configured caps;
    /// the first open fixes the tier's size.
    pub mem_tier_bytes: u64,
}

impl StoreConfig {
    pub fn new(dir: impl Into<PathBuf>) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            max_bytes: DEFAULT_MAX_BYTES,
            max_entries: DEFAULT_MAX_ENTRIES,
            ttl_secs: BTreeMap::new(),
            mem_tier_bytes: DEFAULT_MEM_TIER_BYTES,
        }
    }

    /// Set the shared in-memory tier's byte cap (0 disables the tier).
    pub fn with_mem_tier_bytes(mut self, mem_tier_bytes: u64) -> StoreConfig {
        self.mem_tier_bytes = mem_tier_bytes;
        self
    }

    pub fn with_max_bytes(mut self, max_bytes: u64) -> StoreConfig {
        self.max_bytes = max_bytes;
        self
    }

    pub fn with_max_entries(mut self, max_entries: usize) -> StoreConfig {
        self.max_entries = max_entries;
        self
    }

    /// Set a TTL for one namespace.
    pub fn with_ttl(mut self, namespace: &str, ttl_secs: u64) -> StoreConfig {
        self.ttl_secs.insert(namespace.to_string(), ttl_secs);
        self
    }
}

#[derive(Debug, Clone)]
struct EntryMeta {
    bytes: u64,
    last_used: u64,
    /// Unix seconds at insert time — the TTL anchor. Entries recovered
    /// from a payload scan count as created "now" (unknown age must not
    /// mass-expire a cache on recovery).
    created: u64,
}

/// Wall-clock seconds since the Unix epoch.
fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

struct Inner {
    /// (namespace, key) -> meta. BTreeMap keeps stats/persist ordering
    /// deterministic.
    entries: BTreeMap<(String, CacheKey), EntryMeta>,
    /// Logical LRU clock; bumped on every touch.
    clock: u64,
    /// Free-form persisted metadata (e.g. the manifest hash guarding the
    /// namespaces — see `namespaces.rs`).
    meta: BTreeMap<String, String>,
    /// LRU touches and buffered puts are persisted lazily; structural
    /// changes eagerly.
    dirty: bool,
    /// Puts since the last index persist (see [`PERSIST_EVERY`]).
    pending_puts: u32,
    /// `(mtime, len)` of `index.json` at our last load/merge/write —
    /// the cheap change detector for foreign commits. `None` before
    /// the first sync or when the file is absent.
    disk_stamp: Option<(SystemTime, u64)>,
}

impl Inner {
    fn empty() -> Inner {
        Inner {
            entries: BTreeMap::new(),
            clock: 0,
            meta: BTreeMap::new(),
            dirty: true,
            pending_puts: 0,
            disk_stamp: None,
        }
    }
}

/// Per-namespace usage summary.
#[derive(Debug, Clone)]
pub struct NamespaceStats {
    pub namespace: String,
    pub entries: usize,
    pub bytes: u64,
}

/// Point-in-time store summary (CLI `cache stats`).
#[derive(Debug, Clone)]
pub struct StoreStats {
    pub namespaces: Vec<NamespaceStats>,
    pub entries: usize,
    pub bytes: u64,
    pub max_bytes: u64,
    pub max_entries: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// What a `gc` pass cleaned up.
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Index entries whose payload file had vanished.
    pub dropped_missing: usize,
    /// Payload files on disk that no index entry claimed.
    pub removed_orphans: usize,
    /// Entries evicted to re-enforce the caps.
    pub evicted: usize,
    /// Entries swept because their namespace TTL had elapsed.
    pub expired: usize,
}

// ------------------------------------------------------------- mem tier

/// Process-wide shared in-memory payload tier: a bounded write-through
/// LRU of raw payload bytes in front of the disk store. One instance
/// exists per canonical cache directory per process (see
/// [`mem_tier_for`]), so two `Store` handles — or two server clients —
/// opened on the same directory serve each other's recent payloads
/// without touching the filesystem.
///
/// Payloads are content-addressed by [`CacheKey`], so a tier entry can
/// never disagree with what the disk held for that key; staleness after
/// a foreign delete only re-serves bytes that were valid moments ago.
/// Structural invalidation (version skew, manifest-mismatch `clear`)
/// empties the tier wholesale.
pub struct MemTier {
    max_bytes: u64,
    inner: Mutex<MemInner>,
}

#[derive(Default)]
struct MemInner {
    /// (namespace, key) -> (payload, last_used).
    map: BTreeMap<(String, CacheKey), (Vec<u8>, u64)>,
    bytes: u64,
    clock: u64,
    hits: u64,
}

impl MemTier {
    fn new(max_bytes: u64) -> MemTier {
        MemTier { max_bytes, inner: Mutex::new(MemInner::default()) }
    }

    fn get(&self, ns: &str, key: CacheKey) -> Option<Vec<u8>> {
        let mut m = self.inner.lock().unwrap();
        m.clock += 1;
        let clock = m.clock;
        let out = m.map.get_mut(&(ns.to_string(), key)).map(|(bytes, last_used)| {
            *last_used = clock;
            bytes.clone()
        });
        if out.is_some() {
            m.hits += 1;
        }
        out
    }

    fn put(&self, ns: &str, key: CacheKey, payload: &[u8]) {
        if payload.len() as u64 > self.max_bytes {
            return; // a single oversized payload must not flush the tier
        }
        let mut m = self.inner.lock().unwrap();
        m.clock += 1;
        let clock = m.clock;
        if let Some((old, _)) = m.map.insert(
            (ns.to_string(), key),
            (payload.to_vec(), clock),
        ) {
            m.bytes -= old.len() as u64;
        }
        m.bytes += payload.len() as u64;
        while m.bytes > self.max_bytes {
            let victim = m
                .map
                .iter()
                .min_by_key(|(_, (_, last_used))| *last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some((bytes, _)) = m.map.remove(&k) {
                        m.bytes -= bytes.len() as u64;
                    }
                }
                None => break,
            }
        }
    }

    fn remove(&self, ns: &str, key: CacheKey) {
        let mut m = self.inner.lock().unwrap();
        if let Some((bytes, _)) = m.map.remove(&(ns.to_string(), key)) {
            m.bytes -= bytes.len() as u64;
        }
    }

    fn purge_namespace(&self, ns: &str) {
        let mut m = self.inner.lock().unwrap();
        m.map.retain(|(n, _), _| n.as_str() != ns);
        m.bytes = m.map.values().map(|(b, _)| b.len() as u64).sum();
    }

    fn clear(&self) {
        let mut m = self.inner.lock().unwrap();
        m.map.clear();
        m.bytes = 0;
    }

    /// `(entries, bytes, hits)` — observability/tests only.
    pub fn stats(&self) -> (usize, u64, u64) {
        let m = self.inner.lock().unwrap();
        (m.map.len(), m.bytes, m.hits)
    }
}

/// Per-process registry mapping canonical cache dirs to their shared
/// [`MemTier`]. The first open of a directory fixes the tier size.
fn mem_tier_for(dir: &Path, max_bytes: u64) -> Option<Arc<MemTier>> {
    if max_bytes == 0 {
        return None;
    }
    static REGISTRY: Mutex<BTreeMap<PathBuf, Arc<MemTier>>> = Mutex::new(BTreeMap::new());
    let key = std::fs::canonicalize(dir).unwrap_or_else(|_| dir.to_path_buf());
    let mut reg = REGISTRY.lock().unwrap();
    Some(Arc::clone(
        reg.entry(key).or_insert_with(|| Arc::new(MemTier::new(max_bytes))),
    ))
}

// ------------------------------------------------------------ index lock

/// Advisory cross-process lock over the index: an `O_EXCL` lockfile
/// (`<dir>/index.lock`) holding the owner's pid. See the module docs
/// for the protocol; acquisition breaks stale locks and, after
/// [`LOCK_TIMEOUT`], degrades to unlocked operation rather than wedge
/// the serving path.
struct IndexLock {
    path: PathBuf,
    held: bool,
}

impl IndexLock {
    fn acquire(dir: &Path) -> IndexLock {
        let path = dir.join("index.lock");
        let deadline = Instant::now() + LOCK_TIMEOUT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write;
                    let _ = write!(f, "{}", std::process::id());
                    return IndexLock { path, held: true };
                }
                Err(_) => {
                    let stale = std::fs::metadata(&path)
                        .ok()
                        .and_then(|md| md.modified().ok())
                        .and_then(|m| m.elapsed().ok())
                        .map_or(false, |age| age > LOCK_STALE);
                    if stale {
                        // Remove-then-retry: only one of N waiters'
                        // `create_new` calls can win afterwards.
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    if Instant::now() >= deadline {
                        return IndexLock { path, held: false };
                    }
                    std::thread::sleep(LOCK_RETRY);
                }
            }
        }
    }
}

impl Drop for IndexLock {
    fn drop(&mut self) {
        if self.held {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// `(mtime, len)` of a file — the cheap change detector behind the
/// cross-process read-through. `None` when the file is absent.
fn file_stamp(path: &Path) -> Option<(SystemTime, u64)> {
    std::fs::metadata(path)
        .ok()
        .map(|m| (m.modified().unwrap_or(SystemTime::UNIX_EPOCH), m.len()))
}

/// Content-addressed persistent store with LRU + byte-cap eviction.
pub struct Store {
    cfg: StoreConfig,
    inner: Mutex<Inner>,
    mem: Option<Arc<MemTier>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Store {
    /// Open (or create) a store. A version-skewed index flushes the
    /// store clean (old payload encodings must not be misread);
    /// corrupt/missing indexes recover by scanning payload files. Never
    /// panics on bad on-disk state.
    pub fn open(cfg: StoreConfig) -> Result<Store> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating cache dir {}", cfg.dir.display()))?;
        // After create_dir_all so the registry keys on the canonical path.
        let mem = mem_tier_for(&cfg.dir, cfg.mem_tier_bytes);
        let lock = IndexLock::acquire(&cfg.dir);
        let idx = index_path(&cfg.dir);
        let inner = match load_index(&idx) {
            IndexState::Loaded(mut inner) => {
                inner.disk_stamp = file_stamp(&idx);
                inner
            }
            IndexState::VersionSkew => {
                for d in namespace_dirs(&cfg.dir) {
                    let _ = std::fs::remove_dir_all(&d);
                }
                // Old-generation payload bytes must not be served from
                // memory either.
                if let Some(m) = &mem {
                    m.clear();
                }
                Inner::empty()
            }
            IndexState::Unusable => scan_payloads(&cfg.dir),
        };
        let store = Store {
            cfg,
            inner: Mutex::new(inner),
            mem,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        };
        {
            // Re-enforce caps (the configured caps may have shrunk since
            // the index was written) and persist the recovered state.
            // Still under the open-wide index lock, so use the
            // non-acquiring persist.
            let mut inner = store.inner.lock().unwrap();
            store.evict_locked(&mut inner);
            store.persist_under_flock(&mut inner)?;
        }
        drop(lock);
        Ok(store)
    }

    /// `(entries, bytes, hits)` of the shared in-memory tier, if enabled.
    pub fn mem_tier_stats(&self) -> Option<(usize, u64, u64)> {
        self.mem.as_ref().map(|m| m.stats())
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    fn payload_path(&self, ns: &str, key: CacheKey) -> PathBuf {
        self.cfg.dir.join(ns).join(format!("{}.bin", key.hex()))
    }

    /// True when the namespace has a TTL and the entry has outlived it.
    fn is_expired(&self, ns: &str, meta: &EntryMeta, now: u64) -> bool {
        self.cfg
            .ttl_secs
            .get(ns)
            .map_or(false, |&ttl| now >= meta.created.saturating_add(ttl))
    }

    /// Fetch a payload; touches LRU state on hit. Entries past their
    /// namespace TTL count as misses and are removed on sight.
    pub fn get(&self, ns: &str, key: CacheKey) -> Option<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let map_key = (ns.to_string(), key);
        if !inner.entries.contains_key(&map_key)
            && file_stamp(&index_path(&self.cfg.dir)) != inner.disk_stamp
        {
            // Read-through: the on-disk index changed since our last
            // sync, so a sibling process may have committed this entry.
            let _lock = IndexLock::acquire(&self.cfg.dir);
            self.merge_disk_locked(&mut inner);
        }
        let expired = match inner.entries.get(&map_key) {
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Some(meta) => self.is_expired(ns, meta, now_unix()),
        };
        if expired {
            inner.entries.remove(&map_key);
            self.mem_remove(ns, key);
            let _ = std::fs::remove_file(self.payload_path(ns, key));
            // Lazily persisted (unlike structural removals): expiry can
            // run on the request hot path, and a stale index entry whose
            // payload is gone is already self-healed by the recovery
            // paths, so the O(entries) index write can wait for the next
            // batched flush.
            inner.dirty = true;
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // Shared memory tier first (expiry above still gates it — the
        // tier never resurrects an index-expired entry); fall back to
        // the payload file and populate the tier on the way out.
        let read = match self.mem.as_ref().and_then(|m| m.get(ns, key)) {
            Some(bytes) => Ok(bytes),
            None => std::fs::read(self.payload_path(ns, key)).map(|bytes| {
                if let Some(m) = &self.mem {
                    m.put(ns, key, &bytes);
                }
                bytes
            }),
        };
        match read {
            Ok(bytes) => {
                inner.clock += 1;
                let clock = inner.clock;
                if let Some(e) = inner.entries.get_mut(&map_key) {
                    e.last_used = clock;
                }
                inner.dirty = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(bytes)
            }
            Err(_) => {
                // Payload vanished underneath us: self-heal the index.
                inner.entries.remove(&map_key);
                self.mem_remove(ns, key);
                inner.dirty = true;
                let _ = self.persist_locked(&mut inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or replace) a payload. Returns how many entries were
    /// evicted to stay under the caps.
    pub fn put(&self, ns: &str, key: CacheKey, payload: &[u8]) -> Result<usize> {
        if ns.is_empty() || ns.chars().any(|c| matches!(c, '/' | '\\' | '.')) {
            bail!("invalid cache namespace '{ns}'");
        }
        // Hold the lock across the payload write too, so concurrent puts
        // of the same key cannot race on the temp file.
        let mut inner = self.inner.lock().unwrap();
        let path = self.payload_path(ns, key);
        let parent = path.parent().expect("payload path has a parent");
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
        write_atomic(&path, payload)?;
        if let Some(m) = &self.mem {
            m.put(ns, key, payload); // write-through
        }

        inner.clock += 1;
        let clock = inner.clock;
        inner.entries.insert(
            (ns.to_string(), key),
            EntryMeta { bytes: payload.len() as u64, last_used: clock, created: now_unix() },
        );
        let evicted = self.evict_locked(&mut inner);
        inner.dirty = true;
        inner.pending_puts += 1;
        // The index write is O(entries); buffer it on the hot path and
        // catch up periodically (and immediately after evictions, so the
        // on-disk index never references deleted payloads for long).
        if evicted > 0 || inner.pending_puts >= PERSIST_EVERY {
            self.persist_locked(&mut inner)?;
        }
        Ok(evicted)
    }

    /// Drop one entry. Returns whether it existed.
    pub fn remove(&self, ns: &str, key: CacheKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.entries.remove(&(ns.to_string(), key)).is_some();
        self.mem_remove(ns, key);
        let _ = std::fs::remove_file(self.payload_path(ns, key));
        if existed {
            inner.dirty = true;
            let _ = self.persist_locked(&mut inner);
        }
        existed
    }

    /// Remove all entries, or all entries of one namespace. Also sweeps
    /// the payload directory so orphaned files go too.
    pub fn clear(&self, ns: Option<&str>) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.entries.len();
        match ns {
            Some(ns) => {
                inner.entries.retain(|(n, _), _| n.as_str() != ns);
                let _ = std::fs::remove_dir_all(self.cfg.dir.join(ns));
                if let Some(m) = &self.mem {
                    m.purge_namespace(ns);
                }
            }
            None => {
                inner.entries.clear();
                for d in namespace_dirs(&self.cfg.dir) {
                    let _ = std::fs::remove_dir_all(d);
                }
                if let Some(m) = &self.mem {
                    m.clear();
                }
            }
        }
        let removed = before - inner.entries.len();
        inner.dirty = true;
        let _ = self.persist_locked(&mut inner);
        removed
    }

    /// Validate index<->disk agreement, sweep expired entries, and
    /// re-enforce the caps.
    pub fn gc(&self) -> Result<GcReport> {
        let mut inner = self.inner.lock().unwrap();
        let mut report = GcReport::default();

        // Hold the index lock across the whole pass: the merge below
        // adopts sibling-committed entries so the orphan sweep cannot
        // mistake their payloads for garbage, and no sibling can commit
        // an index between our sweeps and our persist.
        let _lock = IndexLock::acquire(&self.cfg.dir);
        self.merge_disk_locked(&mut inner);

        // 0. Entries past their namespace TTL.
        let now = now_unix();
        let expired: Vec<(String, CacheKey)> = inner
            .entries
            .iter()
            .filter(|((ns, _), meta)| self.is_expired(ns, meta, now))
            .map(|(k, _)| k.clone())
            .collect();
        report.expired = expired.len();
        for (ns, key) in expired {
            let _ = std::fs::remove_file(self.payload_path(&ns, key));
            self.mem_remove(&ns, key);
            inner.entries.remove(&(ns, key));
        }

        // 1. Index entries whose payload is gone.
        let missing: Vec<(String, CacheKey)> = inner
            .entries
            .keys()
            .filter(|(ns, key)| !self.payload_path(ns, *key).exists())
            .cloned()
            .collect();
        report.dropped_missing = missing.len();
        for k in missing {
            self.mem_remove(&k.0, k.1);
            inner.entries.remove(&k);
        }

        // 2. Files on disk that the index does not claim, plus stray
        // temp files left by a writer that died mid-commit.
        sweep_stray_tmps(&self.cfg.dir);
        for dir in namespace_dirs(&self.cfg.dir) {
            let ns = dir.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            sweep_stray_tmps(&dir);
            for (path, key) in payload_files(&dir) {
                if !inner.entries.contains_key(&(ns.clone(), key)) {
                    let _ = std::fs::remove_file(path);
                    report.removed_orphans += 1;
                }
            }
        }

        // 3. Caps.
        report.evicted = self.evict_locked(&mut inner);

        inner.dirty = true;
        self.persist_under_flock(&mut inner)?;
        Ok(report)
    }

    /// Persisted metadata lookup (e.g. the manifest hash).
    pub fn meta(&self, k: &str) -> Option<String> {
        self.inner.lock().unwrap().meta.get(k).cloned()
    }

    /// Set persisted metadata.
    pub fn set_meta(&self, k: &str, v: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.meta.insert(k.to_string(), v.to_string());
        inner.dirty = true;
        self.persist_locked(&mut inner)
    }

    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        let mut per_ns: BTreeMap<&str, (usize, u64)> = BTreeMap::new();
        for ((ns, _), meta) in &inner.entries {
            let slot = per_ns.entry(ns.as_str()).or_default();
            slot.0 += 1;
            slot.1 += meta.bytes;
        }
        StoreStats {
            namespaces: per_ns
                .into_iter()
                .map(|(ns, (entries, bytes))| NamespaceStats {
                    namespace: ns.to_string(),
                    entries,
                    bytes,
                })
                .collect(),
            entries: inner.entries.len(),
            bytes: inner.entries.values().map(|e| e.bytes).sum(),
            max_bytes: self.cfg.max_bytes,
            max_entries: self.cfg.max_entries,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Persist any lazily-buffered LRU touches.
    pub fn flush(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.persist_locked(&mut inner)
    }

    // ------------------------------------------------------------ internals

    /// Enforce the caps; returns number of entries evicted.
    fn evict_locked(&self, inner: &mut Inner) -> usize {
        let keys: Vec<(String, CacheKey)> = inner.entries.keys().cloned().collect();
        let view: Vec<EvictEntry> = keys
            .iter()
            .map(|k| {
                let m = &inner.entries[k];
                EvictEntry { key: k.1, bytes: m.bytes, last_used: m.last_used }
            })
            .collect();
        let plan = plan_evictions(&view, self.cfg.max_bytes, self.cfg.max_entries);
        for &i in &plan {
            let (ns, key) = &keys[i];
            inner.entries.remove(&(ns.clone(), *key));
            self.mem_remove(ns, *key);
            let _ = std::fs::remove_file(self.payload_path(ns, *key));
        }
        if !plan.is_empty() {
            inner.dirty = true;
        }
        self.evictions.fetch_add(plan.len() as u64, Ordering::Relaxed);
        plan.len()
    }

    /// Drop a key from the shared memory tier, if the tier is enabled.
    fn mem_remove(&self, ns: &str, key: CacheKey) {
        if let Some(m) = &self.mem {
            m.remove(ns, key);
        }
    }

    /// Union-merge the on-disk index into memory. Caller must hold the
    /// [`IndexLock`] (or be on a path where freshness loss is accepted).
    /// See the module docs: disk-only entries are adopted iff their
    /// payload file exists; clocks merge by max; our meta wins.
    fn merge_disk_locked(&self, inner: &mut Inner) {
        let path = index_path(&self.cfg.dir);
        let stamp = file_stamp(&path);
        if stamp == inner.disk_stamp {
            return; // nothing foreign happened since our last sync
        }
        if let IndexState::Loaded(disk) = load_index(&path) {
            inner.clock = inner.clock.max(disk.clock);
            for (k, v) in disk.entries {
                match inner.entries.get_mut(&k) {
                    Some(ours) => {
                        ours.last_used = ours.last_used.max(v.last_used);
                    }
                    None => {
                        // Payload writes precede index commits, so an
                        // existing payload marks a real foreign entry; a
                        // missing one means *we* removed it and the disk
                        // index predates that removal.
                        if self.payload_path(&k.0, k.1).exists() {
                            inner.entries.insert(k, v);
                            inner.dirty = true;
                        }
                    }
                }
            }
            for (k, v) in disk.meta {
                inner.meta.entry(k).or_insert(v);
            }
        }
        inner.disk_stamp = stamp;
    }

    /// Acquire the cross-process index lock, then merge + persist.
    fn persist_locked(&self, inner: &mut Inner) -> Result<()> {
        if !inner.dirty {
            return Ok(());
        }
        let _lock = IndexLock::acquire(&self.cfg.dir);
        self.persist_under_flock(inner)
    }

    /// Merge + persist for callers already holding the index lock
    /// (`open`, `gc`). [`IndexLock`] is not re-entrant, so this must not
    /// try to acquire it again.
    fn persist_under_flock(&self, inner: &mut Inner) -> Result<()> {
        if !inner.dirty {
            return Ok(());
        }
        self.merge_disk_locked(inner);
        let entries = Json::Arr(
            inner
                .entries
                .iter()
                .map(|((ns, key), m)| {
                    Json::obj(vec![
                        ("ns", Json::str(ns)),
                        ("key", Json::str(&key.hex())),
                        ("bytes", Json::num(m.bytes as f64)),
                        ("last_used", Json::num(m.last_used as f64)),
                        ("created", Json::num(m.created as f64)),
                    ])
                })
                .collect(),
        );
        let meta = Json::Obj(
            inner.meta.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect(),
        );
        let index = Json::obj(vec![
            ("version", Json::num(CACHE_VERSION as f64)),
            ("clock", Json::num(inner.clock as f64)),
            ("meta", meta),
            ("entries", entries),
        ]);
        write_atomic(&index_path(&self.cfg.dir), index.to_string().as_bytes())?;
        inner.disk_stamp = file_stamp(&index_path(&self.cfg.dir));
        inner.dirty = false;
        inner.pending_puts = 0;
        Ok(())
    }
}

impl Drop for Store {
    fn drop(&mut self) {
        // Best-effort: flush buffered LRU touches.
        if let Ok(mut inner) = self.inner.lock() {
            let _ = self.persist_locked(&mut inner);
        }
    }
}

fn index_path(dir: &Path) -> PathBuf {
    dir.join("index.json")
}

/// Write-then-rename so readers never observe a torn file. The temp
/// name carries pid + a process-local sequence number so concurrent
/// writers (threads *or* sibling processes) never collide on it; a
/// writer that dies mid-commit leaves a stray `*.tmp.*` that `gc`
/// sweeps.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let n = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{}", std::process::id(), n));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Delete stray `*.tmp.*` files (dead writers' leftovers) directly
/// inside `dir` — non-recursive; `gc` calls it per directory.
fn sweep_stray_tmps(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_file()
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.contains(".tmp."))
            {
                let _ = std::fs::remove_file(&p);
            }
        }
    }
}

/// How an on-disk index read went.
enum IndexState {
    Loaded(Inner),
    /// Parsed, but written by a different `CACHE_VERSION` — flush.
    VersionSkew,
    /// Missing/corrupt/truncated — rebuild by scanning payloads.
    Unusable,
}

/// Parse the index, classifying failures (see [`IndexState`]).
fn load_index(path: &Path) -> IndexState {
    let Ok(text) = std::fs::read_to_string(path) else {
        return IndexState::Unusable;
    };
    let Ok(j) = Json::parse(&text) else {
        return IndexState::Unusable;
    };
    match j.get_usize("version") {
        Some(v) if v == CACHE_VERSION as usize => {}
        Some(_) => return IndexState::VersionSkew,
        None => return IndexState::Unusable,
    }
    let mut entries = BTreeMap::new();
    let now = now_unix();
    let Some(list) = j.get("entries").and_then(Json::as_arr) else {
        return IndexState::Unusable;
    };
    for e in list {
        let (Some(ns), Some(key_hex), Some(bytes)) =
            (e.get_str("ns"), e.get_str("key"), e.get_usize("bytes"))
        else {
            return IndexState::Unusable;
        };
        let Some(key) = CacheKey::from_hex(key_hex) else {
            return IndexState::Unusable;
        };
        entries.insert(
            (ns.to_string(), key),
            EntryMeta {
                bytes: bytes as u64,
                last_used: e.get_usize("last_used").unwrap_or(0) as u64,
                created: e.get_usize("created").map(|v| v as u64).unwrap_or(now),
            },
        );
    }
    let meta = j
        .get("meta")
        .and_then(Json::as_obj)
        .map(|o| {
            o.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default();
    IndexState::Loaded(Inner {
        entries,
        clock: j.get_usize("clock").unwrap_or(0) as u64,
        meta,
        dirty: false,
        pending_puts: 0,
        disk_stamp: None,
    })
}

/// True when `bytes` is a healthy payload in either on-disk encoding.
fn payload_looks_valid(bytes: &[u8]) -> bool {
    binary::is_well_formed(bytes)
        || std::str::from_utf8(bytes)
            .ok()
            .map(|t| Json::parse(t).is_ok())
            .unwrap_or(false)
}

/// Rebuild an index by scanning payload directories (recovery path for a
/// same-version store whose index is unusable). Payloads that are
/// neither parseable JSON nor well-formed binary are deleted, as are
/// stray pre-v3 `.json` payload files; LRU order resets.
fn scan_payloads(dir: &Path) -> Inner {
    let mut entries = BTreeMap::new();
    let mut clock = 0;
    for ns_dir in namespace_dirs(dir) {
        let ns = ns_dir.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        sweep_legacy_payloads(&ns_dir);
        for (path, key) in payload_files(&ns_dir) {
            let valid = std::fs::read(&path)
                .map(|bytes| payload_looks_valid(&bytes))
                .unwrap_or(false);
            if !valid {
                let _ = std::fs::remove_file(&path);
                continue;
            }
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            clock += 1;
            entries.insert(
                (ns.clone(), key),
                EntryMeta { bytes, last_used: clock, created: now_unix() },
            );
        }
    }
    Inner { entries, clock, meta: BTreeMap::new(), dirty: true, pending_puts: 0, disk_stamp: None }
}

/// Delete pre-v3 `<hex>.json` payload files found during a scan — they
/// belong to a store generation whose index is already gone.
fn sweep_legacy_payloads(ns_dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(ns_dir) {
        for e in rd.flatten() {
            let p = e.path();
            let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if p.extension().and_then(|s| s.to_str()) == Some("json")
                && CacheKey::from_hex(stem).is_some()
            {
                let _ = std::fs::remove_file(&p);
            }
        }
    }
}

/// Subdirectories of the cache dir (one per namespace).
fn namespace_dirs(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

/// `<16-hex>.bin` payload files inside one namespace directory.
fn payload_files(ns_dir: &Path) -> Vec<(PathBuf, CacheKey)> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(ns_dir) {
        for e in rd.flatten() {
            let p = e.path();
            let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if p.extension().and_then(|s| s.to_str()) == Some("bin") {
                if let Some(key) = CacheKey::from_hex(stem) {
                    out.push((p, key));
                }
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sdacc_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_get_roundtrip_and_counters() {
        let store = Store::open(StoreConfig::new(tmp_dir("roundtrip"))).unwrap();
        let k = CacheKey(42);
        assert_eq!(store.get("req", k), None);
        store.put("req", k, b"{\"a\":1}").unwrap();
        assert_eq!(store.get("req", k).as_deref(), Some(&b"{\"a\":1}"[..]));
        let s = store.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 7);
    }

    #[test]
    fn binary_payload_bytes_roundtrip_untouched() {
        // Payloads are opaque bytes: non-UTF8 binary must come back
        // byte-for-byte.
        let store = Store::open(StoreConfig::new(tmp_dir("binbytes"))).unwrap();
        let payload: Vec<u8> = (0..=255u8).collect();
        store.put("req", CacheKey(7), &payload).unwrap();
        assert_eq!(store.get("req", CacheKey(7)).as_deref(), Some(&payload[..]));
        assert_eq!(store.stats().bytes, 256);
    }

    #[test]
    fn survives_reopen() {
        let dir = tmp_dir("reopen");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("plan", CacheKey(1), b"{\"x\":[1,2]}").unwrap();
            store.put("calib", CacheKey(2), b"{\"y\":3}").unwrap();
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("plan", CacheKey(1)).as_deref(), Some(&b"{\"x\":[1,2]}"[..]));
        assert_eq!(store.get("calib", CacheKey(2)).as_deref(), Some(&b"{\"y\":3}"[..]));
        assert_eq!(store.stats().entries, 2);
    }

    #[test]
    fn byte_cap_never_exceeded() {
        let cfg = StoreConfig::new(tmp_dir("cap")).with_max_bytes(30);
        let store = Store::open(cfg).unwrap();
        for i in 0..10u64 {
            store.put("req", CacheKey(i), b"{\"v\":1234567}").unwrap(); // 13 bytes
            assert!(store.stats().bytes <= 30, "cap breached at i={i}");
        }
        let s = store.stats();
        assert!(s.evictions >= 8, "evictions {}", s.evictions);
        assert_eq!(s.entries, 2);
        // Newest entries survive.
        assert!(store.get("req", CacheKey(9)).is_some());
        assert!(store.get("req", CacheKey(0)).is_none());
    }

    #[test]
    fn lru_respects_touches() {
        let cfg = StoreConfig::new(tmp_dir("lru")).with_max_entries(2).with_max_bytes(1 << 20);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(1), b"{}").unwrap();
        store.put("req", CacheKey(2), b"{}").unwrap();
        // Touch 1 so 2 becomes the LRU victim.
        assert!(store.get("req", CacheKey(1)).is_some());
        store.put("req", CacheKey(3), b"{}").unwrap();
        assert!(store.get("req", CacheKey(1)).is_some());
        assert!(store.get("req", CacheKey(2)).is_none());
        assert!(store.get("req", CacheKey(3)).is_some());
    }

    #[test]
    fn buffered_puts_flush_every_n_and_orphans_are_gc_able() {
        // Crash (no Drop flush) right after one buffered put: the payload
        // is an orphan — not served, but cleanly reclaimed by gc.
        let dir = tmp_dir("crash1");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(1), b"{\"v\":1}").unwrap();
            std::mem::forget(store); // simulated hard crash
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert!(store.get("req", CacheKey(1)).is_none(), "buffered put lost on crash");
        assert_eq!(store.gc().unwrap().removed_orphans, 1);
        drop(store);

        // After PERSIST_EVERY puts the index has caught up, so a crash
        // loses nothing.
        let dir = tmp_dir("crash2");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            for i in 0..super::PERSIST_EVERY as u64 {
                store.put("req", CacheKey(i), b"{}").unwrap();
            }
            std::mem::forget(store);
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, super::PERSIST_EVERY as usize);
    }

    #[test]
    fn corrupt_index_recovers_by_scanning() {
        let dir = tmp_dir("corrupt");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(7), b"{\"keep\":true}").unwrap();
        }
        std::fs::write(dir.join("index.json"), "{\"version\":1,\"entr").unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("req", CacheKey(7)).as_deref(), Some(&b"{\"keep\":true}"[..]));
    }

    #[test]
    fn scan_keeps_wellformed_binary_payloads() {
        use crate::coordinator::{GenResult, GenStats};
        use crate::pas::plan::StepAction;
        use crate::runtime::Tensor;
        let dir = tmp_dir("scanbin");
        let res = GenResult {
            latent: Tensor::new(vec![2, 2], vec![1.0, -2.0, 0.5, f32::NAN]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full],
                step_ms: vec![1.0],
                mac_reduction: 1.0,
                total_ms: 1.0,
            },
        };
        let payload = super::binary::encode_gen_result(&res);
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("request", CacheKey(3), &payload).unwrap();
            // A garbage sibling that is neither JSON nor binary.
            store.put("request", CacheKey(4), &[0xff, 0x00, 0x12]).unwrap();
        }
        std::fs::remove_file(dir.join("index.json")).unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("request", CacheKey(3)).as_deref(), Some(&payload[..]));
        assert!(store.get("request", CacheKey(4)).is_none(), "garbage dropped by scan");
    }

    #[test]
    fn version_skew_flushes_cleanly() {
        // A store written by an older CACHE_VERSION must be flushed on
        // open — its payload encodings (v2: JSON request latents) would
        // be misread by the current codecs — not recovered by scan.
        let dir = tmp_dir("version");
        let ns = dir.join("request");
        std::fs::create_dir_all(&ns).unwrap();
        let key = CacheKey(9);
        // v2 layout: `<hex>.json` payload + version-2 index naming it.
        let payload_path = ns.join(format!("{}.json", key.hex()));
        std::fs::write(&payload_path, "{\"dims\":[1],\"latent\":[0.5]}").unwrap();
        std::fs::write(
            dir.join("index.json"),
            format!(
                "{{\"version\":2,\"clock\":1,\"meta\":{{}},\"entries\":[{{\"ns\":\"request\",\
                 \"key\":\"{}\",\"bytes\":27,\"last_used\":1,\"created\":0}}]}}",
                key.hex()
            ),
        )
        .unwrap();

        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, 0, "old entries must not be served");
        assert!(store.get("request", key).is_none());
        assert!(!payload_path.exists(), "old payload flushed from disk");

        // A future version is flushed the same way.
        drop(store);
        std::fs::write(dir.join("index.json"), "{\"version\":999,\"entries\":[]}").unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn gc_reconciles_disk_and_index() {
        let dir = tmp_dir("gc");
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        store.put("req", CacheKey(1), b"{\"a\":1}").unwrap();
        store.put("req", CacheKey(2), b"{\"b\":2}").unwrap();
        // Vanish one payload; drop one orphan file in.
        std::fs::remove_file(dir.join("req").join(format!("{}.bin", CacheKey(1).hex()))).unwrap();
        std::fs::write(dir.join("req").join(format!("{}.bin", CacheKey(99).hex())), "{}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.dropped_missing, 1);
        assert_eq!(report.removed_orphans, 1);
        assert_eq!(store.stats().entries, 1);
        assert!(store.get("req", CacheKey(2)).is_some());
    }

    #[test]
    fn clear_namespace_only_hits_that_namespace() {
        let store = Store::open(StoreConfig::new(tmp_dir("clearns"))).unwrap();
        store.put("req", CacheKey(1), b"{}").unwrap();
        store.put("plan", CacheKey(2), b"{}").unwrap();
        assert_eq!(store.clear(Some("req")), 1);
        assert!(store.get("req", CacheKey(1)).is_none());
        assert!(store.get("plan", CacheKey(2)).is_some());
        assert_eq!(store.clear(None), 1);
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn ttl_expires_only_configured_namespaces() {
        // TTL 0 on "req": entries expire on the very next access.
        let cfg = StoreConfig::new(tmp_dir("ttl_ns")).with_ttl("req", 0);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(1), b"{\"v\":1}").unwrap();
        store.put("plan", CacheKey(2), b"{\"v\":2}").unwrap();
        assert_eq!(store.get("req", CacheKey(1)), None, "expired");
        assert_eq!(store.get("plan", CacheKey(2)).as_deref(), Some(&b"{\"v\":2}"[..]));
        // The expired entry was evicted for real: index and payload gone.
        let s = store.stats();
        assert_eq!(s.entries, 1);
        assert!(!store.dir().join("req").join(format!("{}.bin", CacheKey(1).hex())).exists());
        // A generous TTL does not expire fresh entries.
        let cfg = StoreConfig::new(tmp_dir("ttl_fresh")).with_ttl("req", 3600);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(3), b"{}").unwrap();
        assert!(store.get("req", CacheKey(3)).is_some());
    }

    #[test]
    fn gc_sweeps_expired_entries() {
        let cfg = StoreConfig::new(tmp_dir("ttl_gc")).with_ttl("req", 0);
        let store = Store::open(cfg).unwrap();
        for i in 0..3u64 {
            store.put("req", CacheKey(i), b"{}").unwrap();
        }
        store.put("calib", CacheKey(9), b"{}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.expired, 3);
        assert_eq!(store.stats().entries, 1, "non-TTL namespace survives");
        // A second pass finds nothing left to sweep.
        assert_eq!(store.gc().unwrap().expired, 0);
    }

    #[test]
    fn ttl_anchor_survives_reopen() {
        // An entry written without TTL stays valid when the store is
        // reopened with a generous TTL (created timestamp persisted),
        // and expires under a zero TTL.
        let dir = tmp_dir("ttl_reopen");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(5), b"{\"keep\":1}").unwrap();
        }
        {
            let store = Store::open(StoreConfig::new(&dir).with_ttl("req", 3600)).unwrap();
            assert!(store.get("req", CacheKey(5)).is_some(), "fresh under 1h TTL");
        }
        let store = Store::open(StoreConfig::new(&dir).with_ttl("req", 0)).unwrap();
        assert!(store.get("req", CacheKey(5)).is_none(), "expired under 0s TTL");
    }

    #[test]
    fn meta_persists_across_reopen() {
        let dir = tmp_dir("meta");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.set_meta("manifest_hash", "abc123").unwrap();
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.meta("manifest_hash").as_deref(), Some("abc123"));
    }

    #[test]
    fn index_lock_acquires_releases_and_degrades_on_foreign_hold() {
        let dir = tmp_dir("lockrt");
        std::fs::create_dir_all(&dir).unwrap();
        {
            let l = IndexLock::acquire(&dir);
            assert!(l.held);
            assert!(dir.join("index.lock").exists());
        }
        assert!(!dir.join("index.lock").exists(), "drop releases the lock");

        // A fresh foreign lock (not stale yet) must not wedge us: after
        // LOCK_TIMEOUT, acquisition degrades to unlocked operation and
        // the foreign lockfile is left alone.
        std::fs::write(dir.join("index.lock"), "424242").unwrap();
        let l = IndexLock::acquire(&dir);
        assert!(!l.held, "fresh foreign lock should degrade, not break");
        drop(l);
        assert!(dir.join("index.lock").exists(), "unheld guard must not remove a foreign lock");
        assert_eq!(std::fs::read_to_string(dir.join("index.lock")).unwrap(), "424242");
        let _ = std::fs::remove_file(dir.join("index.lock"));
    }

    #[test]
    fn mem_tier_is_a_bounded_lru() {
        let tier = MemTier::new(100);
        tier.put("ns", CacheKey(1), &[1u8; 40]);
        tier.put("ns", CacheKey(2), &[2u8; 40]);
        // Touch 1 so 2 becomes the victim when 3 overflows the cap.
        assert_eq!(tier.get("ns", CacheKey(1)).as_deref(), Some(&[1u8; 40][..]));
        tier.put("ns", CacheKey(3), &[3u8; 40]);
        assert!(tier.get("ns", CacheKey(1)).is_some());
        assert!(tier.get("ns", CacheKey(2)).is_none(), "LRU victim evicted");
        assert!(tier.get("ns", CacheKey(3)).is_some());
        let (entries, bytes, _) = tier.stats();
        assert_eq!(entries, 2);
        assert!(bytes <= 100, "cap breached: {bytes}");
        // An oversized payload is refused rather than flushing the tier.
        tier.put("ns", CacheKey(4), &[4u8; 101]);
        assert!(tier.get("ns", CacheKey(4)).is_none());
        assert!(tier.get("ns", CacheKey(1)).is_some(), "tier survived oversize put");
        // Replacement does not double-count bytes.
        tier.put("ns", CacheKey(1), &[9u8; 10]);
        let (_, bytes, _) = tier.stats();
        assert!(bytes <= 100);
    }

    #[test]
    fn mem_tier_serves_sibling_handles_from_memory() {
        let dir = tmp_dir("memtier");
        let a = Store::open(StoreConfig::new(&dir)).unwrap();
        let b = Store::open(StoreConfig::new(&dir)).unwrap();
        a.put("req", CacheKey(1), b"{\"v\":1}").unwrap();
        a.flush().unwrap();
        let (_, _, hits_before) = b.mem_tier_stats().unwrap();
        // b never saw this put: the entry arrives via the read-through
        // index merge and the bytes via the shared memory tier.
        assert_eq!(b.get("req", CacheKey(1)).as_deref(), Some(&b"{\"v\":1}"[..]));
        let (_, _, hits_after) = b.mem_tier_stats().unwrap();
        assert!(hits_after > hits_before, "payload should come from the shared tier");
    }

    #[test]
    fn mem_tier_disabled_with_zero_budget() {
        let dir = tmp_dir("memoff");
        let store = Store::open(StoreConfig::new(&dir).with_mem_tier_bytes(0)).unwrap();
        assert!(store.mem_tier_stats().is_none());
        store.put("req", CacheKey(1), b"{}").unwrap();
        assert_eq!(store.get("req", CacheKey(1)).as_deref(), Some(&b"{}"[..]));
    }

    #[test]
    fn sibling_commits_are_visible_and_deletes_are_not_resurrected() {
        let dir = tmp_dir("sibling");
        let a = Store::open(StoreConfig::new(&dir).with_mem_tier_bytes(0)).unwrap();
        let b = Store::open(StoreConfig::new(&dir).with_mem_tier_bytes(0)).unwrap();

        // Commit via a; b picks it up without reopening.
        a.put("req", CacheKey(1), b"{\"a\":1}").unwrap();
        a.flush().unwrap();
        assert_eq!(b.get("req", CacheKey(1)).as_deref(), Some(&b"{\"a\":1}"[..]));

        // And the reverse direction.
        b.put("req", CacheKey(2), b"{\"b\":2}").unwrap();
        b.flush().unwrap();
        assert_eq!(a.get("req", CacheKey(2)).as_deref(), Some(&b"{\"b\":2}"[..]));

        // a removes an entry; b's next flush must not resurrect it from
        // its in-memory copy into a servable state (payload is gone, so
        // any stale index entry self-heals to a miss).
        a.remove("req", CacheKey(1));
        b.flush().unwrap();
        assert!(b.get("req", CacheKey(1)).is_none(), "deleted entry must stay deleted");
        assert!(a.get("req", CacheKey(1)).is_none());

        // A fresh handle sees exactly the surviving entry.
        let c = Store::open(StoreConfig::new(&dir).with_mem_tier_bytes(0)).unwrap();
        assert!(c.get("req", CacheKey(2)).is_some());
        assert!(c.get("req", CacheKey(1)).is_none());
    }

    #[test]
    fn gc_sweeps_stray_tmp_files() {
        let dir = tmp_dir("tmpsweep");
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        store.put("req", CacheKey(1), b"{}").unwrap();
        // A dead writer's leftovers, in the root and in a namespace dir.
        std::fs::write(dir.join("index.tmp.999.0"), "{").unwrap();
        std::fs::write(dir.join("req").join("dead.tmp.999.1"), "junk").unwrap();
        store.gc().unwrap();
        assert!(!dir.join("index.tmp.999.0").exists());
        assert!(!dir.join("req").join("dead.tmp.999.1").exists());
        assert!(store.get("req", CacheKey(1)).is_some(), "live entry untouched");
    }

    #[test]
    fn replacing_an_entry_does_not_double_count() {
        let store = Store::open(StoreConfig::new(tmp_dir("replace"))).unwrap();
        store.put("req", CacheKey(5), b"{\"v\":1}").unwrap();
        store.put("req", CacheKey(5), b"{\"v\":22}").unwrap();
        let s = store.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 8);
        assert_eq!(store.get("req", CacheKey(5)).as_deref(), Some(&b"{\"v\":22}"[..]));
    }
}
