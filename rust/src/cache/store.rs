//! The on-disk store: payload files + a single index with atomic
//! write-then-rename updates.
//!
//! Layout under the cache directory:
//!
//! ```text
//! <dir>/index.json          # {"version","clock","meta","entries":[..]}
//! <dir>/<namespace>/<key-hex>.bin   # one payload per entry
//! ```
//!
//! Payloads are opaque bytes to the store (the codec layer decides
//! between JSON text and the binary latent framing). The index is the
//! source of truth for LRU state and byte accounting; payloads are
//! content-addressed by [`CacheKey`] hex. Index updates go through a
//! temp file + `rename`, so a crash leaves either the old or the new
//! index — never a torn one.
//!
//! Open-time recovery distinguishes two failure shapes:
//!
//! - **Version skew** (the index parses but carries a different
//!   `CACHE_VERSION`): the store was written by another release whose
//!   payload encodings may differ — v2 kept request latents as JSON
//!   where v3 expects binary — so everything is flushed clean rather
//!   than scanned in and misread.
//! - **Corrupt/missing/truncated index**: same-version payloads are
//!   still trustworthy, so the index is rebuilt by scanning the payload
//!   directories (entries keep their bytes, LRU order resets). Files
//!   that are neither parseable JSON nor well-formed binary payloads
//!   are deleted during the scan.
//!
//! Neither path can make [`Store::open`] panic.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::binary;
use super::evict::{plan_evictions, EvictEntry};
use super::key::{CacheKey, CACHE_VERSION};

/// Default byte cap: plenty for plan fronts + calibration, bounded for
/// request latents.
pub const DEFAULT_MAX_BYTES: u64 = 256 * 1024 * 1024;
pub const DEFAULT_MAX_ENTRIES: usize = 65_536;

/// Puts between index persists. The index write is O(entries), and `put`
/// runs per request on the serving path, so inserts buffer and the index
/// catches up every N puts, on eviction, on structural ops, and on
/// `flush`/`Drop`. A hard crash can orphan at most N-1 recent payloads —
/// they are re-generated on miss and swept by `gc`, which the recovery
/// path already tolerates.
const PERSIST_EVERY: u32 = 16;

/// Store configuration (the `ServerConfig`/CLI cache knobs map to this).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    pub dir: PathBuf,
    /// Hard cap on total payload bytes (the eviction invariant).
    pub max_bytes: u64,
    /// Hard cap on entry count.
    pub max_entries: usize,
    /// Per-namespace time-to-live in seconds (absent = never expires,
    /// the default). An expired entry behaves like a miss on `get` and
    /// is removed on sight; `gc` sweeps the rest. A TTL of 0 expires
    /// entries immediately (useful in tests). Intended user: the
    /// `request` namespace, whose latents age out while calibration and
    /// plan artifacts persist.
    pub ttl_secs: BTreeMap<String, u64>,
}

impl StoreConfig {
    pub fn new(dir: impl Into<PathBuf>) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            max_bytes: DEFAULT_MAX_BYTES,
            max_entries: DEFAULT_MAX_ENTRIES,
            ttl_secs: BTreeMap::new(),
        }
    }

    pub fn with_max_bytes(mut self, max_bytes: u64) -> StoreConfig {
        self.max_bytes = max_bytes;
        self
    }

    pub fn with_max_entries(mut self, max_entries: usize) -> StoreConfig {
        self.max_entries = max_entries;
        self
    }

    /// Set a TTL for one namespace.
    pub fn with_ttl(mut self, namespace: &str, ttl_secs: u64) -> StoreConfig {
        self.ttl_secs.insert(namespace.to_string(), ttl_secs);
        self
    }
}

#[derive(Debug, Clone)]
struct EntryMeta {
    bytes: u64,
    last_used: u64,
    /// Unix seconds at insert time — the TTL anchor. Entries recovered
    /// from a payload scan count as created "now" (unknown age must not
    /// mass-expire a cache on recovery).
    created: u64,
}

/// Wall-clock seconds since the Unix epoch.
fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

struct Inner {
    /// (namespace, key) -> meta. BTreeMap keeps stats/persist ordering
    /// deterministic.
    entries: BTreeMap<(String, CacheKey), EntryMeta>,
    /// Logical LRU clock; bumped on every touch.
    clock: u64,
    /// Free-form persisted metadata (e.g. the manifest hash guarding the
    /// namespaces — see `namespaces.rs`).
    meta: BTreeMap<String, String>,
    /// LRU touches and buffered puts are persisted lazily; structural
    /// changes eagerly.
    dirty: bool,
    /// Puts since the last index persist (see [`PERSIST_EVERY`]).
    pending_puts: u32,
}

impl Inner {
    fn empty() -> Inner {
        Inner {
            entries: BTreeMap::new(),
            clock: 0,
            meta: BTreeMap::new(),
            dirty: true,
            pending_puts: 0,
        }
    }
}

/// Per-namespace usage summary.
#[derive(Debug, Clone)]
pub struct NamespaceStats {
    pub namespace: String,
    pub entries: usize,
    pub bytes: u64,
}

/// Point-in-time store summary (CLI `cache stats`).
#[derive(Debug, Clone)]
pub struct StoreStats {
    pub namespaces: Vec<NamespaceStats>,
    pub entries: usize,
    pub bytes: u64,
    pub max_bytes: u64,
    pub max_entries: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// What a `gc` pass cleaned up.
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Index entries whose payload file had vanished.
    pub dropped_missing: usize,
    /// Payload files on disk that no index entry claimed.
    pub removed_orphans: usize,
    /// Entries evicted to re-enforce the caps.
    pub evicted: usize,
    /// Entries swept because their namespace TTL had elapsed.
    pub expired: usize,
}

/// Content-addressed persistent store with LRU + byte-cap eviction.
pub struct Store {
    cfg: StoreConfig,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Store {
    /// Open (or create) a store. A version-skewed index flushes the
    /// store clean (old payload encodings must not be misread);
    /// corrupt/missing indexes recover by scanning payload files. Never
    /// panics on bad on-disk state.
    pub fn open(cfg: StoreConfig) -> Result<Store> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating cache dir {}", cfg.dir.display()))?;
        let inner = match load_index(&index_path(&cfg.dir)) {
            IndexState::Loaded(inner) => inner,
            IndexState::VersionSkew => {
                for d in namespace_dirs(&cfg.dir) {
                    let _ = std::fs::remove_dir_all(&d);
                }
                Inner::empty()
            }
            IndexState::Unusable => scan_payloads(&cfg.dir),
        };
        let store = Store {
            cfg,
            inner: Mutex::new(inner),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        };
        {
            // Re-enforce caps (the configured caps may have shrunk since
            // the index was written) and persist the recovered state.
            let mut inner = store.inner.lock().unwrap();
            store.evict_locked(&mut inner);
            store.persist_locked(&mut inner)?;
        }
        Ok(store)
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    fn payload_path(&self, ns: &str, key: CacheKey) -> PathBuf {
        self.cfg.dir.join(ns).join(format!("{}.bin", key.hex()))
    }

    /// True when the namespace has a TTL and the entry has outlived it.
    fn is_expired(&self, ns: &str, meta: &EntryMeta, now: u64) -> bool {
        self.cfg
            .ttl_secs
            .get(ns)
            .map_or(false, |&ttl| now >= meta.created.saturating_add(ttl))
    }

    /// Fetch a payload; touches LRU state on hit. Entries past their
    /// namespace TTL count as misses and are removed on sight.
    pub fn get(&self, ns: &str, key: CacheKey) -> Option<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let map_key = (ns.to_string(), key);
        let expired = match inner.entries.get(&map_key) {
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Some(meta) => self.is_expired(ns, meta, now_unix()),
        };
        if expired {
            inner.entries.remove(&map_key);
            let _ = std::fs::remove_file(self.payload_path(ns, key));
            // Lazily persisted (unlike structural removals): expiry can
            // run on the request hot path, and a stale index entry whose
            // payload is gone is already self-healed by the recovery
            // paths, so the O(entries) index write can wait for the next
            // batched flush.
            inner.dirty = true;
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        match std::fs::read(self.payload_path(ns, key)) {
            Ok(bytes) => {
                inner.clock += 1;
                let clock = inner.clock;
                if let Some(e) = inner.entries.get_mut(&map_key) {
                    e.last_used = clock;
                }
                inner.dirty = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(bytes)
            }
            Err(_) => {
                // Payload vanished underneath us: self-heal the index.
                inner.entries.remove(&map_key);
                inner.dirty = true;
                let _ = self.persist_locked(&mut inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or replace) a payload. Returns how many entries were
    /// evicted to stay under the caps.
    pub fn put(&self, ns: &str, key: CacheKey, payload: &[u8]) -> Result<usize> {
        if ns.is_empty() || ns.chars().any(|c| matches!(c, '/' | '\\' | '.')) {
            bail!("invalid cache namespace '{ns}'");
        }
        // Hold the lock across the payload write too, so concurrent puts
        // of the same key cannot race on the temp file.
        let mut inner = self.inner.lock().unwrap();
        let path = self.payload_path(ns, key);
        let parent = path.parent().expect("payload path has a parent");
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
        write_atomic(&path, payload)?;

        inner.clock += 1;
        let clock = inner.clock;
        inner.entries.insert(
            (ns.to_string(), key),
            EntryMeta { bytes: payload.len() as u64, last_used: clock, created: now_unix() },
        );
        let evicted = self.evict_locked(&mut inner);
        inner.dirty = true;
        inner.pending_puts += 1;
        // The index write is O(entries); buffer it on the hot path and
        // catch up periodically (and immediately after evictions, so the
        // on-disk index never references deleted payloads for long).
        if evicted > 0 || inner.pending_puts >= PERSIST_EVERY {
            self.persist_locked(&mut inner)?;
        }
        Ok(evicted)
    }

    /// Drop one entry. Returns whether it existed.
    pub fn remove(&self, ns: &str, key: CacheKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.entries.remove(&(ns.to_string(), key)).is_some();
        let _ = std::fs::remove_file(self.payload_path(ns, key));
        if existed {
            inner.dirty = true;
            let _ = self.persist_locked(&mut inner);
        }
        existed
    }

    /// Remove all entries, or all entries of one namespace. Also sweeps
    /// the payload directory so orphaned files go too.
    pub fn clear(&self, ns: Option<&str>) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.entries.len();
        match ns {
            Some(ns) => {
                inner.entries.retain(|(n, _), _| n.as_str() != ns);
                let _ = std::fs::remove_dir_all(self.cfg.dir.join(ns));
            }
            None => {
                inner.entries.clear();
                for d in namespace_dirs(&self.cfg.dir) {
                    let _ = std::fs::remove_dir_all(d);
                }
            }
        }
        let removed = before - inner.entries.len();
        inner.dirty = true;
        let _ = self.persist_locked(&mut inner);
        removed
    }

    /// Validate index<->disk agreement, sweep expired entries, and
    /// re-enforce the caps.
    pub fn gc(&self) -> Result<GcReport> {
        let mut inner = self.inner.lock().unwrap();
        let mut report = GcReport::default();

        // 0. Entries past their namespace TTL.
        let now = now_unix();
        let expired: Vec<(String, CacheKey)> = inner
            .entries
            .iter()
            .filter(|((ns, _), meta)| self.is_expired(ns, meta, now))
            .map(|(k, _)| k.clone())
            .collect();
        report.expired = expired.len();
        for (ns, key) in expired {
            let _ = std::fs::remove_file(self.payload_path(&ns, key));
            inner.entries.remove(&(ns, key));
        }

        // 1. Index entries whose payload is gone.
        let missing: Vec<(String, CacheKey)> = inner
            .entries
            .keys()
            .filter(|(ns, key)| !self.payload_path(ns, *key).exists())
            .cloned()
            .collect();
        report.dropped_missing = missing.len();
        for k in missing {
            inner.entries.remove(&k);
        }

        // 2. Files on disk that the index does not claim.
        for dir in namespace_dirs(&self.cfg.dir) {
            let ns = dir.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            for (path, key) in payload_files(&dir) {
                if !inner.entries.contains_key(&(ns.clone(), key)) {
                    let _ = std::fs::remove_file(path);
                    report.removed_orphans += 1;
                }
            }
        }

        // 3. Caps.
        report.evicted = self.evict_locked(&mut inner);

        inner.dirty = true;
        self.persist_locked(&mut inner)?;
        Ok(report)
    }

    /// Persisted metadata lookup (e.g. the manifest hash).
    pub fn meta(&self, k: &str) -> Option<String> {
        self.inner.lock().unwrap().meta.get(k).cloned()
    }

    /// Set persisted metadata.
    pub fn set_meta(&self, k: &str, v: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.meta.insert(k.to_string(), v.to_string());
        inner.dirty = true;
        self.persist_locked(&mut inner)
    }

    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        let mut per_ns: BTreeMap<&str, (usize, u64)> = BTreeMap::new();
        for ((ns, _), meta) in &inner.entries {
            let slot = per_ns.entry(ns.as_str()).or_default();
            slot.0 += 1;
            slot.1 += meta.bytes;
        }
        StoreStats {
            namespaces: per_ns
                .into_iter()
                .map(|(ns, (entries, bytes))| NamespaceStats {
                    namespace: ns.to_string(),
                    entries,
                    bytes,
                })
                .collect(),
            entries: inner.entries.len(),
            bytes: inner.entries.values().map(|e| e.bytes).sum(),
            max_bytes: self.cfg.max_bytes,
            max_entries: self.cfg.max_entries,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Persist any lazily-buffered LRU touches.
    pub fn flush(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.persist_locked(&mut inner)
    }

    // ------------------------------------------------------------ internals

    /// Enforce the caps; returns number of entries evicted.
    fn evict_locked(&self, inner: &mut Inner) -> usize {
        let keys: Vec<(String, CacheKey)> = inner.entries.keys().cloned().collect();
        let view: Vec<EvictEntry> = keys
            .iter()
            .map(|k| {
                let m = &inner.entries[k];
                EvictEntry { key: k.1, bytes: m.bytes, last_used: m.last_used }
            })
            .collect();
        let plan = plan_evictions(&view, self.cfg.max_bytes, self.cfg.max_entries);
        for &i in &plan {
            let (ns, key) = &keys[i];
            inner.entries.remove(&(ns.clone(), *key));
            let _ = std::fs::remove_file(self.payload_path(ns, *key));
        }
        if !plan.is_empty() {
            inner.dirty = true;
        }
        self.evictions.fetch_add(plan.len() as u64, Ordering::Relaxed);
        plan.len()
    }

    fn persist_locked(&self, inner: &mut Inner) -> Result<()> {
        if !inner.dirty {
            return Ok(());
        }
        let entries = Json::Arr(
            inner
                .entries
                .iter()
                .map(|((ns, key), m)| {
                    Json::obj(vec![
                        ("ns", Json::str(ns)),
                        ("key", Json::str(&key.hex())),
                        ("bytes", Json::num(m.bytes as f64)),
                        ("last_used", Json::num(m.last_used as f64)),
                        ("created", Json::num(m.created as f64)),
                    ])
                })
                .collect(),
        );
        let meta = Json::Obj(
            inner.meta.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect(),
        );
        let index = Json::obj(vec![
            ("version", Json::num(CACHE_VERSION as f64)),
            ("clock", Json::num(inner.clock as f64)),
            ("meta", meta),
            ("entries", entries),
        ]);
        write_atomic(&index_path(&self.cfg.dir), index.to_string().as_bytes())?;
        inner.dirty = false;
        inner.pending_puts = 0;
        Ok(())
    }
}

impl Drop for Store {
    fn drop(&mut self) {
        // Best-effort: flush buffered LRU touches.
        if let Ok(mut inner) = self.inner.lock() {
            let _ = self.persist_locked(&mut inner);
        }
    }
}

fn index_path(dir: &Path) -> PathBuf {
    dir.join("index.json")
}

/// Write-then-rename so readers never observe a torn file.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// How an on-disk index read went.
enum IndexState {
    Loaded(Inner),
    /// Parsed, but written by a different `CACHE_VERSION` — flush.
    VersionSkew,
    /// Missing/corrupt/truncated — rebuild by scanning payloads.
    Unusable,
}

/// Parse the index, classifying failures (see [`IndexState`]).
fn load_index(path: &Path) -> IndexState {
    let Ok(text) = std::fs::read_to_string(path) else {
        return IndexState::Unusable;
    };
    let Ok(j) = Json::parse(&text) else {
        return IndexState::Unusable;
    };
    match j.get_usize("version") {
        Some(v) if v == CACHE_VERSION as usize => {}
        Some(_) => return IndexState::VersionSkew,
        None => return IndexState::Unusable,
    }
    let mut entries = BTreeMap::new();
    let now = now_unix();
    let Some(list) = j.get("entries").and_then(Json::as_arr) else {
        return IndexState::Unusable;
    };
    for e in list {
        let (Some(ns), Some(key_hex), Some(bytes)) =
            (e.get_str("ns"), e.get_str("key"), e.get_usize("bytes"))
        else {
            return IndexState::Unusable;
        };
        let Some(key) = CacheKey::from_hex(key_hex) else {
            return IndexState::Unusable;
        };
        entries.insert(
            (ns.to_string(), key),
            EntryMeta {
                bytes: bytes as u64,
                last_used: e.get_usize("last_used").unwrap_or(0) as u64,
                created: e.get_usize("created").map(|v| v as u64).unwrap_or(now),
            },
        );
    }
    let meta = j
        .get("meta")
        .and_then(Json::as_obj)
        .map(|o| {
            o.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default();
    IndexState::Loaded(Inner {
        entries,
        clock: j.get_usize("clock").unwrap_or(0) as u64,
        meta,
        dirty: false,
        pending_puts: 0,
    })
}

/// True when `bytes` is a healthy payload in either on-disk encoding.
fn payload_looks_valid(bytes: &[u8]) -> bool {
    binary::is_well_formed(bytes)
        || std::str::from_utf8(bytes)
            .ok()
            .map(|t| Json::parse(t).is_ok())
            .unwrap_or(false)
}

/// Rebuild an index by scanning payload directories (recovery path for a
/// same-version store whose index is unusable). Payloads that are
/// neither parseable JSON nor well-formed binary are deleted, as are
/// stray pre-v3 `.json` payload files; LRU order resets.
fn scan_payloads(dir: &Path) -> Inner {
    let mut entries = BTreeMap::new();
    let mut clock = 0;
    for ns_dir in namespace_dirs(dir) {
        let ns = ns_dir.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        sweep_legacy_payloads(&ns_dir);
        for (path, key) in payload_files(&ns_dir) {
            let valid = std::fs::read(&path)
                .map(|bytes| payload_looks_valid(&bytes))
                .unwrap_or(false);
            if !valid {
                let _ = std::fs::remove_file(&path);
                continue;
            }
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            clock += 1;
            entries.insert(
                (ns.clone(), key),
                EntryMeta { bytes, last_used: clock, created: now_unix() },
            );
        }
    }
    Inner { entries, clock, meta: BTreeMap::new(), dirty: true, pending_puts: 0 }
}

/// Delete pre-v3 `<hex>.json` payload files found during a scan — they
/// belong to a store generation whose index is already gone.
fn sweep_legacy_payloads(ns_dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(ns_dir) {
        for e in rd.flatten() {
            let p = e.path();
            let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if p.extension().and_then(|s| s.to_str()) == Some("json")
                && CacheKey::from_hex(stem).is_some()
            {
                let _ = std::fs::remove_file(&p);
            }
        }
    }
}

/// Subdirectories of the cache dir (one per namespace).
fn namespace_dirs(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

/// `<16-hex>.bin` payload files inside one namespace directory.
fn payload_files(ns_dir: &Path) -> Vec<(PathBuf, CacheKey)> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(ns_dir) {
        for e in rd.flatten() {
            let p = e.path();
            let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if p.extension().and_then(|s| s.to_str()) == Some("bin") {
                if let Some(key) = CacheKey::from_hex(stem) {
                    out.push((p, key));
                }
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sdacc_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_get_roundtrip_and_counters() {
        let store = Store::open(StoreConfig::new(tmp_dir("roundtrip"))).unwrap();
        let k = CacheKey(42);
        assert_eq!(store.get("req", k), None);
        store.put("req", k, b"{\"a\":1}").unwrap();
        assert_eq!(store.get("req", k).as_deref(), Some(&b"{\"a\":1}"[..]));
        let s = store.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 7);
    }

    #[test]
    fn binary_payload_bytes_roundtrip_untouched() {
        // Payloads are opaque bytes: non-UTF8 binary must come back
        // byte-for-byte.
        let store = Store::open(StoreConfig::new(tmp_dir("binbytes"))).unwrap();
        let payload: Vec<u8> = (0..=255u8).collect();
        store.put("req", CacheKey(7), &payload).unwrap();
        assert_eq!(store.get("req", CacheKey(7)).as_deref(), Some(&payload[..]));
        assert_eq!(store.stats().bytes, 256);
    }

    #[test]
    fn survives_reopen() {
        let dir = tmp_dir("reopen");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("plan", CacheKey(1), b"{\"x\":[1,2]}").unwrap();
            store.put("calib", CacheKey(2), b"{\"y\":3}").unwrap();
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("plan", CacheKey(1)).as_deref(), Some(&b"{\"x\":[1,2]}"[..]));
        assert_eq!(store.get("calib", CacheKey(2)).as_deref(), Some(&b"{\"y\":3}"[..]));
        assert_eq!(store.stats().entries, 2);
    }

    #[test]
    fn byte_cap_never_exceeded() {
        let cfg = StoreConfig::new(tmp_dir("cap")).with_max_bytes(30);
        let store = Store::open(cfg).unwrap();
        for i in 0..10u64 {
            store.put("req", CacheKey(i), b"{\"v\":1234567}").unwrap(); // 13 bytes
            assert!(store.stats().bytes <= 30, "cap breached at i={i}");
        }
        let s = store.stats();
        assert!(s.evictions >= 8, "evictions {}", s.evictions);
        assert_eq!(s.entries, 2);
        // Newest entries survive.
        assert!(store.get("req", CacheKey(9)).is_some());
        assert!(store.get("req", CacheKey(0)).is_none());
    }

    #[test]
    fn lru_respects_touches() {
        let cfg = StoreConfig::new(tmp_dir("lru")).with_max_entries(2).with_max_bytes(1 << 20);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(1), b"{}").unwrap();
        store.put("req", CacheKey(2), b"{}").unwrap();
        // Touch 1 so 2 becomes the LRU victim.
        assert!(store.get("req", CacheKey(1)).is_some());
        store.put("req", CacheKey(3), b"{}").unwrap();
        assert!(store.get("req", CacheKey(1)).is_some());
        assert!(store.get("req", CacheKey(2)).is_none());
        assert!(store.get("req", CacheKey(3)).is_some());
    }

    #[test]
    fn buffered_puts_flush_every_n_and_orphans_are_gc_able() {
        // Crash (no Drop flush) right after one buffered put: the payload
        // is an orphan — not served, but cleanly reclaimed by gc.
        let dir = tmp_dir("crash1");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(1), b"{\"v\":1}").unwrap();
            std::mem::forget(store); // simulated hard crash
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert!(store.get("req", CacheKey(1)).is_none(), "buffered put lost on crash");
        assert_eq!(store.gc().unwrap().removed_orphans, 1);
        drop(store);

        // After PERSIST_EVERY puts the index has caught up, so a crash
        // loses nothing.
        let dir = tmp_dir("crash2");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            for i in 0..super::PERSIST_EVERY as u64 {
                store.put("req", CacheKey(i), b"{}").unwrap();
            }
            std::mem::forget(store);
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, super::PERSIST_EVERY as usize);
    }

    #[test]
    fn corrupt_index_recovers_by_scanning() {
        let dir = tmp_dir("corrupt");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(7), b"{\"keep\":true}").unwrap();
        }
        std::fs::write(dir.join("index.json"), "{\"version\":1,\"entr").unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("req", CacheKey(7)).as_deref(), Some(&b"{\"keep\":true}"[..]));
    }

    #[test]
    fn scan_keeps_wellformed_binary_payloads() {
        use crate::coordinator::{GenResult, GenStats};
        use crate::pas::plan::StepAction;
        use crate::runtime::Tensor;
        let dir = tmp_dir("scanbin");
        let res = GenResult {
            latent: Tensor::new(vec![2, 2], vec![1.0, -2.0, 0.5, f32::NAN]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full],
                step_ms: vec![1.0],
                mac_reduction: 1.0,
                total_ms: 1.0,
            },
        };
        let payload = super::binary::encode_gen_result(&res);
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("request", CacheKey(3), &payload).unwrap();
            // A garbage sibling that is neither JSON nor binary.
            store.put("request", CacheKey(4), &[0xff, 0x00, 0x12]).unwrap();
        }
        std::fs::remove_file(dir.join("index.json")).unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.get("request", CacheKey(3)).as_deref(), Some(&payload[..]));
        assert!(store.get("request", CacheKey(4)).is_none(), "garbage dropped by scan");
    }

    #[test]
    fn version_skew_flushes_cleanly() {
        // A store written by an older CACHE_VERSION must be flushed on
        // open — its payload encodings (v2: JSON request latents) would
        // be misread by the current codecs — not recovered by scan.
        let dir = tmp_dir("version");
        let ns = dir.join("request");
        std::fs::create_dir_all(&ns).unwrap();
        let key = CacheKey(9);
        // v2 layout: `<hex>.json` payload + version-2 index naming it.
        let payload_path = ns.join(format!("{}.json", key.hex()));
        std::fs::write(&payload_path, "{\"dims\":[1],\"latent\":[0.5]}").unwrap();
        std::fs::write(
            dir.join("index.json"),
            format!(
                "{{\"version\":2,\"clock\":1,\"meta\":{{}},\"entries\":[{{\"ns\":\"request\",\
                 \"key\":\"{}\",\"bytes\":27,\"last_used\":1,\"created\":0}}]}}",
                key.hex()
            ),
        )
        .unwrap();

        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, 0, "old entries must not be served");
        assert!(store.get("request", key).is_none());
        assert!(!payload_path.exists(), "old payload flushed from disk");

        // A future version is flushed the same way.
        drop(store);
        std::fs::write(dir.join("index.json"), "{\"version\":999,\"entries\":[]}").unwrap();
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn gc_reconciles_disk_and_index() {
        let dir = tmp_dir("gc");
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        store.put("req", CacheKey(1), b"{\"a\":1}").unwrap();
        store.put("req", CacheKey(2), b"{\"b\":2}").unwrap();
        // Vanish one payload; drop one orphan file in.
        std::fs::remove_file(dir.join("req").join(format!("{}.bin", CacheKey(1).hex()))).unwrap();
        std::fs::write(dir.join("req").join(format!("{}.bin", CacheKey(99).hex())), "{}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.dropped_missing, 1);
        assert_eq!(report.removed_orphans, 1);
        assert_eq!(store.stats().entries, 1);
        assert!(store.get("req", CacheKey(2)).is_some());
    }

    #[test]
    fn clear_namespace_only_hits_that_namespace() {
        let store = Store::open(StoreConfig::new(tmp_dir("clearns"))).unwrap();
        store.put("req", CacheKey(1), b"{}").unwrap();
        store.put("plan", CacheKey(2), b"{}").unwrap();
        assert_eq!(store.clear(Some("req")), 1);
        assert!(store.get("req", CacheKey(1)).is_none());
        assert!(store.get("plan", CacheKey(2)).is_some());
        assert_eq!(store.clear(None), 1);
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn ttl_expires_only_configured_namespaces() {
        // TTL 0 on "req": entries expire on the very next access.
        let cfg = StoreConfig::new(tmp_dir("ttl_ns")).with_ttl("req", 0);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(1), b"{\"v\":1}").unwrap();
        store.put("plan", CacheKey(2), b"{\"v\":2}").unwrap();
        assert_eq!(store.get("req", CacheKey(1)), None, "expired");
        assert_eq!(store.get("plan", CacheKey(2)).as_deref(), Some(&b"{\"v\":2}"[..]));
        // The expired entry was evicted for real: index and payload gone.
        let s = store.stats();
        assert_eq!(s.entries, 1);
        assert!(!store.dir().join("req").join(format!("{}.bin", CacheKey(1).hex())).exists());
        // A generous TTL does not expire fresh entries.
        let cfg = StoreConfig::new(tmp_dir("ttl_fresh")).with_ttl("req", 3600);
        let store = Store::open(cfg).unwrap();
        store.put("req", CacheKey(3), b"{}").unwrap();
        assert!(store.get("req", CacheKey(3)).is_some());
    }

    #[test]
    fn gc_sweeps_expired_entries() {
        let cfg = StoreConfig::new(tmp_dir("ttl_gc")).with_ttl("req", 0);
        let store = Store::open(cfg).unwrap();
        for i in 0..3u64 {
            store.put("req", CacheKey(i), b"{}").unwrap();
        }
        store.put("calib", CacheKey(9), b"{}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.expired, 3);
        assert_eq!(store.stats().entries, 1, "non-TTL namespace survives");
        // A second pass finds nothing left to sweep.
        assert_eq!(store.gc().unwrap().expired, 0);
    }

    #[test]
    fn ttl_anchor_survives_reopen() {
        // An entry written without TTL stays valid when the store is
        // reopened with a generous TTL (created timestamp persisted),
        // and expires under a zero TTL.
        let dir = tmp_dir("ttl_reopen");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.put("req", CacheKey(5), b"{\"keep\":1}").unwrap();
        }
        {
            let store = Store::open(StoreConfig::new(&dir).with_ttl("req", 3600)).unwrap();
            assert!(store.get("req", CacheKey(5)).is_some(), "fresh under 1h TTL");
        }
        let store = Store::open(StoreConfig::new(&dir).with_ttl("req", 0)).unwrap();
        assert!(store.get("req", CacheKey(5)).is_none(), "expired under 0s TTL");
    }

    #[test]
    fn meta_persists_across_reopen() {
        let dir = tmp_dir("meta");
        {
            let store = Store::open(StoreConfig::new(&dir)).unwrap();
            store.set_meta("manifest_hash", "abc123").unwrap();
        }
        let store = Store::open(StoreConfig::new(&dir)).unwrap();
        assert_eq!(store.meta("manifest_hash").as_deref(), Some("abc123"));
    }

    #[test]
    fn replacing_an_entry_does_not_double_count() {
        let store = Store::open(StoreConfig::new(tmp_dir("replace"))).unwrap();
        store.put("req", CacheKey(5), b"{\"v\":1}").unwrap();
        store.put("req", CacheKey(5), b"{\"v\":22}").unwrap();
        let s = store.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 8);
        assert_eq!(store.get("req", CacheKey(5)).as_deref(), Some(&b"{\"v\":22}"[..]));
    }
}
