//! Generation coordinator (L3): owns the denoising loop.
//!
//! For each batch of requests the coordinator tokenises prompts, runs the
//! text encoder once, initialises seeded Gaussian latents, then walks the
//! scheduler timesteps executing either the full U-Net artifact (which
//! refreshes the feature cache) or a partial artifact (which consumes it)
//! according to the phase-aware sampling plan. Python is never invoked:
//! every compute step is a PJRT execution of an AOT artifact.
//!
//! The step loop is zero-copy on the host side: loop-invariant inputs
//! (text context, guidance, feature caches) cross the runtime-thread
//! boundary as [`Input::F32Ref`] Arc shares, the latent travels as an
//! Arc-backed [`Tensor`] clone (refcount bump, no buffer copy), and the
//! scheduler update runs in place via [`Sampler::step_mut`] — so a
//! 50-step generation reuses one latent buffer instead of re-copying
//! latent + context + guidance on every step.
//!
//! ## Typed request API
//!
//! Requests are validated at construction ([`GenRequest::builder`] /
//! [`GenRequest::validate`]): steps >= 1, finite guidance, executable
//! plan. The sampler is the [`SamplerKind`] enum rather than a string
//! (its [`SamplerKind::as_str`] bytes are what cache keys hash, so the
//! `String` -> enum migration left every request-cache digest
//! unchanged). Errors cross the API boundary as the structured
//! [`SdError`]; internals keep `anyhow` and convert at the edge.
//!
//! ## Step observability & cancellation
//!
//! The `*_observed` entry points thread a [`StepObserver`] through the
//! denoising loop: `on_step(i, action, ms)` fires after every executed
//! step and `should_cancel()` is checked once per step *before* the
//! U-Net execution, so a cancellation aborts a 50-step run mid-flight
//! (returning [`SdError::Cancelled`]) instead of only at dequeue time.
//! The plain `generate_batch`/`generate_many`/`generate_one` entry
//! points are thin wrappers over the observed variants with a no-op
//! observer — PAS search and the benches are untouched by the redesign.

use std::fmt;
use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{Cache, StoreConfig};
use crate::models::inventory::sd_tiny;
use crate::pas::cost::CostModel;
use crate::pas::plan::{plan_is_executable, SamplingPlan, StepAction};
use crate::policy::{update_trajectory, PolicySpec, StepDecision, TrajectoryStats};
use crate::quant::format::{emulate_activations, QuantScheme};
use crate::runtime::{BackendKind, Input, Runtime, RuntimeHandle, Tensor, TensorI32};
use crate::scheduler::{Ddim, NoiseSchedule, Pndm, Sampler};
use crate::util::rng::Pcg32;

// ------------------------------------------------------------------ errors

/// Structured error vocabulary at the serving/coordination API boundary.
///
/// Internals keep `anyhow` for its context chains; the edge converts
/// via [`SdError::runtime`] (lossy but displayable) and the reverse
/// direction is free: `SdError` implements `std::error::Error`, so `?`
/// and `anyhow::Error::from` lift it back into `anyhow` for the
/// source-compatible blocking wrappers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SdError {
    /// The request failed validation before any work ran (bad steps,
    /// non-finite guidance, non-executable plan, unknown sampler,
    /// incompatible batch, unsupported batch size).
    InvalidRequest(String),
    /// Bounded admission refused the request: the server queue is at
    /// its configured capacity.
    QueueFull,
    /// The job's [`CancelToken`](crate::server::CancelToken) fired —
    /// either before dequeue or mid-run via
    /// [`StepObserver::should_cancel`].
    Cancelled,
    /// The job's deadline elapsed before a worker could run it.
    DeadlineExceeded,
    /// Generation itself failed (runtime/PJRT/codec errors). Carries
    /// the flattened `anyhow` context chain.
    Runtime(String),
}

impl SdError {
    pub fn invalid(msg: impl Into<String>) -> SdError {
        SdError::InvalidRequest(msg.into())
    }

    /// Convert an internal error (typically `anyhow::Error`) at the edge.
    pub fn runtime(e: impl fmt::Display) -> SdError {
        SdError::Runtime(format!("{e:#}"))
    }

    pub fn is_cancelled(&self) -> bool {
        matches!(self, SdError::Cancelled)
    }

    /// THE transient-vs-permanent classification seam for the server's
    /// retry policy. Only `Runtime` errors carrying the fault-injection
    /// marker ([`runtime::TRANSIENT_MARKER`](crate::runtime::TRANSIENT_MARKER))
    /// qualify: they describe a call that failed *this time* and may
    /// succeed on re-dispatch. Everything else is deterministic —
    /// `InvalidRequest` and shape/name contract errors would fail
    /// identically on every attempt, `Cancelled`/`DeadlineExceeded`/
    /// `QueueFull` are final verdicts — so retrying would only burn
    /// capacity repeating the same failure.
    pub fn is_retryable(&self) -> bool {
        matches!(self, SdError::Runtime(m) if m.contains(crate::runtime::TRANSIENT_MARKER))
    }
}

impl fmt::Display for SdError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SdError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            SdError::QueueFull => f.write_str("queue full: request rejected by admission control"),
            SdError::Cancelled => f.write_str("cancelled"),
            SdError::DeadlineExceeded => f.write_str("deadline exceeded"),
            SdError::Runtime(m) => write!(f, "runtime error: {m}"),
        }
    }
}

impl std::error::Error for SdError {}

// ----------------------------------------------------------------- sampler

/// The sampler vocabulary, as a real enum instead of a `String` field.
///
/// **Cache-key stability rule:** [`SamplerKind::as_str`] returns exactly
/// the bytes the retired `sampler: String` field carried ("ddim" /
/// "pndm"), and the request-cache key hashes those bytes — so the
/// migration changed no digest and `CACHE_VERSION` did not move. Any
/// future variant must hash a string no old request could have produced,
/// and renaming an existing variant's `as_str` bytes requires a
/// `CACHE_VERSION` bump.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SamplerKind {
    /// Deterministic DDIM (eta = 0).
    Ddim,
    /// PNDM in its PLMS form — the paper's scheduler (default).
    #[default]
    Pndm,
}

impl SamplerKind {
    pub const ALL: [SamplerKind; 2] = [SamplerKind::Ddim, SamplerKind::Pndm];

    /// Canonical name; these exact bytes feed the cache-key hasher.
    pub fn as_str(self) -> &'static str {
        match self {
            SamplerKind::Ddim => "ddim",
            SamplerKind::Pndm => "pndm",
        }
    }

    /// Construct the sampler — an exhaustive match, so the stringly
    /// `make_sampler` panic arm cannot be reached from the serving
    /// path (adding a variant is a compile error here, not a worker
    /// panic at the first request).
    pub fn build(self, sched: NoiseSchedule, n_steps: usize) -> Box<dyn Sampler + Send> {
        match self {
            SamplerKind::Ddim => Box::new(Ddim::new(sched, n_steps)),
            SamplerKind::Pndm => Box::new(Pndm::new(sched, n_steps)),
        }
    }
}

impl fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for SamplerKind {
    type Err = SdError;

    fn from_str(s: &str) -> Result<SamplerKind, SdError> {
        match s {
            "ddim" => Ok(SamplerKind::Ddim),
            "pndm" => Ok(SamplerKind::Pndm),
            other => Err(SdError::invalid(format!("unknown sampler '{other}' (ddim|pndm)"))),
        }
    }
}

/// Infallible-looking conversion for literals (`req.sampler =
/// "ddim".into()`), kept for source compatibility with the `String`
/// era. Panics on an unknown name — exactly where the old string field
/// panicked later inside `make_sampler`; fallible callers should use
/// `FromStr` instead.
impl From<&str> for SamplerKind {
    fn from(s: &str) -> SamplerKind {
        s.parse().unwrap_or_else(|e| panic!("{e}"))
    }
}

// ----------------------------------------------------------------- request

/// One text-to-image generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub seed: u64,
    pub steps: usize,
    pub guidance: f32,
    pub sampler: SamplerKind,
    pub plan: SamplingPlan,
    /// Mixed-precision scheme: `None` runs the artifacts untouched;
    /// `Some` fake-quantises the U-Net output every step (deterministic
    /// reduced-precision emulation — the artifacts themselves stay fp32).
    pub quant: Option<QuantScheme>,
    /// Approximation policy: how the denoising loop trades compute for
    /// quality ([`crate::policy`]). The default `Pas` reproduces the
    /// pre-policy-seam behaviour bit for bit; the spec participates in
    /// `batch_key()` and the request-cache key, so results produced
    /// under different policies can never batch together or satisfy
    /// each other's cache lookups.
    pub policy: PolicySpec,
}

impl GenRequest {
    pub fn new(prompt: &str, seed: u64) -> GenRequest {
        GenRequest {
            prompt: prompt.to_string(),
            seed,
            steps: 50,
            guidance: 7.5,
            sampler: SamplerKind::Pndm,
            plan: SamplingPlan::Full,
            quant: None,
            policy: PolicySpec::Pas,
        }
    }

    /// Validating builder: invalid requests fail at construction with a
    /// typed [`SdError::InvalidRequest`] instead of deep inside
    /// `generate_batch`.
    pub fn builder(prompt: &str, seed: u64) -> GenRequestBuilder {
        GenRequestBuilder { req: GenRequest::new(prompt, seed) }
    }

    /// The plan-independent field rules (steps >= 1, finite guidance);
    /// the execution path calls this and checks the plan against the
    /// actions vec it builds anyway, instead of expanding it twice.
    fn validate_fields(&self) -> Result<(), SdError> {
        if self.steps == 0 {
            return Err(SdError::invalid("steps must be >= 1"));
        }
        if !self.guidance.is_finite() {
            return Err(SdError::invalid(format!(
                "guidance must be finite (got {})",
                self.guidance
            )));
        }
        Ok(())
    }

    /// The construction-time validity rules: steps >= 1, finite
    /// guidance, and (for concrete plans) an executable action sequence.
    /// `Auto` plans validate after resolution (`resolve_plan` always
    /// yields `Full` or a searched — hence executable — config).
    pub fn validate(&self) -> Result<(), SdError> {
        self.validate_fields()?;
        if !matches!(self.plan, SamplingPlan::Auto)
            && !plan_is_executable(&self.plan.actions(self.steps))
        {
            return Err(SdError::invalid(
                "plan is not executable (partial step before any full step)",
            ));
        }
        Ok(())
    }

    /// Batching key: requests sharing it can run lockstep.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            steps: self.steps,
            sampler: self.sampler,
            plan: self.plan,
            guidance_bits: self.guidance.to_bits(),
            quant: self.quant,
            policy: self.policy,
        }
    }
}

/// Builder returned by [`GenRequest::builder`]; `build()` runs
/// [`GenRequest::validate`].
#[derive(Debug, Clone)]
pub struct GenRequestBuilder {
    req: GenRequest,
}

impl GenRequestBuilder {
    pub fn steps(mut self, steps: usize) -> Self {
        self.req.steps = steps;
        self
    }

    pub fn guidance(mut self, guidance: f32) -> Self {
        self.req.guidance = guidance;
        self
    }

    pub fn sampler(mut self, sampler: SamplerKind) -> Self {
        self.req.sampler = sampler;
        self
    }

    pub fn plan(mut self, plan: SamplingPlan) -> Self {
        self.req.plan = plan;
        self
    }

    pub fn quant(mut self, quant: QuantScheme) -> Self {
        self.req.quant = Some(quant);
        self
    }

    pub fn policy(mut self, policy: PolicySpec) -> Self {
        self.req.policy = policy;
        self
    }

    pub fn build(self) -> Result<GenRequest, SdError> {
        self.req.validate()?;
        Ok(self.req)
    }
}

/// Structured batching key (steps/sampler/plan/guidance/quant must match
/// to run lockstep — the fake-quant round-trip applies to the whole
/// batched eps tensor, so mixed-precision requests can only batch with
/// requests of the same scheme). A real `Hash + Ord` type rather than a
/// lossy `format!("{:?}")` string, so the batcher can use it as a map key
/// directly and the cache key derivation hashes the same fields without
/// re-parsing. Guidance is carried as its exact f32 bit pattern
/// (`f32` itself has no `Eq`/`Hash`). Since the sampler became an enum
/// the key is `Copy`-cheap end to end.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    pub steps: usize,
    pub sampler: SamplerKind,
    pub plan: SamplingPlan,
    pub guidance_bits: u32,
    pub quant: Option<QuantScheme>,
    /// Approximation policy: step schedules and online overrides are
    /// batch-wide decisions, so requests under different policies can
    /// never run lockstep.
    pub policy: PolicySpec,
}

/// Per-request generation outcome.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Final denoised latent, (L, latent_c).
    pub latent: Tensor,
    pub stats: GenStats,
}

#[derive(Debug, Clone)]
pub struct GenStats {
    pub actions: Vec<StepAction>,
    pub step_ms: Vec<f64>,
    /// Eq. 3 MAC reduction of the executed plan (sd-tiny cost model).
    pub mac_reduction: f64,
    pub total_ms: f64,
}

impl GenStats {
    /// Executed full-U-Net steps (feeds the per-priority SLO ledger).
    pub fn full_steps(&self) -> u64 {
        self.actions
            .iter()
            .filter(|a| matches!(a, StepAction::Full))
            .count() as u64
    }

    /// Executed partial (cache-consuming) steps.
    pub fn partial_steps(&self) -> u64 {
        self.actions
            .iter()
            .filter(|a| matches!(a, StepAction::Partial(_)))
            .count() as u64
    }
}

// ---------------------------------------------------------------- observer

/// Step-level observability + cancellation/deadline hook threaded
/// through the denoising loop by the `*_observed` entry points.
///
/// `should_cancel` and `deadline_exceeded` are polled once per denoising
/// step *before* the U-Net executes, so flipping either aborts a run
/// mid-flight with [`SdError::Cancelled`] / [`SdError::DeadlineExceeded`]
/// — the contracts the serving layer's `CancelToken` and per-job
/// deadlines rely on (a job's latency budget is enforced *inside* the
/// loop, not only at admission and dequeue). Cancellation is checked
/// first, so a job that is both cancelled and expired reports
/// `Cancelled`. `on_step` fires after each executed step with the step
/// index, the action that ran, and its wall time; for a batched run all
/// hooks apply to the whole lockstep batch.
pub trait StepObserver {
    fn on_step(&self, _i: usize, _action: StepAction, _ms: f64) {}

    fn should_cancel(&self) -> bool {
        false
    }

    /// True when the run's step budget / wall-clock deadline is spent
    /// and the remaining steps should not execute.
    fn deadline_exceeded(&self) -> bool {
        false
    }
}

/// The do-nothing observer behind the plain (blocking) entry points.
pub struct NoopObserver;

impl StepObserver for NoopObserver {}

// ---------------------------------------------------------------- batching

/// Largest size in `sizes_ascending` that is <= `n`, falling back to
/// the smallest; `None` when no batch sizes exist at all (a manifest
/// with an empty `batch_sizes` table). THE batch-size selection policy:
/// the dynamic batcher (`server::batcher`) and the chunk planner below
/// both route through it, so they can never disagree on chunk shapes.
pub fn best_fit_batch(sizes_ascending: &[usize], n: usize) -> Option<usize> {
    sizes_ascending
        .iter()
        .rev()
        .find(|&&s| s <= n)
        .or_else(|| sizes_ascending.first())
        .copied()
}

/// Split `n` items into compiled batch sizes, largest-first greedy.
/// Every returned size is a *supported* artifact size; when `n` is
/// smaller than the smallest compiled artifact (or a tail remains), the
/// final chunk is the smallest supported size and the caller pads the
/// batch (repeat a lane) then slices the padded lanes back off.
/// An empty `supported_ascending` with work to place is a clean
/// [`SdError::Runtime`] — it used to panic via `expect("no batch
/// sizes")` inside `best_fit_batch`.
pub fn plan_chunks(supported_ascending: &[usize], mut n: usize) -> Result<Vec<usize>, SdError> {
    let mut out = Vec::new();
    while n > 0 {
        let take = best_fit_batch(supported_ascending, n).ok_or_else(|| {
            SdError::Runtime("no compiled batch sizes in the manifest".to_string())
        })?;
        out.push(take);
        n = n.saturating_sub(take);
    }
    Ok(out)
}

/// The coordinator: runtime handle + schedule + cost accounting.
pub struct Coordinator {
    runtime: RuntimeHandle,
    cost_tiny: CostModel,
}

impl Coordinator {
    pub fn new(runtime: RuntimeHandle) -> Coordinator {
        Coordinator { runtime, cost_tiny: CostModel::new(&sd_tiny()) }
    }

    pub fn runtime(&self) -> &RuntimeHandle {
        &self.runtime
    }

    /// Digest of the loaded AOT manifest — the cache invalidation anchor.
    pub fn manifest_hash(&self) -> u64 {
        self.runtime.manifest().hash
    }

    /// The resolved execution backend behind this coordinator.
    pub fn backend(&self) -> BackendKind {
        self.runtime.backend()
    }

    /// Open the persistent cache bound to this coordinator's manifest
    /// digest *and* backend kind — THE cache construction path, so sim
    /// results are always tagged apart from xla results (they are
    /// different latents and must never satisfy each other's lookups).
    pub fn open_cache(&self, cfg: StoreConfig) -> Result<Cache> {
        Cache::open_for(cfg, self.manifest_hash(), self.backend())
    }

    /// Resolve a `SamplingPlan::Auto` request against the plan cache:
    /// the best searched configuration for this (manifest, steps) cell,
    /// or `Full` when nothing has been searched yet. Non-Auto plans pass
    /// through untouched. Called by the server before batching so cache
    /// keys and batch keys always see a concrete plan.
    pub fn resolve_plan(&self, req: &GenRequest, cache: Option<&Cache>) -> GenRequest {
        if !matches!(req.plan, SamplingPlan::Auto) {
            return req.clone();
        }
        let mut out = req.clone();
        out.plan = cache
            .and_then(|c| c.best_plan(req.steps))
            .map(SamplingPlan::Pas)
            .unwrap_or(SamplingPlan::Full);
        out
    }

    /// Batch sizes with compiled artifacts, ascending.
    pub fn supported_batches(&self) -> Vec<usize> {
        let mut b = self.runtime.manifest().batch_sizes.clone();
        b.sort_unstable();
        b
    }

    /// Split `n` requests into supported batch sizes, largest first.
    /// Every size has a compiled artifact; see [`plan_chunks`] for the
    /// padding contract on the final chunk.
    pub fn chunk_sizes(&self, n: usize) -> Result<Vec<usize>, SdError> {
        plan_chunks(&self.supported_batches(), n)
    }

    /// Encode prompts (one text-encoder execution).
    pub fn encode_prompts(&self, prompts: &[String]) -> Result<Tensor> {
        let b = prompts.len();
        let m = &self.runtime.manifest().model;
        let mut toks = Vec::with_capacity(b * m.ctx_len);
        for p in prompts {
            toks.extend(self.runtime.manifest().tokenize(p));
        }
        let t = TensorI32::new(vec![b, m.ctx_len], toks)?;
        let name = Runtime::text_encoder(b);
        let out = self.runtime.execute(&name, &[Input::I32(t)])?;
        out.into_iter().next().ok_or_else(|| anyhow::anyhow!("empty text output"))
    }

    /// Seeded N(0,1) initial latent for one request, (L, latent_c).
    pub fn init_latent(&self, seed: u64) -> Tensor {
        let m = &self.runtime.manifest().model;
        let mut rng = Pcg32::new(seed, 0x1a7e47);
        Tensor::new(vec![m.latent_l(), m.latent_c], rng.gaussian_vec(m.latent_elems()))
            .expect("latent dims match element count")
    }

    /// Run one lockstep batch with a [`StepObserver`] in the loop. All
    /// requests must share `batch_key()` and the batch size must have
    /// compiled artifacts. Cancellation is polled before every step;
    /// a fired token aborts with [`SdError::Cancelled`] mid-run.
    pub fn generate_batch_observed(
        &self,
        reqs: &[GenRequest],
        obs: &dyn StepObserver,
    ) -> Result<Vec<GenResult>, SdError> {
        let b = reqs.len();
        if b == 0 {
            return Err(SdError::invalid("empty batch"));
        }
        if !self.supported_batches().contains(&b) {
            return Err(SdError::invalid(format!(
                "no artifacts for batch size {b} (have {:?})",
                self.supported_batches()
            )));
        }
        let key = reqs[0].batch_key();
        if reqs.iter().any(|r| r.batch_key() != key) {
            return Err(SdError::invalid("generate_batch: requests are not batch-compatible"));
        }
        // Field rules, then the plan checked against the actions vec
        // this function needs anyway (one expansion, not two); the cut
        // bound below is the only manifest-dependent rule.
        reqs[0].validate_fields()?;
        let m = self.runtime.manifest().model.clone();
        let steps = reqs[0].steps;
        // Approximation-policy seam: the policy owns the step schedule.
        // The default `PasPolicy` returns `plan.actions(steps)` verbatim,
        // so the legacy path is reproduced bit for bit.
        let policy = reqs[0].policy.build();
        let policy_id = policy.policy_id();
        let plan = policy.plan(steps, &reqs[0].plan);
        if !plan_is_executable(&plan) {
            return Err(SdError::invalid(
                "plan is not executable (partial step before any full step)",
            ));
        }
        let max_cut = m.max_cut;
        if let Some(StepAction::Partial(l)) =
            plan.iter().find(|a| matches!(a, StepAction::Partial(l) if *l > max_cut))
        {
            return Err(SdError::invalid(format!("plan uses cut {l} > compiled max_cut {max_cut}")));
        }

        let sched = NoiseSchedule::new(self.runtime.manifest().alpha_bar.clone());
        let mut sampler = reqs[0].sampler.build(sched, steps);
        let ts = sampler.timesteps().to_vec();

        // Text conditioning (one batched execution). Loop invariants are
        // Arc'd once and shared with the runtime by refcount each step.
        let prompts: Vec<String> = reqs.iter().map(|r| r.prompt.clone()).collect();
        let ctx = Arc::new(self.encode_prompts(&prompts).map_err(SdError::runtime)?);
        let g = Arc::new(Tensor::scalar(reqs[0].guidance));

        // Stacked latents: one buffer, stepped in place for all N steps.
        let lat_parts: Vec<Tensor> = reqs.iter().map(|r| self.init_latent(r.seed)).collect();
        let mut latent = Tensor::stack(&lat_parts).map_err(SdError::runtime)?;

        // Feature caches per cut level (refreshed by full steps).
        let mut caches: Vec<Option<Arc<Tensor>>> = vec![None; max_cut + 1];
        let mut step_ms = Vec::with_capacity(steps);

        // Per-lane activation precision: the request's explicit scheme
        // wins; otherwise the policy may pick one from the lane's own
        // prompt (text-conditioned precision). Lane-local on purpose —
        // the request cache key promises the latent is a function of
        // the request (prompt included) alone.
        let lane_schemes: Vec<Option<QuantScheme>> =
            reqs.iter().map(|r| r.quant.or_else(|| policy.quant_override(&r.prompt))).collect();

        // Buffer pool for the per-step timestep literal: allocated once,
        // refilled in place each step. The runtime thread drops its
        // input handles before responding, so `Arc::make_mut` finds the
        // buffer unique on every iteration and never copies.
        let mut t_in =
            Arc::new(Tensor::new(vec![b], vec![0.0f32; b]).map_err(SdError::runtime)?);

        // Online-policy state: executed actions (may diverge from the
        // plan via step-time overrides), and the latent-trajectory EWMA
        // that feeds those decisions. The trajectory is only tracked
        // when the policy asks for it, so plan-only policies pay zero
        // extra clones per step.
        let mut executed: Vec<StepAction> = Vec::with_capacity(steps);
        let needs_traj = policy.needs_trajectory();
        let mut traj = TrajectoryStats::default();
        let mut prev_eps: Option<Tensor> = None;
        let t_start = Instant::now();

        for i in 0..plan.len() {
            let mut action = plan[i];
            // Step-time hook: an online policy may override the planned
            // action from trajectory stability. Overrides are clamped to
            // what is executable right now — Full always is; Partial(l)
            // only when the cut is compiled and its cache is warm.
            if let StepDecision::Override(o) = policy.on_step_decision(i, &traj) {
                action = match o {
                    StepAction::Full => StepAction::Full,
                    StepAction::Partial(l) if l <= max_cut && caches[l].is_some() => o,
                    StepAction::Partial(_) => action,
                };
            }
            // Mid-flight cancellation and deadline/step-budget
            // enforcement: checked once per denoising step, before the
            // expensive U-Net execution. Cancellation wins when both
            // fired (the caller asked out; the budget is moot).
            if obs.should_cancel() {
                return Err(SdError::Cancelled);
            }
            if obs.deadline_exceeded() {
                return Err(SdError::DeadlineExceeded);
            }
            let t0 = Instant::now();
            Arc::make_mut(&mut t_in).make_mut().fill(ts[i] as f32);
            let mut eps = match action {
                StepAction::Full => {
                    let out = self
                        .runtime
                        .execute(
                            &Runtime::unet_full(b),
                            &[
                                Input::F32(latent.clone()),
                                Input::F32Ref(Arc::clone(&t_in)),
                                Input::F32Ref(Arc::clone(&ctx)),
                                Input::F32Ref(Arc::clone(&g)),
                            ],
                        )
                        .map_err(SdError::runtime)?;
                    let mut it = out.into_iter();
                    let eps =
                        it.next().ok_or_else(|| SdError::Runtime("missing eps".to_string()))?;
                    for (l, cache) in it.enumerate() {
                        caches[l + 1] = Some(Arc::new(cache));
                    }
                    eps
                }
                StepAction::Partial(l) => {
                    let cache = caches[l].as_ref().map(Arc::clone).ok_or_else(|| {
                        SdError::Runtime(format!("partial step {i} without cache at cut {l}"))
                    })?;
                    let out = self
                        .runtime
                        .execute(
                            &Runtime::unet_partial(l, b),
                            &[
                                Input::F32(latent.clone()),
                                Input::F32Ref(Arc::clone(&t_in)),
                                Input::F32Ref(Arc::clone(&ctx)),
                                Input::F32Ref(Arc::clone(&g)),
                                Input::F32Ref(cache),
                            ],
                        )
                        .map_err(SdError::runtime)?;
                    out.into_iter()
                        .next()
                        .ok_or_else(|| SdError::Runtime("missing eps".to_string()))?
                }
            };
            // Mixed-precision emulation: quantise-dequantise the U-Net
            // output at the request's activation format, so the latent
            // trajectory reflects the reduced-precision datapath the
            // hwsim costing models (batch-compatible by BatchKey.quant).
            // Each batch lane gets its own quantiser fit and its own
            // scheme: the request cache key promises the latent is a
            // function of the request alone, so neither a lane's scale
            // nor its precision may depend on which other requests
            // happened to share the batch.
            if lane_schemes.iter().any(Option::is_some) {
                let lane = eps.len() / b;
                for (chunk, scheme) in
                    eps.make_mut().chunks_mut(lane.max(1)).zip(lane_schemes.iter())
                {
                    if let Some(scheme) = scheme {
                        emulate_activations(chunk, scheme.act);
                    }
                }
            }
            // Trajectory stability: normalized mean-abs eps delta between
            // consecutive steps, folded into an EWMA the policy's
            // step-time hook reads. Tracked only on request.
            if needs_traj {
                let delta = match &prev_eps {
                    Some(prev) => {
                        let cur = eps.data();
                        let old = prev.data();
                        let n = cur.len().min(old.len());
                        let mut num = 0.0f64;
                        let mut den = 0.0f64;
                        for k in 0..n {
                            num += f64::from((cur[k] - old[k]).abs());
                            den += f64::from(cur[k].abs());
                        }
                        num / (den + 1e-12)
                    }
                    None => 0.0,
                };
                update_trajectory(&mut traj, delta, matches!(action, StepAction::Full));
                prev_eps = Some(eps.clone());
            }
            // Scheduler update, in place (same t for every batch lane).
            // The runtime dropped its input handles before responding, so
            // this `make_mut` finds the buffer unique and never copies.
            sampler.step_mut(i, latent.make_mut(), eps.data());
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            step_ms.push(ms);
            executed.push(action);
            obs.on_step(i, action, ms);
            // Observability: per-action counters always (bare labels —
            // the full/partial classifier keys on them); a `step` span
            // when a TraceScope is active (attributed to the scope's
            // job — for a batch, its lead job). Non-default policies
            // qualify the span action as `<policy_id>:<action>` so a
            // trace shows which policy made each step decision.
            crate::obs::counters().step(action.label());
            crate::obs::with_current(|sink, job| {
                let span = crate::obs::SpanEvent::new(job, crate::obs::Phase::Step)
                    .with_step(i as u64)
                    .with_dur_us((ms * 1e3) as u64);
                let span = if reqs[0].policy == PolicySpec::Pas {
                    span.with_action(action.label())
                } else {
                    span.with_action(&format!("{policy_id}:{}", action.label()))
                };
                sink.record(span);
            });
        }

        let total_ms = t_start.elapsed().as_secs_f64() * 1e3;
        let stats = GenStats {
            mac_reduction: self.cost_tiny.mac_reduction(&executed),
            actions: executed,
            step_ms,
            total_ms,
        };
        Ok((0..b)
            .map(|i| GenResult { latent: latent.index0(i), stats: stats.clone() })
            .collect())
    }

    /// Run one lockstep batch (blocking wrapper over
    /// [`Coordinator::generate_batch_observed`] with a no-op observer).
    pub fn generate_batch(&self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        self.generate_batch_observed(reqs, &NoopObserver).map_err(anyhow::Error::from)
    }

    /// Single request with a [`StepObserver`] in the loop.
    pub fn generate_one_observed(
        &self,
        req: &GenRequest,
        obs: &dyn StepObserver,
    ) -> Result<GenResult, SdError> {
        Ok(self.generate_batch_observed(std::slice::from_ref(req), obs)?.remove(0))
    }

    /// Convenience wrapper for a single request.
    pub fn generate_one(&self, req: &GenRequest) -> Result<GenResult> {
        Ok(self.generate_batch(std::slice::from_ref(req))?.remove(0))
    }

    /// Run any number of batch-compatible requests by splitting them into
    /// supported batch sizes ([`plan_chunks`]): a tail smaller than the
    /// smallest compiled artifact is padded by repeating the last request
    /// (lockstep lanes are independent) and the padded lanes are dropped
    /// from the results. The observer spans all chunks: step events fire
    /// per executed chunk and a cancellation aborts between — or inside —
    /// chunks. PAS validation uses the blocking wrapper to batch lanes
    /// whose plans coincide instead of generating one by one.
    pub fn generate_many_observed(
        &self,
        reqs: &[GenRequest],
        obs: &dyn StepObserver,
    ) -> Result<Vec<GenResult>, SdError> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let key = reqs[0].batch_key();
        if reqs.iter().any(|r| r.batch_key() != key) {
            return Err(SdError::invalid("generate_many: requests are not batch-compatible"));
        }
        let mut out = Vec::with_capacity(reqs.len());
        for chunk in self.chunk_sizes(reqs.len())? {
            let start = out.len();
            let real = chunk.min(reqs.len() - start);
            let mut batch: Vec<GenRequest> = reqs[start..start + real].to_vec();
            while batch.len() < chunk {
                batch.push(batch.last().expect("non-empty batch").clone());
            }
            let mut results = self.generate_batch_observed(&batch, obs)?;
            results.truncate(real);
            out.extend(results);
        }
        Ok(out)
    }

    /// Blocking wrapper over [`Coordinator::generate_many_observed`].
    pub fn generate_many(&self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        self.generate_many_observed(reqs, &NoopObserver).map_err(anyhow::Error::from)
    }

    /// Decode latents to RGB images, (B, img_h*img_w, 3) in [0, 1]-ish.
    /// Chunks smaller than the smallest compiled batch are padded by
    /// repeating the last latent (an Arc clone, not a buffer copy) and
    /// the padded outputs are sliced back off.
    pub fn decode(&self, latents: &[Tensor]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(latents.len());
        for chunk_size in self.chunk_sizes(latents.len()).map_err(anyhow::Error::from)? {
            let start = out.len();
            let real = chunk_size.min(latents.len() - start);
            let mut parts: Vec<Tensor> = latents[start..start + real].to_vec();
            while parts.len() < chunk_size {
                parts.push(parts.last().expect("non-empty chunk").clone());
            }
            let batch = Tensor::stack(&parts)?;
            let img = self
                .runtime
                .execute(&Runtime::vae_decoder(chunk_size), &[Input::F32(batch)])?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("missing image output"))?;
            for i in 0..real {
                out.push(img.index0(i));
            }
        }
        crate::obs::counters().decode();
        crate::obs::with_current(|sink, job| {
            sink.record(
                crate::obs::SpanEvent::new(job, crate::obs::Phase::Decode)
                    .with_batch(latents.len() as u64)
                    .with_dur_us(t0.elapsed().as_micros() as u64),
            );
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::plan::PasConfig;

    #[test]
    fn batch_key_separates_incompatible_requests() {
        let a = GenRequest::new("x", 1);
        let mut b = GenRequest::new("y", 2);
        assert_eq!(a.batch_key(), b.batch_key());
        b.steps = 25;
        assert_ne!(a.batch_key(), b.batch_key());
    }

    #[test]
    fn batch_key_separates_quant_schemes() {
        let a = GenRequest::new("x", 1);
        let mut b = GenRequest::new("y", 2);
        b.quant = Some(QuantScheme::w8a8());
        assert_ne!(a.batch_key(), b.batch_key(), "fp32 vs W8A8 cannot lockstep");
        let mut c = GenRequest::new("z", 3);
        c.quant = Some(QuantScheme::w8a8());
        assert_eq!(b.batch_key(), c.batch_key(), "same scheme batches");
        c.quant = Some(QuantScheme::w4a8());
        assert_ne!(b.batch_key(), c.batch_key(), "schemes differ");
    }

    #[test]
    fn batch_key_separates_policies() {
        let a = GenRequest::new("x", 1);
        let mut b = GenRequest::new("y", 2);
        b.policy = PolicySpec::Stability { threshold_milli: 250 };
        assert_ne!(a.batch_key(), b.batch_key(), "pas vs stability cannot lockstep");
        let mut c = GenRequest::new("z", 3);
        c.policy = PolicySpec::Stability { threshold_milli: 250 };
        assert_eq!(b.batch_key(), c.batch_key(), "same policy batches");
        c.policy = PolicySpec::Stability { threshold_milli: 100 };
        assert_ne!(b.batch_key(), c.batch_key(), "parameterizations differ");
    }

    #[test]
    fn batch_key_is_a_real_map_key() {
        use std::collections::HashMap;
        let mut m: HashMap<BatchKey, usize> = HashMap::new();
        m.insert(GenRequest::new("a", 1).batch_key(), 1);
        let mut b = GenRequest::new("b", 2);
        // Same parameters, different prompt/seed: same batch key.
        *m.entry(b.batch_key()).or_insert(0) += 1;
        assert_eq!(m.len(), 1);
        // Guidance participates via its exact bit pattern.
        b.guidance = 7.0;
        m.insert(b.batch_key(), 2);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn request_defaults() {
        let r = GenRequest::new("red circle", 7);
        assert_eq!(r.steps, 50);
        assert_eq!(r.sampler, SamplerKind::Pndm);
        assert!(matches!(r.plan, SamplingPlan::Full));
        assert_eq!(r.quant, None, "full precision unless asked");
        assert_eq!(r.policy, PolicySpec::Pas, "legacy semantics unless asked");
    }

    #[test]
    fn sampler_kind_roundtrips_exact_legacy_bytes() {
        for kind in SamplerKind::ALL {
            assert_eq!(kind.as_str().parse::<SamplerKind>().unwrap(), kind);
            assert_eq!(kind.to_string(), kind.as_str());
        }
        // The exact strings the retired String field carried.
        assert_eq!(SamplerKind::Ddim.as_str(), "ddim");
        assert_eq!(SamplerKind::Pndm.as_str(), "pndm");
        assert_eq!(SamplerKind::default(), SamplerKind::Pndm);
        // Strict parsing: the old field would have panicked in
        // make_sampler for these, so FromStr rejects them up front.
        assert!("euler".parse::<SamplerKind>().is_err());
        assert!("DDIM".parse::<SamplerKind>().is_err());
        // Source-compat literal conversion.
        let k: SamplerKind = "ddim".into();
        assert_eq!(k, SamplerKind::Ddim);
    }

    #[test]
    #[should_panic(expected = "unknown sampler")]
    fn sampler_from_literal_panics_on_unknown() {
        let _: SamplerKind = "euler".into();
    }

    #[test]
    fn builder_accepts_valid_requests() {
        let r = GenRequest::builder("red circle x4 y4", 9)
            .steps(25)
            .guidance(6.0)
            .sampler(SamplerKind::Ddim)
            .plan(SamplingPlan::Pas(PasConfig::pas25(4)))
            .quant(QuantScheme::w8a8())
            .policy(PolicySpec::BlockCache { budget: 3 })
            .build()
            .unwrap();
        assert_eq!(r.steps, 25);
        assert_eq!(r.sampler, SamplerKind::Ddim);
        assert_eq!(r.guidance, 6.0);
        assert!(matches!(r.plan, SamplingPlan::Pas(_)));
        assert_eq!(r.quant, Some(QuantScheme::w8a8()));
        assert_eq!(r.policy, PolicySpec::BlockCache { budget: 3 });
    }

    #[test]
    fn builder_rejects_invalid_requests_at_construction() {
        // Zero steps.
        let e = GenRequest::builder("x", 1).steps(0).build().unwrap_err();
        assert!(matches!(e, SdError::InvalidRequest(_)), "{e}");
        // Non-finite guidance.
        let e = GenRequest::builder("x", 1).guidance(f32::NAN).build().unwrap_err();
        assert!(matches!(e, SdError::InvalidRequest(_)), "{e}");
        let e = GenRequest::builder("x", 1).guidance(f32::INFINITY).build().unwrap_err();
        assert!(matches!(e, SdError::InvalidRequest(_)), "{e}");
        // Non-executable plan: sketching phase longer than the run means
        // a partial step would come before any full step.
        let bad = PasConfig { t_sketch: 8, t_complete: 0, t_sparse: 9, l_sketch: 2, l_refine: 2 };
        let e = GenRequest::builder("x", 1)
            .steps(8)
            .plan(SamplingPlan::Pas(bad))
            .build()
            .unwrap_err();
        assert!(matches!(e, SdError::InvalidRequest(_)), "{e}");
        // Auto passes construction (resolved + re-validated later).
        assert!(GenRequest::builder("x", 1).plan(SamplingPlan::Auto).build().is_ok());
    }

    #[test]
    fn sd_error_display_and_anyhow_conversion() {
        let e = SdError::invalid("steps must be >= 1");
        assert_eq!(e.to_string(), "invalid request: steps must be >= 1");
        assert_eq!(
            SdError::QueueFull.to_string(),
            "queue full: request rejected by admission control"
        );
        assert!(SdError::Cancelled.is_cancelled());
        assert!(!SdError::DeadlineExceeded.is_cancelled());
        // The edge conversion back into anyhow keeps the message.
        let any: anyhow::Error = anyhow::Error::from(SdError::Cancelled);
        assert_eq!(any.to_string(), "cancelled");
        let rt = SdError::runtime(anyhow::anyhow!("pjrt exploded"));
        assert_eq!(rt.to_string(), "runtime error: pjrt exploded");
    }

    #[test]
    fn retryability_classifies_transient_faults_only() {
        use crate::runtime::TRANSIENT_MARKER;

        // Injected transient faults (as they arrive at the edge: the
        // anyhow chain flattened through SdError::runtime) retry.
        let injected = SdError::runtime(anyhow::anyhow!(
            "{TRANSIENT_MARKER} injected: artifact unet_full_b2 call 7"
        ));
        assert!(injected.is_retryable());
        // Contract violations — the exact canonical check_inputs wording
        // — are deterministic and must never be re-dispatched.
        let shape = SdError::runtime(anyhow::anyhow!(
            "artifact unet_full_b1 input 0: shape [1, 3, 3] != manifest [1, 256, 4]"
        ));
        assert!(!shape.is_retryable());
        let count = SdError::runtime(anyhow::anyhow!(
            "artifact unet_full_b1: expected 4 inputs, got 1"
        ));
        assert!(!count.is_retryable());
        // Non-Runtime variants are final verdicts.
        assert!(!SdError::invalid("steps must be >= 1").is_retryable());
        assert!(!SdError::QueueFull.is_retryable());
        assert!(!SdError::Cancelled.is_retryable());
        assert!(!SdError::DeadlineExceeded.is_retryable());
        // Even a Runtime error is permanent without the marker.
        assert!(!SdError::runtime(anyhow::anyhow!("pjrt exploded")).is_retryable());
    }

    #[test]
    fn plan_chunks_only_emits_supported_sizes() {
        let supported = [2usize, 4];
        for n in 1..=11 {
            let chunks = plan_chunks(&supported, n).unwrap();
            assert!(
                chunks.iter().all(|c| supported.contains(c)),
                "n={n}: unsupported chunk in {chunks:?}"
            );
            let total: usize = chunks.iter().sum();
            assert!(total >= n, "n={n}: chunks {chunks:?} cover too little");
            // Padding is confined to the final chunk.
            let body: usize = chunks[..chunks.len() - 1].iter().sum();
            assert!(body < n, "n={n}: padding before the final chunk in {chunks:?}");
        }
    }

    #[test]
    fn plan_chunks_pads_below_smallest_artifact() {
        // The regression: n=1 with smallest compiled batch 2 used to emit
        // an unsupported chunk of 1 and fail at execute time. Now the
        // chunk is the smallest artifact and the caller pads one lane.
        assert_eq!(plan_chunks(&[2, 4], 1).unwrap(), vec![2]);
        assert_eq!(plan_chunks(&[2, 4], 3).unwrap(), vec![2, 2]);
        assert_eq!(plan_chunks(&[2, 4], 7).unwrap(), vec![4, 2, 2]);
        assert_eq!(plan_chunks(&[4], 2).unwrap(), vec![4]);
    }

    #[test]
    fn plan_chunks_exact_fits_need_no_padding() {
        assert_eq!(plan_chunks(&[1, 2, 4], 7).unwrap(), vec![4, 2, 1]);
        assert_eq!(plan_chunks(&[2, 4], 8).unwrap(), vec![4, 4]);
        assert_eq!(plan_chunks(&[1], 3).unwrap(), vec![1, 1, 1]);
        assert!(plan_chunks(&[2, 4], 0).unwrap().is_empty());
    }

    #[test]
    fn empty_size_table_is_a_clean_error_not_a_panic() {
        // The regression this guards: `best_fit_batch` used to
        // `expect("no batch sizes")` and take the whole process down.
        assert_eq!(best_fit_batch(&[], 3), None);
        assert_eq!(best_fit_batch(&[2, 4], 3), Some(2));
        assert_eq!(best_fit_batch(&[2, 4], 1), Some(2), "falls back to smallest");
        let e = plan_chunks(&[], 3).unwrap_err();
        assert!(matches!(e, SdError::Runtime(_)), "{e}");
        // No work to place never needs a size table.
        assert!(plan_chunks(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn default_observer_neither_cancels_nor_expires_nor_panics() {
        let obs = NoopObserver;
        assert!(!obs.should_cancel());
        assert!(!obs.deadline_exceeded(), "no deadline unless an observer provides one");
        obs.on_step(0, StepAction::Full, 1.0);
    }
}
