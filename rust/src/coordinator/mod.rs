//! Generation coordinator (L3): owns the denoising loop.
//!
//! For each batch of requests the coordinator tokenises prompts, runs the
//! text encoder once, initialises seeded Gaussian latents, then walks the
//! scheduler timesteps executing either the full U-Net artifact (which
//! refreshes the feature cache) or a partial artifact (which consumes it)
//! according to the phase-aware sampling plan. Python is never invoked:
//! every compute step is a PJRT execution of an AOT artifact.
//!
//! The step loop is zero-copy on the host side: loop-invariant inputs
//! (text context, guidance, feature caches) cross the runtime-thread
//! boundary as [`Input::F32Ref`] Arc shares, the latent travels as an
//! Arc-backed [`Tensor`] clone (refcount bump, no buffer copy), and the
//! scheduler update runs in place via [`Sampler::step_mut`] — so a
//! 50-step generation reuses one latent buffer instead of re-copying
//! latent + context + guidance on every step.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::Cache;
use crate::models::inventory::sd_tiny;
use crate::pas::cost::CostModel;
use crate::pas::plan::{plan_is_executable, SamplingPlan, StepAction};
use crate::quant::format::{emulate_activations, QuantScheme};
use crate::runtime::{Input, Runtime, RuntimeHandle, Tensor, TensorI32};
use crate::scheduler::{make_sampler, NoiseSchedule, Sampler};
use crate::util::rng::Pcg32;

/// One text-to-image generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub seed: u64,
    pub steps: usize,
    pub guidance: f32,
    /// "ddim" | "pndm".
    pub sampler: String,
    pub plan: SamplingPlan,
    /// Mixed-precision scheme: `None` runs the artifacts untouched;
    /// `Some` fake-quantises the U-Net output every step (deterministic
    /// reduced-precision emulation — the artifacts themselves stay fp32).
    pub quant: Option<QuantScheme>,
}

impl GenRequest {
    pub fn new(prompt: &str, seed: u64) -> GenRequest {
        GenRequest {
            prompt: prompt.to_string(),
            seed,
            steps: 50,
            guidance: 7.5,
            sampler: "pndm".into(),
            plan: SamplingPlan::Full,
            quant: None,
        }
    }

    /// Batching key: requests sharing it can run lockstep.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            steps: self.steps,
            sampler: self.sampler.clone(),
            plan: self.plan,
            guidance_bits: self.guidance.to_bits(),
            quant: self.quant,
        }
    }
}

/// Structured batching key (steps/sampler/plan/guidance/quant must match
/// to run lockstep — the fake-quant round-trip applies to the whole
/// batched eps tensor, so mixed-precision requests can only batch with
/// requests of the same scheme). A real `Hash + Ord` type rather than a
/// lossy `format!("{:?}")` string, so the batcher can use it as a map key
/// directly and the cache key derivation hashes the same fields without
/// re-parsing. Guidance is carried as its exact f32 bit pattern
/// (`f32` itself has no `Eq`/`Hash`).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    pub steps: usize,
    pub sampler: String,
    pub plan: SamplingPlan,
    pub guidance_bits: u32,
    pub quant: Option<QuantScheme>,
}

/// Per-request generation outcome.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Final denoised latent, (L, latent_c).
    pub latent: Tensor,
    pub stats: GenStats,
}

#[derive(Debug, Clone)]
pub struct GenStats {
    pub actions: Vec<StepAction>,
    pub step_ms: Vec<f64>,
    /// Eq. 3 MAC reduction of the executed plan (sd-tiny cost model).
    pub mac_reduction: f64,
    pub total_ms: f64,
}

/// Largest size in `sizes_ascending` that is <= `n`, falling back to
/// the smallest. THE batch-size selection policy: the dynamic batcher
/// (`server::batcher`) and the chunk planner below both route through
/// it, so they can never disagree on chunk shapes.
pub fn best_fit_batch(sizes_ascending: &[usize], n: usize) -> usize {
    sizes_ascending
        .iter()
        .rev()
        .find(|&&s| s <= n)
        .copied()
        .unwrap_or_else(|| *sizes_ascending.first().expect("no batch sizes"))
}

/// Split `n` items into compiled batch sizes, largest-first greedy.
/// Every returned size is a *supported* artifact size; when `n` is
/// smaller than the smallest compiled artifact (or a tail remains), the
/// final chunk is the smallest supported size and the caller pads the
/// batch (repeat a lane) then slices the padded lanes back off — the
/// old behaviour of emitting an unsupported `n`-sized chunk made the
/// execute fail at runtime.
pub fn plan_chunks(supported_ascending: &[usize], mut n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    while n > 0 {
        let take = best_fit_batch(supported_ascending, n);
        out.push(take);
        n = n.saturating_sub(take);
    }
    out
}

/// The coordinator: runtime handle + schedule + cost accounting.
pub struct Coordinator {
    runtime: RuntimeHandle,
    cost_tiny: CostModel,
}

impl Coordinator {
    pub fn new(runtime: RuntimeHandle) -> Coordinator {
        Coordinator { runtime, cost_tiny: CostModel::new(&sd_tiny()) }
    }

    pub fn runtime(&self) -> &RuntimeHandle {
        &self.runtime
    }

    /// Digest of the loaded AOT manifest — the cache invalidation anchor.
    pub fn manifest_hash(&self) -> u64 {
        self.runtime.manifest().hash
    }

    /// Resolve a `SamplingPlan::Auto` request against the plan cache:
    /// the best searched configuration for this (manifest, steps) cell,
    /// or `Full` when nothing has been searched yet. Non-Auto plans pass
    /// through untouched. Called by the server before batching so cache
    /// keys and batch keys always see a concrete plan.
    pub fn resolve_plan(&self, req: &GenRequest, cache: Option<&Cache>) -> GenRequest {
        if !matches!(req.plan, SamplingPlan::Auto) {
            return req.clone();
        }
        let mut out = req.clone();
        out.plan = cache
            .and_then(|c| c.best_plan(req.steps))
            .map(SamplingPlan::Pas)
            .unwrap_or(SamplingPlan::Full);
        out
    }

    /// Batch sizes with compiled artifacts, ascending.
    pub fn supported_batches(&self) -> Vec<usize> {
        let mut b = self.runtime.manifest().batch_sizes.clone();
        b.sort_unstable();
        b
    }

    /// Split `n` requests into supported batch sizes, largest first.
    /// Every size has a compiled artifact; see [`plan_chunks`] for the
    /// padding contract on the final chunk.
    pub fn chunk_sizes(&self, n: usize) -> Vec<usize> {
        plan_chunks(&self.supported_batches(), n)
    }

    /// Encode prompts (one text-encoder execution).
    pub fn encode_prompts(&self, prompts: &[String]) -> Result<Tensor> {
        let b = prompts.len();
        let m = &self.runtime.manifest().model;
        let mut toks = Vec::with_capacity(b * m.ctx_len);
        for p in prompts {
            toks.extend(self.runtime.manifest().tokenize(p));
        }
        let t = TensorI32::new(vec![b, m.ctx_len], toks)?;
        let name = Runtime::text_encoder(b);
        let out = self.runtime.execute(&name, &[Input::I32(t)])?;
        Ok(out.into_iter().next().ok_or_else(|| anyhow!("empty text output"))?)
    }

    /// Seeded N(0,1) initial latent for one request, (L, latent_c).
    pub fn init_latent(&self, seed: u64) -> Tensor {
        let m = &self.runtime.manifest().model;
        let mut rng = Pcg32::new(seed, 0x1a7e47);
        Tensor::new(vec![m.latent_l(), m.latent_c], rng.gaussian_vec(m.latent_elems()))
            .expect("latent dims match element count")
    }

    /// Run one lockstep batch. All requests must share `batch_key()` and
    /// the batch size must have compiled artifacts.
    pub fn generate_batch(&self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        let b = reqs.len();
        if b == 0 {
            bail!("empty batch");
        }
        if !self.supported_batches().contains(&b) {
            bail!("no artifacts for batch size {b} (have {:?})", self.supported_batches());
        }
        let key = reqs[0].batch_key();
        if reqs.iter().any(|r| r.batch_key() != key) {
            bail!("generate_batch: requests are not batch-compatible");
        }
        let m = self.runtime.manifest().model.clone();
        let steps = reqs[0].steps;
        let plan = reqs[0].plan.actions(steps);
        if !plan_is_executable(&plan) {
            bail!("plan is not executable (partial step before any full step)");
        }
        let max_cut = m.max_cut;
        if let Some(StepAction::Partial(l)) =
            plan.iter().find(|a| matches!(a, StepAction::Partial(l) if *l > max_cut))
        {
            bail!("plan uses cut {l} > compiled max_cut {max_cut}");
        }

        let sched = NoiseSchedule::new(self.runtime.manifest().alpha_bar.clone());
        let mut sampler: Box<dyn Sampler + Send> = make_sampler(&reqs[0].sampler, sched, steps);
        let ts = sampler.timesteps().to_vec();

        // Text conditioning (one batched execution). Loop invariants are
        // Arc'd once and shared with the runtime by refcount each step.
        let prompts: Vec<String> = reqs.iter().map(|r| r.prompt.clone()).collect();
        let ctx = Arc::new(self.encode_prompts(&prompts)?);
        let g = Arc::new(Tensor::scalar(reqs[0].guidance));

        // Stacked latents: one buffer, stepped in place for all N steps.
        let lat_parts: Vec<Tensor> = reqs.iter().map(|r| self.init_latent(r.seed)).collect();
        let mut latent = Tensor::stack(&lat_parts)?;

        // Feature caches per cut level (refreshed by full steps).
        let mut caches: Vec<Option<Arc<Tensor>>> = vec![None; max_cut + 1];
        let mut step_ms = Vec::with_capacity(steps);
        let t_start = Instant::now();

        for (i, &action) in plan.iter().enumerate() {
            let t0 = Instant::now();
            let t_in = Tensor::new(vec![b], vec![ts[i] as f32; b])?;
            let mut eps = match action {
                StepAction::Full => {
                    let out = self.runtime.execute(
                        &Runtime::unet_full(b),
                        &[
                            Input::F32(latent.clone()),
                            Input::F32(t_in),
                            Input::F32Ref(Arc::clone(&ctx)),
                            Input::F32Ref(Arc::clone(&g)),
                        ],
                    )?;
                    let mut it = out.into_iter();
                    let eps = it.next().ok_or_else(|| anyhow!("missing eps"))?;
                    for (l, cache) in it.enumerate() {
                        caches[l + 1] = Some(Arc::new(cache));
                    }
                    eps
                }
                StepAction::Partial(l) => {
                    let cache = caches[l]
                        .as_ref()
                        .map(Arc::clone)
                        .ok_or_else(|| anyhow!("partial step {i} without cache at cut {l}"))?;
                    let out = self.runtime.execute(
                        &Runtime::unet_partial(l, b),
                        &[
                            Input::F32(latent.clone()),
                            Input::F32(t_in),
                            Input::F32Ref(Arc::clone(&ctx)),
                            Input::F32Ref(Arc::clone(&g)),
                            Input::F32Ref(cache),
                        ],
                    )?;
                    out.into_iter().next().ok_or_else(|| anyhow!("missing eps"))?
                }
            };
            // Mixed-precision emulation: quantise-dequantise the U-Net
            // output at the request's activation format, so the latent
            // trajectory reflects the reduced-precision datapath the
            // hwsim costing models (batch-compatible by BatchKey.quant).
            // Each batch lane gets its own quantiser fit: the request
            // cache key promises the latent is a function of the request
            // alone, so a lane's scale must not depend on which other
            // requests happened to share the batch.
            if let Some(scheme) = reqs[0].quant {
                let lane = eps.len() / b;
                for chunk in eps.make_mut().chunks_mut(lane.max(1)) {
                    emulate_activations(chunk, scheme.act);
                }
            }
            // Scheduler update, in place (same t for every batch lane).
            // The runtime dropped its input handles before responding, so
            // this `make_mut` finds the buffer unique and never copies.
            sampler.step_mut(i, latent.make_mut(), eps.data());
            step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }

        let total_ms = t_start.elapsed().as_secs_f64() * 1e3;
        let stats = GenStats {
            actions: plan.clone(),
            step_ms,
            mac_reduction: self.cost_tiny.mac_reduction(&plan),
            total_ms,
        };
        Ok((0..b)
            .map(|i| GenResult { latent: latent.index0(i), stats: stats.clone() })
            .collect())
    }

    /// Convenience wrapper for a single request.
    pub fn generate_one(&self, req: &GenRequest) -> Result<GenResult> {
        Ok(self.generate_batch(std::slice::from_ref(req))?.remove(0))
    }

    /// Run any number of batch-compatible requests by splitting them into
    /// supported batch sizes ([`plan_chunks`]): a tail smaller than the
    /// smallest compiled artifact is padded by repeating the last request
    /// (lockstep lanes are independent) and the padded lanes are dropped
    /// from the results. PAS validation uses this to batch lanes whose
    /// plans coincide instead of generating one by one.
    pub fn generate_many(&self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let key = reqs[0].batch_key();
        if reqs.iter().any(|r| r.batch_key() != key) {
            bail!("generate_many: requests are not batch-compatible");
        }
        let mut out = Vec::with_capacity(reqs.len());
        for chunk in self.chunk_sizes(reqs.len()) {
            let start = out.len();
            let real = chunk.min(reqs.len() - start);
            let mut batch: Vec<GenRequest> = reqs[start..start + real].to_vec();
            while batch.len() < chunk {
                batch.push(batch.last().expect("non-empty batch").clone());
            }
            let mut results = self.generate_batch(&batch)?;
            results.truncate(real);
            out.extend(results);
        }
        Ok(out)
    }

    /// Decode latents to RGB images, (B, img_h*img_w, 3) in [0, 1]-ish.
    /// Chunks smaller than the smallest compiled batch are padded by
    /// repeating the last latent (an Arc clone, not a buffer copy) and
    /// the padded outputs are sliced back off.
    pub fn decode(&self, latents: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(latents.len());
        for chunk_size in self.chunk_sizes(latents.len()) {
            let start = out.len();
            let real = chunk_size.min(latents.len() - start);
            let mut parts: Vec<Tensor> = latents[start..start + real].to_vec();
            while parts.len() < chunk_size {
                parts.push(parts.last().expect("non-empty chunk").clone());
            }
            let batch = Tensor::stack(&parts)?;
            let img = self
                .runtime
                .execute(&Runtime::vae_decoder(chunk_size), &[Input::F32(batch)])?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("missing image output"))?;
            for i in 0..real {
                out.push(img.index0(i));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_separates_incompatible_requests() {
        let a = GenRequest::new("x", 1);
        let mut b = GenRequest::new("y", 2);
        assert_eq!(a.batch_key(), b.batch_key());
        b.steps = 25;
        assert_ne!(a.batch_key(), b.batch_key());
    }

    #[test]
    fn batch_key_separates_quant_schemes() {
        let a = GenRequest::new("x", 1);
        let mut b = GenRequest::new("y", 2);
        b.quant = Some(QuantScheme::w8a8());
        assert_ne!(a.batch_key(), b.batch_key(), "fp32 vs W8A8 cannot lockstep");
        let mut c = GenRequest::new("z", 3);
        c.quant = Some(QuantScheme::w8a8());
        assert_eq!(b.batch_key(), c.batch_key(), "same scheme batches");
        c.quant = Some(QuantScheme::w4a8());
        assert_ne!(b.batch_key(), c.batch_key(), "schemes differ");
    }

    #[test]
    fn batch_key_is_a_real_map_key() {
        use std::collections::HashMap;
        let mut m: HashMap<BatchKey, usize> = HashMap::new();
        m.insert(GenRequest::new("a", 1).batch_key(), 1);
        let mut b = GenRequest::new("b", 2);
        // Same parameters, different prompt/seed: same batch key.
        *m.entry(b.batch_key()).or_insert(0) += 1;
        assert_eq!(m.len(), 1);
        // Guidance participates via its exact bit pattern.
        b.guidance = 7.0;
        m.insert(b.batch_key(), 2);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn request_defaults() {
        let r = GenRequest::new("red circle", 7);
        assert_eq!(r.steps, 50);
        assert_eq!(r.sampler, "pndm");
        assert!(matches!(r.plan, SamplingPlan::Full));
        assert_eq!(r.quant, None, "full precision unless asked");
    }

    #[test]
    fn plan_chunks_only_emits_supported_sizes() {
        let supported = [2usize, 4];
        for n in 1..=11 {
            let chunks = plan_chunks(&supported, n);
            assert!(
                chunks.iter().all(|c| supported.contains(c)),
                "n={n}: unsupported chunk in {chunks:?}"
            );
            let total: usize = chunks.iter().sum();
            assert!(total >= n, "n={n}: chunks {chunks:?} cover too little");
            // Padding is confined to the final chunk.
            let body: usize = chunks[..chunks.len() - 1].iter().sum();
            assert!(body < n, "n={n}: padding before the final chunk in {chunks:?}");
        }
    }

    #[test]
    fn plan_chunks_pads_below_smallest_artifact() {
        // The regression: n=1 with smallest compiled batch 2 used to emit
        // an unsupported chunk of 1 and fail at execute time. Now the
        // chunk is the smallest artifact and the caller pads one lane.
        assert_eq!(plan_chunks(&[2, 4], 1), vec![2]);
        assert_eq!(plan_chunks(&[2, 4], 3), vec![2, 2]);
        assert_eq!(plan_chunks(&[2, 4], 7), vec![4, 2, 2]);
        assert_eq!(plan_chunks(&[4], 2), vec![4]);
    }

    #[test]
    fn plan_chunks_exact_fits_need_no_padding() {
        assert_eq!(plan_chunks(&[1, 2, 4], 7), vec![4, 2, 1]);
        assert_eq!(plan_chunks(&[2, 4], 8), vec![4, 4]);
        assert_eq!(plan_chunks(&[1], 3), vec![1, 1, 1]);
        assert!(plan_chunks(&[2, 4], 0).is_empty());
    }
}
