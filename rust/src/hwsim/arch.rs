//! Accelerator configuration (Table I) and derived constants.

/// The SD-Acc accelerator configuration (Sec. VI-A / Table I).
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// Systolic array height/width (weight-stationary).
    pub sa_rows: usize,
    pub sa_cols: usize,
    /// VPU parallel lanes (H-parallel, Fig. 10).
    pub vpu_lanes: usize,
    pub freq_hz: f64,
    /// fp16 arithmetic.
    pub dtype_bytes: usize,
    /// Global buffer capacity (bytes).
    pub gb_bytes: usize,
    /// Dedicated input/weight/output buffers (bytes each).
    pub small_buf_bytes: usize,
    /// Off-chip bandwidth (bytes/s).
    pub dram_bw: f64,
    // --- power (Table I) ----------------------------------------------
    pub p_sa_w: f64,
    pub p_vpu_w: f64,
    pub p_gb_w: f64,
    pub p_small_buf_w: f64,
    /// Off-chip access energy (J per byte), HMC-class memory [45].
    pub dram_j_per_byte: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            sa_rows: 32,
            sa_cols: 32,
            vpu_lanes: 32,
            freq_hz: 200e6,
            dtype_bytes: 2,
            gb_bytes: 2 << 20,
            small_buf_bytes: 64 << 10,
            dram_bw: 38.4e9,
            p_sa_w: 11.30,
            p_vpu_w: 0.98,
            p_gb_w: 0.91,
            p_small_buf_w: 0.14,
            dram_j_per_byte: 30e-12,
        }
    }
}

impl AccelConfig {
    /// Total on-chip power (Table I: 15.98 W incl. misc.).
    pub fn onchip_power_w(&self) -> f64 {
        // The 2.65 W residual (clocking, control, IO) from Table I's
        // total is folded in as a constant.
        self.p_sa_w + self.p_vpu_w + self.p_gb_w + self.p_small_buf_w + 2.65
    }

    /// MACs retired per cycle at full PE utilisation.
    pub fn macs_per_cycle(&self) -> f64 {
        (self.sa_rows * self.sa_cols) as f64
    }

    /// Peak MAC/s — the paper's "GFLOPS" counts 1 add + 1 mul as one MAC
    /// (Fig. 2 caption), so Table I's 204.8 GFLOPS is peak_macs here.
    pub fn peak_macs(&self) -> f64 {
        self.macs_per_cycle() * self.freq_hz
    }

    /// Peak throughput in conventional FLOP/s (1 MAC = 2 FLOP) — used
    /// when comparing against CPU/GPU datasheet numbers.
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.peak_macs()
    }

    pub fn cycles_to_seconds(&self, cycles: f64) -> f64 {
        cycles / self.freq_hz
    }

    /// Sec. VI-F scaling for the speed comparison: 1 GHz, 4096 MACs
    /// (64x64 array), keeping everything else.
    pub fn scaled_1ghz_4096(&self) -> AccelConfig {
        AccelConfig {
            sa_rows: 64,
            sa_cols: 64,
            vpu_lanes: 64,
            freq_hz: 1e9,
            // Bandwidth scales with the MAC count to keep the balance
            // point (consistent with prior accelerators [35], [42]).
            dram_bw: self.dram_bw * 4.0,
            ..self.clone()
        }
    }

    /// Iso-peak-throughput scaling for Fig. 18 comparisons.
    pub fn scaled_to_peak(&self, peak_flops: f64) -> AccelConfig {
        let ratio = peak_flops / self.peak_flops();
        let dim_scale = ratio.sqrt();
        let rows = ((self.sa_rows as f64 * dim_scale).round() as usize).max(1);
        AccelConfig {
            sa_rows: rows,
            sa_cols: rows,
            vpu_lanes: rows,
            dram_bw: self.dram_bw * ratio,
            ..self.clone()
        }
    }
}

/// Simulator policy switches (the ablation axes of Fig. 17b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    pub dataflow: Dataflow,
    pub nonlinear: NonlinearMode,
    pub reuse: ReuseMode,
    pub fusion: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Baseline: conv lowered by a dedicated im2col module ([11], [18]).
    Im2col,
    /// The paper's address-centric Uni-conv (Sec. IV-A/B).
    AddressCentric,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NonlinearMode {
    /// Baseline: store-then-compute, multi-pass VPU, serialised with SA.
    StoreThenCompute,
    /// The paper's 2-stage streaming computing (Sec. IV-C).
    Streaming2Stage,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseMode {
    /// Baseline: no cross-tile operand pinning — the streamed operand is
    /// re-fetched per output-tile group.
    Fixed,
    /// Adaptive input/weight reuse (Sec. V-B): pin the smaller operand
    /// in the global buffer, single-pass the rest.
    Adaptive,
}

impl Policy {
    /// Fig. 17b's four configurations.
    pub fn baseline() -> Policy {
        Policy {
            dataflow: Dataflow::Im2col,
            nonlinear: NonlinearMode::StoreThenCompute,
            reuse: ReuseMode::Fixed,
            fusion: false,
        }
    }

    pub fn with_ac() -> Policy {
        Policy { dataflow: Dataflow::AddressCentric, ..Policy::baseline() }
    }

    pub fn with_ac_ad() -> Policy {
        Policy { reuse: ReuseMode::Adaptive, fusion: true, ..Policy::with_ac() }
    }

    /// Fully optimised (AC + AD + SC).
    pub fn optimized() -> Policy {
        Policy { nonlinear: NonlinearMode::Streaming2Stage, ..Policy::with_ac_ad() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_constants() {
        let c = AccelConfig::default();
        assert_eq!(c.macs_per_cycle() as u64, 1024);
        // 204.8 "GFLOPS" peak in the paper's MAC counting (Sec. VI-D).
        assert!((c.peak_macs() - 204.8e9).abs() < 1e6);
        assert!((c.onchip_power_w() - 15.98).abs() < 0.01);
        assert_eq!(c.gb_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn scaled_config_matches_sec6f() {
        let s = AccelConfig::default().scaled_1ghz_4096();
        assert_eq!(s.macs_per_cycle() as u64, 4096);
        // 4.096 TMAC/s = 8.192 TFLOPS after scaling.
        assert!((s.peak_flops() - 8.192e12).abs() < 1e9);
    }

    #[test]
    fn iso_peak_scaling() {
        let c = AccelConfig::default();
        let s = c.scaled_to_peak(4.0 * c.peak_flops());
        assert_eq!(s.sa_rows, 64);
        assert!((s.peak_flops() / c.peak_flops() - 4.0).abs() < 0.01);
    }

    #[test]
    fn policy_ladder() {
        assert_eq!(Policy::baseline().dataflow, Dataflow::Im2col);
        assert_eq!(Policy::with_ac().dataflow, Dataflow::AddressCentric);
        assert!(Policy::with_ac_ad().fusion);
        assert_eq!(Policy::optimized().nonlinear, NonlinearMode::Streaming2Stage);
    }
}
