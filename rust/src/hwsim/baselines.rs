//! Comparison platforms (Sec. VI-E/F): CPU / GPU analytic models and the
//! Cambricon-D / SDP accelerator simulators.
//!
//! CPU/GPU models are rooflines with measured-efficiency derates (the
//! paper measured single-precision PyTorch, Fig. 2); Cambricon-D and SDP
//! are rebuilt "based on the details provided in their papers" (Sec.
//! VI-E), exactly as SD-Acc itself did: Cambricon-D applies differential
//! (delta) computing to convolutions; SDP prunes unimportant tokens so
//! transformer compute shrinks.

use super::arch::AccelConfig;
use crate::models::inventory::{LayerOp, OpKind};

/// An analytic CPU/GPU platform.
#[derive(Debug, Clone)]
pub struct PlatformModel {
    pub name: &'static str,
    /// Peak single-precision FLOP/s.
    pub peak_flops: f64,
    /// Sustained efficiency on dense conv/matmul kernels.
    pub efficiency: f64,
    /// Extra latency fraction from nonlinear ops (Sec. I: up to 30%).
    pub nonlinear_overhead: f64,
    pub mem_bw: f64,
    pub power_w: f64,
    pub process_nm: u32,
}

/// NVIDIA V100 (12 nm, 300 W, 14 TFLOPS fp32).
pub fn v100() -> PlatformModel {
    PlatformModel {
        name: "V100",
        peak_flops: 14.0e12,
        efficiency: 0.50,
        nonlinear_overhead: 0.15,
        mem_bw: 900e9,
        power_w: 300.0,
        process_nm: 12,
    }
}

/// AMD Ryzen 7 6800H (6 nm, 45 W).
pub fn amd_6800h() -> PlatformModel {
    PlatformModel {
        name: "AMD-6800H",
        peak_flops: 1.2e12,
        efficiency: 0.15,
        nonlinear_overhead: 0.25,
        mem_bw: 51.2e9,
        power_w: 45.0,
        process_nm: 6,
    }
}

/// Intel Xeon Gold 5220R (14 nm, 150 W).
pub fn intel_5220r() -> PlatformModel {
    PlatformModel {
        name: "Intel-5220R",
        peak_flops: 1.7e12,
        efficiency: 0.28,
        nonlinear_overhead: 0.25,
        mem_bw: 140e9,
        power_w: 150.0,
        process_nm: 14,
    }
}

impl PlatformModel {
    /// Latency of one forward pass over an op list (seconds).
    pub fn latency_s(&self, ops: &[LayerOp]) -> f64 {
        let macs: f64 = ops.iter().map(|o| o.kind.macs() as f64).sum();
        let flops = 2.0 * macs;
        let compute = flops / (self.peak_flops * self.efficiency);
        compute / (1.0 - self.nonlinear_overhead)
    }

    /// Energy of one forward pass (J).
    pub fn energy_j(&self, ops: &[LayerOp]) -> f64 {
        self.power_w * self.latency_s(ops)
    }
}

// ------------------------------------------------- comparison accelerators

fn is_conv(op: &LayerOp) -> bool {
    matches!(op.kind, OpKind::Conv { .. })
}

fn is_transformer(op: &LayerOp) -> bool {
    // Transformer-block ops are tagged ".tf" / per-depth ".d{i}" by the
    // inventory builder.
    op.name.contains(".tf") || op.name.contains(".proj_in") || op.name.contains(".proj_out")
}

/// Cambricon-D [25]: full-network differential acceleration — delta
/// computing across consecutive timesteps benefits convolutions.
#[derive(Debug, Clone)]
pub struct CambriconD {
    pub peak_flops: f64,
    /// Effective conv speedup from delta sparsity between timesteps.
    pub conv_delta_speedup: f64,
    pub utilization: f64,
}

impl CambriconD {
    pub fn new(peak_flops: f64) -> Self {
        CambriconD { peak_flops, conv_delta_speedup: 2.5, utilization: 0.85 }
    }

    /// Latency of one U-Net step (seconds), original 50-step sampling.
    pub fn step_latency_s(&self, ops: &[LayerOp]) -> f64 {
        let mut flops_eff = 0.0;
        for op in ops {
            let f = 2.0 * op.kind.macs() as f64;
            flops_eff += if is_conv(op) { f / self.conv_delta_speedup } else { f };
        }
        flops_eff / (self.peak_flops * self.utilization)
    }
}

/// SDP [5]: prompt-guided token pruning — cross-attention importance
/// shrinks the token set, accelerating subsequent transformer compute.
#[derive(Debug, Clone)]
pub struct Sdp {
    pub peak_flops: f64,
    /// Effective transformer speedup from token pruning.
    pub transformer_speedup: f64,
    pub utilization: f64,
}

impl Sdp {
    pub fn new(peak_flops: f64) -> Self {
        Sdp { peak_flops, transformer_speedup: 2.4, utilization: 0.85 }
    }

    /// Token pruning amortises over transformer depth: once pruned after
    /// the first cross-attention, every deeper layer computes on the
    /// reduced token set — deep stacks (SDXL, depth 10) benefit more.
    pub fn for_arch(peak_flops: f64, max_tf_depth: usize) -> Self {
        let speedup = 2.0 + 0.25 * max_tf_depth as f64;
        Sdp { peak_flops, transformer_speedup: speedup, utilization: 0.85 }
    }

    pub fn step_latency_s(&self, ops: &[LayerOp]) -> f64 {
        let mut flops_eff = 0.0;
        for op in ops {
            let f = 2.0 * op.kind.macs() as f64;
            flops_eff += if is_transformer(op) { f / self.transformer_speedup } else { f };
        }
        flops_eff / (self.peak_flops * self.utilization)
    }
}

/// Transformer FLOP share of an inventory (drives the Fig. 18 trends).
pub fn transformer_share(ops: &[LayerOp]) -> f64 {
    let total: f64 = ops.iter().map(|o| 2.0 * o.kind.macs() as f64).sum();
    let tf: f64 = ops
        .iter()
        .filter(|o| is_transformer(o))
        .map(|o| 2.0 * o.kind.macs() as f64)
        .sum();
    tf / total
}

/// SD-Acc running PAS on the iso-peak accelerator: effective step latency
/// given the plan's MAC-reduction factor and the simulator's utilisation.
pub fn sd_acc_step_latency_s(
    cfg: &AccelConfig,
    ops: &[LayerOp],
    mac_reduction: f64,
    utilization: f64,
) -> f64 {
    let flops: f64 = ops.iter().map(|o| 2.0 * o.kind.macs() as f64).sum();
    (flops / mac_reduction) / (cfg.peak_flops() * utilization)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::{sd_v14, sd_xl, unet_ops};

    #[test]
    fn platform_latency_ordering() {
        let ops = unet_ops(&sd_v14());
        let v = v100().latency_s(&ops);
        let a = amd_6800h().latency_s(&ops);
        let i = intel_5220r().latency_s(&ops);
        assert!(v < i && i < a, "v100 {v} intel {i} amd {a}");
        // V100 single-precision SD1.4 step ~ 0.1-0.3 s.
        assert!((0.05..0.5).contains(&v), "v100 step {v}");
    }

    #[test]
    fn cambricon_d_gains_shrink_with_transformer_share() {
        let cd = CambriconD::new(100e12);
        let v14 = unet_ops(&sd_v14());
        let xl = unet_ops(&sd_xl());
        // Relative gain vs a no-delta accelerator at the same peak.
        let plain = |ops: &[LayerOp]| {
            let f: f64 = ops.iter().map(|o| 2.0 * o.kind.macs() as f64).sum();
            f / (cd.peak_flops * cd.utilization)
        };
        let gain14 = plain(&v14) / cd.step_latency_s(&v14);
        let gainxl = plain(&xl) / cd.step_latency_s(&xl);
        assert!(gain14 > gainxl, "C-D gain v1.4 {gain14} <= XL {gainxl}");
    }

    #[test]
    fn sdp_gains_grow_with_transformer_share() {
        let sdp = Sdp::new(100e12);
        let v14 = unet_ops(&sd_v14());
        let xl = unet_ops(&sd_xl());
        let plain = |ops: &[LayerOp]| {
            let f: f64 = ops.iter().map(|o| 2.0 * o.kind.macs() as f64).sum();
            f / (sdp.peak_flops * sdp.utilization)
        };
        let gain14 = plain(&v14) / sdp.step_latency_s(&v14);
        let gainxl = plain(&xl) / sdp.step_latency_s(&xl);
        assert!(gainxl > gain14, "SDP gain XL {gainxl} <= v1.4 {gain14}");
    }

    #[test]
    fn transformer_share_v14_vs_xl() {
        let s14 = transformer_share(&unet_ops(&sd_v14()));
        let sxl = transformer_share(&unet_ops(&sd_xl()));
        assert!(s14 < 0.55);
        assert!(sxl > 0.60, "xl share {sxl}");
    }
}
