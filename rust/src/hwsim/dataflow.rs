//! Systolic-array timing for linear operators under both dataflows.

use super::arch::{AccelConfig, Dataflow};
use crate::models::inventory::OpKind;

/// Cost of a linear op on the SA.
#[derive(Debug, Clone, Copy, Default)]
pub struct SaCost {
    pub cycles: f64,
    /// Extra cycles visible before the SA can stream (im2col conversion).
    pub conversion_cycles: f64,
    pub macs: f64,
}

impl SaCost {
    pub fn utilization(&self, cfg: &AccelConfig) -> f64 {
        if self.cycles == 0.0 {
            return 0.0;
        }
        self.macs / (self.cycles * cfg.macs_per_cycle())
    }
}

/// Weight-stationary matmul (m, k) x (k, n): the SA processes one
/// (sa_rows x sa_cols) weight tile at a time, streaming m rows through
/// it, with fill/drain and weight-load overheads per tile.
///
/// `double_buffered`: the adaptive dataflow prefetches the next weight
/// tile while the current one streams (Sec. V-B), shrinking the per-tile
/// fill overhead; the fixed baseline reloads serially.
pub fn matmul_cycles_db(
    cfg: &AccelConfig,
    m: usize,
    n: usize,
    k: usize,
    double_buffered: bool,
) -> SaCost {
    let kt = k.div_ceil(cfg.sa_rows) as f64;
    let nt = n.div_ceil(cfg.sa_cols) as f64;
    let fill = (cfg.sa_rows + cfg.sa_cols) as f64;
    // Double-buffered: weight prefetch overlaps the previous tile's
    // stream, leaving only the output drain visible.
    let per_tile = m as f64 + if double_buffered { cfg.sa_cols as f64 } else { 1.5 * fill };
    SaCost {
        cycles: kt * nt * per_tile,
        conversion_cycles: 0.0,
        macs: (m as f64) * (n as f64) * (k as f64),
    }
}

/// Double-buffered matmul (the optimised design's default).
pub fn matmul_cycles(cfg: &AccelConfig, m: usize, n: usize, k: usize) -> SaCost {
    matmul_cycles_db(cfg, m, n, k, true)
}

/// im2col bank-conflict inflation on the converted stream (Sec. I / [53]).
pub const IM2COL_CONFLICT_FACTOR: f64 = 1.30;
/// im2col module write throughput (elements/cycle).
pub const IM2COL_ELEMS_PER_CYCLE: f64 = 32.0;
/// Fraction of the conversion latency NOT hidden behind SA compute
/// (explicit latency, varying kernel/stride breaks overlap — Sec. IV).
pub const IM2COL_VISIBLE_FRACTION: f64 = 0.5;

/// Convolution cost under the chosen dataflow.
pub fn conv_cycles(
    cfg: &AccelConfig,
    dataflow: Dataflow,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
) -> SaCost {
    conv_cycles_db(cfg, dataflow, h, w, cin, cout, k, stride, true)
}

#[allow(clippy::too_many_arguments)]
pub fn conv_cycles_db(
    cfg: &AccelConfig,
    dataflow: Dataflow,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    double_buffered: bool,
) -> SaCost {
    let p = h.div_ceil(stride);
    let q = w.div_ceil(stride);
    let macs = (p * q * cin * cout * k * k) as f64;
    match dataflow {
        Dataflow::AddressCentric => {
            // Uni-conv (Fig. 10): F independent 1x1 matmuls; stride is an
            // input-address stride, so only the needed rows stream in.
            // Partial-sum routing runs on the VPU in parallel (hidden).
            let per_kernel = matmul_cycles_db(cfg, p * q, cout, cin, double_buffered);
            SaCost {
                cycles: (k * k) as f64 * per_kernel.cycles,
                conversion_cycles: 0.0,
                macs,
            }
        }
        Dataflow::Im2col => {
            // One big matmul (PQ, k^2*Cin) x (k^2*Cin, Cout) after the
            // im2col transform: conversion latency + bank conflicts.
            let mm = matmul_cycles_db(cfg, p * q, cout, cin * k * k, double_buffered);
            let conversion =
                (p * q * cin * k * k) as f64 / IM2COL_ELEMS_PER_CYCLE * IM2COL_VISIBLE_FRACTION;
            SaCost {
                cycles: mm.cycles * IM2COL_CONFLICT_FACTOR,
                conversion_cycles: conversion,
                macs,
            }
        }
    }
}

/// SA cost for any linear OpKind (nonlinears cost 0 here).
pub fn op_sa_cost(
    cfg: &AccelConfig,
    dataflow: Dataflow,
    double_buffered: bool,
    kind: &OpKind,
) -> SaCost {
    match *kind {
        OpKind::Conv { h, w, cin, cout, k, stride } => {
            conv_cycles_db(cfg, dataflow, h, w, cin, cout, k, stride, double_buffered)
        }
        OpKind::Matmul { m, n, k } | OpKind::MatmulAct { m, n, k } => {
            matmul_cycles_db(cfg, m, n, k, double_buffered)
        }
        _ => SaCost::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    #[test]
    fn large_matmul_is_near_peak_utilization() {
        let c = matmul_cycles(&cfg(), 4096, 1024, 1024);
        let u = c.utilization(&cfg());
        assert!(u > 0.95, "util {u}");
    }

    #[test]
    fn tiny_matmul_underutilizes() {
        let c = matmul_cycles(&cfg(), 8, 8, 8);
        assert!(c.utilization(&cfg()) < 0.1);
    }

    #[test]
    fn address_centric_conv_matches_decomposition() {
        // 9 x (L, Cin)x(Cin, Cout) matmuls.
        let c = conv_cycles(&cfg(), Dataflow::AddressCentric, 64, 64, 320, 320, 3, 1);
        let per = matmul_cycles(&cfg(), 64 * 64, 320, 320);
        assert!((c.cycles - 9.0 * per.cycles).abs() < 1e-6);
        assert!(c.utilization(&cfg()) > 0.9);
    }

    #[test]
    fn im2col_conv_slower_than_address_centric() {
        let ac = conv_cycles(&cfg(), Dataflow::AddressCentric, 64, 64, 320, 320, 3, 1);
        let im = conv_cycles(&cfg(), Dataflow::Im2col, 64, 64, 320, 320, 3, 1);
        let ac_t = ac.cycles + ac.conversion_cycles;
        let im_t = im.cycles + im.conversion_cycles;
        assert!(im_t > 1.1 * ac_t, "im2col {im_t} vs ac {ac_t}");
    }

    #[test]
    fn stride2_conv_quarter_work() {
        let s1 = conv_cycles(&cfg(), Dataflow::AddressCentric, 64, 64, 320, 320, 3, 1);
        let s2 = conv_cycles(&cfg(), Dataflow::AddressCentric, 64, 64, 320, 320, 3, 2);
        let ratio = s1.cycles / s2.cycles;
        assert!((3.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn conv1x1_equals_plain_matmul() {
        let c = conv_cycles(&cfg(), Dataflow::AddressCentric, 32, 32, 640, 640, 1, 1);
        let mm = matmul_cycles(&cfg(), 1024, 640, 640);
        assert!((c.cycles - mm.cycles).abs() < 1e-9);
    }
}
