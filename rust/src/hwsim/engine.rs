//! The cycle-accurate performance model: ops -> {cycles, traffic, energy}.

use super::arch::{AccelConfig, NonlinearMode, Policy, ReuseMode};
use super::dataflow::op_sa_cost;
use super::fusion::plan_fusion;
use super::memory::{op_traffic_bytes, FusionTag};
use super::streaming::nonlinear_visible_cycles;
use crate::models::inventory::{conv3x3_layers, LayerOp};
use crate::quant::format::QuantScheme;

/// Per-run aggregate report.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub sa_cycles: f64,
    /// SA cycles weighted by per-MAC dynamic power relative to the
    /// native datapath (multiplier power ~ linear in operand width at
    /// fixed throughput, since a b-bit MAC costs ~(b/native)^2 the energy
    /// and runs native/b times faster). Equals `sa_cycles` when every op
    /// runs at native precision, so the energy model below reduces
    /// exactly to the Table I formulation.
    pub sa_scaled_cycles: f64,
    pub conversion_cycles: f64,
    pub nonlinear_cycles: f64,
    pub mem_stall_cycles: f64,
    pub traffic_bytes: f64,
    pub macs: f64,
    pub layers: usize,
}

impl Report {
    pub fn total_cycles(&self) -> f64 {
        self.sa_cycles + self.conversion_cycles + self.nonlinear_cycles + self.mem_stall_cycles
    }

    pub fn seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.cycles_to_seconds(self.total_cycles())
    }

    /// Achieved FLOP/s.
    pub fn achieved_flops(&self, cfg: &AccelConfig) -> f64 {
        2.0 * self.macs / self.seconds(cfg)
    }

    /// PE utilisation (MACs retired / MAC slots in total time).
    pub fn utilization(&self, cfg: &AccelConfig) -> f64 {
        self.macs / (self.total_cycles() * cfg.macs_per_cycle())
    }

    /// Operational intensity (FLOP per DRAM byte) for the roofline.
    pub fn operational_intensity(&self) -> f64 {
        2.0 * self.macs / self.traffic_bytes.max(1.0)
    }

    /// Energy (J): on-chip power x time + DRAM access energy, with a
    /// precision correction on the SA term — ops running wider than the
    /// native datapath draw proportionally more MAC power, narrower ops
    /// proportionally less (`sa_scaled_cycles`). At native precision the
    /// correction is exactly zero and this is Table I's formulation.
    pub fn energy_j(&self, cfg: &AccelConfig) -> f64 {
        let sa_correction_s = (self.sa_scaled_cycles - self.sa_cycles) / cfg.freq_hz;
        cfg.onchip_power_w() * self.seconds(cfg)
            + cfg.p_sa_w * sa_correction_s
            + self.traffic_bytes * cfg.dram_j_per_byte
    }
}

/// Fraction of memory time hidden behind compute. im2col's conversion
/// bursts serialise the DMA; the address-centric stream overlaps most of
/// it; the adaptive dataflow's single-pass streams double-buffer almost
/// perfectly (Sec. V-B).
fn mem_overlap(policy: Policy) -> f64 {
    match (policy.dataflow, policy.reuse) {
        (super::arch::Dataflow::Im2col, _) => 0.0,
        (_, ReuseMode::Fixed) => 0.6,
        (_, ReuseMode::Adaptive) => 0.97,
    }
}

/// Simulate an operator list under a policy at native precision.
pub fn simulate(cfg: &AccelConfig, policy: Policy, ops: &[LayerOp]) -> Report {
    simulate_inner(cfg, policy, ops, None)
}

/// Precision-aware simulation: `prec[i]` is the (weight, activation)
/// format of `ops[i]` (see `quant::search::assign`). Three effects:
/// cycles scale with the MAC width (a narrow multiplier array retires
/// proportionally more MACs per cycle, SIMD-style), DRAM traffic scales
/// with per-operand bytes, and the SA energy term scales with per-MAC
/// power — so a W4A8 plan shows up in every `Report` axis.
pub fn simulate_quant(
    cfg: &AccelConfig,
    policy: Policy,
    ops: &[LayerOp],
    prec: &[QuantScheme],
) -> Report {
    assert_eq!(prec.len(), ops.len(), "one scheme per op");
    simulate_inner(cfg, policy, ops, Some(prec))
}

fn simulate_inner(
    cfg: &AccelConfig,
    policy: Policy,
    ops: &[LayerOp],
    prec: Option<&[QuantScheme]>,
) -> Report {
    // Fusion plan over the 3x3-conv backbone (Sec. V-B / Fig. 16).
    let convs = conv3x3_layers(ops);
    let plan = plan_fusion(cfg, &convs);
    let default_tag = FusionTag { weight_refetch: 1.0, ..Default::default() };
    let conv_tag_of = |name: &str| -> FusionTag {
        convs
            .iter()
            .position(|o| o.name == name)
            .map(|i| plan.tags[i])
            .unwrap_or(default_tag)
    };

    // Generic producer-consumer chaining for the non-conv linear chain
    // (transformer ln->qkv->attn->proj->ff): a boundary stays on-chip if
    // the forwarded activation fits in half the global buffer.
    let n = ops.len();
    let mut chain_tags = vec![default_tag; n];
    if policy.fusion {
        let b = cfg.dtype_bytes as f64;
        let thresh = cfg.gb_bytes as f64 * 0.65;
        for (i, op) in ops.iter().enumerate() {
            let linear = matches!(
                op.kind,
                crate::models::inventory::OpKind::Matmul { .. }
                    | crate::models::inventory::OpKind::MatmulAct { .. }
            );
            if !linear {
                continue;
            }
            // Small activations simply live in the global buffer through
            // the block (layer-by-layer fusion for the matmul chain).
            if (op.kind.input_elems() as f64) * b <= thresh {
                chain_tags[i].input_fused = true;
            }
            if (op.kind.output_elems() as f64) * b <= thresh {
                chain_tags[i].output_fused = true;
            }
        }
    }
    // Tile-decoupled streaming softmax (Sec. IV-C) never materialises
    // the logit matrix off-chip: the logits producer streams into the
    // VPU and the AV consumer reads the normalised stream back.
    if policy.nonlinear == NonlinearMode::Streaming2Stage {
        for (i, op) in ops.iter().enumerate() {
            if matches!(op.kind, crate::models::inventory::OpKind::MatmulAct { .. }) {
                if op.name.ends_with("logits") {
                    chain_tags[i].output_fused = true;
                } else if op.name.ends_with("attnv") {
                    chain_tags[i].input_fused = true;
                }
            }
        }
    }

    let mut rep = Report::default();
    let overlap = mem_overlap(policy);
    let double_buffered = policy.reuse == ReuseMode::Adaptive;
    let native_bits = (cfg.dtype_bytes * 8) as f64;
    let native_bytes = cfg.dtype_bytes as f64;
    for (i, op) in ops.iter().enumerate() {
        // Per-op operand widths; the native path uses the Table I dtype.
        let (w_bytes, a_bytes, mac_bits) = match prec {
            None => (native_bytes, native_bytes, native_bits),
            Some(p) => (p[i].weight.bytes(), p[i].act.bytes(), p[i].mac_bits() as f64),
        };
        let mut sa = op_sa_cost(cfg, policy.dataflow, double_buffered, &op.kind);
        // MAC throughput scales inversely with multiplier width: an int8
        // op packs native_bits/8 MACs per PE per cycle; fp32 takes two.
        sa.cycles *= mac_bits / native_bits;
        let nl = nonlinear_visible_cycles(cfg, policy.nonlinear, &op.kind);
        let tag = if op.kind.is_conv3x3() {
            if policy.fusion { conv_tag_of(&op.name) } else { default_tag }
        } else {
            chain_tags[i]
        };
        let tr = op_traffic_bytes(cfg, policy, &op.kind, tag, w_bytes, a_bytes);
        let mem_cycles = tr.total() / cfg.dram_bw * cfg.freq_hz;
        // Un-hidden memory time: the (1 - overlap) fraction of each
        // layer's DMA serialises with compute.
        let stall = mem_cycles * (1.0 - overlap);

        rep.sa_cycles += sa.cycles;
        // Per-MAC energy ~ (width/native)^2 over width/native the cycles
        // => the power-weighted cycle count scales linearly in width.
        rep.sa_scaled_cycles += sa.cycles * (mac_bits / native_bits);
        rep.conversion_cycles += sa.conversion_cycles;
        rep.nonlinear_cycles += nl;
        rep.mem_stall_cycles += stall;
        rep.traffic_bytes += tr.total();
        rep.macs += sa.macs;
        rep.layers += 1;
    }
    rep
}

/// One U-Net denoising step (CFG doubles the batch => 2x work).
pub fn simulate_unet_step(cfg: &AccelConfig, policy: Policy, ops: &[LayerOp]) -> Report {
    double_for_cfg(simulate(cfg, policy, ops))
}

/// Precision-aware variant of [`simulate_unet_step`].
pub fn simulate_unet_step_quant(
    cfg: &AccelConfig,
    policy: Policy,
    ops: &[LayerOp],
    prec: &[QuantScheme],
) -> Report {
    double_for_cfg(simulate_quant(cfg, policy, ops, prec))
}

fn double_for_cfg(mut r: Report) -> Report {
    r.sa_cycles *= 2.0;
    r.sa_scaled_cycles *= 2.0;
    r.conversion_cycles *= 2.0;
    r.nonlinear_cycles *= 2.0;
    r.mem_stall_cycles *= 2.0;
    r.traffic_bytes *= 2.0;
    r.macs *= 2.0;
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::arch::Dataflow;
    use crate::models::inventory::{sd_v14, unet_ops};

    fn ladder() -> (f64, f64, f64, f64) {
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let t = |p: Policy| simulate(&cfg, p, &ops).total_cycles();
        (
            t(Policy::baseline()),
            t(Policy::with_ac()),
            t(Policy::with_ac_ad()),
            t(Policy::optimized()),
        )
    }

    /// Fig. 17b (left): AC ~1.24x, +AD ~1.37x, +SC ~1.65x over the
    /// im2col baseline for SD v1.4.
    #[test]
    fn fig17_ablation_ladder() {
        let (base, ac, ad, sc) = ladder();
        let s_ac = base / ac;
        let s_ad = base / ad;
        let s_sc = base / sc;
        assert!((1.14..1.34).contains(&s_ac), "AC speedup {s_ac:.3}");
        assert!((1.27..1.47).contains(&s_ad), "AC+AD speedup {s_ad:.3}");
        assert!((1.50..1.75).contains(&s_sc), "AC+AD+SC speedup {s_sc:.3}");
        assert!(s_ac < s_ad && s_ad < s_sc);
    }

    #[test]
    fn optimized_hits_high_utilization() {
        // Sec. VI-D: the optimised design reaches ~95% of theoretical.
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let rep = simulate(&cfg, Policy::optimized(), &ops);
        let u = rep.utilization(&cfg);
        assert!(u > 0.80, "utilization {u:.3}");
    }

    #[test]
    fn workload_is_compute_bound_on_the_roofline() {
        // Fig. 17a: SD inference on this config is compute-bound.
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let rep = simulate(&cfg, Policy::optimized(), &ops);
        let balance = cfg.peak_flops() / cfg.dram_bw; // FLOP/byte knee
        assert!(
            rep.operational_intensity() > 2.0 * balance,
            "intensity {:.1} vs knee {balance:.1}",
            rep.operational_intensity()
        );
    }

    #[test]
    fn adaptive_reuse_and_fusion_cut_traffic_in_paper_bands() {
        // Sec. VI-C: adaptive reuse saves ~24.3%, fusion ~30.5% more.
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let mut p_fixed = Policy::with_ac();
        p_fixed.reuse = ReuseMode::Fixed;
        let mut p_reuse = Policy::with_ac();
        p_reuse.reuse = ReuseMode::Adaptive;
        let mut p_fused = p_reuse;
        p_fused.fusion = true;
        let t_fixed = simulate(&cfg, p_fixed, &ops).traffic_bytes;
        let t_reuse = simulate(&cfg, p_reuse, &ops).traffic_bytes;
        let t_fused = simulate(&cfg, p_fused, &ops).traffic_bytes;
        let save_reuse = 1.0 - t_reuse / t_fixed;
        let save_fusion = 1.0 - t_fused / t_reuse;
        assert!((0.10..0.45).contains(&save_reuse), "reuse saving {save_reuse:.3}");
        assert!((0.03..0.45).contains(&save_fusion), "fusion saving {save_fusion:.3}");
    }

    #[test]
    fn energy_dominated_by_onchip_at_fpga_power() {
        // Sec. VI-D: "on-chip computation energy still dominates".
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let rep = simulate(&cfg, Policy::optimized(), &ops);
        let onchip = cfg.onchip_power_w() * rep.seconds(&cfg);
        let dram = rep.traffic_bytes * cfg.dram_j_per_byte;
        assert!(onchip > 5.0 * dram, "onchip {onchip} dram {dram}");
    }

    #[test]
    fn native_scheme_reproduces_plain_simulate_exactly() {
        // The accelerator's native datapath is fp16 (Table I dtype 2 B):
        // a uniform fp16 assignment must be bit-identical to `simulate`.
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let a = simulate(&cfg, Policy::optimized(), &ops);
        let prec = vec![QuantScheme::fp16(); ops.len()];
        let b = simulate_quant(&cfg, Policy::optimized(), &ops, &prec);
        assert_eq!(a.sa_cycles, b.sa_cycles);
        assert_eq!(a.sa_scaled_cycles, b.sa_scaled_cycles);
        assert_eq!(a.traffic_bytes, b.traffic_bytes);
        assert_eq!(a.mem_stall_cycles, b.mem_stall_cycles);
        assert_eq!(a.energy_j(&cfg), b.energy_j(&cfg));
        // At native precision the energy correction is exactly zero.
        assert_eq!(a.sa_scaled_cycles, a.sa_cycles);
    }

    #[test]
    fn precision_scales_cycles_traffic_and_energy() {
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let run = |s: QuantScheme| {
            simulate_quant(&cfg, Policy::optimized(), &ops, &vec![s; ops.len()])
        };
        let fp32 = run(QuantScheme::fp32());
        let fp16 = run(QuantScheme::fp16());
        let w8a8 = run(QuantScheme::w8a8());
        let w4a8 = run(QuantScheme::w4a8());
        let w4a4 = run(QuantScheme::w4a4());
        // Cycles: fp32 doubles the native SA time, int8 halves it, and
        // W4A8 is throughput-bound by its 8-bit activations.
        assert!((fp32.sa_cycles / fp16.sa_cycles - 2.0).abs() < 1e-9);
        assert!((fp16.sa_cycles / w8a8.sa_cycles - 2.0).abs() < 1e-9);
        assert_eq!(w8a8.sa_cycles, w4a8.sa_cycles);
        assert!((w8a8.sa_cycles / w4a4.sa_cycles - 2.0).abs() < 1e-9);
        // Traffic: monotone in operand bytes; W4A8 moves fewer weight
        // bytes than W8A8 at equal cycles.
        assert!(fp32.traffic_bytes > fp16.traffic_bytes);
        assert!(fp16.traffic_bytes > w8a8.traffic_bytes);
        assert!(w8a8.traffic_bytes > w4a8.traffic_bytes);
        // Energy: strictly ordered, and the acceptance band — W8A8 must
        // model at least a 3x energy win over fp32.
        let e32 = fp32.energy_j(&cfg);
        let e16 = fp16.energy_j(&cfg);
        let e8 = w8a8.energy_j(&cfg);
        let e48 = w4a8.energy_j(&cfg);
        assert!(e32 > e16 && e16 > e8 && e8 > e48, "{e32} {e16} {e8} {e48}");
        assert!(e32 / e8 >= 3.0, "W8A8 energy reduction {:.2}x", e32 / e8);
        assert!(e48 > 0.0, "energy stays positive under the int4 refund");
    }

    #[test]
    fn unet_step_quant_doubles_all_axes() {
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let prec = vec![QuantScheme::w8a8(); ops.len()];
        let one = simulate_quant(&cfg, Policy::optimized(), &ops, &prec);
        let step = simulate_unet_step_quant(&cfg, Policy::optimized(), &ops, &prec);
        assert_eq!(step.sa_cycles, 2.0 * one.sa_cycles);
        assert_eq!(step.sa_scaled_cycles, 2.0 * one.sa_scaled_cycles);
        assert_eq!(step.traffic_bytes, 2.0 * one.traffic_bytes);
        assert_eq!(step.macs, 2.0 * one.macs);
    }

    #[test]
    fn im2col_only_hurts_convs() {
        let cfg = AccelConfig::default();
        let mm = vec![LayerOp {
            name: "m".into(),
            block: crate::models::inventory::Block::Mid,
            kind: crate::models::inventory::OpKind::Matmul { m: 512, n: 512, k: 512 },
        }];
        let a = simulate(&cfg, Policy::baseline(), &mm).sa_cycles;
        let mut p = Policy::baseline();
        p.dataflow = Dataflow::AddressCentric;
        let b = simulate(&cfg, p, &mm).sa_cycles;
        assert_eq!(a, b);
    }
}
