//! Adaptive fusion planner (Sec. V-B, Fig. 14c / Fig. 16).
//!
//! Decides per 3x3-conv layer: no fusion, layer-by-layer fusion (both
//! activations co-resident in the global buffer — the middle layers), or
//! cross-layer fusion (weight-resident groups streaming partial
//! activations — the shallowest/deepest layers).

use super::arch::AccelConfig;
use super::memory::{choose_reuse, FusionTag, ReuseChoice};
use crate::models::inventory::{LayerOp, OpKind};

/// Per-layer fusion decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionKind {
    None,
    LayerByLayer,
    CrossLayer,
}

/// The plan: one entry per conv layer, aligned with the input slice.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    pub kinds: Vec<FusionKind>,
    pub tags: Vec<FusionTag>,
}

fn conv_sizes(cfg: &AccelConfig, kind: &OpKind) -> (f64, f64, f64) {
    let b = cfg.dtype_bytes as f64;
    match *kind {
        OpKind::Conv { h, w, cin, cout, k, stride } => {
            let (p, q) = (h.div_ceil(stride), w.div_ceil(stride));
            (
                (h * w * cin) as f64 * b,
                (cin * cout * k * k) as f64 * b,
                (p * q * cout) as f64 * b,
            )
        }
        _ => (0.0, 0.0, 0.0),
    }
}

/// Build the fusion plan for a sequence of conv layers (Fig. 13's 0..51
/// indexing for SD v1.4). Decision procedure from Sec. V-B:
///
/// 1. choose input- vs weight-reuse per layer (least traffic);
/// 2. input-reuse layers: layer-by-layer fusion if this layer's input AND
///    output both fit the global buffer together;
/// 3. weight-reuse layers: greedy cross-layer groups while the group's
///    weights stay within the buffer;
/// 4. otherwise no fusion (weight-access increase would exceed the
///    activation saving).
pub fn plan_fusion(cfg: &AccelConfig, convs: &[&LayerOp]) -> FusionPlan {
    let n = convs.len();
    let gb = cfg.gb_bytes as f64;
    let sizes: Vec<(f64, f64, f64)> = convs.iter().map(|o| conv_sizes(cfg, &o.kind)).collect();
    let reuse: Vec<ReuseChoice> =
        sizes.iter().map(|&(i, w, _)| choose_reuse(cfg, i, w)).collect();

    let mut kinds = vec![FusionKind::None; n];
    let mut refetch = vec![1.0f64; n];
    // Step 2: layer-by-layer for input-reuse layers whose input + output
    // activations are co-resident in the global buffer.
    for i in 0..n {
        if reuse[i] == ReuseChoice::InputReuse {
            let (inp, _, out) = sizes[i];
            if inp + out <= gb {
                kinds[i] = FusionKind::LayerByLayer;
            }
        }
    }
    // Step 3: cross-layer groups over weight-reuse layers. Weights of a
    // group may exceed the buffer — activations then stream in strips
    // and the group's weights are re-fetched per strip; fuse only while
    // the activation saving exceeds the weight re-read penalty
    // ("carefully selected", Sec. V-B).
    let mut i = 0;
    while i < n {
        if reuse[i] == ReuseChoice::InputReuse || kinds[i] != FusionKind::None {
            i += 1;
            continue;
        }
        // Maximal run of non-input-reuse layers starting at i. Layers
        // whose weights exceed the buffer may still join a group — their
        // weights stream per strip, which the penalty term prices in
        // ("may exceed buffer capacity and result in more weight
        // access", Sec. V-B).
        let mut j = i;
        while j < n && reuse[j] != ReuseChoice::InputReuse && kinds[j] == FusionKind::None {
            j += 1;
        }
        // Pick the most profitable sub-window [s, e) of the run: partial
        // activations stream in strips sized by the group's working
        // activation; group weights are re-fetched once per extra strip.
        let mut best: Option<(usize, usize, f64, f64)> = None; // (s, e, net, strips)
        for s in i..j {
            for e in (s + 2)..=j {
                let wsum: f64 = sizes[s..e].iter().map(|x| x.1).sum();
                let strips = ((sizes[s].2 * 2.0) / gb).ceil().max(1.0);
                let penalty = wsum * (strips - 1.0);
                let saving: f64 = (s..e - 1).map(|k| sizes[k].2 + sizes[k + 1].0).sum();
                let net = saving - penalty;
                if net > 0.0 && best.map_or(true, |(_, _, b, _)| net > b) {
                    best = Some((s, e, net, strips));
                }
            }
        }
        if let Some((s, e, _, strips)) = best {
            for k in s..e {
                kinds[k] = FusionKind::CrossLayer;
                refetch[k] = strips;
            }
        }
        i = j.max(i + 1);
    }

    // Translate to boundary tags: a boundary between consecutive layers
    // is fused if both sides participate in some fusion scheme.
    let fused_boundary = |a: FusionKind, b: FusionKind| {
        a != FusionKind::None && b != FusionKind::None
    };
    let mut tags = vec![FusionTag { weight_refetch: 1.0, ..Default::default() }; n];
    for idx in 0..n {
        if idx > 0 && fused_boundary(kinds[idx - 1], kinds[idx]) {
            tags[idx].input_fused = true;
        }
        if idx + 1 < n && fused_boundary(kinds[idx], kinds[idx + 1]) {
            tags[idx].output_fused = true;
        }
        tags[idx].weight_refetch = refetch[idx];
    }
    FusionPlan { kinds, tags }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::{conv3x3_layers, sd_v14, unet_ops};

    #[test]
    fn fig16_pattern_cross_layer_at_ends_layerwise_in_middle() {
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let convs = conv3x3_layers(&ops);
        assert_eq!(convs.len(), 52);
        let plan = plan_fusion(&cfg, &convs);

        // Paper (Fig. 16): cross-layer fusion on layers 0~5 and 44~51.
        for i in [0usize, 1, 2, 3, 4] {
            assert_eq!(plan.kinds[i], FusionKind::CrossLayer, "layer {i}: {:?}", plan.kinds[i]);
        }
        for i in [46usize, 48, 50, 51] {
            assert_eq!(plan.kinds[i], FusionKind::CrossLayer, "layer {i}: {:?}", plan.kinds[i]);
        }
        // Layer-by-layer in the middle (6~36).
        let mid_lbl = (10..35)
            .filter(|&i| plan.kinds[i] == FusionKind::LayerByLayer)
            .count();
        assert!(mid_lbl > 15, "only {mid_lbl} middle layers layer-by-layer");
        // No cross-layer fusion deep in the middle.
        assert!(
            (12..34).all(|i| plan.kinds[i] != FusionKind::CrossLayer),
            "cross-layer leaked into the middle"
        );
    }

    #[test]
    fn tags_mark_interior_boundaries_only() {
        let cfg = AccelConfig::default();
        let ops = unet_ops(&sd_v14());
        let convs = conv3x3_layers(&ops);
        let plan = plan_fusion(&cfg, &convs);
        // First layer of a fused chain never has a fused input.
        assert!(!plan.tags[0].input_fused);
        // A fused boundary sets output on the left and input on the right.
        for i in 1..convs.len() {
            if plan.tags[i].input_fused {
                assert!(plan.tags[i - 1].output_fused, "boundary {i} asymmetric");
            }
        }
    }

    #[test]
    fn tiny_gb_kills_fusion() {
        let mut cfg = AccelConfig::default();
        cfg.gb_bytes = 4 << 10; // 4 KB: nothing fits
        let ops = unet_ops(&sd_v14());
        let convs = conv3x3_layers(&ops);
        let plan = plan_fusion(&cfg, &convs);
        assert!(plan.kinds.iter().all(|&k| k == FusionKind::None));
    }

    #[test]
    fn bigger_gb_fuses_no_less() {
        let ops = unet_ops(&sd_v14());
        let convs = conv3x3_layers(&ops);
        let count = |gb: usize| {
            let mut cfg = AccelConfig::default();
            cfg.gb_bytes = gb;
            plan_fusion(&cfg, &convs)
                .kinds
                .iter()
                .filter(|&&k| k != FusionKind::None)
                .count()
        };
        assert!(count(8 << 20) >= count(2 << 20));
        assert!(count(2 << 20) >= count(256 << 10));
    }
}
