//! Off-chip traffic model: reuse policies + fusion effects (Sec. V).

use super::arch::{AccelConfig, Dataflow, Policy, ReuseMode};
use crate::models::inventory::OpKind;

/// How a layer participates in fusion (Sec. V-B, Fig. 14c).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FusionTag {
    /// Input arrives on-chip from the previous layer (no DRAM read).
    pub input_fused: bool,
    /// Output is forwarded on-chip to the next layer (no DRAM write).
    pub output_fused: bool,
    /// Cross-layer fusion group: weights of the group are co-resident,
    /// counted once but possibly re-fetched if the group overflows.
    pub weight_refetch: f64,
}

/// Which operand the adaptive policy pins in the global buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseChoice {
    InputReuse,
    WeightReuse,
    /// Neither fits: tiled with the larger operand streamed repeatedly.
    Tiled,
}

/// Pick the reuse strategy for a layer (Sec. V-B: "consistently select
/// the reuse method with less memory access").
pub fn choose_reuse(cfg: &AccelConfig, in_bytes: f64, w_bytes: f64) -> ReuseChoice {
    let gb = cfg.gb_bytes as f64;
    let in_fits = in_bytes <= gb;
    let w_fits = w_bytes <= gb;
    match (in_fits, w_fits) {
        (true, true) => {
            if in_bytes <= w_bytes {
                ReuseChoice::InputReuse
            } else {
                ReuseChoice::WeightReuse
            }
        }
        (true, false) => ReuseChoice::InputReuse,
        (false, true) => ReuseChoice::WeightReuse,
        (false, false) => ReuseChoice::Tiled,
    }
}

/// Traffic of one linear op in bytes (weights + input + output), given
/// the policy and the layer's fusion tag.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    pub input: f64,
    pub weight: f64,
    pub output: f64,
}

impl Traffic {
    pub fn total(&self) -> f64 {
        self.input + self.weight + self.output
    }
}

pub fn op_traffic(cfg: &AccelConfig, policy: Policy, kind: &OpKind, tag: FusionTag) -> Traffic {
    let b = cfg.dtype_bytes as f64;
    op_traffic_bytes(cfg, policy, kind, tag, b, b)
}

/// Precision-aware traffic: weights move at `w_bytes`/element and
/// activations at `a_bytes`/element (the quant subsystem's per-layer
/// formats; `op_traffic` is the native-precision special case). The
/// reuse/tiling decisions see the scaled sizes, so narrower operands can
/// flip a layer from Tiled to a single-pass reuse choice — exactly the
/// interaction mixed precision buys on a fixed global buffer.
pub fn op_traffic_bytes(
    cfg: &AccelConfig,
    policy: Policy,
    kind: &OpKind,
    tag: FusionTag,
    w_bytes: f64,
    a_bytes: f64,
) -> Traffic {
    let (mut in_b, w_b, out_b, n_dim) = match *kind {
        OpKind::Conv { h, w, cin, cout, k, stride } => {
            let (p, q) = (h.div_ceil(stride), w.div_ceil(stride));
            (
                (h * w * cin) as f64 * a_bytes,
                (cin * cout * k * k) as f64 * w_bytes,
                (p * q * cout) as f64 * a_bytes,
                cout,
            )
        }
        OpKind::Matmul { m, n, k } => (
            (m * k) as f64 * a_bytes,
            (k * n) as f64 * w_bytes,
            (m * n) as f64 * a_bytes,
            n,
        ),
        // Activation-activation matmul: "weight" side is the second
        // activation operand (K^T / V) — streamed like weights but moved
        // at activation precision.
        OpKind::MatmulAct { m, n, k } => (
            (m * k) as f64 * a_bytes,
            (k * n) as f64 * a_bytes,
            (m * n) as f64 * a_bytes,
            n,
        ),
        // Nonlinears ride the streams (their data is counted by the
        // producing/consuming matmuls); no extra DRAM traffic.
        _ => return Traffic::default(),
    };

    // im2col duplicates the input window-wise before the SA (Sec. I:
    // "significant increase in memory access").
    if policy.dataflow == Dataflow::Im2col {
        if let OpKind::Conv { k, .. } = *kind {
            in_b *= (k * k) as f64;
        }
    }

    let gb = cfg.gb_bytes as f64;
    let (mut input, mut weight) = match policy.reuse {
        ReuseMode::Fixed => {
            // No cross-tile pinning: the streamed input is re-fetched per
            // output-column tile group (bounded by the DMA's burst
            // batching), softened by whatever fraction of it the global
            // buffer happens to retain.
            let rereads = (n_dim as f64 / cfg.sa_cols as f64).ceil().clamp(1.0, 6.0);
            let miss = (1.0 - gb / in_b).clamp(0.0, 1.0);
            (in_b * (1.0 + (rereads - 1.0) * miss), w_b)
        }
        ReuseMode::Adaptive => match choose_reuse(cfg, in_b, w_b) {
            ReuseChoice::InputReuse | ReuseChoice::WeightReuse => (in_b, w_b),
            ReuseChoice::Tiled => {
                // Both exceed GB: stream the larger once per GB-sized
                // chunk of the smaller.
                let chunks = (in_b.min(w_b) / gb).ceil().max(1.0);
                if in_b > w_b {
                    (in_b * chunks, w_b)
                } else {
                    (in_b, w_b * chunks)
                }
            }
        },
    };
    let mut output = out_b;

    if tag.input_fused {
        input = 0.0;
    }
    if tag.output_fused {
        output = 0.0;
    }
    weight *= tag.weight_refetch.max(1.0);
    Traffic { input, weight, output }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    fn conv64() -> OpKind {
        OpKind::Conv { h: 64, w: 64, cin: 320, cout: 320, k: 3, stride: 1 }
    }

    fn mid_conv() -> OpKind {
        OpKind::Conv { h: 8, w: 8, cin: 1280, cout: 1280, k: 3, stride: 1 }
    }

    #[test]
    fn reuse_choice_follows_fig13() {
        let c = cfg();
        // Shallow layer: activations 2.6 MB (> GB), weights 1.8 MB (fit)
        // -> weight reuse.
        assert_eq!(choose_reuse(&c, 2.6e6, 1.8e6), ReuseChoice::WeightReuse);
        // Middle layer: activations 160 KB, weights 29 MB -> input reuse.
        assert_eq!(choose_reuse(&c, 0.16e6, 29e6), ReuseChoice::InputReuse);
        // Both huge -> tiled.
        assert_eq!(choose_reuse(&c, 40e6, 40e6), ReuseChoice::Tiled);
    }

    #[test]
    fn adaptive_single_passes_everything() {
        let t = op_traffic(&cfg(), Policy::optimized(), &mid_conv(), FusionTag::default());
        let b = 2.0;
        assert!((t.input - 8.0 * 8.0 * 1280.0 * b).abs() < 1.0);
        assert!((t.weight - 1280.0 * 1280.0 * 9.0 * b).abs() < 1.0);
    }

    #[test]
    fn fixed_reuse_refetches_streamed_input() {
        let fixed = op_traffic(&cfg(), Policy::with_ac(), &conv64(), FusionTag::default());
        let adaptive = op_traffic(&cfg(), Policy::optimized(), &conv64(), FusionTag::default());
        assert!(
            fixed.input > 1.5 * adaptive.input,
            "fixed {} vs adaptive {}",
            fixed.input,
            adaptive.input
        );
        assert_eq!(fixed.weight, adaptive.weight);
    }

    #[test]
    fn im2col_duplicates_conv_input() {
        let im = op_traffic(&cfg(), Policy::baseline(), &conv64(), FusionTag::default());
        let mut p = Policy::baseline();
        p.dataflow = Dataflow::AddressCentric;
        let ac = op_traffic(&cfg(), p, &conv64(), FusionTag::default());
        assert!(im.input > 5.0 * ac.input, "im2col {} ac {}", im.input, ac.input);
    }

    #[test]
    fn fusion_removes_boundary_traffic() {
        let tag = FusionTag { input_fused: true, output_fused: true, weight_refetch: 1.0 };
        let t = op_traffic(&cfg(), Policy::optimized(), &mid_conv(), tag);
        assert_eq!(t.input, 0.0);
        assert_eq!(t.output, 0.0);
        assert!(t.weight > 0.0);
    }

    #[test]
    fn precision_scales_each_operand_independently() {
        // W4A8 on a mid conv: weights at 0.5 B/elem, activations at 1 B.
        let t = op_traffic_bytes(
            &cfg(),
            Policy::optimized(),
            &mid_conv(),
            FusionTag::default(),
            0.5,
            1.0,
        );
        assert!((t.input - 8.0 * 8.0 * 1280.0).abs() < 1.0);
        assert!((t.weight - 1280.0 * 1280.0 * 9.0 * 0.5).abs() < 1.0);
        // MatmulAct moves its second operand at activation precision.
        let ma = OpKind::MatmulAct { m: 64, n: 64, k: 32 };
        let t = op_traffic_bytes(&cfg(), Policy::optimized(), &ma, FusionTag::default(), 0.5, 1.0);
        assert!((t.weight - (32.0 * 64.0)).abs() < 1e-9, "K/V side uses act bytes");
        // Native byte width reproduces op_traffic exactly.
        let b = cfg().dtype_bytes as f64;
        let a = op_traffic(&cfg(), Policy::optimized(), &conv64(), FusionTag::default());
        let q = op_traffic_bytes(&cfg(), Policy::optimized(), &conv64(), FusionTag::default(), b, b);
        assert_eq!((a.input, a.weight, a.output), (q.input, q.weight, q.output));
    }

    #[test]
    fn nonlinears_are_traffic_free() {
        let t = op_traffic(
            &cfg(),
            Policy::baseline(),
            &OpKind::Softmax { rows: 4096, cols: 4096 },
            FusionTag::default(),
        );
        assert_eq!(t.total(), 0.0);
    }
}
