//! SD-Acc hardware simulator (S10): the paper's cycle-accurate
//! performance model (Sec. VI-A) rebuilt in rust.
//!
//! - [`arch`]: Table I configuration + the Fig. 17b policy ladder.
//! - [`dataflow`]: weight-stationary SA timing; address-centric Uni-conv
//!   vs the im2col baseline.
//! - [`streaming`]: store-then-compute vs 2-stage streaming nonlinears
//!   (calibrated to Fig. 15).
//! - [`memory`]: reuse policies + traffic accounting (Sec. V).
//! - [`fusion`]: the adaptive fusion planner (Fig. 16's pattern).
//! - [`engine`]: per-op assembly into cycles/traffic/energy reports.
//! - [`baselines`]: CPU/GPU analytic models, Cambricon-D and SDP
//!   simulators (Sec. VI-E/F).

pub mod arch;
pub mod baselines;
pub mod dataflow;
pub mod engine;
pub mod fusion;
pub mod memory;
mod proptests;
pub mod streaming;

pub use arch::{AccelConfig, Dataflow, NonlinearMode, Policy, ReuseMode};
pub use engine::{
    simulate, simulate_quant, simulate_unet_step, simulate_unet_step_quant, Report,
};
