//! Property tests over simulator invariants (in-tree framework,
//! rust/src/testing): randomized op shapes and configurations must never
//! violate the physical sanity of the model.

#![cfg(test)]

use super::arch::{AccelConfig, Dataflow, Policy};
use super::dataflow::{conv_cycles, matmul_cycles, op_sa_cost};
use super::engine::simulate;
use super::memory::{op_traffic, FusionTag};
use crate::models::inventory::{unet_ops, LayerOp, OpKind, UNetArch};
use crate::testing::{check_no_shrink, gen_usize};

fn gen_conv(rng: &mut crate::util::rng::Pcg32) -> OpKind {
    let k = if rng.bernoulli(0.5) { 3 } else { 1 };
    OpKind::Conv {
        h: gen_usize(rng, 2, 64),
        w: gen_usize(rng, 2, 64),
        cin: gen_usize(rng, 1, 512),
        cout: gen_usize(rng, 1, 512),
        k,
        stride: if rng.bernoulli(0.25) { 2 } else { 1 },
    }
}

#[test]
fn sa_cycles_bound_macs_from_above() {
    // No op may retire MACs faster than the array's peak.
    let cfg = AccelConfig::default();
    check_no_shrink("sa-cycles-lower-bound", gen_conv, |kind| {
        for df in [Dataflow::AddressCentric, Dataflow::Im2col] {
            for db in [true, false] {
                let c = op_sa_cost(&cfg, df, db, kind);
                if c.cycles * cfg.macs_per_cycle() < c.macs - 1e-6 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn im2col_never_faster_than_address_centric_on_aligned_channels() {
    // For SA-aligned channel counts (every real SD layer: multiples of
    // 32), the im2col path always costs at least as much as Uni-conv.
    // (Unaligned channels can tip the tile-padding balance either way —
    // the whole-network ladder test covers the aggregate claim.)
    let cfg = AccelConfig::default();
    check_no_shrink(
        "im2col-slower-aligned",
        |rng| OpKind::Conv {
            h: gen_usize(rng, 2, 64),
            w: gen_usize(rng, 2, 64),
            cin: 32 * gen_usize(rng, 1, 16),
            cout: 32 * gen_usize(rng, 1, 16),
            k: 3,
            stride: if rng.bernoulli(0.25) { 2 } else { 1 },
        },
        |kind| {
            if let OpKind::Conv { h, w, cin, cout, k, stride } = *kind {
                let ac = conv_cycles(&cfg, Dataflow::AddressCentric, h, w, cin, cout, k, stride);
                let im = conv_cycles(&cfg, Dataflow::Im2col, h, w, cin, cout, k, stride);
                im.cycles + im.conversion_cycles + 1e-6 >= ac.cycles
            } else {
                true
            }
        },
    );
}

#[test]
fn traffic_non_negative_and_adaptive_never_worse_when_pinnable() {
    // Whenever one operand fits the global buffer (every real SD layer
    // except the rare doubly-oversized ones), the adaptive single-pass
    // policy cannot move more bytes than the fixed re-streaming policy.
    let cfg = AccelConfig::default();
    let tag = FusionTag { weight_refetch: 1.0, ..Default::default() };
    check_no_shrink("adaptive-traffic-min", gen_conv, |kind| {
        let fixed = op_traffic(&cfg, Policy::with_ac(), kind, tag);
        let adaptive = op_traffic(&cfg, Policy::optimized(), kind, tag);
        if fixed.total() < 0.0 || adaptive.total() < 0.0 {
            return false;
        }
        let pinnable = adaptive.input.min(adaptive.weight) <= cfg.gb_bytes as f64;
        !pinnable || adaptive.total() <= fixed.total() + 1e-6
    });
}

#[test]
fn matmul_cycles_monotone_in_each_dim() {
    let cfg = AccelConfig::default();
    check_no_shrink(
        "matmul-monotone",
        |rng| {
            (
                gen_usize(rng, 1, 1024),
                gen_usize(rng, 1, 1024),
                gen_usize(rng, 1, 1024),
            )
        },
        |&(m, n, k)| {
            let c = matmul_cycles(&cfg, m, n, k).cycles;
            matmul_cycles(&cfg, m + 32, n, k).cycles >= c
                && matmul_cycles(&cfg, m, n + 32, k).cycles >= c
                && matmul_cycles(&cfg, m, n, k + 32).cycles >= c
        },
    );
}

#[test]
fn policy_ladder_is_monotone_for_random_arch_scales() {
    // Shrinking/growing the model must preserve baseline >= AC >= AD >= opt.
    check_no_shrink(
        "ladder-monotone",
        |rng| {
            let mult = match gen_usize(rng, 0, 2) {
                0 => vec![1, 2, 4, 4],
                1 => vec![1, 2, 4],
                _ => vec![1, 1, 2, 2],
            };
            let tf: Vec<usize> = mult.iter().map(|_| gen_usize(rng, 0, 2)).collect();
            UNetArch {
                name: "rand",
                latent: 16 << gen_usize(rng, 0, 2),
                latent_c: 4,
                model_channels: 32 << gen_usize(rng, 0, 3),
                mult,
                tf_depth: tf,
                ctx_len: 77,
                ctx_dim: 768,
                temb_dim: 1280,
                geglu: true,
            }
        },
        |arch| {
            let cfg = AccelConfig::default();
            let ops = unet_ops(arch);
            let t = |p: Policy| simulate(&cfg, p, &ops).total_cycles();
            let (b, ac, ad, opt) = (
                t(Policy::baseline()),
                t(Policy::with_ac()),
                t(Policy::with_ac_ad()),
                t(Policy::optimized()),
            );
            b + 1e-6 >= ac && ac + 1e-6 >= ad && ad + 1e-6 >= opt
        },
    );
}

#[test]
fn simulate_scales_linearly_with_duplicated_ops() {
    let cfg = AccelConfig::default();
    check_no_shrink(
        "simulate-linear",
        |rng| gen_usize(rng, 1, 5),
        |&n| {
            let op = LayerOp {
                name: "m".into(),
                block: crate::models::inventory::Block::Mid,
                kind: OpKind::Matmul { m: 256, n: 256, k: 256 },
            };
            let ops: Vec<LayerOp> = (0..n).map(|_| op.clone()).collect();
            let one = simulate(&cfg, Policy::optimized(), std::slice::from_ref(&op));
            let many = simulate(&cfg, Policy::optimized(), &ops);
            (many.sa_cycles - n as f64 * one.sa_cycles).abs() < 1e-6
        },
    );
}

#[test]
fn bigger_buffer_never_increases_traffic() {
    let ops = unet_ops(&crate::models::inventory::sd_v14());
    check_no_shrink(
        "gb-monotone",
        |rng| gen_usize(rng, 8, 12), // 256KB..4MB as powers of two
        |&pow| {
            let mut small = AccelConfig::default();
            small.gb_bytes = 1 << (pow + 10);
            let mut big = small.clone();
            big.gb_bytes = 2 << (pow + 10);
            let ts = simulate(&small, Policy::optimized(), &ops).traffic_bytes;
            let tb = simulate(&big, Policy::optimized(), &ops).traffic_bytes;
            tb <= ts * 1.0001
        },
    );
}
