//! Nonlinear-operator timing: store-then-compute vs 2-stage streaming.
//!
//! Baseline (store-then-compute): softmax/layernorm make multiple passes
//! over buffered data on the 32-lane VPU with non-pipelined EXP/DIV/SQRT
//! units, fully serialised with the systolic array (inefficiencies (i) and
//! (ii), Sec. IV-C). Per-element cycle constants are calibrated so the
//! isolated-layer ablation reproduces Fig. 15's reductions (39/24/14 % on
//! self-attention, 25/14/8 % on FFN).
//!
//! 2-stage streaming: NCA rides the pre-matmul write stream, Norm rides
//! the post-matmul read stream (Fig. 11); the only visible latency is one
//! tile + pipeline depth per operator instance.

use super::arch::{AccelConfig, NonlinearMode};
use crate::models::inventory::OpKind;

/// Baseline softmax: 3 passes (max, exp-accumulate, divide) with
/// multi-cycle EXP and DIV — total cycles per element across passes.
pub const SOFTMAX_CYC_PER_ELEM: f64 = 12.6;
/// Baseline layernorm/groupnorm: 3 passes (sum, sq-sum/var, normalise).
pub const NORM_CYC_PER_ELEM: f64 = 9.0;
/// Baseline GELU/SiLU: one pass, non-pipelined EXP + DIV.
pub const GELU_CYC_PER_ELEM: f64 = 8.0;
/// Residual adds / concats: one pass, single-cycle ALU.
pub const ELEMWISE_CYC_PER_ELEM: f64 = 1.0;
/// Streaming mode: visible latency per operator instance (one FIFO tile
/// + datapath pipeline depth, Fig. 12).
pub const STREAM_VISIBLE_CYCLES: f64 = 96.0;

/// Visible (SA-blocking) cycles of a nonlinear operator.
pub fn nonlinear_visible_cycles(cfg: &AccelConfig, mode: NonlinearMode, kind: &OpKind) -> f64 {
    let lanes = cfg.vpu_lanes as f64;
    let baseline = |elems: f64, cyc: f64| elems * cyc / lanes;
    match mode {
        NonlinearMode::StoreThenCompute => match *kind {
            OpKind::Softmax { rows, cols } => baseline((rows * cols) as f64, SOFTMAX_CYC_PER_ELEM),
            OpKind::Layernorm { rows, cols } | OpKind::Groupnorm { rows, cols } => {
                baseline((rows * cols) as f64, NORM_CYC_PER_ELEM)
            }
            OpKind::Gelu { n } | OpKind::Silu { n } => baseline(n as f64, GELU_CYC_PER_ELEM),
            OpKind::Elementwise { n } => baseline(n as f64, ELEMWISE_CYC_PER_ELEM),
            _ => 0.0,
        },
        NonlinearMode::Streaming2Stage => match kind {
            OpKind::Softmax { .. }
            | OpKind::Layernorm { .. }
            | OpKind::Groupnorm { .. }
            | OpKind::Gelu { .. }
            | OpKind::Silu { .. }
            | OpKind::Elementwise { .. } => STREAM_VISIBLE_CYCLES,
            _ => 0.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::dataflow::matmul_cycles;

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    /// Fig. 15 (left): isolated self-attention layers of SD v1.4 —
    /// 2-stage streaming cuts ~39/24/14 % at seq 4096/1024/256.
    #[test]
    fn fig15_self_attention_bands() {
        let cases = [(4096usize, 320usize, 0.39f64), (1024, 640, 0.24), (256, 1280, 0.14)];
        for (seq, c, expect) in cases {
            let mm = matmul_cycles(&cfg(), seq, seq, c).cycles
                + matmul_cycles(&cfg(), seq, c, seq).cycles;
            let sm_base = nonlinear_visible_cycles(
                &cfg(),
                NonlinearMode::StoreThenCompute,
                &OpKind::Softmax { rows: seq, cols: seq },
            );
            let sm_stream = nonlinear_visible_cycles(
                &cfg(),
                NonlinearMode::Streaming2Stage,
                &OpKind::Softmax { rows: seq, cols: seq },
            );
            let red = 1.0 - (mm + sm_stream) / (mm + sm_base);
            assert!(
                (red - expect).abs() < 0.05,
                "seq {seq}: reduction {red:.3} vs paper {expect}"
            );
        }
    }

    /// Fig. 15 (right): FFN layers — ~25/14/8 % reduction.
    #[test]
    fn fig15_ffn_bands() {
        let cases = [(4096usize, 320usize, 0.25f64), (1024, 640, 0.14), (256, 1280, 0.08)];
        for (seq, c, expect) in cases {
            let inner = 4 * c;
            // GEGLU first projection is 2x inner.
            let mm = matmul_cycles(&cfg(), seq, 2 * inner, c).cycles
                + matmul_cycles(&cfg(), seq, c, inner).cycles;
            let base = nonlinear_visible_cycles(
                &cfg(),
                NonlinearMode::StoreThenCompute,
                &OpKind::Layernorm { rows: seq, cols: c },
            ) + nonlinear_visible_cycles(
                &cfg(),
                NonlinearMode::StoreThenCompute,
                &OpKind::Gelu { n: seq * inner },
            );
            let stream = 2.0 * STREAM_VISIBLE_CYCLES;
            let red = 1.0 - (mm + stream) / (mm + base);
            assert!(
                (red - expect).abs() < 0.06,
                "ffn seq {seq}: reduction {red:.3} vs paper {expect}"
            );
        }
    }

    #[test]
    fn streaming_visible_latency_is_negligible() {
        let v = nonlinear_visible_cycles(
            &cfg(),
            NonlinearMode::Streaming2Stage,
            &OpKind::Softmax { rows: 4096, cols: 4096 },
        );
        let b = nonlinear_visible_cycles(
            &cfg(),
            NonlinearMode::StoreThenCompute,
            &OpKind::Softmax { rows: 4096, cols: 4096 },
        );
        assert!(v < 1e-3 * b);
    }

    #[test]
    fn linear_ops_cost_nothing_here() {
        for mode in [NonlinearMode::StoreThenCompute, NonlinearMode::Streaming2Stage] {
            let v = nonlinear_visible_cycles(
                &cfg(),
                mode,
                &OpKind::Matmul { m: 64, n: 64, k: 64 },
            );
            assert_eq!(v, 0.0);
        }
    }
}
