//! # SD-Acc — full-system reproduction
//!
//! Rust coordinator (Layer 3) for the SD-Acc paper: phase-aware sampling
//! for Stable Diffusion plus a cycle-accurate model of the paper's
//! accelerator (address-centric dataflow, 2-stage streaming computing,
//! adaptive reuse & fusion).
//!
//! The compute path (Layer 2 JAX U-Net built on Layer 1 Pallas kernels) is
//! AOT-lowered to HLO text by `python/compile/aot.py` and executed here
//! through the PJRT CPU client (`runtime` module). Python never runs on
//! the request path.

pub mod coordinator;
pub mod hwsim;
pub mod models;
pub mod pas;
pub mod quality;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod testing;
pub mod util;
