//! # SD-Acc — full-system reproduction
//!
//! Rust coordinator (Layer 3) for the SD-Acc paper: phase-aware sampling
//! for Stable Diffusion plus a cycle-accurate model of the paper's
//! accelerator (address-centric dataflow, 2-stage streaming computing,
//! adaptive reuse & fusion).
//!
//! The compute path (Layer 2 JAX U-Net built on Layer 1 Pallas kernels) is
//! AOT-lowered to HLO text by `python/compile/aot.py` and executed here
//! through the PJRT CPU client (`runtime` module). Python never runs on
//! the request path.
//!
//! ## Pluggable execution backends ([`runtime::backend`], [`runtime::sim`])
//!
//! The runtime layer is a seam, not a single executor: the object-safe
//! `ExecBackend` trait (`manifest` / `execute(name, inputs)` /
//! `preload`) is the artifact contract, `Runtime` (PJRT/xla over AOT
//! HLO artifacts) and `SimBackend` (deterministic pure Rust, zero
//! artifacts required) are its two implementations, and both live on
//! the `RuntimeService` owner thread — the xla wrappers are `!Send`, so
//! Send-safety stays a property of the service, never of the backend.
//! **Resolution order** for `BackendKind`: explicit `--backend` flag >
//! `SD_ACC_BACKEND` env > `Auto` (xla when `artifacts/manifest.json`
//! exists, sim otherwise). **Determinism rule:** a sim execution is a
//! pure function of (artifact name, input bytes) — PCG32 texture seeded
//! from FNV-1a input digests, per-element scalar kernels, no global
//! state — so repeated `Client::generate` runs are bit-identical,
//! lockstep batch lanes equal their solo runs bit for bit, and the
//! request cache's replay guarantee holds on both backends. The sim's
//! U-Net stand-in routes a slowly-drifting "deep" term through the
//! feature-cache tensors (fresh cache ⇒ partial ≡ full exactly; stale
//! cache ⇒ small monotone error), so phase-aware-sampling behaviour is
//! meaningfully exercised without artifacts. Shape errors route through
//! one shared `check_inputs`, so both backends report byte-identical
//! wording. **Cache rule:** every key derivation — all four namespaces,
//! since calibration/plan/quant data measure the executor's numerics —
//! hashes a backend-salted manifest digest (`backend_salted_hash`: xla
//! keys are byte-identical to the pre-seam derivation, no
//! `CACHE_VERSION` bump; sim keys are disjoint), while the flush rule
//! stays on the raw digest so both backends can share one store. Sim
//! and xla results can never satisfy each other's lookups, and the sim
//! backend never writes `calibration.json` into the artifacts dir. The
//! payoff: every integration suite
//! and runtime-backed bench section *executes* in artifact-less
//! containers (`ci.sh` exports `SD_ACC_BACKEND=sim`) instead of
//! skipping.
//!
//! ## Zero-copy hot path ([`runtime`], [`scheduler`], [`coordinator`])
//!
//! The denoising loop carries no redundant host-side copies:
//! `runtime::Tensor` storage is a shared `Arc<[f32]>` (clones bump a
//! refcount, mutation is copy-on-write via `Tensor::make_mut` — see the
//! cost model in `runtime::tensor`), loop-invariant inputs cross
//! the runtime-thread boundary as `Input::F32Ref` Arc shares, and
//! samplers expose an in-place `Sampler::step_mut` that reuses one
//! latent buffer for all N steps — bit-identical to the allocating
//! `step` reference path (both call the same scalar kernels; determinism
//! tests compare the trajectories bit for bit). The runtime thread drops
//! its input handles before responding so the per-step `make_mut` never
//! copies. PAS plan search fans candidate validation out over the
//! `util::threadpool` and lane-batches validation prompts whose plans
//! coincide through `Coordinator::generate_many`, returning the same
//! candidate set as the serial path.
//!
//! ## Persistent cache ([`cache`])
//!
//! Expensive one-time work is memoized in a versioned, content-addressed
//! on-disk store with four namespaces: calibration reports
//! (Fig. 4 / Eq. 1-2), searched sampling-plan fronts (Fig. 7), quant
//! profiles, and request-level generation results. Keys are structured
//! FNV-1a hashes over the AOT manifest digest plus the defining fields
//! (`(prompt, seed, steps, sampler, guidance, plan, quant)` for
//! requests), so a manifest rebuild flushes every namespace rather than
//! serving stale latents. Request latents are stored in a
//! length-delimited little-endian binary framing (`cache::binary`) at
//! ≤ 40% of the former JSON float text, bit-exact for NaN/±inf/-0.0;
//! the small structured namespaces stay JSON. The store survives
//! process restarts, enforces an LRU byte cap, recovers from corrupt/
//! truncated indexes by rescanning its payload files, and flushes clean
//! on a `CACHE_VERSION` skew instead of misreading old encodings.
//! Consumers: `pas::calibrate`/`pas::search` (warm starts become
//! lookups), the serving layer (request cache consulted before
//! enqueueing, hit/miss/eviction counters plus batch-occupancy
//! histogram and queue-depth gauge in `server::metrics`), the
//! coordinator (`SamplingPlan::Auto` resolution), and the `sd-acc cache`
//! CLI (`stats`/`gc`/`clear`).
//!
//! ## Session-oriented job API ([`server`], [`coordinator`])
//!
//! The serving surface is typed end to end. Requests validate at
//! construction (`GenRequest::builder`: steps >= 1, finite guidance,
//! executable plan), the sampler is the `SamplerKind` enum whose
//! `as_str` bytes are exactly what the retired `String` field fed the
//! request-cache hasher (digest-stable migration — property-tested; the
//! rule: changing a variant's canonical bytes requires a `CACHE_VERSION`
//! bump), and errors cross the boundary as the structured
//! `coordinator::SdError` (`InvalidRequest` / `QueueFull` / `Cancelled`
//! / `DeadlineExceeded` / `Runtime`) while internals keep `anyhow`.
//! `Client::submit` returns a `JobHandle { id, events, cancel }`
//! streaming the job lifecycle — `Queued`, `CacheHit`, `Scheduled`,
//! one `Step { i, action, ms }` per denoising step (meaningful under
//! phase-aware sampling: full and partial steps cost very differently),
//! and exactly one terminal `Done`/`Failed`/`Cancelled`. Scheduling is
//! priority- and deadline-aware: earliest-deadline-first within a batch
//! key, cross-key dispatch by priority with one-rank-per-`max_wait`
//! aging (no starvation), bounded admission (`max_queue` ->
//! `QueueFull`), and cooperative cancellation honoured in the batcher,
//! at worker dequeue, and once per denoising step via the coordinator's
//! `StepObserver` — so a fired `CancelToken` stops a 50-step run
//! mid-flight. The blocking `Client::generate` survives unchanged,
//! re-expressed over the job API; `bench_serving` holds the event
//! channel to < 5% p50 overhead over the blocking loop.
//!
//! ## Observability ([`obs`])
//!
//! The measurement layer: a lock-light `TraceSink` records structured
//! span events (job id, phase, step index, PAS action, cache namespace
//! + hit/miss, backend kind, bytes, duration) into a bounded ring and
//! an optional JSONL file, with the `JobId` threaded from `server::api`
//! through the batcher, coordinator denoising loop, cache facade and
//! runtime service via a thread-local `TraceScope` — so every cache
//! lookup and backend `execute` is attributable to the job that caused
//! it. Process-global labeled counters (`obs::counters`) split cache
//! traffic per namespace, executes/bytes per backend and steps per PAS
//! action; a counting global allocator (`obs::alloc`, feature
//! `count-alloc`, armed at runtime) makes the zero-copy invariants
//! regression-visible as allocations per step. `Metrics` latency
//! percentiles now come from a bounded deterministic reservoir
//! (`obs::reservoir`); the consistent lifecycle snapshot is
//! `TraceSink::lifecycle_counts`.
//!
//! On top of the raw span stream sit three read-only analytics
//! surfaces: `obs::analyze` reconstructs per-job timelines and
//! decomposes end-to-end latency into phases (queue, batch formation,
//! full vs PAS-partial steps, cache, decode — per-job sums are
//! guaranteed `<=` the measured e2e) plus batch critical paths;
//! `obs::slo` provides log-bucketed histograms with a documented
//! relative-error bound, sliding-window p50/p95/p99 (wired into
//! `server::Metrics` alongside the all-time reservoir) and the
//! per-priority results ledger (goodput, deadline-miss rate,
//! cancel-ack latency, rejects); `obs::export` writes Chrome
//! trace-event / Perfetto JSON. Surfaces: `sd-acc generate --trace`,
//! `serve --trace-out`/`--json`/`--monitor <secs>`, `cache stats
//! --json`, the `sd-acc trace` report subcommand (`--analyze`,
//! `--export-chrome`, `--strict`), and `bench_obs` (emits
//! `BENCH_obs.json` via `ci.sh --bench-commit`, including windowed
//! percentiles and the phase decomposition). JSONL span lines are
//! versioned by `obs::TRACE_SCHEMA_VERSION`.
//!
//! ## Resilience & chaos ([`runtime::faults`], [`server::resilience`], [`server::loadgen`])
//!
//! The failure-hardening layer has three deterministic pieces. The
//! **chaos engine** (`runtime::faults`) attaches a `FaultSpec` schedule
//! of transient execute errors, latency spikes and error bursts to the
//! sim backend — armed only via `RuntimeService::start_with_faults`,
//! the `SD_ACC_FAULTS` env var, or `sd-acc serve --chaos`; the xla path
//! never consults it. Every injection decision is a pure function of
//! (seed, artifact name, per-artifact call index), so a chaos run is
//! bit-replayable; injected errors carry `runtime::TRANSIENT_MARKER`,
//! the substring `SdError::is_retryable` classifies on, while shape and
//! arity contract errors surface before injection and never look
//! transient. The **resilience policy** (`server::resilience`,
//! `ServerConfig::resilience`, default-inert) layers bounded retry with
//! exponential backoff (failed lanes re-enter the batcher solo —
//! keyed apart so a poisoned batch mate cannot recontaminate fresh
//! work — with deadlines still binding and exactly one terminal per
//! job arbitrated by a shared claim flag), hedged re-dispatch of
//! straggling groups (the twin is event-silent and cache-write-barred
//! unless it wins the claim), EWMA load shedding of Low-priority
//! admissions, and hysteretic brownout that rewrites degradable
//! admissions to a cheaper PAS/quant form *before* plan resolution and
//! cache lookup — so a degraded result lives under the degraded
//! request's own cache key and is never stored or served under the
//! full-quality key (standing invariant). The **load engine**
//! (`server::loadgen`, `sd-acc serve --load`) drives closed-loop,
//! Poisson or bursty arrival processes with a seeded
//! prompt/steps/priority/quant mix — deterministic request sequences
//! from per-index RNG streams — and reports goodput and terminal
//! accounting. `tests/integration_chaos.rs` pins replayability, the
//! one-terminal invariant under a transient-failure wave, ≥95% retry
//! recovery, lane isolation (healthy lanes bit-identical to uninjected
//! runs), shed/brownout hysteresis and the cache-key rule;
//! `bench_chaos` emits `BENCH_chaos.json` via `ci.sh --bench-commit`.
//!
//! ## Approximation policies ([`policy`])
//!
//! The *how do we approximate* decision is a pluggable seam: requests
//! carry a [`policy::PolicySpec`] (default `Pas`) that the coordinator
//! builds into an object-safe [`policy::ApproxPolicy`] with a
//! plan-time hook (per-step action schedule) and an optional step-time
//! hook (online overrides from EWMA latent-trajectory deltas —
//! computed only when the policy asks, so the default path stays
//! allocation-identical). Four strategies ship behind it: `pas` (the
//! calibrated phase-aware plan, bit-identical to the pre-seam path),
//! `block-cache:<budget>` (per-block staleness budgets on the feature
//! caches), `stability[:<milli>]` (SADA-style online skip decisions —
//! no calibrate cold-start), and `text-precision` (per-prompt
//! `QuantScheme` from prompt-class sensitivity). The policy's stable
//! `policy_id()` enters the batch key and every request-cache key
//! (`CACHE_VERSION` 4), step spans label non-default policies as
//! `<policy_id>:<action>`, brownout degrades by swapping the default
//! policy for the cheaper `stability` form under its own key, and
//! `loadgen` can draw a per-request policy mix (`mix=` clause).
//! Surfaces: `generate/serve/request --policy`, `sd-acc policy
//! list|describe`, `bench_policy` (MAC-reduction >= PAS at the quality
//! band, `BENCH_policy.json` via `ci.sh --bench-commit`).
//!
//! ## Mixed precision ([`quant`])
//!
//! The paper's third workload problem — diverse weight and activation
//! sizes — is handled by a mixed-precision subsystem: per-layer
//! int4/int8/fp16/fp32 assignment with a quality-aware Pareto search,
//! activation-range calibration cached under the `quant` namespace,
//! precision-scaled hwsim costing (cycles, DRAM traffic and SA energy
//! all track operand widths), fake-quant emulation on the serving path
//! (requests carry an optional `QuantScheme` that participates in
//! batching and cache keys), and a `sd-acc quant` CLI subcommand.
//!
//! ## Wire transport ([`net`])
//!
//! `sd-acc serve --listen <addr>` exposes the job API over hand-rolled
//! HTTP/1.1 — `std::net::TcpListener` + the crate's own thread pool,
//! zero new dependencies. Routes:
//!
//! | method + path              | behaviour                                    |
//! |----------------------------|----------------------------------------------|
//! | `POST /v1/jobs`            | submit (JSON body) -> `202 {"job": "<id>"}`  |
//! | `GET /v1/jobs/<id>/events` | SSE job-event stream (chunked transfer)      |
//! | `DELETE /v1/jobs/<id>`     | fire the job's cancel token                  |
//! | `GET /healthz`             | liveness                                     |
//! | `GET /metrics`             | metrics JSON (+ autoscale advice, wire gauge)|
//! | `POST /admin/shutdown`     | graceful drain                               |
//!
//! Each [`JobEvent`](server::JobEvent) becomes one SSE frame
//! `event: <label>\ndata: <json>\n\n` — the same label vocabulary, the
//! same order and the same exactly-one-terminal guarantee as the
//! in-process `JobHandle` stream (the `done` frame carries a result
//! summary + FNV-1a latent checksum rather than the latent itself).
//! Structured errors map deterministically: `InvalidRequest` 400,
//! `QueueFull` 429, `Cancelled` 499, `DeadlineExceeded` 504, `Runtime`
//! 500; oversized headers/bodies are bounded at the parser (431/413).
//! A client that disconnects mid-stream cancels its job — no orphaned
//! work, no leaked registry entry.
//!
//! N serve processes may share one `--cache` directory: the store
//! serializes every index load-merge-write under an advisory
//! `index.lock` file (stale locks broken, lock-free degradation after
//! a bounded wait), commits are merge-on-write (disk-only entries are
//! adopted only when their payload file exists), and misses re-read
//! the index before being declared — so a second process's identical
//! request is a cross-process `cache-hit`. A per-process in-memory
//! LRU tier in front of the disk store makes repeated hits cheap. See
//! `cache::store`'s "Multi-process sharing" docs for the protocol.
//!
//! Quickstart:
//!
//! ```text
//! sd-acc serve --listen 127.0.0.1:8460 --cache /tmp/sd-cache &
//! sd-acc request --addr 127.0.0.1:8460 --prompt "a red fox" --seed 7 --steps 8
//! curl -N http://127.0.0.1:8460/v1/jobs/<id>/events   # raw SSE
//! sd-acc request --addr 127.0.0.1:8460 --shutdown
//! ```

pub mod cache;
pub mod coordinator;
pub mod hwsim;
pub mod models;
pub mod net;
pub mod obs;
pub mod pas;
pub mod policy;
pub mod quality;
pub mod quant;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod testing;
pub mod util;

/// Counting allocator registration (see [`obs::alloc`]). Compiled in
/// under the default `count-alloc` feature; counting itself stays a
/// single relaxed-atomic check per allocation until armed at runtime
/// (`SD_ACC_COUNT_ALLOC=1` or `obs::alloc::enable`).
#[cfg(feature = "count-alloc")]
#[global_allocator]
static GLOBAL_ALLOCATOR: obs::alloc::CountingAlloc = obs::alloc::CountingAlloc;
