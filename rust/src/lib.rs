//! # SD-Acc — full-system reproduction
//!
//! Rust coordinator (Layer 3) for the SD-Acc paper: phase-aware sampling
//! for Stable Diffusion plus a cycle-accurate model of the paper's
//! accelerator (address-centric dataflow, 2-stage streaming computing,
//! adaptive reuse & fusion).
//!
//! The compute path (Layer 2 JAX U-Net built on Layer 1 Pallas kernels) is
//! AOT-lowered to HLO text by `python/compile/aot.py` and executed here
//! through the PJRT CPU client (`runtime` module). Python never runs on
//! the request path.
//!
//! ## Persistent cache ([`cache`])
//!
//! Expensive one-time work is memoized in a versioned, content-addressed
//! on-disk store with three namespaces: calibration reports
//! (Fig. 4 / Eq. 1-2), searched sampling-plan fronts (Fig. 7), and
//! request-level generation results. Keys are structured FNV-1a hashes
//! over the AOT manifest digest plus the defining fields
//! (`(prompt, seed, steps, sampler, guidance, plan)` for requests), so a
//! manifest rebuild flushes every namespace rather than serving stale
//! latents. The store survives process restarts, enforces an LRU byte
//! cap, and recovers from corrupt/truncated indexes by rescanning its
//! payload files. Consumers: `pas::calibrate`/`pas::search` (warm starts
//! become lookups), the serving layer (request cache consulted before
//! enqueueing, hit/miss/eviction counters in `server::metrics`), the
//! coordinator (`SamplingPlan::Auto` resolution), and the `sd-acc cache`
//! CLI (`stats`/`gc`/`clear`).
//!
//! ## Mixed precision ([`quant`])
//!
//! The paper's third workload problem — diverse weight and activation
//! sizes — is handled by a mixed-precision subsystem: per-layer
//! int4/int8/fp16/fp32 assignment with a quality-aware Pareto search,
//! activation-range calibration cached under the `quant` namespace,
//! precision-scaled hwsim costing (cycles, DRAM traffic and SA energy
//! all track operand widths), fake-quant emulation on the serving path
//! (requests carry an optional `QuantScheme` that participates in
//! batching and cache keys), and a `sd-acc quant` CLI subcommand.

pub mod cache;
pub mod coordinator;
pub mod hwsim;
pub mod models;
pub mod pas;
pub mod quality;
pub mod quant;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod testing;
pub mod util;
