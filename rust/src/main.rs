//! `sd-acc` — leader entrypoint / CLI for the SD-Acc coordinator.
//!
//! Subcommands:
//!   generate   text-to-image via the PJRT runtime (original or PAS)
//!   serve      drive a synthetic workload through the job-API server,
//!              or expose it over HTTP/1.1 + SSE with --listen
//!   request    submit/stream/cancel a job against a --listen server
//!   calibrate  measure shift scores, D*, outliers (Fig. 4 / Eq. 1-2)
//!   simulate   run the accelerator performance model on a real SD arch
//!   quant      mixed precision: calibrate | search | report
//!   policy     approximation-policy registry (list | describe)
//!   cache      persistent cache maintenance (stats | gc | clear)
//!   trace      summarise a span trace (JSONL) written by generate/serve
//!   info       artifact + manifest summary
//!
//! All compute goes through AOT artifacts; python never runs here.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use sd_acc::cache::{default_cache_dir, Cache, Store, StoreConfig, NS_REQUEST};
use sd_acc::coordinator::{Coordinator, GenRequest, StepObserver};
use sd_acc::pas::plan::StepAction;
use sd_acc::hwsim::arch::{AccelConfig, Policy};
use sd_acc::hwsim::engine::{simulate_unet_step, simulate_unet_step_quant};
use sd_acc::models::inventory::{arch_by_name, total_macs, unet_ops};
use sd_acc::obs::{self, Phase, SpanEvent, TraceScope, TraceSink};
use sd_acc::obs::trace::DEFAULT_RING_CAP;
use sd_acc::pas::calibrate::Calibrator;
use sd_acc::pas::plan::{PasConfig, SamplingPlan};
use sd_acc::quality;
use sd_acc::quant::{
    assign, predicted_psnr_db, search, synthetic_profile, QuantCalibrator, QuantConstraints,
    QuantScheme,
};
use sd_acc::runtime::{default_artifacts_dir, BackendKind, FaultSpec, RuntimeService};
use sd_acc::util::cli::{usage, Args, OptSpec};
use sd_acc::util::table::{f, ratio, Table};

fn main() -> ExitCode {
    // Arm the counting allocator when SD_ACC_COUNT_ALLOC=1 (no-op
    // otherwise; counters stay a single relaxed load per allocation).
    sd_acc::obs::alloc::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return ExitCode::from(2);
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "generate" => cmd_generate(rest),
        "serve" => cmd_serve(rest),
        "request" => cmd_request(rest),
        "calibrate" => cmd_calibrate(rest),
        "simulate" => cmd_simulate(rest),
        "quant" => cmd_quant(rest),
        "policy" => cmd_policy(rest),
        "cache" => cmd_cache(rest),
        "trace" => cmd_trace(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "sd-acc {} — SD-Acc reproduction (phase-aware sampling + HW co-design)\n\n\
         usage: sd-acc <generate|serve|request|calibrate|simulate|quant|policy|cache|trace|info> [options]\n\
         run a subcommand with --help for its options",
        sd_acc::util::VERSION
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts_dir)
}

fn need_artifacts(dir: &Path) -> Result<(), String> {
    if dir.join("manifest.json").exists() {
        Ok(())
    } else {
        Err(format!("no artifacts at {} — run `make artifacts`", dir.display()))
    }
}

/// Resolve the execution backend (`--backend` flag > `SD_ACC_BACKEND`
/// env > auto-detect on the artifacts dir) and start the runtime
/// service + coordinator over it — THE construction path for every
/// runtime-backed subcommand. Xla still requires artifacts (same clean
/// error as before); `--backend sim` runs without any.
fn start_runtime(args: &Args) -> Result<(RuntimeService, Coordinator), String> {
    let dir = artifacts_dir(args);
    let kind = BackendKind::resolve(args.get("backend"))
        .map_err(|e| format!("{e:#}"))?
        .for_dir(&dir);
    if kind == BackendKind::Xla {
        need_artifacts(&dir)?;
    } else {
        println!("backend: sim (deterministic pure-Rust executor — no artifacts needed)");
    }
    // `--chaos <spec>` arms deterministic fault injection (subcommands
    // that don't declare the flag simply never see it here); without
    // the flag, `start_with` still consults SD_ACC_FAULTS. Injection is
    // sim-only — start_with_faults rejects it on xla.
    let svc = match args.get("chaos") {
        Some(spec) => {
            let spec = FaultSpec::parse(spec).map_err(|e| format!("--chaos: {e:#}"))?;
            println!("chaos: deterministic fault injection armed");
            RuntimeService::start_with_faults(kind, &dir, Some(spec))
        }
        None => RuntimeService::start_with(kind, &dir),
    }
    .map_err(|e| format!("{e:#}"))?;
    let coord = Coordinator::new(svc.handle());
    Ok((svc, coord))
}

/// The shared `--backend` option row.
fn backend_opt() -> OptSpec {
    OptSpec {
        name: "backend",
        help: "execution backend: auto | xla | sim (also SD_ACC_BACKEND)",
        takes_value: true,
        default: None,
    }
}

/// Open the persistent cache when `--cache-dir` is given. Keys are
/// bound to the coordinator's manifest digest *and* backend kind, so
/// sim latents never satisfy xla lookups.
fn open_cache(args: &Args, coord: &Coordinator) -> Result<Option<Cache>, String> {
    match args.get("cache-dir") {
        Some(d) => coord
            .open_cache(StoreConfig::new(d))
            .map(Some)
            .map_err(|e| format!("{e:#}")),
        None => Ok(None),
    }
}

/// The fixed closed-vocabulary calibration prompt set (first `n` of 3),
/// shared by `calibrate`, `quant calibrate` and `quant search` so they
/// address the same cache cells.
fn calib_prompts(n: usize) -> Vec<String> {
    ["red circle x4 y4 blue square x11 y11", "green stripe x8 y8", "yellow circle x12 y3"]
        .iter()
        .take(n.clamp(1, 3))
        .map(|s| s.to_string())
        .collect()
}

/// Quant-profile acquisition shared by the `quant calibrate|search` arms:
/// measured trajectories (cache-aware) over whichever execution backend
/// resolves — xla over real artifacts, or the deterministic sim backend
/// when none exist — and synthetic deterministic ranges for the
/// non-runnable architectures. The service/coordinator pair is returned
/// so callers can run measured validation (the service owns the runtime
/// thread and must stay alive while the coordinator is used).
#[allow(clippy::type_complexity)]
fn acquire_quant_profile(
    args: &Args,
    arch: &sd_acc::models::inventory::UNetArch,
    steps: usize,
) -> Result<(sd_acc::quant::QuantProfile, Option<(RuntimeService, Coordinator)>), String> {
    let dir = artifacts_dir(args);
    // Measured ranges come from the runnable model only — applying the
    // sd-tiny runtime's block ranges to another architecture would gate
    // quality on cross-model tails (prefix-matched up-blocks, defaulted
    // everything else).
    if arch.name != "sd-tiny" {
        if dir.join("manifest.json").exists() {
            println!(
                "model {} is not the runnable artifact model — synthetic profile \
                 (use --model sd-tiny for measured ranges)",
                arch.name
            );
        }
        return Ok((synthetic_profile(arch, steps), None));
    }
    let (svc, coord) = start_runtime(args)?;
    let cache = open_cache(args, &coord)?;
    let prompts = calib_prompts(args.get_usize("prompts")?.unwrap_or(2));
    let calibrator = QuantCalibrator::new(&coord);
    let profile = match &cache {
        Some(c) => {
            let (p, hit) = calibrator
                .run_cached(c, &prompts, steps, 7.5)
                .map_err(|e| format!("{e:#}"))?;
            if hit {
                println!("quant cache hit — trajectories skipped");
            }
            p
        }
        None => calibrator.run(&prompts, steps, 7.5).map_err(|e| format!("{e:#}"))?,
    };
    Ok((profile, Some((svc, coord))))
}

fn parse_policy(name: &str) -> Result<Policy, String> {
    match name {
        "baseline" => Ok(Policy::baseline()),
        "ac" => Ok(Policy::with_ac()),
        "ad" => Ok(Policy::with_ac_ad()),
        "optimized" => Ok(Policy::optimized()),
        p => Err(format!("unknown policy '{p}'")),
    }
}

/// Parse an approximation-policy label (the `crate::policy` registry,
/// distinct from the hwsim dataflow [`Policy`] above).
fn parse_approx_policy(name: &str) -> Result<sd_acc::policy::PolicySpec, String> {
    sd_acc::policy::PolicySpec::parse(name).ok_or_else(|| {
        format!("unknown approximation policy '{name}' (see `sd-acc policy list`)")
    })
}

fn fmt_bytes(b: u64) -> String {
    if b < 1024 {
        format!("{b} B")
    } else if b < 1024 * 1024 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else {
        format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0))
    }
}

// ----------------------------------------------------------------- generate

fn cmd_generate(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "prompt", help: "text prompt (closed vocabulary)", takes_value: true, default: Some("red circle x4 y4 blue square x11 y11") },
        OptSpec { name: "seed", help: "generation seed", takes_value: true, default: Some("42") },
        OptSpec { name: "steps", help: "denoising steps", takes_value: true, default: Some("30") },
        OptSpec { name: "sampler", help: "ddim | pndm", takes_value: true, default: Some("pndm") },
        OptSpec { name: "pas", help: "enable phase-aware sampling", takes_value: false, default: None },
        OptSpec { name: "t-sparse", help: "PAS sparse period", takes_value: true, default: Some("4") },
        OptSpec { name: "out", help: "output PPM path", takes_value: true, default: Some("out.ppm") },
        OptSpec { name: "artifacts", help: "artifacts dir", takes_value: true, default: None },
        backend_opt(),
        OptSpec { name: "cache-dir", help: "persistent cache dir (enables the request cache)", takes_value: true, default: None },
        OptSpec { name: "auto", help: "resolve the best cached PAS plan (SamplingPlan::Auto)", takes_value: false, default: None },
        OptSpec { name: "quant", help: "mixed-precision scheme (fp16 | w8a8 | w4a8 | ...)", takes_value: true, default: None },
        OptSpec { name: "policy", help: "approximation policy (see `sd-acc policy list`)", takes_value: true, default: None },
        OptSpec { name: "progress", help: "stream per-step progress while generating", takes_value: false, default: None },
        OptSpec { name: "trace", help: "record a span trace of this run (JSONL)", takes_value: false, default: None },
        OptSpec { name: "trace-out", help: "span trace path (implies --trace)", takes_value: true, default: Some("trace.jsonl") },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!("{}", usage("sd-acc generate", "text-to-image generation", &spec));
        return Ok(());
    }
    let (_svc, coord) = start_runtime(&args)?;
    let m = coord.runtime().manifest().model.clone();
    let cache = open_cache(&args, &coord)?;
    // `--trace` records every stage of this single run (job id 0): the
    // lifecycle spans below, plus — through the scope — the cache
    // lookups, denoising steps and backend executes they cause.
    let trace = if args.flag("trace") || raw.iter().any(|a| a == "--trace-out") {
        let path = PathBuf::from(args.get("trace-out").unwrap());
        Some((TraceSink::with_file(DEFAULT_RING_CAP, &path).map_err(|e| format!("{e:#}"))?, path))
    } else {
        None
    };
    let _scope = trace
        .as_ref()
        .map(|(sink, _)| TraceScope::enter(std::sync::Arc::clone(sink), 0));
    if let Some((sink, _)) = &trace {
        sink.record(SpanEvent::new(0, Phase::Queued));
    }

    let steps = args.get_usize("steps")?.unwrap();
    let mut req = GenRequest::new(args.get("prompt").unwrap(), args.get_usize("seed")?.unwrap() as u64);
    req.steps = steps;
    req.sampler = args
        .get("sampler")
        .unwrap()
        .parse()
        .map_err(|e: sd_acc::coordinator::SdError| e.to_string())?;
    if args.flag("pas") {
        req.plan = SamplingPlan::Pas(PasConfig {
            t_sketch: steps / 2,
            t_complete: 3.min(steps / 2),
            t_sparse: args.get_usize("t-sparse")?.unwrap().max(2),
            l_sketch: 2,
            l_refine: 2,
        });
    } else if args.flag("auto") {
        req.plan = SamplingPlan::Auto;
    }
    if let Some(s) = args.get("quant") {
        req.quant =
            Some(QuantScheme::parse(s).ok_or_else(|| format!("unknown quant scheme '{s}'"))?);
    }
    if let Some(p) = args.get("policy") {
        req.policy = parse_approx_policy(p)?;
    }
    let req = coord.resolve_plan(&req, cache.as_ref());
    // Fail typed and early: bad steps/guidance/plan never reach the loop.
    req.validate().map_err(|e| e.to_string())?;
    let res = match cache.as_ref().and_then(|c| c.get_result(&req)) {
        Some(hit) => {
            println!("request cache hit — reusing stored latent");
            hit
        }
        None => {
            let res = if args.flag("progress") {
                coord
                    .generate_one_observed(&req, &PrintProgress { total: steps })
                    .map_err(|e| e.to_string())?
            } else {
                coord.generate_one(&req).map_err(|e| format!("{e:#}"))?
            };
            if let Some(c) = &cache {
                let _ = c.put_result(&req, &res);
            }
            res
        }
    };
    println!(
        "generated in {:.0} ms ({} steps, MAC reduction {:.2}x)",
        res.stats.total_ms,
        steps,
        res.stats.mac_reduction
    );
    let imgs = coord.decode(std::slice::from_ref(&res.latent)).map_err(|e| format!("{e:#}"))?;
    let out = PathBuf::from(args.get("out").unwrap());
    quality::write_ppm(&imgs[0], m.img_h, m.img_w, &out).map_err(|e| format!("{e:#}"))?;
    println!("wrote {}", out.display());
    if let Some((sink, path)) = &trace {
        sink.record(SpanEvent::new(0, Phase::Done));
        sink.flush();
        println!(
            "trace: {} spans -> {} (summarise with `sd-acc trace {}`)",
            sink.recorded(),
            path.display(),
            path.display()
        );
    }
    Ok(())
}

/// `--progress` observer: one line per denoising step, streamed as the
/// loop runs (full vs partial steps have very different costs under
/// phase-aware sampling, so the per-step view is genuinely informative).
struct PrintProgress {
    total: usize,
}

impl StepObserver for PrintProgress {
    fn on_step(&self, i: usize, action: StepAction, ms: f64) {
        let what = match action {
            StepAction::Full => "full".to_string(),
            StepAction::Partial(l) => format!("partial(l={l})"),
        };
        println!("  step {:>3}/{} {:<14} {:7.1} ms", i + 1, self.total, what, ms);
    }
}

// -------------------------------------------------------------------- serve

fn cmd_serve(raw: &[String]) -> Result<(), String> {
    use sd_acc::server::loadgen::{run_load, LoadSpec};
    use sd_acc::server::{Priority, ResiliencePolicy, Server, ServerConfig, SubmitOptions};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let spec = [
        OptSpec { name: "requests", help: "synthetic requests to push", takes_value: true, default: Some("12") },
        OptSpec { name: "steps", help: "denoising steps per request", takes_value: true, default: Some("8") },
        OptSpec { name: "workers", help: "worker threads", takes_value: true, default: Some("2") },
        OptSpec { name: "max-wait-ms", help: "batcher hold time before an aged flush", takes_value: true, default: Some("30") },
        OptSpec { name: "max-queue", help: "bounded admission capacity (QueueFull beyond it)", takes_value: true, default: Some("256") },
        OptSpec { name: "deadline-ms", help: "per-request deadline (0 = none)", takes_value: true, default: Some("0") },
        OptSpec { name: "chaos", help: "deterministic fault schedule, e.g. seed=7,err=0.10,slow=0.03 (sim only)", takes_value: true, default: None },
        OptSpec { name: "load", help: "workload spec: closed|poisson|bursty, e.g. bursty:rate=800,burst=12@6,n=36", takes_value: true, default: None },
        OptSpec { name: "policy", help: "approximation policy for the workload (see `sd-acc policy list`)", takes_value: true, default: None },
        OptSpec { name: "shed-low", help: "shed Low-priority work when smoothed queue depth exceeds N", takes_value: true, default: None },
        OptSpec { name: "brownout", help: "brownout thresholds ENTER:EXIT on smoothed queue depth", takes_value: true, default: None },
        OptSpec { name: "hedge-ms", help: "hedge straggler batches after N ms (0 = off)", takes_value: true, default: Some("0") },
        OptSpec { name: "listen", help: "serve the job API over HTTP/1.1 + SSE on this address (e.g. 127.0.0.1:8460) instead of driving a synthetic workload", takes_value: true, default: None },
        OptSpec { name: "http-threads", help: "wire connection threads (SSE streams hold one each)", takes_value: true, default: Some("8") },
        OptSpec { name: "slo-p95", help: "arm autoscale advice: windowed p95 target in ms", takes_value: true, default: None },
        OptSpec { name: "slo-miss-rate", help: "arm autoscale advice: windowed deadline-miss-rate target (0..1)", takes_value: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts dir", takes_value: true, default: None },
        backend_opt(),
        OptSpec { name: "cache-dir", help: "persistent cache dir (enables the request cache)", takes_value: true, default: None },
        OptSpec { name: "trace-out", help: "record per-job span trace to this JSONL path", takes_value: true, default: None },
        OptSpec { name: "monitor", help: "print a live SLO line to stderr every N seconds (0 = off)", takes_value: true, default: Some("0") },
        OptSpec { name: "json", help: "print the final metrics snapshot as JSON", takes_value: false, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!(
            "{}",
            usage("sd-acc serve", "synthetic workload through the job-API server", &spec)
        );
        return Ok(());
    }
    let (_svc, coord) = start_runtime(&args)?;
    let cache = open_cache(&args, &coord)?.map(Arc::new);
    let trace = match args.get("trace-out") {
        Some(p) => Some((
            TraceSink::with_file(DEFAULT_RING_CAP, Path::new(p)).map_err(|e| format!("{e:#}"))?,
            PathBuf::from(p),
        )),
        None => None,
    };

    let n = args.get_usize("requests")?.unwrap();
    let steps = args.get_usize("steps")?.unwrap();
    let deadline_ms = args.get_u64("deadline-ms")?.unwrap();
    let mut load = args
        .get("load")
        .map(LoadSpec::parse)
        .transpose()?;
    // `--policy` fixes the approximation policy for the whole workload:
    // the synthetic loop applies it per request, and a `--load` spec
    // gets it as a single-class policy axis — unless the spec's own
    // `mix=` clause already chose policies (explicit mix wins).
    let workload_policy = args.get("policy").map(parse_approx_policy).transpose()?;
    if let (Some(spec), Some(policy)) = (load.as_mut(), workload_policy) {
        if spec.mix.policies.is_empty() {
            spec.mix.policies.push((policy, 1.0));
        }
    }
    let mut resilience = ResiliencePolicy::default();
    resilience.shed_low_depth = args.get_usize("shed-low")?;
    if let Some(b) = args.get("brownout") {
        let (enter, exit) =
            b.split_once(':').ok_or("--brownout: expected ENTER:EXIT (e.g. 8:2)")?;
        resilience.brownout_enter = Some(
            enter.parse().map_err(|_| format!("--brownout: bad enter threshold '{enter}'"))?,
        );
        resilience.brownout_exit =
            exit.parse().map_err(|_| format!("--brownout: bad exit threshold '{exit}'"))?;
    }
    let hedge_ms = args.get_u64("hedge-ms")?.unwrap();
    if hedge_ms > 0 {
        resilience.hedge_after = Some(Duration::from_millis(hedge_ms));
    }
    // SLO autoscale advice: armed iff a target is given; either flag
    // alone keeps the other at its policy default.
    let scale_policy = {
        use sd_acc::obs::slo::ScalePolicy;
        let p95 = args.get_f64("slo-p95")?;
        let miss = args.get_f64("slo-miss-rate")?;
        if p95.is_some() || miss.is_some() {
            let mut policy = ScalePolicy::default();
            if let Some(v) = p95 {
                policy.p95_target_ms = v;
            }
            if let Some(v) = miss {
                policy.miss_rate_target = v;
            }
            Some(policy)
        } else {
            None
        }
    };
    let listen = args.get("listen").map(str::to_string);
    // Wire-served job ids are salted with the pid (high 32 bits) so N
    // processes sharing one cache dir emit trace- and wire-distinct ids.
    let job_id_base = if listen.is_some() {
        obs::compose_job_id(std::process::id(), 0)
    } else {
        0
    };
    let server = Server::start(
        Arc::new(coord),
        ServerConfig {
            workers: args.get_usize("workers")?.unwrap().max(1),
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms")?.unwrap()),
            cache,
            max_queue: args.get_usize("max-queue")?.unwrap(),
            trace: trace.as_ref().map(|(sink, _)| Arc::clone(sink)),
            resilience,
            job_id_base,
            scale_policy,
        },
    );
    let client = server.client();

    // Live monitor: a stderr reporter driven off the windowed SLO
    // tracker plus `counters::delta_since` rates — stdout stays clean
    // for the report / `--json` snapshot.
    let monitor_secs = args.get_u64("monitor")?.unwrap();
    let mon_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let monitor = if monitor_secs > 0 {
        use std::sync::atomic::Ordering;
        let metrics = Arc::clone(&server.metrics);
        let stop = Arc::clone(&mon_stop);
        let period = Duration::from_secs(monitor_secs);
        Some(std::thread::spawn(move || {
            let mut last = obs::counters().snapshot();
            let mut next = Instant::now() + period;
            while !stop.load(Ordering::Relaxed) {
                // Small sleep increments so a stop request is honoured
                // promptly even with a long period.
                std::thread::sleep(Duration::from_millis(50));
                if Instant::now() < next {
                    continue;
                }
                next += period;
                let now = obs::counters().snapshot();
                let d = now.delta_since(&last);
                last = now;
                let s = metrics.summary();
                eprintln!(
                    "[monitor] window p50 {:.0} ms p95 {:.0} ms ({} done in window) | \
                     +{} full / +{} partial steps, +{} decodes | \
                     totals: {} done, {} miss, {} cancel, {} reject, depth {} | \
                     resilience: {} retries, {} hedges, {} sheds, {} brownouts | scale: {}",
                    s.windowed_p50_ms,
                    s.windowed_p95_ms,
                    s.windowed_count,
                    d.steps_full,
                    d.steps_partial,
                    d.decodes,
                    s.completed,
                    s.deadline_misses,
                    s.cancellations,
                    s.rejected,
                    s.queue_depth,
                    s.retries,
                    s.hedges,
                    s.sheds,
                    s.brownout_transitions,
                    s.scale_advice.map(|a| a.as_str()).unwrap_or("unarmed")
                );
            }
        }))
    } else {
        None
    };

    // --listen: expose the job API over the wire instead of driving a
    // synthetic workload. Blocks until `POST /admin/shutdown` (e.g.
    // `sd-acc request --addr <addr> --shutdown`), then drains.
    if let Some(listen) = &listen {
        use sd_acc::net::WireServer;
        let threads = args.get_usize("http-threads")?.unwrap().max(1);
        let wire = WireServer::start(client, Arc::clone(&server.metrics), listen, threads)
            .map_err(|e| format!("{e:#}"))?;
        // The CI wire lane polls for this exact line before submitting.
        println!("listening on {}", wire.addr());
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        wire.wait();
        if let Some(h) = monitor {
            mon_stop.store(true, std::sync::atomic::Ordering::Relaxed);
            let _ = h.join();
        }
        let m = server.metrics.summary();
        println!("\n== serve report ==");
        println!(
            "wire drained: {} done, {} cancelled, {} deadline misses, {} rejected",
            m.completed, m.cancellations, m.deadline_misses, m.rejected
        );
        if m.cache_hits + m.cache_misses > 0 {
            println!(
                "request cache: {} hits, {} misses, {} evictions",
                m.cache_hits, m.cache_misses, m.cache_evictions
            );
        }
        if let Some((sink, path)) = &trace {
            sink.flush();
            println!("trace: {} spans -> {}", sink.recorded(), path.display());
        }
        server.shutdown();
        return Ok(());
    }

    let t0 = Instant::now();
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut load_report = None;
    if let Some(spec) = &load {
        println!(
            "driving {} workload requests ({} cooldown) via the deterministic load engine...",
            spec.n, spec.cooldown
        );
        let rep = run_load(&client, spec);
        ok = rep.ok as usize;
        failed = (rep.failed + rep.cancelled + rep.deadline_miss) as usize;
        println!(
            "load: {} submitted, {} ok, {} failed, {} rejected, {} cancelled, {} deadline misses \
             ({:.2} req/s goodput)",
            rep.submitted,
            rep.ok,
            rep.failed,
            rep.rejected,
            rep.cancelled,
            rep.deadline_miss,
            rep.goodput()
        );
        // Per-policy goodput lines — the CI policy lane greps these for
        // evidence that the requested mix actually completed work.
        for (label, n) in &rep.ok_by_policy {
            println!("policy {label}: {n} ok");
        }
        load_report = Some(rep);
    } else {
        println!("submitting {n} requests ({steps} steps, priorities cycling high/normal/low)...");
        let mut handles = Vec::new();
        for i in 0..n {
            let class = i % Priority::ALL.len();
            let mut req = GenRequest::new(
                &format!("red circle x{} y{}", 2 + i % 10, 3 + i % 9),
                9000 + i as u64,
            );
            // Each priority class runs a slightly different step count so
            // the classes land in distinct batch keys — priority governs
            // *cross-key* dispatch order, so one shared key would never
            // exercise it (EDF within a key ignores priority).
            req.steps = steps + class;
            if let Some(policy) = workload_policy {
                req.policy = policy;
            }
            let mut opts = SubmitOptions::with_priority(Priority::ALL[class]);
            if deadline_ms > 0 {
                opts.deadline = Some(Duration::from_millis(deadline_ms));
            }
            match client.submit_with(req, opts) {
                Ok(h) => handles.push(h),
                Err(e) => println!("  {e}"),
            }
        }
        for h in &handles {
            let (events, outcome) = h.wait_with_events();
            let steps_seen = events
                .iter()
                .filter(|e| matches!(e, sd_acc::server::JobEvent::Step { .. }))
                .count();
            match outcome {
                Ok(r) => {
                    ok += 1;
                    println!(
                        "  {} done: {} step events, {:.0} ms generation",
                        h.id, steps_seen, r.stats.total_ms
                    );
                }
                Err(e) => {
                    failed += 1;
                    println!("  {} failed: {e}", h.id);
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if let Some(h) = monitor {
        mon_stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = h.join();
    }
    let m = server.metrics.summary();
    if args.flag("json") {
        // Machine-readable snapshot: the relaxed summary plus the
        // process-global obs counters (cumulative: includes any prior
        // work in this process) and, when tracing, the lock-consistent
        // lifecycle counts.
        use sd_acc::util::json::Json;
        let mut fields = vec![
            ("wall_s", Json::Num(wall)),
            ("summary", m.to_json()),
            ("counters", obs::counters().snapshot().to_json()),
        ];
        if let Some(rep) = &load_report {
            fields.push(("load", rep.to_json()));
        }
        if let Some((sink, _)) = &trace {
            let lc = sink.lifecycle_counts();
            fields.push((
                "lifecycle",
                Json::obj(vec![
                    ("enqueued", Json::Num(lc.enqueued as f64)),
                    ("done", Json::Num(lc.done as f64)),
                    ("failed", Json::Num(lc.failed as f64)),
                    ("cancelled", Json::Num(lc.cancelled as f64)),
                ]),
            ));
        }
        println!("{}", Json::obj(fields).to_string());
        if let Some((sink, _)) = &trace {
            sink.flush();
        }
        server.shutdown();
        return Ok(());
    }
    println!("\n== serve report ==");
    println!(
        "{} ok / {} failed in {:.2}s ({:.2} req/s)",
        ok,
        failed,
        wall,
        (ok + failed) as f64 / wall.max(1e-9)
    );
    println!(
        "latency: p50 {:.0} ms, p95 {:.0} ms | mean batch {:.2}",
        m.p50_ms, m.p95_ms, m.mean_batch_size
    );
    println!(
        "windowed (last {} x {:.0}s): p50 {:.0} ms, p95 {:.0} ms, p99 {:.0} ms over {} jobs (\u{b1}{:.1}%)",
        m.windows,
        m.window_secs,
        m.windowed_p50_ms,
        m.windowed_p95_ms,
        m.windowed_p99_ms,
        m.windowed_count,
        m.slo_relative_error * 100.0
    );
    for p in sd_acc::server::Priority::ALL {
        let lane = m.ledger.lane(p);
        if lane.completed + lane.deadline_misses + lane.cancellations + lane.rejected == 0 {
            continue;
        }
        println!(
            "  lane {:6}: {} done (p50 {:.0} ms), {} miss ({:.0}% rate), {} cancel (ack p95 {:.1} ms), {} reject | steps {}F/{}P",
            p.as_str(),
            lane.completed,
            lane.latency_ms.percentile(50.0),
            lane.deadline_misses,
            lane.deadline_miss_rate() * 100.0,
            lane.cancellations,
            lane.cancel_ack_ms.percentile(95.0),
            lane.rejected,
            lane.steps_full,
            lane.steps_partial
        );
    }
    println!(
        "lifecycle: {} cancelled, {} deadline misses, {} rejected (queue full)",
        m.cancellations, m.deadline_misses, m.rejected
    );
    // Always printed — the CI chaos lane greps this line for evidence
    // that retries/shedding/brownout actually engaged under load.
    println!(
        "resilience: {} retries ({} recovered), {} hedges, {} sheds, {} brownout transitions ({} degraded)",
        m.retries, m.retries_recovered, m.hedges, m.sheds, m.brownout_transitions, m.degraded
    );
    println!(
        "queue depth now: {} total ({}/{}/{} high/normal/low)",
        m.queue_depth,
        m.queue_depth_by_priority[0],
        m.queue_depth_by_priority[1],
        m.queue_depth_by_priority[2]
    );
    if m.cache_hits + m.cache_misses > 0 {
        println!(
            "request cache: {} hits, {} misses, {} evictions",
            m.cache_hits, m.cache_misses, m.cache_evictions
        );
    }
    if let Some((sink, path)) = &trace {
        sink.flush();
        println!(
            "trace: {} spans -> {} (summarise with `sd-acc trace {}`)",
            sink.recorded(),
            path.display(),
            path.display()
        );
    }
    server.shutdown();
    Ok(())
}

// ------------------------------------------------------------------ request

/// Wire client for a `serve --listen` process: submit a job and stream
/// its SSE events (`event: <label>` per frame, exactly one
/// `terminal: <label>` at the end), or hit the control endpoints.
fn cmd_request(raw: &[String]) -> Result<(), String> {
    use sd_acc::net::WireClient;
    use sd_acc::util::json::Json;

    let spec = [
        OptSpec { name: "addr", help: "server address, e.g. 127.0.0.1:8460", takes_value: true, default: None },
        OptSpec { name: "prompt", help: "prompt text", takes_value: true, default: Some("a red fox") },
        OptSpec { name: "seed", help: "generation seed", takes_value: true, default: Some("7") },
        OptSpec { name: "steps", help: "denoising steps", takes_value: true, default: Some("8") },
        OptSpec { name: "guidance", help: "classifier-free guidance scale", takes_value: true, default: Some("7.5") },
        OptSpec { name: "sampler", help: "sampler: ddim | pndm", takes_value: true, default: Some("pndm") },
        OptSpec { name: "plan", help: "sampling plan: full | auto | pas:<t_sparse>", takes_value: true, default: Some("full") },
        OptSpec { name: "quant", help: "mixed-precision scheme label (e.g. w8a8)", takes_value: true, default: None },
        OptSpec { name: "policy", help: "approximation policy label (e.g. stability:250)", takes_value: true, default: None },
        OptSpec { name: "priority", help: "high | normal | low", takes_value: true, default: Some("normal") },
        OptSpec { name: "deadline-ms", help: "deadline budget in ms (0 = none)", takes_value: true, default: Some("0") },
        OptSpec { name: "full-quality", help: "opt out of brownout degradation", takes_value: false, default: None },
        OptSpec { name: "cancel-after-events", help: "DELETE the job after N streamed events", takes_value: true, default: None },
        OptSpec { name: "healthz", help: "just probe GET /healthz", takes_value: false, default: None },
        OptSpec { name: "metrics", help: "just print GET /metrics JSON", takes_value: false, default: None },
        OptSpec { name: "shutdown", help: "just POST /admin/shutdown (graceful drain)", takes_value: false, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!("{}", usage("sd-acc request", "drive a serve --listen endpoint", &spec));
        return Ok(());
    }
    let addr = args.get("addr").ok_or("--addr is required (see serve --listen)")?;
    let client = WireClient::new(addr);

    if args.flag("healthz") {
        let ok = client.healthz().map_err(|e| format!("{e:#}"))?;
        println!("healthz: {}", if ok { "ok" } else { "not ok" });
        return Ok(());
    }
    if args.flag("metrics") {
        let m = client.metrics().map_err(|e| format!("{e:#}"))?;
        println!("{}", m.to_string());
        return Ok(());
    }
    if args.flag("shutdown") {
        client.shutdown().map_err(|e| format!("{e:#}"))?;
        println!("shutdown: ok");
        return Ok(());
    }

    let mut fields = vec![
        ("prompt", Json::str(args.get("prompt").unwrap())),
        ("seed", Json::num(args.get_u64("seed")?.unwrap() as f64)),
        ("steps", Json::num(args.get_usize("steps")?.unwrap() as f64)),
        ("guidance", Json::num(args.get_f64("guidance")?.unwrap())),
        ("sampler", Json::str(args.get("sampler").unwrap())),
        ("plan", Json::str(args.get("plan").unwrap())),
        ("priority", Json::str(args.get("priority").unwrap())),
    ];
    if let Some(q) = args.get("quant") {
        fields.push(("quant", Json::str(q)));
    }
    if let Some(p) = args.get("policy") {
        // Validate locally for a friendly error; the server re-validates.
        parse_approx_policy(p)?;
        fields.push(("policy", Json::str(p)));
    }
    let deadline_ms = args.get_u64("deadline-ms")?.unwrap();
    if deadline_ms > 0 {
        fields.push(("deadline_ms", Json::num(deadline_ms as f64)));
    }
    if args.flag("full-quality") {
        fields.push(("degradable", Json::Bool(false)));
    }
    let body = Json::obj(fields);

    let id = client.submit(&body).map_err(|e| format!("{e:#}"))?;
    println!("job: {id}");
    let cancel_after = args.get_usize("cancel-after-events")?;
    let mut seen = 0usize;
    let events = client
        .stream(id, |ev| {
            println!("event: {}", ev.label);
            seen += 1;
            if cancel_after == Some(seen) {
                // Cancellation races the running job by design; the
                // stream still ends in exactly one terminal event.
                if let Err(e) = client.cancel(id) {
                    eprintln!("cancel failed: {e:#}");
                }
            }
            true
        })
        .map_err(|e| format!("{e:#}"))?;
    let last = events.last().filter(|e| e.is_terminal()).ok_or_else(|| {
        format!("stream for job {id} ended without a terminal event ({} events)", events.len())
    })?;
    println!("terminal: {}", last.label);
    if last.label == "done" {
        println!(
            "done: {} steps, {:.1} ms, mac x{:.2}, latent_fnv {}",
            last.data.get_usize("steps").unwrap_or(0),
            last.data.get_f64("total_ms").unwrap_or(0.0),
            last.data.get_f64("mac_reduction").unwrap_or(0.0),
            last.data.get_str("latent_fnv").unwrap_or("?"),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- calibrate

fn cmd_calibrate(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "steps", help: "timesteps per trajectory", takes_value: true, default: Some("25") },
        OptSpec { name: "prompts", help: "number of calibration prompts", takes_value: true, default: Some("2") },
        OptSpec { name: "artifacts", help: "artifacts dir", takes_value: true, default: None },
        backend_opt(),
        OptSpec { name: "cache-dir", help: "persistent cache dir (warm starts skip the trajectories)", takes_value: true, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!("{}", usage("sd-acc calibrate", "shift-score calibration (Fig. 4)", &spec));
        return Ok(());
    }
    let dir = artifacts_dir(&args);
    let (_svc, coord) = start_runtime(&args)?;
    let cache = open_cache(&args, &coord)?;
    let prompts = calib_prompts(args.get_usize("prompts")?.unwrap());
    let steps = args.get_usize("steps")?.unwrap();
    let calibrator = Calibrator::new(&coord);
    let rep = match &cache {
        Some(c) => {
            let (rep, hit) = calibrator
                .run_cached(c, &prompts, steps, 7.5)
                .map_err(|e| format!("{e:#}"))?;
            if hit {
                println!("calibration cache hit — trajectories skipped");
            }
            rep
        }
        None => calibrator.run(&prompts, steps, 7.5).map_err(|e| format!("{e:#}"))?,
    };
    println!("D* = {} / {steps}, outliers = {:?}", rep.d_star, rep.outliers);
    // calibration.json sits in the artifacts dir and is consumed by the
    // xla tooling (bench_fig4) with no backend tag — sim-measured shift
    // scores must not masquerade as measurements of the real model, so
    // only the xla backend persists the file.
    if coord.backend() == BackendKind::Xla {
        std::fs::write(dir.join("calibration.json"), rep.to_json().to_string())
            .map_err(|e| e.to_string())?;
        println!("wrote {}/calibration.json", dir.display());
    } else {
        println!("(sim backend: calibration.json not written — sim measurements stay out of the artifacts dir)");
    }
    Ok(())
}

// -------------------------------------------------------------------- quant

fn cmd_quant(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "model", help: "sd-v1.4 | sd-v2.1-base | sd-xl | sd-tiny", takes_value: true, default: Some("sd-v1.4") },
        OptSpec { name: "steps", help: "trajectory steps (calibrate)", takes_value: true, default: Some("25") },
        OptSpec { name: "prompts", help: "number of calibration prompts", takes_value: true, default: Some("2") },
        OptSpec { name: "quality-target", help: "latent-PSNR proxy floor in dB (search)", takes_value: true, default: Some("30") },
        OptSpec { name: "scheme", help: "precision scheme for `report` (fp16 | w8a8 | w4a8 | ...)", takes_value: true, default: Some("w8a8") },
        OptSpec { name: "policy", help: "baseline | ac | ad | optimized", takes_value: true, default: Some("optimized") },
        OptSpec { name: "no-pin", help: "disable the fragile-layer sensitivity pass", takes_value: false, default: None },
        OptSpec { name: "artifacts", help: "artifacts dir (calibrate measures real trajectories when present)", takes_value: true, default: None },
        backend_opt(),
        OptSpec { name: "cache-dir", help: "persistent cache dir (profiles cached in the quant namespace)", takes_value: true, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    let action = args.positional().first().map(String::as_str).unwrap_or("search");
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sd-acc quant <calibrate|search|report>",
                "mixed-precision calibration, bit-width search, hwsim report",
                &spec
            )
        );
        return Ok(());
    }
    let model = args.get("model").unwrap();
    let arch = arch_by_name(model).ok_or_else(|| format!("unknown model '{model}'"))?;
    let steps = args.get_usize("steps")?.unwrap();
    let policy = parse_policy(args.get("policy").unwrap())?;
    let cfg = AccelConfig::default();
    let ops = unet_ops(&arch);

    match action {
        "calibrate" => {
            let (profile, _runtime) = acquire_quant_profile(&args, &arch, steps)?;
            let mut t = Table::new(&["tensor", "lo", "hi", "absmax", "p99", "drf"]);
            for r in profile.ranges.iter().take(24) {
                t.row(vec![
                    r.name.clone(),
                    f(r.lo as f64, 2),
                    f(r.hi as f64, 2),
                    f(r.absmax as f64, 2),
                    f(r.p99 as f64, 2),
                    f(profile.drf(&r.name), 2),
                ]);
            }
            t.print();
            if profile.ranges.len() > 24 {
                println!("({} more entries)", profile.ranges.len() - 24);
            }
        }
        "search" => {
            let cons = QuantConstraints {
                min_psnr_db: args.get_f64("quality-target")?.unwrap(),
                pin_fragile: !args.flag("no-pin"),
            };
            // Measured calibration (+ measured validation of the front)
            // when artifacts are present; deterministic synthetic
            // otherwise.
            let (profile, runtime) = acquire_quant_profile(&args, &arch, steps)?;
            let mut front = search(&ops, &cfg, policy, &cons, Some(&profile));
            if let Some((_svc, coord)) = &runtime {
                // Fill measured PSNR on the top candidates (reported, not
                // re-gated: the measured scale is a different proxy than
                // the analytic one the floor applies to, and it reflects
                // the activation axis only — the artifacts run fp32
                // weights, see QuantSearcher's docs).
                let prompts = calib_prompts(args.get_usize("prompts")?.unwrap_or(2));
                let searcher = sd_acc::quant::QuantSearcher { coord };
                searcher
                    .validate(&mut front, &prompts, steps, f64::NEG_INFINITY, 3)
                    .map_err(|e| format!("{e:#}"))?;
            }
            println!(
                "model {} | policy {} | quality target {} dB | profile: {} | Pareto front:",
                arch.name,
                args.get("policy").unwrap(),
                cons.min_psnr_db,
                profile.model
            );
            let mut t = Table::new(&[
                "scheme", "MAC bits", "PSNR proxy (dB)", "measured A-only (dB)",
                "energy/step (J)", "vs fp32", "traffic (GB)", "pinned",
            ]);
            for c in &front {
                t.row(vec![
                    c.scheme.label(),
                    c.scheme.mac_bits().to_string(),
                    f(c.psnr_db, 1),
                    c.measured_psnr_db.map(|p| f(p, 1)).unwrap_or_else(|| "-".into()),
                    f(c.energy_j, 2),
                    ratio(c.energy_reduction),
                    f(c.report.traffic_bytes / 1e9, 2),
                    c.pinned.to_string(),
                ]);
            }
            t.print();
        }
        "report" => {
            let s = args.get("scheme").unwrap();
            let scheme =
                QuantScheme::parse(s).ok_or_else(|| format!("unknown quant scheme '{s}'"))?;
            let pin = !args.flag("no-pin");
            let base = simulate_unet_step_quant(&cfg, policy, &ops, &assign(&ops, QuantScheme::fp32(), false));
            let plan = assign(&ops, scheme, pin);
            let r = simulate_unet_step_quant(&cfg, policy, &ops, &plan);
            let label = scheme.label();
            println!("model {} | policy {} | {label} vs fp32 (CFG x2 step)", arch.name, args.get("policy").unwrap());
            let mut t = Table::new(&["metric", "fp32", label.as_str(), "reduction"]);
            t.row(vec!["SA cycles (M)".into(), f(base.sa_cycles / 1e6, 1), f(r.sa_cycles / 1e6, 1), ratio(base.sa_cycles / r.sa_cycles)]);
            t.row(vec!["traffic (GB)".into(), f(base.traffic_bytes / 1e9, 2), f(r.traffic_bytes / 1e9, 2), ratio(base.traffic_bytes / r.traffic_bytes)]);
            t.row(vec!["step time (s)".into(), f(base.seconds(&cfg), 3), f(r.seconds(&cfg), 3), ratio(base.seconds(&cfg) / r.seconds(&cfg))]);
            t.row(vec!["energy (J)".into(), f(base.energy_j(&cfg), 2), f(r.energy_j(&cfg), 2), ratio(base.energy_j(&cfg) / r.energy_j(&cfg))]);
            t.print();
            println!(
                "  PSNR proxy {} dB | logical MACs {:.1} G | fragile layers pinned: {}",
                f(predicted_psnr_db(&ops, &plan, None), 1),
                total_macs(&ops) as f64 / 1e9,
                if pin { "yes" } else { "no" }
            );
        }
        other => return Err(format!("unknown quant action '{other}' (calibrate|search|report)")),
    }
    Ok(())
}

// ------------------------------------------------------------------- policy

/// `sd-acc policy <list|describe> [name]`: inspect the approximation-
/// policy registry (the `crate::policy` seam every cache key hashes).
fn cmd_policy(raw: &[String]) -> Result<(), String> {
    use sd_acc::policy::PolicySpec;
    let opt_spec =
        [OptSpec { name: "help", help: "show usage", takes_value: false, default: None }];
    let args = Args::parse(raw, &opt_spec)?;
    let action = args.positional().first().map(String::as_str).unwrap_or("list");
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sd-acc policy <list|describe> [name]",
                "approximation-policy registry",
                &opt_spec
            )
        );
        return Ok(());
    }
    match action {
        "list" => {
            let mut t = Table::new(&["policy", "online", "description"]);
            for spec in PolicySpec::all() {
                t.row(vec![
                    spec.label(),
                    if spec.online() { "yes".into() } else { "no".into() },
                    spec.build().describe(),
                ]);
            }
            t.print();
            println!(
                "parameterized forms accepted too, e.g. block-cache:5, stability:90; \
                 the id is hashed into every batch/request cache key"
            );
        }
        "describe" => {
            let name = args
                .positional()
                .get(1)
                .ok_or("policy describe needs a name (see `sd-acc policy list`)")?;
            let spec = parse_approx_policy(name)?;
            let p = spec.build();
            println!("{}", p.policy_id());
            println!("  {}", p.describe());
            println!(
                "  online (adapts to the measured eps trajectory): {}",
                if spec.online() { "yes — served solo, never batched" } else { "no" }
            );
        }
        other => return Err(format!("unknown policy action '{other}' (list|describe)")),
    }
    Ok(())
}

// -------------------------------------------------------------------- cache

fn cmd_cache(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "dir", help: "cache directory ($SD_ACC_CACHE or ./cache)", takes_value: true, default: None },
        OptSpec { name: "max-bytes", help: "byte cap enforced on open/gc", takes_value: true, default: None },
        OptSpec { name: "max-entries", help: "entry cap enforced on open/gc", takes_value: true, default: None },
        OptSpec { name: "namespace", help: "restrict clear to one namespace (calib|plan|quant|request)", takes_value: true, default: None },
        OptSpec { name: "request-ttl-secs", help: "TTL for the request namespace (gc sweeps expired latents)", takes_value: true, default: None },
        OptSpec { name: "json", help: "print stats as JSON instead of a table", takes_value: false, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    let action = args.positional().first().map(String::as_str).unwrap_or("stats");
    if args.flag("help") {
        print!(
            "{}",
            usage("sd-acc cache <stats|gc|clear>", "persistent cache maintenance", &spec)
        );
        return Ok(());
    }
    let mut cfg =
        StoreConfig::new(args.get("dir").map(PathBuf::from).unwrap_or_else(default_cache_dir));
    let requested_max_bytes = args.get_u64("max-bytes")?;
    if let Some(b) = requested_max_bytes {
        cfg.max_bytes = b;
    }
    if let Some(n) = args.get_usize("max-entries")? {
        cfg.max_entries = n;
    }
    if let Some(ttl) = args.get_u64("request-ttl-secs")? {
        cfg = cfg.with_ttl(NS_REQUEST, ttl);
    }
    if action == "stats" {
        // Inspection must be read-only: opening with finite caps would
        // evict on the spot. The caps shown come from the flags/defaults.
        cfg.max_bytes = u64::MAX;
        cfg.max_entries = usize::MAX;
    }
    let store = Store::open(cfg).map_err(|e| format!("{e:#}"))?;
    match action {
        "stats" => {
            let s = store.stats();
            if args.flag("json") {
                use sd_acc::util::json::Json;
                let mut fields = vec![
                    ("dir", Json::Str(store.dir().display().to_string())),
                    (
                        "namespaces",
                        Json::Arr(
                            s.namespaces
                                .iter()
                                .map(|ns| {
                                    Json::obj(vec![
                                        ("namespace", Json::Str(ns.namespace.clone())),
                                        ("entries", Json::Num(ns.entries as f64)),
                                        ("bytes", Json::Num(ns.bytes as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("entries", Json::Num(s.entries as f64)),
                    ("bytes", Json::Num(s.bytes as f64)),
                ];
                if let Some(cap) = requested_max_bytes {
                    fields.push(("max_bytes", Json::Num(cap as f64)));
                }
                if let Some(h) = store.meta("manifest_hash") {
                    fields.push(("manifest_hash", Json::Str(h)));
                }
                println!("{}", Json::obj(fields).to_string());
                return Ok(());
            }
            println!("cache dir : {}", store.dir().display());
            if let Some(h) = store.meta("manifest_hash") {
                println!("manifest  : {h}");
            }
            let mut t = Table::new(&["namespace", "entries", "bytes"]);
            for ns in &s.namespaces {
                t.row(vec![ns.namespace.clone(), ns.entries.to_string(), fmt_bytes(ns.bytes)]);
            }
            t.row(vec![
                "total".into(),
                s.entries.to_string(),
                match requested_max_bytes {
                    Some(cap) => format!("{} (cap {})", fmt_bytes(s.bytes), fmt_bytes(cap)),
                    None => fmt_bytes(s.bytes),
                },
            ]);
            t.print();
        }
        "gc" => {
            let r = store.gc().map_err(|e| format!("{e:#}"))?;
            println!(
                "gc: dropped {} missing entries, removed {} orphan files, \
                 swept {} expired, evicted {} to caps",
                r.dropped_missing, r.removed_orphans, r.expired, r.evicted
            );
        }
        "clear" => {
            let n = store.clear(args.get("namespace"));
            match args.get("namespace") {
                Some(ns) => println!("cleared {n} entries from namespace '{ns}'"),
                None => println!("cleared {n} entries"),
            }
        }
        other => return Err(format!("unknown cache action '{other}' (stats|gc|clear)")),
    }
    Ok(())
}

// -------------------------------------------------------------------- trace

/// `sd-acc trace <file>`: parse a JSONL span trace written by
/// `generate --trace` / `serve --trace-out` and print a per-job summary.
fn cmd_trace(raw: &[String]) -> Result<(), String> {
    use sd_acc::util::json::Json;
    let spec = [
        OptSpec { name: "analyze", help: "decompose per-job latency into phases + batch critical paths", takes_value: false, default: None },
        OptSpec { name: "export-chrome", help: "write a Chrome trace-event / Perfetto JSON to this path", takes_value: true, default: None },
        OptSpec { name: "strict", help: "exit nonzero on parse warnings or jobs without terminals", takes_value: false, default: None },
        OptSpec { name: "json", help: "print the per-job summary (or analysis) as JSON", takes_value: false, default: None },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") || args.positional().is_empty() {
        print!("{}", usage("sd-acc trace <file.jsonl>", "summarise a recorded span trace", &spec));
        return if args.flag("help") { Ok(()) } else { Err("missing trace file argument".into()) };
    }
    let path = PathBuf::from(&args.positional()[0]);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    // Lossy parse: a truncated final line (killed writer) is a warning,
    // not a hard error; mid-file garbage and schema-version mismatches
    // still fail — a trace written by a different vocabulary must not
    // be mis-summarised silently.
    let (spans, warnings) =
        sd_acc::obs::parse_jsonl_lossy(&text).map_err(|e| format!("{e:#}"))?;
    for w in &warnings {
        eprintln!("warning: {w}");
    }
    if spans.is_empty() {
        return Err(format!("{}: no spans", path.display()));
    }

    if let Some(out) = args.get("export-chrome") {
        let out = PathBuf::from(out);
        let n = sd_acc::obs::export::write_chrome(&spans, &out)
            .map_err(|e| format!("{e:#}"))?;
        // Self-validate: the export must round-trip through our own
        // JSON parser before we call it well-formed.
        let back = std::fs::read_to_string(&out)
            .map_err(|e| format!("re-read {}: {e}", out.display()))?;
        let parsed = Json::parse(&back)
            .map_err(|e| format!("exported chrome trace is not valid JSON: {e:?}"))?;
        let events = parsed
            .get("traceEvents")
            .and_then(|j| j.as_arr())
            .ok_or("exported chrome trace lacks a traceEvents array")?
            .len();
        if events != n {
            return Err(format!("chrome export round-trip mismatch: wrote {n}, read {events}"));
        }
        println!("chrome trace: {} events -> {} (validated)", n, out.display());
    }

    if args.flag("analyze") {
        let a = sd_acc::obs::analyze::analyze(&spans);
        if args.flag("json") {
            println!("{}", a.to_json().to_string());
        } else {
            println!(
                "{}: {} spans, {} jobs ({} complete), {} batch group(s)",
                path.display(),
                spans.len(),
                a.jobs.len(),
                a.jobs.iter().filter(|t| t.complete).count(),
                a.batches.len()
            );
            println!("\n== where does a millisecond go ({:.1} ms total e2e) ==", a.total_e2e_ms);
            let mut t = Table::new(&["phase", "total ms", "share %", "p50 ms", "p95 ms", "p99 ms"]);
            for p in &a.phases {
                t.row(vec![
                    p.name.to_string(),
                    f(p.total_ms, 2),
                    f(p.share * 100.0, 1),
                    f(p.p50_ms, 2),
                    f(p.p95_ms, 2),
                    f(p.p99_ms, 2),
                ]);
            }
            t.print();
            println!("\n== per-job decomposition (ms) ==");
            let mut t = Table::new(&[
                "job", "e2e", "queue", "form", "full", "partial", "cache", "decode", "other",
                "batch", "lead", "terminal",
            ]);
            for j in &a.jobs {
                t.row(vec![
                    j.job.to_string(),
                    f(j.e2e_us as f64 / 1e3, 1),
                    f(j.breakdown.queue_us as f64 / 1e3, 1),
                    f(j.breakdown.batch_form_us as f64 / 1e3, 1),
                    f(j.breakdown.step_full_us as f64 / 1e3, 1),
                    f(j.breakdown.step_partial_us as f64 / 1e3, 1),
                    f(j.breakdown.cache_us as f64 / 1e3, 1),
                    f(j.breakdown.decode_us as f64 / 1e3, 1),
                    f(j.other_us as f64 / 1e3, 1),
                    j.batch.map_or("-".into(), |b| b.to_string()),
                    if j.lead { "*".into() } else { String::new() },
                    j.terminal.map_or("-".into(), |p| p.as_str().to_string()),
                ]);
            }
            t.print();
            if !a.batches.is_empty() {
                println!("\n== batch critical paths ==");
                let mut t =
                    Table::new(&["size", "lead job", "span ms", "lead work ms", "overhead ms"]);
                for b in &a.batches {
                    t.row(vec![
                        b.size.to_string(),
                        b.lead.to_string(),
                        f(b.span_us as f64 / 1e3, 1),
                        f(b.lead_work_us as f64 / 1e3, 1),
                        f(b.span_us.saturating_sub(b.lead_work_us) as f64 / 1e3, 1),
                    ]);
                }
                t.print();
            }
            if !a.incomplete_jobs.is_empty() {
                println!(
                    "warning: {} job(s) have no terminal span (truncated trace?): {:?}",
                    a.incomplete_jobs.len(),
                    a.incomplete_jobs
                );
            }
        }
        let orphans = a.incomplete_jobs.len();
        if args.flag("strict") && (!warnings.is_empty() || orphans > 0) {
            return Err(format!(
                "strict: {} parse warning(s), {} incomplete job(s)",
                warnings.len(),
                orphans
            ));
        }
        return Ok(());
    }

    // Aggregate per job, in first-seen order.
    struct JobAgg {
        job: u64,
        spans: u64,
        steps: u64,
        lookups: u64,
        lookup_hits: u64,
        executes: u64,
        bytes: u64,
        first_us: u64,
        last_us: u64,
        terminal: Option<Phase>,
    }
    let mut jobs: Vec<JobAgg> = Vec::new();
    for ev in &spans {
        let agg = match jobs.iter_mut().find(|a| a.job == ev.job) {
            Some(a) => a,
            None => {
                jobs.push(JobAgg {
                    job: ev.job,
                    spans: 0,
                    steps: 0,
                    lookups: 0,
                    lookup_hits: 0,
                    executes: 0,
                    bytes: 0,
                    first_us: ev.ts_us,
                    last_us: ev.ts_us,
                    terminal: None,
                });
                jobs.last_mut().unwrap()
            }
        };
        agg.spans += 1;
        agg.first_us = agg.first_us.min(ev.ts_us);
        agg.last_us = agg.last_us.max(ev.ts_us);
        agg.bytes += ev.bytes.unwrap_or(0);
        match ev.phase {
            Phase::Step => agg.steps += 1,
            Phase::CacheLookup => {
                agg.lookups += 1;
                if ev.hit == Some(true) {
                    agg.lookup_hits += 1;
                }
            }
            Phase::Execute => agg.executes += 1,
            p if p.is_terminal() => agg.terminal = Some(p),
            _ => {}
        }
    }

    if args.flag("json") {
        let out = Json::obj(vec![
            ("trace_schema_version", Json::Num(sd_acc::obs::TRACE_SCHEMA_VERSION as f64)),
            ("spans", Json::Num(spans.len() as f64)),
            (
                "jobs",
                Json::Arr(
                    jobs.iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("job", Json::Num(a.job as f64)),
                                ("spans", Json::Num(a.spans as f64)),
                                ("steps", Json::Num(a.steps as f64)),
                                ("cache_lookups", Json::Num(a.lookups as f64)),
                                ("cache_hits", Json::Num(a.lookup_hits as f64)),
                                ("executes", Json::Num(a.executes as f64)),
                                ("bytes", Json::Num(a.bytes as f64)),
                                ("span_ms", Json::Num((a.last_us - a.first_us) as f64 / 1e3)),
                                (
                                    "terminal",
                                    match a.terminal {
                                        Some(p) => Json::Str(p.as_str().to_string()),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        println!("{}", out.to_string());
        let orphans = jobs.iter().filter(|a| a.terminal.is_none()).count();
        if args.flag("strict") && (!warnings.is_empty() || orphans > 0) {
            return Err(format!(
                "strict: {} parse warning(s), {orphans} incomplete job(s)",
                warnings.len()
            ));
        }
        return Ok(());
    }

    println!("{}: {} spans, {} jobs", path.display(), spans.len(), jobs.len());
    let mut t = Table::new(&[
        "job", "spans", "steps", "lookups", "hits", "executes", "bytes", "span ms", "terminal",
    ]);
    for a in &jobs {
        t.row(vec![
            a.job.to_string(),
            a.spans.to_string(),
            a.steps.to_string(),
            a.lookups.to_string(),
            a.lookup_hits.to_string(),
            a.executes.to_string(),
            fmt_bytes(a.bytes),
            f((a.last_us - a.first_us) as f64 / 1e3, 1),
            a.terminal.map(|p| p.as_str().to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    let orphans = jobs.iter().filter(|a| a.terminal.is_none()).count();
    if orphans > 0 {
        println!("warning: {orphans} job(s) have no terminal span (truncated trace?)");
    }
    if args.flag("strict") && (!warnings.is_empty() || orphans > 0) {
        return Err(format!(
            "strict: {} parse warning(s), {orphans} incomplete job(s)",
            warnings.len()
        ));
    }
    Ok(())
}

// ----------------------------------------------------------------- simulate

fn cmd_simulate(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "model", help: "sd-v1.4 | sd-v2.1-base | sd-xl | sd-tiny", takes_value: true, default: Some("sd-v1.4") },
        OptSpec { name: "policy", help: "baseline | ac | ad | optimized", takes_value: true, default: Some("optimized") },
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!("{}", usage("sd-acc simulate", "accelerator performance model", &spec));
        return Ok(());
    }
    let arch = arch_by_name(args.get("model").unwrap())
        .ok_or_else(|| format!("unknown model '{}'", args.get("model").unwrap()))?;
    let policy = parse_policy(args.get("policy").unwrap())?;
    let cfg = AccelConfig::default();
    let ops = unet_ops(&arch);
    let r = simulate_unet_step(&cfg, policy, &ops);
    println!("model {} | policy {:?}", arch.name, args.get("policy").unwrap());
    println!("  ops                 : {}", r.layers);
    println!("  U-Net step (CFG x2) : {:.3} s @ {:.0} MHz", r.seconds(&cfg), cfg.freq_hz / 1e6);
    println!("  PE utilisation      : {:.1}%", 100.0 * r.utilization(&cfg));
    println!("  off-chip traffic    : {:.2} GB/step", r.traffic_bytes / 1e9);
    println!("  op intensity        : {:.0} FLOP/B (knee {:.1})", r.operational_intensity(), cfg.peak_flops() / cfg.dram_bw);
    println!("  energy              : {:.1} J/step, {:.2} kJ per 50-step image", r.energy_j(&cfg), r.energy_j(&cfg) * 50.0 / 1e3);
    Ok(())
}

// --------------------------------------------------------------------- info

fn cmd_info(raw: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec { name: "artifacts", help: "artifacts dir", takes_value: true, default: None },
        backend_opt(),
        OptSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = Args::parse(raw, &spec)?;
    if args.flag("help") {
        print!("{}", usage("sd-acc info", "artifact summary", &spec));
        return Ok(());
    }
    let dir = artifacts_dir(&args);
    let kind = BackendKind::resolve(args.get("backend"))
        .map_err(|e| format!("{e:#}"))?
        .for_dir(&dir);
    let manifest = match kind {
        BackendKind::Sim => {
            use sd_acc::runtime::ExecBackend;
            println!("backend: sim (synthetic manifest when no artifacts exist)");
            sd_acc::runtime::SimBackend::open(&dir)
                .map_err(|e| format!("{e:#}"))?
                .manifest()
                .clone()
        }
        _ => {
            need_artifacts(&dir)?;
            sd_acc::runtime::Manifest::load(&dir).map_err(|e| format!("{e:#}"))?
        }
    };
    println!("artifacts dir : {}", dir.display());
    println!("model         : sd-tiny latent {}x{}x{}, ctx {}x{}, max_cut {}",
        manifest.model.latent_h, manifest.model.latent_w, manifest.model.latent_c,
        manifest.model.ctx_len, manifest.model.ctx_dim, manifest.model.max_cut);
    println!("batch sizes   : {:?}", manifest.batch_sizes);
    println!("vocab         : {} words", manifest.vocab.len());
    println!("alpha_bar     : {} train steps", manifest.alpha_bar.len());
    println!("artifacts     : {}", manifest.artifacts.len());
    for (name, a) in &manifest.artifacts {
        println!("  {:22} {} inputs, {} params", name, a.inputs.len(), a.n_params);
    }
    for (set, w) in &manifest.weights {
        let elems: usize = w.table.iter().map(|e| e.len).sum();
        println!("weights[{set:4}] : {} leaves, {:.1} MB", w.table.len(), elems as f64 * 4.0 / 1e6);
    }
    Ok(())
}
