//! Operator inventory builder for StableDiff U-Nets (+ text encoder, VAE).

use std::collections::BTreeMap;

/// Paper block indexing (Fig. 3): 12 down blocks, middle, 12 up blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Block {
    Down(usize),
    Mid,
    Up(usize),
    TextEncoder,
    Vae,
}

impl Block {
    pub fn label(&self) -> String {
        match self {
            Block::Down(i) => format!("down{i}"),
            Block::Mid => "mid".into(),
            Block::Up(i) => format!("up{i}"),
            Block::TextEncoder => "text".into(),
            Block::Vae => "vae".into(),
        }
    }
}

/// A single operator with exact shape.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// KxK convolution on an HxW feature map (stride 1 or 2, same pad).
    Conv { h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize },
    /// Dense matmul (m, k) x (k, n) with learned weights.
    Matmul { m: usize, n: usize, k: usize },
    /// Activation-activation matmul (attention logits / values) — no weights.
    MatmulAct { m: usize, n: usize, k: usize },
    Softmax { rows: usize, cols: usize },
    Layernorm { rows: usize, cols: usize },
    Groupnorm { rows: usize, cols: usize },
    Gelu { n: usize },
    Silu { n: usize },
    /// Residual adds, concats, nearest upsampling — pure data movement.
    Elementwise { n: usize },
}

/// An inventory entry: one operator inside one paper block.
#[derive(Debug, Clone)]
pub struct LayerOp {
    pub name: String,
    pub block: Block,
    pub kind: OpKind,
}

impl OpKind {
    /// Multiply-accumulate count (1 MAC = 1 mul + 1 add, Fig. 2 caption).
    pub fn macs(&self) -> u64 {
        match *self {
            OpKind::Conv { h, w, cin, cout, k, stride } => {
                let (p, q) = (h.div_ceil(stride), w.div_ceil(stride));
                (p * q * cin * cout * k * k) as u64
            }
            OpKind::Matmul { m, n, k } | OpKind::MatmulAct { m, n, k } => (m * n * k) as u64,
            // Nonlinears counted as ~0 MACs (they bottleneck latency, not
            // MACs — Sec. IV-C); elementwise likewise.
            _ => 0,
        }
    }

    /// Learned parameter count.
    pub fn params(&self) -> u64 {
        match *self {
            OpKind::Conv { cin, cout, k, .. } => (cin * cout * k * k + cout) as u64,
            OpKind::Matmul { n, k, .. } => (k * n) as u64,
            OpKind::Layernorm { cols, .. } | OpKind::Groupnorm { cols, .. } => 2 * cols as u64,
            _ => 0,
        }
    }

    /// Input activation element count.
    pub fn input_elems(&self) -> u64 {
        match *self {
            OpKind::Conv { h, w, cin, .. } => (h * w * cin) as u64,
            OpKind::Matmul { m, k, .. } | OpKind::MatmulAct { m, k, .. } => (m * k) as u64,
            OpKind::Softmax { rows, cols }
            | OpKind::Layernorm { rows, cols }
            | OpKind::Groupnorm { rows, cols } => (rows * cols) as u64,
            OpKind::Gelu { n } | OpKind::Silu { n } | OpKind::Elementwise { n } => n as u64,
        }
    }

    /// Output activation element count.
    pub fn output_elems(&self) -> u64 {
        match *self {
            OpKind::Conv { h, w, cout, stride, .. } => {
                (h.div_ceil(stride) * w.div_ceil(stride) * cout) as u64
            }
            OpKind::Matmul { m, n, .. } | OpKind::MatmulAct { m, n, .. } => (m * n) as u64,
            _ => self.input_elems(),
        }
    }

    pub fn is_conv3x3(&self) -> bool {
        matches!(self, OpKind::Conv { k: 3, .. })
    }
}

/// U-Net architecture description (real model scale).
#[derive(Debug, Clone)]
pub struct UNetArch {
    pub name: &'static str,
    pub latent: usize,
    pub latent_c: usize,
    pub model_channels: usize,
    pub mult: Vec<usize>,
    /// Transformer depth per level (0 = no attention at that level).
    pub tf_depth: Vec<usize>,
    pub ctx_len: usize,
    pub ctx_dim: usize,
    pub temb_dim: usize,
    /// true: GEGLU feed-forward (SD practice), inner dim 4c.
    pub geglu: bool,
}

/// StableDiff v1.4 (also v1.5): 860M-param U-Net, latent 64x64.
pub fn sd_v14() -> UNetArch {
    UNetArch {
        name: "sd-v1.4",
        latent: 64,
        latent_c: 4,
        model_channels: 320,
        mult: vec![1, 2, 4, 4],
        tf_depth: vec![1, 1, 1, 0],
        ctx_len: 77,
        ctx_dim: 768,
        temb_dim: 1280,
        geglu: true,
    }
}

/// StableDiff v2.1-base: same topology, OpenCLIP ctx_dim 1024.
pub fn sd_v21_base() -> UNetArch {
    UNetArch { name: "sd-v2.1-base", ctx_dim: 1024, ..sd_v14() }
}

/// StableDiff XL: latent 128x128, 3 levels, deep transformers.
pub fn sd_xl() -> UNetArch {
    UNetArch {
        name: "sd-xl",
        latent: 128,
        latent_c: 4,
        model_channels: 320,
        mult: vec![1, 2, 4],
        tf_depth: vec![0, 2, 10],
        ctx_len: 77,
        ctx_dim: 2048,
        temb_dim: 1280,
        geglu: true,
    }
}

/// The runnable sd-tiny model (matches python/compile/config.py), used to
/// cross-check the cost function against actually-measured step times.
pub fn sd_tiny() -> UNetArch {
    UNetArch {
        name: "sd-tiny",
        latent: 16,
        latent_c: 4,
        model_channels: 32,
        mult: vec![1, 2, 4, 4],
        tf_depth: vec![1, 1, 1, 0],
        ctx_len: 16,
        ctx_dim: 64,
        temb_dim: 128,
        geglu: false,
    }
}

pub fn arch_by_name(name: &str) -> Option<UNetArch> {
    match name {
        "sd-v1.4" | "v1.4" | "sd14" => Some(sd_v14()),
        "sd-v2.1-base" | "v2.1" | "sd21" => Some(sd_v21_base()),
        "sd-xl" | "xl" | "sdxl" => Some(sd_xl()),
        "sd-tiny" | "tiny" => Some(sd_tiny()),
        _ => None,
    }
}

// --------------------------------------------------------------- builders

struct Builder {
    ops: Vec<LayerOp>,
    block: Block,
}

impl Builder {
    fn push(&mut self, name: impl Into<String>, kind: OpKind) {
        self.ops.push(LayerOp { name: name.into(), block: self.block, kind });
    }

    fn resnet(&mut self, tag: &str, r: usize, cin: usize, cout: usize, temb: usize) {
        let l = r * r;
        self.push(format!("{tag}.gn1"), OpKind::Groupnorm { rows: l, cols: cin });
        self.push(format!("{tag}.silu1"), OpKind::Silu { n: l * cin });
        self.push(format!("{tag}.conv1"), OpKind::Conv { h: r, w: r, cin, cout, k: 3, stride: 1 });
        self.push(format!("{tag}.temb"), OpKind::Matmul { m: 1, n: cout, k: temb });
        self.push(format!("{tag}.gn2"), OpKind::Groupnorm { rows: l, cols: cout });
        self.push(format!("{tag}.silu2"), OpKind::Silu { n: l * cout });
        self.push(format!("{tag}.conv2"), OpKind::Conv { h: r, w: r, cin: cout, cout, k: 3, stride: 1 });
        if cin != cout {
            self.push(format!("{tag}.skip"), OpKind::Conv { h: r, w: r, cin, cout, k: 1, stride: 1 });
        }
        self.push(format!("{tag}.add"), OpKind::Elementwise { n: l * cout });
    }

    fn transformer(&mut self, tag: &str, r: usize, c: usize, depth: usize, arch: &UNetArch) {
        let l = r * r;
        self.push(format!("{tag}.gn"), OpKind::Groupnorm { rows: l, cols: c });
        self.push(format!("{tag}.proj_in"), OpKind::Conv { h: r, w: r, cin: c, cout: c, k: 1, stride: 1 });
        for d in 0..depth {
            let t = format!("{tag}.d{d}");
            // Self-attention.
            self.push(format!("{t}.ln1"), OpKind::Layernorm { rows: l, cols: c });
            self.push(format!("{t}.qkv"), OpKind::Matmul { m: l, n: 3 * c, k: c });
            self.push(format!("{t}.logits"), OpKind::MatmulAct { m: l, n: l, k: c });
            self.push(format!("{t}.softmax"), OpKind::Softmax { rows: l, cols: l });
            self.push(format!("{t}.attnv"), OpKind::MatmulAct { m: l, n: c, k: l });
            self.push(format!("{t}.proj"), OpKind::Matmul { m: l, n: c, k: c });
            // Cross-attention over the text context.
            self.push(format!("{t}.ln2"), OpKind::Layernorm { rows: l, cols: c });
            self.push(format!("{t}.cq"), OpKind::Matmul { m: l, n: c, k: c });
            self.push(format!("{t}.ckv"), OpKind::Matmul { m: arch.ctx_len, n: 2 * c, k: arch.ctx_dim });
            self.push(format!("{t}.clogits"), OpKind::MatmulAct { m: l, n: arch.ctx_len, k: c });
            self.push(format!("{t}.csoftmax"), OpKind::Softmax { rows: l, cols: arch.ctx_len });
            self.push(format!("{t}.cattnv"), OpKind::MatmulAct { m: l, n: c, k: arch.ctx_len });
            self.push(format!("{t}.cproj"), OpKind::Matmul { m: l, n: c, k: c });
            // Feed-forward (GEGLU doubles the first projection).
            let inner = 4 * c;
            let ff1_out = if arch.geglu { 2 * inner } else { inner };
            self.push(format!("{t}.ln3"), OpKind::Layernorm { rows: l, cols: c });
            self.push(format!("{t}.ff1"), OpKind::Matmul { m: l, n: ff1_out, k: c });
            self.push(format!("{t}.gelu"), OpKind::Gelu { n: l * inner });
            self.push(format!("{t}.ff2"), OpKind::Matmul { m: l, n: c, k: inner });
        }
        self.push(format!("{tag}.proj_out"), OpKind::Conv { h: r, w: r, cin: c, cout: c, k: 1, stride: 1 });
    }
}

/// Build the full U-Net inventory with paper block tags.
///
/// Topology (Fig. 3): block 1 = conv_in; blocks 4/7/10 = stride-2
/// downsample convs; ResNet+Transformer pairs elsewhere (plain ResNet on
/// levels with tf_depth 0); middle = R+T+R; 12 up blocks mirrored, with
/// up-blocks 4/7/10 carrying nearest-upsample + 3x3 conv, and conv_out
/// attached to up-block 1. For 3-level arches (SDXL) the deepest level's
/// slots collapse analogously (blocks 7-12 at the two deep levels).
pub fn unet_ops(arch: &UNetArch) -> Vec<LayerOp> {
    let nlv = arch.mult.len();
    assert!(nlv == 3 || nlv == 4, "3- or 4-level U-Nets supported");
    let ch: Vec<usize> = arch.mult.iter().map(|m| m * arch.model_channels).collect();
    let res: Vec<usize> = (0..nlv).map(|l| arch.latent >> l).collect();
    let mut b = Builder { ops: Vec::new(), block: Block::Down(1) };

    // --- down path -------------------------------------------------------
    b.block = Block::Down(1);
    b.push("conv_in", OpKind::Conv {
        h: res[0], w: res[0], cin: arch.latent_c, cout: ch[0], k: 3, stride: 1,
    });
    // Skip-connection channel list, in push order.
    let mut skips: Vec<usize> = vec![ch[0]];
    let mut idx = 2;
    let mut cin = ch[0];
    for lv in 0..nlv {
        for _ in 0..2 {
            b.block = Block::Down(idx);
            let tag = format!("down{idx}");
            b.resnet(&tag, res[lv], cin, ch[lv], arch.temb_dim);
            if arch.tf_depth[lv] > 0 {
                b.transformer(&format!("{tag}.tf"), res[lv], ch[lv], arch.tf_depth[lv], arch);
            }
            cin = ch[lv];
            skips.push(cin);
            idx += 1;
        }
        if lv + 1 < nlv {
            b.block = Block::Down(idx);
            b.push(
                format!("down{idx}.downsample"),
                OpKind::Conv { h: res[lv], w: res[lv], cin, cout: cin, k: 3, stride: 2 },
            );
            skips.push(cin);
            idx += 1;
        }
    }
    let n_down = idx - 1; // 12 for 4 levels, 8 for 3 levels

    // --- middle ----------------------------------------------------------
    b.block = Block::Mid;
    let deep = *ch.last().unwrap();
    let rdeep = *res.last().unwrap();
    b.resnet("mid.res1", rdeep, deep, deep, arch.temb_dim);
    let mid_depth = *arch.tf_depth.last().unwrap();
    b.transformer("mid.tf", rdeep, deep, mid_depth.max(1), arch);
    b.resnet("mid.res2", rdeep, deep, deep, arch.temb_dim);

    // --- up path (indexed top-down; executed bottom-up) -------------------
    // Up block i consumes skip i (down block i's output). Each level has 3
    // up resnets; the first block of each non-top level group (top-down
    // order) carries upsample + conv.
    let mut up_specs: Vec<(usize, usize, usize, usize, bool)> = Vec::new();
    // (index, level, c_main, c_skip, upsample_after_group)
    {
        let mut i = 1usize;
        for lv in 0..nlv {
            let group = if lv + 1 < nlv { 3 } else { n_down + 1 - i };
            for j in 0..group {
                // Main-branch channels entering this block: the output of
                // the block below (or mid for the deepest-first block).
                let c_main = if j == group - 1 && lv + 1 < nlv {
                    ch[lv + 1] // arrives upsampled from the deeper level
                } else if i == n_down && lv + 1 == nlv {
                    deep // from mid
                } else {
                    ch[lv]
                };
                let c_skip = skips[i - 1];
                let upsample = lv > 0 && j == 0; // blocks 4/7/10 top-down
                up_specs.push((i, lv, c_main, c_skip, upsample));
                i += 1;
            }
        }
    }
    // Emit in execution order (bottom-up: up12 first, up1 last) so the
    // flat 3x3-conv index matches Fig. 13/16's layer numbering 0..51.
    for &(i, lv, c_main, c_skip, upsample) in up_specs.iter().rev() {
        b.block = Block::Up(i);
        let tag = format!("up{i}");
        b.resnet(&tag, res[lv], c_main + c_skip, ch[lv], arch.temb_dim);
        if arch.tf_depth[lv] > 0 {
            b.transformer(&format!("{tag}.tf"), res[lv], ch[lv], arch.tf_depth[lv], arch);
        }
        if upsample {
            // nearest x2 + 3x3 conv (SD upsampler), executed after this
            // group's last resnet, on the upsampled resolution.
            b.push(
                format!("{tag}.upsample_conv"),
                OpKind::Conv {
                    h: res[lv - 1], w: res[lv - 1], cin: ch[lv], cout: ch[lv], k: 3, stride: 1,
                },
            );
        }
    }
    // conv_out belongs to the topmost up block.
    b.block = Block::Up(1);
    b.push("conv_out", OpKind::Conv {
        h: res[0], w: res[0], cin: ch[0], cout: arch.latent_c, k: 3, stride: 1,
    });

    b.ops
}

/// CLIP-style text encoder inventory (Fig. 2 profiling).
pub fn text_encoder_ops(arch: &UNetArch) -> Vec<LayerOp> {
    // v1.4: CLIP ViT-L/14 text tower (12 layers, d=768); v2.1: OpenCLIP-H
    // (23 layers, d=1024); XL: both towers ~ modelled as one d=2048 tower.
    let (layers, d) = match arch.ctx_dim {
        768 => (12usize, 768usize),
        1024 => (23, 1024),
        _ => (32, 1280),
    };
    let l = arch.ctx_len;
    let mut b = Builder { ops: Vec::new(), block: Block::TextEncoder };
    for i in 0..layers {
        let t = format!("text.l{i}");
        b.push(format!("{t}.ln1"), OpKind::Layernorm { rows: l, cols: d });
        b.push(format!("{t}.qkv"), OpKind::Matmul { m: l, n: 3 * d, k: d });
        b.push(format!("{t}.logits"), OpKind::MatmulAct { m: l, n: l, k: d });
        b.push(format!("{t}.softmax"), OpKind::Softmax { rows: l, cols: l });
        b.push(format!("{t}.attnv"), OpKind::MatmulAct { m: l, n: d, k: l });
        b.push(format!("{t}.proj"), OpKind::Matmul { m: l, n: d, k: d });
        b.push(format!("{t}.ln2"), OpKind::Layernorm { rows: l, cols: d });
        b.push(format!("{t}.ff1"), OpKind::Matmul { m: l, n: 4 * d, k: d });
        b.push(format!("{t}.gelu"), OpKind::Gelu { n: l * 4 * d });
        b.push(format!("{t}.ff2"), OpKind::Matmul { m: l, n: d, k: 4 * d });
    }
    b.ops
}

/// VAE decoder inventory (Fig. 2 profiling): latent -> 8x upsampled RGB.
pub fn vae_decoder_ops(arch: &UNetArch) -> Vec<LayerOp> {
    let mut b = Builder { ops: Vec::new(), block: Block::Vae };
    let chs = [512usize, 512, 256, 128];
    let mut r = arch.latent;
    b.push("vae.conv_in", OpKind::Conv { h: r, w: r, cin: arch.latent_c, cout: 512, k: 3, stride: 1 });
    let mut cin = 512;
    for (lv, &c) in chs.iter().enumerate() {
        for j in 0..3 {
            b.resnet(&format!("vae.l{lv}.res{j}"), r, cin, c, 0);
            cin = c;
        }
        if lv + 1 < chs.len() {
            r *= 2;
            b.push(format!("vae.l{lv}.upconv"), OpKind::Conv { h: r, w: r, cin, cout: cin, k: 3, stride: 1 });
        }
    }
    b.push("vae.conv_out", OpKind::Conv { h: r, w: r, cin, cout: 3, k: 3, stride: 1 });
    b.ops
}

/// Ops executed by a phase-aware *partial* step retaining the top `l`
/// block pairs: down blocks 1..=l and up blocks l..=1, no middle.
pub fn partial_unet_ops(arch: &UNetArch, l: usize) -> Vec<LayerOp> {
    unet_ops(arch)
        .into_iter()
        .filter(|o| match o.block {
            Block::Down(i) | Block::Up(i) => i <= l,
            _ => false,
        })
        .collect()
}

// ------------------------------------------------------------ aggregation

/// Total MACs of an op list.
pub fn total_macs(ops: &[LayerOp]) -> u64 {
    ops.iter().map(|o| o.kind.macs()).sum()
}

/// Total learned parameters.
pub fn total_params(ops: &[LayerOp]) -> u64 {
    ops.iter().map(|o| o.kind.params()).sum()
}

/// MACs per paper block.
pub fn block_macs(ops: &[LayerOp]) -> BTreeMap<Block, u64> {
    let mut m = BTreeMap::new();
    for o in ops {
        *m.entry(o.block).or_insert(0) += o.kind.macs();
    }
    m
}

/// The 3x3 convolution layers in inventory order (Fig. 13's index 0..51).
pub fn conv3x3_layers(ops: &[LayerOp]) -> Vec<&LayerOp> {
    ops.iter().filter(|o| o.kind.is_conv3x3()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sd14_unet_params_near_860m() {
        let ops = unet_ops(&sd_v14());
        let p = total_params(&ops);
        // Paper (Fig. 2): ~860M. Inventory omits time-embedding MLP and
        // per-head minutiae; accept 780-900M.
        assert!(
            (780_000_000..900_000_000).contains(&p),
            "sd1.4 params {p}"
        );
    }

    #[test]
    fn sd14_has_52_conv3x3_layers() {
        // Fig. 13: the 3x3 convs of the SD v1.4 U-Net are indexed 0..51.
        let ops = unet_ops(&sd_v14());
        assert_eq!(conv3x3_layers(&ops).len(), 52);
    }

    #[test]
    fn sd14_block_structure() {
        let ops = unet_ops(&sd_v14());
        let bm = block_macs(&ops);
        // 12 down + mid + 12 up.
        assert_eq!(bm.keys().filter(|b| matches!(b, Block::Down(_))).count(), 12);
        assert_eq!(bm.keys().filter(|b| matches!(b, Block::Up(_))).count(), 12);
        assert!(bm.contains_key(&Block::Mid));
        // Downsample-only blocks are cheap relative to content blocks.
        assert!(bm[&Block::Down(4)] < bm[&Block::Down(2)]);
        // Top blocks (high resolution) are MAC-heavy (Fig. 6's shape).
        assert!(bm[&Block::Up(1)] > bm[&Block::Up(12)]);
    }

    #[test]
    fn sd14_step_macs_plausible() {
        // One U-Net pass of SD1.x at 512x512 is ~340-410 GMAC
        // (thop/diffusers report ~680 GFLOPs = ~340 GMAC; CFG doubles it
        // at runtime).
        let macs = total_macs(&unet_ops(&sd_v14()));
        assert!(
            (300e9 as u64..500e9 as u64).contains(&macs),
            "sd1.4 step macs {macs}"
        );
    }

    #[test]
    fn sdxl_transformer_share_exceeds_sd14() {
        // Sec. VI-E: Transformers occupy a larger proportion in XL.
        let share = |arch: &UNetArch| {
            let ops = unet_ops(arch);
            let tf: u64 = ops
                .iter()
                .filter(|o| o.name.contains(".tf") || o.name.contains(".d"))
                .map(|o| o.kind.macs())
                .sum();
            tf as f64 / total_macs(&ops) as f64
        };
        let s14 = share(&sd_v14());
        let sxl = share(&sd_xl());
        assert!(sxl > s14 + 0.15, "tf share v1.4={s14:.2} xl={sxl:.2}");
    }

    #[test]
    fn text_encoder_params_scale() {
        let p = total_params(&text_encoder_ops(&sd_v14()));
        // CLIP ViT-L/14 text tower ~85M (sans embeddings).
        assert!((60_000_000..130_000_000).contains(&p), "text params {p}");
    }

    #[test]
    fn vae_decoder_macs_dwarfed_by_50_unet_steps() {
        // Fig. 2: U-Net (x50 steps, x2 CFG) >> VAE (x1).
        let unet = total_macs(&unet_ops(&sd_v14())) * 50 * 2;
        let vae = total_macs(&vae_decoder_ops(&sd_v14()));
        assert!(unet > 20 * vae, "unet {unet} vae {vae}");
    }

    #[test]
    fn tiny_arch_block_count_matches_paper_indexing() {
        let ops = unet_ops(&sd_tiny());
        let bm = block_macs(&ops);
        assert_eq!(bm.keys().filter(|b| matches!(b, Block::Down(_))).count(), 12);
        assert_eq!(bm.keys().filter(|b| matches!(b, Block::Up(_))).count(), 12);
    }

    #[test]
    fn conv_macs_formula() {
        let c = OpKind::Conv { h: 8, w: 8, cin: 4, cout: 16, k: 3, stride: 1 };
        assert_eq!(c.macs(), 8 * 8 * 4 * 16 * 9);
        let s2 = OpKind::Conv { h: 8, w: 8, cin: 4, cout: 16, k: 3, stride: 2 };
        assert_eq!(s2.macs(), 4 * 4 * 4 * 16 * 9);
    }

    #[test]
    fn weights_vs_activations_inverted_between_shallow_and_middle() {
        // Fig. 13's observation: shallow/deep layers have big activations
        // and small weights; middle layers the reverse.
        let ops = unet_ops(&sd_v14());
        let convs = conv3x3_layers(&ops);
        let first = convs[1]; // a top-level resnet conv
        let mid = convs
            .iter()
            .find(|o| matches!(o.kind, OpKind::Conv { cin: 1280, cout: 1280, .. }))
            .unwrap();
        let act = |o: &LayerOp| o.kind.input_elems();
        let wts = |o: &LayerOp| o.kind.params();
        assert!(act(first) > wts(first) / 4, "shallow: activations comparable/larger");
        assert!(wts(mid) > 4 * act(mid), "middle: weights dominate");
    }
}
