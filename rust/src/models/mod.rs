//! Real StableDiff layer inventories (S9).
//!
//! All MAC/parameter/traffic accounting for the paper's tables uses the
//! *real* SD v1.4 / v2.1-base / SDXL U-Net architectures encoded here
//! (the runnable sd-tiny model is only the functional substitute — see
//! DESIGN.md). The inventory enumerates every operator with its exact
//! shape, tagged by the paper's block indexing (12 down / mid / 12 up,
//! Fig. 3), which drives:
//!
//! - Fig. 2 (component profiling), Fig. 6 (per-block MACs + cost fn),
//! - Table II/III MAC-reduction columns (via pas::cost),
//! - Fig. 13/15/16/17/18 hardware simulations (via hwsim).

pub mod inventory;

pub use inventory::*;
