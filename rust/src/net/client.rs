//! Blocking wire client for the job API — used by `sd-acc request`,
//! the integration suite and `ci.sh`'s wire lane. One TCP connection
//! per call (the server closes after every response), no dependencies
//! beyond `std::net`.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::http::{self, ChunkedReader, PrefixedReader};

/// How long connect / single-shot request-response calls may take. SSE
/// streams are exempt: they block as long as the job runs.
const CALL_TIMEOUT: Duration = Duration::from_secs(10);

/// One wire event as observed by the client: the SSE `event:` label and
/// the parsed `data:` object.
#[derive(Debug, Clone)]
pub struct WireEvent {
    pub label: String,
    pub data: Json,
}

impl WireEvent {
    pub fn is_terminal(&self) -> bool {
        matches!(self.label.as_str(), "done" | "failed" | "cancelled")
    }
}

/// Blocking client bound to one server address.
pub struct WireClient {
    addr: String,
}

impl WireClient {
    pub fn new(addr: impl Into<String>) -> WireClient {
        WireClient { addr: addr.into() }
    }

    fn connect(&self) -> Result<TcpStream> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting to {}", self.addr))?;
        let _ = stream.set_read_timeout(Some(CALL_TIMEOUT));
        Ok(stream)
    }

    fn write_request(
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<()> {
        let body = body.map(|j| j.to_string()).unwrap_or_default();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: sd-acc\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;
        Ok(())
    }

    /// One request-response call; returns `(status, parsed body)`.
    /// Empty bodies parse as `Json::Null`.
    pub fn call(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let mut stream = self.connect()?;
        Self::write_request(&mut stream, method, path, body)?;
        let resp = http::read_response(&mut stream)
            .with_context(|| format!("reading response for {method} {path}"))?;
        let json = if resp.body.is_empty() {
            Json::Null
        } else {
            let text = std::str::from_utf8(&resp.body).context("non-utf8 response body")?;
            Json::parse(text).map_err(|e| anyhow::anyhow!("bad response json: {e}"))?
        };
        Ok((resp.status, json))
    }

    fn expect_ok(&self, method: &str, path: &str, body: Option<&Json>) -> Result<Json> {
        let (status, json) = self.call(method, path, body)?;
        if !(200..300).contains(&status) {
            let msg = json.get_str("error").unwrap_or("(no error body)");
            bail!("{method} {path} -> {status}: {msg}");
        }
        Ok(json)
    }

    /// Submit a job; returns the server-assigned job id.
    pub fn submit(&self, body: &Json) -> Result<u64> {
        let json = self.expect_ok("POST", "/v1/jobs", Some(body))?;
        let id = json
            .get_str("job")
            .context("submit response missing 'job'")?;
        id.parse::<u64>()
            .with_context(|| format!("non-numeric job id '{id}'"))
    }

    /// Stream a job's events, invoking `on_event` per frame. If the
    /// callback returns `false` the connection is dropped mid-stream
    /// (the server then cancels the job). Returns all events observed.
    pub fn stream<F>(&self, id: u64, mut on_event: F) -> Result<Vec<WireEvent>>
    where
        F: FnMut(&WireEvent) -> bool,
    {
        let mut stream = self.connect()?;
        // SSE streams last as long as the job; only connect/head reads
        // keep the short timeout.
        let path = format!("/v1/jobs/{id}/events");
        Self::write_request(&mut stream, "GET", &path, None)?;
        let (resp, leftover) = http::read_response_head(&mut stream)
            .with_context(|| format!("reading SSE head for job {id}"))?;
        if resp.status != 200 {
            // Error responses are plain JSON with Content-Length.
            bail!("GET {path} -> {}", resp.status);
        }
        let _ = stream.set_read_timeout(Some(Duration::from_secs(600)));
        let mut reader = ChunkedReader::new(PrefixedReader::new(leftover, &mut stream));
        let mut events = Vec::new();
        let mut label: Option<String> = None;
        let mut data: Option<String> = None;
        for line in read_lines(&mut reader) {
            let line = line?;
            if let Some(rest) = line.strip_prefix("event: ") {
                label = Some(rest.to_string());
            } else if let Some(rest) = line.strip_prefix("data: ") {
                data = Some(rest.to_string());
            } else if line.is_empty() {
                if let (Some(l), Some(d)) = (label.take(), data.take()) {
                    let parsed = Json::parse(&d)
                        .map_err(|e| anyhow::anyhow!("bad event json: {e}"))?;
                    let ev = WireEvent { label: l, data: parsed };
                    let keep_going = on_event(&ev);
                    let terminal = ev.is_terminal();
                    events.push(ev);
                    if terminal || !keep_going {
                        return Ok(events);
                    }
                }
            }
        }
        Ok(events)
    }

    /// Submit + stream to the terminal event in one call.
    pub fn run(&self, body: &Json) -> Result<(u64, Vec<WireEvent>)> {
        let id = self.submit(body)?;
        let events = self.stream(id, |_| true)?;
        Ok((id, events))
    }

    /// Fire a job's cancel token.
    pub fn cancel(&self, id: u64) -> Result<()> {
        self.expect_ok("DELETE", &format!("/v1/jobs/{id}"), None)?;
        Ok(())
    }

    pub fn healthz(&self) -> Result<bool> {
        let json = self.expect_ok("GET", "/healthz", None)?;
        Ok(json.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn metrics(&self) -> Result<Json> {
        self.expect_ok("GET", "/metrics", None)
    }

    /// Ask the server to drain and stop accepting.
    pub fn shutdown(&self) -> Result<()> {
        self.expect_ok("POST", "/admin/shutdown", None)?;
        Ok(())
    }
}

/// Iterator over `\n`-terminated lines of a byte stream (strips a
/// trailing `\r` if present — SSE frames here use bare `\n`).
fn read_lines<R: std::io::Read>(r: &mut R) -> impl Iterator<Item = Result<String>> + '_ {
    let mut done = false;
    std::iter::from_fn(move || {
        if done {
            return None;
        }
        let mut line = Vec::new();
        let mut byte = [0u8; 1];
        loop {
            match r.read(&mut byte) {
                Ok(0) => {
                    done = true;
                    if line.is_empty() {
                        return None;
                    }
                    break;
                }
                Ok(_) => {
                    if byte[0] == b'\n' {
                        break;
                    }
                    line.push(byte[0]);
                }
                Err(e) => {
                    done = true;
                    return Some(Err(e.into()));
                }
            }
        }
        if line.last() == Some(&b'\r') {
            line.pop();
        }
        match String::from_utf8(line) {
            Ok(s) => Some(Ok(s)),
            Err(_) => Some(Err(anyhow::anyhow!("non-utf8 sse line"))),
        }
    })
}
