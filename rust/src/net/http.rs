//! Minimal HTTP/1.1 framing over blocking byte streams.
//!
//! Hand-rolled on purpose: the crate's no-new-dependencies rule means the
//! wire tier gets exactly the subset of HTTP it needs and nothing more.
//! One request per connection (`Connection: close` on every response), a
//! bounded header block, a bounded `Content-Length` body, and chunked
//! transfer encoding on the *response* side only (for SSE streams whose
//! length is unknown). Anything outside that subset is a structured
//! [`ParseError`] that maps to a deterministic 4xx — never a panic, never
//! an unbounded buffer.
//!
//! Limits (`MAX_HEADER_BYTES`, `MAX_BODY_BYTES`) are enforced *while
//! reading*, so a hostile peer cannot make the server allocate more than
//! the cap plus one read chunk.

use std::io::{self, Read, Write};

/// Cap on the request line + header block, including the blank line.
pub const MAX_HEADER_BYTES: usize = 8 * 1024;
/// Cap on a request body (`Content-Length` larger than this is refused
/// with `413` before any body byte is read).
pub const MAX_BODY_BYTES: usize = 256 * 1024;

/// Why a request (or response, client-side) could not be framed.
#[derive(Debug)]
pub enum ParseError {
    /// Header block exceeded [`MAX_HEADER_BYTES`] -> `431`.
    HeaderTooLarge,
    /// Declared `Content-Length` exceeded [`MAX_BODY_BYTES`] -> `413`.
    BodyTooLarge(usize),
    /// Anything structurally wrong with the framing -> `400`.
    Malformed(&'static str),
    /// Peer closed before a full message arrived.
    ConnectionClosed,
    /// Transport error mid-read.
    Io(io::Error),
}

impl ParseError {
    /// The response status a server should send for this parse failure
    /// (0 when the connection is unusable and no response can be sent).
    pub fn status(&self) -> u16 {
        match self {
            ParseError::HeaderTooLarge => 431,
            ParseError::BodyTooLarge(_) => 413,
            ParseError::Malformed(_) => 400,
            ParseError::ConnectionClosed | ParseError::Io(_) => 0,
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::HeaderTooLarge => write!(f, "header block over {MAX_HEADER_BYTES} bytes"),
            ParseError::BodyTooLarge(n) => {
                write!(f, "body of {n} bytes over the {MAX_BODY_BYTES}-byte cap")
            }
            ParseError::Malformed(m) => write!(f, "malformed message: {m}"),
            ParseError::ConnectionClosed => f.write_str("connection closed mid-message"),
            ParseError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// A parsed request. Headers keep their wire order; lookup is
/// case-insensitive via [`Request::header`].
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A parsed response (client side).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

// ---------------------------------------------------------------- reading

/// Read until the `\r\n\r\n` header terminator, bounded by
/// [`MAX_HEADER_BYTES`]. Returns `(head, leftover)` where `leftover` is
/// whatever body bytes arrived in the same reads.
fn read_head<R: Read>(r: &mut R) -> Result<(Vec<u8>, Vec<u8>), ParseError> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    loop {
        if let Some(end) = find_terminator(&buf) {
            let leftover = buf.split_off(end + 4);
            buf.truncate(end);
            return Ok((buf, leftover));
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(ParseError::HeaderTooLarge);
        }
        let n = r.read(&mut chunk).map_err(ParseError::Io)?;
        if n == 0 {
            return if buf.is_empty() {
                Err(ParseError::ConnectionClosed)
            } else {
                Err(ParseError::Malformed("eof inside header block"))
            };
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn find_terminator(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_headers(lines: &[&str]) -> Result<Vec<(String, String)>, ParseError> {
    let mut out = Vec::with_capacity(lines.len());
    for line in lines {
        let (k, v) = line
            .split_once(':')
            .ok_or(ParseError::Malformed("header line without ':'"))?;
        if k.trim().is_empty() {
            return Err(ParseError::Malformed("empty header name"));
        }
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

fn read_exact_n<R: Read>(r: &mut R, mut leftover: Vec<u8>, n: usize) -> Result<Vec<u8>, ParseError> {
    if leftover.len() >= n {
        leftover.truncate(n);
        return Ok(leftover);
    }
    let mut body = leftover;
    body.reserve(n - body.len());
    let mut chunk = [0u8; 4096];
    while body.len() < n {
        let want = (n - body.len()).min(chunk.len());
        let got = r.read(&mut chunk[..want]).map_err(ParseError::Io)?;
        if got == 0 {
            return Err(ParseError::Malformed("eof inside declared body"));
        }
        body.extend_from_slice(&chunk[..got]);
    }
    Ok(body)
}

/// Parse one request from the stream, enforcing both byte caps.
pub fn read_request<R: Read>(r: &mut R) -> Result<Request, ParseError> {
    let (head, leftover) = read_head(r)?;
    let head = String::from_utf8(head).map_err(|_| ParseError::Malformed("non-utf8 header"))?;
    let mut lines = head.split("\r\n");
    let start = lines.next().ok_or(ParseError::Malformed("empty request"))?;
    let mut parts = start.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed("bad request line"));
    }
    let headers = parse_headers(&lines.collect::<Vec<_>>())?;
    let content_length = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.parse::<usize>().map_err(|_| ParseError::Malformed("bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        return Err(ParseError::BodyTooLarge(content_length));
    }
    let body = read_exact_n(r, leftover, content_length)?;
    Ok(Request { method, path, headers, body })
}

/// Parse one response head; the body is handled by the caller (it may be
/// `Content-Length`-delimited or chunked). Returns the response with an
/// *empty* body plus the leftover bytes already read past the head.
pub fn read_response_head<R: Read>(r: &mut R) -> Result<(Response, Vec<u8>), ParseError> {
    let (head, leftover) = read_head(r)?;
    let head = String::from_utf8(head).map_err(|_| ParseError::Malformed("non-utf8 header"))?;
    let mut lines = head.split("\r\n");
    let start = lines.next().ok_or(ParseError::Malformed("empty response"))?;
    // "HTTP/1.1 200 OK"
    let mut parts = start.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or(ParseError::Malformed("bad status line"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed("bad http version"));
    }
    let headers = parse_headers(&lines.collect::<Vec<_>>())?;
    Ok((Response { status, headers, body: Vec::new() }, leftover))
}

/// Read a full (non-streaming) response: head, then either a
/// `Content-Length` body or a complete chunked body.
pub fn read_response<R: Read>(r: &mut R) -> Result<Response, ParseError> {
    let (mut resp, leftover) = read_response_head(r)?;
    let chunked = resp
        .header("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false);
    if chunked {
        let mut cr = ChunkedReader::new(PrefixedReader::new(leftover, r));
        cr.read_to_end(&mut resp.body).map_err(ParseError::Io)?;
    } else {
        let n = resp
            .header("content-length")
            .map(|v| v.parse::<usize>().map_err(|_| ParseError::Malformed("bad content-length")))
            .transpose()?
            .unwrap_or(0);
        if n > MAX_BODY_BYTES {
            return Err(ParseError::BodyTooLarge(n));
        }
        resp.body = read_exact_n(r, leftover, n)?;
    }
    Ok(resp)
}

// ------------------------------------------------------------- composing

pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete single-shot response (`Connection: close`).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_reason(status),
        content_type,
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Write the head of a chunked SSE response; follow with a
/// [`ChunkedWriter`] over the same stream.
pub fn write_sse_head<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

/// Chunked transfer encoder. Every `write_chunk` flushes, so SSE frames
/// reach the peer promptly; `finish` writes the zero-length terminator.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn new(w: W) -> Self {
        ChunkedWriter { w }
    }

    pub fn write_chunk(&mut self, bytes: &[u8]) -> io::Result<()> {
        if bytes.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", bytes.len())?;
        self.w.write_all(bytes)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(&mut self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

// ------------------------------------------------------ streaming readers

/// `Read` over a prefix buffer followed by an inner reader — used to
/// hand bytes already pulled past a header block back to body decoding.
pub struct PrefixedReader<'a, R: Read> {
    prefix: Vec<u8>,
    pos: usize,
    inner: &'a mut R,
}

impl<'a, R: Read> PrefixedReader<'a, R> {
    pub fn new(prefix: Vec<u8>, inner: &'a mut R) -> Self {
        PrefixedReader { prefix, pos: 0, inner }
    }
}

impl<R: Read> Read for PrefixedReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.pos < self.prefix.len() {
            let n = (self.prefix.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.prefix[self.pos..self.pos + n]);
            self.pos += n;
            return Ok(n);
        }
        self.inner.read(buf)
    }
}

/// Chunked transfer decoder: presents the de-chunked byte stream as a
/// plain `Read`; returns EOF at the zero-length terminator chunk.
pub struct ChunkedReader<R: Read> {
    inner: R,
    /// Bytes left in the current chunk; `None` means "read next size line".
    remaining: Option<usize>,
    done: bool,
}

impl<R: Read> ChunkedReader<R> {
    pub fn new(inner: R) -> Self {
        ChunkedReader { inner, remaining: None, done: false }
    }

    fn read_size_line(&mut self) -> io::Result<usize> {
        // "<hex>\r\n" — read byte-by-byte; size lines are tiny.
        let mut line = Vec::with_capacity(8);
        let mut byte = [0u8; 1];
        loop {
            if self.inner.read(&mut byte)? == 0 {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in chunk size"));
            }
            if byte[0] == b'\n' {
                break;
            }
            if byte[0] != b'\r' {
                line.push(byte[0]);
            }
            if line.len() > 16 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "chunk size line too long"));
            }
        }
        let s = std::str::from_utf8(&line)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-utf8 chunk size"))?;
        usize::from_str_radix(s.trim(), 16)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad chunk size"))
    }

    fn skip_crlf(&mut self) -> io::Result<()> {
        let mut two = [0u8; 2];
        let mut got = 0;
        while got < 2 {
            let n = self.inner.read(&mut two[got..])?;
            if n == 0 {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof after chunk"));
            }
            got += n;
        }
        Ok(())
    }
}

impl<R: Read> Read for ChunkedReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.done || buf.is_empty() {
            return Ok(0);
        }
        if self.remaining.is_none() {
            let size = self.read_size_line()?;
            if size == 0 {
                // Consume the trailing CRLF after the terminator if present;
                // tolerate eof (peers that close right after "0\r\n\r\n").
                let _ = self.skip_crlf();
                self.done = true;
                return Ok(0);
            }
            self.remaining = Some(size);
        }
        let rem = self.remaining.unwrap();
        let want = rem.min(buf.len());
        let n = self.inner.read(&mut buf[..want])?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof inside chunk"));
        }
        if rem - n == 0 {
            self.remaining = None;
            self.skip_crlf()?;
        } else {
            self.remaining = Some(rem - n);
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_simple_request_with_body() {
        let raw = b"POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let req = read_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/jobs");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn request_without_body_and_no_content_length() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn oversized_declared_body_is_413_before_reading_it() {
        let raw = format!(
            "POST /v1/jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let err = read_request(&mut raw.as_bytes()).unwrap_err();
        assert!(matches!(err, ParseError::BodyTooLarge(_)), "{err:?}");
        assert_eq!(err.status(), 413);
    }

    #[test]
    fn oversized_header_block_is_431() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        while raw.len() <= MAX_HEADER_BYTES + 16 {
            raw.push_str("X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        // No terminator: the cap trips while still reading headers.
        let err = read_request(&mut raw.as_bytes()).unwrap_err();
        assert!(matches!(err, ParseError::HeaderTooLarge), "{err:?}");
        assert_eq!(err.status(), 431);
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for raw in [
            &b"NOT-HTTP\r\n\r\n"[..],
            &b"GET /x SPDY/9\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nbroken-header-line\r\n\r\n"[..],
        ] {
            let err = read_request(&mut &raw[..]).unwrap_err();
            assert!(matches!(err, ParseError::Malformed(_)), "{err:?}");
            assert_eq!(err.status(), 400);
        }
    }

    #[test]
    fn truncated_body_reports_malformed_not_hang() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        let err = read_request(&mut &raw[..]).unwrap_err();
        assert!(matches!(err, ParseError::Malformed(_)), "{err:?}");
    }

    #[test]
    fn chunked_roundtrip_through_writer_and_reader() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::new(&mut wire);
            cw.write_chunk(b"event: step\n").unwrap();
            cw.write_chunk(b"data: {}\n\n").unwrap();
            cw.write_chunk(b"").unwrap(); // no-op, must not terminate
            cw.write_chunk(&vec![b'x'; 300]).unwrap(); // multi-hex-digit size
            cw.finish().unwrap();
        }
        let mut out = Vec::new();
        ChunkedReader::new(&wire[..]).read_to_end(&mut out).unwrap();
        let mut expect = b"event: step\ndata: {}\n\n".to_vec();
        expect.extend(std::iter::repeat(b'x').take(300));
        assert_eq!(out, expect);
    }

    #[test]
    fn response_roundtrip_content_length_and_chunked() {
        let mut wire = Vec::new();
        write_response(&mut wire, 202, "application/json", b"{\"ok\":true}").unwrap();
        let resp = read_response(&mut &wire[..]).unwrap();
        assert_eq!(resp.status, 202);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.body, b"{\"ok\":true}");

        let mut wire = Vec::new();
        write_sse_head(&mut wire).unwrap();
        ChunkedWriter::new(&mut wire).write_chunk(b"event: done\n\n").unwrap();
        ChunkedWriter::new(&mut wire).finish().unwrap();
        let resp = read_response(&mut &wire[..]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"event: done\n\n");
    }

    #[test]
    fn prefixed_reader_serves_prefix_then_inner() {
        let mut inner: &[u8] = b"world";
        let mut pr = PrefixedReader::new(b"hello ".to_vec(), &mut inner);
        let mut out = String::new();
        pr.read_to_string(&mut out).unwrap();
        assert_eq!(out, "hello world");
    }
}
