//! # Wire transport: hand-rolled HTTP/1.1 + SSE job streaming
//!
//! The network tier that turns the in-process job API (`server::Client`)
//! into a served endpoint — built entirely on `std::net` and the
//! crate's own [`ThreadPool`](crate::util::threadpool::ThreadPool),
//! honouring the no-new-dependencies rule. Three layers:
//!
//! - [`http`] — bounded HTTP/1.1 framing: request parsing with hard
//!   header (8 KiB -> `431`) and body (256 KiB -> `413`) caps, clean
//!   `400` on malformed framing, response + chunked-transfer writers
//!   and readers. One request per connection; every response closes.
//! - [`proto`] — the JSON wire protocol: `GenRequest`/`SubmitOptions`
//!   to/from wire JSON (validation delegates to `GenRequest::builder`,
//!   so wire and in-process admission are byte-identical), `JobEvent`
//!   SSE frames (`event: <label>\ndata: <json>\n\n`, same label
//!   vocabulary as `JobEvent::label`), and the structured-error map
//!   (`InvalidRequest` 400, `QueueFull` 429, `Cancelled` 499,
//!   `DeadlineExceeded` 504, `Runtime` 500).
//! - [`server`] / [`client`] — the accept loop + job registry
//!   ([`WireServer`]) and the blocking client ([`WireClient`]) that
//!   `sd-acc request`, the integration suite and `ci.sh` drive.
//!
//! The streamed event sequence for a job is the in-process
//! `JobHandle` sequence, one SSE frame per event — same labels, same
//! order, exactly one terminal (`done` / `failed` / `cancelled`) per
//! job; `tests/integration_net.rs` pins the equivalence. A client that
//! disconnects mid-stream cancels its job (the registry entry and the
//! running work are both reclaimed). Multi-process serving shares one
//! on-disk cache through the store's advisory lock protocol — see
//! `cache::store`'s "Multi-process sharing" section; the second
//! process's identical request is a cross-process `cache-hit`.

pub mod client;
pub mod http;
pub mod proto;
pub mod server;

pub use client::{WireClient, WireEvent};
pub use server::WireServer;
