//! Wire protocol: JSON forms for requests and job events, and the
//! `SdError` -> HTTP status mapping.
//!
//! ## Request body (`POST /v1/jobs`)
//!
//! ```json
//! {
//!   "prompt": "a red fox",        // required
//!   "seed": 42,                    // required
//!   "steps": 20,                   // optional (GenRequest default)
//!   "guidance": 7.5,               // optional
//!   "sampler": "pndm",             // optional: "ddim" | "pndm"
//!   "plan": "pas:5",               // optional: "full" | "auto" | "pas:<t_sparse>"
//!   "quant": "w8a8",               // optional QuantScheme label
//!   "policy": "stability",         // optional PolicySpec label (default "pas")
//!   "priority": "normal",          // optional: "high" | "normal" | "low"
//!   "deadline_ms": 2000,           // optional
//!   "degradable": true             // optional (default true, as SubmitOptions)
//! }
//! ```
//!
//! Validation reuses `GenRequest::builder` exactly, so the wire tier can
//! never admit a request the in-process API would reject — and the error
//! strings match byte for byte.
//!
//! ## Event frames (`GET /v1/jobs/<id>/events`, SSE)
//!
//! Each [`JobEvent`] becomes one SSE frame `event: <label>\ndata:
//! <json>\n\n` whose data object always repeats `"label"`. The `done`
//! frame carries a *summary* of the result — `mac_reduction`,
//! `total_ms`, `steps`, `latent_len` and an FNV-1a checksum of the
//! latent bytes (`latent_fnv`, hex string) — not the latent tensor
//! itself: wire consumers verify determinism by checksum, they do not
//! re-decode latents. Job ids cross the wire as decimal *strings*
//! (`compose_job_id` values can exceed 2^53, the exact-integer range of
//! JSON numbers).
//!
//! ## Error mapping
//!
//! | `SdError`          | status |
//! |--------------------|--------|
//! | `InvalidRequest`   | 400    |
//! | `QueueFull`        | 429    |
//! | `Cancelled`        | 499    |
//! | `DeadlineExceeded` | 504    |
//! | `Runtime`          | 500    |

use std::time::Duration;

use crate::coordinator::{GenRequest, GenResult, SamplerKind, SdError};
use crate::pas::plan::{PasConfig, SamplingPlan};
use crate::policy::PolicySpec;
use crate::quant::QuantScheme;
use crate::server::{JobEvent, Priority, SubmitOptions};
use crate::util::json::Json;

/// HTTP status for a structured serving error.
pub fn error_status(e: &SdError) -> u16 {
    match e {
        SdError::InvalidRequest(_) => 400,
        SdError::QueueFull => 429,
        SdError::Cancelled => 499,
        SdError::DeadlineExceeded => 504,
        SdError::Runtime(_) => 500,
    }
}

/// JSON error body: `{"error": "<display>", "code": <status>}`.
pub fn error_body(e: &SdError) -> Json {
    Json::obj(vec![
        ("error", Json::Str(e.to_string())),
        ("code", Json::num(error_status(e) as f64)),
    ])
}

/// FNV-1a over a byte slice — same constants as the cache key hasher.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Checksum of a result's latent: FNV-1a over the little-endian f32
/// bits, so it is bit-exact across processes (NaN payloads included).
pub fn latent_checksum(result: &GenResult) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in result.latent.data() {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

// ---------------------------------------------------------------- request

/// Parse the `POST /v1/jobs` body into a validated request + options.
pub fn request_from_json(j: &Json) -> Result<(GenRequest, SubmitOptions), SdError> {
    let obj = j
        .as_obj()
        .ok_or_else(|| SdError::invalid("request body must be a JSON object"))?;
    let get = |name: &str| obj.iter().find(|(k, _)| k == name).map(|(_, v)| v);

    let prompt = get("prompt")
        .and_then(|v| v.as_str())
        .ok_or_else(|| SdError::invalid("missing required string field 'prompt'"))?;
    let seed = get("seed")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| SdError::invalid("missing required numeric field 'seed'"))? as u64;

    let mut b = GenRequest::builder(prompt, seed);
    if let Some(v) = get("steps") {
        let steps = v
            .as_usize()
            .ok_or_else(|| SdError::invalid("'steps' must be a non-negative integer"))?;
        b = b.steps(steps);
    }
    if let Some(v) = get("guidance") {
        let g = v
            .as_f64()
            .ok_or_else(|| SdError::invalid("'guidance' must be a number"))?;
        b = b.guidance(g as f32);
    }
    if let Some(v) = get("sampler") {
        let s = v
            .as_str()
            .ok_or_else(|| SdError::invalid("'sampler' must be a string"))?;
        b = b.sampler(s.parse::<SamplerKind>()?);
    }
    if let Some(v) = get("plan") {
        let s = v
            .as_str()
            .ok_or_else(|| SdError::invalid("'plan' must be a string"))?;
        b = b.plan(plan_from_str(s)?);
    }
    if let Some(v) = get("quant") {
        if !matches!(v, Json::Null) {
            let s = v
                .as_str()
                .ok_or_else(|| SdError::invalid("'quant' must be a string"))?;
            let scheme = QuantScheme::parse(s)
                .ok_or_else(|| SdError::invalid(format!("unknown quant scheme '{s}'")))?;
            b = b.quant(scheme);
        }
    }
    if let Some(v) = get("policy") {
        if !matches!(v, Json::Null) {
            let s = v
                .as_str()
                .ok_or_else(|| SdError::invalid("'policy' must be a string"))?;
            let spec = PolicySpec::parse(s)
                .ok_or_else(|| SdError::invalid(format!("unknown policy '{s}'")))?;
            b = b.policy(spec);
        }
    }
    let req = b.build()?;

    let mut opts = SubmitOptions::default();
    if let Some(v) = get("priority") {
        let s = v
            .as_str()
            .ok_or_else(|| SdError::invalid("'priority' must be a string"))?;
        opts.priority = priority_from_str(s)?;
    }
    if let Some(v) = get("deadline_ms") {
        let ms = v
            .as_f64()
            .ok_or_else(|| SdError::invalid("'deadline_ms' must be a number"))?;
        if ms < 0.0 || !ms.is_finite() {
            return Err(SdError::invalid("'deadline_ms' must be a finite non-negative number"));
        }
        opts.deadline = Some(Duration::from_millis(ms as u64));
    }
    if let Some(v) = get("degradable") {
        opts.degradable = v
            .as_bool()
            .ok_or_else(|| SdError::invalid("'degradable' must be a boolean"))?;
    }
    Ok((req, opts))
}

/// Compose the wire body for a request + options (client side).
pub fn request_to_json(req: &GenRequest, opts: &SubmitOptions) -> Json {
    let mut fields = vec![
        ("prompt", Json::str(&req.prompt)),
        ("seed", Json::num(req.seed as f64)),
        ("steps", Json::num(req.steps as f64)),
        ("guidance", Json::num(req.guidance as f64)),
        ("sampler", Json::str(req.sampler.as_str())),
        ("plan", Json::Str(plan_to_string(&req.plan))),
    ];
    if let Some(q) = &req.quant {
        fields.push(("quant", Json::Str(q.label())));
    }
    // Emitted only when non-default, so legacy wire bodies stay
    // byte-identical for policy-less requests.
    if req.policy != PolicySpec::default() {
        fields.push(("policy", Json::Str(req.policy.label())));
    }
    fields.push(("priority", Json::str(priority_str(opts.priority))));
    if let Some(d) = opts.deadline {
        fields.push(("deadline_ms", Json::num(d.as_millis() as f64)));
    }
    fields.push(("degradable", Json::Bool(opts.degradable)));
    Json::obj(fields)
}

fn plan_from_str(s: &str) -> Result<SamplingPlan, SdError> {
    if s == "full" {
        return Ok(SamplingPlan::Full);
    }
    if s == "auto" {
        return Ok(SamplingPlan::Auto);
    }
    if let Some(t) = s.strip_prefix("pas:") {
        let t_sparse = t
            .parse::<usize>()
            .map_err(|_| SdError::invalid(format!("bad plan '{s}': expected pas:<t_sparse>")))?;
        return Ok(SamplingPlan::Pas(PasConfig::pas25(t_sparse)));
    }
    Err(SdError::invalid(format!(
        "unknown plan '{s}': expected full | auto | pas:<t_sparse>"
    )))
}

fn plan_to_string(plan: &SamplingPlan) -> String {
    match plan {
        SamplingPlan::Full => "full".to_string(),
        SamplingPlan::Auto => "auto".to_string(),
        SamplingPlan::Pas(cfg) => format!("pas:{}", cfg.t_sparse),
    }
}

fn priority_from_str(s: &str) -> Result<Priority, SdError> {
    match s {
        "high" => Ok(Priority::High),
        "normal" => Ok(Priority::Normal),
        "low" => Ok(Priority::Low),
        other => Err(SdError::invalid(format!(
            "unknown priority '{other}': expected high | normal | low"
        ))),
    }
}

fn priority_str(p: Priority) -> &'static str {
    match p {
        Priority::High => "high",
        Priority::Normal => "normal",
        Priority::Low => "low",
    }
}

// ----------------------------------------------------------------- events

/// The SSE `data:` object for one job event. The label field always
/// matches the SSE `event:` line (and `JobEvent::label`).
pub fn event_to_json(ev: &JobEvent) -> Json {
    let label = ev.label();
    match ev {
        JobEvent::Queued | JobEvent::CacheHit | JobEvent::Cancelled => {
            Json::obj(vec![("label", Json::str(label))])
        }
        JobEvent::Scheduled { batch_size } => Json::obj(vec![
            ("label", Json::str(label)),
            ("batch", Json::num(*batch_size as f64)),
        ]),
        JobEvent::Step { i, action, ms } => Json::obj(vec![
            ("label", Json::str(label)),
            ("i", Json::num(*i as f64)),
            ("action", Json::str(action.label())),
            ("ms", Json::num(*ms)),
        ]),
        JobEvent::Done(result) => Json::obj(vec![
            ("label", Json::str(label)),
            ("mac_reduction", Json::num(result.stats.mac_reduction)),
            ("total_ms", Json::num(result.stats.total_ms)),
            ("steps", Json::num(result.stats.actions.len() as f64)),
            ("latent_len", Json::num(result.latent.len() as f64)),
            ("latent_fnv", Json::Str(format!("{:016x}", latent_checksum(result)))),
        ]),
        JobEvent::Failed(e) => Json::obj(vec![
            ("label", Json::str(label)),
            ("error", Json::Str(e.to_string())),
            ("code", Json::num(error_status(e) as f64)),
        ]),
    }
}

/// One SSE frame for an event: `event: <label>\ndata: <json>\n\n`.
pub fn event_frame(ev: &JobEvent) -> String {
    format!("event: {}\ndata: {}\n\n", ev.label(), event_to_json(ev).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenStats;
    use crate::pas::plan::StepAction;
    use crate::runtime::Tensor;

    fn wire(prompt: &str) -> Json {
        Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("seed", Json::num(7.0)),
            ("steps", Json::num(8.0)),
            ("sampler", Json::str("ddim")),
            ("plan", Json::str("pas:4")),
            ("policy", Json::str("stability:90")),
            ("priority", Json::str("high")),
            ("deadline_ms", Json::num(1500.0)),
            ("degradable", Json::Bool(false)),
        ])
    }

    #[test]
    fn request_roundtrips_through_wire_json() {
        let (req, opts) = request_from_json(&wire("fox")).unwrap();
        assert_eq!(req.prompt, "fox");
        assert_eq!(req.seed, 7);
        assert_eq!(req.steps, 8);
        assert_eq!(req.sampler, SamplerKind::Ddim);
        assert!(matches!(req.plan, SamplingPlan::Pas(ref c) if c.t_sparse == 4));
        assert_eq!(req.policy, PolicySpec::Stability { threshold_milli: 90 });
        assert_eq!(opts.priority, Priority::High);
        assert_eq!(opts.deadline, Some(Duration::from_millis(1500)));
        assert!(!opts.degradable);

        // Compose -> parse is the identity on every wire-visible field.
        let re = request_to_json(&req, &opts);
        let (req2, opts2) = request_from_json(&re).unwrap();
        assert_eq!(req.prompt, req2.prompt);
        assert_eq!(req.seed, req2.seed);
        assert_eq!(req.steps, req2.steps);
        assert_eq!(req.guidance.to_bits(), req2.guidance.to_bits());
        assert_eq!(req.sampler, req2.sampler);
        assert_eq!(req.plan, req2.plan);
        assert_eq!(req.quant, req2.quant);
        assert_eq!(req.policy, req2.policy);
        assert_eq!(opts.priority, opts2.priority);
        assert_eq!(opts.deadline, opts2.deadline);
        assert_eq!(opts.degradable, opts2.degradable);
    }

    #[test]
    fn default_policy_is_omitted_from_the_wire_body() {
        // Legacy clients never sent a policy field; legacy bodies for
        // default-policy requests must stay byte-identical.
        let req = GenRequest::new("fox", 7);
        let body = request_to_json(&req, &SubmitOptions::default());
        assert!(body.get_str("policy").is_none(), "{body:?}");
        let (req2, _) = request_from_json(&body).unwrap();
        assert_eq!(req2.policy, PolicySpec::Pas);
        // And an explicit null parses as the default, like quant.
        let with_null = Json::obj(vec![
            ("prompt", Json::str("fox")),
            ("seed", Json::num(7.0)),
            ("policy", Json::Null),
        ]);
        let (req3, _) = request_from_json(&with_null).unwrap();
        assert_eq!(req3.policy, PolicySpec::Pas);
    }

    #[test]
    fn invalid_wire_requests_map_to_invalid_request() {
        let cases: Vec<Json> = vec![
            Json::str("not an object"),
            Json::obj(vec![("seed", Json::num(1.0))]), // no prompt
            Json::obj(vec![("prompt", Json::str("x"))]), // no seed
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("sampler", Json::str("euler")),
            ]),
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("plan", Json::str("pas")),
            ]),
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("policy", Json::str("euler")),
            ]),
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("policy", Json::str("block-cache:0")),
            ]),
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("priority", Json::str("urgent")),
            ]),
            Json::obj(vec![
                ("prompt", Json::str("x")),
                ("seed", Json::num(1.0)),
                ("steps", Json::num(0.0)), // builder validation refuses
            ]),
        ];
        for c in cases {
            let e = request_from_json(&c).unwrap_err();
            assert!(matches!(e, SdError::InvalidRequest(_)), "{c:?} -> {e}");
            assert_eq!(error_status(&e), 400);
        }
    }

    #[test]
    fn error_statuses_cover_every_variant() {
        assert_eq!(error_status(&SdError::invalid("x")), 400);
        assert_eq!(error_status(&SdError::QueueFull), 429);
        assert_eq!(error_status(&SdError::Cancelled), 499);
        assert_eq!(error_status(&SdError::DeadlineExceeded), 504);
        assert_eq!(error_status(&SdError::Runtime("boom".into())), 500);
    }

    #[test]
    fn event_frames_carry_label_and_done_summary() {
        let result = GenResult {
            latent: Tensor::new(vec![2, 2], vec![0.25, -1.5, 3.75, 0.125]).unwrap(),
            stats: GenStats {
                actions: vec![StepAction::Full, StepAction::Partial(2)],
                step_ms: vec![5.0, 2.5],
                mac_reduction: 1.8,
                total_ms: 7.5,
            },
        };
        let frame = event_frame(&JobEvent::Done(result.clone()));
        assert!(frame.starts_with("event: done\ndata: "), "{frame}");
        assert!(frame.ends_with("\n\n"), "{frame:?}");
        let data = Json::parse(frame["event: done\ndata: ".len()..].trim()).unwrap();
        assert_eq!(data.get_str("label").unwrap(), "done");
        assert_eq!(data.get_usize("latent_len").unwrap(), 4);
        assert_eq!(data.get_usize("steps").unwrap(), 2);
        let fnv = data.get_str("latent_fnv").unwrap();
        assert_eq!(fnv.len(), 16);
        assert_eq!(fnv, format!("{:016x}", latent_checksum(&result)));

        let frame = event_frame(&JobEvent::Failed(SdError::QueueFull));
        let data = Json::parse(frame["event: failed\ndata: ".len()..].trim()).unwrap();
        assert_eq!(data.get_usize("code").unwrap(), 429);

        for ev in [JobEvent::Queued, JobEvent::CacheHit, JobEvent::Cancelled] {
            let data = event_to_json(&ev);
            assert_eq!(data.get_str("label").unwrap(), ev.label());
        }
        let data = event_to_json(&JobEvent::Step {
            i: 3,
            action: StepAction::Partial(2),
            ms: 1.25,
        });
        assert_eq!(data.get_str("action").unwrap(), "partial");
        assert_eq!(data.get_usize("i").unwrap(), 3);
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        let a = GenResult {
            latent: Tensor::new(vec![2], vec![1.0, 2.0]).unwrap(),
            stats: GenStats {
                actions: vec![],
                step_ms: vec![],
                mac_reduction: 1.0,
                total_ms: 0.0,
            },
        };
        let mut b = a.clone();
        b.latent = Tensor::new(vec![2], vec![1.0, 2.5]).unwrap();
        assert_ne!(latent_checksum(&a), latent_checksum(&b));
    }
}
