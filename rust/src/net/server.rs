//! The wire server: a blocking `TcpListener` accept loop fanning
//! connections out over the crate's own [`ThreadPool`], serving the
//! job API over hand-rolled HTTP/1.1 (see [`super::http`]).
//!
//! ## Routes
//!
//! | method + path             | behaviour                                          |
//! |---------------------------|----------------------------------------------------|
//! | `POST /v1/jobs`           | submit; `202 {"job": "<id>"}` or mapped 4xx/5xx    |
//! | `GET /v1/jobs/<id>/events`| SSE stream of the job's events (chunked)           |
//! | `DELETE /v1/jobs/<id>`    | fire the job's cancel token; `200 {"ok":true}`     |
//! | `GET /healthz`            | `200 {"ok":true}`                                  |
//! | `GET /metrics`            | metrics JSON + `"wire"` section (open job count)   |
//! | `POST /admin/shutdown`    | `200`, then stop accepting and drain               |
//!
//! ## Job registry and the no-leak rule
//!
//! `POST /v1/jobs` parks the submitted [`JobHandle`]'s receiver and
//! cancel token in a registry keyed by job id. The event receiver is
//! **take-once**: the first `GET .../events` claims it (a second
//! concurrent streamer gets `409`), streams to the terminal event, and
//! deregisters the job. If the client disconnects mid-stream, the
//! handler fires the job's cancel token, drains the receiver to its
//! terminal event (the standing exactly-one-terminal invariant holds
//! server-side regardless of who is listening) and deregisters — a
//! vanished client can never leak a registry entry or a running job.
//! `DELETE` fires the cancel token but leaves deregistration to the
//! streamer so the cancelled terminal is still observable.
//!
//! ## Graceful drain
//!
//! `POST /admin/shutdown` (or [`WireServer::shutdown`]) raises the stop
//! flag and nudges the accept loop with a loopback connection. The
//! accept loop exits, the connection pool drops — joining every
//! in-flight handler, so open SSE streams finish their jobs — and then
//! any still-registered jobs are cancelled and drained. The job
//! `Server` underneath is owned by the caller and shut down after.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::SdError;
use crate::server::metrics::Metrics;
use crate::server::{CancelToken, Client, JobEvent};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::http::{self, ChunkedWriter, Request};
use super::proto;

/// How long a connection may take to deliver its request head + body.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

struct JobEntry {
    /// Take-once: the first streamer claims it; `None` + registered
    /// means "someone is streaming right now".
    events: Option<Receiver<JobEvent>>,
    cancel: CancelToken,
}

type Registry = Mutex<HashMap<u64, JobEntry>>;

struct WireCtx {
    client: Client,
    metrics: Arc<Metrics>,
    jobs: Registry,
    stop: AtomicBool,
}

/// Handle to a running wire server. Dropping it does *not* stop the
/// server; call [`WireServer::shutdown`] or let `POST /admin/shutdown`
/// end [`WireServer::wait`].
pub struct WireServer {
    addr: SocketAddr,
    ctx: Arc<WireCtx>,
    accept: Option<thread::JoinHandle<()>>,
}

impl WireServer {
    /// Bind `listen` (e.g. `127.0.0.1:0`) and start serving the given
    /// job client. `threads` bounds concurrent connections (SSE streams
    /// hold a thread for their whole job).
    pub fn start(
        client: Client,
        metrics: Arc<Metrics>,
        listen: &str,
        threads: usize,
    ) -> Result<WireServer> {
        let addr = listen
            .to_socket_addrs()
            .with_context(|| format!("bad listen address '{listen}'"))?
            .next()
            .with_context(|| format!("listen address '{listen}' resolved to nothing"))?;
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding wire listener on {addr}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        let ctx = Arc::new(WireCtx {
            client,
            metrics,
            jobs: Mutex::new(HashMap::new()),
            stop: AtomicBool::new(false),
        });

        let accept_ctx = Arc::clone(&ctx);
        let accept = thread::Builder::new()
            .name("sd-acc-wire-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(threads.max(1));
                for stream in listener.incoming() {
                    if accept_ctx.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let ctx = Arc::clone(&accept_ctx);
                    pool.execute(move || handle_connection(stream, &ctx));
                }
                // Pool drop joins every in-flight handler (open SSE
                // streams run their jobs to the terminal event).
                drop(pool);
                drain_registry(&accept_ctx);
            })
            .context("spawn wire accept thread")?;

        Ok(WireServer { addr, ctx, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of jobs currently registered (submitted, terminal not yet
    /// streamed to a client). Exposed in `/metrics` as `wire.jobs_open`.
    pub fn jobs_open(&self) -> usize {
        self.ctx.jobs.lock().unwrap().len()
    }

    /// Block until the accept loop exits (i.e. until
    /// `POST /admin/shutdown` or [`WireServer::shutdown`] from another
    /// thread).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, drain in-flight connections, join.
    pub fn shutdown(mut self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept() so it observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Cancel and drain every still-registered job (shutdown path: clients
/// that submitted but never streamed must not wedge the job server).
fn drain_registry(ctx: &WireCtx) {
    let entries: Vec<JobEntry> = {
        let mut jobs = ctx.jobs.lock().unwrap();
        jobs.drain().map(|(_, e)| e).collect()
    };
    for entry in entries {
        entry.cancel.cancel();
        if let Some(rx) = entry.events {
            while let Ok(ev) = rx.recv() {
                if ev.is_terminal() {
                    break;
                }
            }
        }
    }
}

// ------------------------------------------------------------ connection

fn handle_connection(mut stream: TcpStream, ctx: &WireCtx) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let req = match http::read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let status = e.status();
            if status != 0 {
                respond_error_status(&mut stream, status, &e.to_string());
            }
            return;
        }
    };
    route(&mut stream, &req, ctx);
}

fn respond_json(stream: &mut TcpStream, status: u16, body: &Json) {
    let _ = http::write_response(stream, status, "application/json", body.to_string().as_bytes());
}

fn respond_error_status(stream: &mut TcpStream, status: u16, msg: &str) {
    let body = Json::obj(vec![
        ("error", Json::str(msg)),
        ("code", Json::num(status as f64)),
    ]);
    respond_json(stream, status, &body);
}

fn respond_sd_error(stream: &mut TcpStream, e: &SdError) {
    respond_json(stream, proto::error_status(e), &proto::error_body(e));
}

fn route(stream: &mut TcpStream, req: &Request, ctx: &WireCtx) {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "jobs"]) => post_job(stream, req, ctx),
        ("GET", ["v1", "jobs", id, "events"]) => match id.parse::<u64>() {
            Ok(id) => stream_events(stream, id, ctx),
            Err(_) => respond_error_status(stream, 404, "no such job"),
        },
        ("DELETE", ["v1", "jobs", id]) => match id.parse::<u64>() {
            Ok(id) => delete_job(stream, id, ctx),
            Err(_) => respond_error_status(stream, 404, "no such job"),
        },
        ("GET", ["healthz"]) => {
            respond_json(stream, 200, &Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", ["metrics"]) => get_metrics(stream, ctx),
        ("POST", ["admin", "shutdown"]) => {
            respond_json(stream, 200, &Json::obj(vec![("ok", Json::Bool(true))]));
            ctx.stop.store(true, Ordering::SeqCst);
            // Nudge accept() from here: the handler knows the listener
            // is on our own local peer address's IP + server port.
            if let Ok(local) = stream.local_addr() {
                let _ = TcpStream::connect(local);
            }
        }
        // Known paths with the wrong method get 405, the rest 404.
        (_, ["v1", "jobs"]) | (_, ["healthz"]) | (_, ["metrics"]) | (_, ["admin", "shutdown"]) => {
            respond_error_status(stream, 405, "method not allowed")
        }
        (_, ["v1", "jobs", _, "events"]) | (_, ["v1", "jobs", _]) => {
            respond_error_status(stream, 405, "method not allowed")
        }
        _ => respond_error_status(stream, 404, "unknown route"),
    }
}

// ---------------------------------------------------------------- routes

fn post_job(stream: &mut TcpStream, req: &Request, ctx: &WireCtx) {
    let body = match std::str::from_utf8(&req.body)
        .map_err(|_| SdError::invalid("body is not utf-8"))
        .and_then(|s| Json::parse(s).map_err(|e| SdError::invalid(format!("bad json: {e}"))))
    {
        Ok(j) => j,
        Err(e) => return respond_sd_error(stream, &e),
    };
    let (gen_req, opts) = match proto::request_from_json(&body) {
        Ok(v) => v,
        Err(e) => return respond_sd_error(stream, &e),
    };
    match ctx.client.submit_with(gen_req, opts) {
        Ok(handle) => {
            let id = handle.id.0;
            ctx.jobs.lock().unwrap().insert(
                id,
                JobEntry { events: Some(handle.events), cancel: handle.cancel },
            );
            respond_json(
                stream,
                202,
                &Json::obj(vec![("job", Json::Str(id.to_string()))]),
            );
        }
        Err(e) => respond_sd_error(stream, &e),
    }
}

fn delete_job(stream: &mut TcpStream, id: u64, ctx: &WireCtx) {
    let cancel = {
        let jobs = ctx.jobs.lock().unwrap();
        jobs.get(&id).map(|e| e.cancel.clone())
    };
    match cancel {
        Some(cancel) => {
            cancel.cancel();
            respond_json(
                stream,
                200,
                &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("job", Json::Str(id.to_string())),
                ]),
            );
        }
        None => respond_error_status(stream, 404, "no such job"),
    }
}

fn get_metrics(stream: &mut TcpStream, ctx: &WireCtx) {
    let mut body = ctx.metrics.to_json();
    let wire = Json::obj(vec![(
        "jobs_open",
        Json::num(ctx.jobs.lock().unwrap().len() as f64),
    )]);
    if let Json::Obj(fields) = &mut body {
        fields.push(("wire".to_string(), wire));
    }
    respond_json(stream, 200, &body);
}

fn stream_events(stream: &mut TcpStream, id: u64, ctx: &WireCtx) {
    // Claim the receiver (take-once).
    enum Claim {
        Missing,
        Busy,
        Got(Receiver<JobEvent>, CancelToken),
    }
    let claim = {
        let mut jobs = ctx.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            None => Claim::Missing,
            Some(entry) => match entry.events.take() {
                None => Claim::Busy,
                Some(rx) => Claim::Got(rx, entry.cancel.clone()),
            },
        }
    };
    let (rx, cancel) = match claim {
        Claim::Missing => return respond_error_status(stream, 404, "no such job"),
        Claim::Busy => {
            return respond_error_status(stream, 409, "events already being streamed")
        }
        Claim::Got(rx, cancel) => (rx, cancel),
    };

    if http::write_sse_head(stream).is_err() {
        abandon_stream(ctx, id, rx, &cancel);
        return;
    }
    let mut cw = ChunkedWriter::new(&mut *stream);
    loop {
        match rx.recv() {
            Ok(ev) => {
                let terminal = ev.is_terminal();
                let frame = proto::event_frame(&ev);
                if cw.write_chunk(frame.as_bytes()).is_err() {
                    // Client went away mid-stream: stop the job, drain
                    // to the terminal, deregister. No leak, no orphan.
                    abandon_stream(ctx, id, rx, &cancel);
                    return;
                }
                if terminal {
                    let _ = cw.finish();
                    ctx.jobs.lock().unwrap().remove(&id);
                    return;
                }
            }
            // Sender dropped without a terminal: server shutting down.
            Err(_) => {
                let _ = cw.finish();
                ctx.jobs.lock().unwrap().remove(&id);
                return;
            }
        }
    }
}

/// Mid-stream client loss: fire the cancel token, drain the receiver to
/// its terminal event, and deregister the job.
fn abandon_stream(ctx: &WireCtx, id: u64, rx: Receiver<JobEvent>, cancel: &CancelToken) {
    cancel.cancel();
    while let Ok(ev) = rx.recv() {
        if ev.is_terminal() {
            break;
        }
    }
    ctx.jobs.lock().unwrap().remove(&id);
}

// A tiny smoke test lives here; the full black-box suite (error paths,
// SSE vocabulary equivalence, disconnect semantics) is
// `tests/integration_net.rs`.
#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn route_split_handles_ids_and_unknowns() {
        // Pure routing-table sanity via the public surface: exercised
        // end-to-end in integration_net; here just pin the path parse.
        let path = "/v1/jobs/1234/events";
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        assert_eq!(segs, ["v1", "jobs", "1234", "events"]);
        assert_eq!("1234".parse::<u64>().unwrap(), 1234);
    }

    #[test]
    fn healthz_answers_without_a_job_server() {
        // WireServer only needs a Client for job routes; /healthz must
        // not touch it — but Client cannot be built without a server,
        // so this stays a raw-socket probe against a full stack in
        // integration tests. Here: bind/shutdown lifecycle only.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = http::read_request(&mut s).unwrap();
            assert_eq!(req.path, "/healthz");
            http::write_response(&mut s, 200, "application/json", b"{\"ok\":true}").unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let mut out = Vec::new();
        c.read_to_end(&mut out).unwrap();
        assert!(String::from_utf8_lossy(&out).contains("200 OK"));
        h.join().unwrap();
    }
}
