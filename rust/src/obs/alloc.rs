//! Counting global allocator.
//!
//! A thin wrapper over [`std::alloc::System`] that counts allocation
//! events and bytes behind a runtime switch, making the hot path's
//! zero-copy invariants (PR 3/5) regression-visible as *numbers* —
//! allocations per steady-state step — instead of only structural tests.
//!
//! Two gates, both off by default:
//! - **Compile-time**: the wrapper is only registered as
//!   `#[global_allocator]` under the `count-alloc` feature (default-on in
//!   this repo; [`registered`] reports it).
//! - **Runtime**: even when registered, counting is a single relaxed
//!   `AtomicBool` load until armed via [`enable`] or
//!   `SD_ACC_COUNT_ALLOC=1` ([`init_from_env`]).
//!
//! Debug/observability-only (standing invariant): these counters must
//! never feed cache keys or influence generated bits.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};

/// The wrapper type registered as the global allocator (see `lib.rs`).
pub struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates every operation verbatim to `System`; the counting
// side effects touch only lock-free atomics and never allocate, so the
// GlobalAlloc contract is inherited from `System` unchanged.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Relaxed) {
            ALLOCS.fetch_add(1, Relaxed);
            BYTES.fetch_add(layout.size() as u64, Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Relaxed) {
            ALLOCS.fetch_add(1, Relaxed);
            BYTES.fetch_add(layout.size() as u64, Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        if ENABLED.load(Relaxed) {
            DEALLOCS.fetch_add(1, Relaxed);
        }
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Relaxed) {
            ALLOCS.fetch_add(1, Relaxed);
            BYTES.fetch_add(new_size as u64, Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

/// Whether the wrapper is compiled in as the global allocator.
pub fn registered() -> bool {
    cfg!(feature = "count-alloc")
}

/// Arm counting (no effect on numbers unless [`registered`]).
pub fn enable() {
    ENABLED.store(true, Relaxed);
}

/// Disarm counting.
pub fn disable() {
    ENABLED.store(false, Relaxed);
}

/// Whether counting is currently armed.
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Arm counting if `SD_ACC_COUNT_ALLOC=1` is set in the environment.
pub fn init_from_env() {
    if std::env::var("SD_ACC_COUNT_ALLOC").as_deref() == Ok("1") {
        enable();
    }
}

/// True when allocation numbers are actually being produced
/// (compiled in *and* armed).
pub fn counting_active() -> bool {
    registered() && enabled()
}

/// Cumulative allocation counters at a point in time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocation events (alloc / alloc_zeroed / realloc).
    pub allocs: u64,
    /// Deallocation events.
    pub deallocs: u64,
    /// Total bytes requested by counted allocation events.
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Fieldwise `self - earlier` (saturating).
    pub fn delta_since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs.saturating_sub(earlier.allocs),
            deallocs: self.deallocs.saturating_sub(earlier.deallocs),
            bytes: self.bytes.saturating_sub(earlier.bytes),
        }
    }
}

/// Read the cumulative counters (relaxed loads; use deltas).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Relaxed),
        deallocs: DEALLOCS.load(Relaxed),
        bytes: BYTES.load(Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_observes_heap_traffic_when_registered() {
        if !registered() {
            // Feature off: the wrapper is not the global allocator and
            // the counters legitimately stay at zero.
            assert_eq!(snapshot(), AllocSnapshot::default());
            return;
        }
        let before = snapshot();
        enable();
        // A boxed slice guarantees at least one counted allocation of at
        // least this size while armed.
        let buf: Vec<u8> = Vec::with_capacity(64 * 1024);
        disable();
        let delta = snapshot().delta_since(&before);
        drop(buf);
        assert!(delta.allocs >= 1, "expected counted allocations, got {delta:?}");
        assert!(delta.bytes >= 64 * 1024, "expected counted bytes, got {delta:?}");
    }

    #[test]
    fn disarmed_counting_is_cheap_and_stable() {
        // With counting disarmed the only cost is one relaxed load per
        // allocator call; this just checks enable/disable toggling.
        let was = enabled();
        disable();
        assert!(!enabled());
        if was {
            enable();
        }
    }

    #[test]
    fn delta_saturates() {
        let a = AllocSnapshot { allocs: 1, deallocs: 2, bytes: 3 };
        let b = AllocSnapshot { allocs: 5, deallocs: 5, bytes: 5 };
        assert_eq!(a.delta_since(&b), AllocSnapshot::default());
    }
}
