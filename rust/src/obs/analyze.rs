//! Trace analytics: reconstruct per-job timelines from [`SpanEvent`]s
//! and decompose end-to-end latency into serving phases — the library
//! behind `sd-acc trace <file> --analyze` and the signal source for the
//! future traffic engine (ROADMAP item 2).
//!
//! ## Decomposition model
//!
//! Span timestamps are assigned at the *end* of the operation, so a
//! dur-carrying span covers the interval `[ts_us - dur_us, ts_us]`. A
//! job's timeline starts at the minimum interval start across its spans
//! (this includes the request-cache lookup that precedes the lifecycle
//! entry span) and ends at its latest timestamp. Each phase segment is
//! an interval inside that range:
//!
//! | segment        | interval                                          |
//! |----------------|---------------------------------------------------|
//! | `queue`        | entry span -> `scheduled` span                    |
//! | `batch-form`   | `scheduled` -> start of the first work span       |
//! | `step-full`    | `step` spans whose action is `full` (possibly     |
//! |                | policy-qualified, e.g. `stability:250:full`)      |
//! | `step-partial` | `step` spans with any other action                |
//! | `cache`        | `cache-lookup` spans                              |
//! | `decode`       | `decode` spans                                    |
//! | `other`        | remainder of the end-to-end range                 |
//!
//! Segments are accumulated by a sweep that clips overlap (first
//! category wins), so per-job phase durations **always sum to <= the
//! end-to-end span** — the acceptance invariant `integration_obs`
//! asserts. `execute` spans are *excluded* from the decomposition (they
//! nest inside steps and would double-count) and reported separately.
//!
//! Batch groups are reconstructed from runs of consecutive `scheduled`
//! spans sharing the same `batch` size (the worker records them
//! back-to-back under one lock); the *lead* lane — the job whose scope
//! carried the group's deep-layer spans — defines the group's critical
//! path. Schema v1 carries no explicit batch id, so this is a
//! best-effort reconstruction that degrades to singleton groups when
//! runs from concurrent workers interleave.

use crate::obs::trace::{Phase, SpanEvent};
use crate::util::json::Json;
use crate::util::stats;

/// Phase names in report order. `other` is always last.
pub const PHASE_NAMES: [&str; 7] =
    ["queue", "batch-form", "step-full", "step-partial", "cache", "decode", "other"];

const N_SEGS: usize = 6; // attributed segments, excluding `other`

/// Per-job attributed durations, microseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseBreakdown {
    /// Lifecycle entry -> picked up by a worker (`scheduled`).
    pub queue_us: u64,
    /// `scheduled` -> first attributed work span begins.
    pub batch_form_us: u64,
    /// Full-depth denoising steps.
    pub step_full_us: u64,
    /// PAS partial (approximated) steps.
    pub step_partial_us: u64,
    /// Typed cache lookups (calib/plan/quant/request).
    pub cache_us: u64,
    /// VAE decode.
    pub decode_us: u64,
}

impl PhaseBreakdown {
    /// Sum of the attributed segments (excludes `other`).
    pub fn total_us(&self) -> u64 {
        self.queue_us
            + self.batch_form_us
            + self.step_full_us
            + self.step_partial_us
            + self.cache_us
            + self.decode_us
    }

    fn seg_mut(&mut self, i: usize) -> &mut u64 {
        match i {
            0 => &mut self.queue_us,
            1 => &mut self.batch_form_us,
            2 => &mut self.step_full_us,
            3 => &mut self.step_partial_us,
            4 => &mut self.cache_us,
            _ => &mut self.decode_us,
        }
    }

    fn seg(&self, i: usize) -> u64 {
        match i {
            0 => self.queue_us,
            1 => self.batch_form_us,
            2 => self.step_full_us,
            3 => self.step_partial_us,
            4 => self.cache_us,
            _ => self.decode_us,
        }
    }
}

/// One job's reconstructed timeline.
#[derive(Debug, Clone)]
pub struct JobTimeline {
    pub job: u64,
    /// Lifecycle entry phase (`queued` / `cache-hit`), if seen.
    pub entry: Option<Phase>,
    /// Terminal phase (`done` / `failed` / `cancelled`), if seen.
    pub terminal: Option<Phase>,
    /// Earliest interval start across the job's spans (µs since sink epoch).
    pub start_us: u64,
    /// Latest timestamp across the job's spans.
    pub end_us: u64,
    /// `end_us - start_us`: the measured end-to-end span.
    pub e2e_us: u64,
    pub breakdown: PhaseBreakdown,
    /// Unattributed remainder: `e2e_us - breakdown.total_us()`.
    pub other_us: u64,
    pub steps_full: u64,
    pub steps_partial: u64,
    pub cache_lookups: u64,
    pub cache_lookup_hits: u64,
    /// Backend executes attributed to this job (nested inside steps —
    /// reported separately, excluded from the decomposition).
    pub executes: u64,
    pub execute_us: u64,
    pub bytes_moved: u64,
    /// Batch size from the `scheduled` span, if the job was batched.
    pub batch: Option<u64>,
    /// True when this job's scope carried the group's deep-layer spans.
    pub lead: bool,
    /// Entry and terminal both present.
    pub complete: bool,
}

/// A reconstructed batch group and its critical path.
#[derive(Debug, Clone)]
pub struct BatchGroup {
    /// Logical group size (the `batch` field of the members' spans).
    pub size: u64,
    pub jobs: Vec<u64>,
    /// The lane whose scope carried the group's work spans.
    pub lead: u64,
    /// First member's `scheduled` timestamp.
    pub scheduled_us: u64,
    /// `scheduled` -> last member terminal: the group's wall span.
    pub span_us: u64,
    /// Attributed work (steps + decode) on the lead lane — the critical
    /// path; `span_us - lead_work_us` is group overhead.
    pub lead_work_us: u64,
}

/// Aggregate statistics for one phase across all complete jobs.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub name: &'static str,
    pub total_ms: f64,
    /// Fraction of the summed end-to-end time — the "where does a
    /// millisecond go" column. Shares over all phases sum to 1.
    pub share: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// The full analysis: per-job timelines, batch groups, and the
/// aggregate per-phase distribution.
#[derive(Debug, Clone)]
pub struct TraceAnalysis {
    pub jobs: Vec<JobTimeline>,
    pub batches: Vec<BatchGroup>,
    /// One entry per [`PHASE_NAMES`] name, in that order.
    pub phases: Vec<PhaseStats>,
    /// Summed end-to-end time over complete jobs, ms.
    pub total_e2e_ms: f64,
    /// Jobs missing an entry or terminal span (truncated traces,
    /// in-flight jobs, ring eviction).
    pub incomplete_jobs: Vec<u64>,
}

/// A step span counts as full-depth if its action is `full`, bare or
/// policy-qualified (`<policy_id>:full` under non-default policies).
fn action_is_full(ev: &SpanEvent) -> bool {
    ev.action.as_deref().is_some_and(|a| a == "full" || a.ends_with(":full"))
}

fn seg_index_for(ev: &SpanEvent) -> Option<usize> {
    match ev.phase {
        Phase::Step => {
            if action_is_full(ev) {
                Some(2)
            } else {
                Some(3)
            }
        }
        Phase::CacheLookup => Some(4),
        Phase::Decode => Some(5),
        _ => None,
    }
}

fn analyze_job(job: u64, spans: &[&SpanEvent]) -> JobTimeline {
    let entry = spans.iter().find(|s| s.phase.is_entry());
    let terminal = spans.iter().find(|s| s.phase.is_terminal());
    let start_us =
        spans.iter().map(|s| s.ts_us.saturating_sub(s.dur_us.unwrap_or(0))).min().unwrap_or(0);
    let end_us = spans.iter().map(|s| s.ts_us).max().unwrap_or(0);
    let e2e_us = end_us.saturating_sub(start_us);
    let sched = spans.iter().find(|s| s.phase == Phase::Scheduled);

    // Collect attributed intervals: (start, end, segment index).
    let mut intervals: Vec<(u64, u64, usize)> = Vec::new();
    if let (Some(e), Some(s)) = (entry, sched) {
        intervals.push((e.ts_us.min(s.ts_us), s.ts_us, 0)); // queue
    }
    if let Some(s) = sched {
        // Batch formation: scheduled -> the first work interval that
        // starts at or after the scheduled timestamp.
        let first_work = spans
            .iter()
            .filter(|ev| seg_index_for(ev).is_some() && ev.dur_us.is_some())
            .map(|ev| ev.ts_us.saturating_sub(ev.dur_us.unwrap_or(0)))
            .filter(|&ws| ws >= s.ts_us)
            .min();
        if let Some(ws) = first_work {
            intervals.push((s.ts_us, ws, 1));
        }
    }
    for ev in spans {
        if let (Some(seg), Some(dur)) = (seg_index_for(ev), ev.dur_us) {
            intervals.push((ev.ts_us.saturating_sub(dur), ev.ts_us, seg));
        }
    }

    // Sweep with overlap clipping (first category wins): guarantees the
    // attributed segments sum to <= e2e even if instrumented intervals
    // ever nest or overlap.
    intervals.sort_by_key(|&(s, e, _)| (s, e));
    let mut breakdown = PhaseBreakdown::default();
    let mut cursor = start_us;
    for (s, e, seg) in intervals {
        let s = s.max(cursor).min(end_us);
        let e = e.min(end_us);
        if e > s {
            *breakdown.seg_mut(seg) += e - s;
            cursor = e;
        }
    }

    let mut t = JobTimeline {
        job,
        entry: entry.map(|s| s.phase),
        terminal: terminal.map(|s| s.phase),
        start_us,
        end_us,
        e2e_us,
        other_us: e2e_us.saturating_sub(breakdown.total_us()),
        breakdown,
        steps_full: 0,
        steps_partial: 0,
        cache_lookups: 0,
        cache_lookup_hits: 0,
        executes: 0,
        execute_us: 0,
        bytes_moved: 0,
        batch: sched.and_then(|s| s.batch),
        lead: false,
        complete: entry.is_some() && terminal.is_some(),
    };
    for ev in spans {
        match ev.phase {
            Phase::Step => {
                if action_is_full(ev) {
                    t.steps_full += 1;
                } else {
                    t.steps_partial += 1;
                }
            }
            Phase::CacheLookup => {
                t.cache_lookups += 1;
                if ev.hit == Some(true) {
                    t.cache_lookup_hits += 1;
                }
            }
            Phase::Execute => {
                t.executes += 1;
                t.execute_us += ev.dur_us.unwrap_or(0);
                t.bytes_moved += ev.bytes.unwrap_or(0);
            }
            _ => {}
        }
    }
    t.lead = t.steps_full + t.steps_partial > 0;
    t
}

/// Analyze a span stream (any order; sorted internally by `seq`).
pub fn analyze(spans: &[SpanEvent]) -> TraceAnalysis {
    let mut sorted: Vec<&SpanEvent> = spans.iter().collect();
    sorted.sort_by_key(|s| s.seq);

    // Group spans per job, preserving seq order within each job.
    let mut job_order: Vec<u64> = Vec::new();
    let mut per_job: std::collections::HashMap<u64, Vec<&SpanEvent>> =
        std::collections::HashMap::new();
    for ev in &sorted {
        let bucket = per_job.entry(ev.job).or_insert_with(|| {
            job_order.push(ev.job);
            Vec::new()
        });
        bucket.push(ev);
    }

    let jobs: Vec<JobTimeline> =
        job_order.iter().map(|&job| analyze_job(job, &per_job[&job])).collect();
    let by_job: std::collections::HashMap<u64, &JobTimeline> =
        jobs.iter().map(|t| (t.job, t)).collect();

    // Batch groups: runs of consecutive `scheduled` spans that agree on
    // the group size. The worker records a group's scheduled spans
    // back-to-back, so in single-worker (deterministic CI) traces this
    // recovers groups exactly; interleaved multi-worker runs degrade to
    // singletons.
    let scheduled: Vec<&SpanEvent> =
        sorted.iter().filter(|s| s.phase == Phase::Scheduled).copied().collect();
    let mut batches: Vec<BatchGroup> = Vec::new();
    let mut i = 0;
    while i < scheduled.len() {
        let size = scheduled[i].batch.unwrap_or(1).max(1) as usize;
        let members: Vec<&SpanEvent> = if i + size <= scheduled.len()
            && scheduled[i..i + size].iter().all(|s| s.batch == scheduled[i].batch)
        {
            scheduled[i..i + size].to_vec()
        } else {
            vec![scheduled[i]]
        };
        let n = members.len();
        let member_jobs: Vec<u64> = members.iter().map(|s| s.job).collect();
        let scheduled_us = members.iter().map(|s| s.ts_us).min().unwrap_or(0);
        let end_us = member_jobs
            .iter()
            .filter_map(|j| by_job.get(j))
            .map(|t| t.end_us)
            .max()
            .unwrap_or(scheduled_us);
        let lead = member_jobs
            .iter()
            .copied()
            .find(|j| by_job.get(j).is_some_and(|t| t.lead))
            .unwrap_or(member_jobs[0]);
        let lead_work_us = by_job.get(&lead).map_or(0, |t| {
            t.breakdown.step_full_us + t.breakdown.step_partial_us + t.breakdown.decode_us
        });
        batches.push(BatchGroup {
            size: members[0].batch.unwrap_or(1),
            jobs: member_jobs,
            lead,
            scheduled_us,
            span_us: end_us.saturating_sub(scheduled_us),
            lead_work_us,
        });
        i += n;
    }

    // Aggregate phase stats over complete jobs.
    let complete: Vec<&JobTimeline> = jobs.iter().filter(|t| t.complete).collect();
    let total_e2e_us: u64 = complete.iter().map(|t| t.e2e_us).sum();
    let phases = PHASE_NAMES
        .iter()
        .enumerate()
        .map(|(i, &name)| {
            let vals_ms: Vec<f64> = complete
                .iter()
                .map(|t| if i < N_SEGS { t.breakdown.seg(i) } else { t.other_us } as f64 / 1e3)
                .collect();
            let total_ms: f64 = vals_ms.iter().sum();
            PhaseStats {
                name,
                total_ms,
                share: if total_e2e_us == 0 { 0.0 } else { total_ms / (total_e2e_us as f64 / 1e3) },
                p50_ms: stats::percentile(&vals_ms, 50.0),
                p95_ms: stats::percentile(&vals_ms, 95.0),
                p99_ms: stats::percentile(&vals_ms, 99.0),
            }
        })
        .collect();

    TraceAnalysis {
        incomplete_jobs: jobs.iter().filter(|t| !t.complete).map(|t| t.job).collect(),
        total_e2e_ms: total_e2e_us as f64 / 1e3,
        jobs,
        batches,
        phases,
    }
}

impl JobTimeline {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Num(self.job as f64)),
            ("entry", self.entry.map_or(Json::Null, |p| Json::str(p.as_str()))),
            ("terminal", self.terminal.map_or(Json::Null, |p| Json::str(p.as_str()))),
            ("e2e_ms", Json::Num(self.e2e_us as f64 / 1e3)),
            ("queue_ms", Json::Num(self.breakdown.queue_us as f64 / 1e3)),
            ("batch_form_ms", Json::Num(self.breakdown.batch_form_us as f64 / 1e3)),
            ("step_full_ms", Json::Num(self.breakdown.step_full_us as f64 / 1e3)),
            ("step_partial_ms", Json::Num(self.breakdown.step_partial_us as f64 / 1e3)),
            ("cache_ms", Json::Num(self.breakdown.cache_us as f64 / 1e3)),
            ("decode_ms", Json::Num(self.breakdown.decode_us as f64 / 1e3)),
            ("other_ms", Json::Num(self.other_us as f64 / 1e3)),
            ("steps_full", Json::Num(self.steps_full as f64)),
            ("steps_partial", Json::Num(self.steps_partial as f64)),
            ("executes", Json::Num(self.executes as f64)),
            ("execute_ms", Json::Num(self.execute_us as f64 / 1e3)),
            ("bytes_moved", Json::Num(self.bytes_moved as f64)),
            ("batch", self.batch.map_or(Json::Null, |b| Json::Num(b as f64))),
            ("lead", Json::Bool(self.lead)),
            ("complete", Json::Bool(self.complete)),
        ])
    }
}

impl TraceAnalysis {
    /// Machine-readable form (`sd-acc trace --analyze --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs", Json::Arr(self.jobs.iter().map(JobTimeline::to_json).collect())),
            (
                "batches",
                Json::Arr(
                    self.batches
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("size", Json::Num(b.size as f64)),
                                (
                                    "jobs",
                                    Json::Arr(
                                        b.jobs.iter().map(|&j| Json::Num(j as f64)).collect(),
                                    ),
                                ),
                                ("lead", Json::Num(b.lead as f64)),
                                ("span_ms", Json::Num(b.span_us as f64 / 1e3)),
                                ("lead_work_ms", Json::Num(b.lead_work_us as f64 / 1e3)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("name", Json::str(p.name)),
                                ("total_ms", Json::Num(p.total_ms)),
                                ("share", Json::Num(p.share)),
                                ("p50_ms", Json::Num(p.p50_ms)),
                                ("p95_ms", Json::Num(p.p95_ms)),
                                ("p99_ms", Json::Num(p.p99_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_e2e_ms", Json::Num(self.total_e2e_ms)),
            (
                "incomplete_jobs",
                Json::Arr(self.incomplete_jobs.iter().map(|&j| Json::Num(j as f64)).collect()),
            ),
        ])
    }

    /// Total attributed to `name` across complete jobs, ms.
    pub fn phase_total_ms(&self, name: &str) -> f64 {
        self.phases.iter().find(|p| p.name == name).map_or(0.0, |p| p.total_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64, ts: u64, job: u64, phase: Phase) -> SpanEvent {
        let mut e = SpanEvent::new(job, phase);
        e.seq = seq;
        e.ts_us = ts;
        e
    }

    fn synthetic_job() -> Vec<SpanEvent> {
        vec![
            // request-cache lookup precedes the entry span
            ev(0, 100, 1, Phase::CacheLookup).with_namespace("request").with_hit(false).with_dur_us(80),
            ev(1, 110, 1, Phase::Queued),
            ev(2, 500, 1, Phase::Scheduled).with_batch(1),
            ev(3, 700, 1, Phase::CacheLookup).with_namespace("plan").with_hit(true).with_dur_us(50),
            ev(4, 1_700, 1, Phase::Step).with_step(0).with_action("full").with_dur_us(1_000),
            ev(5, 1_690, 1, Phase::Execute).with_backend("sim").with_bytes(64).with_dur_us(900),
            ev(6, 2_100, 1, Phase::Step).with_step(1).with_action("partial").with_dur_us(400),
            ev(7, 2_600, 1, Phase::Decode).with_batch(1).with_dur_us(450),
            ev(8, 2_650, 1, Phase::Done),
        ]
    }

    #[test]
    fn decomposition_sums_to_at_most_e2e() {
        let a = analyze(&synthetic_job());
        assert_eq!(a.jobs.len(), 1);
        let t = &a.jobs[0];
        assert!(t.complete);
        assert_eq!(t.start_us, 20); // lookup interval start: 100 - 80
        assert_eq!(t.end_us, 2_650);
        assert_eq!(t.e2e_us, 2_630);
        assert_eq!(t.breakdown.total_us() + t.other_us, t.e2e_us);
        assert!(t.breakdown.total_us() <= t.e2e_us);
    }

    #[test]
    fn segments_are_attributed_per_phase() {
        let a = analyze(&synthetic_job());
        let t = &a.jobs[0];
        assert_eq!(t.breakdown.queue_us, 390); // 110 -> 500
        assert_eq!(t.breakdown.batch_form_us, 150); // 500 -> plan lookup start 650
        assert_eq!(t.breakdown.cache_us, 80 + 50);
        assert_eq!(t.breakdown.step_full_us, 1_000);
        assert_eq!(t.breakdown.step_partial_us, 400);
        assert_eq!(t.breakdown.decode_us, 450);
        assert_eq!(t.steps_full, 1);
        assert_eq!(t.steps_partial, 1);
        // Executes are nested, counted separately, not in the breakdown.
        assert_eq!(t.executes, 1);
        assert_eq!(t.execute_us, 900);
        assert!(t.lead);
    }

    #[test]
    fn overlapping_intervals_never_double_count() {
        // Pathological trace: a cache lookup entirely inside a step.
        let spans = vec![
            ev(0, 0, 1, Phase::Queued),
            ev(1, 10, 1, Phase::Scheduled).with_batch(1),
            ev(2, 1_010, 1, Phase::Step).with_step(0).with_action("full").with_dur_us(1_000),
            ev(3, 600, 1, Phase::CacheLookup).with_namespace("plan").with_hit(true).with_dur_us(200),
            ev(4, 1_020, 1, Phase::Done),
        ];
        let a = analyze(&spans);
        let t = &a.jobs[0];
        assert!(t.breakdown.total_us() <= t.e2e_us, "sweep must clip overlap");
    }

    #[test]
    fn batch_groups_reconstruct_from_consecutive_scheduled_runs() {
        let mut spans = Vec::new();
        // Group of 2: jobs 1, 2 scheduled back-to-back.
        spans.push(ev(0, 0, 1, Phase::Queued));
        spans.push(ev(1, 5, 2, Phase::Queued));
        spans.push(ev(2, 100, 1, Phase::Scheduled).with_batch(2));
        spans.push(ev(3, 101, 2, Phase::Scheduled).with_batch(2));
        spans.push(ev(4, 900, 1, Phase::Step).with_step(0).with_action("full").with_dur_us(700));
        spans.push(ev(5, 950, 1, Phase::Done));
        spans.push(ev(6, 960, 2, Phase::Done));
        let a = analyze(&spans);
        assert_eq!(a.batches.len(), 1);
        let b = &a.batches[0];
        assert_eq!(b.size, 2);
        assert_eq!(b.jobs, vec![1, 2]);
        assert_eq!(b.lead, 1, "lead lane is the one carrying step spans");
        assert_eq!(b.span_us, 860); // 100 -> 960
        assert_eq!(b.lead_work_us, 700);
    }

    #[test]
    fn incomplete_jobs_are_flagged_not_aggregated() {
        let spans = vec![
            ev(0, 0, 1, Phase::Queued),
            ev(1, 10, 1, Phase::Done),
            ev(2, 20, 2, Phase::Queued), // no terminal: in flight
        ];
        let a = analyze(&spans);
        assert_eq!(a.incomplete_jobs, vec![2]);
        assert_eq!(a.jobs.iter().filter(|t| t.complete).count(), 1);
    }

    #[test]
    fn phase_shares_sum_to_one_when_time_was_spent() {
        let a = analyze(&synthetic_job());
        let share_sum: f64 = a.phases.iter().map(|p| p.share).sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to {share_sum}");
        assert_eq!(a.phases.len(), PHASE_NAMES.len());
        assert_eq!(a.phases.last().unwrap().name, "other");
    }

    #[test]
    fn analysis_json_is_parseable() {
        let a = analyze(&synthetic_job());
        let j = Json::parse(&a.to_json().to_string()).unwrap();
        assert_eq!(j.get("jobs").and_then(|x| x.as_arr()).unwrap().len(), 1);
        assert_eq!(j.get("phases").and_then(|x| x.as_arr()).unwrap().len(), 7);
        assert!(j.get_f64("total_e2e_ms").unwrap() > 0.0);
    }

    #[test]
    fn cache_hit_fast_path_decomposes_without_scheduled_span() {
        let spans = vec![
            ev(0, 300, 9, Phase::CacheLookup).with_namespace("request").with_hit(true).with_dur_us(250),
            ev(1, 320, 9, Phase::CacheHit),
            ev(2, 340, 9, Phase::Done),
        ];
        let a = analyze(&spans);
        let t = &a.jobs[0];
        assert!(t.complete);
        assert_eq!(t.breakdown.queue_us, 0);
        assert_eq!(t.breakdown.cache_us, 250);
        assert!(t.breakdown.total_us() <= t.e2e_us);
        assert!(a.batches.is_empty());
    }
}
