//! Process-global labeled counters.
//!
//! The flat `server::Metrics` struct aggregates per-server totals; these
//! counters carry the *labels* it cannot express: cache traffic per
//! namespace, execute count and bytes moved per backend, denoise steps
//! per PAS action. They are plain relaxed atomics — cheap enough to bump
//! on the hot path — and cumulative for the process lifetime, so
//! consumers (benches, tests, `serve --json`) work with deltas between
//! two [`CountersSnapshot`]s.
//!
//! Observability-only (standing invariant): counter values must never
//! feed cache keys or influence generated bits.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::util::json::Json;

/// Cache namespaces with dedicated counters, in snapshot order. These
/// mirror the `cache::NS_*` constants.
pub const CACHE_NAMESPACES: [&str; 4] = ["calib", "plan", "quant", "request"];

/// Backend kinds with dedicated counters, in snapshot order.
pub const BACKENDS: [&str; 2] = ["xla", "sim"];

#[derive(Debug)]
struct NsCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl NsCounters {
    const fn new() -> NsCounters {
        NsCounters {
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }
}

#[derive(Debug)]
struct BackendCounters {
    executes: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl BackendCounters {
    const fn new() -> BackendCounters {
        BackendCounters {
            executes: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
        }
    }
}

/// Resilience-policy activity (`server::resilience`): how often the
/// failure-hardening machinery actually fired. Observability-only like
/// everything here — the policy keeps its own per-server state; these
/// labels exist so `serve --monitor`/`--json` can show process-wide
/// deltas.
#[derive(Debug)]
struct ResilienceCounters {
    retries: AtomicU64,
    retries_recovered: AtomicU64,
    hedges: AtomicU64,
    sheds: AtomicU64,
    brownout_transitions: AtomicU64,
    degraded: AtomicU64,
}

impl ResilienceCounters {
    const fn new() -> ResilienceCounters {
        ResilienceCounters {
            retries: AtomicU64::new(0),
            retries_recovered: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            sheds: AtomicU64::new(0),
            brownout_transitions: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
        }
    }
}

/// The process-global counter set. Obtain via [`counters`].
#[derive(Debug)]
pub struct Counters {
    cache: [NsCounters; 4],
    backend: [BackendCounters; 2],
    steps_full: AtomicU64,
    steps_partial: AtomicU64,
    decodes: AtomicU64,
    resilience: ResilienceCounters,
}

static GLOBAL: Counters = Counters {
    cache: [NsCounters::new(), NsCounters::new(), NsCounters::new(), NsCounters::new()],
    backend: [BackendCounters::new(), BackendCounters::new()],
    steps_full: AtomicU64::new(0),
    steps_partial: AtomicU64::new(0),
    decodes: AtomicU64::new(0),
    resilience: ResilienceCounters::new(),
};

/// The process-global labeled counters.
pub fn counters() -> &'static Counters {
    &GLOBAL
}

fn ns_index(ns: &str) -> Option<usize> {
    CACHE_NAMESPACES.iter().position(|n| *n == ns)
}

fn backend_index(backend: &str) -> Option<usize> {
    BACKENDS.iter().position(|b| *b == backend)
}

impl Counters {
    /// One cache lookup that found a decodable entry in `ns`.
    pub fn cache_hit(&self, ns: &str) {
        if let Some(i) = ns_index(ns) {
            self.cache[i].hits.fetch_add(1, Relaxed);
        }
    }

    /// One cache lookup that missed (or self-healed a corrupt entry) in `ns`.
    pub fn cache_miss(&self, ns: &str) {
        if let Some(i) = ns_index(ns) {
            self.cache[i].misses.fetch_add(1, Relaxed);
        }
    }

    /// `n` entries evicted from `ns` by a write.
    pub fn cache_evictions(&self, ns: &str, n: u64) {
        if let Some(i) = ns_index(ns) {
            if n > 0 {
                self.cache[i].evictions.fetch_add(n, Relaxed);
            }
        }
    }

    /// One backend execute moving `bytes_in` operand bytes and
    /// `bytes_out` result bytes.
    pub fn execute(&self, backend: &str, bytes_in: u64, bytes_out: u64) {
        if let Some(i) = backend_index(backend) {
            self.backend[i].executes.fetch_add(1, Relaxed);
            self.backend[i].bytes_in.fetch_add(bytes_in, Relaxed);
            self.backend[i].bytes_out.fetch_add(bytes_out, Relaxed);
        }
    }

    /// One denoise step with the given PAS action label ("full"/"partial").
    pub fn step(&self, action_label: &str) {
        if action_label == "full" {
            self.steps_full.fetch_add(1, Relaxed);
        } else {
            self.steps_partial.fetch_add(1, Relaxed);
        }
    }

    /// One VAE decode call.
    pub fn decode(&self) {
        self.decodes.fetch_add(1, Relaxed);
    }

    /// One transient failure re-dispatched by the retry policy.
    pub fn retry(&self) {
        self.resilience.retries.fetch_add(1, Relaxed);
    }

    /// One previously-retried job that ultimately completed.
    pub fn retry_recovered(&self) {
        self.resilience.retries_recovered.fetch_add(1, Relaxed);
    }

    /// One hedged re-dispatch of a straggling job.
    pub fn hedge(&self) {
        self.resilience.hedges.fetch_add(1, Relaxed);
    }

    /// One request rejected early by the load shedder.
    pub fn shed(&self) {
        self.resilience.sheds.fetch_add(1, Relaxed);
    }

    /// One brownout state change (engage or disengage each count 1).
    pub fn brownout_transition(&self) {
        self.resilience.brownout_transitions.fetch_add(1, Relaxed);
    }

    /// One request degraded to a cheaper plan/quant at admission.
    pub fn degrade(&self) {
        self.resilience.degraded.fetch_add(1, Relaxed);
    }

    /// Point-in-time copy. Each label is read with a relaxed load;
    /// cross-label consistency is not guaranteed (use deltas over quiet
    /// periods, or the trace-sink lifecycle counts for the consistent
    /// path).
    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            cache: CACHE_NAMESPACES
                .iter()
                .zip(&self.cache)
                .map(|(ns, c)| NsSnapshot {
                    namespace: ns,
                    hits: c.hits.load(Relaxed),
                    misses: c.misses.load(Relaxed),
                    evictions: c.evictions.load(Relaxed),
                })
                .collect(),
            backends: BACKENDS
                .iter()
                .zip(&self.backend)
                .map(|(b, c)| BackendSnapshot {
                    backend: b,
                    executes: c.executes.load(Relaxed),
                    bytes_in: c.bytes_in.load(Relaxed),
                    bytes_out: c.bytes_out.load(Relaxed),
                })
                .collect(),
            steps_full: self.steps_full.load(Relaxed),
            steps_partial: self.steps_partial.load(Relaxed),
            decodes: self.decodes.load(Relaxed),
            resilience: ResilienceSnapshot {
                retries: self.resilience.retries.load(Relaxed),
                retries_recovered: self.resilience.retries_recovered.load(Relaxed),
                hedges: self.resilience.hedges.load(Relaxed),
                sheds: self.resilience.sheds.load(Relaxed),
                brownout_transitions: self.resilience.brownout_transitions.load(Relaxed),
                degraded: self.resilience.degraded.load(Relaxed),
            },
        }
    }
}

/// Resilience-policy counters at snapshot time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResilienceSnapshot {
    pub retries: u64,
    pub retries_recovered: u64,
    pub hedges: u64,
    pub sheds: u64,
    pub brownout_transitions: u64,
    pub degraded: u64,
}

impl ResilienceSnapshot {
    /// Any policy activity at all (gates the monitor line).
    pub fn any(&self) -> bool {
        self.retries + self.hedges + self.sheds + self.brownout_transitions + self.degraded > 0
    }
}

/// Per-namespace cache counters at snapshot time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NsSnapshot {
    pub namespace: &'static str,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl NsSnapshot {
    /// hits / (hits + misses); 0 when there was no traffic.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Per-backend counters at snapshot time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendSnapshot {
    pub backend: &'static str,
    pub executes: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl BackendSnapshot {
    /// Operand + result bytes for this backend.
    pub fn bytes_moved(&self) -> u64 {
        self.bytes_in + self.bytes_out
    }
}

/// Point-in-time view of all labeled counters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CountersSnapshot {
    pub cache: Vec<NsSnapshot>,
    pub backends: Vec<BackendSnapshot>,
    pub steps_full: u64,
    pub steps_partial: u64,
    pub decodes: u64,
    pub resilience: ResilienceSnapshot,
}

impl CountersSnapshot {
    /// Fieldwise `self - earlier` (saturating). Both snapshots come from
    /// the same global counter set, so label order is fixed.
    pub fn delta_since(&self, earlier: &CountersSnapshot) -> CountersSnapshot {
        CountersSnapshot {
            cache: self
                .cache
                .iter()
                .zip(&earlier.cache)
                .map(|(now, then)| NsSnapshot {
                    namespace: now.namespace,
                    hits: now.hits.saturating_sub(then.hits),
                    misses: now.misses.saturating_sub(then.misses),
                    evictions: now.evictions.saturating_sub(then.evictions),
                })
                .collect(),
            backends: self
                .backends
                .iter()
                .zip(&earlier.backends)
                .map(|(now, then)| BackendSnapshot {
                    backend: now.backend,
                    executes: now.executes.saturating_sub(then.executes),
                    bytes_in: now.bytes_in.saturating_sub(then.bytes_in),
                    bytes_out: now.bytes_out.saturating_sub(then.bytes_out),
                })
                .collect(),
            steps_full: self.steps_full.saturating_sub(earlier.steps_full),
            steps_partial: self.steps_partial.saturating_sub(earlier.steps_partial),
            decodes: self.decodes.saturating_sub(earlier.decodes),
            resilience: ResilienceSnapshot {
                retries: self.resilience.retries.saturating_sub(earlier.resilience.retries),
                retries_recovered: self
                    .resilience
                    .retries_recovered
                    .saturating_sub(earlier.resilience.retries_recovered),
                hedges: self.resilience.hedges.saturating_sub(earlier.resilience.hedges),
                sheds: self.resilience.sheds.saturating_sub(earlier.resilience.sheds),
                brownout_transitions: self
                    .resilience
                    .brownout_transitions
                    .saturating_sub(earlier.resilience.brownout_transitions),
                degraded: self.resilience.degraded.saturating_sub(earlier.resilience.degraded),
            },
        }
    }

    /// Counters for one namespace.
    pub fn ns(&self, namespace: &str) -> Option<&NsSnapshot> {
        self.cache.iter().find(|c| c.namespace == namespace)
    }

    /// Counters for one backend.
    pub fn backend(&self, backend: &str) -> Option<&BackendSnapshot> {
        self.backends.iter().find(|b| b.backend == backend)
    }

    /// Total bytes moved across all backends.
    pub fn total_bytes_moved(&self) -> u64 {
        self.backends.iter().map(BackendSnapshot::bytes_moved).sum()
    }

    /// Total denoise steps across actions.
    pub fn total_steps(&self) -> u64 {
        self.steps_full + self.steps_partial
    }

    /// Machine-readable form (for `serve --json` and `BENCH_obs.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "cache",
                Json::Arr(
                    self.cache
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("namespace", Json::Str(c.namespace.to_string())),
                                ("hits", Json::Num(c.hits as f64)),
                                ("misses", Json::Num(c.misses as f64)),
                                ("evictions", Json::Num(c.evictions as f64)),
                                ("hit_ratio", Json::Num(c.hit_ratio())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "backends",
                Json::Arr(
                    self.backends
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("backend", Json::Str(b.backend.to_string())),
                                ("executes", Json::Num(b.executes as f64)),
                                ("bytes_in", Json::Num(b.bytes_in as f64)),
                                ("bytes_out", Json::Num(b.bytes_out as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("steps_full", Json::Num(self.steps_full as f64)),
            ("steps_partial", Json::Num(self.steps_partial as f64)),
            ("decodes", Json::Num(self.decodes as f64)),
            (
                "resilience",
                Json::obj(vec![
                    ("retries", Json::Num(self.resilience.retries as f64)),
                    (
                        "retries_recovered",
                        Json::Num(self.resilience.retries_recovered as f64),
                    ),
                    ("hedges", Json::Num(self.resilience.hedges as f64)),
                    ("sheds", Json::Num(self.resilience.sheds as f64)),
                    (
                        "brownout_transitions",
                        Json::Num(self.resilience.brownout_transitions as f64),
                    ),
                    ("degraded", Json::Num(self.resilience.degraded as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The counters are process-global and tests run in parallel, so every
    // assertion here is on deltas this test itself caused (>= not ==
    // where another test could plausibly bump the same label).

    #[test]
    fn cache_labels_count_independently() {
        let before = counters().snapshot();
        counters().cache_hit("plan");
        counters().cache_hit("plan");
        counters().cache_miss("request");
        counters().cache_evictions("request", 3);
        counters().cache_hit("no-such-namespace"); // ignored, no panic
        let d = counters().snapshot().delta_since(&before);
        assert!(d.ns("plan").unwrap().hits >= 2);
        assert!(d.ns("request").unwrap().misses >= 1);
        assert!(d.ns("request").unwrap().evictions >= 3);
        assert_eq!(d.ns("calib").unwrap().hits, 0);
    }

    #[test]
    fn backend_bytes_accumulate() {
        let before = counters().snapshot();
        counters().execute("sim", 100, 50);
        counters().execute("sim", 10, 5);
        let d = counters().snapshot().delta_since(&before);
        let sim = d.backend("sim").unwrap();
        assert!(sim.executes >= 2);
        assert!(sim.bytes_in >= 110);
        assert!(sim.bytes_out >= 55);
        assert!(d.total_bytes_moved() >= 165);
    }

    #[test]
    fn step_actions_split_full_partial() {
        let before = counters().snapshot();
        counters().step("full");
        counters().step("partial");
        counters().step("partial");
        let d = counters().snapshot().delta_since(&before);
        assert!(d.steps_full >= 1);
        assert!(d.steps_partial >= 2);
        assert!(d.total_steps() >= 3);
    }

    #[test]
    fn resilience_labels_accumulate_and_export() {
        let before = counters().snapshot();
        counters().retry();
        counters().retry();
        counters().retry_recovered();
        counters().hedge();
        counters().shed();
        counters().brownout_transition();
        counters().brownout_transition();
        counters().degrade();
        let d = counters().snapshot().delta_since(&before);
        assert!(d.resilience.retries >= 2);
        assert!(d.resilience.retries_recovered >= 1);
        assert!(d.resilience.hedges >= 1);
        assert!(d.resilience.sheds >= 1);
        assert!(d.resilience.brownout_transitions >= 2);
        assert!(d.resilience.degraded >= 1);
        assert!(d.resilience.any());
        assert!(!ResilienceSnapshot::default().any());
        let r = counters().snapshot().to_json();
        let r = r.get("resilience").unwrap();
        for key in
            ["retries", "retries_recovered", "hedges", "sheds", "brownout_transitions", "degraded"]
        {
            assert!(r.get_f64(key).is_some(), "{key} missing from resilience json");
        }
    }

    #[test]
    fn hit_ratio_handles_zero_traffic() {
        let ns = NsSnapshot { namespace: "calib", hits: 0, misses: 0, evictions: 0 };
        assert_eq!(ns.hit_ratio(), 0.0);
        let ns = NsSnapshot { namespace: "calib", hits: 3, misses: 1, evictions: 0 };
        assert!((ns.hit_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn snapshot_json_has_all_labels() {
        let j = counters().snapshot().to_json();
        let cache = j.get("cache").and_then(Json::as_arr).unwrap();
        assert_eq!(cache.len(), CACHE_NAMESPACES.len());
        let backends = j.get("backends").and_then(Json::as_arr).unwrap();
        assert_eq!(backends.len(), BACKENDS.len());
        assert!(j.get_f64("steps_full").is_some());
        assert!(j.get_f64("decodes").is_some());
    }
}
