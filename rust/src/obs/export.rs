//! Chrome trace-event (Perfetto) export: map a span stream onto the
//! JSON Array Format that `ui.perfetto.dev` and `chrome://tracing`
//! open directly.
//!
//! Mapping (standing invariant — the exporter is a *read-only* consumer
//! of span schema v1; any change here tracks [`TRACE_SCHEMA_VERSION`]):
//!
//! - every job becomes a track: `pid` 1, `tid` = job id, named via a
//!   `thread_name` metadata event;
//! - dur-carrying spans become complete duration events (`"ph": "X"`)
//!   at `ts = ts_us - dur_us` (span timestamps mark the *end* of the
//!   operation), `dur = dur_us`;
//! - lifecycle / instantaneous spans become thread-scoped instant
//!   events (`"ph": "i"`, `"s": "t"`);
//! - the remaining span fields ride along in `args` verbatim.
//!
//! Timestamps are microseconds since the recording sink's epoch, which
//! is exactly the unit the trace-event format expects.
//!
//! [`TRACE_SCHEMA_VERSION`]: crate::obs::trace::TRACE_SCHEMA_VERSION

use std::path::Path;

use anyhow::{Context, Result};

use crate::obs::trace::SpanEvent;
use crate::util::json::Json;

fn args_json(ev: &SpanEvent) -> Json {
    let mut fields = vec![("seq", Json::Num(ev.seq as f64))];
    if let Some(v) = ev.step {
        fields.push(("step", Json::Num(v as f64)));
    }
    if let Some(v) = &ev.action {
        fields.push(("action", Json::str(v)));
    }
    if let Some(v) = &ev.namespace {
        fields.push(("namespace", Json::str(v)));
    }
    if let Some(v) = ev.hit {
        fields.push(("hit", Json::Bool(v)));
    }
    if let Some(v) = &ev.backend {
        fields.push(("backend", Json::str(v)));
    }
    if let Some(v) = &ev.artifact {
        fields.push(("artifact", Json::str(v)));
    }
    if let Some(v) = ev.bytes {
        fields.push(("bytes", Json::Num(v as f64)));
    }
    if let Some(v) = ev.batch {
        fields.push(("batch", Json::Num(v as f64)));
    }
    Json::obj(fields)
}

/// Build the trace-event JSON object for a span stream.
pub fn to_chrome_json(spans: &[SpanEvent]) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 8);

    // One thread_name metadata event per job, in first-seen order, so
    // tracks are labeled in the viewer.
    let mut seen: Vec<u64> = Vec::new();
    for ev in spans {
        if !seen.contains(&ev.job) {
            seen.push(ev.job);
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(ev.job as f64)),
                ("args", Json::obj(vec![("name", Json::str(&format!("job {}", ev.job)))])),
            ]));
        }
    }

    for ev in spans {
        let mut fields = vec![
            ("name", Json::str(ev.phase.as_str())),
            ("cat", Json::str("sd-acc")),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(ev.job as f64)),
        ];
        match ev.dur_us {
            Some(dur) => {
                fields.push(("ph", Json::str("X")));
                fields.push(("ts", Json::Num(ev.ts_us.saturating_sub(dur) as f64)));
                fields.push(("dur", Json::Num(dur as f64)));
            }
            None => {
                fields.push(("ph", Json::str("i")));
                fields.push(("s", Json::str("t")));
                fields.push(("ts", Json::Num(ev.ts_us as f64)));
            }
        }
        fields.push(("args", args_json(ev)));
        events.push(Json::obj(fields));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write the export to `path`; returns the number of trace events
/// written (metadata events included).
pub fn write_chrome(spans: &[SpanEvent], path: &Path) -> Result<usize> {
    let j = to_chrome_json(spans);
    let n = j.get("traceEvents").and_then(Json::as_arr).map_or(0, |a| a.len());
    std::fs::write(path, j.to_string())
        .with_context(|| format!("trace: cannot write chrome export {}", path.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Phase;

    fn sample() -> Vec<SpanEvent> {
        let mut q = SpanEvent::new(3, Phase::Queued);
        q.seq = 0;
        q.ts_us = 100;
        let mut s = SpanEvent::new(3, Phase::Step).with_step(0).with_action("full").with_dur_us(40);
        s.seq = 1;
        s.ts_us = 200;
        let mut d = SpanEvent::new(3, Phase::Done);
        d.seq = 2;
        d.ts_us = 210;
        vec![q, s, d]
    }

    #[test]
    fn export_shapes_duration_and_instant_events() {
        let j = to_chrome_json(&sample());
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 1 thread_name metadata + 3 spans.
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get_str("ph"), Some("M"));
        assert_eq!(events[0].get_str("name"), Some("thread_name"));

        let queued = &events[1];
        assert_eq!(queued.get_str("ph"), Some("i"));
        assert_eq!(queued.get_str("s"), Some("t"));
        assert_eq!(queued.get_usize("ts"), Some(100));

        let step = &events[2];
        assert_eq!(step.get_str("ph"), Some("X"));
        // Span timestamps mark the end: X events start at ts - dur.
        assert_eq!(step.get_usize("ts"), Some(160));
        assert_eq!(step.get_usize("dur"), Some(40));
        assert_eq!(step.get_usize("tid"), Some(3));
        let args = step.get("args").unwrap();
        assert_eq!(args.get_str("action"), Some("full"));
    }

    #[test]
    fn export_round_trips_through_util_json() {
        let j = to_chrome_json(&sample());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get_str("displayTimeUnit"), Some("ms"));
        let events = back.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!events.is_empty());
        for e in events {
            assert!(e.get_str("ph").is_some());
        }
    }

    #[test]
    fn write_chrome_reports_event_count() {
        let dir = std::env::temp_dir().join(format!("sdacc_chrome_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let n = write_chrome(&sample(), &path).unwrap();
        assert_eq!(n, 4);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
