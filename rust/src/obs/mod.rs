//! # Observability: per-job trace spans, labeled counters, allocation accounting
//!
//! This module is the crate's measurement layer. Everything SD-Acc claims
//! to win — MAC reduction from phase-aware sampling, memory traffic from
//! dataflow reuse, latency from batching — is a *measured* quantity, and
//! this module is where those measurements become attributable numbers
//! instead of aggregate guesses.
//!
//! ## Span vocabulary
//!
//! A trace is an ordered sequence of [`SpanEvent`]s recorded by a
//! [`TraceSink`]. Every span carries the `job` id (the [`JobId`] minted by
//! `server::api`, or request id `0` for single-shot CLI runs) of the job
//! that *caused* it, plus a [`Phase`] naming what happened:
//!
//! | phase          | emitted by                  | extra fields                 |
//! |----------------|-----------------------------|------------------------------|
//! | `queued`       | `Client::submit_with`       | —                            |
//! | `cache-hit`    | `Client::submit_with`       | — (request-cache fast path)  |
//! | `scheduled`    | server worker (`run_group`) | `batch` (batch size)         |
//! | `step`         | coordinator denoise loop    | `step`, `action`, `dur_us`   |
//! | `decode`       | `Coordinator::decode`       | `batch` (latent count), `dur_us` |
//! | `cache-lookup` | `Cache::get_typed`          | `namespace`, `hit`, `dur_us` |
//! | `cache-write`  | `Cache::put_typed`          | `namespace`, `bytes`         |
//! | `execute`      | `RuntimeHandle::execute`    | `backend`, `artifact`, `bytes`, `dur_us` |
//! | `done`         | server / CLI terminal       | —                            |
//! | `failed`       | server terminal             | —                            |
//! | `cancelled`    | server terminal             | —                            |
//!
//! The `step` span's `action` is `full`/`partial` under the default
//! approximation policy and `<policy_id>:<action>` (e.g.
//! `stability:250:partial`) under a non-default [`crate::policy`] — a
//! vocabulary widening of the existing string field, not a schema
//! change, so no [`TRACE_SCHEMA_VERSION`] bump.
//!
//! `queued` and `cache-hit` are *lifecycle entries*; `done`, `failed` and
//! `cancelled` are *terminals*. The standing job-API invariant (exactly
//! one terminal event per job) is mirrored here: a traced job records
//! exactly one entry span and exactly one terminal span.
//!
//! ## Consumers of the span stream
//!
//! Three read-only consumers interpret recorded spans (none of them may
//! feed inputs back into serving — standing invariant):
//!
//! | surface | module | CLI |
//! |---------|--------|-----|
//! | per-job phase decomposition (queue / batch-form / step-full / step-partial / cache / decode), batch critical path, per-phase p50/p95/p99 | [`analyze`] | `sd-acc trace <file> --analyze` |
//! | windowed SLO percentiles (log-bucketed histograms, sliding window ring) and the per-priority results ledger (goodput, deadline-miss rate, cancel-ack latency, rejects) | [`slo`] (wired into `server::Metrics`) | `sd-acc serve --json` / `--monitor <secs>` |
//! | Chrome trace-event / Perfetto export (jobs -> tracks, dur spans -> `"X"` events, lifecycle spans -> instants) | [`export`] | `sd-acc trace <file> --export-chrome out.json` |
//!
//! Deep-layer spans (`cache-lookup`, `cache-write`, `execute`, `step`,
//! `decode`) are attributed through a thread-local [`TraceScope`]: the
//! layer that knows the job id enters a scope, and instrumented code
//! below it records against the sink + job id of the innermost scope.
//! For a batched group the scope carries the *lead* (first) job of the
//! group — documented as "the job that caused this work". Outside any
//! scope, deep-layer spans are dropped (the labeled counters still
//! count).
//!
//! ## Schema versioning (standing invariant)
//!
//! JSONL span lines carry `"v": TRACE_SCHEMA_VERSION`. Any change to the
//! span field set or field meaning must bump [`TRACE_SCHEMA_VERSION`];
//! readers reject lines from other versions rather than misparse them.
//!
//! ## Counters and the allocator
//!
//! [`counters()`](counters::counters) is a process-global set of labeled
//! atomics the flat `server::Metrics` struct cannot express: cache
//! hit/miss/eviction *per namespace*, execute count and bytes moved *per
//! backend*, step count *per PAS action*. [`alloc`] wraps the system
//! allocator (feature `count-alloc`, runtime-armed via
//! `SD_ACC_COUNT_ALLOC=1` or [`alloc::enable`]) so the zero-copy
//! invariants of the hot path are regression-visible as allocations per
//! step. Allocator and global counters are debug/observability-only:
//! they must never feed cache keys or affect generated bits — standing
//! invariant.
//!
//! [`JobId`]: crate::server::JobId
//! [`SpanEvent`]: trace::SpanEvent
//! [`Phase`]: trace::Phase
//! [`TraceSink`]: trace::TraceSink
//! [`TraceScope`]: trace::TraceScope
//! [`TRACE_SCHEMA_VERSION`]: trace::TRACE_SCHEMA_VERSION

pub mod alloc;
pub mod analyze;
pub mod counters;
pub mod export;
pub mod reservoir;
pub mod slo;
pub mod trace;

mod proptests;

pub use counters::{counters, CountersSnapshot, ResilienceSnapshot};
pub use trace::{
    compose_job_id, parse_jsonl_lossy, split_job_id, with_current, LifecycleCounts, Phase,
    SpanEvent, TraceScope, TraceSink, TRACE_SCHEMA_VERSION,
};
