#![cfg(test)]
//! Property tests for the SLO layer (`obs::slo`): the log-bucketed
//! histogram's relative-error bound against exact percentiles, exact
//! merge associativity/commutativity, and window-ring rotation against
//! a naive keep-everything model.

use crate::obs::slo::{LogHistogram, WindowRing, MIN_VALUE_MS};
use crate::testing::{check_no_shrink, gen_usize};
use crate::util::rng::Pcg32;

/// Log-uniform latency in [MIN_VALUE_MS, ~1e6 ms] — the range the
/// histogram's relative-error bound covers.
fn gen_latency(rng: &mut Pcg32) -> f64 {
    MIN_VALUE_MS * (rng.next_f64() * (1e9f64).ln()).exp()
}

fn gen_stream(rng: &mut Pcg32, max_len: usize) -> Vec<f64> {
    let len = gen_usize(rng, 1, max_len);
    (0..len).map(|_| gen_latency(rng)).collect()
}

fn exact_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank - 1]
}

#[test]
fn prop_percentiles_within_relative_error_of_exact() {
    check_no_shrink(
        "slo-hist-relative-error",
        |rng| gen_stream(rng, 300),
        |xs| {
            let mut h = LogHistogram::new();
            for &x in xs {
                h.record(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let bound = LogHistogram::relative_error_bound() + 1e-9;
            [50.0, 90.0, 95.0, 99.0].iter().all(|&p| {
                let exact = exact_nearest_rank(&sorted, p);
                let approx = h.percentile(p);
                (approx - exact).abs() <= bound * exact
            })
        },
    );
}

#[test]
fn prop_merge_is_associative_and_commutative() {
    check_no_shrink(
        "slo-hist-merge-assoc",
        |rng| (gen_stream(rng, 80), gen_stream(rng, 80), gen_stream(rng, 80)),
        |(xs, ys, zs)| {
            let hist = |vals: &[f64]| {
                let mut h = LogHistogram::new();
                for &v in vals {
                    h.record(v);
                }
                h
            };
            let (a, b, c) = (hist(xs), hist(ys), hist(zs));
            // (a + b) + c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a + (b + c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            // b + a (commutativity)
            let mut ba = b.clone();
            ba.merge(&a);
            let mut ab = a.clone();
            ab.merge(&b);
            left == right && ab == ba && left.count() == a.count() + b.count() + c.count()
        },
    );
}

#[test]
fn prop_window_rotation_matches_naive_model() {
    // Feed a ring and a keep-everything model the same (window index,
    // value) stream with non-decreasing indices (including idle gaps
    // larger than the ring), then check the sliding view equals the
    // model filtered to the last `n` windows.
    check_no_shrink(
        "slo-window-rotation",
        |rng| {
            let windows = gen_usize(rng, 1, 6);
            let events = gen_usize(rng, 1, 60);
            let mut idx = 0u64;
            let stream: Vec<(u64, f64)> = (0..events)
                .map(|_| {
                    idx += gen_usize(rng, 0, 8) as u64; // gaps may skip the whole ring
                    (idx, gen_latency(rng))
                })
                .collect();
            (windows, stream)
        },
        |(windows, stream)| {
            let mut ring = WindowRing::new(*windows);
            let mut model: Vec<(u64, f64)> = Vec::new();
            for &(idx, v) in stream {
                ring.record(idx, v);
                model.push((idx, v));
            }
            let cur = stream.last().map_or(0, |&(idx, _)| idx);
            let lo = cur.saturating_sub(*windows as u64 - 1);
            let mut expect = LogHistogram::new();
            for &(idx, v) in &model {
                if idx >= lo && idx <= cur {
                    expect.record(v);
                }
            }
            ring.sliding(cur) == expect
        },
    );
}
