//! Fixed-size reservoir sampler (Vitter's Algorithm R).
//!
//! Replaces the previously unbounded latency buffer in
//! `server::Metrics`: memory stays `O(cap)` under sustained serving
//! while percentiles remain an unbiased estimate of the full stream.
//! Below capacity the reservoir keeps *every* observation, so small-run
//! summaries (tests, short benches) are exact. The replacement RNG is a
//! deterministic [`Pcg32`] with a fixed seed — same stream in, same
//! samples out, on every run.

use crate::util::rng::Pcg32;

/// Default capacity used by `server::Metrics` for latency sampling.
pub const DEFAULT_CAP: usize = 4096;

/// Uniform reservoir sample over an unbounded stream of `f64`s.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Pcg32,
}

impl Default for Reservoir {
    /// The `server::Metrics` configuration: [`DEFAULT_CAP`] samples.
    fn default() -> Reservoir {
        Reservoir::new(DEFAULT_CAP)
    }
}

impl Reservoir {
    /// A reservoir keeping at most `cap` samples (`cap > 0`).
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "Reservoir: capacity must be positive");
        // Fixed seed/stream: sampling is deterministic by design.
        Reservoir { cap, seen: 0, samples: Vec::new(), rng: Pcg32::new(0x5dac_c0b5, 17) }
    }

    /// Observe one value. The i-th observation replaces a kept sample
    /// with probability cap/i (Algorithm R), so every prefix is a
    /// uniform sample of the stream so far.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
            return;
        }
        let j = self.rng.gen_range(0, self.seen - 1);
        if (j as usize) < self.cap {
            self.samples[j as usize] = x;
        }
    }

    /// The kept samples (unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Number of kept samples (== min(seen, cap)).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True before the first observation.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total observations pushed, kept or not.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Maximum kept samples.
    pub fn cap(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn exact_below_capacity() {
        let mut r = Reservoir::new(100);
        for i in 0..50 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 50);
        assert_eq!(r.seen(), 50);
        let expect: Vec<f64> = (0..50).map(|i| i as f64).collect();
        assert_eq!(r.samples(), expect.as_slice(), "below cap keeps everything, in order");
    }

    #[test]
    fn bounded_over_100k_observations() {
        let mut r = Reservoir::new(DEFAULT_CAP);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), DEFAULT_CAP, "memory stays bounded at capacity");
        assert_eq!(r.seen(), 100_000);
        for &x in r.samples() {
            assert!((0.0..100_000.0).contains(&x));
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Reservoir::new(64);
        let mut b = Reservoir::new(64);
        for i in 0..10_000 {
            let x = (i * 7 % 1013) as f64;
            a.push(x);
            b.push(x);
        }
        assert_eq!(a.samples(), b.samples());
    }

    #[test]
    fn sample_is_representative() {
        // Uniform stream 0..100k: the sampled mean and median should land
        // near the stream's (50k). Loose bounds — this is a sanity check
        // on Algorithm R's uniformity, not a statistical test.
        let mut r = Reservoir::new(DEFAULT_CAP);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        let m = stats::mean(r.samples());
        assert!((30_000.0..70_000.0).contains(&m), "mean={m}");
        let p50 = stats::percentile(r.samples(), 50.0);
        assert!((30_000.0..70_000.0).contains(&p50), "p50={p50}");
    }
}
