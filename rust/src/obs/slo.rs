//! Windowed SLO tracking: log-bucketed latency histograms with a fixed
//! relative-error bound, a ring of time windows for sliding
//! percentiles, and the per-[`Priority`] results ledger (goodput,
//! deadline-miss rate, cancel-ack latency, queue-full rejects).
//!
//! The histogram is HDR-style: bucket boundaries grow geometrically by
//! [`GAMMA`] from [`MIN_VALUE_MS`], so any recorded value `v >=
//! MIN_VALUE_MS` is represented by its bucket's geometric midpoint with
//! relative error at most [`LogHistogram::relative_error_bound`] =
//! `sqrt(GAMMA) - 1` (~2.5%). Values below `MIN_VALUE_MS` clamp into
//! bucket 0 (absolute error <= 1 microsecond); values beyond the last
//! bucket boundary (~20 hours) clamp into the final bucket. Buckets are
//! plain `u64` counts, so [`LogHistogram::merge`] is element-wise
//! addition — exactly associative and commutative, which is what lets
//! window merges and cross-thread aggregation commute (property-tested
//! in `obs::proptests`).
//!
//! Percentiles use the *nearest-rank* convention: `percentile(p)`
//! returns the representative value of the bucket holding the
//! `ceil(p/100 * count)`-th smallest sample. Because bucket assignment
//! is monotone in the value, that representative is within the relative
//! error bound of the exact nearest-rank sample of the raw stream.
//!
//! [`WindowRing`] keys everything off an explicit `u64` window index
//! (no wall clock inside), so rotation is deterministic and testable;
//! [`SloTracker`] layers `Instant`-based indexing on top for
//! `server::Metrics`. Per the standing invariant, all of this is an
//! observer: nothing here may feed batching, cache keys, or outputs.
//!
//! [`Priority`]: crate::server::api::Priority

use std::time::{Duration, Instant};

use crate::server::api::Priority;
use crate::util::json::Json;

/// Smallest distinguishable latency (1 microsecond, in milliseconds).
pub const MIN_VALUE_MS: f64 = 1e-3;

/// Geometric bucket growth factor. `sqrt(GAMMA) - 1` is the relative
/// error bound on any reported percentile.
pub const GAMMA: f64 = 1.05;

/// Bucket count: `MIN_VALUE_MS * GAMMA^511` is ~7e7 ms (~20 hours), far
/// past any serving latency this system produces.
pub const BUCKETS: usize = 512;

/// Log-bucketed latency histogram with bounded relative error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    /// `counts[0]` holds values <= MIN_VALUE_MS; `counts[i]` (i >= 1)
    /// holds values in `(MIN * GAMMA^(i-1), MIN * GAMMA^i]`.
    counts: Vec<u64>,
    count: u64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram { counts: vec![0; BUCKETS], count: 0 }
    }

    /// Maximum relative error of any percentile, for values inside
    /// `[MIN_VALUE_MS, MIN_VALUE_MS * GAMMA^(BUCKETS-1)]`:
    /// `sqrt(GAMMA) - 1` (~2.47% at GAMMA = 1.05).
    pub fn relative_error_bound() -> f64 {
        GAMMA.sqrt() - 1.0
    }

    fn bucket(v: f64) -> usize {
        if !(v > MIN_VALUE_MS) {
            return 0; // includes v <= MIN, v <= 0, and NaN (recorded as floor)
        }
        let i = 1 + ((v / MIN_VALUE_MS).ln() / GAMMA.ln()).floor() as usize;
        i.min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the value reported for any
    /// sample that landed there.
    fn representative(i: usize) -> f64 {
        if i == 0 {
            MIN_VALUE_MS
        } else {
            MIN_VALUE_MS * GAMMA.powf(i as f64 - 0.5)
        }
    }

    /// Record one latency in milliseconds. NaN clamps to bucket 0.
    pub fn record(&mut self, ms: f64) {
        self.counts[Self::bucket(ms)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Element-wise addition of bucket counts: exactly associative and
    /// commutative (all-integer state), so merge order never matters.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
    }

    /// Nearest-rank percentile (`p` in 0..=100) over the bucketed
    /// sample: the representative of the bucket holding the
    /// `ceil(p/100 * count)`-th smallest value. Returns 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::representative(i);
            }
        }
        Self::representative(BUCKETS - 1)
    }

    /// Approximate mean from bucket representatives (same error bound
    /// as the percentiles).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| c as f64 * Self::representative(i))
            .sum();
        sum / self.count as f64
    }
}

/// Ring of `n` time windows, each holding a [`LogHistogram`], keyed by
/// an explicit monotone window index. A slot is lazily reset when a
/// newer index maps onto it, and `sliding(idx)` merges only the slots
/// whose stored index falls inside the last `n` windows ending at
/// `idx` — so slots that were skipped entirely (idle gaps) never leak
/// stale samples into the sliding view.
#[derive(Debug)]
pub struct WindowRing {
    /// `(window index, histogram)`; `u64::MAX` marks a never-used slot.
    slots: Vec<(u64, LogHistogram)>,
}

impl WindowRing {
    pub fn new(windows: usize) -> WindowRing {
        let n = windows.max(1);
        WindowRing { slots: (0..n).map(|_| (u64::MAX, LogHistogram::new())).collect() }
    }

    pub fn windows(&self) -> usize {
        self.slots.len()
    }

    /// Record `ms` into window `idx` (indices must be supplied
    /// non-decreasing for the sliding view to be meaningful).
    pub fn record(&mut self, idx: u64, ms: f64) {
        let n = self.slots.len() as u64;
        let slot = &mut self.slots[(idx % n) as usize];
        if slot.0 != idx {
            slot.0 = idx;
            slot.1 = LogHistogram::new();
        }
        slot.1.record(ms);
    }

    /// Merge of the last `windows()` windows ending at `idx` inclusive.
    pub fn sliding(&self, idx: u64) -> LogHistogram {
        let n = self.slots.len() as u64;
        let lo = idx.saturating_sub(n - 1);
        let mut out = LogHistogram::new();
        for (slot_idx, hist) in &self.slots {
            if *slot_idx != u64::MAX && *slot_idx >= lo && *slot_idx <= idx {
                out.merge(hist);
            }
        }
        out
    }
}

/// Default window width for [`SloTracker`].
pub const DEFAULT_WINDOW: Duration = Duration::from_secs(1);
/// Default window count: 64 x 1s ~= the last minute of traffic.
pub const DEFAULT_WINDOWS: usize = 64;

/// Wall-clock front-end over [`WindowRing`]: maps `Instant::now()`
/// elapsed-since-start onto window indices. Besides the latency ring it
/// keeps two outcome rings (terminals / deadline misses) so the sliding
/// deadline-miss *rate* is available to [`ScalePolicy`] — the recorded
/// values there are ignored, only the windowed counts matter.
#[derive(Debug)]
pub struct SloTracker {
    start: Instant,
    window: Duration,
    ring: WindowRing,
    /// One sample per terminal outcome (done / failed / cancelled /
    /// deadline miss) — the miss-rate denominator.
    terminals: WindowRing,
    /// One sample per deadline miss — the miss-rate numerator.
    misses: WindowRing,
}

impl Default for SloTracker {
    fn default() -> SloTracker {
        SloTracker::new(DEFAULT_WINDOW, DEFAULT_WINDOWS)
    }
}

impl SloTracker {
    pub fn new(window: Duration, windows: usize) -> SloTracker {
        SloTracker {
            start: Instant::now(),
            window: window.max(Duration::from_millis(1)),
            ring: WindowRing::new(windows),
            terminals: WindowRing::new(windows),
            misses: WindowRing::new(windows),
        }
    }

    fn idx(&self) -> u64 {
        (self.start.elapsed().as_nanos() / self.window.as_nanos().max(1)) as u64
    }

    pub fn record(&mut self, ms: f64) {
        let i = self.idx();
        self.ring.record(i, ms);
    }

    /// Record one terminal outcome into the miss-rate rings.
    pub fn record_outcome(&mut self, missed_deadline: bool) {
        let i = self.idx();
        self.terminals.record(i, 0.0);
        if missed_deadline {
            self.misses.record(i, 0.0);
        }
    }

    /// Histogram over the sliding window ending now.
    pub fn windowed(&self) -> LogHistogram {
        self.ring.sliding(self.idx())
    }

    /// `(deadline misses, terminal outcomes)` inside the sliding window.
    pub fn windowed_outcomes(&self) -> (u64, u64) {
        let i = self.idx();
        (self.misses.sliding(i).count(), self.terminals.sliding(i).count())
    }

    pub fn window_secs(&self) -> f64 {
        self.window.as_secs_f64()
    }

    pub fn windows(&self) -> usize {
        self.ring.windows()
    }
}

// -------------------------------------------------------------- autoscale

/// Autoscaling targets evaluated over the sliding SLO window. Like the
/// rest of this module it is an *observer*: the advice stream is for an
/// external scaler (or a human watching `serve --monitor`) — nothing in
/// the serving path may branch on it (standing invariant).
#[derive(Debug, Clone, PartialEq)]
pub struct ScalePolicy {
    /// Advise up when the windowed p95 exceeds this.
    pub p95_target_ms: f64,
    /// Advise up when the windowed deadline-miss rate exceeds this.
    pub miss_rate_target: f64,
    /// Minimum windowed samples before any non-[`ScaleAdvice::Hold`]
    /// advice — a handful of requests after an idle gap must not flap
    /// the fleet.
    pub min_samples: u64,
}

impl Default for ScalePolicy {
    fn default() -> ScalePolicy {
        ScalePolicy { p95_target_ms: 500.0, miss_rate_target: 0.05, min_samples: 16 }
    }
}

/// What the policy recommends right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScaleAdvice {
    /// Breach: add capacity.
    Up,
    /// Inside targets (or not enough samples to say).
    #[default]
    Hold,
    /// Comfortably under targets: capacity can shrink.
    Down,
}

impl ScaleAdvice {
    pub fn as_str(self) -> &'static str {
        match self {
            ScaleAdvice::Up => "up",
            ScaleAdvice::Hold => "hold",
            ScaleAdvice::Down => "down",
        }
    }
}

impl ScalePolicy {
    /// Evaluate the windowed observations against the targets.
    ///
    /// `windowed_count` is the latency-sample count, `windowed_misses` /
    /// `windowed_terminals` the outcome counts (a deadline-missed job
    /// never records a latency, so miss pressure must be judged on its
    /// own denominator — a fleet where *every* job misses still advises
    /// up). `Down` needs clear margin on both axes (half the target),
    /// so advice is hysteretic around the breach point rather than
    /// oscillating on it.
    pub fn advise(
        &self,
        windowed_p95_ms: f64,
        windowed_count: u64,
        windowed_misses: u64,
        windowed_terminals: u64,
    ) -> ScaleAdvice {
        let miss_rate = if windowed_terminals == 0 {
            0.0
        } else {
            windowed_misses as f64 / windowed_terminals as f64
        };
        if windowed_terminals >= self.min_samples && miss_rate > self.miss_rate_target {
            return ScaleAdvice::Up;
        }
        if windowed_count >= self.min_samples && windowed_p95_ms > self.p95_target_ms {
            return ScaleAdvice::Up;
        }
        if windowed_count >= self.min_samples
            && windowed_p95_ms < 0.5 * self.p95_target_ms
            && miss_rate <= 0.5 * self.miss_rate_target
        {
            return ScaleAdvice::Down;
        }
        ScaleAdvice::Hold
    }
}

/// Per-lane slice of the results ledger.
#[derive(Debug, Clone, Default)]
pub struct LaneLedger {
    /// Jobs delivered `Done` on this lane (goodput numerator).
    pub completed: u64,
    /// Jobs dropped for an elapsed deadline.
    pub deadline_misses: u64,
    /// Jobs that ended cancelled.
    pub cancellations: u64,
    /// Submissions bounced by bounded admission (queue full).
    pub rejected: u64,
    /// Full-depth denoising steps executed for completed jobs.
    pub steps_full: u64,
    /// PAS partial (approximated) steps executed for completed jobs.
    pub steps_partial: u64,
    /// End-to-end latency of completed jobs.
    pub latency_ms: LogHistogram,
    /// `CancelToken` fire -> cancellation observed (terminal recorded).
    pub cancel_ack_ms: LogHistogram,
}

impl LaneLedger {
    /// Fraction of terminal outcomes that missed their deadline.
    pub fn deadline_miss_rate(&self) -> f64 {
        let terminals = self.completed + self.deadline_misses + self.cancellations;
        if terminals == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / terminals as f64
        }
    }
}

/// Per-[`Priority`] results ledger — the structure ROADMAP item 2's
/// traffic engine consumes: goodput, deadline-miss rate, cancel-ack
/// latency and rejects, each with its own latency histogram.
#[derive(Debug, Clone, Default)]
pub struct PriorityLedger {
    lanes: [LaneLedger; 3],
}

impl PriorityLedger {
    pub fn lane(&self, p: Priority) -> &LaneLedger {
        &self.lanes[p.index()]
    }

    pub fn on_done(&mut self, p: Priority, latency_ms: f64) {
        let lane = &mut self.lanes[p.index()];
        lane.completed += 1;
        lane.latency_ms.record(latency_ms);
    }

    /// `ack_ms` is the fire-to-observation latency when the token's
    /// fire time is known (it always is on the server paths; `None`
    /// covers externally-constructed tokens that were never fired).
    pub fn on_cancelled(&mut self, p: Priority, ack_ms: Option<f64>) {
        let lane = &mut self.lanes[p.index()];
        lane.cancellations += 1;
        if let Some(ms) = ack_ms {
            lane.cancel_ack_ms.record(ms);
        }
    }

    pub fn on_deadline_miss(&mut self, p: Priority) {
        self.lanes[p.index()].deadline_misses += 1;
    }

    pub fn on_rejected(&mut self, p: Priority) {
        self.lanes[p.index()].rejected += 1;
    }

    /// Attribute executed step counts (full vs PAS-partial) to a lane.
    pub fn on_steps(&mut self, p: Priority, full: u64, partial: u64) {
        let lane = &mut self.lanes[p.index()];
        lane.steps_full += full;
        lane.steps_partial += partial;
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            Priority::ALL
                .iter()
                .map(|&p| {
                    let lane = self.lane(p);
                    Json::obj(vec![
                        ("priority", Json::str(p.as_str())),
                        ("completed", Json::Num(lane.completed as f64)),
                        ("deadline_misses", Json::Num(lane.deadline_misses as f64)),
                        ("deadline_miss_rate", Json::Num(lane.deadline_miss_rate())),
                        ("cancellations", Json::Num(lane.cancellations as f64)),
                        ("rejected", Json::Num(lane.rejected as f64)),
                        ("steps_full", Json::Num(lane.steps_full as f64)),
                        ("steps_partial", Json::Num(lane.steps_partial as f64)),
                        ("latency_p50_ms", Json::Num(lane.latency_ms.percentile(50.0))),
                        ("latency_p95_ms", Json::Num(lane.latency_ms.percentile(95.0))),
                        ("cancel_acks", Json::Num(lane.cancel_ack_ms.count() as f64)),
                        ("cancel_ack_p50_ms", Json::Num(lane.cancel_ack_ms.percentile(50.0))),
                        ("cancel_ack_p95_ms", Json::Num(lane.cancel_ack_ms.percentile(95.0))),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentile_respects_relative_error_bound() {
        let mut h = LogHistogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.37).collect();
        for &x in &xs {
            h.record(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 95.0, 99.0] {
            let exact = sorted[((p / 100.0 * sorted.len() as f64).ceil() as usize).max(1) - 1];
            let approx = h.percentile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(
                rel <= LogHistogram::relative_error_bound() + 1e-9,
                "p{p}: approx {approx} vs exact {exact} (rel {rel})"
            );
        }
    }

    #[test]
    fn histogram_clamps_tiny_values_to_floor_bucket() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-4.0);
        h.record(f64::NAN);
        h.record(1e-9);
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile(99.0), MIN_VALUE_MS);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10.0);
        b.record(1000.0);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab.count(), 2);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge is commutative");
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(95.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn window_ring_drops_expired_windows() {
        let mut r = WindowRing::new(4);
        r.record(0, 5.0);
        r.record(1, 50.0);
        // Window 0 is still inside the 4-wide view at idx 3...
        assert_eq!(r.sliding(3).count(), 2);
        // ...and out of it at idx 4, even though nothing overwrote the
        // slot yet (lazy reset must not leak stale windows).
        assert_eq!(r.sliding(4).count(), 1);
        assert_eq!(r.sliding(10).count(), 0);
    }

    #[test]
    fn window_ring_slot_reuse_resets_old_contents() {
        let mut r = WindowRing::new(2);
        r.record(0, 1.0);
        r.record(0, 1.0);
        r.record(2, 9.0); // same slot as window 0: must reset, not merge
        assert_eq!(r.sliding(2).count(), 1);
    }

    #[test]
    fn slo_tracker_windowed_sees_recent_samples() {
        let mut t = SloTracker::new(Duration::from_secs(60), 8);
        for i in 0..50 {
            t.record(10.0 + i as f64);
        }
        let w = t.windowed();
        assert_eq!(w.count(), 50);
        assert!(w.percentile(50.0) > 0.0);
    }

    #[test]
    fn slo_tracker_windowed_outcomes_count_misses_and_terminals() {
        let mut t = SloTracker::new(Duration::from_secs(60), 8);
        assert_eq!(t.windowed_outcomes(), (0, 0));
        for i in 0..10 {
            t.record_outcome(i % 5 == 0);
        }
        assert_eq!(t.windowed_outcomes(), (2, 10));
    }

    #[test]
    fn scale_policy_advises_up_on_p95_breach_and_down_with_margin() {
        let p = ScalePolicy { p95_target_ms: 100.0, miss_rate_target: 0.1, min_samples: 4 };
        // Not enough samples: hold, even on a breach.
        assert_eq!(p.advise(900.0, 3, 0, 3), ScaleAdvice::Hold);
        // Latency breach with samples: up.
        assert_eq!(p.advise(150.0, 10, 0, 10), ScaleAdvice::Up);
        // Comfortably under both targets: down.
        assert_eq!(p.advise(20.0, 10, 0, 10), ScaleAdvice::Down);
        // Under the p95 target but not by the required margin: hold
        // (hysteresis band between down-margin and the breach point).
        assert_eq!(p.advise(80.0, 10, 0, 10), ScaleAdvice::Hold);
    }

    #[test]
    fn scale_policy_judges_miss_pressure_on_its_own_denominator() {
        let p = ScalePolicy { p95_target_ms: 100.0, miss_rate_target: 0.1, min_samples: 4 };
        // Every job misses its deadline: no latency samples exist at
        // all, yet the advice must still be up.
        assert_eq!(p.advise(0.0, 0, 8, 8), ScaleAdvice::Up);
        // Miss rate just under target with fast latencies: down needs
        // the miss rate under *half* the target too.
        assert_eq!(p.advise(20.0, 20, 1, 20), ScaleAdvice::Down); // 5% = half of 10%
        assert_eq!(p.advise(20.0, 20, 2, 20), ScaleAdvice::Hold); // 10%: no down margin
        assert_eq!(p.advise(20.0, 20, 3, 20), ScaleAdvice::Up); // 15% > target
    }

    #[test]
    fn ledger_tracks_lanes_independently() {
        let mut l = PriorityLedger::default();
        l.on_done(Priority::High, 12.0);
        l.on_done(Priority::High, 14.0);
        l.on_deadline_miss(Priority::Low);
        l.on_cancelled(Priority::Normal, Some(3.0));
        l.on_rejected(Priority::Low);
        l.on_steps(Priority::High, 7, 3);
        assert_eq!(l.lane(Priority::High).completed, 2);
        assert_eq!(l.lane(Priority::High).steps_full, 7);
        assert_eq!(l.lane(Priority::High).steps_partial, 3);
        assert_eq!(l.lane(Priority::Normal).cancellations, 1);
        assert_eq!(l.lane(Priority::Normal).cancel_ack_ms.count(), 1);
        assert_eq!(l.lane(Priority::Low).rejected, 1);
        assert!((l.lane(Priority::Low).deadline_miss_rate() - 1.0).abs() < 1e-12);
        assert_eq!(l.lane(Priority::High).deadline_miss_rate(), 0.0);
    }

    #[test]
    fn ledger_json_is_parseable_and_ordered_by_priority() {
        let mut l = PriorityLedger::default();
        l.on_done(Priority::Normal, 25.0);
        let j = Json::parse(&l.to_json().to_string()).unwrap();
        let lanes = j.as_arr().unwrap();
        assert_eq!(lanes.len(), 3);
        assert_eq!(lanes[0].get_str("priority"), Some("high"));
        assert_eq!(lanes[1].get_str("priority"), Some("normal"));
        assert_eq!(lanes[1].get_usize("completed"), Some(1));
        assert!(lanes[1].get_f64("latency_p50_ms").unwrap() > 0.0);
    }
}
