//! Structured trace spans: the [`TraceSink`] ring + JSONL file sink, and
//! the thread-local [`TraceScope`] that attributes deep-layer events
//! (cache lookups, backend executes, denoise steps) to the job that
//! caused them. See the module header of [`crate::obs`] for the span
//! vocabulary.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Version of the span wire format. Any change to the span field set or
/// the meaning of a field must bump this (standing invariant); readers
/// reject other versions rather than misparse them.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// Default in-memory ring capacity (spans). Old spans are evicted FIFO;
/// the JSONL file sink, when configured, keeps everything.
pub const DEFAULT_RING_CAP: usize = 16_384;

/// What a span records. See the vocabulary table in [`crate::obs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Job admitted to the batcher queue (lifecycle entry).
    Queued,
    /// Job served from the request cache without queueing (lifecycle entry).
    CacheHit,
    /// Job placed into an executing batch of `batch` lanes.
    Scheduled,
    /// One denoising step (`step` index, `action` full/partial).
    Step,
    /// One VAE decode call over `batch` latents.
    Decode,
    /// One typed cache lookup (`namespace`, `hit`).
    CacheLookup,
    /// One typed cache write (`namespace`, `bytes` of encoded payload).
    CacheWrite,
    /// One backend execute (`backend`, `artifact`, `bytes` moved).
    Execute,
    /// Job finished successfully (terminal).
    Done,
    /// Job finished with an error (terminal).
    Failed,
    /// Job finished by cancellation (terminal).
    Cancelled,
}

impl Phase {
    /// Every phase, in declaration order.
    pub const ALL: [Phase; 11] = [
        Phase::Queued,
        Phase::CacheHit,
        Phase::Scheduled,
        Phase::Step,
        Phase::Decode,
        Phase::CacheLookup,
        Phase::CacheWrite,
        Phase::Execute,
        Phase::Done,
        Phase::Failed,
        Phase::Cancelled,
    ];

    /// Stable wire label.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::CacheHit => "cache-hit",
            Phase::Scheduled => "scheduled",
            Phase::Step => "step",
            Phase::Decode => "decode",
            Phase::CacheLookup => "cache-lookup",
            Phase::CacheWrite => "cache-write",
            Phase::Execute => "execute",
            Phase::Done => "done",
            Phase::Failed => "failed",
            Phase::Cancelled => "cancelled",
        }
    }

    /// Inverse of [`Phase::as_str`].
    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.as_str() == s)
    }

    /// Terminal phases — exactly one per traced job (standing invariant).
    pub fn is_terminal(self) -> bool {
        matches!(self, Phase::Done | Phase::Failed | Phase::Cancelled)
    }

    /// Lifecycle-entry phases — exactly one per traced job.
    pub fn is_entry(self) -> bool {
        matches!(self, Phase::Queued | Phase::CacheHit)
    }
}

/// One structured trace event. `seq` and `ts_us` are assigned by the
/// sink at record time (under one lock, so `seq` order and timestamp
/// order agree); all other fields are supplied by the instrumentation
/// site via the `with_*` builders.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    /// Record sequence number, unique and dense per sink.
    pub seq: u64,
    /// Microseconds since the sink's epoch (monotone clock).
    pub ts_us: u64,
    /// Originating job/request id ([`crate::server::JobId`] value, or 0
    /// for single-shot CLI runs).
    pub job: u64,
    /// What happened.
    pub phase: Phase,
    /// Denoising step index (`step` spans).
    pub step: Option<u64>,
    /// Step action label (`step` spans): `"full"` or `"partial"` under
    /// the default policy, `"<policy_id>:full"` / `"<policy_id>:partial"`
    /// under a non-default approximation policy (same field, wider
    /// vocabulary — no schema bump).
    pub action: Option<String>,
    /// Cache namespace (`cache-lookup` / `cache-write` spans).
    pub namespace: Option<String>,
    /// Lookup outcome (`cache-lookup` spans).
    pub hit: Option<bool>,
    /// Backend kind label (`execute` spans).
    pub backend: Option<String>,
    /// Executable artifact name (`execute` spans).
    pub artifact: Option<String>,
    /// Bytes moved or written (`execute` / `cache-write` spans).
    pub bytes: Option<u64>,
    /// Batch size / lane count (`scheduled` / `decode` spans).
    pub batch: Option<u64>,
    /// Duration of the operation, microseconds.
    pub dur_us: Option<u64>,
}

impl SpanEvent {
    /// A bare span for `job` in `phase`; decorate with the `with_*`
    /// builders. `seq`/`ts_us` are placeholders until recorded.
    pub fn new(job: u64, phase: Phase) -> SpanEvent {
        SpanEvent {
            seq: 0,
            ts_us: 0,
            job,
            phase,
            step: None,
            action: None,
            namespace: None,
            hit: None,
            backend: None,
            artifact: None,
            bytes: None,
            batch: None,
            dur_us: None,
        }
    }

    pub fn with_step(mut self, i: u64) -> SpanEvent {
        self.step = Some(i);
        self
    }

    pub fn with_action(mut self, action: &str) -> SpanEvent {
        self.action = Some(action.to_string());
        self
    }

    pub fn with_namespace(mut self, ns: &str) -> SpanEvent {
        self.namespace = Some(ns.to_string());
        self
    }

    pub fn with_hit(mut self, hit: bool) -> SpanEvent {
        self.hit = Some(hit);
        self
    }

    pub fn with_backend(mut self, backend: &str) -> SpanEvent {
        self.backend = Some(backend.to_string());
        self
    }

    pub fn with_artifact(mut self, artifact: &str) -> SpanEvent {
        self.artifact = Some(artifact.to_string());
        self
    }

    pub fn with_bytes(mut self, bytes: u64) -> SpanEvent {
        self.bytes = Some(bytes);
        self
    }

    pub fn with_batch(mut self, batch: u64) -> SpanEvent {
        self.batch = Some(batch);
        self
    }

    pub fn with_dur_us(mut self, dur_us: u64) -> SpanEvent {
        self.dur_us = Some(dur_us);
        self
    }

    /// JSON object for one JSONL line. `None` fields are omitted; the
    /// line always carries `"v"` = [`TRACE_SCHEMA_VERSION`].
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("v", Json::Num(TRACE_SCHEMA_VERSION as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("ts_us", Json::Num(self.ts_us as f64)),
            ("job", Json::Num(self.job as f64)),
            ("phase", Json::Str(self.phase.as_str().to_string())),
        ];
        if let Some(v) = self.step {
            fields.push(("step", Json::Num(v as f64)));
        }
        if let Some(v) = &self.action {
            fields.push(("action", Json::Str(v.clone())));
        }
        if let Some(v) = &self.namespace {
            fields.push(("namespace", Json::Str(v.clone())));
        }
        if let Some(v) = self.hit {
            fields.push(("hit", Json::Bool(v)));
        }
        if let Some(v) = &self.backend {
            fields.push(("backend", Json::Str(v.clone())));
        }
        if let Some(v) = &self.artifact {
            fields.push(("artifact", Json::Str(v.clone())));
        }
        if let Some(v) = self.bytes {
            fields.push(("bytes", Json::Num(v as f64)));
        }
        if let Some(v) = self.batch {
            fields.push(("batch", Json::Num(v as f64)));
        }
        if let Some(v) = self.dur_us {
            fields.push(("dur_us", Json::Num(v as f64)));
        }
        Json::obj(fields)
    }

    /// Inverse of [`SpanEvent::to_json`]. Rejects lines whose `"v"`
    /// differs from [`TRACE_SCHEMA_VERSION`] (schema invariant).
    pub fn from_json(j: &Json) -> Result<SpanEvent> {
        let v = j.get_usize("v").ok_or_else(|| anyhow!("span: missing version field"))? as u64;
        if v != TRACE_SCHEMA_VERSION {
            return Err(anyhow!("span: schema version {v}, expected {TRACE_SCHEMA_VERSION}"));
        }
        let phase_str = j.get_str("phase").ok_or_else(|| anyhow!("span: missing phase"))?;
        let phase =
            Phase::parse(phase_str).ok_or_else(|| anyhow!("span: unknown phase '{phase_str}'"))?;
        Ok(SpanEvent {
            seq: j.get_usize("seq").ok_or_else(|| anyhow!("span: missing seq"))? as u64,
            ts_us: j.get_usize("ts_us").ok_or_else(|| anyhow!("span: missing ts_us"))? as u64,
            job: j.get_usize("job").ok_or_else(|| anyhow!("span: missing job"))? as u64,
            phase,
            step: j.get_usize("step").map(|v| v as u64),
            action: j.get_str("action").map(str::to_string),
            namespace: j.get_str("namespace").map(str::to_string),
            hit: j.get("hit").and_then(Json::as_bool),
            backend: j.get_str("backend").map(str::to_string),
            artifact: j.get_str("artifact").map(str::to_string),
            bytes: j.get_usize("bytes").map(|v| v as u64),
            batch: j.get_usize("batch").map(|v| v as u64),
            dur_us: j.get_usize("dur_us").map(|v| v as u64),
        })
    }

    /// Parse one JSONL line.
    pub fn parse_line(line: &str) -> Result<SpanEvent> {
        let j = Json::parse(line).map_err(|e| anyhow!("span: bad JSON: {e}"))?;
        SpanEvent::from_json(&j)
    }
}

/// Parse a whole JSONL trace, tolerating a truncated tail.
///
/// A process that dies mid-write leaves a final line that is not valid
/// JSON; hard-erroring on it makes every crash trace unreadable. This
/// parser skips a *final* malformed-JSON line with a warning string
/// instead. Everything else stays strict: malformed JSON anywhere but
/// the last line, and well-formed lines that fail span validation
/// (wrong schema version, missing fields) on *any* line — truncation
/// cannot produce those — are hard errors. Line numbers in errors and
/// warnings are 1-based over the raw input.
pub fn parse_jsonl_lossy(text: &str) -> Result<(Vec<SpanEvent>, Vec<String>)> {
    let lines: Vec<(usize, &str)> = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .collect();
    let mut spans = Vec::with_capacity(lines.len());
    let mut warnings = Vec::new();
    for (pos, &(lineno, line)) in lines.iter().enumerate() {
        match SpanEvent::parse_line(line) {
            Ok(ev) => spans.push(ev),
            Err(e) => {
                let last = pos + 1 == lines.len();
                if last && Json::parse(line).is_err() {
                    warnings.push(format!(
                        "line {}: skipped truncated final line ({e:#})",
                        lineno + 1
                    ));
                } else {
                    return Err(anyhow!("line {}: {e:#}", lineno + 1));
                }
            }
        }
    }
    Ok((spans, warnings))
}

impl SpanEvent {
    /// Structural projection: everything except `seq`, `ts_us` and
    /// `dur_us`. Two same-seed deterministic runs must produce
    /// byte-identical structure sequences even though wall-clock fields
    /// differ.
    pub fn structure(&self) -> String {
        let mut out = format!("{} job={}", self.phase.as_str(), self.job);
        if let Some(v) = self.step {
            out.push_str(&format!(" step={v}"));
        }
        if let Some(v) = &self.action {
            out.push_str(&format!(" action={v}"));
        }
        if let Some(v) = &self.namespace {
            out.push_str(&format!(" ns={v}"));
        }
        if let Some(v) = self.hit {
            out.push_str(&format!(" hit={v}"));
        }
        if let Some(v) = &self.backend {
            out.push_str(&format!(" backend={v}"));
        }
        if let Some(v) = &self.artifact {
            out.push_str(&format!(" artifact={v}"));
        }
        if let Some(v) = self.bytes {
            out.push_str(&format!(" bytes={v}"));
        }
        if let Some(v) = self.batch {
            out.push_str(&format!(" batch={v}"));
        }
        out
    }
}

/// Newline-joined [`SpanEvent::structure`] of a span sequence.
pub fn structure_lines(events: &[SpanEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.structure());
        out.push('\n');
    }
    out
}

/// Job lifecycle counts taken under one lock — the *consistent*
/// counterpart to the relaxed per-atomic reads of `Metrics::summary`.
/// `terminals() <= enqueued` holds in every snapshot by construction:
/// entry and terminal spans for a job are recorded in order, and both
/// updates happen inside the same sink lock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LifecycleCounts {
    /// Jobs that entered the traced lifecycle (`queued` + `cache-hit`).
    pub enqueued: u64,
    /// Jobs that finished successfully.
    pub done: u64,
    /// Jobs that finished with an error.
    pub failed: u64,
    /// Jobs that finished by cancellation.
    pub cancelled: u64,
}

impl LifecycleCounts {
    /// Total terminal spans.
    pub fn terminals(&self) -> u64 {
        self.done + self.failed + self.cancelled
    }

    /// Jobs entered but not yet terminal.
    pub fn in_flight(&self) -> u64 {
        self.enqueued.saturating_sub(self.terminals())
    }
}

struct Inner {
    next_seq: u64,
    cap: usize,
    ring: VecDeque<SpanEvent>,
    counts: LifecycleCounts,
}

/// Lock-light span recorder: a bounded in-memory ring (always) plus an
/// optional JSONL file sink. One mutex guards the ring, sequence
/// counter, timestamps and lifecycle counts, so a single lock
/// acquisition yields a consistent view; the file writer has its own
/// lock and never blocks ring readers.
pub struct TraceSink {
    epoch: Instant,
    inner: Mutex<Inner>,
    file: Option<Mutex<BufWriter<File>>>,
    path: Option<PathBuf>,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("TraceSink")
            .field("spans", &g.next_seq)
            .field("ring", &g.ring.len())
            .field("cap", &g.cap)
            .field("path", &self.path)
            .finish()
    }
}

impl TraceSink {
    /// Ring-only sink with the given capacity.
    pub fn in_memory(cap: usize) -> Arc<TraceSink> {
        assert!(cap > 0, "TraceSink: capacity must be positive");
        Arc::new(TraceSink {
            epoch: Instant::now(),
            inner: Mutex::new(Inner {
                next_seq: 0,
                cap,
                ring: VecDeque::with_capacity(cap.min(1024)),
                counts: LifecycleCounts::default(),
            }),
            file: None,
            path: None,
        })
    }

    /// Ring sink that additionally appends every span as a JSONL line to
    /// `path` (truncating any existing file).
    pub fn with_file(cap: usize, path: &Path) -> Result<Arc<TraceSink>> {
        let f = File::create(path)
            .with_context(|| format!("trace: cannot create {}", path.display()))?;
        let sink = TraceSink {
            epoch: Instant::now(),
            inner: Mutex::new(Inner {
                next_seq: 0,
                cap,
                ring: VecDeque::with_capacity(cap.min(1024)),
                counts: LifecycleCounts::default(),
            }),
            file: Some(Mutex::new(BufWriter::new(f))),
            path: Some(path.to_path_buf()),
        };
        Ok(Arc::new(sink))
    }

    /// JSONL output path, if this sink has a file.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Record one span. Assigns `seq` and `ts_us` under the ring lock,
    /// updates lifecycle counts, evicts FIFO past capacity, and appends
    /// the JSONL line if a file sink is configured.
    pub fn record(&self, mut ev: SpanEvent) {
        let line = {
            let mut g = self.inner.lock().unwrap();
            ev.seq = g.next_seq;
            g.next_seq += 1;
            ev.ts_us = self.epoch.elapsed().as_micros() as u64;
            if ev.phase.is_entry() {
                g.counts.enqueued += 1;
            }
            match ev.phase {
                Phase::Done => g.counts.done += 1,
                Phase::Failed => g.counts.failed += 1,
                Phase::Cancelled => g.counts.cancelled += 1,
                _ => {}
            }
            if g.ring.len() == g.cap {
                g.ring.pop_front();
            }
            let line = self.file.as_ref().map(|_| ev.to_json().to_string());
            g.ring.push_back(ev);
            line
        };
        if let (Some(file), Some(line)) = (&self.file, line) {
            let mut w = file.lock().unwrap();
            // Ignore I/O errors: tracing must never take down the pipeline.
            let _ = writeln!(w, "{line}");
        }
    }

    /// Total spans recorded (including ones evicted from the ring).
    pub fn recorded(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Copy of the ring contents, oldest first.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let g = self.inner.lock().unwrap();
        g.ring.iter().cloned().collect()
    }

    /// Consistent lifecycle counts (single lock acquisition). These are
    /// cumulative — unaffected by ring eviction.
    pub fn lifecycle_counts(&self) -> LifecycleCounts {
        self.inner.lock().unwrap().counts
    }

    /// Flush the JSONL writer (no-op for ring-only sinks).
    pub fn flush(&self) {
        if let Some(file) = &self.file {
            let _ = file.lock().unwrap().flush();
        }
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static SCOPES: RefCell<Vec<(Arc<TraceSink>, u64)>> = RefCell::new(Vec::new());
}

/// RAII guard binding `(sink, job)` as the current trace context for
/// this thread. Scopes nest; instrumented code records against the
/// innermost one via [`with_current`]. Deliberately `!Send`: a scope
/// must be dropped on the thread that entered it.
pub struct TraceScope {
    _not_send: std::marker::PhantomData<*const ()>,
}

impl TraceScope {
    /// Enter a scope attributing subsequent spans on this thread to `job`.
    pub fn enter(sink: Arc<TraceSink>, job: u64) -> TraceScope {
        SCOPES.with(|s| s.borrow_mut().push((sink, job)));
        TraceScope { _not_send: std::marker::PhantomData }
    }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        SCOPES.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// Run `f` with the innermost trace scope on this thread, if any. The
/// thread-local borrow is released before `f` runs, so `f` may record
/// spans (but should not enter new scopes).
pub fn with_current<F: FnOnce(&TraceSink, u64)>(f: F) {
    let top = SCOPES.with(|s| s.borrow().last().cloned());
    if let Some((sink, job)) = top {
        f(&sink, job);
    }
}

// ------------------------------------------------- cross-process job ids

/// Compose a fleet-unique job id from a 32-bit origin tag (the wire
/// tier uses the server's process id) and a process-local counter.
///
/// Two `sd-acc serve --listen` processes sharing one cache directory
/// each write their own JSONL trace; joining those traces on `job`
/// only works if ids never collide across processes, so the listen
/// path seeds its `ServerConfig::job_id_base` with
/// `compose_job_id(pid, 0)` and local ids count up from there. The
/// span *schema* is untouched — `job` stays one `u64` field — so
/// `TRACE_SCHEMA_VERSION` does not move; readers that want the split
/// call [`split_job_id`]. In-process servers keep base 0, where
/// `compose_job_id(0, n) == n` reproduces the historical ids exactly.
pub fn compose_job_id(origin: u32, local: u32) -> u64 {
    ((origin as u64) << 32) | local as u64
}

/// Split a composed job id back into `(origin, local)`. For ids from
/// base-0 (in-process) servers the origin is 0.
pub fn split_job_id(job: u64) -> (u32, u32) {
    ((job >> 32) as u32, job as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_id_compose_split_round_trip() {
        assert_eq!(compose_job_id(0, 7), 7, "base-0 ids are the historical ids");
        assert_eq!(split_job_id(7), (0, 7));
        let id = compose_job_id(0xdead_beef, 42);
        assert_eq!(split_job_id(id), (0xdead_beef, 42));
        // Distinct origins can never collide, whatever their counters.
        assert_ne!(compose_job_id(1, 0), compose_job_id(2, 0));
        assert_ne!(compose_job_id(1, u32::MAX), compose_job_id(2, 0));
    }

    #[test]
    fn phase_labels_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::parse(p.as_str()), Some(p));
        }
        assert_eq!(Phase::parse("nope"), None);
    }

    #[test]
    fn span_json_round_trip() {
        let ev = SpanEvent::new(7, Phase::Execute)
            .with_backend("sim")
            .with_artifact("unet_b1")
            .with_bytes(4096)
            .with_dur_us(1234);
        let sink = TraceSink::in_memory(8);
        sink.record(ev);
        let got = sink.snapshot().remove(0);
        let line = got.to_json().to_string();
        let back = SpanEvent::parse_line(&line).unwrap();
        assert_eq!(back, got);
    }

    #[test]
    fn wrong_schema_version_rejected() {
        let ev = SpanEvent::new(1, Phase::Done);
        let line = ev.to_json().to_string();
        let bumped = line.replace(
            &format!("\"v\":{TRACE_SCHEMA_VERSION}"),
            &format!("\"v\":{}", TRACE_SCHEMA_VERSION + 1),
        );
        assert_ne!(line, bumped, "version field must appear in the line");
        assert!(SpanEvent::parse_line(&bumped).is_err());
    }

    #[test]
    fn ring_evicts_fifo_and_keeps_counts() {
        let sink = TraceSink::in_memory(4);
        for i in 0..10u64 {
            sink.record(SpanEvent::new(i, Phase::Queued));
        }
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(snap[0].job, 6, "oldest retained span is #6");
        assert_eq!(sink.recorded(), 10);
        // Lifecycle counts are cumulative, unaffected by eviction.
        assert_eq!(sink.lifecycle_counts().enqueued, 10);
    }

    #[test]
    fn seq_and_timestamps_are_monotone() {
        let sink = TraceSink::in_memory(64);
        for i in 0..20u64 {
            sink.record(SpanEvent::new(1, Phase::Step).with_step(i));
        }
        let snap = sink.snapshot();
        for w in snap.windows(2) {
            assert!(w[1].seq == w[0].seq + 1);
            assert!(w[1].ts_us >= w[0].ts_us);
        }
    }

    #[test]
    fn lifecycle_counts_are_internally_consistent() {
        let sink = TraceSink::in_memory(64);
        sink.record(SpanEvent::new(1, Phase::Queued));
        sink.record(SpanEvent::new(2, Phase::CacheHit));
        sink.record(SpanEvent::new(2, Phase::Done));
        sink.record(SpanEvent::new(1, Phase::Failed));
        let c = sink.lifecycle_counts();
        assert_eq!(c, LifecycleCounts { enqueued: 2, done: 1, failed: 1, cancelled: 0 });
        assert!(c.terminals() <= c.enqueued);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn scope_nesting_attributes_innermost() {
        let outer = TraceSink::in_memory(8);
        let inner = TraceSink::in_memory(8);
        let _a = TraceScope::enter(Arc::clone(&outer), 1);
        {
            let _b = TraceScope::enter(Arc::clone(&inner), 2);
            with_current(|sink, job| {
                assert_eq!(job, 2);
                sink.record(SpanEvent::new(job, Phase::Step).with_step(0));
            });
        }
        with_current(|sink, job| {
            assert_eq!(job, 1);
            sink.record(SpanEvent::new(job, Phase::Step).with_step(1));
        });
        assert_eq!(inner.snapshot().len(), 1);
        assert_eq!(outer.snapshot().len(), 1);
        assert_eq!(outer.snapshot()[0].job, 1);
    }

    #[test]
    fn no_scope_means_no_record() {
        let mut ran = false;
        with_current(|_, _| ran = true);
        assert!(!ran);
    }

    #[test]
    fn structure_ignores_wallclock_fields() {
        let mut a = SpanEvent::new(3, Phase::Step).with_step(5).with_action("full");
        let mut b = a.clone();
        a.seq = 10;
        a.ts_us = 999;
        a.dur_us = Some(1);
        b.seq = 20;
        b.ts_us = 111;
        b.dur_us = Some(2);
        assert_eq!(a.structure(), b.structure());
        assert_eq!(structure_lines(&[a]), structure_lines(&[b]));
    }

    #[test]
    fn lossy_parse_skips_truncated_final_line_with_warning() {
        let full = SpanEvent::new(1, Phase::Queued).to_json().to_string();
        let half = &full[..full.len() / 2]; // a crash-truncated tail
        let text = format!("{full}\n{full}\n{half}");
        let (spans, warnings) = parse_jsonl_lossy(&text).unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("line 3"), "warning was: {}", warnings[0]);
        assert!(warnings[0].contains("truncated"));
    }

    #[test]
    fn lossy_parse_hard_errors_on_mid_file_garbage() {
        let full = SpanEvent::new(1, Phase::Queued).to_json().to_string();
        let text = format!("{full}\n{{broken\n{full}");
        let err = parse_jsonl_lossy(&text).unwrap_err();
        assert!(err.to_string().contains("line 2"), "error was: {err:#}");
    }

    #[test]
    fn lossy_parse_hard_errors_on_wrong_version_even_at_tail() {
        // A well-formed final line with a wrong schema version is not
        // truncation damage; the schema invariant stays strict.
        let full = SpanEvent::new(1, Phase::Queued).to_json().to_string();
        let bumped = full.replace(
            &format!("\"v\":{TRACE_SCHEMA_VERSION}"),
            &format!("\"v\":{}", TRACE_SCHEMA_VERSION + 1),
        );
        let text = format!("{full}\n{bumped}");
        assert!(parse_jsonl_lossy(&text).is_err());
    }

    #[test]
    fn lossy_parse_handles_clean_files_and_blank_lines() {
        let full = SpanEvent::new(1, Phase::Queued).to_json().to_string();
        let text = format!("{full}\n\n{full}\n");
        let (spans, warnings) = parse_jsonl_lossy(&text).unwrap();
        assert_eq!(spans.len(), 2);
        assert!(warnings.is_empty());
        assert_eq!(parse_jsonl_lossy("").unwrap().0.len(), 0);
    }

    #[test]
    fn jsonl_file_sink_writes_parseable_lines() {
        let dir =
            std::env::temp_dir().join(format!("sdacc_trace_jsonl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let sink = TraceSink::with_file(16, &path).unwrap();
        sink.record(SpanEvent::new(1, Phase::Queued));
        sink.record(
            SpanEvent::new(1, Phase::CacheLookup).with_namespace("request").with_hit(false),
        );
        sink.record(SpanEvent::new(1, Phase::Done));
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed: Vec<SpanEvent> =
            text.lines().map(|l| SpanEvent::parse_line(l).unwrap()).collect();
        assert_eq!(parsed, sink.snapshot());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
