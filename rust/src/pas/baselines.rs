//! Algorithm baselines for Table III.
//!
//! - DeepCache [38]: uniform layer skipping — no phase awareness; it runs
//!   the complete U-Net every N steps and a fixed shallow subset
//!   otherwise, from step 0. Executable on our partial artifacts.
//! - BK-SDM [22]: static architecture compression by block pruning +
//!   distillation. Retraining/distillation is out of scope (the paper's
//!   own criticism of the approach); we reproduce its *architecture* by
//!   removing the published block sets from the real inventory, which
//!   yields the MAC-reduction column; CLIP/FID columns in the bench are
//!   quoted from the BK-SDM paper and marked as such.

use crate::models::inventory::{total_macs, unet_ops, Block, LayerOp, UNetArch};
use crate::pas::cost::CostModel;
use crate::pas::plan::StepAction;

/// DeepCache-style uniform plan: Full every `interval` steps (starting at
/// step 0), Partial(l) otherwise — the whole run, no phases.
pub fn deepcache_plan(total_steps: usize, interval: usize, l: usize) -> Vec<StepAction> {
    assert!(interval >= 1);
    (0..total_steps)
        .map(|i| {
            if i % interval == 0 {
                StepAction::Full
            } else {
                StepAction::Partial(l)
            }
        })
        .collect()
}

/// MAC reduction of a DeepCache configuration under a cost model.
pub fn deepcache_reduction(cost: &CostModel, total_steps: usize, interval: usize, l: usize) -> f64 {
    cost.mac_reduction(&deepcache_plan(total_steps, interval, l))
}

/// BK-SDM variants (block-pruned U-Nets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BkSdmVariant {
    Base,
    Small,
    Tiny,
}

impl BkSdmVariant {
    pub fn label(&self) -> &'static str {
        match self {
            BkSdmVariant::Base => "BK-SDM-Base",
            BkSdmVariant::Small => "BK-SDM-Small",
            BkSdmVariant::Tiny => "BK-SDM-Tiny",
        }
    }

    /// Published image-quality scores on MS-COCO 5k (BK-SDM paper /
    /// Table III of SD-Acc) — quoted, not measured here.
    pub fn published_clip_fid(&self) -> (f64, f64) {
        match self {
            BkSdmVariant::Base => (0.2919, 29.16),
            BkSdmVariant::Small => (0.2713, 31.77),
            BkSdmVariant::Tiny => (0.2684, 31.74),
        }
    }

    /// Blocks removed relative to the full U-Net. BK-SDM removes the
    /// second (R, R+T) pair of each down stage and deep up blocks; Small
    /// additionally drops the middle block; Tiny further thins the up
    /// path.
    fn removed_blocks(&self) -> (Vec<Block>, bool) {
        // Base: the second (R, R+T) block of every down stage and its
        // mirrored up block are removed (depth halving per stage).
        let base: Vec<Block> = vec![
            Block::Down(3), Block::Down(6), Block::Down(9), Block::Down(12),
            Block::Up(2), Block::Up(5), Block::Up(8), Block::Up(11),
        ];
        match self {
            BkSdmVariant::Base => (base, false),
            BkSdmVariant::Small => (base, true),
            BkSdmVariant::Tiny => {
                let mut b = base;
                b.push(Block::Up(12));
                b.push(Block::Up(9));
                (b, true)
            }
        }
    }

    /// Pruned inventory for an architecture.
    pub fn pruned_ops(&self, arch: &UNetArch) -> Vec<LayerOp> {
        let (removed, drop_mid) = self.removed_blocks();
        unet_ops(arch)
            .into_iter()
            .filter(|o| {
                if removed.contains(&o.block) {
                    return false;
                }
                if drop_mid && o.block == Block::Mid {
                    return false;
                }
                true
            })
            .collect()
    }

    /// Whole-run MAC reduction (static architecture => per-step ratio).
    pub fn mac_reduction(&self, arch: &UNetArch) -> f64 {
        let full = total_macs(&unet_ops(arch)) as f64;
        let pruned = total_macs(&self.pruned_ops(arch)) as f64;
        full / pruned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::sd_v14;
    use crate::testing;

    #[test]
    fn deepcache_plan_uniform() {
        let p = deepcache_plan(10, 3, 2);
        assert_eq!(p[0], StepAction::Full);
        assert_eq!(p[3], StepAction::Full);
        assert_eq!(p[1], StepAction::Partial(2));
        assert_eq!(p.iter().filter(|&&a| a == StepAction::Full).count(), 4);
    }

    #[test]
    fn deepcache_reduction_band_matches_paper() {
        // Table III: DeepCache ~2.11x MAC reduction (interval 3, shallow
        // retained set) on SD v1.4 at 50 steps.
        let cost = CostModel::new(&sd_v14());
        let red = deepcache_reduction(&cost, 50, 3, 2);
        assert!((1.8..2.6).contains(&red), "deepcache reduction {red}");
    }

    #[test]
    fn bk_sdm_reductions_ordered_and_in_band() {
        // Table III: Base 1.51x, Small 1.56x, Tiny 1.65x.
        let arch = sd_v14();
        let base = BkSdmVariant::Base.mac_reduction(&arch);
        let small = BkSdmVariant::Small.mac_reduction(&arch);
        let tiny = BkSdmVariant::Tiny.mac_reduction(&arch);
        assert!(base < small && small < tiny, "{base} {small} {tiny}");
        assert!((1.2..1.9).contains(&base), "base {base}");
        assert!((1.3..2.1).contains(&tiny), "tiny {tiny}");
    }

    #[test]
    fn pas_beats_deepcache_at_matched_quality_knobs() {
        // The paper's headline Table III comparison: PAS-25/4 (2.84x)
        // vs DeepCache (2.11x) — phase awareness wins.
        let cost = CostModel::new(&sd_v14());
        let pas = cost.mac_reduction(&crate::pas::plan::PasConfig::pas25(4).plan(50));
        let dc = deepcache_reduction(&cost, 50, 3, 2);
        assert!(pas > dc, "pas {pas} <= deepcache {dc}");
    }

    #[test]
    fn deepcache_interval_one_is_original() {
        let cost = CostModel::new(&sd_v14());
        testing::check_no_shrink(
            "deepcache-interval1",
            |rng| testing::gen_usize(rng, 1, 100),
            |&n| (deepcache_reduction(&cost, n, 1, 2) - 1.0).abs() < 1e-12,
        );
    }
}
