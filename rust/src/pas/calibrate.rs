//! Calibration: shift scores (Eq. 1), phase division (Eq. 2), outliers.
//!
//! Drives the `unet_calib` artifact over a calibration prompt set and a
//! real denoising trajectory, measuring the main-branch input of every
//! up-block at every timestep — the A_t^i of Eq. 1. This reproduces the
//! measurement behind Fig. 4 and feeds D* and the outlier set to the
//! Fig. 7 search framework.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cache::Cache;
use crate::coordinator::Coordinator;
use crate::runtime::{Input, Runtime, Tensor};
use crate::scheduler::{make_sampler, NoiseSchedule};
use crate::util::json::Json;
use crate::util::stats;

/// Output of a calibration run.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    /// Normalised shift scores: `scores[i][t]` for up-block i+1 at step
    /// transition t (length steps-1), min-max scaled per block.
    pub scores: Vec<Vec<f64>>,
    /// Normalised predicted-noise magnitude curve (Fig. 4's noise line).
    pub noise: Vec<f64>,
    /// Eq. 2 phase-transition step D*.
    pub d_star: usize,
    /// Up-block indices (1-based) whose late-phase variation stays high.
    pub outliers: Vec<usize>,
    pub steps: usize,
    pub prompts: usize,
}

impl CalibrationReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("d_star", Json::num(self.d_star as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("prompts", Json::num(self.prompts as f64)),
            (
                "outliers",
                Json::Arr(self.outliers.iter().map(|&i| Json::num(i as f64)).collect()),
            ),
            ("noise", Json::arr_f64(&self.noise)),
            (
                "scores",
                Json::Arr(self.scores.iter().map(|s| Json::arr_f64(s)).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CalibrationReport> {
        let arr_f64 = |v: &Json| -> Vec<f64> {
            v.as_arr().unwrap_or(&[]).iter().filter_map(Json::as_f64).collect()
        };
        Ok(CalibrationReport {
            scores: j
                .get("scores")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing scores"))?
                .iter()
                .map(arr_f64)
                .collect(),
            noise: j.get("noise").map(arr_f64).unwrap_or_default(),
            d_star: j.get_usize("d_star").ok_or_else(|| anyhow!("missing d_star"))?,
            outliers: j
                .get("outliers")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            steps: j.get_usize("steps").unwrap_or(0),
            prompts: j.get_usize("prompts").unwrap_or(0),
        })
    }
}

/// Runs calibration trajectories through the calib artifact.
pub struct Calibrator<'a> {
    coord: &'a Coordinator,
}

impl<'a> Calibrator<'a> {
    pub fn new(coord: &'a Coordinator) -> Self {
        Calibrator { coord }
    }

    /// Measure shift scores over `prompts`, each a full `steps`-step
    /// denoising run of the complete U-Net (calib artifact, batch 1).
    pub fn run(&self, prompts: &[String], steps: usize, guidance: f32) -> Result<CalibrationReport> {
        let rt = self.coord.runtime();
        let n_blocks = 12usize;
        // raw[i][t] accumulated over prompts.
        let mut raw = vec![vec![0.0f64; steps - 1]; n_blocks];
        let mut noise_raw = vec![0.0f64; steps];

        for (pi, prompt) in prompts.iter().enumerate() {
            let ctx = Arc::new(self.coord.encode_prompts(std::slice::from_ref(prompt))?);
            let mut latent = Tensor::stack(&[self.coord.init_latent(1000 + pi as u64)])?;
            let sched = NoiseSchedule::new(rt.manifest().alpha_bar.clone());
            let mut sampler = make_sampler("ddim", sched, steps);
            let ts = sampler.timesteps().to_vec();
            let g = Arc::new(Tensor::scalar(guidance));
            let mut prev_ups: Option<Vec<Tensor>> = None;

            for (i, &t) in ts.iter().enumerate() {
                let t_in = Tensor::new(vec![1], vec![t as f32])?;
                let out = rt.execute(
                    &Runtime::unet_calib(1),
                    &[
                        Input::F32(latent.clone()),
                        Input::F32(t_in),
                        Input::F32Ref(Arc::clone(&ctx)),
                        Input::F32Ref(Arc::clone(&g)),
                    ],
                )?;
                let mut it = out.into_iter();
                let eps = it.next().ok_or_else(|| anyhow!("missing eps"))?;
                let ups: Vec<Tensor> = it.collect();
                if ups.len() != n_blocks {
                    anyhow::bail!("calib artifact returned {} block inputs", ups.len());
                }
                noise_raw[i] += stats::l2_norm(eps.data());
                if let Some(prev) = &prev_ups {
                    for b in 0..n_blocks {
                        raw[b][i - 1] += stats::shift_score(ups[b].data(), prev[b].data());
                    }
                }
                prev_ups = Some(ups);
                sampler.step_mut(i, latent.make_mut(), eps.data());
            }
        }

        let inv = 1.0 / prompts.len() as f64;
        for row in raw.iter_mut() {
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        for v in noise_raw.iter_mut() {
            *v *= inv;
        }
        Ok(analyse(raw, noise_raw, steps, prompts.len()))
    }

    /// Cache-aware calibration: a warm start returns the stored report
    /// (content-addressed on manifest digest + steps + prompts +
    /// guidance) without running a single trajectory; a cold start runs
    /// [`Calibrator::run`] and populates the store. The boolean is true
    /// on a cache hit.
    pub fn run_cached(
        &self,
        cache: &Cache,
        prompts: &[String],
        steps: usize,
        guidance: f32,
    ) -> Result<(CalibrationReport, bool)> {
        if let Some(rep) = cache.get_calibration(steps, prompts, guidance) {
            return Ok((rep, true));
        }
        let rep = self.run(prompts, steps, guidance)?;
        cache.put_calibration(steps, prompts, guidance, &rep)?;
        Ok((rep, false))
    }
}

/// Pure analysis half (unit-testable without a runtime): normalise,
/// detect outliers, split phases.
pub fn analyse(
    raw: Vec<Vec<f64>>,
    noise_raw: Vec<f64>,
    steps: usize,
    prompts: usize,
) -> CalibrationReport {
    let scores: Vec<Vec<f64>> = raw.iter().map(|r| stats::min_max_scale(r)).collect();
    let noise = stats::min_max_scale(&noise_raw);

    // Outliers (Sec. III-A key observation 2): blocks whose normalised
    // shift score stays high in the late phase. The paper notes a slight
    // terminal rise for every block (min-max scaling pins it to 1), so
    // the late window is [60%, 90%) — the refinement body, final spike
    // excluded.
    let t1 = scores[0].len();
    let late_start = (t1 * 3) / 5;
    let late_end = (t1 * 9 / 10).max(late_start + 1).min(t1);
    let late_means: Vec<f64> =
        scores.iter().map(|s| stats::mean(&s[late_start..late_end])).collect();
    let med = stats::percentile(&late_means, 50.0);
    let outliers: Vec<usize> = late_means
        .iter()
        .enumerate()
        .filter(|(_, &m)| m > (2.0 * med).max(0.25))
        .map(|(i, _)| i + 1)
        .collect();

    // Averaged curve excluding outliers (Eq. 2's S-bar).
    let mut avg = vec![0.0f64; t1];
    let mut cnt = 0usize;
    for (i, s) in scores.iter().enumerate() {
        if outliers.contains(&(i + 1)) {
            continue;
        }
        for (t, v) in s.iter().enumerate() {
            avg[t] += v;
        }
        cnt += 1;
    }
    let cnt = cnt.max(1);
    for v in avg.iter_mut() {
        *v /= cnt as f64;
    }
    // Eq. 2 over the main body (terminal transition excluded — see above).
    let body = &avg[..avg.len().saturating_sub(1).max(3)];
    let d_star = stats::kmeans2_split(body);

    CalibrationReport { scores, noise, d_star, outliers, steps, prompts }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic Fig. 4-shaped curves: most blocks decay after a knee,
    /// blocks 1-2 stay active late.
    fn synthetic_raw(steps: usize) -> Vec<Vec<f64>> {
        let t1 = steps - 1;
        (0..12)
            .map(|b| {
                (0..t1)
                    .map(|t| {
                        let x = t as f64 / t1 as f64;
                        let early = (-6.0 * (x - 0.12) * (x - 0.12)).exp();
                        let late = if b < 2 { 0.55 + 0.3 * (8.0 * x).sin().abs() } else { 0.04 };
                        if x < 0.45 {
                            0.6 + 0.4 * early
                        } else {
                            late
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn analysis_finds_top_block_outliers_and_midpoint() {
        let steps = 50;
        let raw = synthetic_raw(steps);
        let noise: Vec<f64> = (0..steps).map(|t| 1.0 / (1.0 + t as f64)).collect();
        let rep = analyse(raw, noise, steps, 1);
        assert!(rep.outliers.contains(&1), "outliers {:?}", rep.outliers);
        assert!(rep.outliers.contains(&2));
        assert!(!rep.outliers.contains(&7));
        // The knee sits at x=0.45 of 49 transitions ~ step 22.
        assert!((15..=30).contains(&rep.d_star), "D*={}", rep.d_star);
    }

    #[test]
    fn scores_normalised_to_unit_range() {
        let rep = analyse(synthetic_raw(30), vec![1.0; 30], 30, 1);
        for s in &rep.scores {
            assert!(s.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn report_json_roundtrip() {
        let rep = analyse(synthetic_raw(20), vec![0.5; 20], 20, 2);
        let j = rep.to_json();
        let back = CalibrationReport::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.d_star, rep.d_star);
        assert_eq!(back.outliers, rep.outliers);
        assert_eq!(back.scores.len(), rep.scores.len());
        assert!((back.scores[3][5] - rep.scores[3][5]).abs() < 1e-9);
    }
}
