//! The cost function f(l) and Eq. 3 MAC reduction.
//!
//! f(l) = MACs of running the first l downsampling + upsampling blocks,
//! normalised by the full U-Net (Fig. 6, purple curve). l = n_blocks + 1
//! (13 for 4-level U-Nets) denotes the entire network incl. the middle
//! block.

use crate::models::inventory::{block_macs, unet_ops, Block, UNetArch};
use crate::pas::plan::StepAction;
use crate::quant::format::QuantScheme;

/// Per-architecture cost model derived from the real layer inventory.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// MACs of down-block i (1-based index 0 unused).
    pub down: Vec<u64>,
    /// MACs of up-block i (1-based).
    pub up: Vec<u64>,
    pub mid: u64,
    pub total: u64,
    pub n_blocks: usize,
}

impl CostModel {
    pub fn new(arch: &UNetArch) -> CostModel {
        let ops = unet_ops(arch);
        let bm = block_macs(&ops);
        let n_blocks = bm.keys().filter(|b| matches!(b, Block::Down(_))).count();
        let mut down = vec![0u64; n_blocks + 1];
        let mut up = vec![0u64; n_blocks + 1];
        let mut mid = 0;
        for (b, macs) in &bm {
            match b {
                Block::Down(i) => down[*i] = *macs,
                Block::Up(i) => up[*i] = *macs,
                Block::Mid => mid = *macs,
                _ => {}
            }
        }
        let total = down.iter().sum::<u64>() + up.iter().sum::<u64>() + mid;
        CostModel { down, up, mid, total, n_blocks }
    }

    /// Absolute MACs of running the first `l` down + up blocks; `l` =
    /// n_blocks + 1 means the full network (middle included).
    pub fn macs_at(&self, l: usize) -> u64 {
        assert!(l >= 1 && l <= self.n_blocks + 1, "l={l} out of range");
        if l == self.n_blocks + 1 {
            return self.total;
        }
        self.down[1..=l].iter().sum::<u64>() + self.up[1..=l].iter().sum::<u64>()
    }

    /// Normalised cost f(l) in (0, 1].
    pub fn f(&self, l: usize) -> f64 {
        self.macs_at(l) as f64 / self.total as f64
    }

    /// MACs of one timestep under a step action.
    pub fn step_macs(&self, action: StepAction) -> u64 {
        match action {
            StepAction::Full => self.total,
            StepAction::Partial(l) => self.macs_at(l),
        }
    }

    /// Eq. 3: MAC reduction of a whole plan, T / sum_t f(l_t).
    pub fn mac_reduction(&self, plan: &[StepAction]) -> f64 {
        let spent: f64 = plan.iter().map(|&a| self.f(match a {
            StepAction::Full => self.n_blocks + 1,
            StepAction::Partial(l) => l,
        })).sum();
        plan.len() as f64 / spent
    }

    /// Average MACs per step under a plan.
    pub fn plan_macs(&self, plan: &[StepAction]) -> u64 {
        plan.iter().map(|&a| self.step_macs(a)).sum()
    }

    /// Precision-scaled effective MACs of one full step: logical MACs
    /// weighted by the multiplier width the scheme needs relative to a
    /// `native_bits`-wide datapath (an int8 MAC on a 16-bit array costs
    /// half a native MAC slot; fp32 costs two).
    pub fn effective_macs(&self, scheme: QuantScheme, native_bits: usize) -> f64 {
        self.total as f64 * scheme.mac_bits() as f64 / native_bits as f64
    }

    /// Eq. 3 composed with mixed precision: the phase-aware MAC saving
    /// multiplies with the multiplier-width saving, since partial steps
    /// and narrow MACs cut orthogonal axes (steps x layers vs bits).
    pub fn mac_reduction_quant(
        &self,
        plan: &[StepAction],
        scheme: QuantScheme,
        native_bits: usize,
    ) -> f64 {
        self.mac_reduction(plan) * native_bits as f64 / scheme.mac_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::{sd_tiny, sd_v14};
    use crate::pas::plan::StepAction::{Full, Partial};

    #[test]
    fn f_monotone_increasing_and_capped() {
        let cm = CostModel::new(&sd_v14());
        assert_eq!(cm.n_blocks, 12);
        let mut prev = 0.0;
        for l in 1..=13 {
            let f = cm.f(l);
            assert!(f > prev, "f({l})={f} not increasing");
            prev = f;
        }
        assert!((cm.f(13) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top_blocks_are_cheap_fraction() {
        // Fig. 6: the first two block pairs are a small share of MACs —
        // that is why retaining only them is so profitable.
        let cm = CostModel::new(&sd_v14());
        assert!(cm.f(2) < 0.40, "f(2)={}", cm.f(2));
        assert!(cm.f(2) > 0.05);
    }

    #[test]
    fn eq3_reduces_to_one_for_all_full() {
        let cm = CostModel::new(&sd_v14());
        let plan = vec![Full; 50];
        assert!((cm.mac_reduction(&plan) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eq3_matches_hand_computation() {
        let cm = CostModel::new(&sd_v14());
        let plan = vec![Full, Partial(2), Partial(2), Full];
        let expect = 4.0 / (1.0 + cm.f(2) + cm.f(2) + 1.0);
        assert!((cm.mac_reduction(&plan) - expect).abs() < 1e-12);
    }

    #[test]
    fn paper_config_reduction_in_table2_band() {
        // PAS-25/4 on v1.4 must land near the paper's 2.84x (Table II).
        let cm = CostModel::new(&sd_v14());
        let cfg = crate::pas::plan::PasConfig {
            t_sketch: 25, t_complete: 4, t_sparse: 4, l_sketch: 2, l_refine: 2,
        };
        let plan = cfg.plan(50);
        let red = cm.mac_reduction(&plan);
        assert!((2.3..3.4).contains(&red), "PAS-25/4 reduction {red}");
    }

    #[test]
    fn tiny_model_cost_model_works() {
        let cm = CostModel::new(&sd_tiny());
        assert_eq!(cm.n_blocks, 12);
        assert!(cm.f(1) < cm.f(3));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn l_zero_rejected() {
        CostModel::new(&sd_tiny()).macs_at(0);
    }

    #[test]
    fn precision_composes_multiplicatively_with_pas() {
        let cm = CostModel::new(&sd_v14());
        let plan = crate::pas::plan::PasConfig::pas25(4).plan(50);
        let base = cm.mac_reduction(&plan);
        // W8A8 on a 16-bit datapath doubles the reduction; fp32 halves it.
        let w8 = cm.mac_reduction_quant(&plan, QuantScheme::w8a8(), 16);
        let f32r = cm.mac_reduction_quant(&plan, QuantScheme::fp32(), 16);
        assert!((w8 - 2.0 * base).abs() < 1e-9);
        assert!((f32r - 0.5 * base).abs() < 1e-9);
        // Effective MACs scale the same way.
        assert!(
            (cm.effective_macs(QuantScheme::w8a8(), 16) - cm.total as f64 * 0.5).abs() < 1e-6
        );
    }
}
