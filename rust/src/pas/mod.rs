//! Phase-aware sampling (PAS) — the paper's algorithmic contribution
//! (Sec. III).
//!
//! - [`cost`]: the block cost function f(l) and Eq. 3 MAC reduction,
//!   computed from the real model inventories (models::inventory).
//! - [`plan`]: the {T_sketch, T_complete, T_sparse, L_sketch, L_refine}
//!   hyper-parameter set expanded into a per-timestep action plan.
//! - [`calibrate`]: shift-score measurement (Eq. 1), phase division
//!   (Eq. 2) and outlier detection over real denoising trajectories.
//! - [`search`]: the Fig. 7 optimisation framework — enumerate feasible
//!   configurations under user constraints, rank by MAC reduction.
//! - [`baselines`]: DeepCache-style uniform skipping and BK-SDM-style
//!   static pruning for Table III.

pub mod baselines;
pub mod calibrate;
pub mod cost;
pub mod plan;
pub mod search;

pub use calibrate::{CalibrationReport, Calibrator};
pub use cost::CostModel;
pub use plan::{PasConfig, SamplingPlan, StepAction};
