//! Phase-aware sampling plans (Sec. III-B, Fig. 5).

/// What to execute at one denoising timestep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepAction {
    /// Complete U-Net; refreshes the feature cache.
    Full,
    /// Only the top `l` block pairs, consuming the cached entry point.
    Partial(usize),
}

impl StepAction {
    /// Stable label for traces and per-action counters. The partial cut
    /// level is deliberately dropped: the label names the action class.
    pub fn label(&self) -> &'static str {
        match self {
            StepAction::Full => "full",
            StepAction::Partial(_) => "partial",
        }
    }
}

/// The paper's hyper-parameter set (Fig. 5 top).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PasConfig {
    /// Duration of the sketching phase (must be >= D*).
    pub t_sketch: usize,
    /// Leading timesteps always running the complete U-Net.
    pub t_complete: usize,
    /// Sampling period of the complete U-Net within the sketching phase.
    pub t_sparse: usize,
    /// Top blocks kept during sketching-phase partial steps.
    pub l_sketch: usize,
    /// Top blocks kept during the refinement phase.
    pub l_refine: usize,
}

impl PasConfig {
    /// Paper's default flavour "PAS-25/s" for 50-step SD v1.4-style runs.
    pub fn pas25(t_sparse: usize) -> PasConfig {
        PasConfig { t_sketch: 25, t_complete: 4, t_sparse, l_sketch: 2, l_refine: 2 }
    }

    /// Validity rules from Sec. III-B.
    pub fn validate(&self, total_steps: usize, d_star: usize, max_cut: usize) -> Result<(), String> {
        if self.t_sketch < d_star {
            return Err(format!("t_sketch {} < D* {d_star}", self.t_sketch));
        }
        if self.t_sketch > total_steps {
            return Err(format!("t_sketch {} > total {total_steps}", self.t_sketch));
        }
        if self.t_complete < 1 || self.t_complete > self.t_sketch {
            return Err(format!("t_complete {} out of range", self.t_complete));
        }
        if self.t_sparse < 2 {
            return Err("t_sparse must be >= 2 (1 would mean no compression)".into());
        }
        if self.l_refine < 1 || self.l_sketch < self.l_refine {
            return Err(format!(
                "need l_sketch {} >= l_refine {} >= 1",
                self.l_sketch, self.l_refine
            ));
        }
        if self.l_sketch > max_cut {
            return Err(format!("l_sketch {} > artifact max cut {max_cut}", self.l_sketch));
        }
        Ok(())
    }

    /// Expand into the per-timestep action plan (Fig. 5 bottom):
    /// - steps [0, t_complete): Full,
    /// - steps [t_complete, t_sketch): Full every t_sparse steps,
    ///   Partial(l_sketch) otherwise,
    /// - steps [t_sketch, total): Partial(l_refine).
    pub fn plan(&self, total_steps: usize) -> Vec<StepAction> {
        (0..total_steps)
            .map(|i| {
                if i < self.t_complete {
                    StepAction::Full
                } else if i < self.t_sketch {
                    if (i - self.t_complete) % self.t_sparse == self.t_sparse - 1 {
                        StepAction::Full
                    } else {
                        StepAction::Partial(self.l_sketch)
                    }
                } else {
                    StepAction::Partial(self.l_refine)
                }
            })
            .collect()
    }

    pub fn label(&self) -> String {
        format!("PAS-{}/{}", self.t_sketch, self.t_sparse)
    }
}

/// What a generation request asks the coordinator to run.
///
/// Derives `Hash`/`Ord` so it can sit inside the structured
/// `coordinator::BatchKey` and feed cache-key derivation directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SamplingPlan {
    /// Original model: complete U-Net every step.
    Full,
    /// Phase-aware sampling with the given config.
    Pas(PasConfig),
    /// "Pick the best known plan for me": resolved against the persistent
    /// plan cache (`cache::Cache::best_plan`) by
    /// `Coordinator::resolve_plan` before batching/keying. An Auto plan
    /// that reaches execution unresolved degrades to `Full` — correct,
    /// just without the MAC savings.
    Auto,
}

impl SamplingPlan {
    pub fn actions(&self, total_steps: usize) -> Vec<StepAction> {
        match self {
            SamplingPlan::Full | SamplingPlan::Auto => vec![StepAction::Full; total_steps],
            SamplingPlan::Pas(cfg) => cfg.plan(total_steps),
        }
    }
}

/// A plan is executable only if every partial step is preceded by some
/// full step (the cache must exist). True for all valid PasConfigs since
/// t_complete >= 1; checked as a defensive invariant by the coordinator.
pub fn plan_is_executable(plan: &[StepAction]) -> bool {
    let mut have_cache = false;
    for a in plan {
        match a {
            StepAction::Full => have_cache = true,
            StepAction::Partial(_) if !have_cache => return false,
            _ => {}
        }
    }
    !plan.is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use StepAction::{Full, Partial};

    #[test]
    fn plan_structure_matches_fig5() {
        let cfg = PasConfig { t_sketch: 10, t_complete: 2, t_sparse: 3, l_sketch: 3, l_refine: 2 };
        let plan = cfg.plan(14);
        assert_eq!(plan[0], Full);
        assert_eq!(plan[1], Full);
        // Sketching: every 3rd step (after t_complete) is Full.
        assert_eq!(plan[2], Partial(3));
        assert_eq!(plan[3], Partial(3));
        assert_eq!(plan[4], Full);
        assert_eq!(plan[5], Partial(3));
        assert_eq!(plan[7], Full);
        // Refinement from step 10.
        assert!(plan[10..].iter().all(|&a| a == Partial(2)));
    }

    #[test]
    fn pas25_label() {
        assert_eq!(PasConfig::pas25(4).label(), "PAS-25/4");
    }

    #[test]
    fn validation_rules() {
        let ok = PasConfig::pas25(4);
        assert!(ok.validate(50, 20, 3).is_ok());
        assert!(ok.validate(50, 30, 3).is_err(), "t_sketch below D*");
        assert!(ok.validate(20, 10, 3).is_err(), "t_sketch beyond total");
        let bad = PasConfig { l_sketch: 1, l_refine: 2, ..ok };
        assert!(bad.validate(50, 20, 3).is_err());
        let bad2 = PasConfig { t_sparse: 1, ..ok };
        assert!(bad2.validate(50, 20, 3).is_err());
        let bad3 = PasConfig { l_sketch: 9, l_refine: 2, ..ok };
        assert!(bad3.validate(50, 20, 3).is_err(), "exceeds artifact cuts");
    }

    #[test]
    fn all_valid_plans_are_executable() {
        testing::check_no_shrink(
            "valid-pas-plans-executable",
            |rng| {
                let total = testing::gen_usize(rng, 8, 100);
                let t_sketch = testing::gen_usize(rng, 2, total);
                let t_complete = testing::gen_usize(rng, 1, t_sketch);
                let t_sparse = testing::gen_usize(rng, 2, 8);
                let l_refine = testing::gen_usize(rng, 1, 3);
                let l_sketch = testing::gen_usize(rng, l_refine, 3);
                (total, PasConfig { t_sketch, t_complete, t_sparse, l_sketch, l_refine })
            },
            |&(total, cfg)| {
                if cfg.validate(total, 1, 3).is_err() {
                    return true; // rejected configs are out of scope
                }
                let plan = cfg.plan(total);
                plan.len() == total && plan_is_executable(&plan)
            },
        );
    }

    #[test]
    fn more_sparse_means_fewer_full_steps() {
        let count_full = |s| {
            PasConfig::pas25(s)
                .plan(50)
                .iter()
                .filter(|&&a| a == Full)
                .count()
        };
        assert!(count_full(2) > count_full(3));
        assert!(count_full(3) > count_full(5));
    }

    #[test]
    fn full_plan_sampling() {
        let p = SamplingPlan::Full.actions(5);
        assert_eq!(p, vec![Full; 5]);
    }

    #[test]
    fn unresolved_auto_degrades_to_full() {
        assert_eq!(SamplingPlan::Auto.actions(4), vec![Full; 4]);
    }

    #[test]
    fn partial_without_cache_flagged() {
        assert!(!plan_is_executable(&[Partial(2), Full]));
        assert!(plan_is_executable(&[Full, Partial(2)]));
        assert!(!plan_is_executable(&[]));
    }
}
