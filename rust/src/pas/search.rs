//! The Fig. 7 optimisation framework: enumerate PAS configurations under
//! user constraints, rank by Eq. 3 MAC reduction, optionally validate
//! image quality against the full-sampling reference trajectory.

use anyhow::Result;

use crate::cache::{Cache, PlanFront};
use crate::coordinator::{Coordinator, GenRequest};
use crate::pas::calibrate::CalibrationReport;
use crate::pas::cost::CostModel;
use crate::pas::plan::{PasConfig, SamplingPlan};
use crate::util::stats;

/// User requirements (Fig. 7, step 1).
#[derive(Debug, Clone)]
pub struct SearchConstraints {
    pub total_steps: usize,
    /// Reject configurations below this MAC reduction.
    pub min_mac_reduction: f64,
    /// Latent-PSNR floor vs. the full-sampling reference (quality proxy —
    /// DESIGN.md substitution for CLIP/FID). None = skip validation.
    pub min_psnr_db: Option<f64>,
    /// How many top candidates to validate by actually generating.
    pub max_validate: usize,
}

impl Default for SearchConstraints {
    fn default() -> Self {
        SearchConstraints {
            total_steps: 50,
            min_mac_reduction: 1.5,
            min_psnr_db: None,
            max_validate: 3,
        }
    }
}

/// A feasible configuration with its predicted/measured scores.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub cfg: PasConfig,
    pub mac_reduction: f64,
    /// Filled by validation (latent PSNR vs full reference, dB).
    pub psnr_db: Option<f64>,
    pub validated: bool,
}

/// Enumerate all valid configurations (Fig. 7, step 3) sorted by
/// descending MAC reduction. Spatial params are bounded by the artifact
/// cut levels and the outlier count (L_refine >= #outliers, Sec. III-B).
pub fn enumerate_candidates(
    report: &CalibrationReport,
    cost: &CostModel,
    cons: &SearchConstraints,
    max_cut: usize,
) -> Vec<Candidate> {
    let t = cons.total_steps;
    let l_min = report.outliers.len().max(1).min(max_cut);
    let mut out = Vec::new();
    for t_sketch in report.d_star..=t {
        for t_complete in 1..=4usize {
            for t_sparse in 2..=6usize {
                for l_refine in l_min..=max_cut {
                    for l_sketch in l_refine..=max_cut {
                        let cfg = PasConfig { t_sketch, t_complete, t_sparse, l_sketch, l_refine };
                        if cfg.validate(t, report.d_star, max_cut).is_err() {
                            continue;
                        }
                        let red = cost.mac_reduction(&cfg.plan(t));
                        if red >= cons.min_mac_reduction {
                            out.push(Candidate {
                                cfg,
                                mac_reduction: red,
                                psnr_db: None,
                                validated: false,
                            });
                        }
                    }
                }
            }
        }
    }
    out.sort_by(|a, b| b.mac_reduction.partial_cmp(&a.mac_reduction).unwrap());
    out
}

/// Full search pipeline (Fig. 7, steps 3-4).
pub struct Searcher<'a> {
    pub coord: &'a Coordinator,
    pub cost: CostModel,
}

impl<'a> Searcher<'a> {
    /// Validate the top candidates by generating with PAS and comparing
    /// the final latent to the full-sampling reference (same seeds).
    pub fn search(
        &self,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
    ) -> Result<Vec<Candidate>> {
        let max_cut = self.coord.runtime().manifest().model.max_cut;
        let mut cands = enumerate_candidates(report, &self.cost, cons, max_cut);
        let Some(min_psnr) = cons.min_psnr_db else {
            return Ok(cands);
        };

        // Reference latents (full sampling).
        let refs: Vec<_> = validation_prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut r = GenRequest::new(p, 9000 + i as u64);
                r.steps = cons.total_steps;
                self.coord.generate_one(&r)
            })
            .collect::<Result<Vec<_>>>()?;

        let mut validated = Vec::new();
        for cand in cands.iter_mut().take(cons.max_validate) {
            let mut psnrs = Vec::new();
            for (i, p) in validation_prompts.iter().enumerate() {
                let mut r = GenRequest::new(p, 9000 + i as u64);
                r.steps = cons.total_steps;
                r.plan = SamplingPlan::Pas(cand.cfg);
                let out = self.coord.generate_one(&r)?;
                psnrs.push(stats::psnr(&out.latent.data, &refs[i].latent.data, 2.0));
            }
            cand.psnr_db = Some(stats::mean(&psnrs));
            cand.validated = true;
            if cand.psnr_db.unwrap() >= min_psnr {
                validated.push(cand.clone());
            }
        }
        if validated.is_empty() {
            // Nothing passed quality: return the (unvalidated) ranking so
            // the caller can relax constraints.
            return Ok(cands);
        }
        validated.sort_by(|a, b| b.mac_reduction.partial_cmp(&a.mac_reduction).unwrap());
        Ok(validated)
    }

    /// Cache-aware search: the searched front for this (manifest, steps,
    /// quality target, validation prompts, calibration outcome) cell is
    /// reused on warm starts; cold starts run the Fig. 7 pipeline and —
    /// only when the result actually satisfies the quality floor — store
    /// the front plus the per-steps best-plan summary that
    /// `SamplingPlan::Auto` resolution reads. The fallback ranking that
    /// [`Searcher::search`] returns when nothing passes validation is
    /// deliberately NOT cached: it exists so the caller can relax
    /// constraints, and publishing it would hand quality-failed configs
    /// to every future `Auto` request. The boolean is true on a cache
    /// hit.
    pub fn search_cached(
        &self,
        cache: &Cache,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
    ) -> Result<(Vec<Candidate>, bool)> {
        if let Some(front) =
            cache.get_plan_front(cons, validation_prompts, report.d_star, &report.outliers)
        {
            return Ok((front.candidates, true));
        }
        let cands = self.search(report, cons, validation_prompts)?;
        let passed_quality = match cons.min_psnr_db {
            // No floor requested: the MAC-ranked enumeration is the answer.
            None => true,
            // With a floor, `search` returns either the all-passing
            // validated set or the unvalidated fallback ranking.
            Some(floor) => {
                !cands.is_empty()
                    && cands
                        .iter()
                        .all(|c| c.validated && c.psnr_db.map_or(false, |p| p >= floor))
            }
        };
        if passed_quality {
            let front = PlanFront {
                total_steps: cons.total_steps,
                min_mac_reduction: cons.min_mac_reduction,
                min_psnr_db: cons.min_psnr_db,
                d_star: report.d_star,
                candidates: cands.clone(),
            };
            cache.put_plan_front(cons, validation_prompts, report.d_star, &report.outliers, &front)?;
        }
        Ok((cands, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::sd_v14;
    use crate::pas::calibrate::analyse;

    fn fake_report(d_star_target: usize, steps: usize) -> CalibrationReport {
        // Build raw curves with a knee at d_star_target.
        let t1 = steps - 1;
        let raw: Vec<Vec<f64>> = (0..12)
            .map(|b| {
                (0..t1)
                    .map(|t| {
                        if t < d_star_target {
                            0.8
                        } else if b < 2 {
                            0.6
                        } else {
                            0.05
                        }
                    })
                    .collect()
            })
            .collect();
        analyse(raw, vec![1.0; steps], steps, 1)
    }

    #[test]
    fn enumeration_respects_constraints() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let cons = SearchConstraints { min_mac_reduction: 2.0, ..Default::default() };
        let cands = enumerate_candidates(&rep, &cost, &cons, 3);
        assert!(!cands.is_empty());
        for c in &cands {
            assert!(c.mac_reduction >= 2.0);
            assert!(c.cfg.t_sketch >= rep.d_star);
            assert!(c.cfg.l_refine >= rep.outliers.len().min(3));
            assert!(c.cfg.l_sketch >= c.cfg.l_refine);
        }
        // Sorted descending.
        assert!(cands.windows(2).all(|w| w[0].mac_reduction >= w[1].mac_reduction));
    }

    #[test]
    fn tighter_constraint_shrinks_the_set() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let loose = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 1.2, ..Default::default() },
            3,
        );
        let tight = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 2.8, ..Default::default() },
            3,
        );
        assert!(loose.len() > tight.len());
    }

    #[test]
    fn impossible_constraint_yields_empty() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let cands = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 50.0, ..Default::default() },
            3,
        );
        assert!(cands.is_empty());
    }
}
