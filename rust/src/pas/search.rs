//! The Fig. 7 optimisation framework: enumerate PAS configurations under
//! user constraints, rank by Eq. 3 MAC reduction, optionally validate
//! image quality against the full-sampling reference trajectory.
//!
//! Validation is embarrassingly parallel (each candidate generates with
//! fixed seeds and compares against fixed references), so
//! [`Searcher::search`] fans the top candidates out over a
//! [`ThreadPool`], one worker-local [`Coordinator`] per job sharing the
//! same runtime thread. Validation lanes whose plans coincide — all
//! prompts of one candidate share a batch key — run lane-batched through
//! [`Coordinator::generate_many`]. Both the parallel path and the serial
//! reference ([`Searcher::search_serial`]) call the same per-candidate
//! scoring function, so they return identical candidate sets (same
//! order, same scores) — an integration test locks that in.

use std::sync::Arc;

use anyhow::Result;

use crate::cache::{Cache, PlanFront};
use crate::coordinator::{Coordinator, GenRequest, GenResult};
use crate::pas::calibrate::CalibrationReport;
use crate::pas::cost::CostModel;
use crate::pas::plan::{PasConfig, SamplingPlan};
use crate::util::stats;
use crate::util::threadpool::ThreadPool;

/// User requirements (Fig. 7, step 1).
#[derive(Debug, Clone)]
pub struct SearchConstraints {
    pub total_steps: usize,
    /// Reject configurations below this MAC reduction.
    pub min_mac_reduction: f64,
    /// Latent-PSNR floor vs. the full-sampling reference (quality proxy —
    /// DESIGN.md substitution for CLIP/FID). None = skip validation.
    pub min_psnr_db: Option<f64>,
    /// How many top candidates to validate by actually generating.
    pub max_validate: usize,
}

impl Default for SearchConstraints {
    fn default() -> Self {
        SearchConstraints {
            total_steps: 50,
            min_mac_reduction: 1.5,
            min_psnr_db: None,
            max_validate: 3,
        }
    }
}

/// A feasible configuration with its predicted/measured scores.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub cfg: PasConfig,
    pub mac_reduction: f64,
    /// Filled by validation (latent PSNR vs full reference, dB).
    pub psnr_db: Option<f64>,
    pub validated: bool,
}

/// Enumerate all valid configurations (Fig. 7, step 3) sorted by
/// descending MAC reduction. Spatial params are bounded by the artifact
/// cut levels and the outlier count (L_refine >= #outliers, Sec. III-B).
pub fn enumerate_candidates(
    report: &CalibrationReport,
    cost: &CostModel,
    cons: &SearchConstraints,
    max_cut: usize,
) -> Vec<Candidate> {
    let t = cons.total_steps;
    let l_min = report.outliers.len().max(1).min(max_cut);
    let mut out = Vec::new();
    for t_sketch in report.d_star..=t {
        for t_complete in 1..=4usize {
            for t_sparse in 2..=6usize {
                for l_refine in l_min..=max_cut {
                    for l_sketch in l_refine..=max_cut {
                        let cfg = PasConfig { t_sketch, t_complete, t_sparse, l_sketch, l_refine };
                        if cfg.validate(t, report.d_star, max_cut).is_err() {
                            continue;
                        }
                        let red = cost.mac_reduction(&cfg.plan(t));
                        if red >= cons.min_mac_reduction {
                            out.push(Candidate {
                                cfg,
                                mac_reduction: red,
                                psnr_db: None,
                                validated: false,
                            });
                        }
                    }
                }
            }
        }
    }
    out.sort_by(|a, b| b.mac_reduction.partial_cmp(&a.mac_reduction).unwrap());
    out
}

/// Validation requests for one plan: one per prompt, fixed seeds, all
/// sharing a batch key so [`Coordinator::generate_many`] can lane-batch
/// them.
fn validation_requests(
    prompts: &[String],
    total_steps: usize,
    plan: SamplingPlan,
) -> Vec<GenRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut r = GenRequest::new(p, 9000 + i as u64);
            r.steps = total_steps;
            r.plan = plan;
            r
        })
        .collect()
}

/// Score one candidate: generate with its PAS plan over every validation
/// prompt (lane-batched) and return the mean latent PSNR vs the
/// references. Deterministic — identical from any thread.
fn score_candidate(
    coord: &Coordinator,
    cfg: PasConfig,
    prompts: &[String],
    total_steps: usize,
    refs: &[GenResult],
) -> Result<f64> {
    let reqs = validation_requests(prompts, total_steps, SamplingPlan::Pas(cfg));
    let outs = coord.generate_many(&reqs)?;
    let psnrs: Vec<f64> = outs
        .iter()
        .zip(refs)
        .map(|(out, r)| stats::psnr(out.latent.data(), r.latent.data(), 2.0))
        .collect();
    Ok(stats::mean(&psnrs))
}

/// Full search pipeline (Fig. 7, steps 3-4).
pub struct Searcher<'a> {
    pub coord: &'a Coordinator,
    pub cost: CostModel,
}

impl<'a> Searcher<'a> {
    /// Validate the top candidates by generating with PAS and comparing
    /// the final latent to the full-sampling reference (same seeds).
    /// Candidate scoring fans out over a thread pool; results are
    /// identical to [`Searcher::search_serial`].
    pub fn search(
        &self,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
    ) -> Result<Vec<Candidate>> {
        self.search_impl(report, cons, validation_prompts, true)
    }

    /// Single-threaded reference path: same lane batching, same scoring,
    /// no pool. Exists so tests can prove the parallel path returns the
    /// same candidate set (same order, same scores).
    pub fn search_serial(
        &self,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
    ) -> Result<Vec<Candidate>> {
        self.search_impl(report, cons, validation_prompts, false)
    }

    fn search_impl(
        &self,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
        parallel: bool,
    ) -> Result<Vec<Candidate>> {
        let max_cut = self.coord.runtime().manifest().model.max_cut;
        let mut cands = enumerate_candidates(report, &self.cost, cons, max_cut);
        let Some(min_psnr) = cons.min_psnr_db else {
            return Ok(cands);
        };

        // Reference latents (full sampling): one lane-batched run — all
        // reference requests share a batch key.
        let ref_reqs = validation_requests(validation_prompts, cons.total_steps, SamplingPlan::Full);
        let refs = Arc::new(self.coord.generate_many(&ref_reqs)?);

        let n_validate = cons.max_validate.min(cands.len());
        let cfgs: Vec<PasConfig> = cands[..n_validate].iter().map(|c| c.cfg).collect();
        let scores: Vec<Result<f64>> = if parallel && cfgs.len() > 1 {
            // One worker-local Coordinator per job over the shared
            // runtime handle (Coordinator itself is not 'static here;
            // its handle is cheap to clone and thread-safe).
            let handle = self.coord.runtime().clone();
            let prompts: Arc<Vec<String>> = Arc::new(validation_prompts.to_vec());
            let total_steps = cons.total_steps;
            let refs = Arc::clone(&refs);
            let workers = cfgs
                .len()
                .min(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2))
                .max(1);
            let pool = ThreadPool::new(workers);
            pool.map(cfgs, move |cfg| {
                let coord = Coordinator::new(handle.clone());
                score_candidate(&coord, cfg, &prompts, total_steps, &refs)
            })
        } else {
            cfgs.into_iter()
                .map(|cfg| {
                    score_candidate(self.coord, cfg, validation_prompts, cons.total_steps, &refs)
                })
                .collect()
        };

        let mut validated = Vec::new();
        for (cand, score) in cands.iter_mut().zip(scores) {
            let psnr = score?;
            cand.psnr_db = Some(psnr);
            cand.validated = true;
            if psnr >= min_psnr {
                validated.push(cand.clone());
            }
        }
        if validated.is_empty() {
            // Nothing passed quality: return the (unvalidated) ranking so
            // the caller can relax constraints.
            return Ok(cands);
        }
        validated.sort_by(|a, b| b.mac_reduction.partial_cmp(&a.mac_reduction).unwrap());
        Ok(validated)
    }

    /// Cache-aware search: the searched front for this (manifest, steps,
    /// quality target, validation prompts, calibration outcome) cell is
    /// reused on warm starts; cold starts run the Fig. 7 pipeline and —
    /// only when the result actually satisfies the quality floor — store
    /// the front plus the per-steps best-plan summary that
    /// `SamplingPlan::Auto` resolution reads. The fallback ranking that
    /// [`Searcher::search`] returns when nothing passes validation is
    /// deliberately NOT cached: it exists so the caller can relax
    /// constraints, and publishing it would hand quality-failed configs
    /// to every future `Auto` request. The boolean is true on a cache
    /// hit.
    pub fn search_cached(
        &self,
        cache: &Cache,
        report: &CalibrationReport,
        cons: &SearchConstraints,
        validation_prompts: &[String],
    ) -> Result<(Vec<Candidate>, bool)> {
        if let Some(front) =
            cache.get_plan_front(cons, validation_prompts, report.d_star, &report.outliers)
        {
            return Ok((front.candidates, true));
        }
        let cands = self.search(report, cons, validation_prompts)?;
        let passed_quality = match cons.min_psnr_db {
            // No floor requested: the MAC-ranked enumeration is the answer.
            None => true,
            // With a floor, `search` returns either the all-passing
            // validated set or the unvalidated fallback ranking.
            Some(floor) => {
                !cands.is_empty()
                    && cands
                        .iter()
                        .all(|c| c.validated && c.psnr_db.map_or(false, |p| p >= floor))
            }
        };
        if passed_quality {
            let front = PlanFront {
                total_steps: cons.total_steps,
                min_mac_reduction: cons.min_mac_reduction,
                min_psnr_db: cons.min_psnr_db,
                d_star: report.d_star,
                candidates: cands.clone(),
            };
            cache.put_plan_front(cons, validation_prompts, report.d_star, &report.outliers, &front)?;
        }
        Ok((cands, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::sd_v14;
    use crate::pas::calibrate::analyse;

    fn fake_report(d_star_target: usize, steps: usize) -> CalibrationReport {
        // Build raw curves with a knee at d_star_target.
        let t1 = steps - 1;
        let raw: Vec<Vec<f64>> = (0..12)
            .map(|b| {
                (0..t1)
                    .map(|t| {
                        if t < d_star_target {
                            0.8
                        } else if b < 2 {
                            0.6
                        } else {
                            0.05
                        }
                    })
                    .collect()
            })
            .collect();
        analyse(raw, vec![1.0; steps], steps, 1)
    }

    #[test]
    fn enumeration_respects_constraints() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let cons = SearchConstraints { min_mac_reduction: 2.0, ..Default::default() };
        let cands = enumerate_candidates(&rep, &cost, &cons, 3);
        assert!(!cands.is_empty());
        for c in &cands {
            assert!(c.mac_reduction >= 2.0);
            assert!(c.cfg.t_sketch >= rep.d_star);
            assert!(c.cfg.l_refine >= rep.outliers.len().min(3));
            assert!(c.cfg.l_sketch >= c.cfg.l_refine);
        }
        // Sorted descending.
        assert!(cands.windows(2).all(|w| w[0].mac_reduction >= w[1].mac_reduction));
    }

    #[test]
    fn tighter_constraint_shrinks_the_set() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let loose = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 1.2, ..Default::default() },
            3,
        );
        let tight = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 2.8, ..Default::default() },
            3,
        );
        assert!(loose.len() > tight.len());
    }

    #[test]
    fn impossible_constraint_yields_empty() {
        let rep = fake_report(20, 50);
        let cost = CostModel::new(&sd_v14());
        let cands = enumerate_candidates(
            &rep,
            &cost,
            &SearchConstraints { min_mac_reduction: 50.0, ..Default::default() },
            3,
        );
        assert!(cands.is_empty());
    }

    #[test]
    fn validation_requests_share_a_batch_key() {
        let prompts =
            vec!["red circle x4 y4".to_string(), "green stripe x8 y8".to_string()];
        let cfg = PasConfig { t_sketch: 25, t_complete: 3, t_sparse: 4, l_sketch: 2, l_refine: 2 };
        let reqs = validation_requests(&prompts, 50, SamplingPlan::Pas(cfg));
        assert_eq!(reqs.len(), 2);
        let key = reqs[0].batch_key();
        assert!(reqs.iter().all(|r| r.batch_key() == key), "lanes must batch");
        // Distinct deterministic seeds per prompt index.
        assert_eq!(reqs[0].seed, 9000);
        assert_eq!(reqs[1].seed, 9001);
    }
}
