//! Pluggable approximation policies: *how* a generation trades compute
//! for quality, behind one object-safe seam.
//!
//! SD-Acc's phase-aware sampling is one point in a family of
//! approximate-computation strategies — SADA-style online stability
//! guidance decides skips from the latent trajectory instead of a
//! calibrated plan, and block-level feature caching bounds reuse with
//! per-block staleness budgets. This module turns the coordinator's
//! hard-coded PAS reuse decision into a policy seam so those
//! strategies compose with the request cache and give the traffic
//! engine real quality-vs-latency levers.
//!
//! ## The seam
//!
//! [`PolicySpec`] is the *data* form a request carries
//! (`GenRequest::policy`): small, `Copy`, totally ordered, hashable —
//! it participates in `BatchKey` grouping and in the request-cache key
//! derivation (`cache::namespaces::request_key` hashes
//! [`PolicySpec::label`]; the `CACHE_VERSION` bump to 4 covers the
//! digest change, per the standing invariant). [`PolicySpec::build`]
//! instantiates the behaviour as a boxed [`ApproxPolicy`] once per
//! batch inside the coordinator.
//!
//! [`ApproxPolicy`] has two hooks:
//!
//! * **plan-time** — [`ApproxPolicy::plan`] maps `(total_steps, base
//!   SamplingPlan)` to the per-step action schedule. [`PasPolicy`]
//!   returns `base.actions(total_steps)` verbatim, so the default
//!   policy is bit-identical to the pre-refactor PAS path (parity is
//!   pinned by tests here and in `tests/integration_policy.rs`).
//! * **step-time** — [`ApproxPolicy::on_step_decision`] may override
//!   the planned action from online [`TrajectoryStats`] (EWMA of the
//!   normalized step-to-step eps delta). The coordinator clamps
//!   overrides so they can never make a plan inexecutable: a `Partial`
//!   override is honoured only when its feature cache is warm, and
//!   trajectory stats are computed only when
//!   [`ApproxPolicy::needs_trajectory`] is true — the default path
//!   stays computation- and allocation-identical.
//!
//! `policy_id()` is the stable identity string (`== spec.label()`,
//! pinned by a test below): it names the policy in step spans
//! (`<policy_id>:<action>` for non-default policies), per-policy load
//! reports, and — via the spec — every batch/request cache key, so
//! results produced under different policies can never satisfy each
//! other's lookups (the brownout rule from `server::resilience`
//! generalizes: a degraded-policy result lives under its own policy
//! id).
//!
//! ## Concrete policies
//!
//! | spec                | id                  | strategy |
//! |---------------------|---------------------|----------|
//! | `Pas` (default)     | `pas`               | calibrated phase-aware plan, verbatim |
//! | `BlockCache{budget}`| `block-cache:<b>`   | base plan + per-block staleness budget: a feature cache older than `budget` steps forces a refresh |
//! | `Stability{thresh}` | `stability:<t>`     | SADA-style: sparse static skeleton + online Full refresh when the eps trajectory destabilizes — no calibration needed |
//! | `TextPrecision`     | `text-precision`    | per-prompt `QuantScheme` from prompt-class sensitivity |
//!
//! [`StabilityPolicy`] removes the calibrate cold-start: its skeleton
//! (2 warmup Fulls, then a refresh every 5th step, `Partial(2)`
//! otherwise) is chosen so that even with every rate-limited override
//! firing (at most one forced Full per 4 steps), the executed schedule
//! performs at most as many Full steps as `PasConfig::pas25(4)` at 25
//! steps — MAC reduction >= the PAS reference *by construction*, which
//! `bench_policy --smoke` asserts together with the quality band.

use crate::pas::plan::{SamplingPlan, StepAction};
use crate::quant::QuantScheme;

/// Declarative policy choice carried by a `GenRequest`. Small and
/// `Copy` so it rides through `BatchKey` and the wire protocol; the
/// canonical string form is [`PolicySpec::label`] (also the cache-key
/// bytes — changing any label requires a `CACHE_VERSION` bump, same
/// rule as `SamplerKind::as_str`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PolicySpec {
    /// Calibrated phase-aware sampling — the default; bit-identical to
    /// the pre-policy-seam coordinator path.
    Pas,
    /// Block-level feature caching with a per-block staleness budget
    /// (steps a cached block may be reused before a forced refresh).
    BlockCache { budget: usize },
    /// SADA-style online stability guidance; `threshold_milli` is the
    /// EWMA instability threshold in thousandths (250 = 0.25).
    Stability { threshold_milli: u32 },
    /// Per-prompt precision selection from prompt-class sensitivity.
    TextPrecision,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec::Pas
    }
}

/// Default staleness budget for `block-cache` without a parameter.
pub const DEFAULT_BLOCK_BUDGET: usize = 3;
/// Default EWMA instability threshold (thousandths) for `stability`.
pub const DEFAULT_STABILITY_MILLI: u32 = 250;

impl PolicySpec {
    /// Canonical identity string — the bytes hashed into batch and
    /// request cache keys, and the name accepted by [`PolicySpec::parse`].
    pub fn label(&self) -> String {
        match self {
            PolicySpec::Pas => "pas".to_string(),
            PolicySpec::BlockCache { budget } => format!("block-cache:{budget}"),
            PolicySpec::Stability { threshold_milli } => format!("stability:{threshold_milli}"),
            PolicySpec::TextPrecision => "text-precision".to_string(),
        }
    }

    /// Parse a policy name as accepted by `--policy` and the wire
    /// `"policy"` field: `pas` | `block-cache[:<budget>]` |
    /// `stability[:<threshold_milli>]` | `text-precision`.
    pub fn parse(s: &str) -> Option<PolicySpec> {
        match s {
            "pas" => return Some(PolicySpec::Pas),
            "block-cache" => return Some(PolicySpec::BlockCache { budget: DEFAULT_BLOCK_BUDGET }),
            "stability" => {
                return Some(PolicySpec::Stability { threshold_milli: DEFAULT_STABILITY_MILLI })
            }
            "text-precision" => return Some(PolicySpec::TextPrecision),
            _ => {}
        }
        if let Some(b) = s.strip_prefix("block-cache:") {
            let budget = b.parse::<usize>().ok()?;
            if budget == 0 {
                return None;
            }
            return Some(PolicySpec::BlockCache { budget });
        }
        if let Some(t) = s.strip_prefix("stability:") {
            return Some(PolicySpec::Stability { threshold_milli: t.parse::<u32>().ok()? });
        }
        None
    }

    /// Whether the built policy makes online step-time decisions from
    /// the batch-wide eps trajectory (mirrors
    /// [`ApproxPolicy::needs_trajectory`]; pinned equal by a test).
    /// The server batches such requests solo: a trajectory computed
    /// over a multi-lane batch would make a lane's latent depend on its
    /// batch mates, breaking the request-cache promise that a result is
    /// a function of the request alone.
    pub fn online(&self) -> bool {
        matches!(self, PolicySpec::Stability { .. })
    }

    /// Every policy family at its default parameterization — the
    /// registry behind `sd-acc policy list|describe`.
    pub fn all() -> Vec<PolicySpec> {
        vec![
            PolicySpec::Pas,
            PolicySpec::BlockCache { budget: DEFAULT_BLOCK_BUDGET },
            PolicySpec::Stability { threshold_milli: DEFAULT_STABILITY_MILLI },
            PolicySpec::TextPrecision,
        ]
    }

    /// Instantiate the behaviour. Cheap (no I/O, no calibration) — the
    /// coordinator builds one per batch.
    pub fn build(&self) -> Box<dyn ApproxPolicy> {
        match *self {
            PolicySpec::Pas => Box::new(PasPolicy),
            PolicySpec::BlockCache { budget } => Box::new(BlockCachePolicy { budget }),
            PolicySpec::Stability { threshold_milli } => {
                Box::new(StabilityPolicy { threshold_milli })
            }
            PolicySpec::TextPrecision => Box::new(TextPrecisionPolicy),
        }
    }
}

/// Online trajectory statistics handed to step-time decisions. All
/// quantities are pure functions of the eps tensors the loop already
/// computes, so decisions are deterministic on the sim backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrajectoryStats {
    /// EWMA (alpha 0.5) of `last_delta`; 0 before the second step.
    pub ewma_delta: f64,
    /// Normalized mean-abs eps change vs the previous step:
    /// `mean|eps_i - eps_{i-1}| / (mean|eps_i| + 1e-12)`.
    pub last_delta: f64,
    /// Steps since the last executed `Full` (0 right after one).
    pub steps_since_full: usize,
}

/// A step-time decision: keep the planned action, or override it.
/// Overrides are clamped by the coordinator — `Partial(l)` is honoured
/// only when the cut-`l` feature cache is warm and within the plan's
/// sizing, so an override can never break plan executability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepDecision {
    /// Execute the plan-time action unchanged.
    Planned,
    /// Replace the planned action for this step.
    Override(StepAction),
}

/// The approximation-policy seam (object-safe: the coordinator holds a
/// `Box<dyn ApproxPolicy>` built from the request's [`PolicySpec`]).
pub trait ApproxPolicy: Send + Sync {
    /// Stable identity — must equal the originating spec's `label()`
    /// (pinned by `policy_id_matches_spec_label`): these bytes key
    /// caches, label spans, and name per-policy report lines.
    fn policy_id(&self) -> String;

    /// Plan-time hook: the per-step action schedule for a run of
    /// `total_steps`, given the request's declared `SamplingPlan`
    /// (which calibrated policies consume and online policies may
    /// ignore). Must return exactly `total_steps` actions forming an
    /// executable schedule (`pas::plan::plan_is_executable`).
    fn plan(&self, total_steps: usize, base: &SamplingPlan) -> Vec<StepAction>;

    /// Step-time hook, consulted once per denoising step *only when*
    /// [`ApproxPolicy::needs_trajectory`] is true. Default: keep the plan.
    fn on_step_decision(&self, _i: usize, _stats: &TrajectoryStats) -> StepDecision {
        StepDecision::Planned
    }

    /// Whether the coordinator should compute [`TrajectoryStats`] (an
    /// extra eps clone + delta reduction per step). False keeps the
    /// default path computation- and allocation-identical to the
    /// pre-seam loop.
    fn needs_trajectory(&self) -> bool {
        false
    }

    /// Per-prompt precision override (text-conditioned policies). The
    /// coordinator applies it only when the request carries no explicit
    /// `QuantScheme` — a user choice always wins.
    fn quant_override(&self, _prompt: &str) -> Option<QuantScheme> {
        None
    }

    /// One-line human description for `sd-acc policy list|describe`.
    fn describe(&self) -> String;
}

// ------------------------------------------------------------------- pas

/// The calibrated phase-aware plan behind the trait — the default
/// policy. `plan` is exactly `SamplingPlan::actions`, so outputs are
/// bit-identical to the pre-refactor coordinator path.
pub struct PasPolicy;

impl ApproxPolicy for PasPolicy {
    fn policy_id(&self) -> String {
        PolicySpec::Pas.label()
    }

    fn plan(&self, total_steps: usize, base: &SamplingPlan) -> Vec<StepAction> {
        base.actions(total_steps)
    }

    fn describe(&self) -> String {
        "calibrated phase-aware sampling plan (SD-Acc §3); the default — \
         bit-identical to the pre-policy-seam path"
            .to_string()
    }
}

// ----------------------------------------------------------- block-cache

/// Block-level feature caching with per-block staleness budgets: the
/// base plan's reuse (`Partial`) steps are honoured only while the
/// feature cache they read is at most `budget` steps old; an older
/// cache forces a `Full` refresh at that step. Layered on the existing
/// feature-cache tensors — the budget only ever *adds* refreshes, so
/// the schedule is executable whenever the base plan is.
pub struct BlockCachePolicy {
    pub budget: usize,
}

impl ApproxPolicy for BlockCachePolicy {
    fn policy_id(&self) -> String {
        PolicySpec::BlockCache { budget: self.budget }.label()
    }

    fn plan(&self, total_steps: usize, base: &SamplingPlan) -> Vec<StepAction> {
        let mut actions = base.actions(total_steps);
        let mut staleness = 0usize; // steps since the cached blocks were refreshed
        for a in actions.iter_mut() {
            match *a {
                StepAction::Full => staleness = 0,
                StepAction::Partial(_) => {
                    if staleness >= self.budget.max(1) {
                        *a = StepAction::Full;
                        staleness = 0;
                    } else {
                        staleness += 1;
                    }
                }
            }
        }
        actions
    }

    fn describe(&self) -> String {
        format!(
            "block-level feature caching: reuse cached blocks for at most {} \
             consecutive steps before forcing a full refresh (staleness budget)",
            self.budget
        )
    }
}

// ------------------------------------------------------------- stability

/// How many steps a `Partial` streak may run before a stability
/// override is allowed to force a refresh. Rate-limiting the override
/// is what makes the MAC bound constructive: executed Fulls <=
/// `STABILITY_WARMUP + total_steps / STABILITY_OVERRIDE_SPACING`.
pub const STABILITY_OVERRIDE_SPACING: usize = 4;
/// Static refresh period of the stability skeleton (sparser than
/// `pas25(4)`'s `t_sparse = 4`, so the skeleton alone beats PAS MACs).
pub const STABILITY_REFRESH: usize = 5;
/// Leading Full steps (seed the feature caches + the eps trajectory).
pub const STABILITY_WARMUP: usize = 2;

/// SADA-style online stability guidance: a sparse static skeleton
/// (works with zero calibration — no `calibration.json`, no calibrate
/// cold-start) plus step-time `Full` refreshes whenever the EWMA of
/// the normalized eps delta exceeds the threshold. Overrides are
/// rate-limited to one per [`STABILITY_OVERRIDE_SPACING`] steps, so at
/// 25 steps the executed schedule performs at most
/// `2 + floor(23/4) = 7` Full steps — fewer than `pas25(4)`'s 9 at the
/// same reuse level `l = 2`, i.e. MAC reduction >= the PAS reference
/// by construction (asserted in `bench_policy --smoke`).
pub struct StabilityPolicy {
    /// EWMA instability threshold in thousandths (250 = 0.25).
    pub threshold_milli: u32,
}

impl StabilityPolicy {
    fn threshold(&self) -> f64 {
        self.threshold_milli as f64 / 1000.0
    }
}

impl ApproxPolicy for StabilityPolicy {
    fn policy_id(&self) -> String {
        PolicySpec::Stability { threshold_milli: self.threshold_milli }.label()
    }

    fn plan(&self, total_steps: usize, _base: &SamplingPlan) -> Vec<StepAction> {
        (0..total_steps)
            .map(|i| {
                if i < STABILITY_WARMUP {
                    StepAction::Full
                } else if (i - STABILITY_WARMUP) % STABILITY_REFRESH == STABILITY_REFRESH - 1 {
                    StepAction::Full
                } else {
                    StepAction::Partial(2)
                }
            })
            .collect()
    }

    fn on_step_decision(&self, i: usize, stats: &TrajectoryStats) -> StepDecision {
        // Warmup steps are already Full; past them, refresh when the
        // trajectory destabilizes — but never more often than one
        // forced Full per STABILITY_OVERRIDE_SPACING steps (the MAC
        // bound depends on this cap, not on the threshold).
        if i >= STABILITY_WARMUP
            && stats.steps_since_full >= STABILITY_OVERRIDE_SPACING
            && stats.ewma_delta > self.threshold()
        {
            StepDecision::Override(StepAction::Full)
        } else {
            StepDecision::Planned
        }
    }

    fn needs_trajectory(&self) -> bool {
        true
    }

    fn describe(&self) -> String {
        format!(
            "online stability guidance (SADA-style): {STABILITY_WARMUP} warmup full steps, \
             static refresh every {STABILITY_REFRESH} steps, plus an EWMA-triggered full \
             refresh (threshold {:.3}, at most one per {STABILITY_OVERRIDE_SPACING} steps) — \
             no calibration required",
            self.threshold()
        )
    }
}

// -------------------------------------------------------- text-precision

/// Word count at or below which a prompt is classed insensitive
/// (simple scenes tolerate aggressive activation quantization).
const SIMPLE_PROMPT_WORDS: usize = 4;

/// Per-prompt precision selection: prompt-class sensitivity decides the
/// `QuantScheme` when the request doesn't pin one. The classifier is a
/// deterministic function of the prompt text — short single-object
/// prompts (<= 4 words) run `w8a8`, medium prompts `fp16`, long
/// multi-object prompts (the sensitive class: many vocabulary tokens
/// competing for layout) stay at full precision. Steps follow the
/// request's declared plan unchanged.
pub struct TextPrecisionPolicy;

impl ApproxPolicy for TextPrecisionPolicy {
    fn policy_id(&self) -> String {
        PolicySpec::TextPrecision.label()
    }

    fn plan(&self, total_steps: usize, base: &SamplingPlan) -> Vec<StepAction> {
        base.actions(total_steps)
    }

    fn quant_override(&self, prompt: &str) -> Option<QuantScheme> {
        let words = prompt.split_whitespace().count();
        if words <= SIMPLE_PROMPT_WORDS {
            Some(QuantScheme::w8a8())
        } else if words <= 2 * SIMPLE_PROMPT_WORDS {
            Some(QuantScheme::fp16())
        } else {
            None // sensitive class: full precision
        }
    }

    fn describe(&self) -> String {
        format!(
            "text-conditioned precision: prompts of <= {SIMPLE_PROMPT_WORDS} words run w8a8, \
             <= {} words fp16, longer (sensitive) prompts full precision; \
             an explicit --quant always wins",
            2 * SIMPLE_PROMPT_WORDS
        )
    }
}

/// Fold a trajectory sample into the stats: `delta` is this step's
/// normalized eps change, `was_full` whether the *executed* action was
/// `Full`. Shared by the coordinator loop and the tests so both see
/// the same EWMA.
pub fn update_trajectory(stats: &mut TrajectoryStats, delta: f64, was_full: bool) {
    stats.last_delta = delta;
    stats.ewma_delta = if stats.ewma_delta == 0.0 { delta } else { 0.5 * stats.ewma_delta + 0.5 * delta };
    stats.steps_since_full = if was_full { 0 } else { stats.steps_since_full + 1 };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::plan::{plan_is_executable, PasConfig};

    fn fulls(actions: &[StepAction]) -> usize {
        actions.iter().filter(|a| matches!(a, StepAction::Full)).count()
    }

    #[test]
    fn policy_id_matches_spec_label() {
        // The invariant every cache-key and report surface leans on:
        // the built policy's id IS the spec's canonical label.
        for spec in PolicySpec::all() {
            assert_eq!(spec.build().policy_id(), spec.label());
        }
        let spec = PolicySpec::BlockCache { budget: 7 };
        assert_eq!(spec.build().policy_id(), "block-cache:7");
        let spec = PolicySpec::Stability { threshold_milli: 125 };
        assert_eq!(spec.build().policy_id(), "stability:125");
    }

    #[test]
    fn parse_roundtrips_every_label() {
        for spec in PolicySpec::all() {
            assert_eq!(PolicySpec::parse(&spec.label()), Some(spec));
        }
        assert_eq!(PolicySpec::parse("pas"), Some(PolicySpec::Pas));
        assert_eq!(
            PolicySpec::parse("block-cache"),
            Some(PolicySpec::BlockCache { budget: DEFAULT_BLOCK_BUDGET })
        );
        assert_eq!(
            PolicySpec::parse("stability"),
            Some(PolicySpec::Stability { threshold_milli: DEFAULT_STABILITY_MILLI })
        );
        assert_eq!(
            PolicySpec::parse("stability:90"),
            Some(PolicySpec::Stability { threshold_milli: 90 })
        );
        assert_eq!(PolicySpec::parse("block-cache:0"), None, "zero budget never reuses validly");
        assert_eq!(PolicySpec::parse("euler"), None);
        assert_eq!(PolicySpec::parse("block-cache:x"), None);
    }

    #[test]
    fn pas_policy_plan_is_bit_identical_to_sampling_plan_actions() {
        // The parity property the default policy's cache semantics rest
        // on: PasPolicy::plan == SamplingPlan::actions, action for
        // action, across plan shapes and step counts.
        let plans = [
            SamplingPlan::Full,
            SamplingPlan::Auto,
            SamplingPlan::Pas(PasConfig::pas25(4)),
            SamplingPlan::Pas(PasConfig::pas25(6)),
            SamplingPlan::Pas(PasConfig {
                t_sketch: 10,
                t_complete: 2,
                t_sparse: 3,
                l_sketch: 2,
                l_refine: 1,
            }),
        ];
        let policy = PasPolicy;
        for plan in &plans {
            for steps in [1, 3, 8, 25, 50] {
                assert_eq!(policy.plan(steps, plan), plan.actions(steps), "{plan:?} @ {steps}");
            }
        }
    }

    #[test]
    fn default_policies_skip_the_trajectory_machinery() {
        // The default path must stay computation-identical: only the
        // stability policy asks for per-step trajectory stats.
        assert!(!PolicySpec::Pas.build().needs_trajectory());
        assert!(!PolicySpec::BlockCache { budget: 3 }.build().needs_trajectory());
        assert!(!PolicySpec::TextPrecision.build().needs_trajectory());
        assert!(PolicySpec::Stability { threshold_milli: 250 }.build().needs_trajectory());
        // The spec-level mirror the server's solo-batching rule reads
        // must agree with the trait answer for every registry policy.
        for spec in PolicySpec::all() {
            assert_eq!(spec.online(), spec.build().needs_trajectory(), "{}", spec.label());
        }
    }

    #[test]
    fn block_cache_budget_bounds_staleness_and_stays_executable() {
        let base = SamplingPlan::Pas(PasConfig::pas25(8));
        for budget in 1..=6 {
            let policy = BlockCachePolicy { budget };
            let actions = policy.plan(25, &base);
            assert_eq!(actions.len(), 25);
            assert!(plan_is_executable(&actions));
            // No Partial ever runs with a cache older than the budget.
            let mut staleness = 0usize;
            for a in &actions {
                match a {
                    StepAction::Full => staleness = 0,
                    StepAction::Partial(_) => {
                        assert!(staleness < budget, "stale reuse beyond budget {budget}");
                        staleness += 1;
                    }
                }
            }
            // The budget only adds refreshes relative to the base plan.
            assert!(fulls(&actions) >= fulls(&base.actions(25)));
        }
        // A generous budget reproduces the base plan exactly.
        let lax = BlockCachePolicy { budget: 100 };
        assert_eq!(lax.plan(25, &base), base.actions(25));
    }

    #[test]
    fn stability_skeleton_is_executable_and_beats_pas_macs_even_fully_overridden() {
        let policy = StabilityPolicy { threshold_milli: DEFAULT_STABILITY_MILLI };
        for steps in [1, 2, 3, 7, 25, 50] {
            let plan = policy.plan(steps, &SamplingPlan::Full);
            assert_eq!(plan.len(), steps);
            assert!(plan_is_executable(&plan), "{steps} steps");
        }
        // The constructive MAC bound at the bench's reference length:
        // even if the override fires at every opportunity, executed
        // Fulls stay below pas25(4)'s count at the same reuse level.
        let steps = 25;
        let pas_fulls = fulls(&SamplingPlan::Pas(PasConfig::pas25(4)).actions(steps));
        let worst_case_fulls =
            STABILITY_WARMUP + (steps - STABILITY_WARMUP) / STABILITY_OVERRIDE_SPACING;
        assert!(
            worst_case_fulls < pas_fulls,
            "worst-case stability fulls {worst_case_fulls} must beat PAS {pas_fulls}"
        );
        // And the static skeleton alone is sparser still.
        assert!(fulls(&policy.plan(steps, &SamplingPlan::Full)) < pas_fulls);
    }

    #[test]
    fn stability_overrides_are_rate_limited_and_threshold_gated() {
        let policy = StabilityPolicy { threshold_milli: 250 };
        let unstable = TrajectoryStats {
            ewma_delta: 1.0,
            last_delta: 1.0,
            steps_since_full: STABILITY_OVERRIDE_SPACING,
        };
        assert_eq!(
            policy.on_step_decision(10, &unstable),
            StepDecision::Override(StepAction::Full)
        );
        // Too soon after a Full: rate limit holds regardless of EWMA.
        let recent = TrajectoryStats { steps_since_full: 1, ..unstable };
        assert_eq!(policy.on_step_decision(10, &recent), StepDecision::Planned);
        // Stable trajectory: no refresh.
        let calm = TrajectoryStats { ewma_delta: 0.01, last_delta: 0.01, steps_since_full: 10 };
        assert_eq!(policy.on_step_decision(10, &calm), StepDecision::Planned);
        // Warmup steps are already Full — never overridden.
        assert_eq!(policy.on_step_decision(0, &unstable), StepDecision::Planned);
    }

    #[test]
    fn trajectory_update_tracks_ewma_and_full_distance() {
        let mut s = TrajectoryStats::default();
        update_trajectory(&mut s, 0.4, true);
        assert_eq!(s.steps_since_full, 0);
        assert!((s.ewma_delta - 0.4).abs() < 1e-12, "first sample seeds the EWMA");
        update_trajectory(&mut s, 0.2, false);
        assert_eq!(s.steps_since_full, 1);
        assert!((s.ewma_delta - 0.3).abs() < 1e-12);
        assert!((s.last_delta - 0.2).abs() < 1e-12);
        update_trajectory(&mut s, 0.1, false);
        assert_eq!(s.steps_since_full, 2);
        assert!((s.ewma_delta - 0.2).abs() < 1e-12);
    }

    #[test]
    fn text_precision_classifies_prompts_deterministically() {
        let policy = TextPrecisionPolicy;
        // Simple single-object prompt: aggressive quantization.
        assert_eq!(policy.quant_override("red circle x4 y4"), Some(QuantScheme::w8a8()));
        // Medium prompt: fp16.
        assert_eq!(
            policy.quant_override("red circle x4 y4 blue square"),
            Some(QuantScheme::fp16())
        );
        // Long multi-object prompt: sensitive, full precision.
        assert_eq!(
            policy.quant_override("red circle x4 y4 blue square x11 y11 green stripe x8 y8"),
            None
        );
        // Plan passes through untouched.
        let base = SamplingPlan::Pas(PasConfig::pas25(4));
        assert_eq!(policy.plan(25, &base), base.actions(25));
    }

    #[test]
    fn labels_are_distinct_across_the_registry_and_parameterizations() {
        let mut labels: Vec<String> = PolicySpec::all().iter().map(PolicySpec::label).collect();
        labels.push(PolicySpec::BlockCache { budget: 9 }.label());
        labels.push(PolicySpec::Stability { threshold_milli: 9 }.label());
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len(), "labels must be collision-free: {labels:?}");
    }
}
