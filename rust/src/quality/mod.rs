//! Image-quality proxies + image I/O (S14).
//!
//! Real CLIP/FID/IS need pretrained evaluation networks that cannot run
//! here (DESIGN.md substitution table). The proxies used across Table
//! II/III benches:
//!
//! - latent PSNR vs. the full-sampling reference trajectory (same seed) —
//!   monotone in approximation aggressiveness, like CLIP/FID are used;
//! - a diagonal-covariance Fréchet distance between pooled image-feature
//!   statistics of two batches ("FID-proxy").

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;
use crate::util::stats;

/// Latent-space PSNR (dB) against a reference, dynamic range ~[-2, 2].
pub fn latent_psnr(latent: &Tensor, reference: &Tensor) -> f64 {
    stats::psnr(latent.data(), reference.data(), 4.0)
}

/// Pooled feature vector of an RGB image tensor (HW, 3): 4x4 grid of
/// per-cell channel means + global channel stds -> 51 dims.
pub fn image_features(img: &Tensor, h: usize, w: usize) -> Vec<f64> {
    assert_eq!(img.dims, vec![h * w, 3], "expect (HW, 3) image");
    let cells = 4usize;
    let (ch, cw) = (h / cells, w / cells);
    let mut feats = Vec::with_capacity(cells * cells * 3 + 3);
    for cy in 0..cells {
        for cx in 0..cells {
            let mut sum = [0.0f64; 3];
            for y in cy * ch..(cy + 1) * ch {
                for x in cx * cw..(cx + 1) * cw {
                    let base = (y * w + x) * 3;
                    for c in 0..3 {
                        sum[c] += img.data()[base + c] as f64;
                    }
                }
            }
            let n = (ch * cw) as f64;
            feats.extend(sum.iter().map(|s| s / n));
        }
    }
    for c in 0..3 {
        let vals: Vec<f64> = img.data()[c..].iter().step_by(3).map(|&v| v as f64).collect();
        feats.push(stats::stddev(&vals));
    }
    feats
}

/// FID-proxy between two image batches.
pub fn frechet_proxy(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    stats::frechet_diag(a, b)
}

/// Write an RGB image tensor (HW, 3), values ~[0,1], as binary PPM.
pub fn write_ppm(img: &Tensor, h: usize, w: usize, path: &Path) -> Result<()> {
    if img.dims != vec![h * w, 3] {
        bail!("write_ppm: shape {:?} != ({}, 3)", img.dims, h * w);
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write!(f, "P6\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = img
        .data()
        .iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
        .collect();
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_image(h: usize, w: usize, rgb: [f32; 3]) -> Tensor {
        let mut data = Vec::with_capacity(h * w * 3);
        for _ in 0..h * w {
            data.extend_from_slice(&rgb);
        }
        Tensor::new(vec![h * w, 3], data).unwrap()
    }

    #[test]
    fn psnr_monotone_in_noise() {
        let a = Tensor::new(vec![8, 2], vec![0.1; 16]).unwrap();
        let mut b_small = a.clone();
        let mut b_big = a.clone();
        for (i, (s, l)) in b_small
            .make_mut()
            .iter_mut()
            .zip(b_big.make_mut().iter_mut())
            .enumerate()
        {
            let delta = if i % 2 == 0 { 1.0 } else { -1.0 };
            *s += 0.01 * delta;
            *l += 0.3 * delta;
        }
        assert!(latent_psnr(&b_small, &a) > latent_psnr(&b_big, &a));
    }

    #[test]
    fn features_have_expected_len_and_values() {
        let img = flat_image(16, 16, [0.25, 0.5, 0.75]);
        let f = image_features(&img, 16, 16);
        assert_eq!(f.len(), 4 * 4 * 3 + 3);
        assert!((f[0] - 0.25).abs() < 1e-6);
        assert!((f[1] - 0.5).abs() < 1e-6);
        // Flat image -> zero std.
        assert!(f[48].abs() < 1e-9);
    }

    #[test]
    fn frechet_separates_distinct_batches() {
        let a: Vec<Vec<f64>> = (0..8)
            .map(|i| image_features(&flat_image(16, 16, [0.2 + 0.01 * i as f32, 0.4, 0.6]), 16, 16))
            .collect();
        let b: Vec<Vec<f64>> = (0..8)
            .map(|i| image_features(&flat_image(16, 16, [0.8, 0.1 + 0.01 * i as f32, 0.3]), 16, 16))
            .collect();
        assert!(frechet_proxy(&a, &a) < 1e-9);
        assert!(frechet_proxy(&a, &b) > 0.5);
    }

    #[test]
    fn ppm_roundtrip_header() {
        let img = flat_image(4, 4, [1.0, 0.0, 0.5]);
        let path = std::env::temp_dir().join("sdacc_test.ppm");
        write_ppm(&img, 4, 4, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n4 4\n255\n"));
        assert_eq!(bytes.len(), 11 + 4 * 4 * 3);
        assert_eq!(&bytes[11..14], &[255, 0, 128]);
    }
}
