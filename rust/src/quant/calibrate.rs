//! Activation-range calibration for mixed-precision search.
//!
//! Quantiser scales need the dynamic range of every activation tensor.
//! Two collection paths produce a [`QuantProfile`]:
//!
//! - [`synthetic_profile`]: deterministic per-block ranges derived from
//!   the operator inventory (seeded by layer name), for the real SD
//!   architectures that cannot execute here — mirrors the Fig. 13
//!   shallow-vs-middle activation/weight contrast and gives the
//!   attention-logit tensors the heavy tails that motivate the
//!   sensitivity pass (SDP, arXiv 2403.04982, keeps those high-precision).
//! - [`QuantCalibrator`]: measured ranges over real denoising
//!   trajectories of the runnable model (the `unet_calib` artifact's
//!   eps + per-up-block tensors), the same path `pas::calibrate` drives.
//!
//! Profiles are cached in the `quant` cache namespace, keyed like
//! calibration reports (manifest digest + steps + prompts + guidance),
//! so a manifest rebuild invalidates them.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cache::Cache;
use crate::coordinator::Coordinator;
use crate::models::inventory::{unet_ops, UNetArch};
use crate::runtime::{Input, Runtime, Tensor};
use crate::scheduler::{make_sampler, NoiseSchedule};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats;

/// Observed dynamic range of one named tensor (or tensor group).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRange {
    pub name: String,
    pub lo: f32,
    pub hi: f32,
    /// Largest absolute value — what a symmetric absmax scale clips to.
    pub absmax: f32,
    /// 99th percentile of |x| — the bulk of the distribution.
    pub p99: f32,
}

/// Calibrated activation ranges for one model / trajectory setting.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantProfile {
    pub model: String,
    pub steps: usize,
    pub prompts: usize,
    pub ranges: Vec<LayerRange>,
}

impl QuantProfile {
    /// Range entry for an op name: exact match, else the longest prefix
    /// entry whose name is followed by a `.` separator in `name` (so
    /// "down1" matches "down1.conv1" but not "down12.conv1").
    pub fn range_for(&self, name: &str) -> Option<&LayerRange> {
        let mut best: Option<&LayerRange> = None;
        for r in &self.ranges {
            if r.name == name {
                return Some(r);
            }
            let matches = name
                .strip_prefix(&r.name)
                .map_or(false, |rest| rest.starts_with('.'));
            if matches && best.map_or(true, |b| r.name.len() > b.name.len()) {
                best = Some(r);
            }
        }
        best
    }

    /// Dynamic-range factor: how much worse absmax-scaled quantisation is
    /// for this tensor than for a well-behaved Gaussian. absmax/p99 ~ 1.7
    /// for a Gaussian (4 sigma vs 2.33 sigma); heavy-tailed tensors
    /// (attention logits) push it far higher. Clamped to [0.5, 8].
    pub fn drf(&self, name: &str) -> f64 {
        match self.range_for(name) {
            None => 1.0,
            Some(r) => {
                if r.p99 <= 0.0 || r.absmax <= 0.0 {
                    return 1.0;
                }
                let ratio = r.absmax as f64 / r.p99 as f64 / 1.72;
                (ratio * ratio).clamp(0.5, 8.0)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("steps", Json::num(self.steps as f64)),
            ("prompts", Json::num(self.prompts as f64)),
            (
                "ranges",
                Json::Arr(
                    self.ranges
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::str(&r.name)),
                                ("lo", Json::num(r.lo as f64)),
                                ("hi", Json::num(r.hi as f64)),
                                ("absmax", Json::num(r.absmax as f64)),
                                ("p99", Json::num(r.p99 as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<QuantProfile> {
        let ranges = j
            .get("ranges")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("quant profile: missing ranges"))?
            .iter()
            .map(|r| {
                let field = |k: &str| {
                    r.get_f64(k)
                        .ok_or_else(|| anyhow!("quant profile range: missing '{k}'"))
                };
                Ok(LayerRange {
                    name: r
                        .get_str("name")
                        .ok_or_else(|| anyhow!("quant profile range: missing name"))?
                        .to_string(),
                    lo: field("lo")? as f32,
                    hi: field("hi")? as f32,
                    absmax: field("absmax")? as f32,
                    p99: field("p99")? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(QuantProfile {
            model: j.get_str("model").unwrap_or("").to_string(),
            steps: j.get_usize("steps").unwrap_or(0),
            prompts: j.get_usize("prompts").unwrap_or(0),
            ranges,
        })
    }
}

// ------------------------------------------------------------ accumulation

/// Streaming min/max/absmax plus a bounded deterministic sample of |x|
/// for the percentile. The sample decimates itself as the stream grows
/// (keep-every-k with k doubling whenever the buffer fills, dropping
/// every other retained sample), so it stays spread over the WHOLE
/// observed stream rather than freezing on the first few tensors — and
/// it is a pure function of the stream, no RNG, so repeated runs agree
/// exactly.
#[derive(Debug, Clone)]
pub struct RangeAccum {
    lo: f32,
    hi: f32,
    absmax: f32,
    samples: Vec<f64>,
    seen: usize,
    keep_every: usize,
}

const MAX_SAMPLES: usize = 4096;

impl RangeAccum {
    pub fn new() -> RangeAccum {
        RangeAccum {
            lo: f32::INFINITY,
            hi: f32::NEG_INFINITY,
            absmax: 0.0,
            samples: Vec::new(),
            seen: 0,
            keep_every: 1,
        }
    }

    pub fn observe(&mut self, data: &[f32]) {
        for &x in data {
            self.lo = self.lo.min(x);
            self.hi = self.hi.max(x);
            self.absmax = self.absmax.max(x.abs());
            if self.seen % self.keep_every == 0 {
                if self.samples.len() >= MAX_SAMPLES {
                    // Halve the retained sample and the keep rate: the
                    // buffer always covers the stream seen so far.
                    self.samples = self.samples.iter().copied().step_by(2).collect();
                    self.keep_every *= 2;
                }
                if self.seen % self.keep_every == 0 {
                    self.samples.push(x.abs() as f64);
                }
            }
            self.seen += 1;
        }
    }

    pub fn finish(&self, name: &str) -> LayerRange {
        LayerRange {
            name: name.to_string(),
            lo: if self.lo.is_finite() { self.lo } else { 0.0 },
            hi: if self.hi.is_finite() { self.hi } else { 0.0 },
            absmax: self.absmax,
            p99: stats::percentile(&self.samples, 99.0) as f32,
        }
    }
}

impl Default for RangeAccum {
    fn default() -> Self {
        RangeAccum::new()
    }
}

// --------------------------------------------------------------- synthetic

/// Deterministic per-block profile for an architecture that cannot run
/// here: one entry per paper block (resnet body) plus a `.tf` entry for
/// blocks carrying transformers, whose attention logits get heavy tails.
/// Seeded by layer name, so the profile is identical across processes.
pub fn synthetic_profile(arch: &UNetArch, steps: usize) -> QuantProfile {
    let ops = unet_ops(arch);
    let mut ranges: Vec<LayerRange> = Vec::new();
    let mut push_entry = |name: String, heavy_tail: bool| {
        if ranges.iter().any(|r| r.name == name) {
            return;
        }
        let mut rng = Pcg32::new(crate::cache::key::fnv1a(name.as_bytes()), 0x517);
        let sigma = 0.8 + 0.4 * rng.next_f32();
        let p99 = 2.33 * sigma * (1.0 + 0.1 * rng.next_f32());
        let tail = if heavy_tail { 3.0 + rng.next_f32() } else { 1.0 + 0.3 * rng.next_f32() };
        let absmax = 4.0 * sigma * tail;
        ranges.push(LayerRange { name, lo: -absmax, hi: absmax, absmax, p99 });
    };
    for op in &ops {
        let block = op.block.label();
        // Transformer sub-ops are named "<block>.tf..." by the builder.
        if op.name.contains(".tf") {
            push_entry(format!("{block}.tf"), true);
        } else {
            push_entry(block, false);
        }
    }
    QuantProfile {
        model: arch.name.to_string(),
        steps,
        prompts: 0,
        ranges,
    }
}

// ----------------------------------------------------------------- runtime

/// Measured range collection over real denoising trajectories: drives the
/// `unet_calib` artifact (the same one `pas::calibrate` uses) and
/// accumulates ranges for the predicted noise and every up-block input.
pub struct QuantCalibrator<'a> {
    coord: &'a Coordinator,
}

impl<'a> QuantCalibrator<'a> {
    pub fn new(coord: &'a Coordinator) -> Self {
        QuantCalibrator { coord }
    }

    pub fn run(
        &self,
        prompts: &[String],
        steps: usize,
        guidance: f32,
    ) -> Result<QuantProfile> {
        let rt = self.coord.runtime();
        let n_blocks = 12usize;
        let mut eps_acc = RangeAccum::new();
        let mut latent_acc = RangeAccum::new();
        let mut up_accs: Vec<RangeAccum> = vec![RangeAccum::new(); n_blocks];

        for (pi, prompt) in prompts.iter().enumerate() {
            let ctx = Arc::new(self.coord.encode_prompts(std::slice::from_ref(prompt))?);
            let mut latent = Tensor::stack(&[self.coord.init_latent(3000 + pi as u64)])?;
            let sched = NoiseSchedule::new(rt.manifest().alpha_bar.clone());
            let mut sampler = make_sampler("ddim", sched, steps);
            let ts = sampler.timesteps().to_vec();
            let g = Arc::new(Tensor::scalar(guidance));

            for (i, &t) in ts.iter().enumerate() {
                latent_acc.observe(latent.data());
                let t_in = Tensor::new(vec![1], vec![t as f32])?;
                let out = rt.execute(
                    &Runtime::unet_calib(1),
                    &[
                        Input::F32(latent.clone()),
                        Input::F32(t_in),
                        Input::F32Ref(Arc::clone(&ctx)),
                        Input::F32Ref(Arc::clone(&g)),
                    ],
                )?;
                let mut it = out.into_iter();
                let eps = it.next().ok_or_else(|| anyhow!("missing eps"))?;
                let ups: Vec<Tensor> = it.collect();
                if ups.len() != n_blocks {
                    anyhow::bail!("calib artifact returned {} block inputs", ups.len());
                }
                eps_acc.observe(eps.data());
                for (b, u) in ups.iter().enumerate() {
                    up_accs[b].observe(u.data());
                }
                sampler.step_mut(i, latent.make_mut(), eps.data());
            }
        }

        let mut ranges = vec![eps_acc.finish("eps"), latent_acc.finish("latent")];
        for (b, acc) in up_accs.iter().enumerate() {
            ranges.push(acc.finish(&format!("up{}", b + 1)));
        }
        Ok(QuantProfile {
            model: "runtime".into(),
            steps,
            prompts: prompts.len(),
            ranges,
        })
    }

    /// Cache-aware collection: warm starts return the stored profile
    /// (keyed on manifest digest + steps + prompts + guidance) without
    /// running a trajectory. The boolean is true on a cache hit.
    pub fn run_cached(
        &self,
        cache: &Cache,
        prompts: &[String],
        steps: usize,
        guidance: f32,
    ) -> Result<(QuantProfile, bool)> {
        if let Some(p) = cache.get_quant_profile(steps, prompts, guidance) {
            return Ok((p, true));
        }
        let p = self.run(prompts, steps, guidance)?;
        cache.put_quant_profile(steps, prompts, guidance, &p)?;
        Ok((p, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inventory::{sd_tiny, sd_v14};

    #[test]
    fn synthetic_profile_is_deterministic_and_covers_blocks() {
        let a = synthetic_profile(&sd_v14(), 50);
        let b = synthetic_profile(&sd_v14(), 50);
        assert_eq!(a, b, "same arch, same profile");
        // 12 down + mid + 12 up bodies, plus .tf entries for attention
        // levels — comfortably more than 25 entries, fewer than per-op.
        assert!(a.ranges.len() > 25 && a.ranges.len() < 80, "{} entries", a.ranges.len());
        assert!(a.ranges.iter().any(|r| r.name == "mid"));
        assert!(a.ranges.iter().any(|r| r.name == "down2.tf"));
    }

    #[test]
    fn prefix_lookup_respects_separators() {
        let p = synthetic_profile(&sd_v14(), 50);
        let hit = p.range_for("down2.conv1").expect("down2 body entry");
        assert_eq!(hit.name, "down2");
        // Transformer sub-op resolves to the longer .tf entry.
        let tf = p.range_for("down2.tf.d0.logits").expect("down2.tf entry");
        assert_eq!(tf.name, "down2.tf");
        // "down1" must not swallow "down12" ops.
        let deep = p.range_for("down12.conv1").expect("down12 entry");
        assert_eq!(deep.name, "down12");
        assert!(p.range_for("nonexistent").is_none());
    }

    #[test]
    fn heavy_tailed_tf_entries_have_higher_drf() {
        let p = synthetic_profile(&sd_tiny(), 20);
        let body = p.drf("down2.conv1");
        let tf = p.drf("down2.tf.d0.logits");
        assert!(tf > 2.0 * body, "tf drf {tf} vs body {body}");
        assert_eq!(p.drf("unknown.layer"), 1.0);
        assert!((0.5..=8.0).contains(&tf));
    }

    #[test]
    fn profile_json_roundtrip_exact() {
        let p = synthetic_profile(&sd_tiny(), 20);
        let back =
            QuantProfile::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn range_accum_tracks_extremes_and_percentile() {
        let mut acc = RangeAccum::new();
        // 1000 small values and one outlier.
        let mut data = vec![0.5f32; 500];
        data.extend(vec![-0.5f32; 500]);
        data.push(100.0);
        acc.observe(&data);
        let r = acc.finish("x");
        assert_eq!(r.lo, -0.5);
        assert_eq!(r.hi, 100.0);
        assert_eq!(r.absmax, 100.0);
        // p99 of |x| stays near the bulk, far below the outlier.
        assert!(r.p99 <= 1.0, "p99 {}", r.p99);
        // Deterministic across identical streams.
        let mut acc2 = RangeAccum::new();
        acc2.observe(&data);
        assert_eq!(acc2.finish("x"), r);
    }
}
