//! Numeric formats and fake-quantisation (the "diverse weight and
//! activation sizes" axis, paper Sec. I issue 3).
//!
//! The runnable artifacts execute in fp32 — reduced precision is
//! *modelled* (hwsim costing) and *emulated* (fake-quant round-trips over
//! `runtime::Tensor` data), the standard software proxy for mixed-
//! precision accelerators (SDP, arXiv 2403.04982; "Speed Is All You
//! Need", arXiv 2304.11267). Four formats cover the design space the
//! related work sweeps: int4/int8 symmetric or affine integers (per-
//! tensor or per-channel scales) and fp16/fp32 floats (fp16 applies real
//! round-to-nearest-even at the 10-bit mantissa boundary).

use crate::runtime::Tensor;

/// A storage/compute format for one tensor operand. Variant order is
/// ascending precision, so `Ord` gives "at least as precise as" and
/// `a.max(b)` picks the safer format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NumericFormat {
    Int4,
    Int8,
    Fp16,
    Fp32,
}

impl NumericFormat {
    pub fn bits(self) -> usize {
        match self {
            NumericFormat::Int4 => 4,
            NumericFormat::Int8 => 8,
            NumericFormat::Fp16 => 16,
            NumericFormat::Fp32 => 32,
        }
    }

    /// Bytes per element (int4 packs two elements per byte).
    pub fn bytes(self) -> f64 {
        self.bits() as f64 / 8.0
    }

    pub fn is_float(self) -> bool {
        matches!(self, NumericFormat::Fp16 | NumericFormat::Fp32)
    }

    pub fn label(self) -> &'static str {
        match self {
            NumericFormat::Int4 => "int4",
            NumericFormat::Int8 => "int8",
            NumericFormat::Fp16 => "fp16",
            NumericFormat::Fp32 => "fp32",
        }
    }

    pub fn parse(s: &str) -> Option<NumericFormat> {
        match s {
            "int4" | "i4" | "4" => Some(NumericFormat::Int4),
            "int8" | "i8" | "8" => Some(NumericFormat::Int8),
            "fp16" | "f16" | "16" => Some(NumericFormat::Fp16),
            "fp32" | "f32" | "32" => Some(NumericFormat::Fp32),
            _ => None,
        }
    }

    /// Largest representable symmetric integer magnitude (int formats).
    pub fn qmax(self) -> Option<f32> {
        match self {
            NumericFormat::Int4 => Some(7.0),
            NumericFormat::Int8 => Some(127.0),
            _ => None,
        }
    }

    /// Noise-to-signal power proxy of quantising a ~Gaussian tensor to
    /// this format (symmetric, ~4-sigma clipping): MSE/sigma^2 ≈
    /// (2·4σ/2^b)^2 / 12 / σ^2 = 5.33·4^-b for b-bit integers; floats use
    /// their effective mantissa width. Feeds the latent-PSNR proxy in
    /// [`crate::quant::search::predicted_psnr_db`].
    pub fn quant_nsr(self) -> f64 {
        match self {
            NumericFormat::Int4 => 2.08e-2,
            NumericFormat::Int8 => 8.14e-5,
            // fp16: 11-bit effective mantissa.
            NumericFormat::Fp16 => 1.4e-7,
            NumericFormat::Fp32 => 1.0e-14,
        }
    }
}

/// A (weight, activation) format pair — the unit of assignment: one per
/// `LayerOp` in a searched plan, or one per request as the uniform
/// serving-path scheme ("W4A8" etc.).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QuantScheme {
    pub weight: NumericFormat,
    pub act: NumericFormat,
}

impl QuantScheme {
    pub fn new(weight: NumericFormat, act: NumericFormat) -> QuantScheme {
        QuantScheme { weight, act }
    }

    pub fn fp32() -> QuantScheme {
        QuantScheme::new(NumericFormat::Fp32, NumericFormat::Fp32)
    }

    pub fn fp16() -> QuantScheme {
        QuantScheme::new(NumericFormat::Fp16, NumericFormat::Fp16)
    }

    pub fn w8a8() -> QuantScheme {
        QuantScheme::new(NumericFormat::Int8, NumericFormat::Int8)
    }

    pub fn w4a8() -> QuantScheme {
        QuantScheme::new(NumericFormat::Int4, NumericFormat::Int8)
    }

    pub fn w4a4() -> QuantScheme {
        QuantScheme::new(NumericFormat::Int4, NumericFormat::Int4)
    }

    /// Multiplier width the MAC array must provision: the wider operand.
    pub fn mac_bits(self) -> usize {
        self.weight.bits().max(self.act.bits())
    }

    /// "W4A8" for mixed integers, "fp16"/"fp32" for uniform floats.
    pub fn label(self) -> String {
        if self.weight == self.act && self.weight.is_float() {
            self.weight.label().to_string()
        } else {
            format!("W{}A{}", self.weight.bits(), self.act.bits())
        }
    }

    /// Parse "fp32" | "fp16" | "w8a8" | "w4a8" | "w4a4" | "w<b>a<b>".
    pub fn parse(s: &str) -> Option<QuantScheme> {
        let s = s.to_lowercase();
        if let Some(f) = NumericFormat::parse(&s) {
            return Some(QuantScheme::new(f, f));
        }
        let rest = s.strip_prefix('w')?;
        let (w, a) = rest.split_once('a')?;
        Some(QuantScheme::new(NumericFormat::parse(w)?, NumericFormat::parse(a)?))
    }
}

/// Scale/zero-point granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    /// One scale per channel; element i belongs to channel `i % channels`
    /// (row-major (rows, channels) layout, the inventory convention).
    PerChannel,
}

/// Fitted quantisation parameters for one tensor: per-channel scale and
/// zero point (a single entry for per-tensor granularity). Float formats
/// carry no parameters — `fake_quant` applies mantissa rounding directly.
#[derive(Debug, Clone)]
pub struct Quantizer {
    pub format: NumericFormat,
    pub granularity: Granularity,
    /// Affine fits use the [0, 2^b - 1] code range with a zero point;
    /// symmetric fits use [-qmax, qmax]. (The flag, not a zero point of
    /// 0, decides the branch: affine fits of all-positive data land on a
    /// zero point of 0 and must still use the full unsigned code range.)
    pub affine: bool,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

fn channel_count(granularity: Granularity, channels: usize) -> usize {
    match granularity {
        Granularity::PerTensor => 1,
        Granularity::PerChannel => channels.max(1),
    }
}

impl Quantizer {
    /// Symmetric absmax fit: scale = absmax / qmax, zero point 0.
    pub fn fit_symmetric(
        data: &[f32],
        format: NumericFormat,
        granularity: Granularity,
        channels: usize,
    ) -> Quantizer {
        let nch = channel_count(granularity, channels);
        let mut scale = vec![0.0f32; nch];
        if let Some(qmax) = format.qmax() {
            let mut absmax = vec![0.0f32; nch];
            for (i, &x) in data.iter().enumerate() {
                let c = i % nch;
                absmax[c] = absmax[c].max(x.abs());
            }
            for (s, &m) in scale.iter_mut().zip(&absmax) {
                *s = if m > 0.0 { m / qmax } else { 0.0 };
            }
        }
        Quantizer { format, granularity, affine: false, scale, zero: vec![0.0; nch] }
    }

    /// Affine min/max fit: scale = range / (2^b - 1), zero point maps the
    /// minimum onto code 0 — better for one-sided (post-SiLU/GELU) data.
    /// The fitted range is extended to include 0 (the TFLite convention):
    /// it keeps the zero point a representable code, so ranges that do
    /// not cross zero (e.g. [10, 11]) quantise correctly instead of
    /// having their zero point clamped into nonsense.
    pub fn fit_affine(
        data: &[f32],
        format: NumericFormat,
        granularity: Granularity,
        channels: usize,
    ) -> Quantizer {
        let nch = channel_count(granularity, channels);
        let mut scale = vec![0.0f32; nch];
        let mut zero = vec![0.0f32; nch];
        if format.qmax().is_some() {
            let levels = ((1usize << format.bits()) - 1) as f32;
            let mut lo = vec![f32::INFINITY; nch];
            let mut hi = vec![f32::NEG_INFINITY; nch];
            for (i, &x) in data.iter().enumerate() {
                let c = i % nch;
                lo[c] = lo[c].min(x);
                hi[c] = hi[c].max(x);
            }
            for c in 0..nch {
                let (l, h) = (lo[c].min(0.0), hi[c].max(0.0));
                let range = h - l;
                if range.is_finite() && range > 0.0 {
                    scale[c] = range / levels;
                    zero[c] = (-l / scale[c]).round().clamp(0.0, levels);
                }
            }
        }
        Quantizer { format, granularity, affine: true, scale, zero }
    }

    /// Quantise-dequantise round trip (fake quant). Integer formats with
    /// a zero scale (constant/empty input) pass values through unchanged.
    pub fn fake_quant(&self, data: &[f32]) -> Vec<f32> {
        match self.format {
            NumericFormat::Fp32 => data.to_vec(),
            NumericFormat::Fp16 => data.iter().map(|&x| f16_round(x)).collect(),
            f => {
                let qmax = f.qmax().expect("integer format");
                let levels = ((1usize << f.bits()) - 1) as f32;
                let nch = self.scale.len();
                data.iter()
                    .enumerate()
                    .map(|(i, &x)| {
                        let c = i % nch;
                        let s = self.scale[c];
                        if s == 0.0 {
                            return x;
                        }
                        if self.affine {
                            // Affine: codes in [0, 2^b - 1].
                            let z = self.zero[c];
                            let q = (x / s + z).round().clamp(0.0, levels);
                            (q - z) * s
                        } else {
                            // Symmetric: codes in [-qmax, qmax].
                            (x / s).round().clamp(-qmax, qmax) * s
                        }
                    })
                    .collect()
            }
        }
    }

    pub fn fake_quant_tensor(&self, t: &Tensor) -> Tensor {
        Tensor::new(t.dims.clone(), self.fake_quant(t.data()))
            .expect("fake quant preserves element count")
    }
}

/// One-call fake quant: symmetric fit + round trip.
pub fn fake_quant(
    data: &[f32],
    format: NumericFormat,
    granularity: Granularity,
    channels: usize,
) -> Vec<f32> {
    Quantizer::fit_symmetric(data, format, granularity, channels).fake_quant(data)
}

/// In-place per-tensor symmetric activation emulation — the coordinator
/// applies this to the U-Net eps output every step when a request carries
/// a quant scheme, so reduced-precision requests produce (deterministic)
/// reduced-precision latents.
pub fn emulate_activations(data: &mut [f32], format: NumericFormat) {
    match format {
        NumericFormat::Fp32 => {}
        NumericFormat::Fp16 => {
            for x in data.iter_mut() {
                *x = f16_round(*x);
            }
        }
        _ => {
            let q = Quantizer::fit_symmetric(data, format, Granularity::PerTensor, 1);
            let out = q.fake_quant(data);
            data.copy_from_slice(&out);
        }
    }
}

// ------------------------------------------------------------------- fp16

/// Round an f32 to the nearest representable fp16 value (ties to even),
/// returned as f32. Overflow saturates to +-inf, |x| < 2^-24 flushes to
/// signed zero — IEEE 754 binary16 semantics without a half-float dep.
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 -> binary16 bit pattern, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (quietened).
        return sign | 0x7c00 | (((man != 0) as u16) << 9);
    }
    let e16 = exp - 127 + 15;
    if e16 >= 31 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // underflow -> signed zero
        }
        // Subnormal: drop (14 - e16) mantissa bits of the full 24-bit
        // significand, rounding to nearest-even.
        let full = man | 0x0080_0000;
        let shift = (14 - e16) as u32;
        let lsb = 1u32 << shift;
        let half = lsb >> 1;
        let mut v = full >> shift;
        let rem = full & (lsb - 1);
        if rem > half || (rem == half && v & 1 == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    let mut v = ((e16 as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && v & 1 == 1) {
        v += 1; // carry may roll into the exponent (and into inf) — correct
    }
    sign | v as u16
}

/// binary16 bit pattern -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // Subnormal (or zero): value = man * 2^-24, exactly representable.
        let mag = man as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp + 127 - 15) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rms(a: &[f32], b: &[f32]) -> f64 {
        (a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let d = x as f64 - y as f64;
                d * d
            })
            .sum::<f64>()
            / a.len() as f64)
            .sqrt()
    }

    #[test]
    fn f16_exact_values_roundtrip() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, -2.75, 65504.0, 6.103515625e-5] {
            assert_eq!(f16_round(x), x, "{x} must be fp16-exact");
        }
    }

    #[test]
    fn f16_overflow_and_underflow() {
        assert_eq!(f16_round(1e5), f32::INFINITY);
        assert_eq!(f16_round(-1e5), f32::NEG_INFINITY);
        assert_eq!(f16_round(1e-9), 0.0);
        assert!(f16_round(-1e-9).to_bits() == (-0.0f32).to_bits());
        assert!(f16_round(f32::NAN).is_nan());
    }

    #[test]
    fn f16_ties_round_to_even() {
        // fp16 spacing at 2048 is 2: 2049 sits exactly between 2048 and
        // 2050 and must round to the even mantissa (2048).
        assert_eq!(f16_round(2049.0), 2048.0);
        assert_eq!(f16_round(2051.0), 2052.0);
    }

    #[test]
    fn f16_subnormals_quantise() {
        // Smallest subnormal is 2^-24; 1.4e-45-scale f32s flush to zero,
        // values near 2^-24 snap to multiples of it.
        let ulp = 1.0f32 / 16_777_216.0;
        assert_eq!(f16_round(ulp), ulp);
        assert_eq!(f16_round(2.4 * ulp), 2.0 * ulp);
    }

    #[test]
    fn int8_beats_int4_on_gaussian_data() {
        let mut rng = Pcg32::seeded(7);
        let data = rng.gaussian_vec(4096);
        let e8 = rms(&fake_quant(&data, NumericFormat::Int8, Granularity::PerTensor, 1), &data);
        let e4 = rms(&fake_quant(&data, NumericFormat::Int4, Granularity::PerTensor, 1), &data);
        assert!(e8 < e4 / 4.0, "int8 rms {e8} vs int4 {e4}");
        // Round-trip error is bounded by half the step size.
        let q = Quantizer::fit_symmetric(&data, NumericFormat::Int8, Granularity::PerTensor, 1);
        let back = q.fake_quant(&data);
        let bound = q.scale[0] as f64 * 0.5 + 1e-6;
        for (x, y) in data.iter().zip(&back) {
            assert!((*x as f64 - *y as f64).abs() <= bound);
        }
    }

    #[test]
    fn per_channel_beats_per_tensor_on_skewed_channels() {
        // Channel 0 is 100x larger than channel 1: a shared absmax scale
        // wipes out channel 1's resolution.
        let mut rng = Pcg32::seeded(11);
        let n = 1024;
        let mut data = Vec::with_capacity(2 * n);
        for _ in 0..n {
            data.push((rng.next_f32() * 2.0 - 1.0) * 100.0);
            data.push(rng.next_f32() * 2.0 - 1.0);
        }
        let pt = fake_quant(&data, NumericFormat::Int8, Granularity::PerTensor, 2);
        let pc = fake_quant(&data, NumericFormat::Int8, Granularity::PerChannel, 2);
        let ch1 = |v: &[f32]| v.iter().skip(1).step_by(2).copied().collect::<Vec<f32>>();
        let e_pt = rms(&ch1(&pt), &ch1(&data));
        let e_pc = rms(&ch1(&pc), &ch1(&data));
        assert!(e_pc < e_pt / 10.0, "per-channel {e_pc} vs per-tensor {e_pt}");
    }

    #[test]
    fn affine_beats_symmetric_on_one_sided_data() {
        // Post-SiLU-style data in [0, 1]: symmetric wastes half the codes.
        let mut rng = Pcg32::seeded(13);
        let data: Vec<f32> = (0..4096).map(|_| rng.next_f32()).collect();
        let sym = Quantizer::fit_symmetric(&data, NumericFormat::Int4, Granularity::PerTensor, 1);
        let aff = Quantizer::fit_affine(&data, NumericFormat::Int4, Granularity::PerTensor, 1);
        let e_sym = rms(&sym.fake_quant(&data), &data);
        let e_aff = rms(&aff.fake_quant(&data), &data);
        assert!(e_aff < e_sym, "affine {e_aff} vs symmetric {e_sym}");
        // Regression: an affine fit of all-positive data lands on a zero
        // point of 0 and must still use the full unsigned code range —
        // every element stays within half a step, nothing is clipped.
        let back = aff.fake_quant(&data);
        let bound = aff.scale[0] as f64 * 0.5 + 1e-6;
        for (x, y) in data.iter().zip(&back) {
            assert!((*x as f64 - *y as f64).abs() <= bound, "clipped: {x} -> {y}");
        }
    }

    #[test]
    fn affine_handles_ranges_that_exclude_zero() {
        // The fitted range is zero-extended, so data living entirely
        // above (or below) zero round-trips within half a step instead
        // of being collapsed by a clamped zero point.
        for sign in [1.0f32, -1.0] {
            let data: Vec<f32> =
                (0..=255).map(|i| sign * (10.0 + i as f32 / 255.0)).collect();
            let q = Quantizer::fit_affine(&data, NumericFormat::Int8, Granularity::PerTensor, 1);
            let back = q.fake_quant(&data);
            let bound = q.scale[0] as f64 * 0.5 + 1e-4;
            for (x, y) in data.iter().zip(&back) {
                assert!(
                    (*x as f64 - *y as f64).abs() <= bound,
                    "sign {sign}: {x} -> {y} (bound {bound})"
                );
            }
        }
    }

    #[test]
    fn fp32_and_constant_inputs_pass_through() {
        let data = vec![1.25f32, -3.5, 0.0];
        assert_eq!(fake_quant(&data, NumericFormat::Fp32, Granularity::PerTensor, 1), data);
        let zeros = vec![0.0f32; 8];
        assert_eq!(fake_quant(&zeros, NumericFormat::Int8, Granularity::PerTensor, 1), zeros);
    }

    #[test]
    fn tensor_roundtrip_keeps_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.1, -0.2, 0.3, 1.0, -1.0, 0.5]).unwrap();
        let q = Quantizer::fit_symmetric(t.data(), NumericFormat::Int8, Granularity::PerChannel, 3);
        let out = q.fake_quant_tensor(&t);
        assert_eq!(out.dims, t.dims);
        assert!(rms(out.data(), t.data()) < 0.01);
    }

    #[test]
    fn emulate_activations_is_deterministic_and_lossy() {
        let mut rng = Pcg32::seeded(17);
        let orig = rng.gaussian_vec(256);
        let mut a = orig.clone();
        let mut b = orig.clone();
        emulate_activations(&mut a, NumericFormat::Int8);
        emulate_activations(&mut b, NumericFormat::Int8);
        assert_eq!(a, b, "same input, same output");
        assert_ne!(a, orig, "int8 emulation must actually quantise");
        let mut c = orig.clone();
        emulate_activations(&mut c, NumericFormat::Fp32);
        assert_eq!(c, orig, "fp32 is the identity");
    }

    #[test]
    fn scheme_labels_and_parsing() {
        assert_eq!(QuantScheme::w8a8().label(), "W8A8");
        assert_eq!(QuantScheme::w4a8().label(), "W4A8");
        assert_eq!(QuantScheme::fp16().label(), "fp16");
        for s in ["fp32", "fp16", "w8a8", "w4a8", "w4a4", "W8A16"] {
            let parsed = QuantScheme::parse(s).expect(s);
            assert_eq!(parsed.label().to_lowercase(), s.to_lowercase());
        }
        assert!(QuantScheme::parse("w3a7").is_none());
        assert_eq!(QuantScheme::w4a8().mac_bits(), 8);
        assert_eq!(QuantScheme::fp32().mac_bits(), 32);
    }

    #[test]
    fn format_order_is_ascending_precision() {
        assert!(NumericFormat::Int4 < NumericFormat::Int8);
        assert!(NumericFormat::Int8 < NumericFormat::Fp16);
        assert!(NumericFormat::Fp16 < NumericFormat::Fp32);
        assert_eq!(NumericFormat::Int4.max(NumericFormat::Fp16), NumericFormat::Fp16);
        // NSR proxy is monotone in precision.
        assert!(NumericFormat::Int4.quant_nsr() > NumericFormat::Int8.quant_nsr());
        assert!(NumericFormat::Int8.quant_nsr() > NumericFormat::Fp16.quant_nsr());
    }
}
