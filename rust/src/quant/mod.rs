//! Mixed-precision quantization subsystem (S15).
//!
//! SD-Acc names three workload problems (Sec. I): redundant sampling
//! compute (covered by [`pas`](crate::pas)), heterogeneous operators
//! (covered by [`hwsim`](crate::hwsim)), and **diverse weight and
//! activation sizes** — this module. It assigns a per-layer numeric
//! format to every `LayerOp` in the inventory and propagates that choice
//! end-to-end: quantisers and fake-quant emulation, activation-range
//! calibration, a quality-aware bit-width search, precision-scaled
//! hwsim costing, and a `quant` field on the serving request path.
//!
//! File map (paper section / related-work citation each reproduces):
//!
//! - [`format`]: int4/int8/fp16/fp32 symmetric & affine quantisers,
//!   per-tensor and per-channel, with exact binary16 rounding — the
//!   reduced-precision layouts of "Speed Is All You Need" (Chen et al.,
//!   arXiv 2304.11267) and the int datapath of the SDP processor (Choi
//!   et al., arXiv 2403.04982).
//! - [`calibrate`]: activation-range collection (min/max + percentile)
//!   over deterministic synthetic inventories or measured denoising
//!   trajectories (the `unet_calib` artifact `pas::calibrate` drives),
//!   producing a cacheable [`QuantProfile`] — the calibration step of
//!   every post-training-quantisation flow, keyed like Fig. 4 reports.
//! - [`search`]: quality-aware bit-width assignment in the Fig. 7
//!   optimisation-framework shape — enumerate, gate on a latent-PSNR
//!   proxy (DESIGN.md substitution for CLIP/FID), keep the Pareto set
//!   over precision-scaled energy — with a sensitivity pass pinning
//!   first/last convolutions and attention-softmax inputs to fp16, the
//!   layer set SDP exempts from its text-conditioned int datapath.
//!
//! Cross-cutting integration: `hwsim::simulate_quant` scales cycles,
//! DRAM traffic and SA energy with operand bytes and MAC width (so a
//! W4A8 plan shows up in every `Report` axis), `pas::cost::CostModel`
//! composes Eq. 3 with the multiplier-width saving, the coordinator
//! fake-quants U-Net outputs for requests carrying a scheme (batched
//! under a `quant`-aware `BatchKey`), profiles persist in the `quant`
//! cache namespace under manifest-hash invalidation, and the
//! `sd-acc quant calibrate|search|report` CLI drives the whole loop.

pub mod calibrate;
pub mod format;
pub mod search;

pub use calibrate::{synthetic_profile, LayerRange, QuantCalibrator, QuantProfile};
pub use format::{
    emulate_activations, f16_round, fake_quant, Granularity, NumericFormat, QuantScheme,
    Quantizer,
};
pub use search::{
    assign, enumerate_schemes, is_fragile, predicted_psnr_db, search, QuantCandidate,
    QuantConstraints, QuantSearcher,
};
